"""L2 JAX model: the compute graphs the Rust coordinator executes.

Three exported computations, each lowered once by aot.py:

  * ``ring_matmul``  — blocked Z_2^64 matmul (calls the L1 Pallas kernel);
    the coordinator's generic local-product primitive.
  * ``esd``          — the fused distance kernel D' = U − 2·X·muT in ring
    space (L1 Pallas), used by each party's local distance term.
  * ``kmeans_step``  — one full plaintext float32 Lloyd iteration
    (distance via the float ESD + argmin + masked mean), used for
    initialization strategies and cleartext validation inside Rust.

Python never runs at protocol time: these graphs are AOT-lowered to HLO
text and executed through PJRT by rust/src/runtime/.
"""

import jax
import jax.numpy as jnp

from compile.kernels.esd import esd_pallas
from compile.kernels.ring_matmul import ring_matmul_pallas

jax.config.update("jax_enable_x64", True)


def ring_matmul(x, y):
    """Z_2^64 matmul via the Pallas blocked kernel."""
    return (ring_matmul_pallas(x, y),)


def esd(x, mu):
    """Ring-space distance matrix via the Pallas ESD kernel."""
    return (esd_pallas(x, mu),)


def kmeans_step(x, mu):
    """One plaintext Lloyd iteration (float32).

    Distance reuses the ESD formulation; assignment and update are dense
    XLA ops so the whole step fuses into one executable.
    """
    k = mu.shape[0]
    u = jnp.sum(mu * mu, axis=1)[None, :]
    d = u - 2.0 * (x @ mu.T)
    assign = jnp.argmin(d, axis=1)
    onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)
    counts = jnp.sum(onehot, axis=0)
    sums = onehot.T @ x
    safe = jnp.maximum(counts, 1.0)[:, None]
    new_mu = jnp.where(counts[:, None] > 0, sums / safe, mu)
    return (new_mu, counts)
