"""AOT lowering: JAX/Pallas computations → HLO text artifacts.

HLO *text* (not serialized HloModuleProto) is the interchange format: the
image's xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit instruction ids,
while the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Emits ``artifacts/<name>.hlo.txt`` plus ``artifacts/manifest.tsv`` with
one line per entry::

    name<TAB>file<TAB>kind<TAB>dtype<TAB>shape1;shape2;...<TAB>out_shape

Shape-specialized entries (HLO bakes shapes): the Rust runtime pads and
tiles arbitrary operands onto these canonical shapes (runtime/tiled.rs).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402


def to_hlo_text(fn, specs):
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def entries():
    """(name, fn, kind, dtype, input shapes, output shape) to export."""
    i64 = jnp.int64
    f32 = jnp.float32
    out = []
    # Generic ring matmul tiles (block-multiple shapes).
    for b in (128, 256):
        out.append(
            (
                f"ring_matmul_{b}",
                model.ring_matmul,
                "ring_matmul",
                i64,
                [(b, b), (b, b)],
                (b, b),
            )
        )
    # Fused ESD distance tile: 256-row blocks, d padded to 128 columns,
    # k padded to 16 clusters (zero-padding is exact in ring space).
    out.append(
        (
            "esd_256x128x16",
            model.esd,
            "esd",
            i64,
            [(256, 128), (16, 128)],
            (256, 16),
        )
    )
    # Plaintext Lloyd step for the quickstart / validation path.
    for (n, d, k) in [(1000, 4, 3), (64, 4, 2)]:
        out.append(
            (
                f"kmeans_step_{n}x{d}x{k}",
                model.kmeans_step,
                "kmeans_step",
                f32,
                [(n, d), (k, d)],
                (k, d),
            )
        )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    lines = []
    for name, fn, kind, dtype, shapes, out_shape in entries():
        specs = [spec(s, dtype) for s in shapes]
        text = to_hlo_text(fn, specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        dt = "i64" if dtype == jnp.int64 else "f32"
        shp = ";".join(",".join(str(x) for x in s) for s in shapes)
        osh = ",".join(str(x) for x in out_shape)
        lines.append(f"{name}\t{fname}\t{kind}\t{dt}\t{shp}\t{osh}")
        print(f"wrote {fname} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.tsv"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"manifest: {len(lines)} entries")


if __name__ == "__main__":
    main()
