"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package must
match its reference here bit-exactly (integer ring ops) or to float
tolerance (plaintext ops). pytest + hypothesis sweep shapes against them.
"""

import jax
import jax.numpy as jnp


def ring_matmul_ref(x, y):
    """Matrix product in Z_2^64 (int64 two's-complement wrap).

    XLA integer arithmetic wraps, so a plain matmul in int64 *is* the
    ring product mod 2^64.
    """
    assert x.dtype == jnp.int64 and y.dtype == jnp.int64
    return jnp.matmul(x, y)


def esd_ref(x, mu):
    """Ring-space D' = U - 2*X*muT (paper Eq. 3), scale 2f.

    x:  (n, d) int64 fixed-point encodings (scale f)
    mu: (k, d) int64 fixed-point encodings (scale f)
    returns (n, k) int64 at scale 2f.
    """
    u = jnp.sum(mu * mu, axis=1)[None, :]  # (1, k), scale 2f
    xmu = jnp.matmul(x, mu.T)  # (n, k), scale 2f
    return u - 2 * xmu


def esd_f32_ref(x, mu):
    """Plaintext float D' (for the cleartext k-means step)."""
    u = jnp.sum(mu * mu, axis=1)[None, :]
    return u - 2.0 * (x @ mu.T)


def kmeans_step_ref(x, mu):
    """One full plaintext Lloyd iteration (float32).

    Returns (new_mu, assignments, counts). Empty clusters keep their old
    centroid (mirrors the secure protocol's oblivious fallback).
    """
    d = esd_f32_ref(x, mu)  # (n, k); row-constant |x|^2 omitted
    assign = jnp.argmin(d, axis=1)  # (n,)
    onehot = jax.nn.one_hot(assign, mu.shape[0], dtype=x.dtype)  # (n, k)
    counts = jnp.sum(onehot, axis=0)  # (k,)
    sums = onehot.T @ x  # (k, d)
    safe = jnp.maximum(counts, 1.0)[:, None]
    new_mu = jnp.where(counts[:, None] > 0, sums / safe, mu)
    return new_mu, assign, counts
