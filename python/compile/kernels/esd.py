"""L1 Pallas kernel: blocked ring-space ESD distance (paper Eq. 3).

The paper's vectorization insight — compute `D' = U − 2·X·muT` as one
matrix operation instead of n·k scalar interactions — maps to TPU as a
tiled kernel: the grid walks row-blocks of X; each step keeps one
(block_n × d) tile of X and the whole (k × d) centroid panel resident in
VMEM, fusing the matmul with the broadcast subtract so D' never
round-trips through HBM at intermediate precision.

Hardware adaptation notes (DESIGN.md §Hardware-Adaptation):
  * ring arithmetic is int64; XLA integers wrap, giving Z_2^64 exactly;
  * `interpret=True` always — CPU PJRT cannot execute Mosaic
    custom-calls; on real TPU the same BlockSpec schedule drives the MXU;
  * block_n is chosen so the working set (block_n·d + k·d + block_n·k
    int64 words) fits a ≤16 MiB VMEM budget (see vmem_bytes()).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 256


def _esd_kernel(x_ref, mu_ref, u_ref, o_ref):
    """One grid step: o = u − 2·x·muT for a row-block of X."""
    x = x_ref[...]          # (bn, d)   int64, scale f
    mu = mu_ref[...]        # (k, d)    int64, scale f
    u = u_ref[...]          # (1, k)    int64, scale 2f
    xmu = jax.lax.dot_general(
        x,
        mu,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int64,
    )                        # (bn, k), scale 2f — wraps mod 2^64
    o_ref[...] = u - 2 * xmu


def vmem_bytes(block_n: int, d: int, k: int) -> int:
    """Estimated VMEM working set of one grid step (int64 words)."""
    return 8 * (block_n * d + k * d + k + block_n * k)


@functools.partial(jax.jit, static_argnames=("block_n",))
def esd_pallas(x, mu, block_n: int = DEFAULT_BLOCK_N):
    """Blocked D' = U − 2·X·muT over Z_2^64.

    x: (n, d) int64, mu: (k, d) int64; n must be a multiple of block_n
    (aot.py pads); returns (n, k) int64 at scale 2f.
    """
    n, d = x.shape
    k = mu.shape[0]
    assert n % block_n == 0, f"n={n} not a multiple of block_n={block_n}"
    u = jnp.sum(mu * mu, axis=1, dtype=jnp.int64)[None, :]  # (1, k)
    grid = (n // block_n,)
    return pl.pallas_call(
        _esd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.int64),
        interpret=True,  # CPU PJRT path; Mosaic lowering is TPU-only
    )(x, mu, u)
