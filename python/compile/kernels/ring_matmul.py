"""L1 Pallas kernel: blocked matrix multiplication over Z_2^64.

The generic local-compute primitive behind the coordinator's hot path:
every party-local product (`X_A·(mu_A)T`, `(C_A)T·X_A`, Beaver
recombination terms E·V, U·F) is a ring matmul. The kernel tiles all
three dimensions so arbitrary (m, k, n) dispatch through a small set of
AOT-compiled shapes with padding (runtime/tiled.rs).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _matmul_kernel(x_ref, y_ref, o_ref, *, steps):
    """Grid (i, j, s): accumulate x(i,s)·y(s,j) into o(i,j)."""
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int64,
    )


@functools.partial(jax.jit, static_argnames=("block",))
def ring_matmul_pallas(x, y, block: int = DEFAULT_BLOCK):
    """x (m×t) · y (t×n) mod 2^64, all dims multiples of `block`."""
    m, t = x.shape
    t2, n = y.shape
    assert t == t2
    assert m % block == 0 and t % block == 0 and n % block == 0, (
        f"shape ({m},{t},{n}) not multiple of {block}"
    )
    steps = t // block
    grid = (m // block, n // block, steps)
    kernel = functools.partial(_matmul_kernel, steps=steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, s: (i, s)),
            pl.BlockSpec((block, block), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int64),
        interpret=True,
    )(x, y)
