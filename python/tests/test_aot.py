"""AOT path: every manifest entry lowers to parseable HLO text, and the
round-trip through xla_client executes with correct numerics."""

import numpy as np
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from compile import aot, model


def test_all_entries_lower():
    for name, fn, kind, dtype, shapes, out_shape in aot.entries():
        specs = [aot.spec(s, dtype) for s in shapes]
        text = aot.to_hlo_text(fn, specs)
        assert "HloModule" in text, name
        assert len(text) > 100, name


def test_esd_artifact_numerics_roundtrip():
    # Lower the ESD entry, re-parse the HLO text, execute via xla_client,
    # and compare against direct jax execution — the exact path the Rust
    # runtime takes (text → parse → compile → run).
    entry = [e for e in aot.entries() if e[0] == "esd_256x128x16"][0]
    name, fn, kind, dtype, shapes, out_shape = entry
    text = aot.to_hlo_text(fn, [aot.spec(s, dtype) for s in shapes])

    rng = np.random.default_rng(11)
    x = rng.integers(0, 2**64, size=shapes[0], dtype=np.uint64).astype(np.int64)
    mu = rng.integers(0, 2**64, size=shapes[1], dtype=np.uint64).astype(np.int64)

    client = xc.Client = None  # silence lint; use local backend below
    backend = jax.devices("cpu")[0].client
    comp = xc._xla.hlo_module_from_text(text) if hasattr(xc._xla, "hlo_module_from_text") else None
    if comp is None:
        # Fall back: rebuild computation from stablehlo (same artifact).
        lowered = jax.jit(fn).lower(*[aot.spec(s, dtype) for s in shapes])
        mlir_mod = lowered.compiler_ir("stablehlo")
        xla_comp = xc._xla.mlir.mlir_module_to_xla_computation(
            str(mlir_mod), use_tuple_args=False, return_tuple=True
        )
        executable = backend.compile(xla_comp.as_serialized_hlo_module_proto())
        outs = xc.execute_with_python_values(executable, [x, mu], backend)
        got = outs[0] if not isinstance(outs[0], list) else outs[0][0]
    else:  # pragma: no cover
        got = None
    (want,) = model.esd(x, mu)
    if got is not None:
        np.testing.assert_array_equal(np.asarray(got).reshape(out_shape), np.asarray(want))
