"""L2 correctness: model graphs vs references and invariants."""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels import ref


def test_kmeans_step_matches_ref():
    rng = np.random.default_rng(3)
    x = rng.random((50, 4)).astype(np.float32)
    mu = rng.random((3, 4)).astype(np.float32)
    got_mu, got_counts = model.kmeans_step(x, mu)
    want_mu, _, want_counts = ref.kmeans_step_ref(x, mu)
    np.testing.assert_allclose(np.asarray(got_mu), np.asarray(want_mu), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got_counts), np.asarray(want_counts))


def test_kmeans_step_reduces_inertia():
    rng = np.random.default_rng(4)
    # Two well-separated blobs.
    a = rng.normal(0.2, 0.02, size=(30, 2))
    b = rng.normal(0.8, 0.02, size=(30, 2))
    x = np.vstack([a, b]).astype(np.float32)
    mu = np.array([[0.4, 0.4], [0.6, 0.6]], dtype=np.float32)

    def inertia(mu_):
        d = np.asarray(ref.esd_f32_ref(x, mu_))
        return float(np.sum(np.min(d, axis=1)))

    i0 = inertia(mu)
    mu1, _ = model.kmeans_step(x, mu)
    i1 = inertia(np.asarray(mu1))
    assert i1 <= i0 + 1e-6


def test_kmeans_step_empty_cluster_keeps_centroid():
    x = np.full((10, 2), 0.1, dtype=np.float32)
    mu = np.array([[0.1, 0.1], [9.0, 9.0]], dtype=np.float32)
    new_mu, counts = model.kmeans_step(x, mu)
    assert np.asarray(counts)[1] == 0
    np.testing.assert_allclose(np.asarray(new_mu)[1], mu[1])


def test_ring_matmul_model_wraps():
    rng = np.random.default_rng(9)
    x = rng.integers(0, 2**64, size=(128, 128), dtype=np.uint64).astype(np.int64)
    y = rng.integers(0, 2**64, size=(128, 128), dtype=np.uint64).astype(np.int64)
    (got,) = model.ring_matmul(x, y)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.ring_matmul_ref(x, y)))
