"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and values; ring ops must match bit-exactly
(Z_2^64 wrap included).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels.esd import esd_pallas, vmem_bytes
from compile.kernels.ring_matmul import ring_matmul_pallas
from compile.kernels import ref


def rand_i64(rng, shape):
    # Full-range 64-bit ring elements (shares are uniform).
    return rng.integers(0, 2**64, size=shape, dtype=np.uint64).astype(np.int64)


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(1, 3),
    d=st.integers(1, 24),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31),
    block_n=st.sampled_from([8, 32]),
)
def test_esd_matches_ref_bit_exact(n_blocks, d, k, seed, block_n):
    rng = np.random.default_rng(seed)
    n = n_blocks * block_n
    x = rand_i64(rng, (n, d))
    mu = rand_i64(rng, (k, d))
    got = esd_pallas(x, mu, block_n=block_n)
    want = ref.esd_ref(x, mu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    mi=st.integers(1, 3),
    ti=st.integers(1, 3),
    ni=st.integers(1, 3),
    seed=st.integers(0, 2**31),
)
def test_ring_matmul_matches_ref_bit_exact(mi, ti, ni, seed):
    block = 16
    rng = np.random.default_rng(seed)
    x = rand_i64(rng, (mi * block, ti * block))
    y = rand_i64(rng, (ti * block, ni * block))
    got = ring_matmul_pallas(x, y, block=block)
    want = ref.ring_matmul_ref(x, y)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_esd_wraps_mod_2_64():
    # Deliberate overflow: values near 2^63.
    x = np.full((8, 2), -(2**62), dtype=np.int64)
    mu = np.full((2, 2), 2**62 - 1, dtype=np.int64)
    got = esd_pallas(x, mu, block_n=8)
    want = ref.esd_ref(x, mu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fixed_point_semantics():
    # Fixed-point encoded reals should reproduce the float D' after scaling.
    f = 20
    rng = np.random.default_rng(7)
    xr = rng.random((16, 4))
    mur = rng.random((3, 4))
    x = np.round(xr * 2**f).astype(np.int64)
    mu = np.round(mur * 2**f).astype(np.int64)
    got = np.asarray(esd_pallas(x, mu, block_n=16)).astype(np.float64) / 2 ** (2 * f)
    want = np.sum(mur * mur, axis=1)[None, :] - 2 * xr @ mur.T
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_vmem_budget_default_blocks():
    # The canonical AOT tile must fit a 16 MiB VMEM budget.
    assert vmem_bytes(256, 128, 16) < 16 * 2**20
