//! Sparse optimization in action (paper §4.3 / Q4 flavour).
//!
//! Runs the same clustering with the dense Beaver path and with HE
//! Protocol 2 on a high-dimensional sparse dataset, and prints the
//! *online communication* of the distance step — the quantity the sparse
//! path shrinks from O(n·d) ring elements to O((d+n)·k) ciphertexts.

use ppkmeans::cli::Args;
use ppkmeans::data::sparse_gen;
use ppkmeans::kmeans::config::{EsdMode, Partition, SecureKmeansConfig};
use ppkmeans::kmeans::secure;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("n", 400);
    let d = args.get_usize("d", 32);
    let sparsity = args.get_f64("sparsity", 0.9);
    let k = 2;
    let iters = args.get_usize("iters", 3);

    println!("sparse optimization demo: n={n} d={d} sparsity={sparsity} k={k} t={iters}");
    let ds = sparse_gen::generate(n, d, k, sparsity, 77);
    println!("  measured sparsity: {:.3}", sparse_gen::measured_sparsity(&ds));

    let base = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: d / 2 },
        ..Default::default()
    };
    let dense = secure::run(&ds, &base).expect("dense run");

    let mut scfg = base.clone();
    scfg.esd = EsdMode::He { bits: 768 };
    let sparse = secure::run(&ds, &scfg).expect("sparse run");

    assert_eq!(
        dense.assignments, sparse.assignments,
        "both paths must produce identical clusterings"
    );

    let db = dense.meter_a.get("online.s1").bytes_sent + dense.meter_b.get("online.s1").bytes_sent;
    let sb =
        sparse.meter_a.get("online.s1").bytes_sent + sparse.meter_b.get("online.s1").bytes_sent;
    println!("  distance-step online traffic per run:");
    println!("    dense Beaver path : {db} bytes");
    println!("    sparse HE path    : {sb} bytes");
    println!(
        "  (identical assignments; HE trades bandwidth for compute — the\n   paper's bandwidth-constrained deployment regime)"
    );
    println!("sparse_scaling OK");
}
