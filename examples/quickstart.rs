//! Quickstart: two parties jointly cluster vertically partitioned data
//! without revealing their features.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ppkmeans::coordinator::Session;
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::kmeans::plaintext;
use ppkmeans::net::cost::CostModel;
use ppkmeans::runtime::dispatch;

fn main() {
    // 1 000 samples, 4 features (party A holds 2, party B holds 2),
    // 3 latent clusters.
    let data = BlobSpec::new(1000, 4, 3).generate(42);

    let cfg = SecureKmeansConfig {
        k: 3,
        iters: 10,
        partition: Partition::Vertical { d_a: 2 },
        ..Default::default()
    };
    let session = Session::new(cfg.clone()).with_link(CostModel::lan());
    let out = session.run(&data).expect("protocol run");

    println!("privacy-preserving K-means (two-party, semi-honest)");
    println!("  n=1000 d=4 k=3 iters={} (vertical split 2+2)", out.iters_run);
    println!("  PJRT artifacts: {}", if dispatch::available() { "loaded" } else { "native fallback" });
    for j in 0..out.k {
        let c: Vec<String> =
            out.centroids[j * out.d..(j + 1) * out.d].iter().map(|v| format!("{v:.3}")).collect();
        println!("  centroid {j}: [{}]", c.join(", "));
    }

    // Validate against plaintext K-means from the same initialization.
    let plain = plaintext::kmeans(&ppkmeans::data::normalize::min_max(&data), 3, 10, cfg.seed);
    let agree = out
        .assignments
        .iter()
        .zip(&plain.assignments)
        .filter(|(a, b)| a == b)
        .count();
    println!("  agreement with plaintext K-means: {agree}/1000");

    let online = out.meter_a.total_prefix("online.");
    println!(
        "  online traffic: {} bytes in {} rounds (party A)",
        online.bytes_sent, online.rounds
    );
    assert!(agree >= 990, "secure protocol must track plaintext trajectory");
    println!("quickstart OK");
}
