//! Horizontally partitioned clustering (paper §4.1/§4.2): each party
//! holds a disjoint set of *samples* with the full feature vector —
//! e.g. two regional branches pooling their transaction histories.

use ppkmeans::cli::Args;
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::kmeans::{plaintext, secure};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("n", 600);
    let k = args.get_usize("k", 3);
    let iters = args.get_usize("iters", 8);

    let mut spec = BlobSpec::new(n, 3, k);
    spec.spread = 0.03;
    let ds = spec.generate(11);

    let cfg = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Horizontal { n_a: n / 3 }, // uneven split
        ..Default::default()
    };
    let out = secure::run(&ds, &cfg).expect("secure horizontal run");
    let plain = plaintext::kmeans(&ds, k, iters, cfg.seed);

    let agree = out
        .assignments
        .iter()
        .zip(&plain.assignments)
        .filter(|(a, b)| a == b)
        .count();
    println!("horizontal partition: n={n} (A holds {}, B holds {})", n / 3, n - n / 3);
    println!("  agreement with plaintext trajectory: {agree}/{n}");
    for j in 0..k {
        let c: Vec<String> =
            out.centroids[j * 3..(j + 1) * 3].iter().map(|v| format!("{v:.3}")).collect();
        println!("  centroid {j}: [{}]", c.join(", "));
    }
    assert!(agree as f64 / n as f64 > 0.98);
    println!("horizontal_partition OK");
}
