//! End-to-end driver — the paper's Q5 deployment (§5.6).
//!
//! A payment company (18 transaction/user features) and a merchant (24
//! behaviour features) jointly cluster 10 000 transactions with the
//! privacy-preserving K-means, flag outliers as fraud, and score with
//! the Jaccard coefficient against ground truth. Three models compared,
//! as in the paper:
//!
//!   * ours (secure joint clustering)        — paper: J = 0.86
//!   * M-Kmeans (secure joint, GC baseline)  — paper: J = 0.83
//!   * plaintext K-means, payment data only  — paper: J = 0.62
//!
//! Shapes to reproduce: ours ≈ M-Kmeans ≫ single-party. Runtime numbers
//! are recorded in EXPERIMENTS.md. `--n`, `--iters`, `--runs` override
//! the defaults (paper: n = 10 000, 10 runs).

use ppkmeans::cli::Args;
use ppkmeans::data::fraud_gen;
use ppkmeans::fraud::{detect_outliers, jaccard, OutlierConfig};
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::kmeans::{plaintext, secure};
use ppkmeans::mkmeans::{self, MkmeansConfig};
use ppkmeans::util::stats::mean;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("n", 2_000); // --n 10000 for the paper size
    let runs = args.get_usize("runs", 3); // paper: 10
    let iters = args.get_usize("iters", 8);
    let k = args.get_usize("k", 4);
    let fraud_rate = 0.05;

    println!("fraud detection deployment (Q5): n={n}, k={k}, t={iters}, {runs} runs");
    let mut j_ours = vec![];
    let mut j_mk = vec![];
    let mut j_single = vec![];
    let ocfg = OutlierConfig { rate: fraud_rate, min_cluster_frac: 0.02 };

    for run in 0..runs {
        let f = fraud_gen::generate(n, fraud_rate, 1000 + run as u128);
        let ds = &f.data;

        // Ours: secure joint clustering over the vertical split 18 + 24.
        let cfg = SecureKmeansConfig {
            k,
            iters,
            seed: 7 + run as u128,
            partition: Partition::Vertical { d_a: f.d_payment },
            ..Default::default()
        };
        let ours = secure::run(ds, &cfg).expect("secure run");
        let flagged = detect_outliers(ds, &ours.centroids, &ours.assignments, k, &ocfg);
        j_ours.push(jaccard(&flagged, &f.outliers));

        // M-Kmeans baseline on the same data/split.
        let mcfg = MkmeansConfig { k, iters, seed: 7 + run as u128, d_a: f.d_payment };
        let mk = mkmeans::run_vertical(ds, &mcfg).expect("mkmeans run");
        let flagged = detect_outliers(ds, &mk.centroids, &mk.assignments, k, &ocfg);
        j_mk.push(jaccard(&flagged, &f.outliers));

        // Single-party plaintext: payment features only.
        let pay = f.payment_only();
        let plain = plaintext::kmeans(&pay, k, iters, 7 + run as u128);
        let flagged = detect_outliers(&pay, &plain.centroids, &plain.assignments, k, &ocfg);
        j_single.push(jaccard(&flagged, &f.outliers));

        println!(
            "  run {run}: ours J={:.3}  M-Kmeans J={:.3}  payment-only J={:.3}",
            j_ours[run], j_mk[run], j_single[run]
        );
    }

    let (jo, jm, js) = (mean(&j_ours), mean(&j_mk), mean(&j_single));
    println!("\naverage Jaccard over {runs} runs:");
    println!("  ours (secure joint):       {jo:.3}   (paper: 0.86)");
    println!("  M-Kmeans (secure joint):   {jm:.3}   (paper: 0.83)");
    println!("  plaintext, payment only:   {js:.3}   (paper: 0.62)");

    // The paper's qualitative claims.
    assert!((jo - jm).abs() < 0.15, "joint secure models must agree: {jo} vs {jm}");
    assert!(jo > js + 0.1, "joint modelling must beat single-party: {jo} vs {js}");
    println!("fraud_detection OK — joint secure ≈ M-Kmeans ≫ single-party");
}
