//! Packed ≡ scalar property tests for the `runtime::simd` kernels.
//!
//! The lane-width contract (see `runtime::simd`): the packed paths are
//! throughput-only — every kernel must be **bit-identical** to its
//! scalar reference at every supported width and at every odd tail
//! length. This file exercises the contract through the public API of
//! each rewritten hot path: Speck counter-mode batches, the multi-key
//! lockstep hash, the PRG bulk fill, the 64×64 bit transpose, and the
//! axpy / add / sub / truncation sweeps of the online phase. The
//! end-to-end version of the same contract (full train + serve at
//! lanes = 1 vs lanes = 8) lives in `rust/tests/lanes.rs`.

use ppkmeans::ring::matrix::Mat;
use ppkmeans::runtime::simd::{
    self, set_global_lanes, transpose64, Lanes, U64s,
};
use ppkmeans::ss::trunc::trunc_share;
use ppkmeans::util::cipher::{Speck128, SpeckMulti};
use ppkmeans::util::hash::{hash256, hash256_many};
use ppkmeans::util::prng::Prg;

const WIDTHS: [usize; 3] = [1, 4, 8];

/// Run `f` at the given global lane width, restoring the scalar default
/// afterwards (a racing test can only change throughput, never bits).
fn with_lanes<T>(width: usize, f: impl FnOnce() -> T) -> T {
    set_global_lanes(width);
    let out = f();
    set_global_lanes(1);
    out
}

#[test]
fn speck_packed_blocks_match_scalar_chain() {
    let key = Speck128::new(*b"simd-prop-key-01");
    let mut p = Prg::new(0x5EC);
    for _ in 0..20 {
        let xs0: [u64; 8] = std::array::from_fn(|_| p.next_u64());
        let ys0: [u64; 8] = std::array::from_fn(|_| p.next_u64());
        let (mut xs, mut ys) = (xs0, ys0);
        key.encrypt_blocks(&mut xs, &mut ys);
        let mut x4: [u64; 4] = xs0[..4].try_into().unwrap();
        let mut y4: [u64; 4] = ys0[..4].try_into().unwrap();
        key.encrypt_blocks(&mut x4, &mut y4);
        for i in 0..8 {
            let (mut x, mut y) = (xs0[i], ys0[i]);
            key.encrypt_words(&mut x, &mut y);
            assert_eq!((xs[i], ys[i]), (x, y), "8-lane {i}");
            if i < 4 {
                assert_eq!((x4[i], y4[i]), (x, y), "4-lane {i}");
            }
        }
    }
}

#[test]
fn multi_key_speck_matches_independent_instances() {
    let mut p = Prg::new(0x5EC2);
    let keys: [[u8; 16]; 8] = std::array::from_fn(|_| p.next_u128().to_le_bytes());
    let vs: [u128; 8] = std::array::from_fn(|_| p.next_u128());
    let multi = SpeckMulti::new(&keys);
    let got = multi.encrypt_u128s(&vs);
    for i in 0..8 {
        assert_eq!(
            got[i],
            Speck128::new(keys[i]).encrypt_u128(vs[i]),
            "lane {i}"
        );
    }
}

#[test]
fn prg_bulk_fill_is_width_independent() {
    // Odd lengths and misaligned buffers hit every branch: buffer drain,
    // packed batches, the leftover scalar-pair loop, the odd final word.
    for len in [0usize, 1, 7, 15, 16, 17, 33, 100, 257] {
        for misalign in [0usize, 1, 3] {
            let want = with_lanes(1, || {
                let mut p = Prg::new(0xB01_D);
                for _ in 0..misalign {
                    p.next_u64();
                }
                p.u64s(len)
            });
            for width in WIDTHS {
                let got = with_lanes(width, || {
                    let mut p = Prg::new(0xB01_D);
                    for _ in 0..misalign {
                        p.next_u64();
                    }
                    p.u64s(len)
                });
                assert_eq!(got, want, "len={len} misalign={misalign} width={width}");
            }
        }
    }
}

#[test]
fn lockstep_hash_is_width_independent_at_ragged_batches() {
    // 24-byte messages are the IKNP (index, row-key) shape; the other
    // lengths straddle the 16-byte block boundary.
    for len in [0usize, 5, 16, 24, 40] {
        for count in [1usize, 2, 7, 8, 9, 13, 17] {
            let msgs: Vec<Vec<u8>> = (0..count)
                .map(|i| (0..len).map(|j| (i * 131 + j * 7) as u8).collect())
                .collect();
            let refs: Vec<&[u8]> = msgs.iter().map(|m| m.as_slice()).collect();
            let want: Vec<[u8; 32]> = msgs.iter().map(|m| hash256(m)).collect();
            for width in WIDTHS {
                let got = with_lanes(width, || hash256_many(&refs));
                assert_eq!(got, want, "len={len} count={count} width={width}");
            }
        }
    }
}

#[test]
fn bit_transpose_matches_probe_and_involutes() {
    let mut p = Prg::new(0x7A05);
    for _ in 0..5 {
        let orig: [u64; 64] = std::array::from_fn(|_| p.next_u64());
        let mut t = orig;
        transpose64(&mut t);
        for i in 0..64 {
            for j in 0..64 {
                assert_eq!((t[j] >> i) & 1, (orig[i] >> j) & 1, "bit ({i},{j})");
            }
        }
        transpose64(&mut t);
        assert_eq!(t, orig, "transpose must be an involution");
    }
}

#[test]
fn axpy_and_word_sweeps_are_width_independent() {
    let mut p = Prg::new(0xA2B);
    for len in [0usize, 1, 3, 7, 8, 9, 29, 64, 65, 200] {
        let base = p.u64s(len);
        let b = p.u64s(len);
        let a = p.next_u64();
        let mut want_axpy = base.clone();
        let mut want_add = vec![0u64; len];
        let mut want_sub = Vec::new();
        for i in 0..len {
            want_axpy[i] = want_axpy[i].wrapping_add(a.wrapping_mul(b[i]));
            want_add[i] = base[i].wrapping_add(b[i]);
            want_sub.push(base[i].wrapping_sub(b[i]));
        }
        for width in WIDTHS {
            with_lanes(width, || {
                let mut got = base.clone();
                simd::axpy(&mut got, a, &b);
                assert_eq!(got, want_axpy, "axpy len={len} width={width}");
                let mut got = vec![0u64; len];
                simd::add_words(&mut got, &base, &b);
                assert_eq!(got, want_add, "add len={len} width={width}");
                let mut got = Vec::new();
                simd::sub_words_into(&mut got, &base, &b);
                assert_eq!(got, want_sub, "sub len={len} width={width}");
            });
        }
    }
}

#[test]
fn truncation_sweep_is_width_independent_and_correct() {
    let mut p = Prg::new(0x7121C);
    for len in [1usize, 6, 8, 17, 63] {
        let x = p.u64s(len);
        for party in [0usize, 1] {
            let want: Vec<u64> = x
                .iter()
                .map(|&v| simd::trunc_word(v, party, 20))
                .collect();
            for width in WIDTHS {
                let got = with_lanes(width, || simd::trunc_words(&x, party, 20));
                assert_eq!(got, want, "party={party} len={len} width={width}");
            }
        }
    }
}

#[test]
fn trunc_share_reconstructs_shifted_value_at_every_width() {
    // The SecureML guarantee, through the public ss::trunc API: for
    // shares whose sum is a small fixed-point value, the truncated
    // shares reconstruct the arithmetic shift of the sum (±1 ulp) — at
    // every lane width, identically.
    let mut p = Prg::new(0x515D);
    let vals: Vec<i64> = (0..40).map(|_| (p.next_u64() as i64) >> 24).collect();
    let n = vals.len();
    let mask: Vec<u64> = (0..n).map(|_| p.next_u64()).collect();
    let m0 = Mat {
        rows: 1,
        cols: n,
        data: mask.clone(),
    };
    let m1 = Mat {
        rows: 1,
        cols: n,
        data: vals
            .iter()
            .zip(&mask)
            .map(|(&v, &m)| (v as u64).wrapping_sub(m))
            .collect(),
    };
    let mut witness: Option<Vec<u64>> = None;
    for width in WIDTHS {
        let (t0, t1) = with_lanes(width, || {
            (trunc_share(0, &m0, 20), trunc_share(1, &m1, 20))
        });
        let recon: Vec<u64> = t0
            .data
            .iter()
            .zip(&t1.data)
            .map(|(&a, &b)| a.wrapping_add(b))
            .collect();
        for (i, &v) in vals.iter().enumerate() {
            let want = (v >> 20) as i64;
            let got = recon[i] as i64;
            assert!(
                (got - want).abs() <= 1,
                "width={width} i={i}: {got} vs {want}"
            );
        }
        match &witness {
            None => witness = Some(recon),
            Some(w) => assert_eq!(&recon, w, "width={width} must match scalar"),
        }
    }
}

#[test]
fn matmul_routes_through_axpy_identically() {
    // Mat::matmul's inner loop is the axpy sweep; whole products must be
    // width-independent (including the zero-skip path on sparse rows).
    let mut p = Prg::new(0x3A73);
    for (m, k, n) in [(1usize, 1usize, 1usize), (3, 5, 2), (7, 8, 9), (16, 16, 16)] {
        let mut a = Mat {
            rows: m,
            cols: k,
            data: p.u64s(m * k),
        };
        // Sprinkle zeros so the zero-skip branch is exercised.
        for i in (0..a.data.len()).step_by(3) {
            a.data[i] = 0;
        }
        let b = Mat {
            rows: k,
            cols: n,
            data: p.u64s(k * n),
        };
        let want = with_lanes(1, || a.matmul(&b));
        for width in [4usize, 8] {
            let got = with_lanes(width, || a.matmul(&b));
            assert_eq!(got.data, want.data, "{m}x{k}x{n} width={width}");
        }
    }
}

#[test]
fn lanes_knob_rounds_and_defaults_consistently() {
    assert_eq!(Lanes::default(), Lanes::scalar());
    assert_eq!(Lanes::auto().width, 8);
    assert_eq!(Lanes::new(6).width, 4);
    // The U64s block type itself round-trips slices.
    let v = U64s::<4>::from_slice(&[9, 8, 7, 6, 5]);
    let mut out = [0u64; 4];
    v.write(&mut out);
    assert_eq!(out, [9, 8, 7, 6]);
}
