//! End-to-end tests for the `ppkm-lint` binary over the committed
//! fixture trees (`tests/lint_fixtures/`): seeded violations must fail
//! the run naming rule, file and line; the trap tree (tokens hidden in
//! comments, strings, raw strings, test regions, or behind justified
//! suppressions) must come back clean; a typo'd policy file must be a
//! hard error, not a silently ignored directive.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures").join(name)
}

fn run_lint(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_ppkm-lint"))
        .args(args)
        .output()
        .expect("spawn ppkm-lint")
}

fn run_on(tree: &str) -> Output {
    let root = fixture(tree);
    run_lint(&["--root", root.to_str().expect("utf8 fixture path")])
}

#[test]
fn seeded_violations_fail_naming_rule_file_and_line() {
    let out = run_on("seeded");
    assert_eq!(out.status.code(), Some(1), "seeded tree must exit 1");
    let stdout = String::from_utf8_lossy(&out.stdout);
    // One expected finding per rule, with exact file:line anchors.
    for want in [
        "no-unordered-iteration: src/ss/bad_map.rs:3: `HashMap`",
        "no-wallclock-in-protocol: src/kmeans/clock.rs:4: `Instant`",
        "no-rogue-threads: src/offline/rogue.rs:4: `thread::spawn`",
        "no-unmetered-io: src/serve/raw_io.rs:3: `TcpStream`",
        "no-ambient-entropy: src/util/entropy.rs:4: `thread_rng`",
        "no-unchecked-open: src/serve/raw_open.rs:5: `reconstruct(`",
        "no-panic-in-wire-paths: src/net/panicky.rs:4: `.unwrap()`",
        "no-panic-in-wire-paths: src/net/panicky.rs:9: `panic!`",
    ] {
        assert!(stdout.contains(want), "missing `{want}` in:\n{stdout}");
    }
    // A suppression without a justification does not suppress.
    assert!(
        stdout.contains("no-panic-in-wire-paths: src/net/bare_allow.rs:5"),
        "{stdout}"
    );
    assert!(stdout.contains("without a justification"), "{stdout}");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("finding"), "stderr must count findings: {stderr}");
}

#[test]
fn trap_tree_is_clean() {
    // Comments (line, block, doc), plain/raw/byte strings, char
    // literals next to lifetimes, #[cfg(test)] regions and justified
    // suppressions: all token look-alikes, zero findings.
    let out = run_on("clean");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(0), "clean tree must exit 0: {stdout}");
    assert!(stdout.contains("clean"), "{stdout}");
}

#[test]
fn seeded_output_is_deterministic() {
    let a = run_on("seeded");
    let b = run_on("seeded");
    assert_eq!(a.stdout, b.stdout, "findings must come out in a stable order");
}

#[test]
fn typoed_policy_file_is_a_hard_error() {
    let out = run_on("badcfg");
    assert_eq!(out.status.code(), Some(2), "config errors must exit 2");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("lint.rules"), "error must name the policy file: {stderr}");
    assert!(stderr.contains("no-such-rule"), "error must name the bad id: {stderr}");
}

#[test]
fn list_prints_the_full_catalog() {
    let out = run_lint(&["--list"]);
    assert_eq!(out.status.code(), Some(0));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for id in [
        "no-unordered-iteration",
        "no-wallclock-in-protocol",
        "no-rogue-threads",
        "no-unmetered-io",
        "no-ambient-entropy",
        "no-unchecked-open",
        "no-panic-in-wire-paths",
    ] {
        assert!(stdout.contains(id), "missing {id} in:\n{stdout}");
    }
}

#[test]
fn the_repo_itself_is_lint_clean() {
    // The acceptance gate from the ISSUE, driven through the real
    // binary: the shipped tree with the shipped policy has zero
    // findings (every remaining suppression carries a justification).
    let out = run_lint(&[]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(0), "live tree must be clean:\n{stdout}");
}
