//! Sparse-path integration: Protocol 2 at realistic shapes, HE2SS
//! batching, and the communication claims of §4.3.

use ppkmeans::he::ou::Ou;
use ppkmeans::he::HeScheme;
use ppkmeans::net::run_two_party;
use ppkmeans::ring::matrix::Mat;
use ppkmeans::sparse::{protocol2, Csr};
use ppkmeans::ss::share::reconstruct;
use ppkmeans::util::prng::Prg;

fn keypair(bits: usize, seed: u128) -> (ppkmeans::he::ou::OuPk, ppkmeans::he::ou::OuSk) {
    let mut prg = Prg::new(seed);
    Ou::keygen(bits, &mut prg)
}

#[test]
fn protocol2_high_dimensional_one_hot() {
    // One-hot rows (the paper's motivating feature engineering): d ≫ k.
    let (n, d, k) = (12, 64, 3);
    let mut prg = Prg::new(21);
    let mut dense = Mat::zeros(n, d);
    for i in 0..n {
        let hot = prg.next_below(d as u64) as usize;
        dense.set(i, hot, 1 << 20); // fixed-point 1.0
    }
    let x = Csr::from_dense(&dense);
    assert_eq!(x.nnz(), n);
    let y = Mat::random(d, k, &mut prg);
    let want = dense.matmul(&y);

    let (pk, sk) = keypair(768, 33);
    let ct_width = Ou::ct_bytes(&pk);
    let pk_a = pk.clone();
    let xc = x.clone();
    let yc = y.clone();
    let ((ra, ma), (rb, _)) = run_two_party(
        move |c| {
            let mut prg = Prg::new(41);
            let z = protocol2::sparse_party::<Ou>(c, &pk_a, &xc, (d, k), &mut prg);
            reconstruct(c, &z)
        },
        move |c| {
            let mut prg = Prg::new(42);
            let z = protocol2::dense_party::<Ou>(c, &pk, &sk, &yc, n, &mut prg);
            reconstruct(c, &z)
        },
    );
    assert_eq!(ra, want);
    assert_eq!(rb, want);
    // §4.3 claim: traffic independent of d·n (the X size) — A ships only
    // n·k masked ciphertexts + the reconstruction.
    let expected = (n * k * ct_width) as u64 + (n * k * 8) as u64;
    assert_eq!(ma.total().bytes_sent, expected);
}

#[test]
fn protocol2_empty_matrix_and_full_matrix_edges() {
    let (n, d, k) = (4, 5, 2);
    let mut prg = Prg::new(51);
    for density in [0.0f64, 1.0] {
        let mut dense = Mat::zeros(n, d);
        if density > 0.0 {
            for v in dense.data.iter_mut() {
                *v = prg.next_u64();
            }
        }
        let x = Csr::from_dense(&dense);
        let y = Mat::random(d, k, &mut prg);
        let want = dense.matmul(&y);
        let (pk, sk) = keypair(768, 52);
        let pk_a = pk.clone();
        let yc = y.clone();
        let ((ra, _), _) = run_two_party(
            move |c| {
                let mut prg = Prg::new(61);
                let z = protocol2::sparse_party::<Ou>(c, &pk_a, &x, (d, k), &mut prg);
                reconstruct(c, &z)
            },
            move |c| {
                let mut prg = Prg::new(62);
                let z = protocol2::dense_party::<Ou>(c, &pk, &sk, &yc, n, &mut prg);
                reconstruct(c, &z)
            },
        );
        assert_eq!(ra, want, "density {density}");
    }
}

#[test]
fn comm_crossover_favors_he_when_d_large() {
    // Beaver online: (n·d + d·k) elements × 8 B per party.
    // Protocol 2: (d·k + n·k) ciphertexts. For d ≫ k, HE wins.
    let (pk, _) = keypair(768, 99);
    let ct = Ou::ct_bytes(&pk) as u64;
    let k = 2u64;
    let n = 1000u64;
    let beaver = |d: u64| (n * d + d * k) * 8;
    let he = |d: u64| (d * k + n * k) * ct;
    // Small d: Beaver cheaper; large d: HE cheaper (the paper's regime).
    assert!(beaver(4) < he(4));
    let d_big = 20_000;
    assert!(he(d_big) < beaver(d_big), "HE must win at d = {d_big}");
}
