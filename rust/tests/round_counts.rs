//! Round-count regression tests: flight budgets are a first-class,
//! regression-tested quantity of the round-batched protocol engine.
//!
//! All budgets are asserted on the quickstart config (n = 1000, d = 4,
//! k = 3, vertical 2+2) with the dealer-simulated offline phase, so the
//! numbers are exact and deterministic.

use ppkmeans::data::{blobs::BlobSpec, sparse_gen};
use ppkmeans::kmeans::assign::min_k_rounds;
use ppkmeans::kmeans::config::{EsdMode, Partition, SecureKmeansConfig, TileFlights};
use ppkmeans::kmeans::{plaintext, secure};
use ppkmeans::ss::boolean::CMP_ROUNDS;
use ppkmeans::ss::RoundPolicy;

const N: usize = 1000;
const D: usize = 4;
const K: usize = 3;
const ITERS: usize = 2;

fn quickstart_cfg(policy: RoundPolicy) -> SecureKmeansConfig {
    SecureKmeansConfig {
        k: K,
        iters: ITERS,
        partition: Partition::Vertical { d_a: D / 2 },
        round_policy: policy,
        ..Default::default()
    }
}

fn quickstart_data() -> ppkmeans::data::blobs::Dataset {
    let mut spec = BlobSpec::new(N, D, K);
    spec.spread = 0.02;
    spec.generate(42)
}

#[test]
fn s1_distance_is_one_flight_per_iteration() {
    let out = secure::run(&quickstart_data(), &quickstart_cfg(RoundPolicy::Coalesced)).unwrap();
    // Norm square + both Beaver cross products coalesce into one flight.
    assert_eq!(out.meter_a.get("online.s1").rounds, ITERS as u64);
}

#[test]
fn s2_assignment_budget_is_levels_times_cmp_plus_one() {
    let out = secure::run(&quickstart_data(), &quickstart_cfg(RoundPolicy::Coalesced)).unwrap();
    // ⌈log₂ k⌉ tree levels, each one CMP circuit + one fused MUX flight.
    let levels = (usize::BITS - (K - 1).leading_zeros()) as u64;
    let per_iter = levels * (CMP_ROUNDS + 1);
    assert_eq!(min_k_rounds(K), per_iter, "helper must agree with the formula");
    assert_eq!(out.meter_a.get("online.s2").rounds, ITERS as u64 * per_iter);
}

#[test]
fn batched_engine_at_least_halves_rounds_vs_gate_per_flight() {
    let data = quickstart_data();
    let batched = secure::run(&data, &quickstart_cfg(RoundPolicy::Coalesced)).unwrap();
    let pergate = secure::run(&data, &quickstart_cfg(RoundPolicy::PerGate)).unwrap();
    // Identical math, identical outputs…
    assert_eq!(batched.assignments, pergate.assignments);
    // …but the per-iteration online flight count must drop ≥ 2× (it
    // drops far more: every AND layer of every comparison coalesces).
    let rb = batched.meter_a.total_prefix("online.").rounds as f64 / ITERS as f64;
    let rp = pergate.meter_a.total_prefix("online.").rounds as f64 / ITERS as f64;
    assert!(
        rp >= 2.0 * rb,
        "gate-per-flight baseline {rp} rounds/iter vs batched {rb}: expected ≥ 2× drop"
    );
}

#[test]
fn total_online_rounds_are_stable() {
    // Full-iteration budget on the quickstart config: any change to this
    // number is a deliberate protocol-depth change and must be reviewed.
    let out = secure::run(&quickstart_data(), &quickstart_cfg(RoundPolicy::Coalesced)).unwrap();
    let per_iter_s1 = 1;
    let per_iter_s2 = min_k_rounds(K);
    let s1 = out.meter_a.get("online.s1").rounds;
    let s2 = out.meter_a.get("online.s2").rounds;
    let s3 = out.meter_a.get("online.s3").rounds;
    assert_eq!(s1, ITERS as u64 * per_iter_s1);
    assert_eq!(s2, ITERS as u64 * per_iter_s2);
    // S3 = (CMP + fused MUX) for the empty-cluster fallback — the
    // numerator reveal rides the CMP's first flight — plus the division
    // pipeline; assert it stays within the engine's depth budget.
    let s3_per_iter = s3 / ITERS as u64;
    assert!(
        s3_per_iter <= CMP_ROUNDS + 1 + 26,
        "S3 depth regressed: {s3_per_iter} flights/iter"
    );
}

#[test]
fn lockstep_tiling_adds_zero_flights() {
    // Acceptance criterion: with tile_rows = Some(B) under
    // TileFlights::Lockstep, every online phase's flight count equals
    // the monolithic baseline exactly — S1's tiles share one staged
    // flight, S2 batches all tiles' lanes per tree level, S3's per-tile
    // numerators ride the division-prep comparison. B = 192 does not
    // divide n = 1000 (ragged 40-row last tile).
    let data = quickstart_data();
    let mono = secure::run(&data, &quickstart_cfg(RoundPolicy::Coalesced)).unwrap();
    let mut cfg = quickstart_cfg(RoundPolicy::Coalesced);
    cfg.tile_rows = Some(192);
    cfg.tile_flights = TileFlights::Lockstep;
    let tiled = secure::run(&data, &cfg).unwrap();
    assert_eq!(tiled.tiles_run, 6);
    for phase in ["online.s1", "online.s2", "online.s3"] {
        assert_eq!(
            tiled.meter_a.get(phase).rounds,
            mono.meter_a.get(phase).rounds,
            "lockstep tiling must not change {phase} flights"
        );
    }
    // Same protocol, same outputs.
    assert_eq!(tiled.assignments, mono.assignments);
}

#[test]
fn streamed_tiling_trades_rounds_for_memory() {
    // The streamed policy pays ≈ tiles × the lockstep flight count (its
    // O(B·d) memory story) but must still compute the same clustering.
    let data = quickstart_data();
    let mut cfg = quickstart_cfg(RoundPolicy::Coalesced);
    cfg.tile_rows = Some(250);
    cfg.tile_flights = TileFlights::Lockstep;
    let lockstep = secure::run(&data, &cfg).unwrap();
    cfg.tile_flights = TileFlights::Streamed;
    let streamed = secure::run(&data, &cfg).unwrap();
    assert_eq!(streamed.assignments, lockstep.assignments);
    let rl = lockstep.meter_a.total_prefix("online.").rounds;
    let rs = streamed.meter_a.total_prefix("online.").rounds;
    // Per iteration, streamed pays tiles× the S1/S2 flights plus one
    // numerator flight per tile; only the S3 division tail stays shared.
    // At 4 tiles that is ≥ 2× the lockstep budget (deterministic).
    assert!(
        rs >= 2 * rl,
        "streamed ({rs} flights) must pay per-tile rounds over lockstep ({rl}) at 4 tiles"
    );
}

#[test]
fn auto_mode_selects_he_on_sparse_and_beaver_on_dense() {
    // Sparse workload (60% zeros) → HE Protocol 2; dense blobs → Beaver.
    // Outputs must match the plaintext oracle in both cases.
    let sparse = sparse_gen::generate(36, 6, 2, 0.6, 55);
    let mut cfg = SecureKmeansConfig {
        k: 2,
        iters: 2,
        esd: EsdMode::Auto,
        partition: Partition::Vertical { d_a: 3 },
        ..Default::default()
    };
    let out = secure::run(&sparse, &cfg).unwrap();
    assert_eq!(out.backend_name, "he-protocol2");
    let oracle = plaintext::kmeans(&sparse, 2, 2, cfg.seed);
    assert_eq!(out.assignments, oracle.assignments);
    for (a, b) in out.centroids.iter().zip(&oracle.centroids) {
        assert!((a - b).abs() < 1e-2, "sparse-path centroid {a} vs {b}");
    }

    let mut spec = BlobSpec::new(36, 6, 2);
    spec.spread = 0.02;
    let dense = spec.generate(56);
    cfg.partition = Partition::Vertical { d_a: 3 };
    let out = secure::run(&dense, &cfg).unwrap();
    assert_eq!(out.backend_name, "beaver");
    let oracle = plaintext::kmeans(&dense, 2, 2, cfg.seed);
    assert_eq!(out.assignments, oracle.assignments);
    for (a, b) in out.centroids.iter().zip(&oracle.centroids) {
        assert!((a - b).abs() < 1e-2, "dense-path centroid {a} vs {b}");
    }
}

#[test]
fn explicit_backends_agree_with_auto() {
    // The same sparse dataset through the explicit He and Beaver modes
    // must produce identical clusterings (exact ring arithmetic in both).
    let ds = sparse_gen::generate(30, 6, 2, 0.6, 57);
    let base = SecureKmeansConfig {
        k: 2,
        iters: 2,
        partition: Partition::Vertical { d_a: 3 },
        ..Default::default()
    };
    let beaver = secure::run(&ds, &base).unwrap();
    let mut he_cfg = base.clone();
    he_cfg.esd = EsdMode::he();
    let he = secure::run(&ds, &he_cfg).unwrap();
    assert_eq!(beaver.backend_name, "beaver");
    assert_eq!(he.backend_name, "he-protocol2");
    assert_eq!(beaver.assignments, he.assignments);
}
