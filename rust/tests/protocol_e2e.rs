//! End-to-end protocol integration: SS gates composed across modules,
//! OT-generated triples driving the online phase, store prefill
//! semantics (the online/offline split), and pricing consistency.

use ppkmeans::net::{duplex_pair, run_two_party};
use ppkmeans::offline::dealer::Dealer;
use ppkmeans::offline::gilboa::OtTripleGen;
use ppkmeans::offline::store::TripleStore;
use ppkmeans::ring::fixed::{decode_f64, encode_f64};
use ppkmeans::ring::matrix::Mat;
use ppkmeans::ss::share::{reconstruct, split};
use ppkmeans::ss::{Session, SessionOptions, arith, compare, divide, matmul, mux};
use ppkmeans::util::prng::Prg;
use std::thread;

/// A composite pipeline: (x⊙y) → compare vs z → select → divide.
/// Exercises SMUL, CMP, B2A/MUX and division in one shared dataflow.
#[test]
fn composite_pipeline_matches_plaintext() {
    let xs = [2.5f64, -1.0, 4.0, 0.5];
    let ys = [1.5f64, 3.0, -2.0, 2.0];
    let zs = [4.0f64, -4.0, -7.0, 2.0];
    let dens = [2u64, 4, 5, 10];
    let n = xs.len();

    // Plaintext reference: w = (x*y < z) ? x*y : z ; out = w / den.
    let want: Vec<f64> = (0..n)
        .map(|i| {
            let p = xs[i] * ys[i];
            let w = if p < zs[i] { p } else { zs[i] };
            w / dens[i] as f64
        })
        .collect();

    let mut prg = Prg::new(501);
    let x = Mat::from_vec(1, n, xs.iter().map(|&v| encode_f64(v)).collect());
    let y = Mat::from_vec(1, n, ys.iter().map(|&v| encode_f64(v)).collect());
    let z = Mat::from_vec(1, n, zs.iter().map(|&v| encode_f64(v)).collect());
    let den = Mat::from_vec(1, n, dens.to_vec());
    let (x0, x1) = split(&x, &mut prg);
    let (y0, y1) = split(&y, &mut prg);
    let (z0, z1) = split(&z, &mut prg);
    let (d0, d1) = split(&den, &mut prg);

    let run = move |party: usize, x: Mat, y: Mat, z: Mat, dn: Mat| {
        move |c: &mut ppkmeans::net::Chan| {
            let mut ts = Dealer::new(502, party);
            let mut ctx = Session::new(c, &mut ts, Prg::new(1 + party as u128), SessionOptions::default());
            let p2f = arith::smul_elem(&mut ctx, &x, &y);
            let p = ppkmeans::ss::trunc::trunc_frac(party, &p2f);
            let lt = compare::lt(&mut ctx, &p, &z);
            let w = mux::mux(&mut ctx, &lt, &p, &z);
            let q = divide::divide(&mut ctx, &w, &dn);
            reconstruct(c, &q)
        }
    };
    let ((r, _), _) =
        run_two_party(run(0, x0, y0, z0, d0), run(1, x1, y1, z1, d1));
    for i in 0..n {
        let got = decode_f64(r.data[i]);
        assert!((got - want[i]).abs() < 5e-3, "lane {i}: got {got} want {}", want[i]);
    }
}

/// OT-generated triples must drive a correct online matmul.
#[test]
fn beaver_matmul_over_ot_triples() {
    let a = Mat::from_vec(3, 2, vec![1, 2, 3, 4, 5, 6]);
    let b = Mat::from_vec(2, 3, vec![7, 8, 9, 10, 11, 12]);
    let want = a.matmul(&b);
    let mut prg = Prg::new(9);
    let (a0, a1) = split(&a, &mut prg);
    let (b0, b1) = split(&b, &mut prg);

    // Two channel pairs: protocol + OT.
    let (p0, p1) = duplex_pair();
    let (o0, o1) = duplex_pair();
    let h = thread::spawn(move || {
        let mut c = p0;
        let mut ts = OtTripleGen::new(o0, 313);
        let mut ctx = Session::new(&mut c, &mut ts, Prg::new(1), SessionOptions::default());
        let z = matmul::ss_matmul(&mut ctx, &a0, &b0);
        reconstruct(&mut c, &z)
    });
    let mut c = p1;
    let mut ts = OtTripleGen::new(o1, 313);
    let mut ctx = Session::new(&mut c, &mut ts, Prg::new(2), SessionOptions::default());
    let z = matmul::ss_matmul(&mut ctx, &a1, &b1);
    let r1 = reconstruct(&mut c, &z);
    let r0 = h.join().unwrap();
    assert_eq!(r0, want);
    assert_eq!(r1, want);
}

/// Prefilled store serves the online phase with zero generation misses —
/// the operational meaning of the online/offline split.
#[test]
fn online_offline_split_has_zero_misses() {
    use ppkmeans::data::blobs::BlobSpec;
    use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
    use ppkmeans::kmeans::secure;

    // Recording run: capture the exact demand.
    let ds = BlobSpec::new(20, 2, 2).generate(5);
    let cfg = SecureKmeansConfig {
        k: 2,
        iters: 2,
        partition: Partition::Vertical { d_a: 1 },
        ..Default::default()
    };
    let out = secure::run(&ds, &cfg).unwrap();
    let demand = out.demand;

    // Prefill a store with that demand, then drain it in the same order:
    // every request must hit.
    let mut store = TripleStore::new(Dealer::new(cfg.seed, 0));
    store.prefill(&demand);
    for ((m, k, n), count) in demand.mats.clone() {
        for _ in 0..count {
            use ppkmeans::ss::triples::TripleSource;
            let _ = store.mat_triple(m, k, n);
        }
    }
    for &lanes in &demand.vec_chunks {
        use ppkmeans::ss::triples::TripleSource;
        let _ = store.vec_triple(lanes);
    }
    for &lanes in &demand.bit_chunks {
        use ppkmeans::ss::triples::TripleSource;
        let _ = store.bit_triple(lanes);
    }
    assert_eq!(store.misses, 0, "prefilled store must absorb the whole online phase");
}
