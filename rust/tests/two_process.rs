//! Two real OS processes over localhost TCP, diffed against the
//! in-process reference — the same check CI's `two-process` job runs
//! with the release binary, here wired into `cargo test` via
//! `CARGO_BIN_EXE_ppkmeans`.

use ppkmeans::coordinator::remote::{run_scenario_local, Scenario};
use std::path::Path;
use std::process::Command;

const SCENARIO: &str = "\
# two-process regression scenario: tiny fraud-shaped train -> score
pipeline = serve
n = 96
k = 2
iters = 2
seed = 1337
data_seed = 7
stream_seed = 4242
rate = 0.05
batch_rows = 12
batches = 3
prefab = 2
low_water = 1
refill = 1
save_model = false
";

fn read(path: &Path) -> String {
    std::fs::read_to_string(path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

#[test]
fn two_process_tcp_run_matches_in_process_reference() {
    let exe = env!("CARGO_BIN_EXE_ppkmeans");
    let dir = std::env::temp_dir().join(format!("ppkm_two_proc_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let scn = dir.join("ci.scn");
    std::fs::write(&scn, SCENARIO).unwrap();
    let scn_str = scn.to_str().unwrap();

    // A per-process port keeps parallel test runs from colliding.
    let addr = format!("127.0.0.1:{}", 41000 + (std::process::id() % 20000) as u16);
    let p0_json = dir.join("p0.json");
    let p1_json = dir.join("p1.json");

    let mut p0 = Command::new(exe)
        .args(["party", "--role", "p0", "--listen", addr.as_str(), "--scenario", scn_str])
        .args(["--out", p0_json.to_str().unwrap()])
        .spawn()
        .expect("spawn p0");
    let p1_status = Command::new(exe)
        .args(["party", "--role", "p1", "--connect", addr.as_str(), "--scenario", scn_str])
        .args(["--out", p1_json.to_str().unwrap()])
        .status()
        .expect("run p1");
    let p0_status = p0.wait().expect("wait p0");
    assert!(p0_status.success(), "party 0 failed: {p0_status}");
    assert!(p1_status.success(), "party 1 failed: {p1_status}");

    // The in-process reference runs the same scenario through the same
    // run_scenario code path, over the duplex pair instead of TCP.
    let sc = Scenario::from_file(&scn).unwrap();
    let (l0, l1) = run_scenario_local(&sc).unwrap();
    assert_eq!(
        read(&p0_json),
        l0.to_json(),
        "party 0: two-process transcript must be bit-identical to in-process"
    );
    assert_eq!(
        read(&p1_json),
        l1.to_json(),
        "party 1: two-process transcript must be bit-identical to in-process"
    );

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mismatched_scenarios_fail_the_handshake_cleanly() {
    let exe = env!("CARGO_BIN_EXE_ppkmeans");
    let dir = std::env::temp_dir().join(format!("ppkm_two_proc_bad_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let scn_a = dir.join("a.scn");
    let scn_b = dir.join("b.scn");
    std::fs::write(&scn_a, SCENARIO).unwrap();
    std::fs::write(&scn_b, SCENARIO.replace("iters = 2", "iters = 3")).unwrap();

    let addr = format!("127.0.0.1:{}", 21000 + (std::process::id() % 20000) as u16);
    let mut p0 = Command::new(exe)
        .args(["party", "--role", "p0", "--listen", addr.as_str()])
        .args(["--scenario", scn_a.to_str().unwrap()])
        .spawn()
        .expect("spawn p0");
    let p1 = Command::new(exe)
        .args(["party", "--role", "p1", "--connect", addr.as_str()])
        .args(["--scenario", scn_b.to_str().unwrap()])
        .output()
        .expect("run p1");
    let p0_status = p0.wait().expect("wait p0");
    // Both sides must exit nonzero with a typed handshake error — no
    // protocol bytes, no panic, no garbage shares.
    assert!(!p0_status.success(), "p0 must reject the mismatch");
    assert!(!p1.status.success(), "p1 must reject the mismatch");
    let stderr = String::from_utf8_lossy(&p1.stderr);
    assert!(stderr.contains("scenario mismatch"), "stderr: {stderr}");
    assert!(stderr.contains("iters"), "must name the differing key: {stderr}");

    std::fs::remove_dir_all(&dir).ok();
}
