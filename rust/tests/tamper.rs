//! Active-adversary fault injection for the malicious tier: a
//! [`FaultMode::Tamper`] plan flips one payload bit on the wire and
//! keeps the link alive — the deferred MAC ledger must then make
//! **both** honest endpoints abort with a typed [`Error::MacCheck`]
//! naming the *same* phase barrier, across all three deployment shapes
//! (scenario training, the serve loop, the session-multiplexed
//! gateway). Plus the negative controls: an *untampered* malicious run
//! reveals bit-for-bit what the semi-honest run reveals, paying only
//! the fixed barrier tax (3 flights / 96 bytes per barrier) and the
//! commit-reveal surcharge (32 bytes per committed reveal).

use ppkmeans::coordinator::remote::{run_scenario, run_scenario_local, PartyTranscript, Scenario};
use ppkmeans::data::fraud_gen;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::net::fault::{FaultMode, FaultPlan};
use ppkmeans::net::meter::PhaseStats;
use ppkmeans::net::{duplex_pair, run_two_party, Chan, Security};
use ppkmeans::offline::bank::BankConfig;
use ppkmeans::runtime::pool;
use ppkmeans::serve::driver::{serve_party, serve_stream, train_model, ServeConfig};
use ppkmeans::serve::gateway::{gateway_party, GatewayConfig, GatewayOutput, SessionWorkload};
use ppkmeans::serve::model::TrainedModel;
use ppkmeans::serve::scorer::score_rounds;
use ppkmeans::util::error::{Error, Result};
use std::collections::BTreeMap;
use std::thread;

/// Tiny malicious-tier training scenario. Flight 1 is the handshake
/// hello; every flight from 2 on rides the armed ledger, and the first
/// Lloyd iteration alone spans well past flight 8 — so a bit flip at
/// any flight in the sweep below is caught at the `train.iter.0`
/// barrier.
const TRAIN_SCENARIO: &str = "\
pipeline = train
n = 48
d = 4
k = 2
iters = 2
seed = 7
data_seed = 5
security = malicious
";

/// Drive `run_scenario` for both parties over a duplex pair, keeping
/// **both** results — `run_scenario_local` collapses the pair into one
/// `Result`, which would hide one side's abort.
fn run_both(sc: &Scenario) -> (Result<PartyTranscript>, Result<PartyTranscript>) {
    let (mut c0, mut c1) = duplex_pair();
    let (s0, s1) = (sc.clone(), sc.clone());
    pool::run_pair(
        move || run_scenario(&mut c0, &s0),
        move || run_scenario(&mut c1, &s1),
    )
}

/// Extract the phase name out of a MAC-check abort; panics (failing the
/// test) on any other error variant.
fn barrier_phase(e: &Error) -> String {
    let msg = match e {
        Error::MacCheck(m) => m,
        other => panic!("expected Error::MacCheck, got: {other}"),
    };
    let pre = "phase barrier '";
    let start = msg
        .find(pre)
        .unwrap_or_else(|| panic!("MacCheck names no phase barrier: {msg}"))
        + pre.len();
    let end = msg[start..].find('\'').expect("unterminated phase name") + start;
    msg[start..end].to_string()
}

/// Both parties must abort typed, and they must agree on *which*
/// barrier caught the tampering — the symmetric crosswise ledger
/// comparison guarantees neither side is left hanging or fooled.
fn assert_both_abort_at(
    r0: Result<PartyTranscript>,
    r1: Result<PartyTranscript>,
    want_phase: &str,
    what: &str,
) {
    let e0 = r0.map(|_| ()).expect_err(&format!("{what}: party 0 must abort"));
    let e1 = r1.map(|_| ()).expect_err(&format!("{what}: party 1 must abort"));
    let (p0, p1) = (barrier_phase(&e0), barrier_phase(&e1));
    assert_eq!(p0, p1, "{what}: parties disagree on the failing barrier");
    assert_eq!(p0, want_phase, "{what}: wrong barrier caught the bit flip");
}

// ---- Training pipeline ----

/// Sweep the bit flip across early flights of either party: each run
/// must die at the first Lloyd boundary, on both sides, typed.
#[test]
fn tampered_train_aborts_both_parties_at_the_iteration_barrier() {
    let base = Scenario::parse(TRAIN_SCENARIO).unwrap();
    for (party, flight) in [(0, 2), (1, 3), (0, 5), (1, 6), (0, 8)] {
        let mut sc = base.clone();
        sc.fault_party = party;
        sc.fault_flight = flight;
        sc.fault_mode = FaultMode::Tamper;
        let (r0, r1) = run_both(&sc);
        assert_both_abort_at(
            r0,
            r1,
            "train.iter.0",
            &format!("tamper p{party} flight {flight}"),
        );
    }
}

/// Negative control: with no tampering, the malicious tier reveals
/// exactly what the semi-honest tier reveals, every shared phase's
/// traffic is byte-identical, and the overhead is confined to the
/// `mac.barrier` phase (3 flights / 96 bytes per barrier) plus the
/// commit-reveal surcharge (2 reveals × 32 bytes) in `reveal`.
#[test]
fn untampered_malicious_train_matches_semi_honest_reveals() {
    let mal = Scenario::parse(TRAIN_SCENARIO).unwrap();
    let mut sh = mal.clone();
    sh.security = Security::SemiHonest;
    let (m0, m1) = run_scenario_local(&mal).unwrap();
    let (s0, s1) = run_scenario_local(&sh).unwrap();
    let phase_map = |t: &PartyTranscript| -> BTreeMap<String, PhaseStats> {
        t.phases.iter().map(|(k, v)| (k.clone(), *v)).collect()
    };
    for (m, s) in [(&m0, &s0), (&m1, &s1)] {
        assert_eq!(m.reveals, s.reveals, "p{}: tiers must reveal identically", m.role);
        let (mm, sm) = (phase_map(m), phase_map(s));
        // Every semi-honest phase exists unchanged under the malicious
        // tier, except the reveal's commit surcharge.
        for (k, v) in &sm {
            let mv = mm.get(k).unwrap_or_else(|| panic!("malicious run lost phase {k}"));
            if k == "reveal" {
                assert_eq!(mv.bytes_sent, v.bytes_sent + 2 * 32, "commit-reveal surcharge");
                assert_eq!(mv.rounds, v.rounds + 2, "one commit flight per reveal");
            } else {
                assert_eq!(mv, v, "p{}: phase {k} must not grow under MACs", m.role);
            }
        }
        // The only new phase is the barrier tax itself.
        let extra: Vec<&String> = mm.keys().filter(|k| !sm.contains_key(*k)).collect();
        assert_eq!(extra, ["mac.barrier"], "p{}", m.role);
        let mac = mm["mac.barrier"];
        assert!(mac.rounds > 0 && mac.rounds % 3 == 0, "3 flights per barrier: {mac:?}");
        assert_eq!(mac.bytes_sent, mac.rounds / 3 * 96, "96 bytes per barrier: {mac:?}");
    }
}

// ---- Serve loop ----

const BR: usize = 8; // batch_rows
const BATCHES: usize = 3;
const K: usize = 3;

/// Train a small fraud model and pre-slice a scored stream into the
/// two parties' raw per-batch blocks.
fn serve_fixture() -> (TrainedModel, TrainedModel, Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let train = fraud_gen::generate(200, 0.05, 41);
    let cfg = SecureKmeansConfig {
        k: K,
        iters: 2,
        seed: 17,
        partition: Partition::Vertical { d_a: train.d_payment },
        ..Default::default()
    };
    let (_, [ma, mb]) = train_model(&train.data, &cfg, 0.05).unwrap();
    let stream = fraud_gen::generate(BATCHES * BR, 0.05, 4242);
    let (d, d_a) = (ma.d, ma.d_a);
    assert_eq!(stream.data.d, d);
    let mut blocks_a = Vec::with_capacity(BATCHES);
    let mut blocks_b = Vec::with_capacity(BATCHES);
    for b in 0..BATCHES {
        let mut xa = Vec::new();
        let mut xb = Vec::new();
        for i in b * BR..(b + 1) * BR {
            let row = stream.data.row(i);
            xa.extend_from_slice(&row[..d_a]);
            xb.extend_from_slice(&row[d_a..]);
        }
        blocks_a.push(xa);
        blocks_b.push(xb);
    }
    (ma, mb, blocks_a, blocks_b)
}

fn serve_cfg(security: Security) -> ServeConfig {
    ServeConfig {
        batch_rows: BR,
        batches: BATCHES,
        bank: BankConfig { prefab_batches: 2, low_water: 1, refill_batches: 2 },
        seed: 0xBA4C,
        security,
        ..Default::default()
    }
}

/// One serve run with a tamper plan armed on `fault_party`.
fn run_tampered_serve(fault_party: usize, at_flight: u64) -> (Result<()>, Result<()>) {
    let (ma, mb, blocks_a, blocks_b) = serve_fixture();
    let cfg = serve_cfg(Security::Malicious);
    let (cfg_a, cfg_b) = (cfg.clone(), cfg.clone());
    let plan = FaultPlan { at_flight, mode: FaultMode::Tamper };
    let side = |party: usize, m: TrainedModel, blocks: Vec<Vec<f64>>, cfg: ServeConfig| {
        move |c: &mut Chan| {
            if party == fault_party {
                c.set_fault(plan);
            }
            serve_party(c, m, blocks, &cfg).map(|_| ())
        }
    };
    let ((r0, _), (r1, _)) = run_two_party(
        side(0, ma, blocks_a, cfg_a),
        side(1, mb, blocks_b, cfg_b),
    );
    (r0, r1)
}

/// The serve loop settles its ledger once per scored batch: a flip in
/// the warmup or probe traffic dies at `serve.batch.0`, a flip in the
/// next batch's flights dies at `serve.batch.1` — on both sides. The
/// flight arithmetic is exact: warmup is 1 flight, each batch costs
/// `score_rounds(k)` flights, each barrier 3.
#[test]
fn tampered_serve_aborts_both_parties_at_the_batch_barrier() {
    let per_batch = score_rounds(K);
    let batch0_last = 1 + per_batch; // warmup + the probe batch
    let batch1_first = batch0_last + 3 + 1; // skip the 3 barrier flights
    let cases = [
        (0, 2, "serve.batch.0"),           // inside the probe batch
        (1, batch0_last, "serve.batch.0"), // the reveal flight itself
        (0, batch1_first + 2, "serve.batch.1"),
    ];
    for (party, flight, want) in cases {
        let (r0, r1) = run_tampered_serve(party, flight);
        let what = format!("serve tamper p{party} flight {flight}");
        let e0 = r0.expect_err(&format!("{what}: party 0 must abort"));
        let e1 = r1.expect_err(&format!("{what}: party 1 must abort"));
        let (p0, p1) = (barrier_phase(&e0), barrier_phase(&e1));
        assert_eq!(p0, p1, "{what}: parties disagree on the failing barrier");
        assert_eq!(p0, want, "{what}");
    }
}

/// Negative control: untampered malicious serving scores bit-for-bit
/// like semi-honest serving and pays exactly one 3-flight / 96-byte
/// barrier per batch — nothing else grows.
#[test]
fn untampered_malicious_serve_matches_semi_honest_and_pays_per_batch() {
    let train = fraud_gen::generate(200, 0.05, 41);
    let tcfg = SecureKmeansConfig {
        k: K,
        iters: 2,
        seed: 17,
        partition: Partition::Vertical { d_a: train.d_payment },
        ..Default::default()
    };
    let (_, [ma, mb]) = train_model(&train.data, &tcfg, 0.05).unwrap();
    let stream = fraud_gen::generate(BATCHES * BR, 0.05, 4242);
    let mal = serve_stream(
        [ma.clone(), mb.clone()],
        &stream.data,
        &serve_cfg(Security::Malicious),
    )
    .unwrap();
    let sh = serve_stream([ma, mb], &stream.data, &serve_cfg(Security::SemiHonest)).unwrap();
    assert_eq!(mal.results, sh.results, "tiers must score identically");
    for meter in [&mal.meter_a, &mal.meter_b] {
        let mac = meter.get("mac.barrier");
        assert_eq!(mac.rounds, 3 * BATCHES as u64, "3 flights per batch barrier");
        assert_eq!(mac.bytes_sent, 96 * BATCHES as u64, "96 bytes per batch barrier");
    }
    for meter in [&sh.meter_a, &sh.meter_b] {
        assert_eq!(meter.get("mac.barrier"), PhaseStats::default(), "semi-honest pays nothing");
    }
}

/// The malicious tier refuses to checkpoint: the deferred ledger does
/// not survive a restart, so arming both is a typed config error.
#[test]
fn malicious_serve_rejects_checkpointing() {
    let mut sc = Scenario::parse(
        "pipeline = serve\nn = 96\nk = 2\niters = 2\nseed = 1337\ndata_seed = 7\n\
         stream_seed = 4242\nrate = 0.05\nbatch_rows = 8\nbatches = 2\nsave_model = false\n\
         security = malicious\n",
    )
    .unwrap();
    let dir = std::env::temp_dir().join(format!("ppkm_tamper_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    sc.ckpt_dir = dir.to_str().unwrap().to_string();
    let err = run_scenario_local(&sc).unwrap_err();
    assert!(
        matches!(err, Error::Config(_)),
        "checkpointing under the malicious tier must fail typed, got: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// ---- Gateway ----

const NS: usize = 3; // sessions
const NB: usize = 2; // batches per session

/// Train a small fraud model and slice a stream into per-party session
/// workloads (tags 1..=NS) — the `tests/gateway.rs` fixture shape.
fn gateway_fixture() -> (TrainedModel, TrainedModel, Vec<SessionWorkload>, Vec<SessionWorkload>) {
    let train = fraud_gen::generate(200, 0.05, 41);
    let cfg = SecureKmeansConfig {
        k: K,
        iters: 2,
        seed: 17,
        partition: Partition::Vertical { d_a: train.d_payment },
        ..Default::default()
    };
    let (_, [ma, mb]) = train_model(&train.data, &cfg, 0.05).unwrap();
    let stream = fraud_gen::generate(NS * NB * BR, 0.05, 4242);
    let (d, d_a) = (ma.d, ma.d_a);
    assert_eq!(stream.data.d, d);
    let mut wl_a = Vec::new();
    let mut wl_b = Vec::new();
    for s in 0..NS {
        let mut blocks_a = Vec::new();
        let mut blocks_b = Vec::new();
        for b in 0..NB {
            let base = (s * NB + b) * BR;
            let mut xa = Vec::new();
            let mut xb = Vec::new();
            for i in base..base + BR {
                let row = stream.data.row(i);
                xa.extend_from_slice(&row[..d_a]);
                xb.extend_from_slice(&row[d_a..]);
            }
            blocks_a.push(xa);
            blocks_b.push(xb);
        }
        wl_a.push(SessionWorkload { tag: s as u64 + 1, blocks: blocks_a });
        wl_b.push(SessionWorkload { tag: s as u64 + 1, blocks: blocks_b });
    }
    (ma, mb, wl_a, wl_b)
}

/// One worker, so the mux frame schedule (and therefore which session a
/// link-level bit flip lands in) is deterministic.
fn gateway_cfg(security: Security) -> GatewayConfig {
    GatewayConfig {
        sessions: NS,
        queue: 0,
        workers: 1,
        replenishers: 1,
        shards: 2,
        batch_rows: BR,
        batches: NB,
        bank: BankConfig { prefab_batches: 1, low_water: 1, refill_batches: 1 },
        seed: 0x6A7E1,
        security,
        ..GatewayConfig::default()
    }
}

/// Run both parties' gateways; each on a fat stack like production.
fn run_gateway(
    c0: Chan,
    c1: Chan,
    ma: TrainedModel,
    mb: TrainedModel,
    wl_a: Vec<SessionWorkload>,
    wl_b: Vec<SessionWorkload>,
    cfg: &GatewayConfig,
) -> (GatewayOutput, GatewayOutput) {
    let (cfg_a, cfg_b) = (cfg.clone(), cfg.clone());
    let side = |mut c: Chan, m: TrainedModel, wl: Vec<SessionWorkload>, cfg: GatewayConfig| {
        thread::Builder::new()
            .stack_size(64 << 20)
            .spawn(move || gateway_party(&mut c, m, wl, &cfg).unwrap())
            .unwrap()
    };
    let h0 = side(c0, ma, wl_a, cfg_a);
    let h1 = side(c1, mb, wl_b, cfg_b);
    (h0.join().unwrap(), h1.join().unwrap())
}

/// A link-level bit flip inside one session's mux traffic kills exactly
/// that session — typed, same phase on both parties — while every other
/// session scores on untouched, and the flat link's own `gateway.done`
/// barrier still passes (the gateway returns `Ok`).
///
/// Flight accounting: the flat link spends 1 (hello) + 1 (probe warmup)
/// + `score_rounds(k)` (probe batch) flights before the mux takes over;
/// from there the inherited fault state counts *frames*. With one
/// worker, session tag 1 runs first — its warmup + first batch span the
/// frames right after the takeover, so frame 40 lands inside
/// `gateway.tag1.batch.0`'s window for k = 3.
#[test]
fn tampered_gateway_session_aborts_on_both_parties_and_spares_the_rest() {
    let (ma, mb, wl_a, wl_b) = gateway_fixture();
    let cfg = gateway_cfg(Security::Malicious);

    // Clean malicious reference.
    let (c0, c1) = duplex_pair();
    let (ref_a, ref_b) =
        run_gateway(c0, c1, ma.clone(), mb.clone(), wl_a.clone(), wl_b.clone(), &cfg);

    // Tampered run: the flip lands in session tag 1's first batch.
    let pre_mux = 1 + 1 + score_rounds(K);
    let at_flight = pre_mux + 13;
    let (mut c0, c1) = duplex_pair();
    c0.set_fault(FaultPlan { at_flight, mode: FaultMode::Tamper });
    let (out_a, out_b) = run_gateway(c0, c1, ma, mb, wl_a, wl_b, &cfg);

    let mut phases = Vec::new();
    for (out, clean) in [(&out_a, &ref_a), (&out_b, &ref_b)] {
        assert_eq!(out.sessions.len(), clean.sessions.len());
        let mut failed = Vec::new();
        for ((tag, r), (ctag, cr)) in out.sessions.iter().zip(&clean.sessions) {
            assert_eq!(tag, ctag);
            match r {
                Err(e) => {
                    failed.push(*tag);
                    phases.push(barrier_phase(e));
                }
                Ok(report) => {
                    let cr = cr.as_ref().expect("clean reference session failed");
                    assert_eq!(
                        report.results, cr.results,
                        "untouched session {tag} must match the clean run"
                    );
                }
            }
        }
        assert_eq!(failed, [1u64], "exactly the tampered session must die");
    }
    assert_eq!(phases.len(), 2);
    assert_eq!(phases[0], phases[1], "parties disagree on the failing barrier");
    assert_eq!(phases[0], "gateway.tag1.batch.0");
}

/// Negative control: untampered malicious gateway sessions reveal
/// bit-for-bit what their semi-honest counterparts reveal.
#[test]
fn untampered_malicious_gateway_matches_semi_honest() {
    let (ma, mb, wl_a, wl_b) = gateway_fixture();
    let (c0, c1) = duplex_pair();
    let (mal_a, mal_b) = run_gateway(
        c0,
        c1,
        ma.clone(),
        mb.clone(),
        wl_a.clone(),
        wl_b.clone(),
        &gateway_cfg(Security::Malicious),
    );
    let (c0, c1) = duplex_pair();
    let (sh_a, sh_b) =
        run_gateway(c0, c1, ma, mb, wl_a, wl_b, &gateway_cfg(Security::SemiHonest));
    for (m, s) in [(&mal_a, &sh_a), (&mal_b, &sh_b)] {
        assert_eq!(m.admitted(), NS);
        assert_eq!(m.sessions.len(), s.sessions.len());
        for ((mt, mr), (st, sr)) in m.sessions.iter().zip(&s.sessions) {
            assert_eq!(mt, st);
            let mr = mr.as_ref().expect("malicious session failed without tampering");
            let sr = sr.as_ref().expect("semi-honest session failed");
            assert_eq!(mr.results, sr.results, "session {mt}: tiers must score identically");
        }
    }
}
