//! Integration: PJRT runtime executes the AOT artifacts with numerics
//! identical to the native ring implementation.
//!
//! Requires the `pjrt` cargo feature (the whole file is compiled out on
//! the default feature set) and `make artifacts` (skipped with a message
//! otherwise).
#![cfg(feature = "pjrt")]

use ppkmeans::ring::matrix::Mat;
use ppkmeans::runtime::{dispatch, tiled, ArtifactStore};
use ppkmeans::util::prng::Prg;
use std::path::Path;

fn store() -> Option<ArtifactStore> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactStore::load(&dir) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping PJRT tests (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn manifest_lists_all_kinds() {
    let Some(s) = store() else { return };
    assert!(!s.by_kind("ring_matmul").is_empty());
    assert!(!s.by_kind("esd").is_empty());
    assert!(!s.by_kind("kmeans_step").is_empty());
}

#[test]
fn tiled_ring_matmul_matches_native_exact() {
    let Some(s) = store() else { return };
    let mut prg = Prg::new(41);
    // Deliberately awkward (non-multiple-of-block) shapes.
    for (m, t, n) in [(1, 1, 1), (7, 13, 5), (130, 129, 2), (256, 64, 300)] {
        let a = Mat::random(m, t, &mut prg);
        let b = Mat::random(t, n, &mut prg);
        let native = a.matmul(&b);
        let pjrt = tiled::ring_matmul(&s, &a, &b).unwrap();
        assert_eq!(native, pjrt, "shape {m}x{t}x{n}");
    }
}

#[test]
fn tiled_esd_matches_native_exact() {
    let Some(s) = store() else { return };
    let mut prg = Prg::new(42);
    for (n, d, k) in [(10, 2, 2), (300, 8, 5), (256, 128, 16)] {
        let x = Mat::random(n, d, &mut prg);
        let mu = Mat::random(k, d, &mut prg);
        // Native D' = U − 2Xμᵀ.
        let mut want = Mat::zeros(n, k);
        for i in 0..n {
            for j in 0..k {
                let mut u = 0u64;
                let mut dot = 0u64;
                for l in 0..d {
                    u = u.wrapping_add(mu.at(j, l).wrapping_mul(mu.at(j, l)));
                    dot = dot.wrapping_add(x.at(i, l).wrapping_mul(mu.at(j, l)));
                }
                want.set(i, j, u.wrapping_sub(dot.wrapping_mul(2)));
            }
        }
        let got = tiled::esd(&s, &x, &mu).unwrap();
        assert_eq!(got, want, "shape n={n} d={d} k={k}");
    }
}

#[test]
fn kmeans_step_artifact_runs() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dispatch::init(&dir).is_err() {
        return;
    }
    // Two tight blobs; one step from mid-way centroids must move toward
    // the blob means.
    let (n, d, k) = (64usize, 4usize, 2usize);
    let mut x = vec![0f32; n * d];
    for i in 0..n {
        let base = if i < n / 2 { 0.2 } else { 0.8 };
        for l in 0..d {
            x[i * d + l] = base + 0.01 * ((i * d + l) % 7) as f32 / 7.0;
        }
    }
    let mu = vec![0.4f32; d].into_iter().chain(vec![0.6f32; d]).collect::<Vec<_>>();
    let (new_mu, counts) = dispatch::kmeans_step(&x, &mu, n, d, k).expect("artifact present");
    assert_eq!(counts.iter().sum::<f32>() as usize, n);
    assert!((new_mu[0] - 0.2).abs() < 0.05, "centroid0 {:?}", &new_mu[..d]);
    assert!((new_mu[d] - 0.8).abs() < 0.05, "centroid1 {:?}", &new_mu[d..]);
}

#[test]
fn dispatch_falls_back_natively_without_init() {
    // Small product — dispatch must not require artifacts.
    let a = Mat::from_vec(2, 2, vec![1, 2, 3, 4]);
    let b = Mat::from_vec(2, 2, vec![5, 6, 7, 8]);
    assert_eq!(dispatch::matmul(&a, &b), a.matmul(&b));
}

#[test]
fn secure_kmeans_runs_with_pjrt_dispatch() {
    // End-to-end: protocol correctness is unchanged when the PJRT
    // backend serves the large local products.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dispatch::init(&dir).is_err() {
        return;
    }
    use ppkmeans::data::blobs::BlobSpec;
    use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
    use ppkmeans::kmeans::{plaintext, secure};
    let mut spec = BlobSpec::new(80, 4, 2);
    spec.spread = 0.02;
    let ds = spec.generate(3);
    let cfg = SecureKmeansConfig {
        k: 2,
        iters: 4,
        partition: Partition::Vertical { d_a: 2 },
        ..Default::default()
    };
    let sec = secure::run(&ds, &cfg).unwrap();
    let plain = plaintext::kmeans(&ds, 2, 4, cfg.seed);
    assert_eq!(sec.assignments, plain.assignments);
}
