//! Secure K-means end-to-end: exact agreement with the plaintext oracle
//! across partitionings, cluster counts, and datasets.

use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::data::sparse_gen;
use ppkmeans::kmeans::config::{EsdMode, Partition, SecureKmeansConfig};
use ppkmeans::kmeans::{plaintext, secure};

fn well_separated(n: usize, d: usize, k: usize, seed: u128) -> ppkmeans::data::blobs::Dataset {
    let mut spec = BlobSpec::new(n, d, k);
    spec.spread = 0.02;
    spec.generate(seed)
}

#[test]
fn vertical_grid_matches_plaintext() {
    for (n, d, k, d_a) in [(40, 2, 2, 1), (60, 5, 3, 2), (50, 4, 4, 3)] {
        let ds = well_separated(n, d, k, 100 + n as u128);
        let cfg = SecureKmeansConfig {
            k,
            iters: 5,
            partition: Partition::Vertical { d_a },
            ..Default::default()
        };
        let sec = secure::run(&ds, &cfg).unwrap();
        let plain = plaintext::kmeans(&ds, k, 5, cfg.seed);
        assert_eq!(sec.assignments, plain.assignments, "n={n} d={d} k={k}");
    }
}

#[test]
fn horizontal_grid_matches_plaintext() {
    for (n, d, k, n_a) in [(40, 2, 2, 13), (60, 3, 3, 30)] {
        let ds = well_separated(n, d, k, 200 + n as u128);
        let cfg = SecureKmeansConfig {
            k,
            iters: 4,
            partition: Partition::Horizontal { n_a },
            ..Default::default()
        };
        let sec = secure::run(&ds, &cfg).unwrap();
        let plain = plaintext::kmeans(&ds, k, 4, cfg.seed);
        assert_eq!(sec.assignments, plain.assignments, "n={n} d={d} k={k}");
    }
}

#[test]
fn naive_and_vectorized_agree_everywhere() {
    let ds = well_separated(16, 3, 2, 9);
    let mk = |esd: EsdMode| SecureKmeansConfig {
        k: 2,
        iters: 2,
        esd,
        partition: Partition::Vertical { d_a: 1 },
        ..Default::default()
    };
    let v = secure::run(&ds, &mk(EsdMode::Vectorized)).unwrap();
    let nv = secure::run(&ds, &mk(EsdMode::Naive)).unwrap();
    assert_eq!(v.assignments, nv.assignments);
    // Centroids agree up to fixed-point truncation noise (the two modes
    // consume different share randomness, so the ±1-ulp probabilistic
    // truncation error differs).
    for (a, b) in v.centroids.iter().zip(&nv.centroids) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn online_comm_scales_linearly_with_n() {
    // Eq. 3's promise: per-iteration online traffic is O(n·k), not O(n·k·rounds).
    let bytes = |n: usize| {
        let ds = well_separated(n, 2, 2, 77);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 2,
            partition: Partition::Vertical { d_a: 1 },
            ..Default::default()
        };
        let out = secure::run(&ds, &cfg).unwrap();
        out.meter_a.total_prefix("online.").bytes_sent
    };
    // Large enough n that the O(k)-sized division/norm terms are noise.
    let b1 = bytes(400);
    let b2 = bytes(800);
    let ratio = b2 as f64 / b1 as f64;
    assert!((1.5..2.5).contains(&ratio), "expected ~2x, got {ratio}");
}

#[test]
fn rounds_independent_of_n() {
    let rounds = |n: usize| {
        let ds = well_separated(n, 2, 2, 78);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 2,
            partition: Partition::Vertical { d_a: 1 },
            ..Default::default()
        };
        let out = secure::run(&ds, &cfg).unwrap();
        out.meter_a.total_prefix("online.").rounds
    };
    assert_eq!(rounds(30), rounds(90), "vectorization: rounds must not grow with n");
}

#[test]
fn sparse_and_dense_paths_identical_results() {
    let ds = sparse_gen::generate(30, 6, 2, 0.6, 55);
    let base = SecureKmeansConfig {
        k: 2,
        iters: 2,
        partition: Partition::Vertical { d_a: 3 },
        ..Default::default()
    };
    let dense = secure::run(&ds, &base).unwrap();
    let mut scfg = base.clone();
    scfg.esd = EsdMode::he();
    let sparse = secure::run(&ds, &scfg).unwrap();
    assert_eq!(dense.assignments, sparse.assignments);
    for (a, b) in dense.centroids.iter().zip(&sparse.centroids) {
        // Both paths are exact in the ring; the only divergence is the
        // ±1-ulp probabilistic truncation, whose draw differs with the
        // share randomness of each path.
        assert!((a - b).abs() < 1e-5, "centroids must agree up to truncation ulps: {a} vs {b}");
    }
}

#[test]
fn fraud_pipeline_joint_beats_single_party() {
    use ppkmeans::data::fraud_gen;
    use ppkmeans::fraud::{detect_outliers, jaccard, OutlierConfig};

    let f = fraud_gen::generate(600, 0.05, 31);
    let k = 4;
    let cfg = SecureKmeansConfig {
        k,
        iters: 6,
        partition: Partition::Vertical { d_a: f.d_payment },
        ..Default::default()
    };
    let ocfg = OutlierConfig { rate: 0.05, min_cluster_frac: 0.02 };
    let joint = secure::run(&f.data, &cfg).unwrap();
    let flagged = detect_outliers(&f.data, &joint.centroids, &joint.assignments, k, &ocfg);
    let j_joint = jaccard(&flagged, &f.outliers);

    let pay = f.payment_only();
    let single = plaintext::kmeans(&pay, k, 6, cfg.seed);
    let flagged = detect_outliers(&pay, &single.centroids, &single.assignments, k, &ocfg);
    let j_single = jaccard(&flagged, &f.outliers);

    assert!(
        j_joint > j_single,
        "joint secure clustering ({j_joint:.3}) must beat payment-only ({j_single:.3})"
    );
}
