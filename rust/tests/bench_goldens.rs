//! Golden flight/byte counts for the bench-smoke CI gate.
//!
//! Wall-clock is hardware-dependent and stays informational; every byte
//! and every flight is deterministic, so drift there is a real protocol
//! change and must be deliberate. The goldens live in
//! `rust/tests/goldens/` and hold real measured counts only — a missing
//! file is bootstrapped in place from the live measurement (run the
//! test once locally and commit the result to pin the counts), but
//! placeholder contents are never accepted. To update after an
//! intentional protocol change: `UPDATE_GOLDENS=1 cargo test --test
//! bench_goldens`, then commit the diff. Either way the test also
//! re-runs the measurement and asserts it is reproducible within the
//! same process, so CI catches nondeterminism even on a bootstrap run.

use ppkmeans::bench::{
    gateway_counts, gateway_golden_lines, malicious_golden_lines, serve_counts,
    serve_golden_lines, train_counts, train_golden_lines, train_malicious_counts,
};
use std::path::PathBuf;

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/goldens").join(name)
}

/// Compare `actual` against the committed golden, bootstrapping or
/// updating the file when asked to.
fn check_golden(name: &str, actual: &str) {
    let path = golden_path(name);
    let committed = std::fs::read_to_string(&path).unwrap_or_default();
    let update = std::env::var("UPDATE_GOLDENS").is_ok();
    if update || committed.is_empty() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, actual).unwrap_or_else(|e| panic!("write {name}: {e}"));
        eprintln!("bench_goldens: wrote {} — commit it to pin these counts", path.display());
        return;
    }
    assert_eq!(
        committed, actual,
        "flight/byte counts drifted from {name} — if the protocol change is \
         intentional, regenerate with `UPDATE_GOLDENS=1 cargo test --test \
         bench_goldens` and commit the diff"
    );
}

#[test]
fn train_counts_match_goldens() {
    for k in [2usize, 5] {
        let c = train_counts(256, 2, k, 3);
        let lines = train_golden_lines(&c);
        check_golden(&format!("train_n256_k{k}.golden"), &lines);
        // Reproducibility inside one process: a second identical run
        // must produce identical counts (this is what makes the golden
        // meaningful at all).
        let again = train_golden_lines(&train_counts(256, 2, k, 3));
        assert_eq!(lines, again, "train counts must be deterministic (k={k})");
    }
}

#[test]
fn malicious_train_counts_match_goldens() {
    for k in [2usize, 5] {
        let iters = 3usize;
        let c = train_malicious_counts(256, 2, k, iters);
        let lines = malicious_golden_lines(&c);
        check_golden(&format!("train_malicious_n256_k{k}.golden"), &lines);
        let again = malicious_golden_lines(&train_malicious_counts(256, 2, k, iters));
        assert_eq!(lines, again, "malicious counts must be deterministic (k={k})");
        // The surcharge formulas from docs/PROTOCOLS.md: one 3-flight
        // 96-byte-per-party barrier per Lloyd iteration plus train.done,
        // and a 32-byte commit per final opened matrix per party.
        let barriers = (iters + 1) as u64;
        assert_eq!(c.mac_barrier_rounds, 3 * barriers, "3 flights per barrier (k={k})");
        assert_eq!(c.mac_barrier_bytes, 2 * 96 * barriers, "96 B/party/barrier (k={k})");
        assert_eq!(c.reveal_extra_bytes, 2 * 2 * 32, "two openings, 32 B commit each (k={k})");
        assert_eq!(c.reveal_extra_rounds, 2, "one commit flight per opening (k={k})");
        // The online phases themselves cost the same as semi-honest.
        let sh = train_counts(256, 2, k, iters);
        assert_eq!(c.online_bytes, sh.online_bytes, "online traffic is tier-independent");
    }
}

#[test]
fn serving_counts_match_golden() {
    let c = serve_counts(200, 2, 2, 16, 4);
    let lines = serve_golden_lines(&c);
    check_golden("serving_k2_b4x16.golden", &lines);
    let again = serve_golden_lines(&serve_counts(200, 2, 2, 16, 4));
    assert_eq!(lines, again, "serving counts must be deterministic");
    assert_eq!(c.bank_misses, 0, "a planned bank must never miss");
}

#[test]
fn gateway_counts_match_golden() {
    let c = gateway_counts(200, 2, 2, 3, 8, 3);
    let lines = gateway_golden_lines(&c);
    check_golden("gateway_k2_s3_b3x8.golden", &lines);
    let again = gateway_golden_lines(&gateway_counts(200, 2, 2, 3, 8, 3));
    assert_eq!(lines, again, "gateway counts must be deterministic");
    assert_eq!(c.misses, 0, "background replenishment must cover every draw");
    assert_eq!(c.consumed, 9, "3 sessions x 3 batches consume one kit each");
    // All three sessions score the same shape, and the link phase is the
    // exact sum of the per-session meters (tags included).
    assert_eq!(c.link_bytes, 3 * c.session_bytes, "3 equal sessions sum to the link");
}
