//! Determinism regression for the packed-lane runtime (`runtime::simd`):
//! a protocol run with 8-wide packed kernels must be **transcript
//! identical** to the scalar run — bit-identical reveals and shares, and
//! identical per-phase Meter flight/byte counts — so the lane width is
//! purely a throughput knob, exactly like the thread count
//! (`rust/tests/parallel.rs`). The two knobs compose: the widest run is
//! also checked under a 4-worker fan-out.

use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::data::fraud_gen;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig, TileFlights};
use ppkmeans::kmeans::secure;
use ppkmeans::net::meter::PhaseStats;
use ppkmeans::offline::bank::BankConfig;
use ppkmeans::runtime::pool::Parallelism;
use ppkmeans::runtime::simd::{set_global_lanes, Lanes};
use ppkmeans::serve::driver::{serve_stream, train_model, ServeConfig};

fn meter_snapshot(out: &secure::SecureKmeansOutput) -> Vec<(String, PhaseStats)> {
    out.meter_a.phases().map(|(k, v)| (k.to_string(), *v)).collect()
}

#[test]
fn secure_kmeans_is_bit_identical_across_lane_widths() {
    // Full training run, tiled — same shape as the thread-count
    // regression so the two knobs guard the same transcript.
    let mut spec = BlobSpec::new(400, 6, 3);
    spec.spread = 0.02;
    let data = spec.generate(71);
    let base = SecureKmeansConfig {
        k: 3,
        iters: 3,
        partition: Partition::Vertical { d_a: 3 },
        tile_rows: Some(128),
        tile_flights: TileFlights::Lockstep,
        ..Default::default()
    };
    let scalar = secure::run(&data, &base).unwrap();
    set_global_lanes(1);
    for width in [4usize, 8] {
        let cfg = SecureKmeansConfig { lanes: Lanes::new(width), ..base.clone() };
        let packed = secure::run(&data, &cfg).unwrap();
        set_global_lanes(1);

        // Reveals and shares: bit-identical.
        assert_eq!(packed.centroids, scalar.centroids, "centroids, lanes={width}");
        assert_eq!(packed.assignments, scalar.assignments, "lanes={width}");
        assert_eq!(packed.centroid_shares[0], scalar.centroid_shares[0], "lanes={width}");
        assert_eq!(packed.centroid_shares[1], scalar.centroid_shares[1], "lanes={width}");

        // Transcript: every phase's flight and byte counters must match —
        // packed kernels are party-local and never touch the Chan
        // schedule.
        assert_eq!(
            meter_snapshot(&packed),
            meter_snapshot(&scalar),
            "party-0 meters, lanes={width}"
        );
        assert_eq!(
            packed.meter_b.total().rounds,
            scalar.meter_b.total().rounds,
            "lanes={width}"
        );
        assert_eq!(
            packed.meter_b.total().bytes_sent,
            scalar.meter_b.total().bytes_sent,
            "lanes={width}"
        );

        // Offline accounting: same demand, same ledger.
        assert_eq!(packed.demand, scalar.demand, "lanes={width}");
        assert_eq!(packed.ledger, scalar.ledger, "lanes={width}");
    }

    // Composition: 8 lanes × 4 workers must still match the scalar
    // sequential transcript — the speedups multiply, the bits don't move.
    let both = SecureKmeansConfig {
        lanes: Lanes::auto(),
        parallelism: Parallelism::new(4),
        ..base
    };
    let combined = secure::run(&data, &both).unwrap();
    set_global_lanes(1);
    assert_eq!(combined.centroids, scalar.centroids, "8 lanes x 4 threads");
    assert_eq!(combined.assignments, scalar.assignments, "8 lanes x 4 threads");
    assert_eq!(
        meter_snapshot(&combined),
        meter_snapshot(&scalar),
        "8 lanes x 4 threads meters"
    );
    assert_eq!(combined.demand, scalar.demand);
    assert_eq!(combined.ledger, scalar.ledger);
}

#[test]
fn serving_is_bit_identical_across_lane_widths() {
    // Train once, then serve the same stream with scalar and 8-lane
    // scorers: identical reveals (assignments + fraud flags) and
    // identical serve-phase meters, batch for batch.
    let f = fraud_gen::generate(300, 0.05, 4100);
    let cfg = SecureKmeansConfig {
        k: 2,
        iters: 2,
        partition: Partition::Vertical { d_a: f.d_payment },
        ..Default::default()
    };
    let (_, models) = train_model(&f.data, &cfg, 0.05).unwrap();
    set_global_lanes(1);
    let stream = fraud_gen::generate(4 * 16, 0.05, 4200);
    let base = ServeConfig {
        batch_rows: 16,
        batches: 4,
        bank: BankConfig { prefab_batches: 2, low_water: 1, refill_batches: 1 },
        seed: 0xDE7,
        ..Default::default()
    };
    let scalar = serve_stream(models.clone(), &stream.data, &base).unwrap();
    set_global_lanes(1);
    let packed_cfg = ServeConfig { lanes: Lanes::auto(), ..base };
    let packed = serve_stream(models, &stream.data, &packed_cfg).unwrap();
    set_global_lanes(1);

    assert_eq!(packed.results, scalar.results, "scores and flags must be bit-identical");
    for (i, (s, p)) in scalar.batch_stats.iter().zip(&packed.batch_stats).enumerate() {
        assert_eq!(p.online, s.online, "batch {i} serve-phase meters");
        assert_eq!(p.flagged, s.flagged, "batch {i} flags");
    }
    assert_eq!(
        packed.meter_a.total_prefix("serve.").rounds,
        scalar.meter_a.total_prefix("serve.").rounds
    );
    assert_eq!(
        packed.meter_a.total_prefix("serve.").bytes_sent,
        scalar.meter_a.total_prefix("serve.").bytes_sent
    );
    assert_eq!(packed.per_batch_demand, scalar.per_batch_demand);
    assert_eq!(packed.bank_misses + scalar.bank_misses, 0);
}
