//! Loopback transcript equivalence: the same protocol over localhost
//! TCP ([`Chan::from_tcp`]) and over the in-process duplex pair must
//! produce **bit-identical** shares, reveals and per-phase meters — the
//! property that makes the two-process deployment a drop-in for every
//! number this repo reports.

use ppkmeans::coordinator::remote::{run_scenario, run_scenario_local, Pipeline, Scenario};
use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::kmeans::secure::run_party;
use ppkmeans::net::meter::PhaseStats;
use ppkmeans::net::{duplex_pair, Chan, TcpTransport};
use std::net::TcpListener;
use std::thread;

/// A connected TCP channel pair over an ephemeral localhost port.
fn tcp_pair() -> (Chan, Chan) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let h = thread::spawn(move || TcpTransport::accept_from(&listener).unwrap());
    let client = TcpTransport::connect(&addr).unwrap();
    let server = h.join().unwrap();
    (Chan::from_tcp(server, 0), Chan::from_tcp(client, 1))
}

/// Run a scenario with both parties as threads over a given channel
/// pair, returning both transcript JSONs.
fn run_over(mut c0: Chan, mut c1: Chan, sc: &Scenario) -> (String, String) {
    let sc0 = sc.clone();
    let sc1 = sc.clone();
    let h0 = thread::Builder::new()
        .stack_size(64 << 20)
        .spawn(move || run_scenario(&mut c0, &sc0).unwrap().to_json())
        .unwrap();
    let h1 = thread::Builder::new()
        .stack_size(64 << 20)
        .spawn(move || run_scenario(&mut c1, &sc1).unwrap().to_json())
        .unwrap();
    (h0.join().unwrap(), h1.join().unwrap())
}

#[test]
fn train_transcripts_are_transport_independent() {
    let sc = Scenario {
        pipeline: Pipeline::Train,
        n: 60,
        d: 4,
        k: 2,
        iters: 3,
        seed: 21,
        data_seed: 9,
        ..Default::default()
    };
    let (l0, l1) = run_scenario_local(&sc).unwrap();
    let (c0, c1) = tcp_pair();
    let (t0, t1) = run_over(c0, c1, &sc);
    assert_eq!(l0.to_json(), t0, "party 0 transcript must not depend on the transport");
    assert_eq!(l1.to_json(), t1, "party 1 transcript must not depend on the transport");
    // Sanity: the transcript actually carries protocol phases.
    assert!(t0.contains("online.s1"));
    assert!(t0.contains("handshake"));
}

#[test]
fn serve_pipeline_transcripts_are_transport_independent() {
    // Train → score over TCP, with a bank small enough to force a
    // replenishment mid-stream.
    let sc = Scenario {
        pipeline: Pipeline::Serve,
        n: 120,
        k: 2,
        iters: 2,
        seed: 5,
        data_seed: 3,
        batch_rows: 12,
        batches: 3,
        prefab: 1,
        low_water: 1,
        refill: 1,
        ..Default::default()
    };
    let (l0, l1) = run_scenario_local(&sc).unwrap();
    let (c0, c1) = tcp_pair();
    let (t0, t1) = run_over(c0, c1, &sc);
    assert_eq!(l0.to_json(), t0);
    assert_eq!(l1.to_json(), t1);
    assert!(t0.contains("serve.s1"), "serving phases must be metered");
    assert!(t0.contains("\"bank_misses\": \"0\""), "planned bank must not miss: {t0}");
}

/// One party's observable outcome: reconstructed centroid words, own
/// share words, assignments, and the full per-phase meter.
type Side = (Vec<u64>, Vec<u64>, Vec<usize>, Vec<(String, PhaseStats)>);

fn party_side(
    chan: &mut Chan,
    data: &ppkmeans::data::blobs::Dataset,
    cfg: &SecureKmeansConfig,
) -> Side {
    let r = run_party(chan, data, cfg).unwrap();
    let phases = chan.meter().phases().map(|(k, v)| (k.to_string(), *v)).collect();
    (r.mu.data.clone(), r.mu_share.data.clone(), r.assignments, phases)
}

/// Library-level equivalence, below the transcript layer: raw shares,
/// reveals, assignments and every phase meter from `run_party`.
#[test]
fn run_party_shares_reveals_and_meters_match_across_transports() {
    let ds = BlobSpec::new(50, 4, 2).generate(3);
    let cfg = SecureKmeansConfig {
        k: 2,
        iters: 3,
        partition: Partition::Vertical { d_a: 2 },
        ..Default::default()
    };

    let run_pair = |mut c0: Chan, mut c1: Chan| -> (Side, Side) {
        let (da, db) = (ds.clone(), ds.clone());
        let (cfg_a, cfg_b) = (cfg.clone(), cfg.clone());
        let h0 = thread::Builder::new()
            .stack_size(64 << 20)
            .spawn(move || party_side(&mut c0, &da, &cfg_a))
            .unwrap();
        let h1 = thread::Builder::new()
            .stack_size(64 << 20)
            .spawn(move || party_side(&mut c1, &db, &cfg_b))
            .unwrap();
        (h0.join().unwrap(), h1.join().unwrap())
    };

    let (mpsc0, mpsc1) = {
        let (c0, c1) = duplex_pair();
        run_pair(c0, c1)
    };
    let (tcp0, tcp1) = {
        let (c0, c1) = tcp_pair();
        run_pair(c0, c1)
    };
    // Bit-identical: reconstructed centroids, this party's share,
    // assignments, and the full per-phase byte/flight accounting.
    assert_eq!(mpsc0, tcp0, "party 0 must be transport-independent");
    assert_eq!(mpsc1, tcp1, "party 1 must be transport-independent");
    // And the two parties agree on the reveal.
    assert_eq!(mpsc0.0, mpsc1.0);
}
