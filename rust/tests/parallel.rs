//! Determinism regression for the multi-core runtime (`runtime::pool`):
//! a protocol run fanned out across N workers must be **transcript
//! identical** to the single-threaded run — bit-identical reveals and
//! shares, and identical per-phase Meter flight/byte counts — so the
//! thread count is purely a throughput knob and every existing round /
//! byte regression budget applies unchanged at any parallelism.

use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::data::fraud_gen;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig, TileFlights};
use ppkmeans::kmeans::secure;
use ppkmeans::net::meter::PhaseStats;
use ppkmeans::offline::bank::BankConfig;
use ppkmeans::offline::dealer::Dealer;
use ppkmeans::offline::store::{Demand, TripleStore};
use ppkmeans::runtime::pool::Parallelism;
use ppkmeans::serve::driver::{serve_stream, train_model, ServeConfig};
use ppkmeans::ss::triples::TripleSource;

fn meter_snapshot(out: &secure::SecureKmeansOutput) -> Vec<(String, PhaseStats)> {
    out.meter_a.phases().map(|(k, v)| (k.to_string(), *v)).collect()
}

#[test]
fn secure_kmeans_is_bit_identical_across_thread_counts() {
    // Full training run, tiled so per-tile fan-out actually engages.
    let mut spec = BlobSpec::new(400, 6, 3);
    spec.spread = 0.02;
    let data = spec.generate(71);
    let base = SecureKmeansConfig {
        k: 3,
        iters: 3,
        partition: Partition::Vertical { d_a: 3 },
        tile_rows: Some(128),
        tile_flights: TileFlights::Lockstep,
        ..Default::default()
    };
    let seq = secure::run(&data, &base).unwrap();
    let par_cfg = SecureKmeansConfig { parallelism: Parallelism::new(4), ..base };
    let par = secure::run(&data, &par_cfg).unwrap();

    // Reveals: bit-identical (exact fixed-point words decode to exact
    // f64s, so f64 equality is the right assertion here).
    assert_eq!(par.centroids, seq.centroids, "centroids must be bit-identical");
    assert_eq!(par.assignments, seq.assignments);
    assert_eq!(par.centroid_shares[0], seq.centroid_shares[0], "party-0 share");
    assert_eq!(par.centroid_shares[1], seq.centroid_shares[1], "party-1 share");

    // Transcript: every phase's flight and byte counters must match —
    // the Chan schedule never sees the worker pool.
    assert_eq!(meter_snapshot(&par), meter_snapshot(&seq), "party-0 meters");
    let on_seq = seq.meter_a.total_prefix("online.");
    let on_par = par.meter_a.total_prefix("online.");
    assert_eq!(on_par.rounds, on_seq.rounds);
    assert_eq!(on_par.bytes_sent, on_seq.bytes_sent);
    assert_eq!(par.meter_b.total().rounds, seq.meter_b.total().rounds);
    assert_eq!(par.meter_b.total().bytes_sent, seq.meter_b.total().bytes_sent);

    // Offline accounting: same demand, same ledger.
    assert_eq!(par.demand, seq.demand);
    assert_eq!(par.ledger, seq.ledger);
}

#[test]
fn horizontal_run_is_thread_count_independent() {
    let mut spec = BlobSpec::new(90, 4, 2);
    spec.spread = 0.02;
    let data = spec.generate(72);
    let base = SecureKmeansConfig {
        k: 2,
        iters: 2,
        partition: Partition::Horizontal { n_a: 40 },
        tile_rows: Some(32),
        ..Default::default()
    };
    let seq = secure::run(&data, &base).unwrap();
    let par = secure::run(
        &data,
        &SecureKmeansConfig { parallelism: Parallelism::new(4), ..base },
    )
    .unwrap();
    assert_eq!(par.centroids, seq.centroids);
    assert_eq!(par.assignments, seq.assignments);
    assert_eq!(meter_snapshot(&par), meter_snapshot(&seq));
}

#[test]
fn serving_is_bit_identical_across_thread_counts() {
    // Train once, then serve the same stream with 1-thread and 4-thread
    // scorers: identical reveals (assignments + fraud flags) and
    // identical serve-phase meters, batch for batch.
    let f = fraud_gen::generate(300, 0.05, 4100);
    let cfg = SecureKmeansConfig {
        k: 2,
        iters: 2,
        partition: Partition::Vertical { d_a: f.d_payment },
        ..Default::default()
    };
    let (_, models) = train_model(&f.data, &cfg, 0.05).unwrap();
    let stream = fraud_gen::generate(4 * 16, 0.05, 4200);
    let base = ServeConfig {
        batch_rows: 16,
        batches: 4,
        bank: BankConfig { prefab_batches: 2, low_water: 1, refill_batches: 1 },
        seed: 0xDE7,
        ..Default::default()
    };
    let seq = serve_stream(models.clone(), &stream.data, &base).unwrap();
    let par_cfg = ServeConfig { parallelism: Parallelism::new(4), ..base };
    let par = serve_stream(models, &stream.data, &par_cfg).unwrap();

    assert_eq!(par.results, seq.results, "scores and flags must be bit-identical");
    for (i, (s, p)) in seq.batch_stats.iter().zip(&par.batch_stats).enumerate() {
        assert_eq!(p.online, s.online, "batch {i} serve-phase meters");
        assert_eq!(p.flagged, s.flagged, "batch {i} flags");
    }
    assert_eq!(
        par.meter_a.total_prefix("serve.").rounds,
        seq.meter_a.total_prefix("serve.").rounds
    );
    assert_eq!(
        par.meter_a.total_prefix("serve.").bytes_sent,
        seq.meter_a.total_prefix("serve.").bytes_sent
    );
    assert_eq!(par.per_batch_demand, seq.per_batch_demand);
    assert_eq!(par.bank_misses + seq.bank_misses, 0);
}

#[test]
fn parallel_prefill_is_bit_identical_and_cross_party_consistent() {
    let mut demand = Demand::default();
    demand.mat(16, 4, 3);
    demand.mat(16, 4, 3);
    demand.mat(4, 4, 4);
    demand.vec_lanes(32);
    demand.vec_lanes(8);
    demand.bit_lanes(128);
    demand.dabit_lanes(24);

    // Thread-count independence of the stocked material.
    let draw = |store: &mut TripleStore<Dealer>| {
        let m = store.mat_triple(16, 4, 3);
        let v = store.vec_triple(32);
        let b = store.bit_triple(128);
        let d = store.dabits(24);
        (m, v, b, d)
    };
    let mut base = TripleStore::new(Dealer::new(0xFEED, 1));
    base.prefill(&demand);
    let (bm, bv, bb, bd) = draw(&mut base);
    for threads in [2usize, 4, 8] {
        let mut s = TripleStore::new(Dealer::new(0xFEED, 1));
        s.prefill_par(&demand, threads);
        let (m, v, b, d) = draw(&mut s);
        assert_eq!(m.z, bm.z, "threads = {threads}");
        assert_eq!(v.z, bv.z, "threads = {threads}");
        assert_eq!(b.c, bb.c, "threads = {threads}");
        assert_eq!(d.arith, bd.arith, "threads = {threads}");
        assert_eq!(s.misses, 0);
    }

    // Mixed styles stay consistent: party 0 prefills with 4 workers,
    // party 1 draws inline one item at a time — shares must still
    // reconstruct to valid triples.
    let mut s0 = TripleStore::new(Dealer::new(0xC0FFEE, 0));
    s0.prefill_par(&demand, 4);
    let mut d1 = Dealer::new(0xC0FFEE, 1);
    for _ in 0..2 {
        let t0 = s0.mat_triple(16, 4, 3);
        let t1 = d1.mat_triple(16, 4, 3);
        let u = t0.u.add(&t1.u);
        let v = t0.v.add(&t1.v);
        let z = t0.z.add(&t1.z);
        assert_eq!(u.matmul(&v), z);
    }
    let t0 = s0.vec_triple(32);
    let t1 = d1.vec_triple(32);
    for i in 0..32 {
        let u = t0.u[i].wrapping_add(t1.u[i]);
        let v = t0.v[i].wrapping_add(t1.v[i]);
        let z = t0.z[i].wrapping_add(t1.z[i]);
        assert_eq!(u.wrapping_mul(v), z, "lane {i}");
    }
    assert_eq!(s0.misses, 0, "prefilled draws must all hit");
}
