//! Seeded property tests (proptest is unavailable offline): randomized
//! sweeps over protocol invariants with deterministic seeds so failures
//! reproduce exactly.

use ppkmeans::net::run_two_party;
use ppkmeans::offline::dealer::Dealer;
use ppkmeans::ring::fixed::{decode_f64, encode_f64, SCALE};
use ppkmeans::ring::matrix::Mat;
use ppkmeans::ss::share::{reconstruct, split};
use ppkmeans::ss::{Session, SessionOptions, arith, boolean, compare, divide};
use ppkmeans::util::prng::Prg;

/// Property: for all (x, y) in the fixed-point range, reconstructed
/// SMUL equals the wrapping ring product.
#[test]
fn prop_smul_correct_over_random_inputs() {
    for trial in 0..20 {
        let mut prg = Prg::new(7000 + trial);
        let n = 1 + (prg.next_below(40) as usize);
        let x = Mat::random(1, n, &mut prg);
        let y = Mat::random(1, n, &mut prg);
        let want: Vec<u64> =
            x.data.iter().zip(&y.data).map(|(a, b)| a.wrapping_mul(*b)).collect();
        let (x0, x1) = split(&x, &mut prg);
        let (y0, y1) = split(&y, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(7100 + trial, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let z = arith::smul_elem(&mut ctx, &x0, &y0);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(7100 + trial, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let z = arith::smul_elem(&mut ctx, &x1, &y1);
                reconstruct(c, &z)
            },
        );
        assert_eq!(r.data, want, "trial {trial} n={n}");
    }
}

/// Property: CMP agrees with plaintext `<` for random fixed-point pairs.
#[test]
fn prop_cmp_matches_plaintext_order() {
    for trial in 0..15 {
        let mut prg = Prg::new(8000 + trial);
        let n = 1 + (prg.next_below(30) as usize);
        let xs: Vec<f64> = (0..n).map(|_| (prg.next_f64() - 0.5) * 1000.0).collect();
        let ys: Vec<f64> = (0..n).map(|_| (prg.next_f64() - 0.5) * 1000.0).collect();
        let x = Mat::from_vec(1, n, xs.iter().map(|&v| encode_f64(v)).collect());
        let y = Mat::from_vec(1, n, ys.iter().map(|&v| encode_f64(v)).collect());
        let (x0, x1) = split(&x, &mut prg);
        let (y0, y1) = split(&y, &mut prg);
        let ((bits, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(8100 + trial, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let b = compare::lt(&mut ctx, &x0, &y0);
                let theirs = c.exchange_u64s(&b.words);
                (0..n)
                    .map(|i| ((b.words[i / 64] ^ theirs[i / 64]) >> (i % 64)) & 1 == 1)
                    .collect::<Vec<_>>()
            },
            move |c| {
                let mut ts = Dealer::new(8100 + trial, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let b = compare::lt(&mut ctx, &x1, &y1);
                let _ = c.exchange_u64s(&b.words);
            },
        );
        for i in 0..n {
            assert_eq!(bits[i], xs[i] < ys[i], "trial {trial} lane {i}");
        }
    }
}

/// Property: reciprocal error is bounded for the entire count range that
/// K-means can produce (1..=n for bench-scale n).
#[test]
fn prop_reciprocal_bounded_error() {
    for trial in 0..8 {
        let mut prg = Prg::new(9000 + trial);
        let counts: Vec<u64> =
            (0..12).map(|_| 1 + prg.next_below(1_000_000)).collect();
        let d = Mat::from_vec(1, counts.len(), counts.clone());
        let (d0, d1) = split(&d, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(9100 + trial, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let z = divide::reciprocal_int(&mut ctx, &d0);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(9100 + trial, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let z = divide::reciprocal_int(&mut ctx, &d1);
                reconstruct(c, &z)
            },
        );
        for (i, &cnt) in counts.iter().enumerate() {
            let got = decode_f64(r.data[i]);
            let want = 1.0 / cnt as f64;
            let tol = (want * 2e-3).max(4.0 / SCALE);
            assert!((got - want).abs() < tol, "trial {trial} count {cnt}: {got} vs {want}");
        }
    }
}

/// Property: A2B ∘ B2A round-trips bit planes (bit 0 of random values).
#[test]
fn prop_a2b_b2a_roundtrip() {
    for trial in 0..10 {
        let mut prg = Prg::new(9500 + trial);
        let n = 1 + (prg.next_below(20) as usize);
        let x = Mat::random(1, n, &mut prg);
        let want: Vec<u64> = x.data.iter().map(|v| v & 1).collect();
        let (x0, x1) = split(&x, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(9600 + trial, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let planes = boolean::a2b(&mut ctx, &x0);
                let lifted = boolean::b2a(&mut ctx, &planes[0]);
                reconstruct(c, &lifted)
            },
            move |c| {
                let mut ts = Dealer::new(9600 + trial, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let planes = boolean::a2b(&mut ctx, &x1);
                let lifted = boolean::b2a(&mut ctx, &planes[0]);
                reconstruct(c, &lifted)
            },
        );
        assert_eq!(r.data, want, "trial {trial}");
    }
}

/// Failure injection: a party panicking mid-protocol must surface as a
/// panic in the harness, not a deadlock.
#[test]
fn prop_peer_failure_is_detected() {
    let result = std::panic::catch_unwind(|| {
        run_two_party(
            |c| {
                c.send_u64s(&[1]);
                c.recv_u64s() // peer dies before answering
            },
            |_c| {
                panic!("simulated party crash");
            },
        )
    });
    assert!(result.is_err(), "harness must propagate the peer failure");
}
