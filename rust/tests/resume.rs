//! Kill-and-resume fault-injection matrix (the PR's acceptance bar):
//! a party killed at any barrier or batch boundary and relaunched with
//! the same checkpoint directory must negotiate the common PPKMCKP1
//! checkpoint in the v2 handshake, replay only the remainder, and land
//! a transcript **byte-identical** to an uninterrupted run — reveal
//! digests and per-phase flight/byte counts alike. Plus the live
//! centroid-refresh drift test: the hot-swapped model must track a
//! moving fraud cluster exactly (ring-exact oracle, no tolerances)
//! while dropping zero batches.

use ppkmeans::coordinator::remote::{run_scenario_local, PartyTranscript, Pipeline, Scenario};
use ppkmeans::data::blobs::Dataset;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::net::fault::FaultMode;
use ppkmeans::offline::bank::BankConfig;
use ppkmeans::ring::fixed::{encode_f64, FRAC_BITS};
use ppkmeans::ring::matrix::Mat;
use ppkmeans::serve::driver::{serve_stream, train_model, ServeConfig};
use ppkmeans::ss::trunc::trunc_share;
use std::path::{Path, PathBuf};
use std::process::Command;

/// The serve scenario every kill point replays: tiny fraud-shaped
/// train → score with a live refresh after batch 2, so the sweep
/// crosses training iterations, the train.done barrier, the warmup,
/// every scored batch AND the hot-swap of the refreshed model.
const SCENARIO: &str = "\
pipeline = serve
n = 96
k = 2
iters = 2
seed = 1337
data_seed = 7
stream_seed = 4242
rate = 0.05
batch_rows = 8
batches = 4
prefab = 2
low_water = 1
refill = 2
refresh.every = 2
refresh.alpha = 0.25
save_model = false
";

const GATEWAY_SCENARIO: &str = "\
pipeline = gateway
n = 96
k = 2
iters = 2
seed = 1337
data_seed = 7
stream_seed = 4242
rate = 0.05
batch_rows = 8
batches = 3
prefab = 1
low_water = 1
refill = 1
gateway.sessions = 2
gateway.queue = 0
gateway.workers = 2
";

fn serve_scenario() -> Scenario {
    Scenario::parse(SCENARIO).unwrap()
}

fn gateway_scenario() -> Scenario {
    Scenario::parse(GATEWAY_SCENARIO).unwrap()
}

/// Fresh per-test checkpoint directory (both parties share it — files
/// are party-prefixed, like two hosts mounting the same scratch dir).
fn tmp(tag: &str, salt: u64) -> PathBuf {
    let d = std::env::temp_dir().join(format!("ppkm_resume_{}_{tag}_{salt}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn reveal<'a>(t: &'a PartyTranscript, key: &str) -> &'a str {
    t.reveals
        .iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| v.as_str())
        .unwrap_or_else(|| panic!("transcript has no {key} reveal"))
}

/// `"prefab+replenished-consumed=remaining"` must balance as arithmetic.
fn assert_ledger_balances(t: &PartyTranscript) {
    let v = reveal(t, "bank_ledger");
    let (lhs, rhs) = v.split_once('=').unwrap();
    let (pr, c) = lhs.rsplit_once('-').unwrap();
    let (p, r) = pr.split_once('+').unwrap();
    let lhs_val = p.parse::<i64>().unwrap() + r.parse::<i64>().unwrap()
        - c.parse::<i64>().unwrap();
    assert_eq!(lhs_val, rhs.parse::<i64>().unwrap(), "bank ledger must balance: {v}");
}

/// Total flights one party sends over a run — the sweep space for the
/// deterministic fault trigger.
fn total_flights(t: &PartyTranscript) -> u64 {
    t.phases.iter().map(|(_, p)| p.rounds).sum()
}

fn ckpt_files(dir: &Path, party: usize) -> usize {
    let prefix = format!("party{party}.");
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().starts_with(&prefix))
                .count()
        })
        .unwrap_or(0)
}

/// Kill p1 at a spread of flights covering every stage of the serve
/// pipeline, resume from the on-disk checkpoints, and require the
/// resumed transcripts to be byte-identical to the uninterrupted
/// reference — the tentpole's hard acceptance bar.
#[test]
fn killed_and_resumed_transcripts_match_the_uninterrupted_run() {
    let base = serve_scenario();
    let (r0, r1) = run_scenario_local(&base).unwrap();
    let total = total_flights(&r1);
    assert!(total > 14, "scenario too small to sweep ({total} flights)");
    // ~14 kill points: flight 1 (mid-handshake, no checkpoint yet),
    // every training iteration, the train.done barrier, warmup/probe,
    // each scored batch, the refresh flight and the final barrier.
    let step = (total / 14).max(1) as usize;
    let mut flights: Vec<u64> = (1..=total).step_by(step).collect();
    if flights.last() != Some(&total) {
        flights.push(total);
    }
    for f in flights {
        let dir = tmp("kill", f);
        let mut sc = base.clone();
        sc.ckpt_dir = dir.to_str().unwrap().to_string();
        sc.fault_flight = f;
        sc.fault_party = 1;
        sc.fault_mode = FaultMode::Kill;
        assert!(
            run_scenario_local(&sc).is_err(),
            "fault at flight {f}/{total} must kill the run"
        );
        // Relaunch with the fault disarmed and the same checkpoint
        // directory: the handshake negotiates the common checkpoint
        // and the pipeline replays only the remainder.
        sc.fault_flight = 0;
        let (t0, t1) = run_scenario_local(&sc)
            .unwrap_or_else(|e| panic!("resume after kill at flight {f}: {e}"));
        assert_eq!(t0.to_json(), r0.to_json(), "p0 transcript after kill at flight {f}");
        assert_eq!(t1.to_json(), r1.to_json(), "p1 transcript after kill at flight {f}");
        assert_ledger_balances(&t0);
        assert_ledger_balances(&t1);
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// The non-kill fault modes and a p0-side crash resume the same way:
/// drop (lost frame), trunc (garbage on the wire — the peer must fail
/// typed, never write a poisoned checkpoint), and the roles swapped.
#[test]
fn other_fault_modes_and_the_other_party_resume_identically() {
    let base = serve_scenario();
    let (r0, r1) = run_scenario_local(&base).unwrap();
    let total = total_flights(&r0);
    let cases =
        [(0, FaultMode::Kill, total / 2), (1, FaultMode::Drop, total / 2), (1, FaultMode::Trunc, 2 * total / 5)];
    for (i, (party, mode, f)) in cases.into_iter().enumerate() {
        let dir = tmp("mode", i as u64);
        let mut sc = base.clone();
        sc.ckpt_dir = dir.to_str().unwrap().to_string();
        sc.fault_flight = f;
        sc.fault_party = party;
        sc.fault_mode = mode;
        assert!(
            run_scenario_local(&sc).is_err(),
            "{} on p{party} at flight {f} must kill the run",
            mode.as_str()
        );
        sc.fault_flight = 0;
        let (t0, t1) = run_scenario_local(&sc).unwrap_or_else(|e| {
            panic!("resume after {} on p{party} at flight {f}: {e}", mode.as_str())
        });
        assert_eq!(t0.to_json(), r0.to_json(), "p0 after {} on p{party}", mode.as_str());
        assert_eq!(t1.to_json(), r1.to_json(), "p1 after {} on p{party}", mode.as_str());
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// A crash during the *resumed* run must converge too: later checkpoint
/// ordinals are rewritten byte-identically, so a second kill-and-resume
/// lands on the same transcript as one, or none.
#[test]
fn a_second_kill_during_the_resumed_run_still_converges() {
    let base = serve_scenario();
    let (r0, r1) = run_scenario_local(&base).unwrap();
    let total = total_flights(&r1);
    let dir = tmp("double", 0);
    let mut sc = base.clone();
    sc.ckpt_dir = dir.to_str().unwrap().to_string();
    sc.fault_party = 1;
    sc.fault_mode = FaultMode::Kill;
    // First crash mid-training …
    sc.fault_flight = total / 3;
    assert!(run_scenario_local(&sc).is_err());
    // … second crash early in the resumed run (flight counting restarts
    // with the process, exactly like a real relaunch) …
    sc.fault_flight = 5;
    assert!(run_scenario_local(&sc).is_err(), "second fault must fire in the resumed run");
    // … third launch runs to completion and matches the reference.
    sc.fault_flight = 0;
    let (t0, t1) = run_scenario_local(&sc).unwrap();
    assert_eq!(t0.to_json(), r0.to_json());
    assert_eq!(t1.to_json(), r1.to_json());
    std::fs::remove_dir_all(&dir).ok();
}

/// Mid-gateway-session kill: the fault trigger rides the mux link (it
/// counts tagged frames there), so the crash lands inside concurrent
/// session traffic. The gateway keeps no per-batch checkpoints — the
/// resume negotiates the train.done snapshot, skips training entirely,
/// re-runs the scoring tail, and every per-session reveal plus the
/// ShardedBank ledger totals match the uninterrupted run.
#[test]
fn mid_gateway_session_kill_resumes_from_the_train_barrier() {
    let base = gateway_scenario();
    let (g0, g1) = run_scenario_local(&base).unwrap();

    // A clean checkpointing run must not perturb the transcript, and
    // tells us how many checkpoints a full run writes (training only).
    let full_dir = tmp("gw_full", 0);
    let mut sc = base.clone();
    sc.ckpt_dir = full_dir.to_str().unwrap().to_string();
    let (c0, c1) = run_scenario_local(&sc).unwrap();
    assert_eq!(c0.to_json(), g0.to_json(), "checkpointing must not change the transcript");
    assert_eq!(c1.to_json(), g1.to_json());
    let n_full = ckpt_files(&full_dir, 1);
    assert!(n_full >= 2, "expected train.iter.* + train.done checkpoints, got {n_full}");
    std::fs::remove_dir_all(&full_dir).ok();

    // Probe kill points from late to early: the first one that both
    // fires AND left the full training checkpoint set is a crash inside
    // the gateway scoring tail (handshake, mux hello or session frames).
    let mut found = false;
    for &f in &[400u64, 280, 200, 140, 100, 70, 50, 35, 25] {
        let dir = tmp("gw_kill", f);
        let mut sc = base.clone();
        sc.ckpt_dir = dir.to_str().unwrap().to_string();
        sc.fault_flight = f;
        sc.fault_party = 1;
        sc.fault_mode = FaultMode::Kill;
        if run_scenario_local(&sc).is_ok() {
            // Fault beyond the end of the run — try an earlier flight.
            std::fs::remove_dir_all(&dir).ok();
            continue;
        }
        if ckpt_files(&dir, 1) < n_full {
            // Crashed during training: covered by the serve sweep.
            std::fs::remove_dir_all(&dir).ok();
            continue;
        }
        sc.fault_flight = 0;
        let (t0, t1) = run_scenario_local(&sc)
            .unwrap_or_else(|e| panic!("gateway resume after kill at {f}: {e}"));
        assert_eq!(t0.to_json(), g0.to_json(), "p0 gateway transcript after kill at {f}");
        assert_eq!(t1.to_json(), g1.to_json(), "p1 gateway transcript after kill at {f}");
        // The sharded bank's ledger totals survive the crash exactly.
        for key in ["gateway.admitted", "gateway.rejected", "gateway.consumed", "gateway.misses"]
        {
            assert_eq!(reveal(&t0, key), reveal(&g0, key), "{key} after resume");
        }
        std::fs::remove_dir_all(&dir).ok();
        found = true;
        break;
    }
    assert!(found, "no probe flight landed a kill inside the gateway scoring tail");
}

/// Real two-process crash: p1 aborts (SIGABRT) mid-run, both processes
/// die, and a relaunch with the same per-party checkpoint directories
/// produces transcripts byte-identical to the in-process reference.
/// This is the same matrix entry the CI `two-process` job runs with
/// `scenarios/ci_resume.scn`.
#[test]
fn two_process_abort_and_resume_matches_the_reference() {
    let exe = env!("CARGO_BIN_EXE_ppkmeans");
    let dir = tmp("two_proc", 0);
    let scn = dir.join("resume.scn");
    std::fs::write(&scn, SCENARIO).unwrap();
    let scn_str = scn.to_str().unwrap();
    let (ck0, ck1) = (dir.join("ck0"), dir.join("ck1"));
    let (ck0_str, ck1_str) = (ck0.to_str().unwrap(), ck1.to_str().unwrap());

    let sc = Scenario::from_file(&scn).unwrap();
    let (l0, l1) = run_scenario_local(&sc).unwrap();
    // Abort at ~60% of the run: deep enough that both sides hold real
    // checkpoints, early enough that real work remains to replay.
    let f = (total_flights(&l1) * 3 / 5).max(2).to_string();

    let port = 31000 + (std::process::id() % 20000) as u16;
    let addr = format!("127.0.0.1:{port}");
    let mut p0 = Command::new(exe)
        .args(["party", "--role", "p0", "--listen", addr.as_str(), "--scenario", scn_str])
        .args(["--ckpt-dir", ck0_str])
        .spawn()
        .expect("spawn p0");
    let p1_status = Command::new(exe)
        .args(["party", "--role", "p1", "--connect", addr.as_str(), "--scenario", scn_str])
        .args(["--ckpt-dir", ck1_str])
        .args(["--fault-flight", &f, "--fault-mode", "abort", "--fault-party", "1"])
        .status()
        .expect("run p1");
    let p0_status = p0.wait().expect("wait p0");
    assert!(!p1_status.success(), "p1 must die of the injected abort");
    assert!(!p0_status.success(), "p0 must exit nonzero on the peer crash");
    assert!(ckpt_files(&ck0, 0) > 0, "p0 must hold checkpoints before the resume");
    assert!(ckpt_files(&ck1, 1) > 0, "p1 must hold checkpoints before the resume");

    // Relaunch on a fresh port, faults disarmed, same checkpoint dirs.
    let addr = format!("127.0.0.1:{}", port + 1);
    let p0_json = dir.join("p0.json");
    let p1_json = dir.join("p1.json");
    let mut p0 = Command::new(exe)
        .args(["party", "--role", "p0", "--listen", addr.as_str(), "--scenario", scn_str])
        .args(["--ckpt-dir", ck0_str, "--out", p0_json.to_str().unwrap()])
        .spawn()
        .expect("respawn p0");
    let p1_status = Command::new(exe)
        .args(["party", "--role", "p1", "--connect", addr.as_str(), "--scenario", scn_str])
        .args(["--ckpt-dir", ck1_str, "--out", p1_json.to_str().unwrap()])
        .status()
        .expect("rerun p1");
    let p0_status = p0.wait().expect("wait p0");
    assert!(p0_status.success(), "resumed p0 failed: {p0_status}");
    assert!(p1_status.success(), "resumed p1 failed: {p1_status}");

    let read = |p: &Path| std::fs::read_to_string(p).unwrap();
    assert_eq!(read(&p0_json), l0.to_json(), "p0: resumed transcript vs uninterrupted");
    assert_eq!(read(&p1_json), l1.to_json(), "p1: resumed transcript vs uninterrupted");
    std::fs::remove_dir_all(&dir).ok();
}

/// The committed CI scenario stays honest: serve pipeline, live refresh
/// on, and no checkpoint/fault state baked into the shared file (those
/// are per-process CLI overrides, like a real crash).
#[test]
fn committed_ci_resume_scenario_keeps_fault_state_party_local() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../scenarios/ci_resume.scn");
    let sc = Scenario::from_file(&path).unwrap();
    assert_eq!(sc.pipeline, Pipeline::Serve);
    assert!(sc.refresh_every > 0, "CI scenario must exercise the live-refresh hot swap");
    assert!(
        sc.ckpt_dir.is_empty() && sc.fault_flight == 0,
        "ckpt/fault knobs are per-process CLI overrides, not shared scenario state"
    );
    assert!(sc.n <= 500 && sc.batches <= 8, "kill-and-resume entries must run in seconds");
}

// ---- Live centroid refresh under drift -----------------------------------

/// Deterministic synthetic rows: two clusters on d=4, cluster 1
/// drifting downward over the stream. The jitter is index-derived so
/// the dataset is a pure function of its arguments.
fn jitter(i: usize, c: usize) -> f64 {
    ((i * 31 + c * 17) % 13) as f64 / 13.0 * 0.03 - 0.015
}

fn two_cluster_rows(n: usize, d: usize, center_of: impl Fn(usize) -> f64) -> Dataset {
    let mut x = Vec::with_capacity(n * d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let base = center_of(i);
        labels.push((i % 2 != 0) as usize);
        for c in 0..d {
            x.push(base + jitter(i, c));
        }
    }
    Dataset { n, d, x, labels }
}

/// Exact replica of one party's `Scorer::refresh` share update: public
/// window means over its own normalized columns, α-blend in the ring,
/// local truncation. Running this for both parties lets the test hold
/// the exact post-refresh centroid shares — so the assignment oracle
/// below is integer-exact, no fixed-point tolerance games.
#[allow(clippy::too_many_arguments)]
fn refresh_replica(
    mu: &mut Mat,
    party: usize,
    c0: usize,
    nc: usize,
    stats: &[(f64, f64)],
    rows: &[&[f64]],
    assigns: &[usize],
    alpha: f64,
) {
    let (k, d) = (mu.rows, mu.cols);
    let mut counts = vec![0usize; k];
    let mut sums = vec![0.0f64; k * nc];
    for (row, &j) in rows.iter().zip(assigns) {
        counts[j] += 1;
        for c in 0..nc {
            let (lo, hi) = stats[c];
            let v = row[c0 + c];
            sums[j * nc + c] += if hi > lo { (v - lo) / (hi - lo) } else { 0.0 };
        }
    }
    let mut delta = Mat::zeros(k, d);
    for j in 0..k {
        if counts[j] == 0 {
            continue;
        }
        for c in 0..d {
            let own = c >= c0 && c < c0 + nc;
            let recent = if own {
                encode_f64(sums[j * nc + (c - c0)] / counts[j] as f64)
            } else {
                0
            };
            delta.data[j * d + c] = recent.wrapping_sub(mu.data[j * d + c]);
        }
    }
    let alpha_f = encode_f64(alpha);
    for w in &mut delta.data {
        *w = w.wrapping_mul(alpha_f);
    }
    let step = trunc_share(party, &delta, FRAC_BITS);
    for (m, s) in mu.data.iter_mut().zip(&step.data) {
        *m = m.wrapping_add(*s);
    }
}

/// The protocol's exact ring-arithmetic assignment: D'_j = ‖μ_j‖² −
/// 2·x·μ_j on encoded normalized rows (same oracle as tests/serve.rs).
fn oracle_assign(x_enc: &[u64], mu_enc: &Mat) -> usize {
    let (k, d) = (mu_enc.rows, mu_enc.cols);
    let mut best = 0usize;
    let mut best_v = i64::MAX;
    for j in 0..k {
        let mut u = 0u64;
        let mut dot = 0u64;
        for l in 0..d {
            let m = mu_enc.at(j, l);
            u = u.wrapping_add(m.wrapping_mul(m));
            dot = dot.wrapping_add(x_enc[l].wrapping_mul(m));
        }
        let dp = u.wrapping_sub(dot.wrapping_mul(2)) as i64;
        if dp < best_v {
            best_v = dp;
            best = j;
        }
    }
    best
}

/// A fraud cluster drifts through the served stream; periodic delta
/// refresh hot-swaps the centroid shares mid-serve. Every batch's
/// assignments must equal the ring-exact oracle evaluated against the
/// *refreshed* centroids (replicated share-for-share in the test), the
/// refreshed centroid must actually have chased the drift, and zero
/// batches may be dropped along the way.
#[test]
fn drift_refresh_tracks_the_moving_cluster_with_zero_dropped_batches() {
    let (d, d_a, k) = (4usize, 2usize, 2usize);
    let (batches, batch_rows) = (8usize, 16usize);
    let alpha = 0.5;

    // Train on two stationary clusters at 0.1 and 0.9.
    let train = two_cluster_rows(160, d, |i| if i % 2 == 0 { 0.1 } else { 0.9 });
    // Init picks k seed-chosen data rows; even if both land in one
    // blob, Lloyd separates bimodal data within ~3 iterations — 5
    // guarantees the "stationary centroid stays put" margin below.
    let cfg = SecureKmeansConfig {
        k,
        iters: 5,
        seed: 21,
        partition: Partition::Vertical { d_a },
        ..Default::default()
    };
    let (out, [ma, mb]) = train_model(&train, &cfg, 0.05).unwrap();

    // Stream: cluster A stays at 0.1; cluster B drifts 0.9 → 0.585.
    let stream = two_cluster_rows(batches * batch_rows, d, |i| {
        let b = i / batch_rows;
        if i % 2 == 0 {
            0.1
        } else {
            0.9 - 0.045 * b as f64
        }
    });
    let scfg = ServeConfig {
        batch_rows,
        batches,
        bank: BankConfig { prefab_batches: 3, low_water: 1, refill_batches: 3 },
        seed: 0x4EF4_1357,
        refresh_every: 2,
        refresh_alpha: alpha,
        ..Default::default()
    };
    let served = serve_stream([ma.clone(), mb.clone()], &stream, &scfg).unwrap();

    // Zero dropped batches: every batch scored, every row intact.
    assert_eq!(served.results.len(), batches);
    assert_eq!(served.batch_stats.len(), batches);
    for (b, r) in served.results.iter().enumerate() {
        assert_eq!(r.assignments.len(), batch_rows, "batch {b}");
        assert_eq!(r.malformed_rows, 0, "batch {b}");
    }
    // Refresh fires after batches 2, 4 and 6 (never after the last),
    // one `serve.refresh` flight each, on both parties' meters.
    assert_eq!(served.meter_a.get("serve.refresh").rounds, 3);
    assert_eq!(served.meter_b.get("serve.refresh").rounds, 3);

    // Replay the refresh schedule share-for-share and check every
    // batch's assignments against the exact ring oracle.
    let joint_stats: Vec<(f64, f64)> = ma.stats.iter().chain(mb.stats.iter()).cloned().collect();
    let mut mu0 = ma.mu_share.clone();
    let mut mu1 = mb.mu_share.clone();
    for b in 0..batches {
        let mu_enc = mu0.add(&mu1);
        for r in 0..batch_rows {
            let row = stream.row(b * batch_rows + r);
            let x_enc: Vec<u64> = row
                .iter()
                .zip(&joint_stats)
                .map(|(&v, &(lo, hi))| {
                    encode_f64(if hi > lo { (v - lo) / (hi - lo) } else { 0.0 })
                })
                .collect();
            assert_eq!(
                served.results[b].assignments[r],
                oracle_assign(&x_enc, &mu_enc),
                "batch {b} row {r} must match the refreshed-centroid oracle"
            );
        }
        if scfg.refresh_every > 0 && (b + 1) % scfg.refresh_every == 0 && b + 1 < batches {
            let w0 = b + 1 - scfg.refresh_every;
            let mut rows: Vec<&[f64]> = Vec::new();
            let mut assigns: Vec<usize> = Vec::new();
            for wb in w0..=b {
                for r in 0..batch_rows {
                    rows.push(stream.row(wb * batch_rows + r));
                    assigns.push(served.results[wb].assignments[r]);
                }
            }
            refresh_replica(&mut mu0, 0, 0, d_a, &ma.stats, &rows, &assigns, alpha);
            refresh_replica(&mut mu1, 1, d_a, d - d_a, &mb.stats, &rows, &assigns, alpha);
        }
    }

    // The refresh must have *chased* the drift: the high cluster's
    // centroid moved substantially toward the drifted window mean,
    // while the stationary cluster barely moved.
    let initial = &out.centroids;
    let final_mu = mu0.add(&mu1).decode();
    let jb = if initial[0] > initial[d] { 0 } else { 1 };
    let ja = 1 - jb;
    assert!(
        initial[jb * d] - final_mu[jb * d] > 0.08,
        "drifting cluster must pull its centroid down: {} -> {}",
        initial[jb * d],
        final_mu[jb * d]
    );
    assert!(
        (initial[ja * d] - final_mu[ja * d]).abs() < 0.05,
        "stationary cluster must stay put: {} -> {}",
        initial[ja * d],
        final_mu[ja * d]
    );
    // And the stream still separates into both clusters at the end.
    let last = &served.results[batches - 1].assignments;
    assert!(last.contains(&0) && last.contains(&1), "both clusters must stay in use");
}
