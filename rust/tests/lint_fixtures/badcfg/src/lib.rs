//! Empty crate body; only the policy file matters for this fixture.
