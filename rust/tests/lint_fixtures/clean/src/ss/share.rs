//! Inside the confined subtree the raw open primitives are legal —
//! this is where open_auth and reconstruct_committed wrap them. The
//! authenticated wrappers themselves must never trip the rule from
//! any module (no `reconstruct(` substring hides in their names).

pub fn open_here(chan: &mut Chan, share: &Mat) -> Mat {
    reconstruct(chan, share)
}

pub fn open_to_here(chan: &mut Chan, share: &Mat) -> Option<Mat> {
    reconstruct_to(chan, share, 1)
}

pub fn checked(chan: &mut Chan, share: &AuthMat) -> Result<Mat> {
    reconstruct_committed(chan, share, "fixture.phase")
}
