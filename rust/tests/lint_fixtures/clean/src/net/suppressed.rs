//! A justified inline suppression silences the rule at one site.

pub fn last_resort(v: Option<u64>) -> u64 {
    // lint:allow(no-panic-in-wire-paths): fixture for a justified, documented escape hatch
    v.unwrap()
}

pub fn same_line(v: Option<u64>) -> u64 {
    v.unwrap() // lint:allow(no-panic-in-wire-paths): marker on the offending line itself
}
