//! False-positive traps: every token below sits where the lexer must
//! blank it — a doc line mentioning HashMap and Instant::now() is not
//! a violation, and neither is anything else in this file.

pub fn fine() -> &'static str {
    // HashMap in a comment, thread::spawn and .unwrap() too.
    /* a block comment with panic!("x")
    spanning lines, mentioning TcpStream */
    let s = "contains .unwrap() and panic!(\"x\") in a string";
    let r = r#"raw with "HashMap" and Instant inside"#;
    let rr = r##"nested hashes: thread::spawn and "quotes" survive"##;
    let b = b"byte string with thread_rng";
    let c = 'x'; // a char literal; lifetimes like 'a below must survive
    fn g<'a>(v: &'a str) -> &'a str {
        v
    }
    let _ = (s, r, rr, b, c);
    g("ok")
}

pub fn authenticated_open(chan: &mut Chan, share: &AuthMat) -> Result<Mat> {
    // reconstruct( in this comment must not fire, and the wrapper's
    // name must not be mistaken for the raw primitive.
    reconstruct_committed(chan, share, "net.fixture")
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn test_regions_are_exempt() {
        let mut m = HashMap::new();
        m.insert(1u64, 2u64);
        let t0 = std::time::Instant::now();
        let h = std::thread::spawn(|| m.len());
        let _ = (t0.elapsed(), h.join());
    }
}
