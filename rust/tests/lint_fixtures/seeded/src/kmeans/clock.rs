//! Seeded violation: wall-clock observed inside a protocol step.

pub fn step_wall() -> f64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
