//! Seeded violation: panics in a wire path.

pub fn recv_one(v: Option<u64>) -> u64 {
    v.unwrap()
}

pub fn must(flag: bool) {
    if !flag {
        panic!("wire broke");
    }
}
