//! Seeded violation: a suppression without a justification still counts.

pub fn lazy(v: Option<u64>) -> u64 {
    // lint:allow(no-panic-in-wire-paths)
    v.unwrap()
}
