//! Seeded violation: ambient OS entropy (the rule applies everywhere).

pub fn nonce() -> u64 {
    let mut rng = rand::thread_rng();
    rng.gen()
}
