//! Seeded violation: an unmetered socket outside net/.

pub fn dial(addr: &str) -> std::io::Result<std::net::TcpStream> {
    std::net::TcpStream::connect(addr)
}
