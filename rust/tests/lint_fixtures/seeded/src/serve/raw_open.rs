//! Seeded violation: a raw share open outside the sanctioned
//! semi-honest modules — bypasses the deferred MAC ledger.

pub fn leak(chan: &mut Chan, share: &Mat) -> Mat {
    reconstruct(chan, share)
}
