//! Seeded violation: ad-hoc fan-out bypassing runtime::pool.

pub fn fan_out() -> u64 {
    let h = std::thread::spawn(|| 1 + 1);
    h.join().unwrap_or(0)
}
