//! M-Kmeans baseline integration: correctness against plaintext, and the
//! structural cost differences the paper exploits (Q1).

use ppkmeans::data::blobs::BlobSpec;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::kmeans::{plaintext, secure};
use ppkmeans::mkmeans::{run_vertical, MkmeansConfig};

#[test]
fn mkmeans_correct_on_multiple_datasets() {
    for (n, k, seed) in [(12, 2, 1u128), (18, 3, 2)] {
        let mut spec = BlobSpec::new(n, 2, k);
        spec.spread = 0.02;
        let ds = spec.generate(seed);
        let cfg = MkmeansConfig { k, iters: 2, seed: 5, d_a: 1 };
        let out = run_vertical(&ds, &cfg).unwrap();
        let plain = plaintext::kmeans(&ds, k, 2, 5);
        assert_eq!(out.assignments, plain.assignments, "n={n} k={k}");
    }
}

#[test]
fn ours_online_beats_mkmeans_total_structure() {
    // The paper's headline (Q1): our online phase ≪ M-Kmeans single
    // timeline, because M-Kmeans pays OT triple generation + GC inline.
    let mut spec = BlobSpec::new(24, 2, 2);
    spec.spread = 0.02;
    let ds = spec.generate(8);

    let scfg = SecureKmeansConfig {
        k: 2,
        iters: 2,
        partition: Partition::Vertical { d_a: 1 },
        ..Default::default()
    };
    let ours = secure::run(&ds, &scfg).unwrap();
    let ours_online_bytes = ours.meter_a.total_prefix("online.").bytes_sent
        + ours.meter_b.total_prefix("online.").bytes_sent;

    let mcfg = MkmeansConfig { k: 2, iters: 2, seed: scfg.seed, d_a: 1 };
    let mk = run_vertical(&ds, &mcfg).unwrap();

    assert_eq!(ours.assignments, mk.assignments, "both protocols compute the same model");
    assert!(
        mk.bytes_total > 5 * ours_online_bytes,
        "M-Kmeans single-timeline traffic ({}) must dwarf our online ({})",
        mk.bytes_total,
        ours_online_bytes
    );
}

#[test]
fn gc_width_covers_distance_range() {
    // |D'| at scale 2f with unit-interval data: < d · 2^(2·20) ≤ 2^45 for
    // d ≤ 32 — safely inside the 48-bit GC words.
    let max_d = 32u64;
    let bound = (max_d as f64) * (1u64 << 40) as f64;
    assert!(bound < (1u64 << (ppkmeans::mkmeans::gcmin::GC_WIDTH - 1)) as f64);
}
