//! End-to-end scoring-service test (the PR's acceptance scenario):
//! train on generated fraud data, persist both parties' model shares,
//! resume them in fresh scorers, and score a stream of micro-batches
//! against a prefabricated, replenished material bank — asserting
//! plaintext-oracle agreement, the exact assignment-only flight budget,
//! and a balanced bank ledger.

use ppkmeans::data::fraud_gen;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::offline::bank::BankConfig;
use ppkmeans::ring::fixed::encode_f64;
use ppkmeans::ring::matrix::Mat;
use ppkmeans::serve::driver::{serve_stream, train_model, ServeConfig};
use ppkmeans::serve::model::TrainedModel;
use ppkmeans::serve::scorer::score_rounds;

/// Exact plaintext oracle of the protocol's assignment math: D'_j =
/// ‖μ_j‖² − 2·x·μ_j evaluated in ring arithmetic on the encoded
/// (normalized) row — integer-exact, so no fixed-point tolerance games.
fn oracle_assign(x_enc: &[u64], mu_enc: &Mat) -> usize {
    let (k, d) = (mu_enc.rows, mu_enc.cols);
    let mut best = 0usize;
    let mut best_v = i64::MAX;
    for j in 0..k {
        let mut u = 0u64;
        let mut dot = 0u64;
        for l in 0..d {
            let m = mu_enc.at(j, l);
            u = u.wrapping_add(m.wrapping_mul(m));
            dot = dot.wrapping_add(x_enc[l].wrapping_mul(m));
        }
        let dp = u.wrapping_sub(dot.wrapping_mul(2)) as i64;
        if dp < best_v {
            best_v = dp;
            best = j;
        }
    }
    best
}

/// The oracle's true squared distance (scale 2f) for the flag check.
fn oracle_dist_2f(x_enc: &[u64], mu_enc: &Mat, j: usize) -> i64 {
    let d = mu_enc.cols;
    let mut acc = 0u64;
    for l in 0..d {
        let diff = x_enc[l].wrapping_sub(mu_enc.at(j, l));
        acc = acc.wrapping_add(diff.wrapping_mul(diff));
    }
    acc as i64
}

#[test]
fn train_save_load_score_forever() {
    let (k, iters) = (3, 3);
    let batch_rows = 20;
    let batches = 11; // 1 probe + 10 bank-served

    // ---- Train on generated fraud data (vertical 18 + 24 split). ----
    let train = fraud_gen::generate(300, 0.05, 41);
    let cfg = SecureKmeansConfig {
        k,
        iters,
        seed: 17,
        partition: Partition::Vertical { d_a: train.d_payment },
        ..Default::default()
    };
    let (out, models) = train_model(&train.data, &cfg, 0.05).unwrap();
    assert_eq!(out.centroid_shares[0].add(&out.centroid_shares[1]).decode(), out.centroids);

    // ---- Save both parties' shares; resume them in a fresh process'
    // worth of state (load from disk, build new scorers). ----
    let dir = std::env::temp_dir().join(format!("ppkm_serve_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let [ma, mb] = models;
    ma.save(&dir.join(TrainedModel::file_name(0))).unwrap();
    mb.save(&dir.join(TrainedModel::file_name(1))).unwrap();
    let la = TrainedModel::load(&dir.join(TrainedModel::file_name(0))).unwrap();
    let lb = TrainedModel::load(&dir.join(TrainedModel::file_name(1))).unwrap();
    assert_eq!(la, ma);
    assert_eq!(lb, mb);
    std::fs::remove_dir_all(&dir).ok();
    let tau_2f = ppkmeans::fraud::encode_threshold_2f(la.tau);

    // ---- Score 11 micro-batches; the bank holds 5, forcing at least
    // one replenishment over the 10 bank-served batches. ----
    let stream = fraud_gen::generate(batches * batch_rows, 0.05, 4242);
    let scfg = ServeConfig {
        batch_rows,
        batches,
        bank: BankConfig { prefab_batches: 5, low_water: 2, refill_batches: 4 },
        seed: 0xBA4C,
        ..Default::default()
    };
    let served = serve_stream([la.clone(), lb.clone()], &stream.data, &scfg).unwrap();
    assert_eq!(served.results.len(), batches);
    assert_eq!(served.batch_stats.len(), batches);

    // (a) Assignments (and flags) match the plaintext oracle on every
    // transaction. The oracle normalizes with the models' training
    // stats — exactly what each scorer does locally per block.
    let joint_stats: Vec<(f64, f64)> =
        la.stats.iter().chain(lb.stats.iter()).cloned().collect();
    assert_eq!(joint_stats.len(), stream.data.d);
    let mu_enc = Mat::encode(k, stream.data.d, &out.centroids);
    let mut checked = 0;
    for (b, result) in served.results.iter().enumerate() {
        assert_eq!(result.malformed_rows, 0, "batch {b}");
        for r in 0..batch_rows {
            let row = stream.data.row(b * batch_rows + r);
            let x_enc: Vec<u64> = row
                .iter()
                .zip(&joint_stats)
                .map(|(&v, &(lo, hi))| {
                    encode_f64(if hi > lo { (v - lo) / (hi - lo) } else { 0.0 })
                })
                .collect();
            let want = oracle_assign(&x_enc, &mu_enc);
            assert_eq!(result.assignments[r], want, "batch {b} row {r}");
            let want_flag = oracle_dist_2f(&x_enc, &mu_enc, want) > tau_2f as i64;
            assert_eq!(result.fraud_flags[r], want_flag, "flag: batch {b} row {r}");
            checked += 1;
        }
    }
    assert_eq!(checked, batches * batch_rows);

    // (b) Every batch costs exactly the assignment-only budget — and no
    // S3 phase ever ran during serving.
    let budget = score_rounds(k);
    for (b, s) in served.batch_stats.iter().enumerate() {
        assert_eq!(s.online.rounds, budget, "batch {b} flight budget");
        assert!(s.online.bytes_sent > 0, "batch {b}");
    }
    assert_eq!(served.warmup_stats.rounds, 1, "warmup is one flight");
    for phase in ["serve.s3", "online.s1", "online.s2", "online.s3"] {
        assert_eq!(served.meter_a.get(phase).rounds, 0, "{phase} must not run");
        assert_eq!(served.meter_b.get(phase).rounds, 0, "{phase} must not run");
    }
    // The serve.* phases account for every serving flight.
    let phase_sum: u64 = ["serve.warmup", "serve.s1", "serve.s2", "serve.flag", "serve.reveal"]
        .iter()
        .map(|p| served.meter_a.get(p).rounds)
        .sum();
    assert_eq!(phase_sum, 1 + budget * batches as u64);

    // (c) Bank stock accounting balances exactly.
    assert_eq!(served.bank_prefabricated, 5);
    assert_eq!(served.bank_consumed, batches - 1, "probe is served inline");
    assert!(served.bank_replenish_events >= 1, "5 < 10 batches must replenish");
    assert_eq!(
        served.bank_prefabricated + served.bank_replenished - served.bank_consumed,
        served.bank_remaining,
        "prefabricated + replenished − consumed == remaining"
    );
    assert_eq!(served.bank_misses, 0, "every draw must hit prefabricated stock");

    // The planned per-batch demand is tile-uniform: no training-sized
    // matrix shape — everything is bounded by the batch and the geometry.
    let max_dim = served
        .per_batch_demand
        .mats
        .iter()
        .map(|&((m, kk, n), _)| m.max(kk).max(n))
        .max()
        .unwrap();
    assert!(
        max_dim <= batch_rows.max(stream.data.d),
        "per-batch shapes must be batch-bounded, got {max_dim}"
    );
}

#[test]
fn serve_stream_validates_inputs() {
    let train = fraud_gen::generate(120, 0.05, 7);
    let cfg = SecureKmeansConfig {
        k: 2,
        iters: 2,
        partition: Partition::Vertical { d_a: train.d_payment },
        ..Default::default()
    };
    let (_, [ma, mb]) = train_model(&train.data, &cfg, 0.05).unwrap();

    // Stream shorter than batches × rows.
    let short = fraud_gen::generate(30, 0.05, 8);
    let scfg = ServeConfig { batch_rows: 16, batches: 4, ..Default::default() };
    assert!(serve_stream([ma.clone(), mb.clone()], &short.data, &scfg).is_err());

    // Mismatched feature count.
    let wrong_d = ppkmeans::data::blobs::BlobSpec::new(64, 4, 2).generate(9);
    let scfg = ServeConfig { batch_rows: 8, batches: 2, ..Default::default() };
    assert!(serve_stream([ma.clone(), mb.clone()], &wrong_d, &scfg).is_err());

    // Two copies of the same party's share.
    let scfg = ServeConfig { batch_rows: 8, batches: 2, ..Default::default() };
    let stream = fraud_gen::generate(16, 0.05, 10);
    assert!(serve_stream([ma.clone(), ma.clone()], &stream.data, &scfg).is_err());

    // Shares from two different training runs (same geometry, different
    // public τ) must be rejected instead of reconstructing garbage.
    let other = fraud_gen::generate(120, 0.05, 99);
    let (_, [_, mb2]) = train_model(&other.data, &cfg, 0.05).unwrap();
    assert_ne!(mb2.tau, ma.tau, "distinct runs should land distinct quantiles");
    let scfg = ServeConfig { batch_rows: 8, batches: 2, ..Default::default() };
    assert!(serve_stream([ma.clone(), mb2], &stream.data, &scfg).is_err());

    // Horizontal training cannot produce a serving model.
    let hcfg = SecureKmeansConfig {
        k: 2,
        iters: 1,
        partition: Partition::Horizontal { n_a: 60 },
        ..Default::default()
    };
    assert!(train_model(&train.data, &hcfg, 0.05).is_err());
}
