//! Gateway regressions: the determinism contract (a session's reveals
//! and meter are bit-identical alone, concurrent, and across
//! transports), the sharded bank's ledger under three checkout
//! interleavings, meter conservation through the mux, and the typed
//! `Error::Overload` backpressure paths (admission queue + dry bank).

use ppkmeans::coordinator::remote::{run_scenario, run_scenario_local, Pipeline, Scenario};
use ppkmeans::data::fraud_gen;
use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
use ppkmeans::net::meter::Meter;
use ppkmeans::net::mux::MUX_LINK_PHASE;
use ppkmeans::net::{duplex_pair, Chan, TcpTransport};
use ppkmeans::offline::bank::BankConfig;
use ppkmeans::offline::store::Demand;
use ppkmeans::runtime::pool;
use ppkmeans::serve::driver::train_model;
use ppkmeans::serve::gateway::{
    gateway_party, GatewayConfig, GatewayOutput, SessionWorkload, ShardedBank,
};
use ppkmeans::serve::model::TrainedModel;
use ppkmeans::ss::triples::TripleSource;
use ppkmeans::util::error::Error;
use std::collections::BTreeMap;
use std::net::TcpListener;
use std::thread;

// ---- Satellite: three interleavings of concurrent shard checkout ----

const IBATCHES: usize = 5;

fn demand() -> Demand {
    let mut d = Demand::default();
    d.mat(4, 2, 3);
    d.vec_lanes(8);
    d
}

fn ibank(tags: &[u64]) -> ShardedBank {
    ShardedBank::new(
        0x7E57,
        0,
        demand(),
        tags,
        IBATCHES,
        // prefab 1, no low-water: every later checkout steals inline,
        // so the interleavings really contend on the shard locks.
        BankConfig { prefab_batches: 1, low_water: 0, refill_batches: 2 },
        2,
        1,
    )
}

type Drawn = BTreeMap<(u64, usize), (Vec<u64>, Vec<u64>, Vec<u64>)>;

/// Check a session-batch kit out, draw its elementwise triple, and
/// prove the draw hit prefabricated stock (miss-free work-stealing).
fn draw(bank: &ShardedBank, tag: u64, batch: usize) -> ((u64, usize), (Vec<u64>, Vec<u64>, Vec<u64>)) {
    let mut kit = bank.checkout(tag, batch).unwrap();
    let t = kit.vec_triple(8);
    assert_eq!(kit.misses, 0, "stolen kit for ({tag}, {batch}) missed its stock");
    ((tag, batch), (t.u, t.v, t.z))
}

fn check_ledgers(bank: &ShardedBank, label: &str) {
    let g = bank.ledger();
    assert!(g.balances(), "{label}: global ledger must balance: {g:?}");
    assert_eq!(g.consumed, (3 * IBATCHES) as u64, "{label}");
    assert!(g.stalls > 0, "{label}: prefab 1 must force not-ready checkouts");
    let mut sum = (0u64, 0u64, 0u64, 0u64);
    for s in bank.shard_ledgers() {
        assert!(s.balances(), "{label}: shard ledger must balance: {s:?}");
        sum = (
            sum.0 + s.prefabricated,
            sum.1 + s.replenished,
            sum.2 + s.consumed,
            sum.3 + s.stock,
        );
    }
    assert_eq!(
        sum,
        (g.prefabricated, g.replenished, g.consumed, g.stock),
        "{label}: shard ledgers must sum to the global ledger"
    );
}

#[test]
fn three_checkout_interleavings_balance_and_agree() {
    let tags = [1u64, 2, 3];

    // (a) Session-major: one concurrent worker per session, so two
    // sessions contend on the shard they share (work-stealing).
    let bank_a = ibank(&tags);
    let per_worker = pool::run_workers("gwia", 3, |i| {
        (0..IBATCHES).map(|b| draw(&bank_a, tags[i], b)).collect::<Vec<_>>()
    });
    let a: Drawn = per_worker.into_iter().flatten().collect();
    check_ledgers(&bank_a, "session-major");

    // (b) Batch-major on a single thread: strict round-robin.
    let bank_b = ibank(&tags);
    let mut b: Drawn = BTreeMap::new();
    for batch in 0..IBATCHES {
        for &tag in &tags {
            let (k, v) = draw(&bank_b, tag, batch);
            b.insert(k, v);
        }
    }
    check_ledgers(&bank_b, "batch-major");

    // (c) Skewed: one worker interleaves sessions 3 and 1 (reverse
    // shard order), the other drains session 2.
    let bank_c = ibank(&tags);
    let per_worker = pool::run_workers("gwic", 2, |i| {
        let mut out = Vec::new();
        if i == 0 {
            for batch in 0..IBATCHES {
                out.push(draw(&bank_c, 3, batch));
                out.push(draw(&bank_c, 1, batch));
            }
        } else {
            for batch in 0..IBATCHES {
                out.push(draw(&bank_c, 2, batch));
            }
        }
        out
    });
    let c: Drawn = per_worker.into_iter().flatten().collect();
    check_ledgers(&bank_c, "skewed");

    // Whoever fabricated a kit, its material is identical: triples are
    // keyed by (tag, batch) alone.
    assert_eq!(a.len(), 3 * IBATCHES);
    assert_eq!(a, b, "session-major and batch-major must draw identical material");
    assert_eq!(a, c, "work-stealing must not change any kit's material");
}

// ---- End-to-end gateway fixtures ----

const BR: usize = 8; // batch_rows
const NB: usize = 2; // batches per session
const NS: usize = 3; // sessions

/// Train a small fraud model and slice a stream into per-party
/// session workloads (tags 1..=NS).
fn trained() -> (TrainedModel, TrainedModel, Vec<SessionWorkload>, Vec<SessionWorkload>) {
    let train = fraud_gen::generate(200, 0.05, 41);
    let cfg = SecureKmeansConfig {
        k: 3,
        iters: 2,
        seed: 17,
        partition: Partition::Vertical { d_a: train.d_payment },
        ..Default::default()
    };
    let (_, [ma, mb]) = train_model(&train.data, &cfg, 0.05).unwrap();
    let stream = fraud_gen::generate(NS * NB * BR, 0.05, 4242);
    let (d, d_a) = (ma.d, ma.d_a);
    assert_eq!(stream.data.d, d);
    let mut wl_a = Vec::new();
    let mut wl_b = Vec::new();
    for s in 0..NS {
        let mut blocks_a = Vec::new();
        let mut blocks_b = Vec::new();
        for b in 0..NB {
            let base = (s * NB + b) * BR;
            let mut xa = Vec::new();
            let mut xb = Vec::new();
            for i in base..base + BR {
                let row = stream.data.row(i);
                xa.extend_from_slice(&row[..d_a]);
                xb.extend_from_slice(&row[d_a..]);
            }
            blocks_a.push(xa);
            blocks_b.push(xb);
        }
        wl_a.push(SessionWorkload { tag: s as u64 + 1, blocks: blocks_a });
        wl_b.push(SessionWorkload { tag: s as u64 + 1, blocks: blocks_b });
    }
    (ma, mb, wl_a, wl_b)
}

fn gateway_cfg(sessions: usize, workers: usize) -> GatewayConfig {
    GatewayConfig {
        sessions,
        queue: 0,
        workers,
        replenishers: 1,
        shards: 2,
        batch_rows: BR,
        batches: NB,
        bank: BankConfig { prefab_batches: 1, low_water: 1, refill_batches: 1 },
        seed: 0x6A7E1,
        ..GatewayConfig::default()
    }
}

type PartyRun = (GatewayOutput, Meter);

/// Run both parties' gateways over the given channel pair.
fn run_gateway(
    c0: Chan,
    c1: Chan,
    ma: TrainedModel,
    mb: TrainedModel,
    wl_a: Vec<SessionWorkload>,
    wl_b: Vec<SessionWorkload>,
    cfg: &GatewayConfig,
) -> (PartyRun, PartyRun) {
    let (cfg_a, cfg_b) = (cfg.clone(), cfg.clone());
    let side = |mut c: Chan, m: TrainedModel, wl: Vec<SessionWorkload>, cfg: GatewayConfig| {
        thread::Builder::new()
            .stack_size(64 << 20)
            .spawn(move || {
                let out = gateway_party(&mut c, m, wl, &cfg).unwrap();
                (out, c.into_meter())
            })
            .unwrap()
    };
    let h0 = side(c0, ma, wl_a, cfg_a);
    let h1 = side(c1, mb, wl_b, cfg_b);
    (h0.join().unwrap(), h1.join().unwrap())
}

/// A connected TCP channel pair over an ephemeral localhost port.
fn tcp_pair() -> (Chan, Chan) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let h = thread::spawn(move || TcpTransport::accept_from(&listener).unwrap());
    let client = TcpTransport::connect(&addr).unwrap();
    let server = h.join().unwrap();
    (Chan::from_tcp(server, 0), Chan::from_tcp(client, 1))
}

// ---- The determinism contract ----

/// `sessions = N` concurrent over real TCP ≡ each session alone over an
/// in-process duplex pair: per-session reveals, meters and miss counts
/// are bit-identical, and per-session meters sum exactly to the link's
/// `gateway.mux` totals.
#[test]
fn concurrent_sessions_match_sequential_single_session_runs() {
    let (ma, mb, wl_a, wl_b) = trained();

    // Concurrent: all NS sessions at once, 3 workers, over TCP.
    let (c0, c1) = tcp_pair();
    let cfg = gateway_cfg(NS, 3);
    let ((out_a, meter_a), (out_b, meter_b)) =
        run_gateway(c0, c1, ma.clone(), mb.clone(), wl_a.clone(), wl_b.clone(), &cfg);
    for out in [&out_a, &out_b] {
        assert_eq!(out.admitted(), NS);
        assert!(out.rejected.is_empty());
        assert_eq!(out.misses(), 0, "probe-planned bank must never miss");
        assert!(out.ledger.balances(), "{:?}", out.ledger);
        assert_eq!(out.ledger.consumed, (NS * NB) as u64);
    }
    // Meter conservation: per-session meters sum to the mux link phase.
    for (out, meter) in [(&out_a, &meter_a), (&out_b, &meter_b)] {
        let sum = out.online_total();
        let link = meter.get(MUX_LINK_PHASE);
        assert_eq!(sum.bytes_sent, link.bytes_sent, "session meters must sum to the link");
        assert_eq!(sum.msgs_sent, link.msgs_sent);
        assert_eq!(link.rounds, 0, "link flight interleaving must stay unmetered");
    }

    // Alone: each session in its own single-session gateway (tag
    // preserved), one worker, in-process duplex.
    for i in 0..NS {
        let (c0, c1) = duplex_pair();
        let cfg1 = gateway_cfg(1, 1);
        let ((alone_a, _), (alone_b, _)) = run_gateway(
            c0,
            c1,
            ma.clone(),
            mb.clone(),
            vec![wl_a[i].clone()],
            vec![wl_b[i].clone()],
            &cfg1,
        );
        for (alone, conc) in [(&alone_a, &out_a), (&alone_b, &out_b)] {
            let (atag, ar) = &alone.sessions[0];
            let (ctag, cr) = &conc.sessions[i];
            assert_eq!(atag, ctag);
            let (ar, cr) = (ar.as_ref().unwrap(), cr.as_ref().unwrap());
            assert_eq!(ar.results, cr.results, "session {atag}: reveals must match alone");
            assert_eq!(ar.online, cr.online, "session {atag}: meters must match alone");
            assert_eq!(ar.misses, cr.misses);
        }
        // And both parties agree on the reveal.
        let ra = alone_a.sessions[0].1.as_ref().unwrap();
        let rb = alone_b.sessions[0].1.as_ref().unwrap();
        assert_eq!(ra.results, rb.results);
    }
}

// ---- Typed backpressure ----

#[test]
fn admission_queue_rejects_the_same_sessions_on_both_parties() {
    let (ma, mb, wl_a, wl_b) = trained();
    let (c0, c1) = duplex_pair();
    let cfg = GatewayConfig { queue: 2, ..gateway_cfg(NS, 2) };
    let ((out_a, _), (out_b, _)) = run_gateway(c0, c1, ma, mb, wl_a, wl_b, &cfg);
    for out in [&out_a, &out_b] {
        assert_eq!(out.admitted(), 2);
        assert_eq!(out.rejected, vec![3], "tags beyond the queue bound are refused");
        assert_eq!(out.ledger.consumed, (2 * NB) as u64, "rejected sessions draw nothing");
        assert!(out.sessions.iter().all(|(_, r)| r.is_ok()));
    }
    assert_eq!(out_a.rejected, out_b.rejected);
}

#[test]
fn dry_bank_aborts_sessions_with_a_typed_overload_on_both_parties() {
    let (ma, mb, wl_a, wl_b) = trained();
    let (c0, c1) = duplex_pair();
    // Prefab covers batch 0 only and replenishment is disabled: every
    // session must die at batch 1 — symmetrically, typed, no panic, and
    // the gateway itself still tears down cleanly.
    let cfg = GatewayConfig {
        bank: BankConfig { prefab_batches: 1, low_water: 0, refill_batches: 0 },
        ..gateway_cfg(NS, 2)
    };
    let ((out_a, _), (out_b, _)) = run_gateway(c0, c1, ma, mb, wl_a, wl_b, &cfg);
    for out in [&out_a, &out_b] {
        assert_eq!(out.admitted(), NS);
        assert!(out.ledger.balances());
        assert_eq!(out.ledger.consumed, NS as u64, "exactly the prefabricated batch 0 kits");
        for (tag, r) in &out.sessions {
            match r {
                Err(Error::Overload(msg)) => {
                    assert!(msg.contains("replenishment is disabled"), "session {tag}: {msg}");
                }
                other => panic!("session {tag}: expected Overload, got {other:?}"),
            }
        }
    }
}

// ---- Scenario layer ----

/// Run a scenario with both parties as threads over a channel pair.
fn run_over(mut c0: Chan, mut c1: Chan, sc: &Scenario) -> (String, String) {
    let sc0 = sc.clone();
    let sc1 = sc.clone();
    let h0 = thread::Builder::new()
        .stack_size(64 << 20)
        .spawn(move || run_scenario(&mut c0, &sc0).unwrap().to_json())
        .unwrap();
    let h1 = thread::Builder::new()
        .stack_size(64 << 20)
        .spawn(move || run_scenario(&mut c1, &sc1).unwrap().to_json())
        .unwrap();
    (h0.join().unwrap(), h1.join().unwrap())
}

#[test]
fn gateway_pipeline_transcripts_are_transport_and_worker_independent() {
    let sc = Scenario {
        pipeline: Pipeline::Gateway,
        n: 120,
        k: 2,
        iters: 2,
        seed: 5,
        data_seed: 3,
        batch_rows: 8,
        batches: 2,
        prefab: 1,
        low_water: 1,
        refill: 1,
        sessions: 3,
        queue: 0,
        gateway_workers: 3,
        ..Default::default()
    };
    let (l0, l1) = run_scenario_local(&sc).unwrap();
    let (c0, c1) = tcp_pair();
    let (t0, t1) = run_over(c0, c1, &sc);
    assert_eq!(l0.to_json(), t0, "party 0 transcript must not depend on the transport");
    assert_eq!(l1.to_json(), t1, "party 1 transcript must not depend on the transport");
    assert!(t0.contains("gateway.mux"), "mux traffic must be metered: {t0}");
    assert!(t0.contains("session1.scores") && t0.contains("session3.scores"));
    assert!(t0.contains("\"gateway.misses\": \"0\""), "{t0}");
    assert!(t0.contains("\"gateway.admitted\": \"3\""), "{t0}");

    // The worker count is a party-local throughput knob: same digest,
    // same transcript, byte for byte.
    let sc_w1 = Scenario { gateway_workers: 1, ..sc.clone() };
    assert_eq!(sc_w1.digest(), sc.digest());
    let (w0, w1) = run_scenario_local(&sc_w1).unwrap();
    assert_eq!(w0.to_json(), l0.to_json(), "worker count must not move the transcript");
    assert_eq!(w1.to_json(), l1.to_json());
}
