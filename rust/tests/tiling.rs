//! Row-tiling acceptance tests: tiled output matches the plaintext
//! oracle / monolithic runs across backends and partitions, and the
//! recorded offline demand is tile-bounded — the deployable
//! offline/online split decoupled from n.

use ppkmeans::data::{blobs::BlobSpec, sparse_gen};
use ppkmeans::kmeans::config::{EsdMode, Partition, SecureKmeansConfig, TileFlights};
use ppkmeans::kmeans::{plaintext, secure};
use ppkmeans::offline::dealer::Dealer;
use ppkmeans::offline::store::TripleStore;

fn well_separated(n: usize, d: usize, k: usize, seed: u128) -> ppkmeans::data::blobs::Dataset {
    let mut spec = BlobSpec::new(n, d, k);
    spec.spread = 0.02;
    spec.generate(seed)
}

/// Largest dimension of any matrix-triple shape in a demand.
fn max_mat_dim(demand: &ppkmeans::offline::store::Demand) -> usize {
    demand.mats.iter().map(|&((m, k, n), _)| m.max(k).max(n)).max().unwrap_or(0)
}

#[test]
fn tiled_demand_has_no_n_sized_matrix_shape() {
    // Acceptance criterion: with tile_rows = Some(B) every recorded
    // matrix-triple dimension is bounded by max(B, d, k) — no shape
    // grows with n. The monolithic run's shapes do.
    let (n, d, k, b) = (60usize, 4usize, 3usize, 17usize);
    let ds = well_separated(n, d, k, 90);
    let base = SecureKmeansConfig {
        k,
        iters: 2,
        partition: Partition::Vertical { d_a: d / 2 },
        ..Default::default()
    };
    let mono = secure::run(&ds, &base).unwrap();
    assert_eq!(max_mat_dim(&mono.demand), n, "monolithic shapes are n-sized");

    for flights in [TileFlights::Lockstep, TileFlights::Streamed] {
        let cfg =
            SecureKmeansConfig { tile_rows: Some(b), tile_flights: flights, ..base.clone() };
        let tiled = secure::run(&ds, &cfg).unwrap();
        assert!(!tiled.demand.mats.is_empty());
        let bound = b.max(d).max(k);
        assert!(
            max_mat_dim(&tiled.demand) <= bound,
            "{flights:?}: max mat dim {} must be ≤ {bound}",
            max_mat_dim(&tiled.demand)
        );
        assert!(
            tiled.demand.peak_mat_triple_bytes() < mono.demand.peak_mat_triple_bytes(),
            "{flights:?}: tiling must shrink the peak triple"
        );
    }
}

#[test]
fn divisor_tiling_demand_is_uniform_and_prefillable() {
    // With B | n the per-tile matrix shapes are uniform — a handful of
    // shapes whose counts are (tiles × iters)-multiples — so one
    // prefill recipe drawn from the recorded demand serves the whole
    // run: replaying the demand against a prefilled store is all hits.
    let (n, d, k, b, iters) = (60usize, 4usize, 3usize, 20usize, 2usize);
    let tiles = n / b;
    let ds = well_separated(n, d, k, 91);
    let cfg = SecureKmeansConfig {
        k,
        iters,
        partition: Partition::Vertical { d_a: d / 2 },
        tile_rows: Some(b),
        ..Default::default()
    };
    let out = secure::run(&ds, &cfg).unwrap();
    for &((m, kk, nn), count) in &out.demand.mats {
        assert!(
            m.max(kk).max(nn) <= b.max(d).max(k),
            "shape ({m},{kk},{nn}) exceeds the tile bound"
        );
        assert_eq!(
            count % (tiles * iters),
            0,
            "uniform tiling must repeat shape ({m},{kk},{nn}) per tile per iteration"
        );
    }
    // The recorded demand is a complete prefill recipe.
    let mut store = TripleStore::new(Dealer::new(cfg.seed, 0));
    store.prefill(&out.demand);
    use ppkmeans::ss::triples::TripleSource;
    for &((m, kk, nn), count) in &out.demand.mats {
        for _ in 0..count {
            let _ = store.mat_triple(m, kk, nn);
        }
    }
    for &lanes in &out.demand.vec_chunks {
        let _ = store.vec_triple(lanes);
    }
    for &lanes in &out.demand.bit_chunks {
        let _ = store.bit_triple(lanes);
    }
    for &lanes in &out.demand.dabit_chunks {
        let _ = store.dabits(lanes);
    }
    assert_eq!(store.misses, 0, "prefilled replay must not miss");
}

#[test]
fn auto_mode_tiles_both_backends_against_the_oracle() {
    // EsdMode::Auto + tiling: the sparse workload routes to HE Protocol
    // 2 (per-tile ciphertext exchanges), the dense one to Beaver; both
    // must match the plaintext oracle with a non-divisor tile size.
    let (n, b) = (60usize, 17usize);
    let mut cfg = SecureKmeansConfig {
        k: 2,
        iters: 2,
        esd: EsdMode::Auto,
        partition: Partition::Vertical { d_a: 3 },
        tile_rows: Some(b),
        ..Default::default()
    };

    let sparse = sparse_gen::generate(n, 6, 2, 0.6, 92);
    let out = secure::run(&sparse, &cfg).unwrap();
    assert_eq!(out.backend_name, "he-protocol2");
    assert_eq!(out.tiles_run, 4);
    let oracle = plaintext::kmeans(&sparse, 2, 2, cfg.seed);
    assert_eq!(out.assignments, oracle.assignments);
    for (a, o) in out.centroids.iter().zip(&oracle.centroids) {
        assert!((a - o).abs() < 1e-2, "sparse-path centroid {a} vs {o}");
    }

    let mut spec = BlobSpec::new(n, 6, 2);
    spec.spread = 0.02;
    let dense = spec.generate(93);
    cfg.tile_flights = TileFlights::Streamed;
    let out = secure::run(&dense, &cfg).unwrap();
    assert_eq!(out.backend_name, "beaver");
    let oracle = plaintext::kmeans(&dense, 2, 2, cfg.seed);
    assert_eq!(out.assignments, oracle.assignments);
    for (a, o) in out.centroids.iter().zip(&oracle.centroids) {
        assert!((a - o).abs() < 1e-2, "dense-path centroid {a} vs {o}");
    }
}

#[test]
fn explicit_he_backend_rides_the_tile_schedule() {
    // The sparse path with explicit EsdMode::He and a non-divisor tile
    // size: per-tile Protocol 2 exchanges must compose to the same
    // clustering as the monolithic HE run.
    let ds = sparse_gen::generate(30, 6, 2, 0.6, 94);
    let base = SecureKmeansConfig {
        k: 2,
        iters: 2,
        esd: EsdMode::he(),
        partition: Partition::Vertical { d_a: 3 },
        ..Default::default()
    };
    let mono = secure::run(&ds, &base).unwrap();
    let cfg = SecureKmeansConfig { tile_rows: Some(13), ..base };
    let tiled = secure::run(&ds, &cfg).unwrap();
    assert_eq!(tiled.backend_name, "he-protocol2");
    assert_eq!(tiled.tiles_run, 3);
    assert_eq!(tiled.assignments, mono.assignments);
}
