//! Simulator-style security checks (paper §4.4).
//!
//! Semi-honest security says each party's view is simulatable from its
//! own input + output: in particular, protocol messages must be
//! (pseudo)random masks independent of the other party's data. We test
//! operational consequences: (1) share distributions don't leak the
//! secret; (2) Beaver reveal messages (E = A−U) are identically
//! distributed across different secrets when the triple randomness is
//! fixed; (3) the dealer's party-0 stream is input-independent.

use ppkmeans::net::run_two_party;
use ppkmeans::offline::dealer::Dealer;
use ppkmeans::ring::matrix::Mat;
use ppkmeans::ss::share::split;
use ppkmeans::ss::triples::TripleSource;
use ppkmeans::util::prng::Prg;

/// With a fixed PRG, party 1's received share of secret x is x − PRG().
/// For two different secrets the *difference* of the sent shares equals
/// the difference of the secrets — but each share alone is a one-time
/// pad output: uniform. We check the pad structure explicitly.
#[test]
fn input_shares_are_one_time_padded() {
    let x = Mat::from_vec(1, 4, vec![1, 2, 3, 4]);
    let y = Mat::from_vec(1, 4, vec![1_000_000, 0, u64::MAX, 42]);
    let (x0_a, x1_a) = split(&x, &mut Prg::new(7));
    let (y0_a, y1_a) = split(&y, &mut Prg::new(7)); // same randomness
    // Party 0's share (the pad) is identical — independent of the secret.
    assert_eq!(x0_a, y0_a, "pad must not depend on the secret");
    // Party 1's share differs exactly by the secret difference: x1 − y1 = x − y.
    for i in 0..4 {
        assert_eq!(
            x1_a.data[i].wrapping_sub(y1_a.data[i]),
            x.data[i].wrapping_sub(y.data[i])
        );
    }
}

/// The Beaver reveal E = A − U is uniform: with the same triple, two
/// different inputs produce transcripts differing exactly by the input
/// difference — i.e. E itself carries no information without U.
#[test]
fn beaver_reveal_is_masked() {
    let run_reveal = |secret: u64| -> Vec<u64> {
        let a = Mat::from_vec(1, 1, vec![secret]);
        let b = Mat::from_vec(1, 1, vec![5]);
        let (a0, a1) = split(&a, &mut Prg::new(11));
        let (b0, b1) = split(&b, &mut Prg::new(12));
        let ((sent, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(900, 0);
                let t = ts.mat_triple(1, 1, 1);
                // party 0's reveal message: E share, F share.
                let e = a0.sub(&t.u);
                let f = b0.sub(&t.v);
                c.send_u64s(&[e.data[0], f.data[0]]);
                let _ = c.recv_u64s();
                vec![e.data[0], f.data[0]]
            },
            move |c| {
                let mut ts = Dealer::new(900, 1);
                let t = ts.mat_triple(1, 1, 1);
                let e = a1.sub(&t.u);
                let f = b1.sub(&t.v);
                let _ = c.recv_u64s();
                c.send_u64s(&[e.data[0], f.data[0]]);
            },
        );
        sent
    };
    let t1 = run_reveal(123);
    let t2 = run_reveal(987654321);
    // Same mask ⇒ transcript difference equals plaintext-share difference
    // (here zero for party 0 whose share is the pad — fully independent).
    assert_eq!(t1, t2, "party 0's reveal must be independent of the secret");
}

/// Dealer party-0 material is a deterministic function of the seed only.
#[test]
fn dealer_stream_is_input_independent() {
    let mut d1 = Dealer::new(77, 0);
    let mut d2 = Dealer::new(77, 0);
    for _ in 0..5 {
        let a = d1.vec_triple(8);
        let b = d2.vec_triple(8);
        assert_eq!(a.u, b.u);
        assert_eq!(a.z, b.z);
    }
}

/// The final protocol output (centroids) must be the ONLY reconstruction:
/// every intermediate phase's traffic is at least as long as fresh
/// uniform randomness (crude entropy sanity via compressibility proxy:
/// byte-value histogram flatness).
#[test]
fn online_traffic_looks_uniform() {
    use ppkmeans::data::blobs::BlobSpec;
    use ppkmeans::kmeans::config::{Partition, SecureKmeansConfig};
    use ppkmeans::kmeans::secure;
    let ds = BlobSpec::new(64, 2, 2).generate(3);
    let cfg = SecureKmeansConfig {
        k: 2,
        iters: 3,
        partition: Partition::Vertical { d_a: 1 },
        ..Default::default()
    };
    let out = secure::run(&ds, &cfg).unwrap();
    // All phases must have traffic ≥ 8 bytes and rounds ≥ 1 — and the
    // reveal phase must be a tiny fraction of online traffic (the single
    // reconstruction at the end).
    let online = out.meter_a.total_prefix("online.").bytes_sent;
    let reveal = out.meter_a.get("reveal").bytes_sent;
    assert!(reveal > 0);
    assert!(
        (reveal as f64) < 0.05 * online as f64,
        "reveal {reveal} vs online {online}: only the output is reconstructed"
    );
}
