//! Cluster-based outlier scoring.
//!
//! The paper's deployment flags transactions that "distinguish outliers
//! when the input size is large enough" [9 — k-means--]: a sample is an
//! outlier if it is far from its assigned centroid (distance above a
//! quantile threshold) or belongs to an abnormally small cluster.

use crate::data::blobs::Dataset;
use crate::kmeans::plaintext::esd;

/// Outlier-detection knobs.
#[derive(Debug, Clone)]
pub struct OutlierConfig {
    /// Flag the top `rate` fraction of samples by distance score.
    pub rate: f64,
    /// Clusters holding fewer than `min_cluster_frac · n` samples are
    /// treated as outlier clusters wholesale.
    pub min_cluster_frac: f64,
}

impl Default for OutlierConfig {
    fn default() -> Self {
        OutlierConfig { rate: 0.05, min_cluster_frac: 0.02 }
    }
}

/// Score samples against centroids and return flagged indices (sorted).
pub fn detect_outliers(
    data: &Dataset,
    centroids: &[f64],
    assignments: &[usize],
    k: usize,
    cfg: &OutlierConfig,
) -> Vec<usize> {
    let d = data.d;
    assert_eq!(centroids.len(), k * d);
    assert_eq!(assignments.len(), data.n);
    let mut counts = vec![0usize; k];
    for &a in assignments {
        counts[a] += 1;
    }
    let min_sz = (cfg.min_cluster_frac * data.n as f64).ceil() as usize;
    // Distance of each sample to its centroid; members of tiny clusters
    // get an infinite score so they always rank first.
    let mut scored: Vec<(f64, usize)> = (0..data.n)
        .map(|i| {
            let j = assignments[i];
            let s = if counts[j] < min_sz {
                f64::INFINITY
            } else {
                esd(data.row(i), &centroids[j * d..(j + 1) * d])
            };
            (s, i)
        })
        .collect();
    scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let n_flag = ((data.n as f64) * cfg.rate).round() as usize;
    let mut out: Vec<usize> = scored[..n_flag.min(data.n)].iter().map(|&(_, i)| i).collect();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_far_points() {
        // 20 points near (0.2, 0.2); 2 points far away; k = 1.
        let mut x = vec![];
        for i in 0..20 {
            x.extend_from_slice(&[0.2 + 0.001 * i as f64, 0.2]);
        }
        x.extend_from_slice(&[0.95, 0.95, 0.9, 0.05]);
        let ds = Dataset { n: 22, d: 2, x, labels: vec![0; 22] };
        let centroids = vec![0.25, 0.2];
        let assignments = vec![0usize; 22];
        let cfg = OutlierConfig { rate: 2.0 / 22.0, min_cluster_frac: 0.0 };
        let got = detect_outliers(&ds, &centroids, &assignments, 1, &cfg);
        assert_eq!(got, vec![20, 21]);
    }

    #[test]
    fn tiny_clusters_flagged_wholesale() {
        let x = vec![0.1, 0.1, 0.11, 0.1, 0.12, 0.1, 0.9, 0.9];
        let ds = Dataset { n: 4, d: 2, x, labels: vec![0; 4] };
        let centroids = vec![0.11, 0.1, 0.9, 0.9];
        let assignments = vec![0, 0, 0, 1];
        let cfg = OutlierConfig { rate: 0.25, min_cluster_frac: 0.3 };
        let got = detect_outliers(&ds, &centroids, &assignments, 2, &cfg);
        assert_eq!(got, vec![3]); // the singleton cluster member
    }
}
