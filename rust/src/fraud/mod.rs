//! Fraud detection on clustering output (paper §5.6).
//!
//! Transactions are grouped by (secure) K-means; outliers — samples far
//! from every dense cluster — are flagged as fraud candidates and scored
//! against ground truth with the Jaccard coefficient. For the serving
//! path, [`threshold`] evaluates the distance-threshold flag **under
//! MPC** on the secret-shared minimum distances, so streaming fraud
//! candidates are a protocol output, not a post-hoc computation on
//! revealed data.

pub mod jaccard;
pub mod outlier;
pub mod threshold;

pub use jaccard::jaccard;
pub use outlier::{detect_outliers, OutlierConfig};
pub use threshold::{distance_threshold, encode_threshold_2f, flag_above};
