//! Fraud detection on clustering output (paper §5.6).
//!
//! Transactions are grouped by (secure) K-means; outliers — samples far
//! from every dense cluster — are flagged as fraud candidates and scored
//! against ground truth with the Jaccard coefficient.

pub mod jaccard;
pub mod outlier;

pub use jaccard::jaccard;
pub use outlier::{detect_outliers, OutlierConfig};
