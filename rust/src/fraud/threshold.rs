//! Secure distance thresholding: the serving-side fraud flag.
//!
//! At training time the coordinator learns a **public** squared-distance
//! threshold τ from the revealed clustering (a quantile of the training
//! samples' distances to their assigned centroids,
//! [`distance_threshold`]). At serving time the flag
//! `[‖x − μ_c(x)‖² > τ]` is evaluated **under MPC** on the secret-shared
//! minimum distance ([`flag_above`]) — fraud candidates are decided by
//! the protocol, not recomputed from revealed assignments, so the only
//! scoring outputs ever reconstructed are the assignment and the flag
//! bit itself.
//!
//! Scale bookkeeping: S1/S2 work on `D' = ‖μ‖² − 2·x·μ` (the constant
//! per-row `‖x‖²` is dropped because it never changes comparisons).
//! The flag needs the *true* squared distance, so each party adds its
//! own plaintext block's row norms back — `‖x‖² = ‖x_A‖² + ‖x_B‖²` is a
//! free local share-sum under the vertical partition — before the single
//! CMP against τ encoded at scale 2f ([`encode_threshold_2f`]).

use crate::data::blobs::Dataset;
use crate::kmeans::plaintext::esd;
use crate::ring::fixed::SCALE;
use crate::ring::matrix::Mat;
use crate::ss::boolean::BoolShare;
use crate::ss::compare::gt_public;
use crate::ss::{Session, SessionOptions};

/// Pick τ as the `(1 − rate)` quantile of the training samples' squared
/// distances to their assigned centroids: roughly the top `rate`
/// fraction of a matching-distribution stream will flag. `rate` is
/// clamped to `[0, 1]`: `rate = 0` yields the maximum training distance
/// (nothing seen in training would flag), `rate = 1` the minimum.
pub fn distance_threshold(
    data: &Dataset,
    centroids: &[f64],
    assignments: &[usize],
    k: usize,
    rate: f64,
) -> f64 {
    let d = data.d;
    assert_eq!(centroids.len(), k * d);
    assert_eq!(assignments.len(), data.n);
    let mut dists: Vec<f64> = (0..data.n)
        .map(|i| {
            let j = assignments[i];
            esd(data.row(i), &centroids[j * d..(j + 1) * d])
        })
        .collect();
    dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rate = rate.clamp(0.0, 1.0);
    let idx = (((data.n as f64) * (1.0 - rate)).floor() as usize).min(data.n - 1);
    dists[idx]
}

/// Encode a plaintext squared-distance threshold at scale 2f (the scale
/// of `D'` and of locally-added `‖x‖²` terms).
pub fn encode_threshold_2f(tau: f64) -> u64 {
    (tau * SCALE * SCALE).round() as i64 as u64
}

/// XOR-shared `[dist > τ]` per lane, for a secret-shared distance matrix
/// at scale 2f against the public threshold `tau_2f`. Strict: a distance
/// exactly equal to τ is **not** flagged. Costs exactly
/// [`crate::ss::boolean::CMP_ROUNDS`] flights for any lane count.
pub fn flag_above(ctx: &mut Session, dist: &Mat, tau_2f: u64) -> BoolShare {
    let c = Mat::from_vec(dist.rows, dist.cols, vec![tau_2f; dist.len()]);
    gt_public(ctx, dist, &c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ss::share::split;
    use crate::ss::Session;
    use crate::util::prng::Prg;

    #[test]
    fn quantile_threshold_brackets_the_tail() {
        // 10 samples at distance ~0, 10 at distance 4 (two clusters of
        // one point each would be degenerate; use one centroid).
        let mut x = Vec::new();
        for _ in 0..10 {
            x.extend_from_slice(&[0.0, 0.0]);
        }
        for _ in 0..10 {
            x.extend_from_slice(&[2.0, 0.0]);
        }
        let ds = Dataset { n: 20, d: 2, x, labels: vec![0; 20] };
        let centroids = vec![0.0, 0.0];
        let assignments = vec![0usize; 20];
        // rate 0.5 → τ at the boundary between the near and far halves.
        let tau = distance_threshold(&ds, &centroids, &assignments, 1, 0.5);
        assert!((0.0..=4.0).contains(&tau), "tau {tau}");
        // rate 0 → τ is the max distance: nothing above it.
        let tau0 = distance_threshold(&ds, &centroids, &assignments, 1, 0.0);
        assert_eq!(tau0, 4.0);
    }

    #[test]
    fn secure_flag_matches_plaintext_threshold() {
        use crate::ss::boolean::CMP_ROUNDS;
        // Distances (scale 2f) 1.0, 2.5, 3.0, 0.1 against τ = 2.5:
        // strictly-above flags only the 3.0 lane.
        let tau = 2.5;
        let vals = [1.0, 2.5, 3.0, 0.1];
        let enc: Vec<u64> = vals.iter().map(|&v| encode_threshold_2f(v)).collect();
        let dist = Mat::from_vec(1, 4, enc);
        let mut prg = Prg::new(31);
        let (d0, d1) = split(&dist, &mut prg);
        let tau_2f = encode_threshold_2f(tau);
        let ((got, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(32, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let before = ctx.chan.meter().total().rounds;
                let b = flag_above(&mut ctx, &d0, tau_2f);
                let spent = ctx.chan.meter().total().rounds - before;
                let theirs = c.exchange_u64s(&b.words);
                let flags: Vec<bool> =
                    (0..4).map(|i| ((b.words[0] ^ theirs[0]) >> i) & 1 == 1).collect();
                (flags, spent)
            },
            move |c| {
                let mut ts = Dealer::new(32, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let b = flag_above(&mut ctx, &d1, tau_2f);
                let _ = c.exchange_u64s(&b.words);
            },
        );
        let (flags, spent) = got;
        assert_eq!(flags, vec![false, false, true, false]);
        assert_eq!(spent, CMP_ROUNDS, "one CMP for any lane count");
    }
}
