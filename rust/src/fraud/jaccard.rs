//! Jaccard coefficient `J(R, R*) = |R ∩ R*| / |R ∪ R*|` (paper §5.6).
//!
//! Inputs are treated as **sets**: order and duplicates are ignored.
//!
//! Degenerate inputs are defined explicitly instead of falling into the
//! 0/0 division: **`J(∅, ∅) = 1.0`** — two empty candidate sets are
//! identical (a detector that flags nothing on a stream with no fraud is
//! perfectly right), while `J(∅, S) = 0.0` for non-empty `S` — flagging
//! nothing when there *is* fraud (or flagging something when there is
//! none) shares no element with the truth.

/// Jaccard similarity of two index sets (need not be sorted; duplicates
/// collapse). Returns a value in `[0, 1]`; see the module docs for the
/// `J(∅, ∅) = 1.0` convention.
pub fn jaccard(r: &[usize], r_star: &[usize]) -> f64 {
    use std::collections::HashSet;
    let a: HashSet<usize> = r.iter().copied().collect();
    let b: HashSet<usize> = r_star.iter().copied().collect();
    let inter = a.intersection(&b).count();
    let union = a.union(&b).count();
    if union == 0 {
        return 1.0; // J(∅, ∅): both empty → identical, not NaN
    }
    inter as f64 / union as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_and_identity() {
        assert_eq!(jaccard(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(jaccard(&[1, 2], &[3, 4]), 0.0);
        let j = jaccard(&[1, 2, 3, 4], &[3, 4, 5, 6]);
        assert!((j - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn both_empty_is_one_not_nan() {
        let j = jaccard(&[], &[]);
        assert!(!j.is_nan(), "J(∅, ∅) must be defined");
        assert_eq!(j, 1.0);
    }

    #[test]
    fn empty_vs_non_empty_is_zero() {
        assert_eq!(jaccard(&[], &[1, 2, 3]), 0.0);
        assert_eq!(jaccard(&[7], &[]), 0.0);
    }

    #[test]
    fn order_and_duplicates_ignored() {
        assert_eq!(jaccard(&[3, 1, 2, 2], &[2, 3, 1]), 1.0);
        // Duplicates collapse before counting: {1,2} vs {2,3} → 1/3.
        let j = jaccard(&[1, 1, 2], &[2, 2, 3]);
        assert!((j - 1.0 / 3.0).abs() < 1e-12);
        // Duplicates on both sides of an empty overlap stay 0.
        assert_eq!(jaccard(&[5, 5, 5], &[6, 6]), 0.0);
    }

    #[test]
    fn symmetric() {
        let (a, b) = ([1usize, 2, 9], [2usize, 9, 11, 12]);
        assert_eq!(jaccard(&a, &b), jaccard(&b, &a));
    }
}
