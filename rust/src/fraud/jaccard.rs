//! Jaccard coefficient `J(R, R*) = |R ∩ R*| / |R ∪ R*|` (paper §5.6).

/// Jaccard similarity of two index sets (need not be sorted).
pub fn jaccard(r: &[usize], r_star: &[usize]) -> f64 {
    use std::collections::HashSet;
    let a: HashSet<usize> = r.iter().copied().collect();
    let b: HashSet<usize> = r_star.iter().copied().collect();
    let inter = a.intersection(&b).count();
    let union = a.union(&b).count();
    if union == 0 {
        return 1.0; // both empty: identical
    }
    inter as f64 / union as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_and_identity() {
        assert_eq!(jaccard(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(jaccard(&[1, 2], &[3, 4]), 0.0);
        assert_eq!(jaccard(&[], &[]), 1.0);
        let j = jaccard(&[1, 2, 3, 4], &[3, 4, 5, 6]);
        assert!((j - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn order_and_duplicates_ignored() {
        assert_eq!(jaccard(&[3, 1, 2, 2], &[2, 3, 1]), 1.0);
    }
}
