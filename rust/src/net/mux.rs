//! Session multiplexer: many concurrent protocol sessions over one
//! party-pair link.
//!
//! The serving gateway ([`crate::serve::gateway`]) scores many client
//! sessions at once, but the deployment has exactly one authenticated
//! link per party pair. [`MuxLink`] splits that link into tagged
//! sub-channels: every frame a session sends is prefixed with its
//! 8-byte little-endian session tag ([`MUX_TAG_BYTES`]), and the
//! receive side routes arriving frames into per-session inboxes. The
//! extension is PPKMWRE1-compatible — the gateway handshake words
//! negotiate it *before* the first tagged frame, and an un-muxed peer
//! never sees a tagged frame (see `docs/PROTOCOLS.md`, "Gateway").
//!
//! ## Accounting invariant
//!
//! Each session gets its own [`Meter`] (inside its [`crate::net::Chan`])
//! that charges payload **plus tag** per frame, so per-session
//! `bytes_sent`/`msgs_sent` sum *exactly* to the link totals kept here
//! under the `"gateway.mux"` phase. Rounds (flights) remain a
//! per-session notion: link-level flight interleaving depends on worker
//! scheduling, so the link meter records `rounds: 0` and stays
//! deterministic.
//!
//! ## Concurrency shape
//!
//! The send half and receive half sit under *separate* locks — a worker
//! blocked in a receive must never stop another worker from sending, or
//! two symmetric parties deadlock. Receives use a reader-token scheme:
//! one blocked receiver takes the transport's receive half out of the
//! shared state (releasing the lock), blocks on the wire, and routes
//! whatever arrives to the owning inbox before waking the others. A
//! transport error is *sticky*: it poisons the link for every session
//! with the same typed error, never a panic
//! (`no-panic-in-wire-paths`).

// Wire-facing code returns typed errors (ppkm-lint rule
// no-panic-in-wire-paths); the clippy deny backs the lint at the
// type-system level, same as the rest of `net`.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use super::channel::{Backend, Chan, MacAcc};
use super::fault::{FaultState, SendAction};
use super::meter::{Meter, PhaseStats};
use super::shape::LinkShaper;
use super::tcp::TcpTransport;
use crate::util::error::{Error, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Bytes of session tag prefixed to every multiplexed frame (u64 LE).
pub const MUX_TAG_BYTES: u64 = 8;

/// Phase label under which the link meter accounts multiplexed traffic.
pub const MUX_LINK_PHASE: &str = "gateway.mux";

/// Lock a mutex, riding through poisoning. A worker that panicked while
/// holding a mux lock left only plain-old-data behind (queues and
/// counters mutate atomically under the lock), so the state is usable;
/// the panic itself still propagates through the pool's join.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Send half of the underlying transport (independently locked).
enum SendHalf {
    Mpsc(Sender<Vec<u8>>),
    Tcp(TcpTransport),
}

impl SendHalf {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        match self {
            SendHalf::Mpsc(tx) => tx
                .send(frame.to_vec())
                .map_err(|_| Error::ChannelClosed("in-process peer hung up".into())),
            SendHalf::Tcp(t) => t.send(frame),
        }
    }
}

/// Receive half of the underlying transport.
enum RecvHalf {
    Mpsc(Receiver<Vec<u8>>),
    Tcp(TcpTransport),
}

impl RecvHalf {
    fn recv(&mut self) -> Result<Vec<u8>> {
        match self {
            RecvHalf::Mpsc(rx) => rx
                .recv()
                .map_err(|_| Error::ChannelClosed("in-process peer hung up".into())),
            RecvHalf::Tcp(t) => t.recv(),
        }
    }
}

/// Receive-side shared state: the reader token, per-session inboxes,
/// link shaping, and the sticky error.
struct RxState {
    /// The transport's receive half. `None` while one session holds the
    /// reader token (it is blocked on the wire with the lock released).
    recv: Option<RecvHalf>,
    /// Per-session frame queues, keyed by tag. `BTreeMap` per the
    /// `no-unordered-iteration` lint: any iteration is deterministic.
    inboxes: BTreeMap<u64, VecDeque<Vec<u8>>>,
    /// Link shaping moves here from the wrapped `Chan`: one physical
    /// pipe, paced once per arriving frame by whichever session reads it.
    shaper: Option<LinkShaper>,
    /// Sticky transport failure: once set, every session receive returns
    /// this as a typed [`Error::ChannelClosed`].
    dead: Option<String>,
}

struct MuxShared {
    tx: Mutex<SendHalf>,
    /// Fault state inherited from the wrapped channel (see
    /// [`crate::net::fault`]): at link level the trigger counts frames
    /// (flights are a per-session notion), checked on every session send.
    fault: Mutex<Option<FaultState>>,
    rx: Mutex<RxState>,
    /// Signalled when frames are routed or the link dies.
    rx_cv: Condvar,
    /// Link-level accounting (phase [`MUX_LINK_PHASE`]): exact bytes and
    /// message counts, rounds pinned to 0 (see module docs).
    link: Mutex<Meter>,
}

/// A party-pair link split into tagged sub-channels.
///
/// Built from an existing connected [`Chan`] with [`MuxLink::new`];
/// hand out per-session endpoints with [`MuxLink::session`]; when every
/// session endpoint has been dropped, [`MuxLink::finish`] reassembles
/// and returns the original flat `Chan` (meter, shaper and party
/// identity restored, link traffic folded in).
pub struct MuxLink {
    shared: Arc<MuxShared>,
    party: usize,
    /// The flat channel's MAC ledger, parked for the mux's lifetime and
    /// restored by [`MuxLink::finish`]. Per-session malicious security
    /// uses per-session ledgers (each session `Chan` arms its own via
    /// `enable_mac` with a tag-keyed seed); the link-level ledger only
    /// covers flat pre-/post-mux traffic.
    mac: Option<MacAcc>,
}

/// One session's endpoint into the shared link (the `Backend::Mux`
/// payload inside a session `Chan`). Sends tag-prefix frames; receives
/// via the routed inbox.
pub struct MuxSession {
    shared: Arc<MuxShared>,
    id: u64,
}

impl MuxLink {
    /// Take over a connected link. The wrapped channel's meter, shaper
    /// and party identity are preserved and restored by
    /// [`MuxLink::finish`]; shaping applies to the multiplexed stream as
    /// a whole (one physical pipe). Muxing an already-muxed session is a
    /// configuration error.
    pub fn new(chan: Chan) -> Result<MuxLink> {
        let (backend, meter, shaper, fault, mac, party) = chan.into_raw_parts();
        let (tx, rx) = match backend {
            Backend::Mpsc { tx, rx } => (SendHalf::Mpsc(tx), RecvHalf::Mpsc(rx)),
            Backend::Tcp(t) => {
                // Clone = send half, original = receive half; both refer
                // to the same socket, independently lockable.
                let send = t.try_clone()?;
                (SendHalf::Tcp(send), RecvHalf::Tcp(t))
            }
            Backend::Mux(_) => {
                return Err(Error::Config(
                    "cannot multiplex an already-multiplexed session channel".into(),
                ))
            }
        };
        Ok(MuxLink {
            shared: Arc::new(MuxShared {
                tx: Mutex::new(tx),
                fault: Mutex::new(fault),
                rx: Mutex::new(RxState { recv: Some(rx), inboxes: BTreeMap::new(), shaper, dead: None }),
                rx_cv: Condvar::new(),
                link: Mutex::new(meter),
            }),
            party,
            mac,
        })
    }

    /// Open the session tagged `id`, returning a fully independent
    /// [`Chan`] (own meter, own round buffer) riding the shared link.
    /// Each tag can be open at most once per link.
    pub fn session(&self, id: u64) -> Result<Chan> {
        let mut rx = lock(&self.shared.rx);
        if rx.inboxes.contains_key(&id) {
            return Err(Error::Config(format!("mux session {id} already open")));
        }
        rx.inboxes.insert(id, VecDeque::new());
        drop(rx);
        Ok(Chan::from_raw_parts(
            Backend::Mux(MuxSession { shared: Arc::clone(&self.shared), id }),
            Meter::new(),
            None,
            None,
            None,
            self.party,
        ))
    }

    /// Snapshot of the link meter (exact multiplexed bytes/msgs under
    /// phase [`MUX_LINK_PHASE`], plus whatever the pre-mux channel had
    /// accumulated).
    pub fn link_meter(&self) -> Meter {
        lock(&self.shared.link).clone()
    }

    /// Tear the mux down and reassemble the flat [`Chan`]. Every session
    /// endpoint must have been dropped (the link state is uniquely owned
    /// again) and every inbox drained — a leftover frame means some
    /// session exited mid-protocol, which is a protocol error, not a
    /// panic.
    pub fn finish(self) -> Result<Chan> {
        let shared = Arc::try_unwrap(self.shared).map_err(|_| {
            Error::Runtime("mux finish: session endpoints still alive".into())
        })?;
        let rx = shared.rx.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some((id, q)) = rx.inboxes.iter().find(|(_, q)| !q.is_empty()) {
            return Err(Error::Protocol(format!(
                "mux finish: session {id} left {} unread frame(s) in its inbox",
                q.len()
            )));
        }
        if let Some(msg) = rx.dead {
            return Err(Error::ChannelClosed(format!("mux link died: {msg}")));
        }
        let recv = rx.recv.ok_or_else(|| {
            Error::Runtime("mux finish: reader token not returned".into())
        })?;
        let tx = shared.tx.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        let backend = match (tx, recv) {
            (SendHalf::Mpsc(tx), RecvHalf::Mpsc(rx)) => Backend::Mpsc { tx, rx },
            // Either TCP handle is the whole socket again.
            (SendHalf::Tcp(t), RecvHalf::Tcp(_)) => Backend::Tcp(t),
            _ => return Err(Error::Runtime("mux finish: transport halves disagree".into())),
        };
        let meter = shared.link.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        let fault = shared.fault.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        Ok(Chan::from_raw_parts(backend, meter, rx.shaper, fault, self.mac, self.party))
    }
}

impl MuxSession {
    /// Send `payload` on this session: one wire frame of
    /// `tag ‖ payload`, accounted against the link meter (the *session*
    /// meter is updated by the owning `Chan`, tag included, so the two
    /// agree byte-for-byte).
    pub fn send(&mut self, payload: &[u8]) -> Result<()> {
        let mut frame = Vec::with_capacity(payload.len() + MUX_TAG_BYTES as usize);
        frame.extend_from_slice(&self.id.to_le_bytes());
        frame.extend_from_slice(payload);
        {
            // Inherited fault state (frame-counted at link level; see
            // crate::net::fault). Checked before the frame moves or is
            // accounted, mirroring the flat-channel hook.
            let mut fault = lock(&self.shared.fault);
            if let Some(f) = fault.as_mut() {
                match f.on_link_send()? {
                    SendAction::Pass => {}
                    SendAction::Abort => std::process::abort(),
                    SendAction::Swallow => return Ok(()),
                    SendAction::Tamper => {
                        // Flip one payload bit (past the 8-byte session
                        // tag, so routing still works) and fall through
                        // to the normal metered send below.
                        let tag = MUX_TAG_BYTES as usize;
                        if frame.len() > tag {
                            let mid = tag + (frame.len() - tag) / 2;
                            if let Some(b) = frame.get_mut(mid) {
                                *b ^= 1;
                            }
                        }
                    }
                    SendAction::Truncate => {
                        let keep = ((frame.len() / 2) | 1).min(frame.len());
                        let mut tx = lock(&self.shared.tx);
                        let _ = tx.send(&frame[..keep]).is_ok();
                        return Err(f.closed_error());
                    }
                }
            }
        }
        {
            let mut tx = lock(&self.shared.tx);
            tx.send(&frame)?;
        }
        lock(&self.shared.link).record(
            MUX_LINK_PHASE,
            PhaseStats { bytes_sent: frame.len() as u64, msgs_sent: 1, rounds: 0 },
        );
        Ok(())
    }

    /// Receive the next payload addressed to this session. Whoever finds
    /// its inbox empty takes the reader token, blocks on the wire with
    /// the lock released, and routes the arriving frame — to itself or
    /// to another session's inbox (waking the waiters). A transport
    /// error becomes sticky and fails every session.
    pub fn recv(&mut self) -> Result<Vec<u8>> {
        let mut rx = lock(&self.shared.rx);
        loop {
            if let Some(q) = rx.inboxes.get_mut(&self.id) {
                if let Some(frame) = q.pop_front() {
                    return Ok(frame);
                }
            }
            if let Some(msg) = &rx.dead {
                return Err(Error::ChannelClosed(format!("mux link died: {msg}")));
            }
            if let Some(mut half) = rx.recv.take() {
                // We hold the reader token: block on the wire unlocked so
                // senders (and the peer) keep making progress.
                drop(rx);
                let got = half.recv();
                rx = lock(&self.shared.rx);
                rx.recv = Some(half);
                match got {
                    Ok(frame) => {
                        if let Err(e) = route(&mut rx, &self.shared.link, frame) {
                            rx.dead = Some(e.to_string());
                            self.shared.rx_cv.notify_all();
                            return Err(e);
                        }
                        self.shared.rx_cv.notify_all();
                        // Loop: the frame may or may not have been ours.
                    }
                    Err(e) => {
                        rx.dead = Some(e.to_string());
                        self.shared.rx_cv.notify_all();
                        return Err(e);
                    }
                }
            } else {
                // Another session is blocked on the wire; wait for it to
                // route something or return the token.
                rx = self
                    .shared
                    .rx_cv
                    .wait(rx)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        }
    }
}

/// Route one arriving wire frame to its session inbox: strip and decode
/// the tag, count the receive on the link meter, pace the shaper.
/// Malformed or misaddressed frames are protocol errors that kill the
/// link (the stream is no longer trustworthy once framing desyncs).
fn route(rx: &mut RxState, link: &Mutex<Meter>, frame: Vec<u8>) -> Result<()> {
    if frame.len() < MUX_TAG_BYTES as usize {
        return Err(Error::Protocol(format!(
            "mux frame of {} bytes is shorter than its {MUX_TAG_BYTES}-byte session tag",
            frame.len()
        )));
    }
    let mut tag = [0u8; 8];
    tag.copy_from_slice(&frame[..8]);
    let id = u64::from_le_bytes(tag);
    lock(link).on_recv();
    if let Some(s) = &mut rx.shaper {
        s.pace_recv(frame.len() as u64);
    }
    match rx.inboxes.get_mut(&id) {
        Some(q) => {
            q.push_back(frame[8..].to_vec());
            Ok(())
        }
        None => Err(Error::Protocol(format!("mux frame addressed to unknown session {id}"))),
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::net::duplex_pair;
    use std::thread;

    /// Two sessions ping-pong concurrently over one duplex link; the
    /// per-session meters must sum exactly to the link totals.
    #[test]
    fn sessions_are_independent_and_meters_sum_to_link() {
        let (c0, c1) = duplex_pair();
        let run = |chan: Chan, party: usize| {
            let link = MuxLink::new(chan).unwrap();
            let handles: Vec<_> = [1u64, 2u64]
                .into_iter()
                .map(|id| {
                    let mut s = link.session(id).unwrap();
                    thread::spawn(move || {
                        s.set_phase("t");
                        for i in 0..4u64 {
                            let v = s.exchange_u64s(&[id * 100 + i + party as u64 * 1000]);
                            assert_eq!(v, vec![id * 100 + i + (1 - party) as u64 * 1000]);
                        }
                        s.into_meter()
                    })
                })
                .collect();
            let session_meters: Vec<Meter> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            let flat = link.finish().unwrap();
            (session_meters, flat.into_meter())
        };
        let h = thread::spawn(move || run(c0, 0));
        let (s1, l1) = run(c1, 1);
        let (s0, l0) = h.join().unwrap();
        for (sessions, link_meter) in [(&s0, &l0), (&s1, &l1)] {
            let mut sum = PhaseStats::default();
            for m in sessions.iter() {
                sum.merge(&m.total());
            }
            let link_total = link_meter.get(MUX_LINK_PHASE);
            assert_eq!(sum.bytes_sent, link_total.bytes_sent);
            assert_eq!(sum.msgs_sent, link_total.msgs_sent);
            // 4 exchanges = 4 flights per session, deterministic.
            for m in sessions.iter() {
                assert_eq!(m.total().rounds, 4);
                // 8 payload + 8 tag bytes per frame, 4 frames.
                assert_eq!(m.total().bytes_sent, 4 * 16);
            }
            // Link rounds stay 0: flight interleaving is scheduling-
            // dependent, so the link meter never counts flights.
            assert_eq!(link_total.rounds, 0);
        }
    }

    #[test]
    fn finish_restores_a_usable_flat_channel() {
        let (c0, c1) = duplex_pair();
        let h = thread::spawn(move || {
            let link = MuxLink::new(c0).unwrap();
            {
                let mut s = link.session(7).unwrap();
                s.send_u64s(&[42]);
                assert_eq!(s.recv_u64s(), vec![43]);
            }
            let mut flat = link.finish().unwrap();
            flat.send_u64s(&[1, 2, 3]);
            flat.into_meter()
        });
        let link = MuxLink::new(c1).unwrap();
        {
            let mut s = link.session(7).unwrap();
            assert_eq!(s.recv_u64s(), vec![42]);
            s.send_u64s(&[43]);
        }
        let mut flat = link.finish().unwrap();
        assert_eq!(flat.recv_u64s(), vec![1, 2, 3]);
        let m0 = h.join().unwrap();
        // Link meter carries the mux traffic plus the post-mux flat send.
        assert_eq!(m0.get(MUX_LINK_PHASE).msgs_sent, 1);
        assert!(m0.total().bytes_sent >= 16 + 24);
    }

    #[test]
    fn duplicate_session_id_is_refused() {
        let (c0, _c1) = duplex_pair();
        let link = MuxLink::new(c0).unwrap();
        let _a = link.session(3).unwrap();
        let err = link.session(3).unwrap_err();
        assert!(err.to_string().contains("already open"), "{err}");
    }

    #[test]
    fn dead_link_fails_every_session_with_typed_error() {
        let (c0, c1) = duplex_pair();
        drop(c1); // peer gone before any traffic
        let link = MuxLink::new(c0).unwrap();
        let mut a = link.session(1).unwrap();
        let mut b = link.session(2).unwrap();
        assert!(a.try_recv_bytes().is_err());
        // The failure is sticky: the second session sees it too, without
        // touching the wire.
        let err = b.try_recv_bytes().unwrap_err();
        assert!(err.to_string().contains("mux link died"), "{err}");
    }

    /// finish() fails with a typed runtime error while a session
    /// endpoint is still alive (the Arc is not uniquely owned).
    #[test]
    fn finish_is_refused_while_sessions_alive() {
        let (c0, _c1) = duplex_pair();
        let link = MuxLink::new(c0).unwrap();
        let s = link.session(1).unwrap();
        match link.finish() {
            Ok(_) => unreachable!("finish must fail while a session is alive"),
            Err(e) => assert!(e.to_string().contains("still alive"), "{e}"),
        }
        drop(s);
    }

    #[test]
    fn mux_over_mux_is_refused() {
        let (c0, _c1) = duplex_pair();
        let link = MuxLink::new(c0).unwrap();
        let s = link.session(1).unwrap();
        let err = MuxLink::new(s).unwrap_err();
        assert!(err.to_string().contains("already-multiplexed"), "{err}");
    }
}
