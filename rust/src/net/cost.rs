//! Network cost model: translate exact (bytes, rounds) measurements into
//! wall-clock network time for a target link.
//!
//! The paper reports LAN (10 Gbps, 0.02 ms RTT) for the M-Kmeans
//! comparison (Q1) and WAN (20 Mbps, 40 ms RTT) for Q2-Q4. Running both
//! parties on one host, we *measure* compute time and message sizes, then
//! *model* link time as `rounds · RTT + bytes / bandwidth` — the standard
//! flight model, which is also what dominates the paper's WAN numbers.

use super::meter::PhaseStats;

/// A symmetric point-to-point link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Round-trip latency in seconds.
    pub rtt_s: f64,
    /// Bandwidth in bits per second.
    pub bandwidth_bps: f64,
}

impl CostModel {
    /// Paper's LAN: 10 Gbps, 0.02 ms RTT.
    pub fn lan() -> Self {
        CostModel { rtt_s: 0.02e-3, bandwidth_bps: 10e9 }
    }

    /// Paper's WAN: 20 Mbps, 40 ms RTT.
    pub fn wan() -> Self {
        CostModel { rtt_s: 40e-3, bandwidth_bps: 20e6 }
    }

    /// An infinitely fast link (pure-compute accounting).
    pub fn zero() -> Self {
        CostModel { rtt_s: 0.0, bandwidth_bps: f64::INFINITY }
    }

    /// Modeled link time for a traffic summary.
    pub fn time(&self, stats: &PhaseStats) -> f64 {
        stats.rounds as f64 * self.rtt_s + (stats.bytes_sent as f64 * 8.0) / self.bandwidth_bps
    }

    /// Modeled link time from raw counts.
    pub fn time_raw(&self, bytes: u64, rounds: u64) -> f64 {
        self.time(&PhaseStats { bytes_sent: bytes, msgs_sent: 0, rounds })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wan_latency_dominates_small_messages() {
        let wan = CostModel::wan();
        // 10 rounds of 8 bytes: latency term 0.4 s, bandwidth term ~32 us.
        let t = wan.time_raw(80, 10);
        assert!((t - 0.4).abs() < 1e-3, "{t}");
    }

    #[test]
    fn lan_bandwidth_dominates_bulk() {
        let lan = CostModel::lan();
        // 1 GB in one round: ~0.86 s, latency negligible.
        let t = lan.time_raw(1 << 30, 1);
        assert!((t - (1u64 << 30) as f64 * 8.0 / 10e9).abs() < 1e-3);
    }

    #[test]
    fn zero_model_is_free() {
        assert_eq!(CostModel::zero().time_raw(1 << 40, 1000), 0.0);
    }
}
