//! Real TCP transport for two-process deployments.
//!
//! Frames are `u32` little-endian length prefixes followed by the
//! payload, mirroring what the in-process channel carries so that meters
//! agree between backends.

use crate::util::error::{Error, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

/// A connected, framed TCP transport.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    /// Listen on `addr` and accept a single peer (party 0 role).
    pub fn listen(addr: &str) -> Result<TcpTransport> {
        let listener = TcpListener::bind(addr)?;
        let (stream, _) = listener.accept()?;
        stream.set_nodelay(true)?;
        Ok(TcpTransport { stream })
    }

    /// Connect to a listening peer (party 1 role), retrying briefly so
    /// the two processes can start in any order.
    pub fn connect(addr: &str) -> Result<TcpTransport> {
        let mut last = None;
        for _ in 0..50 {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true)?;
                    return Ok(TcpTransport { stream });
                }
                Err(e) => {
                    last = Some(e);
                    std::thread::sleep(std::time::Duration::from_millis(100));
                }
            }
        }
        Err(Error::ChannelClosed(format!("connect {addr}: {:?}", last)))
    }

    /// Send one framed message.
    pub fn send(&mut self, bytes: &[u8]) -> Result<()> {
        let len = bytes.len() as u32;
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(bytes)?;
        Ok(())
    }

    /// Receive one framed message.
    pub fn recv(&mut self) -> Result<Vec<u8>> {
        let mut lenb = [0u8; 4];
        self.stream.read_exact(&mut lenb)?;
        let len = u32::from_le_bytes(lenb) as usize;
        let mut buf = vec![0u8; len];
        self.stream.read_exact(&mut buf)?;
        Ok(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn tcp_roundtrip_localhost() {
        let addr = "127.0.0.1:47391";
        let server = thread::spawn(move || {
            let mut t = TcpTransport::listen(addr).unwrap();
            let m = t.recv().unwrap();
            t.send(&m).unwrap();
        });
        let mut c = TcpTransport::connect(addr).unwrap();
        c.send(b"hello ppkmeans").unwrap();
        assert_eq!(c.recv().unwrap(), b"hello ppkmeans");
        server.join().unwrap();
    }
}
