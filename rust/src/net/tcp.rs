//! Real TCP transport for two-process deployments.
//!
//! Frames are `u32` little-endian length prefixes followed by the
//! payload, mirroring what the in-process channel carries so that meters
//! agree between backends (see `docs/PROTOCOLS.md`, "Wire format").
//!
//! ## Hardening
//!
//! The codec treats the peer as untrusted at the framing layer:
//!
//! * a length prefix above [`MAX_FRAME_BYTES`] is rejected with a typed
//!   [`Error::Protocol`] **before** any allocation;
//! * the receive buffer grows with the bytes actually read, never with
//!   the announced length — a lying prefix can cost at most the bytes
//!   the peer really sends;
//! * a clean disconnect surfaces as [`Error::ChannelClosed`], a
//!   mid-frame disconnect as a "truncated frame" [`Error::ChannelClosed`]
//!   carrying the byte counts — never a panic.

use crate::util::error::{Error, Result};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};

/// Default bound on connect retries (was an effectively unbounded wait).
pub const DEFAULT_CONNECT_ATTEMPTS: usize = 50;
/// Default delay between connect retries.
pub const DEFAULT_CONNECT_DELAY: std::time::Duration = std::time::Duration::from_millis(100);

/// Hard cap on a single frame's payload (256 MiB). The largest honest
/// frame is an S1 reveal flight, well under this at any benchmarked
/// scale; anything bigger is a corrupt or hostile length prefix and is
/// rejected before allocation.
pub const MAX_FRAME_BYTES: usize = 256 << 20;

/// A connected, framed TCP transport.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    /// Listen on `addr` and accept a single peer (party 0 role).
    pub fn listen(addr: &str) -> Result<TcpTransport> {
        let listener = TcpListener::bind(addr)?;
        Self::accept_from(&listener)
    }

    /// Accept a single peer from an already-bound listener. Binding is
    /// split out so callers (tests, drivers) can bind port 0 and read
    /// the ephemeral port back before blocking in accept.
    pub fn accept_from(listener: &TcpListener) -> Result<TcpTransport> {
        let (stream, _) = listener.accept()?;
        stream.set_nodelay(true)?;
        Ok(TcpTransport { stream })
    }

    /// Connect to a listening peer (party 1 role), retrying briefly so
    /// the two processes can start in any order. Gives up after
    /// [`DEFAULT_CONNECT_ATTEMPTS`] × [`DEFAULT_CONNECT_DELAY`] instead
    /// of sleeping forever.
    pub fn connect(addr: &str) -> Result<TcpTransport> {
        Self::connect_with_retry(addr, DEFAULT_CONNECT_ATTEMPTS, DEFAULT_CONNECT_DELAY)
    }

    /// Connect with an explicit retry budget: at most `attempts` tries
    /// spaced by `delay`, then an [`Error::ChannelClosed`] carrying the
    /// last OS error — callers decide whether to re-dial, never hang.
    pub fn connect_with_retry(
        addr: &str,
        attempts: usize,
        delay: std::time::Duration,
    ) -> Result<TcpTransport> {
        assert!(attempts > 0, "need at least one connect attempt");
        let mut last = None;
        for attempt in 0..attempts {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true)?;
                    return Ok(TcpTransport { stream });
                }
                Err(e) => {
                    last = Some(e);
                    if attempt + 1 < attempts {
                        std::thread::sleep(delay);
                    }
                }
            }
        }
        Err(Error::ChannelClosed(format!(
            "connect {addr}: gave up after {attempts} attempts: {:?}",
            last
        )))
    }

    /// Duplicate the transport handle (both refer to the same socket).
    /// The session mux ([`crate::net::mux`]) splits a link into an
    /// independently-locked send half and receive half this way —
    /// holding one lock across a blocking receive while another worker
    /// sends is what keeps two concurrent parties deadlock-free.
    pub fn try_clone(&self) -> Result<TcpTransport> {
        Ok(TcpTransport { stream: self.stream.try_clone()? })
    }

    /// Send one framed message. Refuses frames above [`MAX_FRAME_BYTES`]
    /// with a typed error (a peer applying the same cap would reject
    /// them anyway).
    pub fn send(&mut self, bytes: &[u8]) -> Result<()> {
        if bytes.len() > MAX_FRAME_BYTES {
            return Err(Error::Protocol(format!(
                "refusing to send a {}-byte frame (cap {MAX_FRAME_BYTES})",
                bytes.len()
            )));
        }
        let len = bytes.len() as u32;
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(bytes)?;
        Ok(())
    }

    /// Receive one framed message. Typed errors, bounded allocation:
    /// an oversized announced length is [`Error::Protocol`], a peer
    /// hangup between frames is [`Error::ChannelClosed`], and a frame
    /// cut short by a disconnect is [`Error::ChannelClosed`] with the
    /// received/expected byte counts.
    pub fn recv(&mut self) -> Result<Vec<u8>> {
        let mut lenb = [0u8; 4];
        if let Err(e) = self.stream.read_exact(&mut lenb) {
            return Err(if e.kind() == ErrorKind::UnexpectedEof {
                Error::ChannelClosed("peer closed the connection".into())
            } else {
                Error::Io(e)
            });
        }
        let len = u32::from_le_bytes(lenb) as usize;
        if len > MAX_FRAME_BYTES {
            return Err(Error::Protocol(format!(
                "peer announced a {len}-byte frame (cap {MAX_FRAME_BYTES}); refusing to allocate"
            )));
        }
        // `take(len)` + `read_to_end` grows the buffer with the bytes
        // actually received: the untrusted prefix never sizes an
        // allocation up front.
        let mut buf = Vec::new();
        (&self.stream).take(len as u64).read_to_end(&mut buf)?;
        if buf.len() != len {
            return Err(Error::ChannelClosed(format!(
                "truncated frame: got {} of {len} bytes before the peer hung up",
                buf.len()
            )));
        }
        Ok(buf)
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use std::thread;

    /// Bind an ephemeral port and return (listener, addr string).
    fn ephemeral() -> (TcpListener, String) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        (l, addr)
    }

    #[test]
    fn connect_fails_fast_when_nobody_listens() {
        // Unroutable-ish local port with a 2-attempt budget: must return
        // an error promptly instead of hanging forever.
        let t0 = std::time::Instant::now();
        let r = TcpTransport::connect_with_retry(
            "127.0.0.1:47399",
            2,
            std::time::Duration::from_millis(10),
        );
        assert!(r.is_err());
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
    }

    #[test]
    fn tcp_roundtrip_localhost() {
        let (l, addr) = ephemeral();
        let server = thread::spawn(move || {
            let mut t = TcpTransport::accept_from(&l).unwrap();
            let m = t.recv().unwrap();
            t.send(&m).unwrap();
        });
        let mut c = TcpTransport::connect(&addr).unwrap();
        c.send(b"hello ppkmeans").unwrap();
        assert_eq!(c.recv().unwrap(), b"hello ppkmeans");
        server.join().unwrap();
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocating() {
        let (l, addr) = ephemeral();
        let server = thread::spawn(move || {
            let mut t = TcpTransport::accept_from(&l).unwrap();
            t.recv()
        });
        // A raw peer announcing a 4 GiB-ish frame: the receiver must
        // return a typed error immediately, not allocate or panic.
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        let err = server.join().unwrap().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("wire protocol"), "{msg}");
        assert!(msg.contains("refusing to allocate"), "{msg}");
    }

    #[test]
    fn truncated_frame_is_a_typed_error() {
        let (l, addr) = ephemeral();
        let server = thread::spawn(move || {
            let mut t = TcpTransport::accept_from(&l).unwrap();
            t.recv()
        });
        // Announce 100 bytes, send 3, hang up.
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        s.write_all(&100u32.to_le_bytes()).unwrap();
        s.write_all(b"abc").unwrap();
        drop(s);
        let err = server.join().unwrap().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("truncated frame: got 3 of 100"), "{msg}");
    }

    #[test]
    fn clean_hangup_is_channel_closed() {
        let (l, addr) = ephemeral();
        let server = thread::spawn(move || {
            let mut t = TcpTransport::accept_from(&l).unwrap();
            t.recv()
        });
        let s = std::net::TcpStream::connect(&addr).unwrap();
        drop(s);
        let err = server.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("peer closed"), "{err}");
    }

    #[test]
    fn oversized_send_is_refused_locally() {
        let (l, addr) = ephemeral();
        let server = thread::spawn(move || {
            let _t = TcpTransport::accept_from(&l).unwrap();
        });
        let mut c = TcpTransport::connect(&addr).unwrap();
        // A huge virtual slice is enough to trip the cap check — the
        // data is never touched because send() refuses first.
        let big = vec![0u8; MAX_FRAME_BYTES + 1];
        assert!(c.send(&big).is_err());
        server.join().unwrap();
    }
}
