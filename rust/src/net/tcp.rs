//! Real TCP transport for two-process deployments.
//!
//! Frames are `u32` little-endian length prefixes followed by the
//! payload, mirroring what the in-process channel carries so that meters
//! agree between backends.

use crate::util::error::{Error, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

/// Default bound on connect retries (was an effectively unbounded wait).
pub const DEFAULT_CONNECT_ATTEMPTS: usize = 50;
/// Default delay between connect retries.
pub const DEFAULT_CONNECT_DELAY: std::time::Duration = std::time::Duration::from_millis(100);

/// A connected, framed TCP transport.
pub struct TcpTransport {
    stream: TcpStream,
}

impl TcpTransport {
    /// Listen on `addr` and accept a single peer (party 0 role).
    pub fn listen(addr: &str) -> Result<TcpTransport> {
        let listener = TcpListener::bind(addr)?;
        let (stream, _) = listener.accept()?;
        stream.set_nodelay(true)?;
        Ok(TcpTransport { stream })
    }

    /// Connect to a listening peer (party 1 role), retrying briefly so
    /// the two processes can start in any order. Gives up after
    /// [`DEFAULT_CONNECT_ATTEMPTS`] × [`DEFAULT_CONNECT_DELAY`] instead
    /// of sleeping forever.
    pub fn connect(addr: &str) -> Result<TcpTransport> {
        Self::connect_with_retry(addr, DEFAULT_CONNECT_ATTEMPTS, DEFAULT_CONNECT_DELAY)
    }

    /// Connect with an explicit retry budget: at most `attempts` tries
    /// spaced by `delay`, then an [`Error::ChannelClosed`] carrying the
    /// last OS error — callers decide whether to re-dial, never hang.
    pub fn connect_with_retry(
        addr: &str,
        attempts: usize,
        delay: std::time::Duration,
    ) -> Result<TcpTransport> {
        assert!(attempts > 0, "need at least one connect attempt");
        let mut last = None;
        for attempt in 0..attempts {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    stream.set_nodelay(true)?;
                    return Ok(TcpTransport { stream });
                }
                Err(e) => {
                    last = Some(e);
                    if attempt + 1 < attempts {
                        std::thread::sleep(delay);
                    }
                }
            }
        }
        Err(Error::ChannelClosed(format!(
            "connect {addr}: gave up after {attempts} attempts: {:?}",
            last
        )))
    }

    /// Send one framed message.
    pub fn send(&mut self, bytes: &[u8]) -> Result<()> {
        let len = bytes.len() as u32;
        self.stream.write_all(&len.to_le_bytes())?;
        self.stream.write_all(bytes)?;
        Ok(())
    }

    /// Receive one framed message.
    pub fn recv(&mut self) -> Result<Vec<u8>> {
        let mut lenb = [0u8; 4];
        self.stream.read_exact(&mut lenb)?;
        let len = u32::from_le_bytes(lenb) as usize;
        let mut buf = vec![0u8; len];
        self.stream.read_exact(&mut buf)?;
        Ok(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn connect_fails_fast_when_nobody_listens() {
        // Unroutable-ish local port with a 2-attempt budget: must return
        // an error promptly instead of hanging forever.
        let t0 = std::time::Instant::now();
        let r = TcpTransport::connect_with_retry(
            "127.0.0.1:47399",
            2,
            std::time::Duration::from_millis(10),
        );
        assert!(r.is_err());
        assert!(t0.elapsed() < std::time::Duration::from_secs(5));
    }

    #[test]
    fn tcp_roundtrip_localhost() {
        let addr = "127.0.0.1:47391";
        let server = thread::spawn(move || {
            let mut t = TcpTransport::listen(addr).unwrap();
            let m = t.recv().unwrap();
            t.send(&m).unwrap();
        });
        let mut c = TcpTransport::connect(addr).unwrap();
        c.send(b"hello ppkmeans").unwrap();
        assert_eq!(c.recv().unwrap(), b"hello ppkmeans");
        server.join().unwrap();
    }
}
