//! Two-party transport with exact communication accounting and flight
//! batching.
//!
//! The paper's testbed is two machines on a real LAN (10 Gbps / 0.02 ms
//! RTT) or WAN (20 Mbps / 40 ms RTT). We reproduce it with two party
//! threads connected by an accounted duplex channel: every protocol
//! message is actually serialized, so **byte and round counts are exact
//! measurements**; wall-clock network time is then *modeled* as
//! `rounds·RTT + bytes/bandwidth` by [`cost::CostModel`] and added to the
//! measured compute time. A real TCP backend ([`tcp`]) supports
//! two-process deployments, and a deterministic link shaper ([`shape`])
//! can enforce a [`CostModel`] on either backend so LAN/WAN wall-clock
//! is *measured* on the wire rather than modeled.
//!
//! [`Chan`] additionally carries a **round buffer**: protocol gates
//! stage their symmetric reveals and one `flush_round()` ships them all
//! in a single flight — the transport half of the round-batched engine
//! (the gate half lives in [`crate::ss`]). The per-phase [`Meter`]
//! counts those flights exactly (a flight = the first send after a
//! receive), which is what makes round budgets regression-testable.

// Wire-facing code returns typed errors (ppkm-lint rule
// no-panic-in-wire-paths); the clippy deny backs the lint at the
// type-system level across this whole subtree.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod channel;
pub mod cost;
pub mod fault;
pub mod meter;
pub mod mux;
pub mod shape;
pub mod tcp;

pub use channel::{duplex_pair, Chan, Security};
pub use cost::CostModel;
pub use fault::{FaultMode, FaultPlan, FaultyChan};
pub use meter::{Meter, PhaseStats};
pub use mux::{MuxLink, MUX_TAG_BYTES};
pub use shape::LinkShaper;
pub use tcp::TcpTransport;

/// Run a two-party protocol: spawns one thread per party (via
/// [`crate::runtime::pool::run_pair`]) over an in-process duplex
/// channel and returns each party's result together with its
/// communication meter.
///
/// ```
/// use ppkmeans::net::run_two_party;
/// let ((a, _), (b, _)) = run_two_party(
///     |chan| { chan.send_u64s(&[41]); chan.recv_u64s()[0] + 1 },
///     |chan| { let v = chan.recv_u64s(); chan.send_u64s(&[v[0] + 1]); v[0] },
/// );
/// assert_eq!(a, 43);
/// assert_eq!(b, 41);
/// ```
pub fn run_two_party<R0, R1, F0, F1>(f0: F0, f1: F1) -> ((R0, Meter), (R1, Meter))
where
    R0: Send + 'static,
    R1: Send + 'static,
    F0: FnOnce(&mut Chan) -> R0 + Send + 'static,
    F1: FnOnce(&mut Chan) -> R1 + Send + 'static,
{
    let (mut c0, mut c1) = duplex_pair();
    crate::runtime::pool::run_pair(
        move || {
            let r = f0(&mut c0);
            (r, c0.into_meter())
        },
        move || {
            let r = f1(&mut c1);
            (r, c1.into_meter())
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_pong() {
        let ((a, m0), (b, m1)) = run_two_party(
            |c| {
                c.send_u64s(&[7, 8]);
                c.recv_u64s()
            },
            |c| {
                let v = c.recv_u64s();
                c.send_u64s(&[v[0] + v[1]]);
                v
            },
        );
        assert_eq!(a, vec![15]);
        assert_eq!(b, vec![7, 8]);
        assert!(m0.total().bytes_sent >= 16);
        assert!(m1.total().bytes_sent >= 8);
    }

    #[test]
    fn rounds_are_counted_per_flight() {
        let ((_, m0), _) = run_two_party(
            |c| {
                for _ in 0..3 {
                    c.send_u64s(&[1]);
                    c.recv_u64s();
                }
            },
            |c| {
                for _ in 0..3 {
                    let v = c.recv_u64s();
                    c.send_u64s(&v);
                }
            },
        );
        assert_eq!(m0.total().rounds, 3);
    }
}
