//! Deterministic link shaping: make a loopback transport *behave* like a
//! target link so wall-clock numbers are measured, not modeled.
//!
//! The benches historically ran both parties on one host and translated
//! exact (bytes, flights) counts into link time through
//! [`CostModel::time`]. A [`LinkShaper`] closes the loop: attached to a
//! [`crate::net::Chan`] (in-process or TCP), it delays every **received**
//! message by the modeled one-way latency (RTT/2) plus its serialization
//! time (bytes·8 / bandwidth), with serialization accumulating on a
//! virtual inbound pipe so back-to-back frames queue like they would on
//! a real link. A symmetric exchange therefore costs one RTT end to end
//! — the same flight model the [`CostModel`] prices — and a full shaped
//! run's wall-clock is a *measurement* of compute + link, comparable
//! side by side with the modeled figure.
//!
//! Shaping is deterministic in the sense that it injects no randomness
//! and never touches payloads: byte counts, flight counts and every
//! revealed value are bit-identical with and without a shaper (the
//! meters run **before** pacing). Only elapsed time changes.
//!
//! Sleeps are lower bounds — the OS may wake the thread late — so shaped
//! wall-clock ≥ modeled link time + compute, which is also true of a
//! real link.

use super::cost::CostModel;
use std::time::{Duration, Instant};

/// Paces one endpoint's inbound traffic to a [`CostModel`].
#[derive(Debug, Clone)]
pub struct LinkShaper {
    model: CostModel,
    /// Virtual time at which the inbound serialization pipe frees up
    /// (`None` before any traffic).
    link_free: Option<Instant>,
}

impl LinkShaper {
    /// Shape to the given link. [`CostModel::zero`] yields a no-op
    /// shaper.
    pub fn new(model: CostModel) -> LinkShaper {
        LinkShaper { model, link_free: None }
    }

    /// The link being enforced.
    pub fn model(&self) -> &CostModel {
        &self.model
    }

    /// Whether this shaper never delays (zero RTT, infinite bandwidth).
    pub fn is_free(&self) -> bool {
        self.model.rtt_s <= 0.0 && self.model.bandwidth_bps.is_infinite()
    }

    /// Serialization time of `bytes` on this link (zero on an infinite
    /// link).
    pub fn serialization(&self, bytes: u64) -> Duration {
        if self.model.bandwidth_bps.is_infinite() {
            return Duration::ZERO;
        }
        Duration::from_secs_f64(bytes as f64 * 8.0 / self.model.bandwidth_bps)
    }

    /// One-way propagation latency (RTT/2).
    pub fn latency(&self) -> Duration {
        Duration::from_secs_f64(self.model.rtt_s / 2.0)
    }

    /// Block until a just-received `bytes`-long message would have
    /// finished arriving on the modeled link: the inbound pipe serializes
    /// it after any still-queued predecessor, then one-way latency
    /// applies on top (propagation overlaps serialization of later
    /// frames, so only the pipe time is carried forward).
    pub fn pace_recv(&mut self, bytes: u64) {
        if self.is_free() {
            return;
        }
        let now = Instant::now();
        let start = match self.link_free {
            Some(t) if t > now => t,
            _ => now,
        };
        let free = start + self.serialization(bytes);
        self.link_free = Some(free);
        let ready = free + self.latency();
        let wait = ready.saturating_duration_since(now);
        if wait > Duration::ZERO {
            std::thread::sleep(wait);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_link_never_sleeps() {
        let mut s = LinkShaper::new(CostModel::zero());
        assert!(s.is_free());
        let t0 = Instant::now();
        for _ in 0..1000 {
            s.pace_recv(1 << 20);
        }
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn latency_paces_each_receive_by_half_rtt() {
        // 20 ms RTT, infinite bandwidth: 3 receives ≥ 3 × 10 ms.
        let mut s = LinkShaper::new(CostModel { rtt_s: 20e-3, bandwidth_bps: f64::INFINITY });
        let t0 = Instant::now();
        for _ in 0..3 {
            s.pace_recv(8);
        }
        assert!(t0.elapsed() >= Duration::from_millis(29), "{:?}", t0.elapsed());
    }

    #[test]
    fn bandwidth_paces_bytes() {
        // 8 kbit/s = 1 KB/s: a 100-byte frame serializes in ≥ 100 ms.
        let mut s = LinkShaper::new(CostModel { rtt_s: 0.0, bandwidth_bps: 8e3 });
        let t0 = Instant::now();
        s.pace_recv(100);
        assert!(t0.elapsed() >= Duration::from_millis(95), "{:?}", t0.elapsed());
    }

    #[test]
    fn serialization_queues_back_to_back_frames() {
        // Two 50-byte frames on the 1 KB/s link: the second starts after
        // the first finishes → total ≥ 100 ms even though each alone is
        // 50 ms.
        let mut s = LinkShaper::new(CostModel { rtt_s: 0.0, bandwidth_bps: 8e3 });
        let t0 = Instant::now();
        s.pace_recv(50);
        s.pace_recv(50);
        assert!(t0.elapsed() >= Duration::from_millis(95), "{:?}", t0.elapsed());
    }
}
