//! In-process duplex channel between the two party threads.
//!
//! Messages are real serialized byte vectors (little-endian u64 framing),
//! so the meter sees exactly what a socket would carry (sans TCP/IP
//! headers, which the paper's numbers also exclude).

use super::meter::Meter;
use crate::ring::matrix::Mat;
use std::sync::mpsc::{channel, Receiver, Sender};

enum Backend {
    Mpsc { tx: Sender<Vec<u8>>, rx: Receiver<Vec<u8>> },
    Tcp(super::tcp::TcpTransport),
}

/// One endpoint of a two-party connection with an attached [`Meter`].
pub struct Chan {
    backend: Backend,
    meter: Meter,
    /// Identity of this endpoint: 0 or 1.
    pub party: usize,
}

/// Create a connected pair of in-process endpoints (party 0, party 1).
pub fn duplex_pair() -> (Chan, Chan) {
    let (tx0, rx1) = channel();
    let (tx1, rx0) = channel();
    (
        Chan { backend: Backend::Mpsc { tx: tx0, rx: rx0 }, meter: Meter::new(), party: 0 },
        Chan { backend: Backend::Mpsc { tx: tx1, rx: rx1 }, meter: Meter::new(), party: 1 },
    )
}

impl Chan {
    /// Wrap a connected TCP transport as an endpoint.
    pub fn from_tcp(t: super::tcp::TcpTransport, party: usize) -> Chan {
        Chan { backend: Backend::Tcp(t), meter: Meter::new(), party }
    }

    /// Label subsequent traffic with a phase.
    pub fn set_phase(&mut self, label: &str) {
        self.meter.set_phase(label);
    }

    /// Borrow the meter (read-only).
    pub fn meter(&self) -> &Meter {
        &self.meter
    }

    /// Consume the endpoint, returning its meter.
    pub fn into_meter(self) -> Meter {
        self.meter
    }

    /// Send a raw byte message.
    pub fn send_bytes(&mut self, bytes: &[u8]) {
        self.meter.on_send(bytes.len() as u64);
        match &mut self.backend {
            Backend::Mpsc { tx, .. } => tx.send(bytes.to_vec()).expect("peer closed"),
            Backend::Tcp(t) => t.send(bytes).expect("tcp send"),
        }
    }

    /// Receive the next raw byte message.
    pub fn recv_bytes(&mut self) -> Vec<u8> {
        self.meter.on_recv();
        match &mut self.backend {
            Backend::Mpsc { rx, .. } => rx.recv().expect("peer closed"),
            Backend::Tcp(t) => t.recv().expect("tcp recv"),
        }
    }

    /// Send a vector of ring elements (8 bytes each, little endian).
    pub fn send_u64s(&mut self, xs: &[u64]) {
        let mut bytes = Vec::with_capacity(xs.len() * 8);
        for x in xs {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        self.send_bytes(&bytes);
    }

    /// Receive a vector of ring elements.
    pub fn recv_u64s(&mut self) -> Vec<u64> {
        let bytes = self.recv_bytes();
        assert_eq!(bytes.len() % 8, 0, "malformed u64 frame");
        bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect()
    }

    /// Send a matrix (shape is protocol-known; only the buffer travels).
    pub fn send_mat(&mut self, m: &Mat) {
        self.send_u64s(&m.data);
    }

    /// Receive a matrix with the given (protocol-known) shape.
    pub fn recv_mat(&mut self, rows: usize, cols: usize) -> Mat {
        let data = self.recv_u64s();
        assert_eq!(data.len(), rows * cols, "matrix frame shape mismatch");
        Mat::from_vec(rows, cols, data)
    }

    /// Symmetric exchange of ring vectors: party 0 sends first, party 1
    /// receives first (one round in each direction, one RTT total since
    /// both directions overlap on a full-duplex link).
    pub fn exchange_u64s(&mut self, xs: &[u64]) -> Vec<u64> {
        if self.party == 0 {
            self.send_u64s(xs);
            self.recv_u64s()
        } else {
            let r = self.recv_u64s();
            self.send_u64s(xs);
            r
        }
    }

    /// Symmetric exchange of equal-shape matrices.
    pub fn exchange_mat(&mut self, m: &Mat) -> Mat {
        let data = self.exchange_u64s(&m.data);
        assert_eq!(data.len(), m.data.len(), "exchange shape mismatch");
        Mat::from_vec(m.rows, m.cols, data)
    }

    /// Send one u64 scalar.
    pub fn send_scalar(&mut self, x: u64) {
        self.send_u64s(&[x]);
    }

    /// Receive one u64 scalar.
    pub fn recv_scalar(&mut self) -> u64 {
        let v = self.recv_u64s();
        assert_eq!(v.len(), 1);
        v[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn mat_roundtrip() {
        let (mut c0, mut c1) = duplex_pair();
        let m = Mat::from_vec(2, 2, vec![1, 2, 3, u64::MAX]);
        let mc = m.clone();
        let h = thread::spawn(move || {
            c0.send_mat(&mc);
        });
        let got = c1.recv_mat(2, 2);
        h.join().unwrap();
        assert_eq!(got, m);
    }

    #[test]
    fn exchange_is_symmetric() {
        let (mut c0, mut c1) = duplex_pair();
        let h = thread::spawn(move || c0.exchange_u64s(&[1, 2]));
        let from0 = c1.exchange_u64s(&[3, 4]);
        let from1 = h.join().unwrap();
        assert_eq!(from0, vec![1, 2]);
        assert_eq!(from1, vec![3, 4]);
    }
}
