//! In-process duplex channel between the two party threads, with a
//! round buffer for flight batching.
//!
//! Messages are real serialized byte vectors (little-endian u64 framing),
//! so the meter sees exactly what a socket would carry (sans TCP/IP
//! headers, which the paper's numbers also exclude).
//!
//! ## Round buffer
//!
//! Protocol gates *stage* their symmetric reveals ([`Chan::stage_u64s`])
//! instead of exchanging immediately; [`Chan::flush_round`] concatenates
//! every staged segment into one framed payload, performs a single
//! symmetric exchange (one flight, one RTT), and splits the peer's
//! payload back into per-segment reveals addressable by the handle that
//! `stage_u64s` returned. Both parties must stage the same segment
//! lengths in the same order between flushes — true by construction for
//! the symmetric gate set, and asserted on the total.

use super::cost::CostModel;
use super::fault::{FaultPlan, FaultState, SendAction};
use super::meter::Meter;
use super::shape::LinkShaper;
use crate::ring::matrix::Mat;
use crate::util::error::{Error, Result};
use crate::util::hash::Hash256;
use crate::util::prng::Prg;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Adversary model a protocol run defends against. Protocol-relevant:
/// both parties must agree (the scenario layer digests it) — a
/// [`Security::Malicious`] run arms the channel's deferred MAC-check
/// ledger ([`Chan::enable_mac`]) and pays O(1) extra flights per phase
/// barrier; [`Security::SemiHonest`] leaves the transcript byte-identical
/// to the pre-MAC protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Security {
    /// Honest-but-curious parties (the paper's model): reveals are
    /// trusted, no authentication traffic at all.
    #[default]
    SemiHonest,
    /// Actively cheating parties: every opened value and every wire
    /// frame is folded into a random-linear-combination ledger that is
    /// verified in one batched commit/reveal/verdict check per phase
    /// barrier, with SPDZ-style MAC limbs on authenticated shares.
    Malicious,
}

impl Security {
    /// Canonical scenario / CLI spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Security::SemiHonest => "semi_honest",
            Security::Malicious => "malicious",
        }
    }

    /// Parse a scenario / CLI spelling.
    pub fn parse(s: &str) -> Result<Security> {
        match s {
            "semi_honest" | "semihonest" | "semi-honest" => Ok(Security::SemiHonest),
            "malicious" => Ok(Security::Malicious),
            other => Err(Error::Config(format!(
                "unknown security tier '{other}' (semi_honest|malicious)"
            ))),
        }
    }

    /// Whether this tier authenticates the transcript.
    pub fn malicious(&self) -> bool {
        matches!(self, Security::Malicious)
    }
}

/// Deferred MAC-check ledger of a malicious-security channel.
///
/// Three independent random-linear-combination accumulators, all over
/// Z_{2^64} with coefficients forced odd (an odd `r` makes `r·2^b ≠ 0`
/// for every bit weight `b`, so any single flipped payload bit shifts
/// the digest — deterministic detection, no soundness gap for the
/// bit-flip adversary the fault layer models):
///
/// * `sigma_out` / `sigma_in` — every wire frame this endpoint sends /
///   receives, folded word-by-word (plus a length word) with a
///   per-direction coefficient stream. Each direction of the link is
///   FIFO, so the sender's j-th outbound word and the receiver's j-th
///   inbound word line up exactly; at a barrier each party's `sigma_out`
///   must equal the peer's `sigma_in`. This covers **all** traffic —
///   staged gate reveals, direct exchanges, asymmetric sends.
/// * `sigma_mac` — the SPDZ check: for every authenticated opened value
///   `w` with local MAC limb `m_i` (where `m_0 + m_1 = α·w`), fold
///   `r·(m_i − α_i·w)`; the two parties' accumulators must sum to zero.
///
/// The window resets at every [`Chan::mac_barrier`]; the coefficient
/// streams keep running, so a replayed window cannot reuse its
/// coefficients.
pub(crate) struct MacAcc {
    /// This party's additive share of the global MAC key α (α odd).
    alpha: u64,
    /// Coefficients for frames this endpoint sends.
    rlc_out: Prg,
    /// Coefficients for frames this endpoint receives (the peer's
    /// `rlc_out` stream — seeded by sender identity).
    rlc_in: Prg,
    /// Coefficients for authenticated opened values (shared stream; the
    /// open order is symmetric by the gate-engine invariant).
    rlc_mac: Prg,
    /// Party-local commitment nonces (deterministic per seed/party, so
    /// malicious-mode transcripts stay golden-pinnable).
    nonce: Prg,
    out_words: u64,
    in_words: u64,
    mac_words: u64,
    sigma_out: u64,
    sigma_in: u64,
    sigma_mac: u64,
    /// Barriers completed on this channel (diagnostics).
    barriers: u64,
}

fn fold_frame(prg: &mut Prg, sigma: &mut u64, count: &mut u64, bytes: &[u8]) {
    // Length word first: truncation/extension moves the digest even when
    // the surviving words agree.
    let r = prg.next_u64() | 1;
    *sigma = sigma.wrapping_add(r.wrapping_mul(bytes.len() as u64));
    *count += 1;
    for chunk in bytes.chunks(8) {
        let mut w = [0u8; 8];
        w[..chunk.len()].copy_from_slice(chunk);
        let r = prg.next_u64() | 1;
        *sigma = sigma.wrapping_add(r.wrapping_mul(u64::from_le_bytes(w)));
        *count += 1;
    }
}

impl MacAcc {
    fn new(alpha: u64, seed: u128, party: usize) -> MacAcc {
        // Direction streams are keyed by *sender* identity, so my
        // outbound stream is exactly the peer's inbound stream.
        let dir = |p: usize| Prg::new(seed ^ 0x0AC0_F01D ^ ((p as u128 + 1) << 64));
        MacAcc {
            alpha,
            rlc_out: dir(party),
            rlc_in: dir(1 - party),
            rlc_mac: Prg::new(seed ^ (0x0ACC_ED << 96)),
            nonce: Prg::new(seed ^ (0x0A0_CE << 96) ^ ((party as u128 + 1) << 32)),
            out_words: 0,
            in_words: 0,
            mac_words: 0,
            sigma_out: 0,
            sigma_in: 0,
            sigma_mac: 0,
            barriers: 0,
        }
    }

    fn fold_out(&mut self, bytes: &[u8]) {
        fold_frame(&mut self.rlc_out, &mut self.sigma_out, &mut self.out_words, bytes);
    }

    fn fold_in(&mut self, bytes: &[u8]) {
        fold_frame(&mut self.rlc_in, &mut self.sigma_in, &mut self.in_words, bytes);
    }

    fn fold_opened(&mut self, opened: &[u64], limbs: &[u64]) {
        debug_assert_eq!(opened.len(), limbs.len(), "one MAC limb per opened word");
        for (w, m) in opened.iter().zip(limbs) {
            let r = self.rlc_mac.next_u64() | 1;
            let local = m.wrapping_sub(self.alpha.wrapping_mul(*w));
            self.sigma_mac = self.sigma_mac.wrapping_add(r.wrapping_mul(local));
            self.mac_words += 1;
        }
    }

    fn reset_window(&mut self) {
        self.out_words = 0;
        self.in_words = 0;
        self.mac_words = 0;
        self.sigma_out = 0;
        self.sigma_in = 0;
        self.sigma_mac = 0;
        self.barriers += 1;
    }
}

/// Hash commitment to a barrier reveal: 4 words binding the phase label
/// and every ledger word (including the party nonce).
fn barrier_commit(phase: &str, reveal: &[u64]) -> [u64; 4] {
    let mut h = Hash256::new();
    h.update(b"ppkm.mac.barrier.v1");
    h.update(phase.as_bytes());
    for w in reveal {
        h.update(w.to_le_bytes());
    }
    let d = h.finalize();
    let mut out = [0u64; 4];
    for (i, c) in d.chunks_exact(8).enumerate() {
        let mut w = [0u8; 8];
        w.copy_from_slice(c);
        out[i] = u64::from_le_bytes(w);
    }
    out
}

/// Barrier verdict words ("MACBAROK" / "MACBARNO", big-endian).
const MAC_VERDICT_OK: u64 = u64::from_be_bytes(*b"MACBAROK");
const MAC_VERDICT_BAD: u64 = u64::from_be_bytes(*b"MACBARNO");

pub(crate) enum Backend {
    Mpsc { tx: Sender<Vec<u8>>, rx: Receiver<Vec<u8>> },
    Tcp(super::tcp::TcpTransport),
    /// One multiplexed session riding a shared link (see
    /// [`super::mux`]): every frame is prefixed with the session tag,
    /// and the session meter charges payload **plus tag**, so the
    /// per-session meters sum exactly to the link's byte/msg totals.
    Mux(super::mux::MuxSession),
}

/// One endpoint of a two-party connection with an attached [`Meter`].
pub struct Chan {
    backend: Backend,
    meter: Meter,
    /// Optional deterministic link shaping (see [`LinkShaper`]): paces
    /// every receive to a [`CostModel`] without touching payloads or
    /// meters.
    shaper: Option<LinkShaper>,
    /// Optional armed fault (see [`crate::net::fault`]): consulted before
    /// any byte moves or is metered, so flights before the trigger are
    /// bit-identical to an uninjected run.
    fault: Option<FaultState>,
    /// Deferred MAC-check ledger, armed by [`Chan::enable_mac`] under
    /// [`Security::Malicious`]. `None` (semi-honest) leaves every path
    /// byte-identical to the unauthenticated protocol.
    mac: Option<MacAcc>,
    /// Identity of this endpoint: 0 or 1.
    pub party: usize,
    /// Segments queued for the next flight.
    staged: Vec<Vec<u64>>,
    /// (local, peer) segment pairs by handle; `None` once taken. The
    /// local half is kept so gate closures need not clone their masked
    /// payload. Handles are offset by `resolved_base` (consumed prefix
    /// slots are compacted away, bounding memory by the *outstanding*
    /// gates, not the lifetime gate count).
    resolved: Vec<Option<(Vec<u64>, Vec<u64>)>>,
    resolved_base: usize,
}

/// Decode a frame into ring elements: a length that is not a multiple
/// of 8 is a typed [`Error::Protocol`] (shared by the receive and
/// exchange paths so the check cannot drift between them).
fn decode_u64s(bytes: &[u8]) -> Result<Vec<u64>> {
    if bytes.len() % 8 != 0 {
        return Err(Error::Protocol(format!(
            "malformed u64 frame of {} bytes (not a multiple of 8)",
            bytes.len()
        )));
    }
    Ok(bytes
        .chunks_exact(8)
        .map(|c| {
            let mut w = [0u8; 8];
            w.copy_from_slice(c);
            u64::from_le_bytes(w)
        })
        .collect())
}

/// The single justified abort in the wire layer. Protocol internals
/// treat a dead or misbehaving peer mid-protocol as unrecoverable —
/// there is no share state to roll back to — so every infallible
/// `Chan` method funnels its failure here for one loud, attributed
/// exit. Fallible callers (the deployment handshake, barriers, the
/// serve driver) use the `try_*` twins and never reach this.
fn wire_fatal(op: &str, e: Error) -> ! {
    // Infallible Chan methods funnel unrecoverable mid-protocol wire
    // failures here; recoverable paths use the try_* twins.
    // lint:allow(no-panic-in-wire-paths): the one sanctioned wire-layer abort
    panic!("net::channel {op}: unrecoverable wire failure: {e}")
}

/// Create a connected pair of in-process endpoints (party 0, party 1).
pub fn duplex_pair() -> (Chan, Chan) {
    let (tx0, rx1) = channel();
    let (tx1, rx0) = channel();
    (
        Chan {
            backend: Backend::Mpsc { tx: tx0, rx: rx0 },
            meter: Meter::new(),
            shaper: None,
            fault: None,
            mac: None,
            party: 0,
            staged: Vec::new(),
            resolved: Vec::new(),
            resolved_base: 0,
        },
        Chan {
            backend: Backend::Mpsc { tx: tx1, rx: rx1 },
            meter: Meter::new(),
            shaper: None,
            fault: None,
            mac: None,
            party: 1,
            staged: Vec::new(),
            resolved: Vec::new(),
            resolved_base: 0,
        },
    )
}

impl Chan {
    /// Wrap a connected TCP transport as an endpoint.
    pub fn from_tcp(t: super::tcp::TcpTransport, party: usize) -> Chan {
        Chan {
            backend: Backend::Tcp(t),
            meter: Meter::new(),
            shaper: None,
            fault: None,
            mac: None,
            party,
            staged: Vec::new(),
            resolved: Vec::new(),
            resolved_base: 0,
        }
    }

    /// Attach deterministic link shaping: every subsequent receive is
    /// paced to `model` (RTT/2 latency + serialization per byte, see
    /// [`LinkShaper`]). Payloads, reveals and meter counts are
    /// bit-identical with or without shaping — only wall-clock changes.
    pub fn set_shaper(&mut self, model: CostModel) {
        self.shaper = Some(LinkShaper::new(model));
    }

    /// Remove any attached link shaping.
    pub fn clear_shaper(&mut self) {
        self.shaper = None;
    }

    /// The link model currently being enforced, if any.
    pub fn shaper_model(&self) -> Option<CostModel> {
        self.shaper.as_ref().map(|s| *s.model())
    }

    /// Arm a deterministic fault (see [`crate::net::fault`]): `plan.mode`
    /// fires on this endpoint's `plan.at_flight`-th flight-opening send.
    pub fn set_fault(&mut self, plan: FaultPlan) {
        self.fault = Some(FaultState::new(plan));
    }

    /// Disarm any scheduled fault.
    pub fn clear_fault(&mut self) {
        self.fault = None;
    }

    /// The armed fault plan, if any.
    pub fn fault_plan(&self) -> Option<FaultPlan> {
        self.fault.as_ref().map(|f| f.plan())
    }

    // ---- Malicious-security MAC ledger ---------------------------------

    /// Arm the deferred MAC-check ledger ([`Security::Malicious`]).
    ///
    /// `alpha_share` is this party's additive share of the global MAC
    /// key α (dealer-derived, α odd — see
    /// `offline::dealer::mac_key_share`); `seed` keys the public
    /// random-linear-combination coefficient streams and must match the
    /// peer's. Both parties must arm at the *same* protocol point: every
    /// frame from here on is folded into the ledger and verified at the
    /// next [`Chan::mac_barrier`]. Arming is idempotent (re-arming
    /// mid-window would desync the coefficient streams).
    pub fn enable_mac(&mut self, alpha_share: u64, seed: u128) {
        if self.mac.is_none() {
            self.mac = Some(MacAcc::new(alpha_share, seed, self.party));
        }
    }

    /// Whether the MAC ledger is armed (i.e. the channel runs at
    /// [`Security::Malicious`]).
    pub fn mac_enabled(&self) -> bool {
        self.mac.is_some()
    }

    /// The security tier this channel currently enforces.
    pub fn security(&self) -> Security {
        if self.mac.is_some() {
            Security::Malicious
        } else {
            Security::SemiHonest
        }
    }

    /// Phase barriers completed on this channel (0 when unarmed).
    pub fn mac_barriers(&self) -> u64 {
        self.mac.as_ref().map(|m| m.barriers).unwrap_or(0)
    }

    /// This party's α-share, if the ledger is armed. Crate-internal:
    /// authenticated gates need it to recombine output MAC limbs
    /// (`α_i·(E·F)` terms); it must never appear on the wire.
    pub(crate) fn mac_alpha(&self) -> Option<u64> {
        self.mac.as_ref().map(|m| m.alpha)
    }

    /// Fold an authenticated open into the SPDZ accumulator: `opened`
    /// are reconstructed public words, `limbs` this party's MAC-limb
    /// shares (`m_0 + m_1 = α·w`). No-op under semi-honest security, so
    /// gates may call it unconditionally.
    pub fn fold_opened(&mut self, opened: &[u64], limbs: &[u64]) {
        if let Some(m) = &mut self.mac {
            m.fold_opened(opened, limbs);
        }
    }

    /// Verify the whole deferred ledger in one batched check — **three**
    /// fixed-size flights (commit, reveal, verdict; 32 + 56 + 8 payload
    /// bytes each way) regardless of how many words the window folded.
    /// No-op (zero flights) under semi-honest security.
    ///
    /// Failure is symmetric: the exchanged verdict word makes *both*
    /// parties abort with a typed [`Error::MacCheck`] naming `phase`
    /// whenever either side's checks fail. On success the window resets;
    /// the coefficient streams keep running.
    pub fn mac_barrier(&mut self, phase: &str) -> Result<()> {
        // Take the ledger out so the barrier's own flights are not
        // folded into the window they verify.
        let Some(mut acc) = self.mac.take() else { return Ok(()) };
        let res = self.mac_barrier_exchange(&mut acc, phase);
        acc.reset_window();
        self.mac = Some(acc);
        res
    }

    fn mac_barrier_exchange(&mut self, acc: &mut MacAcc, phase: &str) -> Result<()> {
        let reveal = [
            acc.out_words,
            acc.in_words,
            acc.mac_words,
            acc.sigma_out,
            acc.sigma_in,
            acc.sigma_mac,
            acc.nonce.next_u64(),
        ];
        let commit = barrier_commit(phase, &reveal);
        let their_commit = self.try_exchange_u64s(&commit)?;
        let their_reveal = self.try_exchange_u64s(&reveal)?;
        let ok = their_commit.len() == 4
            && their_reveal.len() == 7
            // The peer's reveal must match its prior commitment …
            && their_commit[..] == barrier_commit(phase, &their_reveal)[..]
            // … the per-direction ledgers must agree crosswise …
            && their_reveal[0] == acc.in_words
            && their_reveal[1] == acc.out_words
            && their_reveal[3] == acc.sigma_in
            && their_reveal[4] == acc.sigma_out
            // … and the SPDZ accumulators must cancel.
            && their_reveal[2] == acc.mac_words
            && their_reveal[5].wrapping_add(acc.sigma_mac) == 0;
        let verdict = self.try_exchange_u64s(&[if ok { MAC_VERDICT_OK } else { MAC_VERDICT_BAD }])?;
        let peer_ok = verdict.len() == 1 && verdict[0] == MAC_VERDICT_OK;
        if ok && peer_ok {
            Ok(())
        } else if !ok {
            Err(Error::MacCheck(format!(
                "phase barrier '{phase}': batched ledger check failed \
                 ({} out / {} in words folded, {} MAC'd opens)",
                acc.out_words, acc.in_words, acc.mac_words
            )))
        } else {
            Err(Error::MacCheck(format!(
                "phase barrier '{phase}': peer reported a failed ledger on its side"
            )))
        }
    }

    /// Overwrite the meter with a checkpointed snapshot — the resume
    /// path's last act before re-entering the protocol: replayed setup
    /// traffic (handshake, backend negotiation) is erased and the meter
    /// continues exactly where the interrupted run's left off, including
    /// the open-flight flag.
    pub fn restore_meter(&mut self, meter: Meter) {
        self.meter = meter;
    }

    /// Label subsequent traffic with a phase.
    pub fn set_phase(&mut self, label: &str) {
        self.meter.set_phase(label);
    }

    /// Borrow the meter (read-only).
    pub fn meter(&self) -> &Meter {
        &self.meter
    }

    /// Decompose the endpoint for the session mux: transport backend,
    /// meter, shaper and party identity. The round buffer must be
    /// drained (asserted) — a mux takeover mid-flight would corrupt the
    /// segment accounting.
    pub(crate) fn into_raw_parts(
        self,
    ) -> (Backend, Meter, Option<LinkShaper>, Option<FaultState>, Option<MacAcc>, usize) {
        assert!(
            self.staged.is_empty(),
            "round buffer still holds {} unflushed segments",
            self.staged.len()
        );
        (self.backend, self.meter, self.shaper, self.fault, self.mac, self.party)
    }

    /// Reassemble an endpoint from raw parts (the mux's session
    /// constructor and its link restore path).
    pub(crate) fn from_raw_parts(
        backend: Backend,
        meter: Meter,
        shaper: Option<LinkShaper>,
        fault: Option<FaultState>,
        mac: Option<MacAcc>,
        party: usize,
    ) -> Chan {
        Chan {
            backend,
            meter,
            shaper,
            fault,
            mac,
            party,
            staged: Vec::new(),
            resolved: Vec::new(),
            resolved_base: 0,
        }
    }

    /// Consume the endpoint, returning its meter.
    pub fn into_meter(self) -> Meter {
        debug_assert!(
            self.staged.is_empty(),
            "round buffer still holds {} unflushed segments",
            self.staged.len()
        );
        self.meter
    }

    // ---- Round buffer -------------------------------------------------

    /// Queue a symmetric reveal for the next flight; returns the handle
    /// under which the peer's matching segment is addressable after
    /// [`Chan::flush_round`].
    pub fn stage_u64s(&mut self, xs: Vec<u64>) -> usize {
        self.staged.push(xs);
        self.resolved_base + self.resolved.len() + self.staged.len() - 1
    }

    /// Number of segments currently queued for the next flight.
    pub fn staged_segments(&self) -> usize {
        self.staged.len()
    }

    /// Exchange every staged segment in **one** flight. No-op when
    /// nothing is staged.
    pub fn flush_round(&mut self) {
        if self.staged.is_empty() {
            return;
        }
        // Compact the fully-consumed prefix before growing.
        let consumed = self.resolved.iter().take_while(|s| s.is_none()).count();
        if consumed > 0 {
            self.resolved.drain(..consumed);
            self.resolved_base += consumed;
        }
        let total: usize = self.staged.iter().map(|s| s.len()).sum();
        let mut payload = Vec::with_capacity(total);
        for s in &self.staged {
            payload.extend_from_slice(s);
        }
        let theirs = self.exchange_u64s(&payload);
        // Only the TOTAL length is verifiable without shipping per-segment
        // metadata, and in-band headers would corrupt the exact byte/flight
        // accounting the meters and benches rely on. The per-segment split
        // below therefore trusts the symmetric-gate invariant: both parties
        // stage identical segment lengths in identical order between
        // flushes. A protocol author who breaks it gets garbage shares, not
        // a panic — when adding an asymmetric gate, reveal through explicit
        // send/recv instead of the round buffer.
        assert_eq!(
            theirs.len(),
            payload.len(),
            "round buffer: peers staged unequal payloads ({} segments locally)",
            self.staged.len()
        );
        let mut off = 0;
        for s in std::mem::take(&mut self.staged) {
            let len = s.len();
            self.resolved.push(Some((s, theirs[off..off + len].to_vec())));
            off += len;
        }
    }

    /// Take a staged segment's (local, peer) reveal pair (panics if the
    /// flight has not been flushed yet, or on double-take). Returning
    /// the local half spares gate closures a payload clone.
    pub fn take_segment(&mut self, handle: usize) -> (Vec<u64>, Vec<u64>) {
        assert!(
            handle >= self.resolved_base && handle - self.resolved_base < self.resolved.len(),
            "segment {handle} not flushed — call flush_round() first"
        );
        self.resolved[handle - self.resolved_base].take().unwrap_or_else(|| {
            wire_fatal("take_segment", Error::Protocol("segment already taken".into()))
        })
    }

    // ---- Framed transport --------------------------------------------

    /// Fallible send of a raw byte message: typed errors instead of a
    /// panic when the peer is gone or the frame violates the transport
    /// cap. The deployment handshake and barriers use this path so a
    /// misbehaving peer yields a clean process exit.
    pub fn try_send_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        // The MAC ledger folds the frame the honest sender *intended*:
        // any downstream tampering (the fault layer's wire adversary)
        // diverges the peer's inbound digest and fails the next barrier.
        if let Some(m) = &mut self.mac {
            m.fold_out(bytes);
        }
        // Armed faults fire before any byte moves or is metered: a
        // killed flight leaves the meter exactly as an OS kill would.
        match self.fault.as_mut().map(FaultState::on_send).transpose()? {
            None | Some(SendAction::Pass) => {}
            Some(SendAction::Abort) => std::process::abort(),
            Some(SendAction::Swallow) => return Ok(()),
            Some(SendAction::Tamper) => {
                // Active adversary: flip one bit mid-frame and ship it
                // normally — metered like a clean send, channel alive.
                let mut owned = bytes.to_vec();
                if let Some(b) = {
                    let mid = owned.len() / 2;
                    owned.get_mut(mid)
                } {
                    *b ^= 1;
                }
                return self.ship_bytes(&owned);
            }
            Some(SendAction::Truncate) => {
                // Ship an odd prefix (never a multiple of 8) unmetered,
                // then die; the peer's u64 decode rejects the frame.
                let keep = ((bytes.len() / 2) | 1).min(bytes.len());
                let cut = &bytes[..keep];
                let _ = match &mut self.backend {
                    Backend::Mpsc { tx, .. } => tx.send(cut.to_vec()).is_ok(),
                    Backend::Tcp(t) => t.send(cut).is_ok(),
                    Backend::Mux(s) => s.send(cut).is_ok(),
                };
                return Err(self
                    .fault
                    .as_ref()
                    .map(FaultState::closed_error)
                    .unwrap_or_else(|| Error::ChannelClosed("injected fault".into())));
            }
        }
        self.ship_bytes(bytes)
    }

    /// Put one frame on the wire and meter it (post-fault, post-ledger).
    fn ship_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        // A mux session's wire cost includes its 8-byte tag, so the
        // per-session meters sum exactly to the link totals.
        let wire_len = bytes.len() as u64
            + match &self.backend {
                Backend::Mux(_) => super::mux::MUX_TAG_BYTES,
                _ => 0,
            };
        match &mut self.backend {
            Backend::Mpsc { tx, .. } => tx
                .send(bytes.to_vec())
                .map_err(|_| Error::ChannelClosed("in-process peer hung up".into()))?,
            Backend::Tcp(t) => t.send(bytes)?,
            Backend::Mux(s) => s.send(bytes)?,
        }
        self.meter.on_send(wire_len);
        Ok(())
    }

    /// Fallible receive of the next raw byte message (see
    /// [`Chan::try_send_bytes`]). Applies link shaping after metering.
    pub fn try_recv_bytes(&mut self) -> Result<Vec<u8>> {
        if let Some(f) = self.fault.as_mut() {
            f.on_recv()?;
        }
        let bytes = match &mut self.backend {
            Backend::Mpsc { rx, .. } => rx
                .recv()
                .map_err(|_| Error::ChannelClosed("in-process peer hung up".into()))?,
            Backend::Tcp(t) => t.recv()?,
            // Link shaping for mux sessions happens once, in the mux
            // reader (one physical pipe); session chans stay unshaped.
            Backend::Mux(s) => s.recv()?,
        };
        self.meter.on_recv();
        if let Some(m) = &mut self.mac {
            m.fold_in(&bytes);
        }
        if let Some(s) = &mut self.shaper {
            s.pace_recv(bytes.len() as u64);
        }
        Ok(bytes)
    }

    /// Send a raw byte message (panics on a dead peer — protocol
    /// internals treat that as unrecoverable; fallible callers use
    /// [`Chan::try_send_bytes`]).
    pub fn send_bytes(&mut self, bytes: &[u8]) {
        self.try_send_bytes(bytes).unwrap_or_else(|e| wire_fatal("send_bytes", e));
    }

    /// Receive the next raw byte message (panicking twin of
    /// [`Chan::try_recv_bytes`]).
    pub fn recv_bytes(&mut self) -> Vec<u8> {
        self.try_recv_bytes().unwrap_or_else(|e| wire_fatal("recv_bytes", e))
    }

    /// Send a vector of ring elements (8 bytes each, little endian).
    pub fn send_u64s(&mut self, xs: &[u64]) {
        let mut bytes = Vec::with_capacity(xs.len() * 8);
        for x in xs {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        self.send_bytes(&bytes);
    }

    /// Fallible receive of a ring-element vector: a frame whose length
    /// is not a multiple of 8 is a typed [`Error::Protocol`].
    pub fn try_recv_u64s(&mut self) -> Result<Vec<u64>> {
        let bytes = self.try_recv_bytes()?;
        decode_u64s(&bytes)
    }

    /// Receive a vector of ring elements (panicking twin of
    /// [`Chan::try_recv_u64s`]).
    pub fn recv_u64s(&mut self) -> Vec<u64> {
        self.try_recv_u64s().unwrap_or_else(|e| wire_fatal("recv_u64s", e))
    }

    /// Send a matrix (shape is protocol-known; only the buffer travels).
    pub fn send_mat(&mut self, m: &Mat) {
        self.send_u64s(&m.data);
    }

    /// Fallible receive of a matrix with the given (protocol-known)
    /// shape: a peer shipping the wrong element count yields a typed
    /// [`Error::Shape`] instead of a panic or a misshaped buffer.
    pub fn try_recv_mat(&mut self, rows: usize, cols: usize) -> Result<Mat> {
        let want = rows
            .checked_mul(cols)
            .ok_or_else(|| Error::Shape(format!("recv_mat {rows}×{cols} overflows")))?;
        let data = self.try_recv_u64s()?;
        if data.len() != want {
            return Err(Error::Shape(format!(
                "matrix frame carries {} words, expected {rows}×{cols} = {want}",
                data.len()
            )));
        }
        Ok(Mat::from_vec(rows, cols, data))
    }

    /// Receive a matrix with the given (protocol-known) shape
    /// (panicking twin of [`Chan::try_recv_mat`]).
    pub fn recv_mat(&mut self, rows: usize, cols: usize) -> Mat {
        self.try_recv_mat(rows, cols).unwrap_or_else(|e| wire_fatal("recv_mat", e))
    }

    /// Fallible symmetric exchange of raw bytes (the deployment
    /// handshake's transport): party 0 sends first, party 1 receives
    /// first.
    pub fn try_exchange_bytes(&mut self, bytes: &[u8]) -> Result<Vec<u8>> {
        if self.party == 0 {
            self.try_send_bytes(bytes)?;
            self.try_recv_bytes()
        } else {
            let r = self.try_recv_bytes()?;
            self.try_send_bytes(bytes)?;
            Ok(r)
        }
    }

    /// Fallible symmetric exchange of ring vectors (see
    /// [`Chan::exchange_u64s`]).
    pub fn try_exchange_u64s(&mut self, xs: &[u64]) -> Result<Vec<u64>> {
        let mut bytes = Vec::with_capacity(xs.len() * 8);
        for x in xs {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        let theirs = self.try_exchange_bytes(&bytes)?;
        decode_u64s(&theirs)
    }

    /// Symmetric exchange of ring vectors: party 0 sends first, party 1
    /// receives first (one round in each direction, one RTT total since
    /// both directions overlap on a full-duplex link). Panicking twin of
    /// [`Chan::try_exchange_u64s`] — one implementation, so the flight
    /// ordering cannot drift between handshake and protocol traffic.
    pub fn exchange_u64s(&mut self, xs: &[u64]) -> Vec<u64> {
        self.try_exchange_u64s(xs).unwrap_or_else(|e| wire_fatal("exchange_u64s", e))
    }

    /// Symmetric exchange of equal-shape matrices.
    pub fn exchange_mat(&mut self, m: &Mat) -> Mat {
        let data = self.exchange_u64s(&m.data);
        assert_eq!(data.len(), m.data.len(), "exchange shape mismatch");
        Mat::from_vec(m.rows, m.cols, data)
    }

    /// Send one u64 scalar.
    pub fn send_scalar(&mut self, x: u64) {
        self.send_u64s(&[x]);
    }

    /// Receive one u64 scalar.
    pub fn recv_scalar(&mut self) -> u64 {
        let v = self.recv_u64s();
        assert_eq!(v.len(), 1);
        v[0]
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::net::fault::FaultMode;
    use std::thread;

    #[test]
    fn mat_roundtrip() {
        let (mut c0, mut c1) = duplex_pair();
        let m = Mat::from_vec(2, 2, vec![1, 2, 3, u64::MAX]);
        let mc = m.clone();
        let h = thread::spawn(move || {
            c0.send_mat(&mc);
        });
        let got = c1.recv_mat(2, 2);
        h.join().unwrap();
        assert_eq!(got, m);
    }

    #[test]
    fn exchange_is_symmetric() {
        let (mut c0, mut c1) = duplex_pair();
        let h = thread::spawn(move || c0.exchange_u64s(&[1, 2]));
        let from0 = c1.exchange_u64s(&[3, 4]);
        let from1 = h.join().unwrap();
        assert_eq!(from0, vec![1, 2]);
        assert_eq!(from1, vec![3, 4]);
    }

    #[test]
    fn staged_segments_travel_in_one_flight() {
        let (mut c0, mut c1) = duplex_pair();
        let h = thread::spawn(move || {
            let a = c0.stage_u64s(vec![1, 2]);
            let b = c0.stage_u64s(vec![3]);
            c0.flush_round();
            let ra = c0.take_segment(a);
            let rb = c0.take_segment(b);
            (ra.1, rb.1, c0.into_meter())
        });
        let a = c1.stage_u64s(vec![10, 20]);
        let b = c1.stage_u64s(vec![30]);
        c1.flush_round();
        let got_a = c1.take_segment(a);
        assert_eq!(got_a, (vec![10, 20], vec![1, 2]));
        assert_eq!(c1.take_segment(b).1, vec![3]);
        let (ra, rb, m0) = h.join().unwrap();
        assert_eq!(ra, vec![10, 20]);
        assert_eq!(rb, vec![30]);
        // One flight for both segments, 24 bytes total.
        assert_eq!(m0.total().rounds, 1);
        assert_eq!(m0.total().bytes_sent, 24);
    }

    #[test]
    fn flush_with_empty_buffer_is_free() {
        let (mut c0, _c1) = duplex_pair();
        c0.flush_round();
        assert_eq!(c0.meter().total().rounds, 0);
    }

    #[test]
    #[should_panic(expected = "not flushed")]
    fn taking_before_flush_panics() {
        let (mut c0, _c1) = duplex_pair();
        let h = c0.stage_u64s(vec![1]);
        let _ = c0.take_segment(h);
    }

    #[test]
    fn try_recv_mat_rejects_wrong_dims() {
        let (mut c0, mut c1) = duplex_pair();
        let h = thread::spawn(move || {
            c0.send_u64s(&[1, 2, 3]); // 3 words
        });
        // Expecting a 2×2 matrix (4 words) → typed shape error, no panic.
        let err = c1.try_recv_mat(2, 2).unwrap_err();
        assert!(err.to_string().contains("expected 2×2"), "{err}");
        h.join().unwrap();
    }

    #[test]
    fn try_recv_on_hung_up_peer_is_channel_closed() {
        let (c0, mut c1) = duplex_pair();
        drop(c0);
        let err = c1.try_recv_bytes().unwrap_err();
        assert!(err.to_string().contains("hung up"), "{err}");
        assert!(c1.try_send_bytes(b"x").is_err());
    }

    #[test]
    fn shaping_changes_wall_clock_but_not_meters() {
        use crate::net::cost::CostModel;
        use std::time::{Duration, Instant};

        let run = |shape: Option<CostModel>| {
            let (mut c0, mut c1) = duplex_pair();
            if let Some(m) = shape {
                c0.set_shaper(m);
                c1.set_shaper(m);
            }
            let h = thread::spawn(move || {
                for _ in 0..3 {
                    c0.send_u64s(&[1, 2]);
                    c0.recv_u64s();
                }
                c0.into_meter()
            });
            for _ in 0..3 {
                let v = c1.recv_u64s();
                c1.send_u64s(&v);
            }
            (h.join().unwrap(), c1.into_meter())
        };
        let t0 = Instant::now();
        let (m0, m1) = run(None);
        let unshaped = t0.elapsed();
        // 20 ms RTT: each of the 3 ping-pong rounds pays ≥ one full RTT
        // (10 ms per direction), so the shaped run takes ≥ ~60 ms.
        let t0 = Instant::now();
        let (s0, s1) = run(Some(CostModel { rtt_s: 20e-3, bandwidth_bps: f64::INFINITY }));
        let shaped = t0.elapsed();
        assert!(shaped >= Duration::from_millis(55), "{shaped:?}");
        assert!(shaped > unshaped, "shaping must slow the loop down");
        // Meters are bit-identical: shaping never touches accounting.
        assert_eq!(m0.total(), s0.total());
        assert_eq!(m1.total(), s1.total());
        assert_eq!(s0.total().rounds, 3);
    }

    // ---- MAC ledger -----------------------------------------------------

    /// Two-party harness: arm both ends with α-shares summing to an odd
    /// key and a shared coefficient seed.
    fn mac_pair(seed: u128) -> (Chan, Chan) {
        let (mut c0, mut c1) = duplex_pair();
        c0.enable_mac(0x1234_5678_9abc_def1, seed);
        c1.enable_mac(0x0f0f_0f0f_0f0f_0f0e, seed);
        (c0, c1)
    }

    #[test]
    fn mac_barrier_passes_on_clean_traffic() {
        let (mut c0, mut c1) = mac_pair(7);
        let h = thread::spawn(move || {
            c0.exchange_u64s(&[1, 2, 3]);
            c0.send_u64s(&[9]); // asymmetric flight: folded too
            let r = c0.mac_barrier("test.phase");
            (r, c0.mac_barriers())
        });
        c1.exchange_u64s(&[4, 5, 6]);
        c1.recv_u64s();
        c1.mac_barrier("test.phase").unwrap();
        assert_eq!(c1.mac_barriers(), 1);
        let (r0, b0) = h.join().unwrap();
        r0.unwrap();
        assert_eq!(b0, 1);
        assert!(c1.mac_enabled());
        assert_eq!(c1.security(), Security::Malicious);
    }

    #[test]
    fn mac_barrier_spans_windows_independently() {
        // A second window after a passed barrier verifies on its own.
        let (mut c0, mut c1) = mac_pair(11);
        let h = thread::spawn(move || {
            for w in 0..3u64 {
                c0.exchange_u64s(&[w, w + 1]);
                c0.mac_barrier("w").unwrap();
            }
            c0.mac_barriers()
        });
        for w in 0..3u64 {
            c1.exchange_u64s(&[10 + w]);
            c1.mac_barrier("w").unwrap();
        }
        assert_eq!(h.join().unwrap(), 3);
    }

    #[test]
    fn tampered_flight_fails_barrier_on_both_parties() {
        let (mut c0, mut c1) = mac_pair(13);
        c0.set_fault(FaultPlan { at_flight: 1, mode: FaultMode::Tamper });
        let h = thread::spawn(move || {
            c0.exchange_u64s(&[1, 2, 3]); // party 0 ships a flipped bit
            c0.mac_barrier("train.step")
        });
        c1.exchange_u64s(&[4, 5, 6]);
        let e1 = c1.mac_barrier("train.step").unwrap_err();
        let e0 = h.join().unwrap().unwrap_err();
        // The receiver's in-ledger disagrees with the sender's out-ledger
        // → local failure on party 1, peer-verdict failure on party 0;
        // both are typed and both name the phase.
        assert!(matches!(e1, Error::MacCheck(_)), "{e1}");
        assert!(matches!(e0, Error::MacCheck(_)), "{e0}");
        assert!(e1.to_string().contains("train.step"), "{e1}");
        assert!(e0.to_string().contains("train.step"), "{e0}");
    }

    #[test]
    fn bad_mac_limb_fails_barrier() {
        let (mut c0, mut c1) = mac_pair(17);
        let h = thread::spawn(move || {
            // α0·w as limb; peer uses α1·w, so sums hold for w = 42 …
            c0.fold_opened(&[42], &[0x1234_5678_9abc_def1u64.wrapping_mul(42)]);
            // … but the second open carries a limb off by one.
            c0.fold_opened(&[7], &[0x1234_5678_9abc_def1u64.wrapping_mul(7).wrapping_add(1)]);
            c0.mac_barrier("open.check")
        });
        c1.fold_opened(&[42], &[0x0f0f_0f0f_0f0f_0f0eu64.wrapping_mul(42)]);
        c1.fold_opened(&[7], &[0x0f0f_0f0f_0f0f_0f0eu64.wrapping_mul(7)]);
        let e1 = c1.mac_barrier("open.check").unwrap_err();
        let e0 = h.join().unwrap().unwrap_err();
        assert!(matches!(e1, Error::MacCheck(_)), "{e1}");
        assert!(matches!(e0, Error::MacCheck(_)), "{e0}");
    }

    #[test]
    fn semi_honest_barrier_is_a_free_no_op() {
        let (mut c0, _c1) = duplex_pair();
        assert!(!c0.mac_enabled());
        assert_eq!(c0.security(), Security::SemiHonest);
        c0.mac_barrier("anything").unwrap();
        c0.fold_opened(&[1, 2], &[3, 4]);
        assert_eq!(c0.meter().total().rounds, 0);
        assert_eq!(c0.meter().total().bytes_sent, 0);
        assert_eq!(c0.mac_barriers(), 0);
    }

    #[test]
    fn security_parses_and_round_trips() {
        for s in [Security::SemiHonest, Security::Malicious] {
            assert_eq!(Security::parse(s.as_str()).unwrap(), s);
        }
        assert_eq!(Security::parse("semihonest").unwrap(), Security::SemiHonest);
        assert_eq!(Security::parse("semi-honest").unwrap(), Security::SemiHonest);
        assert!(Security::parse("covert").is_err());
        assert!(Security::Malicious.malicious());
        assert!(!Security::SemiHonest.malicious());
    }
}
