//! Per-phase communication accounting.
//!
//! Protocol code labels phases (`meter.set_phase("online.distance")`);
//! the channel attributes every message to the current phase. A *round*
//! is counted when a send starts a new flight — i.e. the first send after
//! a receive (or the very first send): consecutive sends without an
//! intervening receive belong to the same flight and cost one RTT.

use std::collections::BTreeMap;

/// Totals for one labelled phase.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PhaseStats {
    /// Bytes this party put on the wire.
    pub bytes_sent: u64,
    /// Messages this party put on the wire.
    pub msgs_sent: u64,
    /// Communication rounds initiated by this party (flights).
    pub rounds: u64,
}

impl PhaseStats {
    pub fn merge(&mut self, o: &PhaseStats) {
        self.bytes_sent += o.bytes_sent;
        self.msgs_sent += o.msgs_sent;
        self.rounds += o.rounds;
    }

    /// Traffic accumulated since an earlier snapshot of the same counter
    /// — per-request accounting for long-lived sessions: snapshot
    /// (`meter.total_prefix(...)`) before a request, subtract after.
    /// Saturating, so a mismatched snapshot cannot underflow.
    pub fn since(&self, before: &PhaseStats) -> PhaseStats {
        PhaseStats {
            bytes_sent: self.bytes_sent.saturating_sub(before.bytes_sent),
            msgs_sent: self.msgs_sent.saturating_sub(before.msgs_sent),
            rounds: self.rounds.saturating_sub(before.rounds),
        }
    }
}

/// Per-party communication meter with phase attribution.
#[derive(Debug, Clone)]
pub struct Meter {
    phases: BTreeMap<String, PhaseStats>,
    current: String,
    /// True when the next send opens a new flight (round).
    flight_open: bool,
}

impl Default for Meter {
    fn default() -> Self {
        Meter { phases: BTreeMap::new(), current: "default".into(), flight_open: true }
    }
}

impl Meter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Switch attribution to a new phase label.
    pub fn set_phase(&mut self, label: &str) {
        self.current = label.to_string();
        self.phases.entry(self.current.clone()).or_default();
    }

    /// Current phase label.
    pub fn phase(&self) -> &str {
        &self.current
    }

    /// Record a sent message of `bytes` length.
    pub fn on_send(&mut self, bytes: u64) {
        let e = self.phases.entry(self.current.clone()).or_default();
        e.bytes_sent += bytes;
        e.msgs_sent += 1;
        if self.flight_open {
            e.rounds += 1;
            self.flight_open = false;
        }
    }

    /// Record a receive (closes the current flight).
    pub fn on_recv(&mut self) {
        self.flight_open = true;
    }

    /// Stats for one phase (zero if never entered).
    pub fn get(&self, label: &str) -> PhaseStats {
        self.phases.get(label).copied().unwrap_or_default()
    }

    /// Sum over all phases.
    pub fn total(&self) -> PhaseStats {
        let mut t = PhaseStats::default();
        for s in self.phases.values() {
            t.merge(s);
        }
        t
    }

    /// Sum over phases whose label starts with `prefix`
    /// (e.g. all of `"online."`).
    pub fn total_prefix(&self, prefix: &str) -> PhaseStats {
        let mut t = PhaseStats::default();
        for (k, s) in &self.phases {
            if k.starts_with(prefix) {
                t.merge(s);
            }
        }
        t
    }

    /// Iterate (label, stats) sorted by label.
    pub fn phases(&self) -> impl Iterator<Item = (&str, &PhaseStats)> {
        self.phases.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Merge another meter into this one (e.g. both parties' totals).
    pub fn merge(&mut self, other: &Meter) {
        for (k, v) in &other.phases {
            self.phases.entry(k.clone()).or_default().merge(v);
        }
    }

    /// Full serializable state: sorted `(label, stats)` pairs, the
    /// current phase label, and the flight flag. The flag matters —
    /// after an exchange the sender-first party sits with an open flight
    /// while its peer does not, and restoring it wrong would add a
    /// phantom round to the first post-resume send.
    pub fn snapshot(&self) -> (Vec<(String, PhaseStats)>, String, bool) {
        (
            self.phases.iter().map(|(k, v)| (k.clone(), *v)).collect(),
            self.current.clone(),
            self.flight_open,
        )
    }

    /// Rebuild a meter from a [`Self::snapshot`] — the exact inverse.
    pub fn from_snapshot(
        phases: Vec<(String, PhaseStats)>,
        current: String,
        flight_open: bool,
    ) -> Self {
        Meter { phases: phases.into_iter().collect(), current, flight_open }
    }

    /// Fold raw stats into a phase without touching the flight state.
    /// The mux link accountant uses this: session frames are counted
    /// against the link (`bytes`/`msgs` exactly), while *flights* stay a
    /// per-session notion — link-level flight interleaving depends on
    /// worker scheduling, so the caller passes `rounds: 0` to keep the
    /// link meter deterministic.
    pub fn record(&mut self, label: &str, stats: PhaseStats) {
        self.phases.entry(label.to_string()).or_default().merge(&stats);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_attribute_bytes() {
        let mut m = Meter::new();
        m.set_phase("offline");
        m.on_send(100);
        m.set_phase("online.s1");
        m.on_send(10);
        m.on_recv();
        m.on_send(5);
        assert_eq!(m.get("offline").bytes_sent, 100);
        assert_eq!(m.get("online.s1").bytes_sent, 15);
        assert_eq!(m.total().bytes_sent, 115);
        assert_eq!(m.total_prefix("online.").bytes_sent, 15);
    }

    #[test]
    fn rounds_count_flights_not_messages() {
        let mut m = Meter::new();
        m.on_send(1);
        m.on_send(1); // same flight
        assert_eq!(m.total().rounds, 1);
        m.on_recv();
        m.on_send(1); // new flight
        assert_eq!(m.total().rounds, 2);
        assert_eq!(m.total().msgs_sent, 3);
    }

    #[test]
    fn since_gives_per_request_deltas() {
        let mut m = Meter::new();
        m.set_phase("serve.s1");
        m.on_send(10);
        m.on_recv();
        let before = m.total_prefix("serve.");
        m.on_send(7);
        m.on_recv();
        m.on_send(3);
        let delta = m.total_prefix("serve.").since(&before);
        assert_eq!(delta.bytes_sent, 10);
        assert_eq!(delta.rounds, 2);
        assert_eq!(delta.msgs_sent, 2);
        // A mismatched (newer) snapshot saturates instead of panicking.
        let newer = m.total_prefix("serve.");
        assert_eq!(before.since(&newer).bytes_sent, 0);
    }

    #[test]
    fn snapshot_roundtrips_including_flight_state() {
        let mut m = Meter::new();
        m.set_phase("online.s1");
        m.on_send(10); // flight now closed
        let (p, c, f) = m.snapshot();
        assert!(!f);
        let mut back = Meter::from_snapshot(p, c, f);
        assert_eq!(back.phase(), "online.s1");
        // A send on the restored meter must NOT open a new flight.
        back.on_send(1);
        m.on_send(1);
        assert_eq!(back.get("online.s1"), m.get("online.s1"));
        m.on_recv();
        let (p2, c2, f2) = m.snapshot();
        assert!(f2);
        let back2 = Meter::from_snapshot(p2, c2, f2);
        assert_eq!(back2.total(), m.total());
    }

    #[test]
    fn merge_adds() {
        let mut a = Meter::new();
        a.set_phase("p");
        a.on_send(3);
        let mut b = Meter::new();
        b.set_phase("p");
        b.on_send(4);
        a.merge(&b);
        assert_eq!(a.get("p").bytes_sent, 7);
    }
}
