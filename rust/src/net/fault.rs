//! Deterministic fault injection for the accounted channel.
//!
//! Crash-resumption can only be *tested* if crashes are reproducible.
//! A [`FaultPlan`] schedules exactly one fault at the Nth flight-opening
//! send of a [`Chan`] — the same trigger notion the meter uses for
//! rounds — so an in-process duplex run and a two-process TCP run die at
//! the exact same protocol point. Like [`crate::net::shape`], the layer
//! never perturbs what it does not simulate: every send before the
//! trigger is byte- and meter-identical to an uninjected run, and the
//! killed flight itself is never metered (an OS kill would not have
//! flushed those counters either).
//!
//! Modes:
//! * [`FaultMode::Kill`] — the flight never leaves: the local party gets
//!   a typed `ChannelClosed` and every later op fails the same way (the
//!   peer observes a hangup once the party unwinds).
//! * [`FaultMode::Drop`] — the flight is silently swallowed (a lost
//!   frame); the local party continues until its next channel op, which
//!   fails, while the peer blocks until the hangup unblocks it.
//! * [`FaultMode::Trunc`] — an odd-length prefix goes out (never a
//!   multiple of 8, so the peer's u64 decode yields a typed
//!   `Error::Protocol`), then the local side dies.
//! * [`FaultMode::Abort`] — `std::process::abort()`: a real SIGABRT for
//!   the two-process kill-and-resume matrix in CI.
//! * [`FaultMode::Tamper`] — an *active-adversary* model, not a crash:
//!   the flight ships with exactly one payload bit flipped and the
//!   channel stays alive on both ends. Under `Security::SemiHonest` the
//!   corruption silently skews shares; under `Security::Malicious` the
//!   next MAC phase barrier catches it on **both** parties with a typed
//!   [`Error::MacCheck`] (regression-tested in `rust/tests/tamper.rs`).
//!
//! On a multiplexed gateway link, link-level flight interleaving is
//! scheduling-dependent, so the mux trigger counts *frames* instead of
//! flights (see `MuxSession::send`) — a mid-session fault still fires
//! deterministically "somewhere inside the session traffic", which is
//! all the train-barrier resume model needs (the gateway tail re-runs
//! from the last training checkpoint).

// Wire-facing layer: typed errors only (ppkm-lint no-panic-in-wire-paths).
#![deny(clippy::unwrap_used, clippy::expect_used)]

use super::channel::Chan;
use crate::util::error::{Error, Result};

/// What happens to the triggering flight.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultMode {
    /// Fail the send without putting anything on the wire.
    Kill,
    /// Swallow the send silently (lost frame), fail from the next op on.
    Drop,
    /// Ship an odd-length prefix of the frame, then die.
    Trunc,
    /// `std::process::abort()` — a real OS-level crash.
    Abort,
    /// Flip one bit of the triggering flight's payload and ship it;
    /// the sender keeps running (active tampering, not a crash).
    Tamper,
}

impl FaultMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultMode::Kill => "kill",
            FaultMode::Drop => "drop",
            FaultMode::Trunc => "trunc",
            FaultMode::Abort => "abort",
            FaultMode::Tamper => "tamper",
        }
    }

    /// Parse a scenario / CLI spelling.
    pub fn parse(s: &str) -> Result<FaultMode> {
        match s {
            "kill" => Ok(FaultMode::Kill),
            "drop" => Ok(FaultMode::Drop),
            "trunc" => Ok(FaultMode::Trunc),
            "abort" => Ok(FaultMode::Abort),
            "tamper" => Ok(FaultMode::Tamper),
            other => Err(Error::Config(format!(
                "unknown fault mode '{other}' (kill|drop|trunc|abort|tamper)"
            ))),
        }
    }
}

/// One scheduled fault: `mode` fires on the `at_flight`-th (1-based)
/// flight-opening send of the injected channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    pub at_flight: u64,
    pub mode: FaultMode,
}

/// Decision for the send that consulted the fault layer.
pub(crate) enum SendAction {
    Pass,
    Swallow,
    Truncate,
    Abort,
    /// Ship the frame with one payload bit flipped; the channel lives on.
    Tamper,
}

/// Live trigger state attached to a [`Chan`] (or a mux link).
#[derive(Debug, Clone)]
pub struct FaultState {
    plan: FaultPlan,
    /// Flight-opening sends observed so far.
    flights_seen: u64,
    /// True when the next send opens a new flight (mirrors the meter's
    /// round accounting exactly).
    flight_open: bool,
    /// Set once the fault fired: every later op fails.
    dead: bool,
}

impl FaultState {
    pub(crate) fn new(plan: FaultPlan) -> FaultState {
        FaultState { plan, flights_seen: 0, flight_open: true, dead: false }
    }

    pub(crate) fn plan(&self) -> FaultPlan {
        self.plan
    }

    pub(crate) fn closed_error(&self) -> Error {
        Error::ChannelClosed(format!(
            "injected fault: {} at flight {}",
            self.plan.mode.as_str(),
            self.plan.at_flight
        ))
    }

    fn trigger(&mut self) -> Result<SendAction> {
        match self.plan.mode {
            FaultMode::Kill => {
                self.dead = true;
                Err(self.closed_error())
            }
            FaultMode::Drop => {
                self.dead = true;
                Ok(SendAction::Swallow)
            }
            FaultMode::Trunc => {
                self.dead = true;
                Ok(SendAction::Truncate)
            }
            FaultMode::Abort => Ok(SendAction::Abort),
            // Active tampering: fire once, stay alive — detection (or
            // silent corruption) is the *receiving* stack's business.
            FaultMode::Tamper => Ok(SendAction::Tamper),
        }
    }

    /// Consulted before every channel send, ahead of any byte movement
    /// or metering.
    pub(crate) fn on_send(&mut self) -> Result<SendAction> {
        if self.dead {
            return Err(self.closed_error());
        }
        if self.flight_open {
            self.flight_open = false;
            self.flights_seen += 1;
            if self.flights_seen == self.plan.at_flight {
                return self.trigger();
            }
        }
        Ok(SendAction::Pass)
    }

    /// Consulted before every channel receive.
    pub(crate) fn on_recv(&mut self) -> Result<()> {
        if self.dead {
            return Err(self.closed_error());
        }
        self.flight_open = true;
        Ok(())
    }

    /// Mux-link variant: flights are a per-session notion there, so the
    /// link trigger counts every frame as one unit.
    pub(crate) fn on_link_send(&mut self) -> Result<SendAction> {
        if self.dead {
            return Err(self.closed_error());
        }
        self.flights_seen += 1;
        if self.flights_seen == self.plan.at_flight {
            return self.trigger();
        }
        Ok(SendAction::Pass)
    }
}

/// A [`Chan`] with an armed [`FaultPlan`] — the in-process face of the
/// fault layer. Deref gives the full channel API; the wrapper only
/// guarantees the plan is installed (and survives a gateway mux swap,
/// since the state rides the channel itself).
pub struct FaultyChan {
    inner: Chan,
}

impl FaultyChan {
    /// Arm `plan` on `chan`.
    pub fn new(mut chan: Chan, plan: FaultPlan) -> FaultyChan {
        chan.set_fault(plan);
        FaultyChan { inner: chan }
    }

    /// Disarm and return the bare channel.
    pub fn into_inner(mut self) -> Chan {
        self.inner.clear_fault();
        self.inner
    }
}

impl std::ops::Deref for FaultyChan {
    type Target = Chan;
    fn deref(&self) -> &Chan {
        &self.inner
    }
}

impl std::ops::DerefMut for FaultyChan {
    fn deref_mut(&mut self) -> &mut Chan {
        &mut self.inner
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::net::duplex_pair;
    use std::thread;

    #[test]
    fn kill_fires_on_the_exact_flight_and_meters_stay_clean() {
        let (c0, mut c1) = duplex_pair();
        let mut f0 = FaultyChan::new(c0, FaultPlan { at_flight: 2, mode: FaultMode::Kill });
        let h = thread::spawn(move || {
            // Flight 1: two sends in one flight, then a recv closes it.
            f0.try_send_bytes(&[1; 8]).unwrap();
            f0.try_send_bytes(&[2; 8]).unwrap();
            f0.try_recv_bytes().unwrap();
            // Flight 2: the opening send triggers the kill.
            let err = f0.try_send_bytes(&[3; 8]).unwrap_err();
            assert!(err.to_string().contains("injected fault"), "{err}");
            // Everything after is dead with the same typed error.
            assert!(f0.try_send_bytes(&[4; 8]).is_err());
            assert!(f0.try_recv_bytes().is_err());
            let m = f0.into_inner().into_meter();
            // Only flight 1 was metered: 2 msgs, 16 bytes, 1 round.
            assert_eq!(m.total().msgs_sent, 2);
            assert_eq!(m.total().bytes_sent, 16);
            assert_eq!(m.total().rounds, 1);
        });
        assert_eq!(c1.try_recv_bytes().unwrap(), vec![1; 8]);
        assert_eq!(c1.try_recv_bytes().unwrap(), vec![2; 8]);
        c1.try_send_bytes(&[9; 8]).unwrap();
        // The killed peer unwinds; our next receive observes the hangup.
        h.join().unwrap();
        assert!(c1.try_recv_bytes().is_err());
    }

    #[test]
    fn trunc_hands_the_peer_a_typed_protocol_error() {
        let (c0, mut c1) = duplex_pair();
        let mut f0 = FaultyChan::new(c0, FaultPlan { at_flight: 1, mode: FaultMode::Trunc });
        let h = thread::spawn(move || {
            let err = f0.try_send_bytes(&[7; 32]).unwrap_err();
            assert!(err.to_string().contains("injected fault"), "{err}");
        });
        // 32 bytes truncate to 17 — not a multiple of 8.
        let err = c1.try_recv_u64s().unwrap_err();
        assert!(err.to_string().contains("malformed u64 frame"), "{err}");
        h.join().unwrap();
    }

    #[test]
    fn drop_swallows_silently_then_fails_the_next_op() {
        let (c0, c1) = duplex_pair();
        let mut f0 = FaultyChan::new(c0, FaultPlan { at_flight: 1, mode: FaultMode::Drop });
        // The dropped send reports success (the caller cannot tell) …
        f0.try_send_bytes(&[1; 8]).unwrap();
        // … but the channel is dead from the next op on.
        assert!(f0.try_send_bytes(&[2; 8]).is_err());
        assert!(f0.try_recv_bytes().is_err());
        // Nothing reached the peer; dropping our end unblocks it.
        drop(f0);
        let mut c1 = c1;
        assert!(c1.try_recv_bytes().is_err());
    }

    #[test]
    fn flights_before_the_trigger_are_untouched() {
        let (c0, mut c1) = duplex_pair();
        let mut f0 = FaultyChan::new(c0, FaultPlan { at_flight: 100, mode: FaultMode::Kill });
        let h = thread::spawn(move || {
            for i in 0..5u64 {
                assert_eq!(f0.try_exchange_u64s(&[i]).unwrap(), vec![i * 10]);
            }
            f0.into_inner().into_meter()
        });
        for i in 0..5u64 {
            assert_eq!(c1.try_exchange_u64s(&[i * 10]).unwrap(), vec![i]);
        }
        let m = h.join().unwrap();
        assert_eq!(m.total().rounds, 5);
        assert_eq!(m.total().bytes_sent, 40);
    }

    #[test]
    fn mode_parse_roundtrips_and_rejects_garbage() {
        for m in [
            FaultMode::Kill,
            FaultMode::Drop,
            FaultMode::Trunc,
            FaultMode::Abort,
            FaultMode::Tamper,
        ] {
            assert_eq!(FaultMode::parse(m.as_str()).unwrap(), m);
        }
        assert!(FaultMode::parse("segv").is_err());
    }

    #[test]
    fn tamper_flips_one_bit_and_keeps_both_ends_alive() {
        let (c0, mut c1) = duplex_pair();
        let mut f0 = FaultyChan::new(c0, FaultPlan { at_flight: 2, mode: FaultMode::Tamper });
        let h = thread::spawn(move || {
            // Flight 1 passes clean.
            f0.try_send_bytes(&[0xAA; 8]).unwrap();
            f0.try_recv_bytes().unwrap();
            // Flight 2 is tampered but reports success, and the channel
            // stays usable afterwards.
            f0.try_send_bytes(&[0xAA; 8]).unwrap();
            f0.try_recv_bytes().unwrap();
            f0.try_send_bytes(&[0xBB; 8]).unwrap();
            f0.into_inner().into_meter()
        });
        assert_eq!(c1.try_recv_bytes().unwrap(), vec![0xAA; 8]);
        c1.try_send_bytes(&[1; 8]).unwrap();
        let tampered = c1.try_recv_bytes().unwrap();
        assert_ne!(tampered, vec![0xAA; 8], "flight 2 must arrive corrupted");
        let flipped: u32 = tampered
            .iter()
            .zip(&[0xAAu8; 8])
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(flipped, 1, "exactly one bit differs");
        c1.try_send_bytes(&[2; 8]).unwrap();
        assert_eq!(c1.try_recv_bytes().unwrap(), vec![0xBB; 8], "flight 3 clean again");
        let m = h.join().unwrap();
        // All three flights were metered normally — tampering is invisible
        // to the sender's accounting.
        assert_eq!(m.total().msgs_sent, 3);
        assert_eq!(m.total().bytes_sent, 24);
    }
}
