//! Assignment-only inference over a long-lived secret-shared model.
//!
//! A [`Scorer`] wraps one party's [`TrainedModel`] and scores streaming
//! micro-batches of transactions: per batch it runs S1 distance (the
//! tile path of the existing [`crate::kmeans::backend::BeaverBackend`])
//! and the S2 `F_min^k` comparison tree — **never** the S3 update — plus
//! the secure distance-threshold fraud flag of
//! [`crate::fraud::threshold`], then reveals assignment + flag in a
//! single exchange. The per-batch flight budget is exact and
//! data-independent ([`score_rounds`]):
//!
//! ```text
//! 1                      S1  (both cross-product reveals, one flight)
//! ⌈log₂k⌉·(CMP_ROUNDS+1) S2  (comparison tree)
//! CMP_ROUNDS             flag (one CMP against τ)
//! 1                      reveal (assignments + flags, one exchange)
//! ```
//!
//! The centroid-norm row `‖μ_j‖²` depends only on the model, so it is
//! computed **once** at [`Scorer::warmup`] and cached — every scored
//! batch then has the *same* offline demand (two tile-shaped matrix
//! triples plus the S2/flag lane chunks), which is what lets a
//! [`crate::offline::bank::MaterialBank`] prefabricate material
//! batch-by-batch.

use super::model::TrainedModel;
use crate::fraud::threshold::{encode_threshold_2f, flag_above};
use crate::kmeans::assign::{decode_one_hot_row, min_k_rounds};
use crate::kmeans::backend::{BeaverBackend, PartyData};
use crate::kmeans::esd;
use crate::kmeans::secure::assign_only_tile;
use crate::net::Chan;
use crate::ring::fixed::{encode_f64, FRAC_BITS};
use crate::ring::matrix::Mat;
use crate::ss::boolean::CMP_ROUNDS;
use crate::ss::triples::TripleSource;
use crate::ss::trunc::trunc_share;
use crate::ss::{Session, SessionOptions};
use crate::util::error::{Error, Result};
use crate::util::prng::Prg;

/// Exact online flights per scored micro-batch (any batch size): S1 +
/// `F_min^k` + the threshold CMP + the single reveal exchange. This is
/// the **assignment-only budget** — no S3 rounds — asserted by the
/// serving tests.
pub fn score_rounds(k: usize) -> u64 {
    1 + min_k_rounds(k) + CMP_ROUNDS + 1
}

/// One scored micro-batch, as revealed to both parties.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScoreResult {
    /// Cluster index per transaction.
    pub assignments: Vec<usize>,
    /// Secure distance-threshold fraud flag per transaction.
    pub fraud_flags: Vec<bool>,
    /// Reconstructed assignment rows that were not a valid one-hot
    /// vector (protocol corruption; counted, mapped to the first
    /// 1-entry or cluster 0 — same policy as training).
    pub malformed_rows: usize,
}

impl ScoreResult {
    /// Number of transactions flagged as fraud candidates.
    pub fn flagged(&self) -> usize {
        self.fraud_flags.iter().filter(|&&f| f).count()
    }
}

/// One party's streaming scorer over a trained model share.
pub struct Scorer {
    /// The persisted model share this scorer serves.
    pub model: TrainedModel,
    backend: BeaverBackend,
    /// Cached shared norm row `[‖μ_1‖², …, ‖μ_k‖²]` (1×k, scale 2f).
    u_row: Option<Mat>,
    tau_2f: u64,
    seed: u128,
    batches_scored: u64,
    refreshes_done: u32,
}

impl Scorer {
    /// Wrap a model share. `seed` feeds the per-batch mask PRG (any
    /// value; need not match the peer's).
    pub fn new(model: TrainedModel, seed: u128) -> Scorer {
        let backend = BeaverBackend::new(model.d_a, model.d);
        let tau_2f = encode_threshold_2f(model.tau);
        Scorer { model, backend, u_row: None, tau_2f, seed, batches_scored: 0, refreshes_done: 0 }
    }

    /// Rebuild a scorer from checkpointed state
    /// ([`crate::resume::ServeState`]): the already-warmed norm row and
    /// the batch/refresh counters that key every per-batch mask PRG and
    /// refresh dealer. No warmup flight runs — the resumed party picks
    /// up at batch `batches_scored` in wire lockstep with its peer.
    pub fn restore(
        model: TrainedModel,
        seed: u128,
        u_row: Mat,
        batches_scored: u64,
        refreshes_done: u32,
    ) -> Scorer {
        let backend = BeaverBackend::new(model.d_a, model.d);
        let tau_2f = encode_threshold_2f(model.tau);
        Scorer { model, backend, u_row: Some(u_row), tau_2f, seed, batches_scored, refreshes_done }
    }

    /// Whether [`Scorer::warmup`] has run.
    pub fn warmed_up(&self) -> bool {
        self.u_row.is_some()
    }

    /// The cached shared norm row (`None` before warmup) — snapshotted
    /// into serve checkpoints so a resumed scorer skips the warmup.
    pub fn u_row(&self) -> Option<&Mat> {
        self.u_row.as_ref()
    }

    /// Batches scored so far.
    pub fn batches_scored(&self) -> u64 {
        self.batches_scored
    }

    /// Centroid refreshes applied so far (keys the next refresh's
    /// dealer seed).
    pub fn refreshes_done(&self) -> u32 {
        self.refreshes_done
    }

    /// One-time shared computation of the centroid-norm row (one flight,
    /// metered as `serve.warmup`). Must run before the first
    /// [`Scorer::score_batch`]; keeping it out of the per-batch path is
    /// what makes every batch's round count and offline demand uniform.
    pub fn warmup(&mut self, chan: &mut Chan, ts: &mut dyn TripleSource) {
        let party = chan.party;
        // The session inherits the channel's tier: an armed channel
        // (malicious) folds this flight into the deferred MAC ledger.
        let opts = SessionOptions::with_security(chan.security());
        let mut ctx =
            Session::new(chan, ts, Prg::new(self.seed ^ ((party as u128) << 64) ^ 0x57A7), opts);
        ctx.set_phase("serve.warmup");
        let p = esd::centroid_norms_row_begin(&mut ctx, &self.model.mu_share);
        ctx.flush();
        self.u_row = Some(p.resolve(&mut ctx));
    }

    /// Score one micro-batch. `raw_block` is this party's **raw**
    /// (unnormalized) feature block, row-major `rows × ncols`; the
    /// scorer applies the training normalization stats locally. Both
    /// parties must call with the same batch size. Costs exactly
    /// [`score_rounds`]`(k)` flights and a fixed per-batch offline
    /// demand.
    pub fn score_batch(
        &mut self,
        chan: &mut Chan,
        ts: &mut dyn TripleSource,
        raw_block: &[f64],
    ) -> Result<ScoreResult> {
        let u_row = match &self.u_row {
            Some(u) => u.clone(),
            None => {
                return Err(Error::Config(
                    "Scorer::warmup must run once before score_batch".into(),
                ))
            }
        };
        let x_mat = self.model.normalize_block(raw_block)?;
        let rows = x_mat.rows;
        if rows == 0 {
            return Err(Error::Shape("empty micro-batch".into()));
        }
        // Local per-row ‖x_mine‖² (scale 2f): the term S1 drops from D'
        // but the true-distance threshold needs back.
        let my_norms: Vec<u64> = (0..rows)
            .map(|i| {
                x_mat
                    .row(i)
                    .iter()
                    .fold(0u64, |acc, &v| acc.wrapping_add(v.wrapping_mul(v)))
            })
            .collect();
        let x = PartyData::dense_only(x_mat);
        let party = chan.party;
        let batch_idx = self.batches_scored;
        self.batches_scored += 1;
        let opts = SessionOptions::with_security(chan.security());
        let mut ctx = Session::new(
            chan,
            ts,
            Prg::new(
                self.seed ^ ((party as u128) << 64) ^ ((batch_idx as u128) << 8) ^ 0x5C0E,
            ),
            opts,
        );

        // S1 + S2 via the assignment-only entry point (no S3).
        let (c_share, minvals) = assign_only_tile(
            &mut ctx,
            &mut self.backend,
            &x,
            &self.model.mu_share,
            &u_row,
            (0, rows),
            "serve.",
        );

        // Secure fraud flag: dist² = D'_min + ‖x_A‖² + ‖x_B‖² (each
        // party adds its own block's plaintext norms to its share), then
        // one CMP against the public τ — the candidates are decided
        // under MPC, not recomputed from revealed assignments.
        ctx.set_phase("serve.flag");
        let mut dist = minvals;
        for i in 0..rows {
            dist.data[i] = dist.data[i].wrapping_add(my_norms[i]);
        }
        let flags = flag_above(&mut ctx, &dist, self.tau_2f);

        // Reveal assignments + flags in ONE exchange flight.
        ctx.set_phase("serve.reveal");
        let k = self.model.k;
        let mut payload = Vec::with_capacity(rows * k + flags.words.len());
        payload.extend_from_slice(&c_share.data);
        payload.extend_from_slice(&flags.words);
        let theirs = ctx.chan.exchange_u64s(&payload);
        if theirs.len() != payload.len() {
            return Err(Error::ChannelClosed(format!(
                "score reveal: peer sent {} words, expected {}",
                theirs.len(),
                payload.len()
            )));
        }

        // Parse: one-hot rows (the training reveal's shared decoder and
        // malformed-row policy)…  Under the malicious tier a malformed
        // row is *expected* behaviour for a tampering peer — the batch
        // barrier right after this call aborts the loop with a typed
        // `Error::MacCheck` — so the debug assert only polices the
        // semi-honest path, where malformation means our own bug.
        let tolerate_malformed = ctx.chan.security().malicious();
        let mut malformed_rows = 0usize;
        let assignments: Vec<usize> = (0..rows)
            .map(|i| {
                let row: Vec<u64> = (0..k)
                    .map(|j| c_share.data[i * k + j].wrapping_add(theirs[i * k + j]))
                    .collect();
                let (idx, well_formed) = decode_one_hot_row(&row);
                if !well_formed {
                    malformed_rows += 1;
                    debug_assert!(
                        tolerate_malformed || well_formed,
                        "scored row {i} is not one-hot: {row:?}"
                    );
                }
                idx
            })
            .collect();
        // …and the XOR-shared flag bits.
        let fw = &theirs[rows * k..];
        let fraud_flags: Vec<bool> = (0..rows)
            .map(|i| ((flags.words[i / 64] ^ fw[i / 64]) >> (i % 64)) & 1 == 1)
            .collect();

        Ok(ScoreResult { assignments, fraud_flags, malformed_rows })
    }

    /// Incremental centroid refresh from recently scored traffic — the
    /// live-model half of crash resumability: a long-lived scorer tracks
    /// drifting fraud patterns without retraining or downtime.
    ///
    /// Assignments are *revealed* per batch, so both parties hold the
    /// identical public window partition; each party's raw feature block
    /// is its own plaintext. The per-cluster mean of the window
    /// restricted to this party's columns (zeros elsewhere) is therefore
    /// a valid **additive sharing** of the full recent-centroid matrix —
    /// no extra protocol needed to form it. The update is the streaming
    /// EWMA step
    ///
    /// ```text
    /// μ ← μ + α · (recent − μ)
    /// ```
    ///
    /// computed share-locally: the delta is a ring subtraction, the
    /// public-α product a local scale + [`trunc_share`]. Only the cached
    /// norm row must be recomputed jointly — one `serve.refresh` flight,
    /// the same shape as the warmup. A cluster with no window traffic
    /// keeps its centroid (both parties see the public count and zero
    /// that delta row symmetrically).
    ///
    /// `window_blocks[b]` is this party's raw feature block of window
    /// batch `b`, `window_assignments[b]` the revealed assignments of
    /// that batch. Both parties must call at the same point in the batch
    /// stream with the same window length and α.
    pub fn refresh(
        &mut self,
        chan: &mut Chan,
        ts: &mut dyn TripleSource,
        window_blocks: &[&[f64]],
        window_assignments: &[&[usize]],
        alpha: f64,
    ) -> Result<()> {
        if window_blocks.len() != window_assignments.len() || window_blocks.is_empty() {
            return Err(Error::Shape(format!(
                "refresh window holds {} blocks but {} assignment sets",
                window_blocks.len(),
                window_assignments.len()
            )));
        }
        let k = self.model.k;
        let nc = self.model.ncols();
        let (c0, d) = (self.model.col0(), self.model.d);
        // Public per-cluster counts + own-column sums over the window,
        // in *normalized* feature space (the space the centroids live
        // in).
        let mut counts = vec![0usize; k];
        let mut sums = vec![0.0f64; k * nc];
        for (block, assign) in window_blocks.iter().zip(window_assignments) {
            if nc == 0 || block.len() % nc != 0 || block.len() / nc != assign.len() {
                return Err(Error::Shape(format!(
                    "refresh window batch: {} raw values vs {} assignments over {nc} columns",
                    block.len(),
                    assign.len()
                )));
            }
            for (i, &j) in assign.iter().enumerate() {
                if j >= k {
                    return Err(Error::Protocol(format!(
                        "refresh window holds revealed assignment {j} but the model has k={k}"
                    )));
                }
                counts[j] += 1;
                for c in 0..nc {
                    let (lo, hi) = self.model.stats[c];
                    let v = block[i * nc + c];
                    sums[j * nc + c] += if hi > lo { (v - lo) / (hi - lo) } else { 0.0 };
                }
            }
        }

        // Share of (recent − μ): own columns carry the window mean minus
        // the own share; the peer's columns carry −share alone (the peer
        // contributes its mean there). Empty clusters keep a zero row on
        // both sides.
        let mu = &self.model.mu_share;
        let mut delta = Mat::zeros(k, d);
        for j in 0..k {
            if counts[j] == 0 {
                continue;
            }
            for c in 0..d {
                let own = c >= c0 && c < c0 + nc;
                let recent = if own {
                    encode_f64(sums[j * nc + (c - c0)] / counts[j] as f64)
                } else {
                    0
                };
                delta.data[j * d + c] = recent.wrapping_sub(mu.data[j * d + c]);
            }
        }

        // α is public: scale each share locally (f-scale α × f-scale
        // delta = 2f) and truncate back — zero communication.
        let alpha_f = encode_f64(alpha);
        for w in &mut delta.data {
            *w = w.wrapping_mul(alpha_f);
        }
        let step = trunc_share(chan.party, &delta, FRAC_BITS);
        for (m, s) in self.model.mu_share.data.iter_mut().zip(&step.data) {
            *m = m.wrapping_add(*s);
        }

        // The cached ‖μ_j‖² row is stale now — recompute it with one
        // warmup-shaped flight, keyed by the refresh index so resumed
        // and uninterrupted runs derive identical masks.
        let idx = self.refreshes_done;
        self.refreshes_done += 1;
        let party = chan.party;
        let opts = SessionOptions::with_security(chan.security());
        let mut ctx = Session::new(
            chan,
            ts,
            Prg::new(self.seed ^ ((party as u128) << 64) ^ ((idx as u128) << 32) ^ 0x4EF4),
            opts,
        );
        ctx.set_phase("serve.refresh");
        let p = esd::centroid_norms_row_begin(&mut ctx, &self.model.mu_share);
        ctx.flush();
        self.u_row = Some(p.resolve(&mut ctx));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ss::share::split;
    use crate::util::prng::Prg;

    /// Build a matched pair of models around known plaintext centroids
    /// (identity normalization, shares split randomly).
    fn model_pair(
        centroids: &[f64],
        k: usize,
        d: usize,
        d_a: usize,
        tau: f64,
    ) -> [TrainedModel; 2] {
        let mu = Mat::encode(k, d, centroids);
        let mut prg = Prg::new(0x0DE1);
        let (m0, m1) = split(&mu, &mut prg);
        let stats_a: Vec<(f64, f64)> = (0..d_a).map(|_| (0.0, 1.0)).collect();
        let stats_b: Vec<(f64, f64)> = (0..d - d_a).map(|_| (0.0, 1.0)).collect();
        [
            TrainedModel { party: 0, k, d, d_a, mu_share: m0, stats: stats_a, tau },
            TrainedModel { party: 1, k, d, d_a, mu_share: m1, stats: stats_b, tau },
        ]
    }

    #[test]
    fn scores_match_nearest_centroid_and_budget() {
        // Two well-separated centroids; four queries with known nearest
        // neighbours, one of them far from both (a fraud candidate).
        let centroids = [0.1, 0.1, 0.9, 0.9];
        let (k, d, d_a) = (2, 2, 1);
        let tau = 0.3; // squared-distance threshold
        let [ma, mb] = model_pair(&centroids, k, d, d_a, tau);
        // dist²(row3, c0) = 0.75² = 0.5625 < dist²(row3, c1) = 0.6425 → c0,
        // and 0.5625 > τ → flagged.
        let rows = [[0.12, 0.1], [0.88, 0.92], [0.1, 0.15], [0.85, 0.1]];
        let xa: Vec<f64> = rows.iter().map(|r| r[0]).collect();
        let xb: Vec<f64> = rows.iter().map(|r| r[1]).collect();
        let want_assign = vec![0usize, 1, 0, 0];
        let want_flags = vec![false, false, false, true];
        let ((got, m0), (_, m1)) = run_two_party(
            move |c| {
                let mut scorer = Scorer::new(ma, 0xA11CE);
                let mut src = Dealer::new(900, 0);
                scorer.warmup(c, &mut src);
                scorer.score_batch(c, &mut src, &xa).unwrap()
            },
            move |c| {
                let mut scorer = Scorer::new(mb, 0xB0B);
                let mut src = Dealer::new(900, 1);
                scorer.warmup(c, &mut src);
                scorer.score_batch(c, &mut src, &xb).unwrap()
            },
        );
        assert_eq!(got.assignments, want_assign);
        assert_eq!(got.fraud_flags, want_flags);
        assert_eq!(got.malformed_rows, 0);
        // Budget: warmup is 1 flight; the batch costs exactly
        // score_rounds(k) — and no S3 phase ever appears.
        assert_eq!(m0.get("serve.warmup").rounds, 1);
        let batch = m0.total_prefix("serve.").since(&m0.get("serve.warmup"));
        assert_eq!(batch.rounds, score_rounds(k));
        assert_eq!(m0.get("serve.s3").rounds, 0);
        assert_eq!(m0.get("online.s3").rounds, 0);
        assert_eq!(m1.get("serve.s3").rounds, 0);
    }

    #[test]
    fn score_before_warmup_is_rejected() {
        let [ma, mb] = model_pair(&[0.2, 0.2, 0.8, 0.8], 2, 2, 1, 1.0);
        let ((err, _), _) = run_two_party(
            move |c| {
                let mut scorer = Scorer::new(ma, 1);
                let mut src = Dealer::new(901, 0);
                scorer.score_batch(c, &mut src, &[0.5]).is_err()
            },
            move |c| {
                // Peer does nothing; the error side never communicates.
                let _ = (c, mb);
            },
        );
        assert!(err);
    }
}
