//! The serve loop: train once, then pump a transaction stream through
//! two long-lived scorers backed by replenished material banks.
//!
//! [`train_model`] runs the full secure training protocol and packages
//! each party's centroid share + normalization stats + fraud threshold
//! into a persistable [`TrainedModel`]. [`serve_stream`] then simulates
//! the deployed service: both party threads load their model (typically
//! from disk, via [`TrainedModel::load`]), warm up their scorer, learn
//! the per-batch offline [`Demand`] from a single recorded probe batch
//! (the repo's record-then-prefill idiom), stand up a
//! [`MaterialBank`], and score the stream micro-batch by micro-batch —
//! FIFO, with per-request phase metering (`serve.s1` / `serve.s2` /
//! `serve.flag` / `serve.reveal`) captured per batch via
//! [`PhaseStats::since`].

// The deployment loop faces a real peer over TCP: it must surface
// typed errors, never panic (ppkm-lint rule no-panic-in-wire-paths).
#![deny(clippy::unwrap_used, clippy::expect_used)]

use super::model::TrainedModel;
use super::scorer::{ScoreResult, Scorer};
use crate::data::blobs::Dataset;
use crate::data::normalize;
use crate::fraud::threshold::distance_threshold;
use crate::kmeans::config::{Partition, SecureKmeansConfig};
use crate::kmeans::secure::{self, PartyResult, SecureKmeansOutput};
use crate::net::cost::CostModel;
use crate::net::meter::{Meter, PhaseStats};
use crate::net::{run_two_party, Chan, Security};
use crate::offline::bank::{BankConfig, MaterialBank};
use crate::offline::dealer::{mac_key_share, Dealer};
use crate::offline::store::{Demand, TripleStore};
use crate::resume::{BankCounters, MeterSnapshot, Payload, ResumeCtx, ServeState, TrainState};
use crate::runtime::pool::Parallelism;
use crate::runtime::simd::Lanes;
use crate::util::error::{Error, Result};
use crate::util::timer::Timer;

/// Parameters of a serving run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Transactions per micro-batch (uniform across the stream — the
    /// precondition for a uniform per-batch offline demand).
    pub batch_rows: usize,
    /// Total micro-batches to score. The **first** batch doubles as the
    /// demand probe (served with inline generation while its exact
    /// demand is recorded); the remaining `batches − 1` are served from
    /// the bank.
    pub batches: usize,
    /// Bank stocking policy for the post-probe batches.
    pub bank: BankConfig,
    /// Seed for dealers and mask PRGs (public).
    pub seed: u128,
    /// Worker threads for party-local compute (bank prefabrication /
    /// replenishment and the per-batch plaintext-side products). Scores,
    /// reveals and meters are bit-identical for any value.
    pub parallelism: Parallelism,
    /// Packed-lane width for the crypto kernels
    /// ([`crate::runtime::simd`]): bank fabrication PRG draws and the
    /// per-batch axpy/truncation sweeps run this many lanes per step.
    /// Scores, reveals and meters are bit-identical for any value.
    pub lanes: Lanes,
    /// Optional deterministic link shaping
    /// ([`crate::net::shape::LinkShaper`]) for the serve loop's
    /// transport: per-batch wall-clock then *measures* compute + link
    /// instead of modeling the link afterwards. `None` (default) leaves
    /// the transport unshaped; scores, reveals and meters are identical
    /// either way.
    pub shape: Option<CostModel>,
    /// Refresh the centroid shares from recently scored traffic every
    /// this many batches (`0` disables refresh). Protocol-relevant —
    /// both parties must agree (the scenario layer digests it); a
    /// refresh adds one `serve.refresh` flight between the batches it
    /// separates and hot-swaps the updated model into the running
    /// scorer with zero dropped batches
    /// ([`crate::serve::scorer::Scorer::refresh`]).
    pub refresh_every: usize,
    /// Blend weight α of a refresh step: `μ ← μ + α·(recent − μ)`.
    /// Protocol-relevant; must match the peer's.
    pub refresh_alpha: f64,
    /// Adversary model of the serve loop. [`Security::Malicious`] arms
    /// the channel's deferred MAC ledger before the warmup flight and
    /// settles it in **one** batched barrier per scored batch
    /// (`serve.batch.{i}` — 3 fixed-size flights, metered under
    /// `mac.barrier`); [`Security::SemiHonest`] (default) is
    /// transcript-byte-identical to every release before the tier
    /// existed. Protocol-relevant; the scenario layer digests it.
    pub security: Security,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            batch_rows: 64,
            batches: 12,
            bank: BankConfig::default(),
            seed: 0x5E11E,
            parallelism: Parallelism::sequential(),
            lanes: Lanes::scalar(),
            shape: None,
            refresh_every: 0,
            refresh_alpha: 0.25,
            security: Security::SemiHonest,
        }
    }
}

/// Ledger-seed salt of the malicious serve loop: distinct from the
/// training salt so serve and train coefficient streams never alias
/// even when the two phases share a protocol seed.
const SERVE_MAC_LEDGER_SALT: u128 = 0x5EAC_1ED6_u128 << 64;

/// Per-batch serving metrics (party 0's view).
#[derive(Debug, Clone)]
pub struct BatchStats {
    /// Transactions in the batch.
    pub rows: usize,
    /// Fraud candidates flagged.
    pub flagged: usize,
    /// Online traffic of this batch alone (all `serve.*` phases).
    pub online: PhaseStats,
    /// Compute wall-clock of this batch, measured from **before**
    /// material checkout: the probe batch includes its inline triple
    /// generation, and a bank batch whose checkout triggered a
    /// synchronous replenishment includes that fabrication stall.
    pub wall_secs: f64,
}

/// Everything a bench or report needs from one serving run.
#[derive(Debug)]
pub struct ServeOutput {
    /// Revealed per-batch results (both parties see identical values).
    pub results: Vec<ScoreResult>,
    /// Per-batch traffic/wall metrics (batch 0 is the probe).
    pub batch_stats: Vec<BatchStats>,
    /// The recorded per-batch offline demand the bank was planned from.
    pub per_batch_demand: Demand,
    /// Traffic of the one-time scorer warmup (norm-row flight).
    pub warmup_stats: PhaseStats,
    /// Bank ledger: batches fabricated up front.
    pub bank_prefabricated: usize,
    /// Batches added by replenishment.
    pub bank_replenished: usize,
    /// Batches checked out.
    pub bank_consumed: usize,
    /// Batches left in stock at shutdown.
    pub bank_remaining: usize,
    /// Replenishment events.
    pub bank_replenish_events: usize,
    /// Checkouts that replenished synchronously **on the scoring path**
    /// — each one stalled a batch behind inline fabrication (the
    /// gateway's background replenishers exist to drive this to 0).
    pub bank_stalls: u64,
    /// Online draws that missed prefabricated stock (0 when planned
    /// correctly).
    pub bank_misses: u64,
    /// Matrix-triple bytes of one prefabricated batch.
    pub per_batch_mat_triple_bytes: u64,
    /// Number of clusters of the served model.
    pub k: usize,
    /// Transactions per micro-batch.
    pub batch_rows: usize,
    /// Party 0's full per-phase meter.
    pub meter_a: Meter,
    /// Party 1's full per-phase meter.
    pub meter_b: Meter,
}

/// Train on (raw) vertically partitioned data and package each party's
/// model artifact: centroid share, own-block normalization stats, and
/// the public fraud threshold (the `(1 − flag_rate)` quantile of
/// training distances). The returned [`SecureKmeansOutput`] still
/// carries the usual training telemetry.
pub fn train_model(
    data: &Dataset,
    cfg: &SecureKmeansConfig,
    flag_rate: f64,
) -> Result<(SecureKmeansOutput, [TrainedModel; 2])> {
    let d_a = match cfg.partition {
        Partition::Vertical { d_a } => d_a,
        Partition::Horizontal { .. } => {
            return Err(Error::Config(
                "the scoring service requires a vertical partition (each party \
                 holds its feature block of incoming transactions)"
                    .into(),
            ))
        }
    };
    let stats = normalize::column_stats(data);
    let normalized = normalize::min_max(data);
    let out = secure::run(&normalized, cfg)?;
    let tau = distance_threshold(&normalized, &out.centroids, &out.assignments, cfg.k, flag_rate);
    let models = [0usize, 1].map(|party| {
        let (c0, c1) = if party == 0 { (0, d_a) } else { (d_a, data.d) };
        TrainedModel {
            party,
            k: cfg.k,
            d: data.d,
            d_a,
            mu_share: out.centroid_shares[party].clone(),
            stats: stats[c0..c1].to_vec(),
            tau,
        }
    });
    Ok((out, models))
}

/// One party's serve-loop result: everything [`ServeOutput`] reports,
/// seen from a single endpoint — the unit a two-process deployment
/// exchanges nothing extra to produce (both parties reveal identical
/// scores, so each side's ledger stands alone).
pub struct ServePartyOutput {
    /// Revealed per-batch results (identical on both parties).
    pub results: Vec<ScoreResult>,
    /// Per-batch traffic/wall metrics (batch 0 is the probe).
    pub batch_stats: Vec<BatchStats>,
    /// The recorded per-batch offline demand the bank was planned from.
    pub per_batch_demand: Demand,
    /// Traffic of the one-time scorer warmup (norm-row flight).
    pub warmup_stats: PhaseStats,
    /// Bank ledger: batches fabricated up front.
    pub bank_prefabricated: usize,
    /// Batches added by replenishment.
    pub bank_replenished: usize,
    /// Batches checked out.
    pub bank_consumed: usize,
    /// Batches left in stock at shutdown.
    pub bank_remaining: usize,
    /// Replenishment events.
    pub bank_replenish_events: usize,
    /// Checkouts that replenished synchronously on the scoring path.
    pub bank_stalls: u64,
    /// Online draws that missed prefabricated stock (0 when planned
    /// correctly).
    pub bank_misses: u64,
    /// Matrix-triple bytes of one prefabricated batch.
    pub per_batch_mat_triple_bytes: u64,
}

/// Run **one party's** serve loop over any connected [`Chan`] backend:
/// warm the scorer, probe batch 0 for its exact offline demand, stand up
/// a replenished [`MaterialBank`], and score every block FIFO. This is
/// the deployment entry point — the in-process [`serve_stream`] drives
/// two of these over a duplex pair; a `ppkmeans party` process drives
/// one over TCP. `blocks` holds this party's **raw** feature block per
/// micro-batch (uniform size). Uses `cfg.bank`, `cfg.seed`,
/// `cfg.parallelism` and `cfg.shape`; the batch geometry is implied by
/// `blocks`.
pub fn serve_party(
    chan: &mut Chan,
    model: TrainedModel,
    blocks: Vec<Vec<f64>>,
    cfg: &ServeConfig,
) -> Result<ServePartyOutput> {
    serve_party_ckpt(chan, model, blocks, cfg, &mut ResumeCtx::disabled(), None)
}

/// Post-batch bookkeeping shared by the probe and the bank loop: apply
/// a centroid refresh when one is due (`cfg.refresh_every`, windowed
/// over the batches since the last refresh, never after the final
/// batch), then checkpoint the `serve.batch.{i}` site with the
/// **post-refresh** model so a resumed batch `i+1` scores against the
/// same centroids an uninterrupted run would.
#[allow(clippy::too_many_arguments)]
fn after_batch(
    chan: &mut Chan,
    cfg: &ServeConfig,
    blocks: &[Vec<f64>],
    i: usize,
    scorer: &mut Scorer,
    results: &[ScoreResult],
    batch_stats: &[BatchStats],
    per_batch: &Demand,
    bank: &MaterialBank<Dealer>,
    warmup: PhaseStats,
    rctx: &mut ResumeCtx,
) -> Result<()> {
    // Malicious tier: settle everything the batch put on the wire —
    // scores, reveals, any warmup still in the window — in one batched
    // check. Guarded so a semi-honest meter never grows the phase.
    if cfg.security.malicious() {
        chan.set_phase("mac.barrier");
        chan.mac_barrier(&format!("serve.batch.{i}"))?;
    }
    let every = cfg.refresh_every;
    if every > 0 && (i + 1) % every == 0 && i + 1 < blocks.len() {
        let w0 = i + 1 - every;
        let wb: Vec<&[f64]> = blocks[w0..=i].iter().map(|b| b.as_slice()).collect();
        let wa: Vec<&[usize]> =
            results[w0..=i].iter().map(|r| r.assignments.as_slice()).collect();
        // Each refresh draws from its own indexed dealer, independent of
        // the bank — the bank's uniform per-batch planning is untouched.
        let mut src = Dealer::new(
            cfg.seed ^ 0x44 ^ ((scorer.refreshes_done() as u128) << 16),
            chan.party,
        );
        scorer.refresh(chan, &mut src, &wb, &wa, cfg.refresh_alpha)?;
    }
    if rctx.enabled() {
        if let Some(u) = scorer.u_row() {
            let counters = BankCounters {
                prefabricated: bank.prefabricated as u64,
                replenished: bank.replenished as u64,
                consumed: bank.consumed as u64,
                replenish_events: bank.replenish_events as u64,
                stalls: bank.stalls,
            };
            rctx.save(
                &format!("serve.batch.{i}"),
                chan.meter(),
                Payload::Serve(ServeState {
                    model: scorer.model.to_bytes(),
                    u_row: u.clone(),
                    refreshes_done: scorer.refreshes_done(),
                    batches_scored: scorer.batches_scored() as u32,
                    per_batch: per_batch.clone(),
                    bank: counters,
                    warmup,
                    results: results.to_vec(),
                    stats: batch_stats
                        .iter()
                        .map(|s| (s.rows as u64, s.flagged as u64, s.online))
                        .collect(),
                }),
            );
        }
    }
    Ok(())
}

/// [`serve_party`] with crash resumability: checkpoint every scored
/// batch through `rctx` (`serve.batch.{i}` sites) and, when `resume`
/// carries a negotiated [`ServeState`], skip the warmup **and** the
/// demand probe — both were snapshotted — rebuild the bank to
/// bit-identical stock ([`MaterialBank::restore`]), and continue at
/// batch `batches_scored`. Both parties resume symmetrically, so the
/// wire stays in lockstep and the finished transcript matches an
/// uninterrupted run's byte for byte.
pub fn serve_party_ckpt(
    chan: &mut Chan,
    model: TrainedModel,
    blocks: Vec<Vec<f64>>,
    cfg: &ServeConfig,
    rctx: &mut ResumeCtx,
    resume: Option<ServeState>,
) -> Result<ServePartyOutput> {
    let party = chan.party;
    if cfg.security.malicious() {
        if rctx.enabled() || resume.is_some() {
            return Err(Error::Config(
                "resume: a malicious-tier serve loop cannot checkpoint or restore — the \
                 deferred MAC ledger does not survive a restart; rerun from scratch or \
                 drop to semi_honest"
                    .into(),
            ));
        }
        // Armed before the warmup flight so the whole serve transcript
        // rides the ledger (idempotent if training already armed it).
        chan.enable_mac(mac_key_share(cfg.seed, party), cfg.seed ^ SERVE_MAC_LEDGER_SALT);
    }
    let (bank_cfg, seed, threads) = (cfg.bank, cfg.seed, cfg.parallelism.threads);
    // Worker count for the per-batch plaintext-side products (see
    // runtime::pool) — scores and meters are thread-count independent.
    crate::runtime::pool::set_global_threads(threads);
    // Packed-lane width for the SIMD kernels — same contract.
    crate::runtime::simd::set_global_lanes(cfg.lanes.width);
    if let Some(link) = cfg.shape {
        chan.set_shaper(link);
    }

    // `t0` is taken by the caller BEFORE material checkout, so a batch
    // whose checkout triggers a synchronous replenishment is charged the
    // fabrication stall it actually caused.
    let score_one = |scorer: &mut Scorer,
                         chan: &mut Chan,
                         ts: &mut dyn crate::ss::triples::TripleSource,
                         block: &[f64],
                         t0: Timer|
     -> Result<(ScoreResult, BatchStats)> {
        let before = chan.meter().total_prefix("serve.");
        let r = scorer.score_batch(chan, ts, block)?;
        let wall = t0.secs();
        let online = chan.meter().total_prefix("serve.").since(&before);
        let stats =
            BatchStats { rows: r.assignments.len(), flagged: r.flagged(), online, wall_secs: wall };
        Ok((r, stats))
    };

    let mut results: Vec<ScoreResult>;
    let mut batch_stats: Vec<BatchStats>;
    let mut scorer: Scorer;
    let warmup_stats: PhaseStats;
    let per_batch: Demand;
    let mut bank: MaterialBank<Dealer>;
    let start: usize;

    match resume {
        None => {
            scorer = Scorer::new(model, seed ^ 0x5C0_0E);

            // One-time warmup: the shared norm row (material generated
            // inline — a single k·d-lane chunk).
            let mut warm_src = Dealer::new(seed ^ 0x11, party);
            scorer.warmup(chan, &mut warm_src);
            warmup_stats = chan.meter().get("serve.warmup");

            results = Vec::with_capacity(blocks.len());
            batch_stats = Vec::with_capacity(blocks.len());

            // Batch 0 — the demand probe: an empty recording store falls
            // through to inline generation while logging the exact
            // per-batch demand.
            let mut probe = TripleStore::new(Dealer::new(seed ^ 0x22, party));
            let t0 = Timer::started();
            let (r, s) = score_one(&mut scorer, chan, &mut probe, &blocks[0], t0)?;
            results.push(r);
            batch_stats.push(s);
            per_batch = probe.demand.clone();

            // The bank serves every remaining batch from prefabricated
            // stock; prefab and replenishment fan out across the worker
            // pool. Stood up *before* the probe's checkpoint so the
            // site's counters describe a real bank.
            bank = MaterialBank::new_par(
                Dealer::new(seed ^ 0x33, party),
                per_batch.clone(),
                bank_cfg,
                threads,
            );
            after_batch(
                chan,
                cfg,
                &blocks,
                0,
                &mut scorer,
                &results,
                &batch_stats,
                &per_batch,
                &bank,
                warmup_stats,
                rctx,
            )?;
            start = 1;
        }
        Some(st) => {
            let scored = st.batches_scored as usize;
            if scored == 0 || scored > blocks.len() {
                return Err(Error::Protocol(format!(
                    "serve resume: checkpoint says {scored} batches scored but this stream \
                     has {} — scenario and checkpoint disagree",
                    blocks.len()
                )));
            }
            warmup_stats = st.warmup;
            per_batch = st.per_batch;
            scorer = Scorer::restore(
                model,
                seed ^ 0x5C0_0E,
                st.u_row,
                scored as u64,
                st.refreshes_done,
            );
            bank = MaterialBank::restore(
                Dealer::new(seed ^ 0x33, party),
                per_batch.clone(),
                bank_cfg,
                threads,
                &st.bank,
            )?;
            results = st.results;
            batch_stats = st
                .stats
                .into_iter()
                .map(|(rows, flagged, online)| BatchStats {
                    rows: rows as usize,
                    flagged: flagged as usize,
                    online,
                    // Wall-clock is not persisted (transcripts exclude
                    // it); resumed batches report zero.
                    wall_secs: 0.0,
                })
                .collect();
            start = scored;
        }
    }

    for i in start..blocks.len() {
        let t0 = Timer::started();
        let ts = bank.checkout();
        let (r, s) = score_one(&mut scorer, chan, ts, &blocks[i], t0)?;
        results.push(r);
        batch_stats.push(s);
        after_batch(
            chan,
            cfg,
            &blocks,
            i,
            &mut scorer,
            &results,
            &batch_stats,
            &per_batch,
            &bank,
            warmup_stats,
            rctx,
        )?;
    }

    Ok(ServePartyOutput {
        results,
        batch_stats,
        per_batch_mat_triple_bytes: bank.per_batch_mat_triple_bytes(),
        per_batch_demand: per_batch,
        warmup_stats,
        bank_prefabricated: bank.prefabricated,
        bank_replenished: bank.replenished,
        bank_consumed: bank.consumed,
        bank_remaining: bank.stock(),
        bank_replenish_events: bank.replenish_events,
        bank_stalls: bank.stalls,
        bank_misses: bank.misses(),
    })
}

/// One-party analogue of [`train_model`] for two-process deployments:
/// run this party's side of secure training over `chan` and package
/// **its own** model artifact. Both processes hold the full raw
/// training set (synthetic from a negotiated seed, or pre-shared), so
/// the normalization stats and the public threshold τ come out
/// identical on each side — exactly as [`train_model`] computes them.
pub fn train_model_party(
    chan: &mut Chan,
    data: &Dataset,
    cfg: &SecureKmeansConfig,
    flag_rate: f64,
) -> Result<(PartyResult, TrainedModel)> {
    train_model_party_ckpt(chan, data, cfg, flag_rate, &mut ResumeCtx::disabled(), None)
}

/// [`train_model_party`] with crash resumability: Lloyd iterations
/// checkpoint through `rctx` (`train.iter.{i}` sites, see
/// [`crate::kmeans::secure::run_party_ckpt`]) and a negotiated
/// [`TrainState`] resumes mid-training. Normalization stats and τ are
/// recomputed locally — they are deterministic functions of the raw
/// data both processes already hold.
pub fn train_model_party_ckpt(
    chan: &mut Chan,
    data: &Dataset,
    cfg: &SecureKmeansConfig,
    flag_rate: f64,
    rctx: &mut ResumeCtx,
    resume: Option<(TrainState, MeterSnapshot)>,
) -> Result<(PartyResult, TrainedModel)> {
    let d_a = match cfg.partition {
        Partition::Vertical { d_a } => d_a,
        Partition::Horizontal { .. } => {
            return Err(Error::Config(
                "the scoring service requires a vertical partition (each party \
                 holds its feature block of incoming transactions)"
                    .into(),
            ))
        }
    };
    let stats = normalize::column_stats(data);
    let normalized = normalize::min_max(data);
    let r = secure::run_party_ckpt(chan, &normalized, cfg, rctx, resume)?;
    let tau = distance_threshold(&normalized, &r.mu.decode(), &r.assignments, cfg.k, flag_rate);
    let party = chan.party;
    let (c0, c1) = if party == 0 { (0, d_a) } else { (d_a, data.d) };
    let model = TrainedModel {
        party,
        k: cfg.k,
        d: data.d,
        d_a,
        mu_share: r.mu_share.clone(),
        stats: stats[c0..c1].to_vec(),
        tau,
    };
    Ok((r, model))
}

/// Serve a transaction stream with both parties' models: slices the
/// (raw, joint) stream into `batches × batch_rows` micro-batches, splits
/// each at the vertical boundary, and scores them FIFO against
/// per-party material banks. Returns party 0's view plus both meters.
pub fn serve_stream(
    models: [TrainedModel; 2],
    stream: &Dataset,
    cfg: &ServeConfig,
) -> Result<ServeOutput> {
    let [ma, mb] = models;
    if ma.d != stream.d {
        return Err(Error::Config(format!(
            "stream has d={} but the model was trained with d={}",
            stream.d, ma.d
        )));
    }
    if ma.k != mb.k || ma.d != mb.d || ma.d_a != mb.d_a {
        return Err(Error::Config("the two model shares disagree on geometry".into()));
    }
    if ma.party != 0 || mb.party != 1 {
        return Err(Error::Config(
            "serve_stream expects [party 0's share, party 1's share] in order".into(),
        ));
    }
    // τ is public and written identically into both artifacts at
    // training time, so a mismatch means the shares come from different
    // training runs — reconstructing such centroids yields silent
    // garbage, catch it here instead.
    if ma.tau != mb.tau {
        return Err(Error::Config(format!(
            "model shares disagree on τ ({} vs {}) — they come from different \
             training runs and would reconstruct garbage centroids",
            ma.tau, mb.tau
        )));
    }
    if cfg.batches == 0 || cfg.batch_rows == 0 {
        return Err(Error::Config("serving needs batches ≥ 1 and batch_rows ≥ 1".into()));
    }
    let need = cfg.batches * cfg.batch_rows;
    if stream.n < need {
        return Err(Error::Config(format!(
            "stream of {} transactions is shorter than {} batches × {} rows",
            stream.n, cfg.batches, cfg.batch_rows
        )));
    }
    // Pre-slice each batch into the two raw party blocks.
    let (d, d_a) = (stream.d, ma.d_a);
    let mut blocks_a: Vec<Vec<f64>> = Vec::with_capacity(cfg.batches);
    let mut blocks_b: Vec<Vec<f64>> = Vec::with_capacity(cfg.batches);
    for b in 0..cfg.batches {
        let mut xa = Vec::with_capacity(cfg.batch_rows * d_a);
        let mut xb = Vec::with_capacity(cfg.batch_rows * (d - d_a));
        for i in b * cfg.batch_rows..(b + 1) * cfg.batch_rows {
            let row = stream.row(i);
            xa.extend_from_slice(&row[..d_a]);
            xb.extend_from_slice(&row[d_a..]);
        }
        blocks_a.push(xa);
        blocks_b.push(xb);
    }
    let k = ma.k;
    let batch_rows = cfg.batch_rows;
    let cfg_a = cfg.clone();
    let cfg_b = cfg.clone();
    let ((ra, meter_a), (rb, meter_b)) = run_two_party(
        move |c| serve_party(c, ma, blocks_a, &cfg_a),
        move |c| serve_party(c, mb, blocks_b, &cfg_b),
    );
    let (ra, rb) = (ra?, rb?);
    debug_assert_eq!(ra.results, rb.results, "parties must reveal identical scores");
    debug_assert_eq!(ra.bank_misses + rb.bank_misses, 0, "planned banks must not miss");
    Ok(ServeOutput {
        results: ra.results,
        batch_stats: ra.batch_stats,
        per_batch_demand: ra.per_batch_demand,
        warmup_stats: ra.warmup_stats,
        bank_prefabricated: ra.bank_prefabricated,
        bank_replenished: ra.bank_replenished,
        bank_consumed: ra.bank_consumed,
        bank_remaining: ra.bank_remaining,
        bank_replenish_events: ra.bank_replenish_events,
        bank_stalls: ra.bank_stalls + rb.bank_stalls,
        bank_misses: ra.bank_misses + rb.bank_misses,
        per_batch_mat_triple_bytes: ra.per_batch_mat_triple_bytes,
        k,
        batch_rows,
        meter_a,
        meter_b,
    })
}
