//! The secure scoring service: train once, score forever.
//!
//! The paper's headline deployment is fraud detection — clustering is
//! trained jointly, then **incoming transactions are scored against the
//! learned clusters** without ever re-running the update step. This
//! subsystem is that product surface:
//!
//! * [`model`] — the persisted [`model::TrainedModel`] artifact: one
//!   party's additive centroid share + its own block's normalization
//!   stats + the public fraud threshold, in a versioned, checksummed
//!   binary format. Each party saves its share to disk and a later
//!   process resumes it; neither file alone reveals the centroids.
//! * [`scorer`] — assignment-only inference per micro-batch: S1
//!   distance through the existing tile-granular cross-product backend,
//!   S2 `F_min^k`, a secure distance-threshold fraud flag, and a single
//!   reveal exchange — exactly [`scorer::score_rounds`]`(k)` flights per
//!   batch, **no S3**.
//! * [`driver`] — [`driver::train_model`] packages training output into
//!   model artifacts; [`driver::serve_stream`] pumps a transaction
//!   stream through both parties' scorers backed by replenished
//!   [`crate::offline::bank::MaterialBank`]s, with per-request phase
//!   metering.
//! * [`gateway`] — the session-multiplexed service front:
//!   [`gateway::gateway_stream`] scores many concurrent sessions over a
//!   single party-pair link ([`crate::net::mux`]), backed by a sharded,
//!   background-replenished [`gateway::ShardedBank`] with admission
//!   control (typed `Error::Overload` backpressure).
//!
//! Reporting (latency/throughput under the LAN/WAN link models) lives in
//! [`crate::coordinator::serve`]; the `ppkmeans serve` / `ppkmeans
//! score` subcommands and `cargo bench --bench serving` drive it.

pub mod driver;
pub mod gateway;
pub mod model;
pub mod scorer;

pub use driver::{
    serve_party, serve_stream, train_model, train_model_party, ServeConfig, ServeOutput,
    ServePartyOutput,
};
pub use model::TrainedModel;
pub use scorer::{score_rounds, ScoreResult, Scorer};
