//! The scoring gateway: many concurrent sessions over one party link.
//!
//! The paper's deployment is a fraud-detection *service* — millions of
//! users, each an independent stream of transactions to score — while
//! [`crate::serve::driver::serve_stream`] pumps exactly one stream over
//! one channel. This subsystem closes that gap with three pieces:
//!
//! * **session mux** ([`crate::net::mux`]) — tagged frames carry many
//!   concurrent [`crate::serve::Scorer`] sessions over a single
//!   party-pair link; per-session meters still sum to the link totals;
//! * **sharded material bank** ([`bank::ShardedBank`]) — per-shard kit
//!   stock with work-stealing checkout and *background* replenishment
//!   on [`crate::runtime::pool`], overlapping fabrication with online
//!   scoring instead of stalling it;
//! * **admission control** ([`admitted_sessions`]) — a bounded session
//!   queue whose overflow is a typed [`Error::Overload`], never a
//!   panic (`no-panic-in-wire-paths` covers this subtree).
//!
//! ## Determinism contract
//!
//! Every per-session seed keys off the session **tag** alone
//! ([`session_seed`] / [`kit_seed`]), so a session's reveals, shares
//! and per-session meter are bit-identical whether it runs alone
//! (`sessions = 1`) or among `N` concurrent sessions — frames may
//! reorder on the wire, transcripts are per-session. Worker, shard and
//! replenisher counts are party-local throughput knobs.
//!
//! ## Wire compatibility
//!
//! The gateway extension is negotiated *before* the first tagged frame
//! by [`exchange_hello`] — ten plain `u64` words on the flat link, in
//! the same framed format as the PPKMWRE1 deployment handshake (see
//! `docs/PROTOCOLS.md`, "Gateway"). A peer that does not speak the
//! extension fails the magic check with a typed error instead of
//! misparsing tagged frames.

// Backpressure and peer misbehaviour surface as typed errors — the
// clippy deny backs ppkm-lint's no-panic-in-wire-paths at the type
// level, as in net/ and serve::driver.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod bank;
pub mod driver;

pub use bank::{BankLedger, ShardedBank};
pub use driver::{
    gateway_party, gateway_stream, GatewayOutput, GatewayStreamOutput, SessionReport,
};

use crate::net::cost::CostModel;
use crate::net::{Chan, Security};
use crate::offline::bank::BankConfig;
use crate::runtime::pool::Parallelism;
use crate::runtime::simd::Lanes;
use crate::util::error::{Error, Result};

/// Magic word opening the gateway hello: `"PPKMGWY1"` big-endian.
pub const GATEWAY_MAGIC: u64 = u64::from_be_bytes(*b"PPKMGWY1");

/// Version of the gateway hello / tagged-frame extension.
pub const GATEWAY_WIRE_VERSION: u64 = 1;

/// Parameters of a gateway run.
///
/// `sessions`, `queue`, `batches`, `batch_rows` and the bank stocking
/// policy are **protocol-relevant** (verified by [`exchange_hello`] and
/// digested into scenarios); `workers`, `replenishers`, `shards`,
/// `parallelism` and `lanes` are party-local throughput knobs — reveals
/// and per-session meters are bit-identical for any values.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Sessions offered to the gateway (the client-side demand).
    pub sessions: usize,
    /// Admission queue bound: at most this many sessions are admitted;
    /// the rest are refused with [`Error::Overload`]. `0` = unbounded.
    pub queue: usize,
    /// Concurrent scoring worker threads (party-local, ≥ 1).
    pub workers: usize,
    /// Background bank replenisher threads (party-local; `0` makes all
    /// replenishment inline on the scoring path, counted as stalls).
    pub replenishers: usize,
    /// Bank shards (party-local, ≥ 1); sessions map to shards
    /// round-robin in workload order.
    pub shards: usize,
    /// Transactions per micro-batch (uniform across sessions).
    pub batch_rows: usize,
    /// Micro-batches per session.
    pub batches: usize,
    /// Per-session kit stocking policy: `prefab_batches` kits up front,
    /// background refill of `refill_batches` whenever fewer than
    /// `low_water` kits are stocked-or-in-flight. `refill_batches = 0`
    /// disables replenishment: a dry session fails over to
    /// [`Error::Overload`].
    pub bank: BankConfig,
    /// Seed for all dealers and mask PRGs (public).
    pub seed: u128,
    /// Worker threads for party-local compute inside a batch.
    pub parallelism: Parallelism,
    /// Packed-lane width for the crypto kernels.
    pub lanes: Lanes,
    /// Optional deterministic link shaping of the shared link.
    pub shape: Option<CostModel>,
    /// Refresh each session's centroid shares from its own recently
    /// scored traffic every this many batches (`0` disables).
    /// Protocol-relevant like `seed`: both parties must pass the same
    /// value (the scenario layer digests it) — a refresh adds one
    /// `serve.refresh` flight on that session's channel and hot-swaps
    /// the updated model into the running scorer mid-session.
    pub refresh_every: usize,
    /// Blend weight α of a refresh step: `μ ← μ + α·(recent − μ)`.
    /// Protocol-relevant; must match the peer's.
    pub refresh_alpha: f64,
    /// Adversary model of the gateway run. [`Security::Malicious`] arms
    /// the flat link's MAC ledger before the hello (settled by one
    /// `gateway.done` barrier after mux teardown) and gives every
    /// admitted session its own tag-keyed ledger with one batched
    /// barrier per scored batch; [`Security::SemiHonest`] (default) is
    /// transcript-byte-identical to every release before the tier.
    /// Protocol-relevant: verified by [`exchange_hello`] and digested
    /// into scenarios — mismatched tiers would desync on the very
    /// first barrier.
    pub security: Security,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            sessions: 1,
            queue: 0,
            workers: 1,
            replenishers: 1,
            shards: 1,
            batch_rows: 32,
            batches: 4,
            bank: BankConfig::default(),
            seed: 0x6A7E_11E7,
            parallelism: Parallelism::sequential(),
            lanes: Lanes::scalar(),
            shape: None,
            refresh_every: 0,
            refresh_alpha: 0.25,
            security: Security::SemiHonest,
        }
    }
}

/// One client session's workload: a unique tag (≥ 1 — tag 0 is the
/// gateway's demand probe) and this party's raw feature block per
/// micro-batch.
#[derive(Debug, Clone)]
pub struct SessionWorkload {
    /// Session identity: the mux frame tag and the seed key. Must be
    /// unique per gateway run and non-zero.
    pub tag: u64,
    /// Raw (unnormalized) feature blocks, one per micro-batch, uniform
    /// `batch_rows × own-d` row-major.
    pub blocks: Vec<Vec<f64>>,
}

/// Sessions admitted under the queue bound: `min(offered, queue)`,
/// with `queue = 0` meaning unbounded. Pure in the protocol-relevant
/// inputs, so both parties admit the *same* prefix of the workload.
pub fn admitted_sessions(offered: usize, queue: usize) -> usize {
    if queue == 0 {
        offered
    } else {
        offered.min(queue)
    }
}

/// Base seed of one session's protocol randomness: the scorer's mask
/// PRG derives from `session_seed ^ 0x5C0_0E` and its warmup dealer
/// from `session_seed ^ 0x11` — tag-keyed, so a session's shares don't
/// depend on which other sessions run (`sessions = 1 ≡ sessions = N`).
pub fn session_seed(seed: u128, tag: u64) -> u128 {
    seed ^ ((tag as u128) << 96)
}

/// Dealer seed of one session-batch material kit. Tag and batch index
/// occupy disjoint bit ranges, so every kit across the whole gateway
/// run has a distinct, stateless seed — which is what lets *any*
/// worker or replenisher fabricate *any* kit (work-stealing) while the
/// two parties stay paired on correlated randomness.
pub fn kit_seed(seed: u128, tag: u64, batch: usize) -> u128 {
    session_seed(seed, tag) ^ ((batch as u128) << 40) ^ 0x6B17
}

/// Exchange and verify the gateway hello on the still-flat link (phase
/// `gateway.handshake`): ten words covering the magic, the extension
/// version, and every protocol-relevant knob. A disagreeing peer —
/// wrong magic/version, or a parameter mismatch that would desync the
/// two parties' admission or bank schedules (or pair a semi-honest
/// endpoint with a MAC-expecting one) — yields a typed
/// [`Error::Protocol`] before any tagged frame is sent.
pub fn exchange_hello(chan: &mut Chan, cfg: &GatewayConfig) -> Result<()> {
    chan.set_phase("gateway.handshake");
    let mine = [
        GATEWAY_MAGIC,
        GATEWAY_WIRE_VERSION,
        cfg.sessions as u64,
        cfg.queue as u64,
        cfg.batches as u64,
        cfg.batch_rows as u64,
        cfg.bank.prefab_batches as u64,
        cfg.bank.low_water as u64,
        cfg.bank.refill_batches as u64,
        cfg.security.malicious() as u64,
    ];
    let theirs = chan.try_exchange_u64s(&mine)?;
    if theirs.len() != mine.len() {
        return Err(Error::Protocol(format!(
            "gateway hello: peer sent {} words, expected {}",
            theirs.len(),
            mine.len()
        )));
    }
    if theirs[0] != GATEWAY_MAGIC {
        return Err(Error::Protocol(format!(
            "gateway hello: bad magic {:#018x} (peer does not speak the \
             tagged-frame extension)",
            theirs[0]
        )));
    }
    if theirs[1] != GATEWAY_WIRE_VERSION {
        return Err(Error::Protocol(format!(
            "gateway hello: peer speaks extension version {}, we speak {}",
            theirs[1], GATEWAY_WIRE_VERSION
        )));
    }
    let labels = [
        "sessions",
        "queue",
        "batches",
        "batch_rows",
        "prefab",
        "low_water",
        "refill",
        "security",
    ];
    for (i, label) in labels.iter().enumerate() {
        if theirs[2 + i] != mine[2 + i] {
            return Err(Error::Protocol(format!(
                "gateway hello: {label} mismatch (ours {}, peer {}) — the \
                 parties would desync admission or the bank schedule",
                mine[2 + i],
                theirs[2 + i]
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::net::duplex_pair;
    use crate::runtime::pool;

    #[test]
    fn admission_is_min_of_offered_and_queue() {
        assert_eq!(admitted_sessions(8, 0), 8, "queue 0 = unbounded");
        assert_eq!(admitted_sessions(8, 3), 3);
        assert_eq!(admitted_sessions(2, 3), 2);
    }

    #[test]
    fn seeds_are_distinct_across_sessions_and_batches() {
        let base = 0xABCD;
        let mut seen = std::collections::BTreeSet::new();
        for tag in 0..10u64 {
            assert!(seen.insert(session_seed(base, tag)));
            for batch in 0..10usize {
                assert!(seen.insert(kit_seed(base, tag, batch)), "tag {tag} batch {batch}");
            }
        }
    }

    #[test]
    fn hello_agrees_and_disagrees() {
        let cfg = GatewayConfig { sessions: 4, queue: 2, ..GatewayConfig::default() };
        let (mut c0, mut c1) = duplex_pair();
        let cfg_b = cfg.clone();
        let (a, b) = pool::run_pair(
            move || exchange_hello(&mut c0, &cfg).map(|()| true),
            move || exchange_hello(&mut c1, &cfg_b).map(|()| true),
        );
        assert!(a.unwrap() && b.unwrap());

        // A sessions mismatch must fail BOTH sides with a typed error.
        let (mut c0, mut c1) = duplex_pair();
        let ga = GatewayConfig { sessions: 4, ..GatewayConfig::default() };
        let gb = GatewayConfig { sessions: 5, ..GatewayConfig::default() };
        let (a, b) = pool::run_pair(
            move || exchange_hello(&mut c0, &ga),
            move || exchange_hello(&mut c1, &gb),
        );
        let msg = a.unwrap_err().to_string();
        assert!(msg.contains("sessions mismatch"), "{msg}");
        assert!(b.is_err());
    }
}
