//! Sharded, background-replenished material bank for the gateway.
//!
//! The in-process [`crate::offline::bank::MaterialBank`] replenishes
//! *synchronously inside checkout* — a dry bank blocks the serve loop.
//! The gateway instead stocks **kits**: one kit is the full offline
//! material of one `(session, batch)` micro-batch, fabricated from a
//! stateless dealer seeded by [`super::kit_seed`]. Because the seed is
//! a pure function of `(seed, tag, batch)`, *any* thread — a scoring
//! worker stealing fabrication inline, or a background replenisher on
//! [`crate::runtime::pool`] — produces the bit-identical kit, and the
//! two parties stay paired on correlated randomness no matter who
//! fabricates what, when.
//!
//! Sessions are assigned round-robin to **shards** (one lock + condvar
//! each), so concurrent checkouts on different shards never contend.
//! Per shard the exact ledger
//!
//! ```text
//! prefabricated + replenished − consumed == stock   (always)
//! ```
//!
//! holds under the shard lock at every instant (reserved-but-unbuilt
//! batches are tracked separately via `fab_next`), and the global
//! ledger is the shard sum — asserted by the interleaving regression
//! in `rust/tests/gateway.rs`.
//!
//! Checkout semantics per session (strictly in batch order):
//!
//! * kit stocked → pop it, count `consumed`;
//! * kit reserved by another thread → wait on the shard condvar
//!   (counted as a **stall**: the scoring path had to wait);
//! * kit unreserved → steal fabrication inline (also a stall), unless
//!   `refill_batches = 0`, in which case the dry bank is a typed
//!   [`Error::Overload`] — backpressure, never a panic.

use super::kit_seed;
use crate::offline::bank::BankConfig;
use crate::offline::dealer::Dealer;
use crate::offline::store::{Demand, TripleStore};
use crate::runtime::pool;
use crate::util::error::{Error, Result};
use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex, MutexGuard};

/// Lock a mutex, riding through poisoning: bank state mutates
/// atomically under the lock (counter bumps and queue inserts), so a
/// panicking peer thread leaves it consistent; the panic itself still
/// propagates through the pool's join.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// One session's kit stock inside a shard.
struct SessionStock {
    /// Fabricated, not-yet-consumed kits by batch index (BTreeMap per
    /// the no-unordered-iteration lint — stock reports iterate).
    kits: BTreeMap<usize, TripleStore<Dealer>>,
    /// Next batch index **not yet reserved** for fabrication. Batches
    /// in `consume_next..fab_next` are stocked or being fabricated.
    fab_next: usize,
    /// Next batch index the session will check out.
    consume_next: usize,
}

/// Mutable state of one shard, all under one lock.
struct ShardState {
    sessions: BTreeMap<u64, SessionStock>,
    prefabricated: u64,
    replenished: u64,
    consumed: u64,
    /// Checkouts that found their kit not ready (waited or fabricated
    /// inline) — the gateway analogue of the serve loop's bank stall.
    stalls: u64,
}

struct Shard {
    state: Mutex<ShardState>,
    /// Signalled when kits are inserted into this shard.
    cv: Condvar,
}

/// Replenisher coordination: a stop flag plus a work epoch bumped on
/// every checkout, so a parked replenisher can never miss a
/// stock-dropped event (it re-scans whenever the epoch moved).
struct WorkState {
    stop: bool,
    epoch: u64,
}

/// Exact stock ledger of a shard or of the whole bank.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BankLedger {
    /// Kits fabricated up front at construction.
    pub prefabricated: u64,
    /// Kits added after construction (background or inline-stolen).
    pub replenished: u64,
    /// Kits checked out.
    pub consumed: u64,
    /// Kits currently in stock.
    pub stock: u64,
    /// Checkouts that found their kit not ready.
    pub stalls: u64,
}

impl BankLedger {
    /// `prefabricated + replenished − consumed == stock`.
    pub fn balances(&self) -> bool {
        self.prefabricated + self.replenished == self.consumed + self.stock
    }

    fn merge(&mut self, o: &BankLedger) {
        self.prefabricated += o.prefabricated;
        self.replenished += o.replenished;
        self.consumed += o.consumed;
        self.stock += o.stock;
        self.stalls += o.stalls;
    }
}

/// The gateway's sharded, background-replenished kit bank.
pub struct ShardedBank {
    shards: Vec<Shard>,
    /// Session tag → shard index (workload order, round-robin).
    by_tag: BTreeMap<u64, usize>,
    per_batch: Demand,
    seed: u128,
    party: usize,
    cfg: BankConfig,
    /// Micro-batches per session (kit indices run `0..batches`).
    batches: usize,
    work: Mutex<WorkState>,
    work_cv: Condvar,
}

impl ShardedBank {
    /// Plan a bank for `tags` sessions of `batches` micro-batches each,
    /// and prefabricate `min(cfg.prefab_batches, batches)` kits per
    /// session on up to `threads` workers. The stocked material is
    /// bit-identical for any `threads`/`shards` value (stateless kit
    /// seeds), so the two parties may configure them independently.
    pub fn new(
        seed: u128,
        party: usize,
        per_batch: Demand,
        tags: &[u64],
        batches: usize,
        cfg: BankConfig,
        shards: usize,
        threads: usize,
    ) -> ShardedBank {
        let nshards = shards.max(1).min(tags.len().max(1));
        let prefab = cfg.prefab_batches.min(batches);
        let mut by_tag = BTreeMap::new();
        let mut states: Vec<ShardState> = (0..nshards)
            .map(|_| ShardState {
                sessions: BTreeMap::new(),
                prefabricated: 0,
                replenished: 0,
                consumed: 0,
                stalls: 0,
            })
            .collect();
        for (i, &tag) in tags.iter().enumerate() {
            let si = i % nshards;
            by_tag.insert(tag, si);
            states[si].sessions.insert(
                tag,
                SessionStock { kits: BTreeMap::new(), fab_next: prefab, consume_next: 0 },
            );
        }
        // Prefab fan-out: one flat job list over (tag, batch), expanded
        // in index order — output kits are position-independent anyway.
        let jobs: Vec<(u64, usize)> =
            tags.iter().flat_map(|&t| (0..prefab).map(move |b| (t, b))).collect();
        let kits = pool::parallel_gen(threads.max(1), jobs.len(), |i| {
            let (tag, batch) = jobs[i];
            fabricate_kit(seed, party, &per_batch, tag, batch)
        });
        for ((tag, batch), kit) in jobs.into_iter().zip(kits) {
            let si = by_tag[&tag];
            if let Some(ss) = states[si].sessions.get_mut(&tag) {
                ss.kits.insert(batch, kit);
                states[si].prefabricated += 1;
            }
        }
        ShardedBank {
            shards: states
                .into_iter()
                .map(|s| Shard { state: Mutex::new(s), cv: Condvar::new() })
                .collect(),
            by_tag,
            per_batch,
            seed,
            party,
            cfg,
            batches,
            work: Mutex::new(WorkState { stop: false, epoch: 0 }),
            work_cv: Condvar::new(),
        }
    }

    /// The planned per-batch demand.
    pub fn per_batch_demand(&self) -> &Demand {
        &self.per_batch
    }

    /// Check out session `tag`'s kit for `batch` (strictly sequential
    /// per session). Blocks while the kit is being fabricated
    /// elsewhere; steals fabrication inline when nobody has reserved
    /// it; returns [`Error::Overload`] if the bank is dry with
    /// replenishment disabled.
    pub fn checkout(&self, tag: u64, batch: usize) -> Result<TripleStore<Dealer>> {
        let si = *self
            .by_tag
            .get(&tag)
            .ok_or_else(|| Error::Offline(format!("bank knows no session {tag}")))?;
        let shard = &self.shards[si];
        let mut stalled = false;
        let mut g = lock(&shard.state);
        loop {
            let ss = g
                .sessions
                .get_mut(&tag)
                .ok_or_else(|| Error::Offline(format!("bank lost session {tag}")))?;
            if batch != ss.consume_next {
                return Err(Error::Offline(format!(
                    "session {tag}: out-of-order checkout of batch {batch} (next is {})",
                    ss.consume_next
                )));
            }
            if let Some(kit) = ss.kits.remove(&batch) {
                ss.consume_next += 1;
                g.consumed += 1;
                if stalled {
                    g.stalls += 1;
                }
                drop(g);
                // Stock dropped: wake the replenishers to re-scan.
                self.bump_epoch();
                return Ok(kit);
            }
            if ss.fab_next <= batch {
                // Unreserved and unstocked.
                if self.cfg.refill_batches == 0 {
                    return Err(Error::Overload(format!(
                        "session {tag}: material bank dry at batch {batch} and \
                         replenishment is disabled (refill_batches = 0)"
                    )));
                }
                // Steal fabrication inline: reserve the refill range so
                // no other thread duplicates it, build unlocked.
                let lo = ss.fab_next;
                let hi = (lo + self.cfg.refill_batches).min(self.batches);
                ss.fab_next = hi;
                drop(g);
                stalled = true;
                let kits: Vec<_> = (lo..hi)
                    .map(|b| fabricate_kit(self.seed, self.party, &self.per_batch, tag, b))
                    .collect();
                g = lock(&shard.state);
                if let Some(ss) = g.sessions.get_mut(&tag) {
                    for (b, kit) in (lo..hi).zip(kits) {
                        ss.kits.insert(b, kit);
                    }
                }
                g.replenished += (hi - lo) as u64;
                shard.cv.notify_all();
                // Loop back: our batch is in stock now.
            } else {
                // Reserved by another thread (background replenisher or
                // a stealing worker): wait for the insert.
                stalled = true;
                g = shard.cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        }
    }

    /// Body of one background replenisher thread (run it via
    /// [`crate::runtime::pool::run_workers`], alongside the scoring
    /// workers). Scans shards for sessions whose stocked-or-in-flight
    /// kit count fell below `low_water`, reserves a refill range,
    /// fabricates it unlocked, and parks on the work condvar when
    /// nothing needs doing. Returns after [`ShardedBank::stop`].
    pub fn replenish_loop(&self) {
        let mut seen = 0u64;
        loop {
            while let Some((si, tag, lo, hi)) = self.reserve_refill() {
                let kits: Vec<_> = (lo..hi)
                    .map(|b| fabricate_kit(self.seed, self.party, &self.per_batch, tag, b))
                    .collect();
                let shard = &self.shards[si];
                let mut g = lock(&shard.state);
                if let Some(ss) = g.sessions.get_mut(&tag) {
                    for (b, kit) in (lo..hi).zip(kits) {
                        ss.kits.insert(b, kit);
                    }
                }
                g.replenished += (hi - lo) as u64;
                shard.cv.notify_all();
            }
            let mut g = lock(&self.work);
            if g.stop {
                return;
            }
            if g.epoch == seen {
                g = self.work_cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner);
            }
            seen = g.epoch;
        }
    }

    /// Tell every parked replenisher to exit once no refill work is
    /// pending. Idempotent.
    pub fn stop(&self) {
        let mut g = lock(&self.work);
        g.stop = true;
        self.work_cv.notify_all();
    }

    /// Reserve the next refill job in deterministic shard/session scan
    /// order, or `None` when every session is stocked ahead of its
    /// low-water mark (or fully fabricated).
    fn reserve_refill(&self) -> Option<(usize, u64, usize, usize)> {
        if self.cfg.refill_batches == 0 || self.cfg.low_water == 0 {
            return None;
        }
        for (si, shard) in self.shards.iter().enumerate() {
            let mut g = lock(&shard.state);
            for (&tag, ss) in g.sessions.iter_mut() {
                let ahead = ss.fab_next - ss.consume_next;
                if ss.fab_next < self.batches && ahead < self.cfg.low_water {
                    let lo = ss.fab_next;
                    let hi = (lo + self.cfg.refill_batches).min(self.batches);
                    ss.fab_next = hi;
                    return Some((si, tag, lo, hi));
                }
            }
        }
        None
    }

    fn bump_epoch(&self) {
        let mut g = lock(&self.work);
        g.epoch = g.epoch.wrapping_add(1);
        self.work_cv.notify_all();
    }

    /// Per-shard ledgers, in shard order. Each balances at every
    /// instant (taken under the shard lock).
    pub fn shard_ledgers(&self) -> Vec<BankLedger> {
        self.shards
            .iter()
            .map(|s| {
                let g = lock(&s.state);
                BankLedger {
                    prefabricated: g.prefabricated,
                    replenished: g.replenished,
                    consumed: g.consumed,
                    stock: g.sessions.values().map(|ss| ss.kits.len() as u64).sum(),
                    stalls: g.stalls,
                }
            })
            .collect()
    }

    /// The global ledger (shard sum).
    pub fn ledger(&self) -> BankLedger {
        let mut total = BankLedger::default();
        for l in self.shard_ledgers() {
            total.merge(&l);
        }
        total
    }
}

/// Fabricate one `(tag, batch)` kit: a [`TripleStore`] prefilled with
/// the planned per-batch demand from the kit's stateless dealer.
fn fabricate_kit(
    seed: u128,
    party: usize,
    per_batch: &Demand,
    tag: u64,
    batch: usize,
) -> TripleStore<Dealer> {
    let mut store = TripleStore::new(Dealer::new(kit_seed(seed, tag, batch), party));
    store.prefill(per_batch);
    store
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::ss::triples::TripleSource;

    fn demand() -> Demand {
        let mut d = Demand::default();
        d.mat(4, 2, 3);
        d.vec_lanes(8);
        d
    }

    fn bank(tags: &[u64], batches: usize, cfg: BankConfig, shards: usize) -> ShardedBank {
        ShardedBank::new(0xBA4F, 0, demand(), tags, batches, cfg, shards, 1)
    }

    #[test]
    fn sequential_checkout_balances_and_never_misses() {
        let cfg = BankConfig { prefab_batches: 2, low_water: 0, refill_batches: 2 };
        let b = bank(&[1, 2, 3], 5, cfg, 2);
        assert_eq!(b.ledger().prefabricated, 6);
        for tag in [1u64, 2, 3] {
            for batch in 0..5 {
                let mut kit = b.checkout(tag, batch).unwrap();
                let _ = kit.mat_triple(4, 2, 3);
                let _ = kit.vec_triple(8);
                assert_eq!(kit.misses, 0, "tag {tag} batch {batch}");
            }
        }
        let l = b.ledger();
        assert!(l.balances(), "{l:?}");
        assert_eq!(l.consumed, 15);
        assert_eq!(l.prefabricated + l.replenished, 15 + l.stock);
        // low_water 0: every refill was an inline steal → stalls > 0.
        assert!(l.stalls > 0);
    }

    #[test]
    fn kits_match_across_parties_and_fabricators() {
        // Party 0 checks out via inline stealing (prefab 0); party 1 has
        // everything prefabricated. The correlated randomness must still
        // pair: u·v == z across the two shares.
        let steal = BankConfig { prefab_batches: 0, low_water: 0, refill_batches: 1 };
        let stock = BankConfig { prefab_batches: 3, low_water: 0, refill_batches: 1 };
        let b0 = ShardedBank::new(0xBA4F, 0, demand(), &[9], 3, steal, 1, 1);
        let b1 = ShardedBank::new(0xBA4F, 1, demand(), &[9], 3, stock, 1, 2);
        for batch in 0..3 {
            let t0 = b0.checkout(9, batch).unwrap().vec_triple(8);
            let t1 = b1.checkout(9, batch).unwrap().vec_triple(8);
            for i in 0..8 {
                let u = t0.u[i].wrapping_add(t1.u[i]);
                let v = t0.v[i].wrapping_add(t1.v[i]);
                let z = t0.z[i].wrapping_add(t1.z[i]);
                assert_eq!(u.wrapping_mul(v), z, "batch {batch} lane {i}");
            }
        }
    }

    #[test]
    fn dry_bank_without_refill_is_a_typed_overload() {
        let cfg = BankConfig { prefab_batches: 1, low_water: 0, refill_batches: 0 };
        let b = bank(&[5], 3, cfg, 1);
        assert!(b.checkout(5, 0).is_ok());
        let err = b.checkout(5, 1).unwrap_err();
        assert!(matches!(err, Error::Overload(_)), "{err}");
        assert!(err.to_string().contains("replenishment is disabled"), "{err}");
        assert!(b.ledger().balances());
    }

    #[test]
    fn out_of_order_and_unknown_sessions_are_typed_errors() {
        let cfg = BankConfig { prefab_batches: 2, low_water: 0, refill_batches: 1 };
        let b = bank(&[7], 2, cfg, 1);
        assert!(b.checkout(8, 0).unwrap_err().to_string().contains("no session"));
        assert!(b.checkout(7, 1).unwrap_err().to_string().contains("out-of-order"));
    }

    #[test]
    fn background_replenisher_keeps_the_scoring_path_stall_free() {
        // One replenisher thread races the consumer; with a generous
        // low-water mark it fabricates ahead, so checkouts (which only
        // start after the initial prefab) never stall.
        let cfg = BankConfig { prefab_batches: 2, low_water: 2, refill_batches: 2 };
        let b = bank(&[1], 12, cfg, 1);
        let done: Vec<Result<()>> = pool::run_workers("bankt", 2, |i| {
            if i == 0 {
                // Stop the replenisher even on error, or the join hangs.
                let r = (0..12).try_for_each(|batch| b.checkout(1, batch).map(drop));
                b.stop();
                r
            } else {
                b.replenish_loop();
                Ok(())
            }
        });
        assert!(done.into_iter().all(|r| r.is_ok()));
        let l = b.ledger();
        assert!(l.balances(), "{l:?}");
        assert_eq!(l.consumed, 12);
        assert_eq!(l.prefabricated, 2);
        assert_eq!(l.replenished, 10 + l.stock);
    }
}
