//! The gateway driver: admit, multiplex, and score many sessions
//! concurrently over one party-pair link.
//!
//! [`gateway_party`] is one party's endpoint (any connected
//! [`Chan`] backend — the in-process [`gateway_stream`] drives two over
//! a duplex pair, `ppkmeans gateway` drives one over TCP):
//!
//! 1. **hello** — [`super::exchange_hello`] verifies both parties agree
//!    on every protocol-relevant knob, on the still-flat link;
//! 2. **admission** — the first [`super::admitted_sessions`] workloads
//!    are admitted, the rest refused (reported, typed
//!    [`Error::Overload`] semantics — never a panic);
//! 3. **probe** — one throwaway scoring of the first workload's first
//!    block against a recording [`TripleStore`] learns the exact
//!    per-batch offline [`Demand`] (the repo's record-then-prefill
//!    idiom), still on the flat link, seeded under the reserved tag 0;
//! 4. **mux** — the link becomes a [`MuxLink`]; every admitted session's
//!    sub-channel is opened *up front* (a frame addressed to an
//!    unregistered tag would kill the link), then `workers` scoring
//!    threads pull sessions off a shared cursor while `replenishers`
//!    background threads keep the [`ShardedBank`] stocked;
//! 5. **teardown** — the last scoring worker stops the replenishers,
//!    [`MuxLink::finish`] reassembles the flat channel (leftover frames
//!    in any inbox are a typed protocol error), and the caller's `Chan`
//!    is usable again — the coordinator's closing barrier runs on it.
//!
//! Per-session transcripts are bit-identical for any `workers` /
//! `replenishers` / `shards` / `sessions` mix (tag-keyed seeds,
//! per-session meters) — the determinism regressions live in
//! `rust/tests/gateway.rs`.

use super::bank::{BankLedger, ShardedBank};
use super::{admitted_sessions, exchange_hello, session_seed, GatewayConfig, SessionWorkload};
use crate::data::blobs::Dataset;
use crate::net::meter::{Meter, PhaseStats};
use crate::net::mux::MuxLink;
use crate::net::{duplex_pair, run_two_party, Chan};
use crate::offline::dealer::{mac_key_share, Dealer};
use crate::offline::store::{Demand, TripleStore};
use crate::runtime::pool;
use crate::serve::model::TrainedModel;
use crate::serve::scorer::{ScoreResult, Scorer};
use crate::util::error::{Error, Result};
use crate::util::timer::Timer;
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Ledger-seed salt of the malicious gateway: the flat link's ledger
/// uses `cfg.seed ^ SALT`; each session's uses its tag-keyed
/// [`session_seed`]` ^ SALT`, so no two coefficient streams in a run
/// alias (and none alias the train/serve salts).
const GATEWAY_MAC_LEDGER_SALT: u128 = 0x6AC7_1ED6_u128 << 64;

/// One admitted session's complete outcome, as seen by one party.
#[derive(Debug, Clone)]
pub struct SessionReport {
    /// The session's tag (mux frame tag, seed key).
    pub tag: u64,
    /// Revealed per-batch results (identical on both parties).
    pub results: Vec<ScoreResult>,
    /// This session's complete online traffic — its own meter total
    /// (warmup + every batch), tag bytes included. Summed over all
    /// sessions this equals the link's `gateway.mux` byte/msg totals.
    pub online: PhaseStats,
    /// Wall-clock from the session's warmup to its last reveal, as
    /// scheduled on this party (includes any bank stalls it hit).
    pub wall_secs: f64,
    /// Offline-store draws that missed prefabricated stock (0 when the
    /// probe demand matched — asserted in benches).
    pub misses: u64,
}

/// Everything one party's gateway run produces.
#[derive(Debug)]
pub struct GatewayOutput {
    /// Per admitted session, in workload order: its tag and its outcome.
    /// A failed session (e.g. bank dry with replenishment disabled —
    /// [`Error::Overload`]) aborts deterministically at the same batch
    /// boundary on both parties; the others keep scoring.
    pub sessions: Vec<(u64, Result<SessionReport>)>,
    /// Tags refused at admission (offered beyond the queue bound).
    pub rejected: Vec<u64>,
    /// The probe-recorded per-batch offline demand the bank was planned
    /// from (this party's own draws).
    pub per_batch_demand: Demand,
    /// The bank's global ledger at teardown
    /// (`prefabricated + replenished − consumed == stock`).
    pub ledger: BankLedger,
    /// Wall-clock of the whole run (hello through mux teardown).
    pub wall_secs: f64,
}

impl GatewayOutput {
    /// Sessions admitted (scored or deterministically aborted).
    pub fn admitted(&self) -> usize {
        self.sessions.len()
    }

    /// Total offline-store misses across all admitted sessions.
    pub fn misses(&self) -> u64 {
        self.sessions
            .iter()
            .filter_map(|(_, r)| r.as_ref().ok())
            .map(|s| s.misses)
            .sum()
    }

    /// Sum of all per-session online meters — must equal the link's
    /// `gateway.mux` totals byte-for-byte (regression-tested).
    pub fn online_total(&self) -> PhaseStats {
        let mut sum = PhaseStats::default();
        for (_, r) in &self.sessions {
            if let Ok(s) = r {
                sum.merge(&s.online);
            }
        }
        sum
    }
}

/// Decrements the live-worker count on scope exit — panic or return —
/// and stops the bank replenishers when the last scoring worker leaves,
/// so the `run_workers` join can never hang on a parked replenisher.
struct StopGuard<'a> {
    bank: &'a ShardedBank,
    active: &'a AtomicUsize,
}

impl Drop for StopGuard<'_> {
    fn drop(&mut self) {
        if self.active.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.bank.stop();
        }
    }
}

/// Run **one party's** gateway over any connected [`Chan`]. `workloads`
/// holds every *offered* session (unique non-zero tags, `cfg.batches`
/// raw feature blocks each, this party's vertical slice); admission
/// keeps the first [`admitted_sessions`]`(cfg.sessions, cfg.queue)` of
/// them. On success the channel is flat again (post-[`MuxLink::finish`])
/// and the caller may keep using it.
pub fn gateway_party(
    chan: &mut Chan,
    model: TrainedModel,
    workloads: Vec<SessionWorkload>,
    cfg: &GatewayConfig,
) -> Result<GatewayOutput> {
    if cfg.sessions == 0 || cfg.batches == 0 || cfg.batch_rows == 0 {
        return Err(Error::Config(
            "gateway needs sessions ≥ 1, batches ≥ 1 and batch_rows ≥ 1".into(),
        ));
    }
    if workloads.len() != cfg.sessions {
        return Err(Error::Config(format!(
            "gateway offered {} workloads but cfg.sessions = {}",
            workloads.len(),
            cfg.sessions
        )));
    }
    let mut tags = BTreeSet::new();
    for w in &workloads {
        if w.tag == 0 {
            return Err(Error::Config("session tag 0 is reserved for the demand probe".into()));
        }
        if !tags.insert(w.tag) {
            return Err(Error::Config(format!("duplicate session tag {}", w.tag)));
        }
        if w.blocks.len() != cfg.batches {
            return Err(Error::Config(format!(
                "session {} offers {} blocks but cfg.batches = {}",
                w.tag,
                w.blocks.len(),
                cfg.batches
            )));
        }
    }
    let party = chan.party;
    let threads = cfg.parallelism.threads;
    crate::runtime::pool::set_global_threads(threads);
    crate::runtime::simd::set_global_lanes(cfg.lanes.width);
    if let Some(link) = cfg.shape {
        chan.set_shaper(link);
    }
    let wall = Timer::started();

    // Malicious tier: arm the flat link's ledger before the hello, so
    // the hello and the tag-0 demand probe both ride it — settled by
    // the one `gateway.done` barrier after mux teardown. (Idempotent
    // when an earlier phase on this channel already armed it.)
    if cfg.security.malicious() {
        chan.enable_mac(
            mac_key_share(cfg.seed, party),
            cfg.seed ^ GATEWAY_MAC_LEDGER_SALT,
        );
    }

    // 1. Hello: agree on every protocol-relevant knob or die typed.
    exchange_hello(chan, cfg)?;

    // 2. Admission: both parties compute the same split (pure in the
    //    hello-verified parameters).
    let admitted = admitted_sessions(cfg.sessions, cfg.queue);
    let rejected: Vec<u64> = workloads[admitted..].iter().map(|w| w.tag).collect();
    let admitted_wl = &workloads[..admitted];

    // 3. Demand probe under the reserved tag 0, still on the flat link:
    //    a recording store logs the exact per-batch demand while the
    //    probe batch generates its material inline.
    let probe_seed = session_seed(cfg.seed, 0);
    let mut probe_scorer = Scorer::new(model.clone(), probe_seed ^ 0x5C0_0E);
    let mut probe_warm = Dealer::new(probe_seed ^ 0x11, party);
    probe_scorer.warmup(chan, &mut probe_warm);
    let mut probe = TripleStore::new(Dealer::new(probe_seed ^ 0x22, party));
    probe_scorer.score_batch(chan, &mut probe, &admitted_wl[0].blocks[0])?;
    let per_batch = probe.demand.clone();

    // 4. Bank + mux + workers.
    let admitted_tags: Vec<u64> = admitted_wl.iter().map(|w| w.tag).collect();
    let bank = ShardedBank::new(
        cfg.seed,
        party,
        per_batch.clone(),
        &admitted_tags,
        cfg.batches,
        cfg.bank,
        cfg.shards,
        threads,
    );
    // Swap the caller's channel for a placeholder while the mux owns
    // the link; finish() puts the flat channel back.
    let (placeholder, _spare) = duplex_pair();
    let link = std::mem::replace(chan, placeholder);
    let mux = MuxLink::new(link)?;
    // Pre-open EVERY admitted session before any worker sends: a frame
    // arriving for an unregistered tag kills the link.
    let mut slots: Vec<Mutex<Option<Chan>>> = Vec::with_capacity(admitted);
    for tag in &admitted_tags {
        slots.push(Mutex::new(Some(mux.session(*tag)?)));
    }

    let workers = cfg.workers.max(1);
    let cursor = AtomicUsize::new(0);
    let active = AtomicUsize::new(workers);
    let seed = cfg.seed;
    let model_ref = &model;
    let bank_ref = &bank;
    let slots_ref = &slots;
    let bodies = pool::run_workers("gw", workers + cfg.replenishers, |i| {
        if i >= workers {
            bank_ref.replenish_loop();
            return Vec::new();
        }
        let _guard = StopGuard { bank: bank_ref, active: &active };
        let score_session = |idx: usize| -> Result<SessionReport> {
            let w = &admitted_wl[idx];
            let mut sch = slots_ref[idx]
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .take()
                .ok_or_else(|| {
                    Error::Runtime(format!("session {} channel claimed twice", w.tag))
                })?;
            let t0 = Timer::started();
            let s_seed = session_seed(seed, w.tag);
            // Each session runs its own tag-keyed ledger (the flat
            // link's is parked inside the mux), so a session's barrier
            // schedule is independent of which other sessions run —
            // same invariance the seeds already guarantee.
            if cfg.security.malicious() {
                sch.enable_mac(
                    mac_key_share(s_seed, party),
                    s_seed ^ GATEWAY_MAC_LEDGER_SALT,
                );
            }
            let mut scorer = Scorer::new(model_ref.clone(), s_seed ^ 0x5C0_0E);
            let mut warm = Dealer::new(s_seed ^ 0x11, party);
            scorer.warmup(&mut sch, &mut warm);
            let mut results = Vec::with_capacity(w.blocks.len());
            let mut misses = 0u64;
            for (b, block) in w.blocks.iter().enumerate() {
                let mut kit = bank_ref.checkout(w.tag, b)?;
                results.push(scorer.score_batch(&mut sch, &mut kit, block)?);
                misses += kit.misses;
                // One batched ledger check per scored batch — 3 fixed-
                // size flights on this session's sub-channel.
                if cfg.security.malicious() {
                    sch.set_phase("mac.barrier");
                    sch.mac_barrier(&format!("gateway.tag{}.batch.{b}", w.tag))?;
                }
                // Per-session live refresh: hot-swap the centroids from
                // this session's own recent window, mid-stream and
                // without dropping a batch. Material comes from a
                // session+refresh-keyed dealer, not the kit bank, so the
                // bank's uniform per-batch planning is untouched.
                let every = cfg.refresh_every;
                if every > 0 && (b + 1) % every == 0 && b + 1 < w.blocks.len() {
                    let w0 = b + 1 - every;
                    let wb: Vec<&[f64]> =
                        w.blocks[w0..=b].iter().map(|bl| bl.as_slice()).collect();
                    let wa: Vec<&[usize]> =
                        results[w0..=b].iter().map(|r| r.assignments.as_slice()).collect();
                    let mut src = Dealer::new(
                        s_seed ^ 0x44 ^ ((scorer.refreshes_done() as u128) << 16),
                        party,
                    );
                    scorer.refresh(&mut sch, &mut src, &wb, &wa, cfg.refresh_alpha)?;
                }
            }
            Ok(SessionReport {
                tag: w.tag,
                results,
                online: sch.into_meter().total(),
                wall_secs: t0.secs(),
                misses,
            })
        };
        let mut out = Vec::new();
        loop {
            let idx = cursor.fetch_add(1, Ordering::Relaxed);
            if idx >= admitted {
                return out;
            }
            out.push((idx, admitted_wl[idx].tag, score_session(idx)));
        }
    });

    // Collect per-session outcomes back into workload order.
    let mut by_idx: Vec<Option<(u64, Result<SessionReport>)>> =
        (0..admitted).map(|_| None).collect();
    for worker_out in bodies {
        for (idx, tag, r) in worker_out {
            by_idx[idx] = Some((tag, r));
        }
    }
    let sessions: Vec<(u64, Result<SessionReport>)> = by_idx
        .into_iter()
        .enumerate()
        .map(|(idx, slot)| {
            slot.unwrap_or_else(|| {
                let tag = admitted_wl[idx].tag;
                (tag, Err(Error::Runtime(format!("session {tag} was never scheduled"))))
            })
        })
        .collect();

    // 5. Teardown: an aborted session may have left its channel in its
    //    slot — drop the slots so the mux is uniquely owned again.
    drop(slots);
    let ledger = bank.ledger();
    *chan = mux.finish()?;
    chan.set_phase("gateway.done");
    // Settle the flat link's ledger (hello + demand probe): the parked
    // MacAcc came back with the channel from `finish`.
    if cfg.security.malicious() {
        chan.set_phase("mac.barrier");
        chan.mac_barrier("gateway.done")?;
        chan.set_phase("gateway.done");
    }

    Ok(GatewayOutput {
        sessions,
        rejected,
        per_batch_demand: per_batch,
        ledger,
        wall_secs: wall.secs(),
    })
}

/// Both parties' view of one in-process gateway run.
#[derive(Debug)]
pub struct GatewayStreamOutput {
    /// Party 0's gateway output.
    pub a: GatewayOutput,
    /// Party 1's gateway output (identical reveals, own meters/ledger).
    pub b: GatewayOutput,
    /// Party 0's full link meter (handshake, probe, `gateway.mux`).
    pub meter_a: Meter,
    /// Party 1's full link meter.
    pub meter_b: Meter,
}

/// Drive a full two-party gateway in process: slice the (raw, joint)
/// `stream` into `sessions × batches × batch_rows` micro-batches —
/// consecutive row chunks per session, tags `1..=sessions`, split at
/// the vertical boundary — and run [`gateway_party`] on both ends of a
/// duplex pair. The in-process analogue of two `ppkmeans gateway`
/// processes.
pub fn gateway_stream(
    models: [TrainedModel; 2],
    stream: &Dataset,
    cfg: &GatewayConfig,
) -> Result<GatewayStreamOutput> {
    let [ma, mb] = models;
    if ma.d != stream.d {
        return Err(Error::Config(format!(
            "stream has d={} but the model was trained with d={}",
            stream.d, ma.d
        )));
    }
    if ma.k != mb.k || ma.d != mb.d || ma.d_a != mb.d_a {
        return Err(Error::Config("the two model shares disagree on geometry".into()));
    }
    if ma.party != 0 || mb.party != 1 {
        return Err(Error::Config(
            "gateway_stream expects [party 0's share, party 1's share] in order".into(),
        ));
    }
    if ma.tau != mb.tau {
        return Err(Error::Config(format!(
            "model shares disagree on τ ({} vs {}) — they come from different \
             training runs and would reconstruct garbage centroids",
            ma.tau, mb.tau
        )));
    }
    let need = cfg.sessions * cfg.batches * cfg.batch_rows;
    if stream.n < need {
        return Err(Error::Config(format!(
            "stream of {} transactions is shorter than {} sessions × {} batches × {} rows",
            stream.n, cfg.sessions, cfg.batches, cfg.batch_rows
        )));
    }
    let (d, d_a) = (stream.d, ma.d_a);
    let mut wl_a = Vec::with_capacity(cfg.sessions);
    let mut wl_b = Vec::with_capacity(cfg.sessions);
    for s in 0..cfg.sessions {
        let mut blocks_a = Vec::with_capacity(cfg.batches);
        let mut blocks_b = Vec::with_capacity(cfg.batches);
        for b in 0..cfg.batches {
            let base = (s * cfg.batches + b) * cfg.batch_rows;
            let mut xa = Vec::with_capacity(cfg.batch_rows * d_a);
            let mut xb = Vec::with_capacity(cfg.batch_rows * (d - d_a));
            for i in base..base + cfg.batch_rows {
                let row = stream.row(i);
                xa.extend_from_slice(&row[..d_a]);
                xb.extend_from_slice(&row[d_a..]);
            }
            blocks_a.push(xa);
            blocks_b.push(xb);
        }
        let tag = s as u64 + 1;
        wl_a.push(SessionWorkload { tag, blocks: blocks_a });
        wl_b.push(SessionWorkload { tag, blocks: blocks_b });
    }
    let (cfg_a, cfg_b) = (cfg.clone(), cfg.clone());
    let ((ra, meter_a), (rb, meter_b)) = run_two_party(
        move |c| gateway_party(c, ma, wl_a, &cfg_a),
        move |c| gateway_party(c, mb, wl_b, &cfg_b),
    );
    let (a, b) = (ra?, rb?);
    #[cfg(debug_assertions)]
    {
        for ((ta, sa), (tb, sb)) in a.sessions.iter().zip(&b.sessions) {
            debug_assert_eq!(ta, tb, "parties must admit the same sessions");
            if let (Ok(sa), Ok(sb)) = (sa, sb) {
                debug_assert_eq!(
                    sa.results, sb.results,
                    "session {ta}: parties must reveal identical scores"
                );
            }
        }
        debug_assert_eq!(a.rejected, b.rejected, "parties must reject the same sessions");
    }
    Ok(GatewayStreamOutput { a, b, meter_a, meter_b })
}
