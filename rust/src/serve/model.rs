//! The trained-model artifact: what a party persists after training so a
//! later process can score forever without retraining.
//!
//! A [`TrainedModel`] holds one party's **additive share** of the final
//! fixed-point centroids, the min-max normalization stats of that
//! party's own feature block (each party normalizes incoming
//! transactions with the *training* stats, locally — the stats of the
//! other party's columns are never stored), the public fraud threshold
//! τ, and the run geometry (k, d, d_a). Neither file alone reveals the
//! centroids: reconstruction needs both parties' shares, exactly as
//! during the protocol.
//!
//! ## Binary format (version 1, little-endian)
//!
//! ```text
//! magic     8 B   "PPKMDL01"
//! version   u32   1
//! party     u32   0 | 1
//! k         u32
//! d         u32   joint feature count
//! d_a       u32   vertical split point (party 0 owns cols [0, d_a))
//! frac_bits u32   fixed-point scale of the stored share (must match)
//! ncols     u32   columns of this party's block (= stats entries)
//! tau       f64   public fraud threshold (squared distance, normalized)
//! stats     ncols × (f64 min, f64 max)
//! mu_share  k·d × u64
//! checksum  u64   FNV-1a over every preceding byte
//! ```
//!
//! Loading validates magic, version, `frac_bits`, geometry consistency,
//! exact length and the checksum, so a truncated or bit-flipped artifact
//! fails loudly instead of silently mis-scoring.

use crate::ring::fixed::FRAC_BITS;
use crate::ring::matrix::Mat;
use crate::util::codec::{fnv1a64, push_f64, push_u32, push_u64};
use crate::util::error::{Error, Result};
use std::path::Path;

/// File magic for model artifacts.
pub const MODEL_MAGIC: &[u8; 8] = b"PPKMDL01";
/// Current artifact format version.
pub const MODEL_VERSION: u32 = 1;

/// One party's persisted share of a trained clustering model.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainedModel {
    /// Which party this share belongs to (0 or 1).
    pub party: usize,
    /// Number of clusters.
    pub k: usize,
    /// Joint feature count.
    pub d: usize,
    /// Vertical split: party 0 owns columns `[0, d_a)`, party 1 the rest.
    pub d_a: usize,
    /// This party's additive share of the k×d fixed-point centroids.
    pub mu_share: Mat,
    /// Per-column `(min, max)` training normalization stats for this
    /// party's own block ([`TrainedModel::ncols`] entries).
    pub stats: Vec<(f64, f64)>,
    /// Public fraud threshold τ on the squared distance in normalized
    /// feature space (see [`crate::fraud::threshold`]).
    pub tau: f64,
}

/// Artifact name used in every parse error (shared codec helpers take it
/// so model and checkpoint failures stay distinguishable).
const WHAT: &str = "model artifact";

fn bad(msg: impl Into<String>) -> Error {
    Error::Config(format!("{WHAT}: {}", msg.into()))
}

fn rd_u32(b: &[u8], off: &mut usize) -> Result<u32> {
    crate::util::codec::rd_u32(b, off, WHAT)
}

fn rd_u64(b: &[u8], off: &mut usize) -> Result<u64> {
    crate::util::codec::rd_u64(b, off, WHAT)
}

fn rd_f64(b: &[u8], off: &mut usize) -> Result<f64> {
    crate::util::codec::rd_f64(b, off, WHAT)
}

impl TrainedModel {
    /// First joint-feature column of this party's block.
    pub fn col0(&self) -> usize {
        if self.party == 0 {
            0
        } else {
            self.d_a
        }
    }

    /// Width of this party's block.
    pub fn ncols(&self) -> usize {
        if self.party == 0 {
            self.d_a
        } else {
            self.d - self.d_a
        }
    }

    /// Serialize to the versioned binary format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let ncols = self.ncols();
        debug_assert_eq!(self.stats.len(), ncols, "stats must cover the block");
        debug_assert_eq!(self.mu_share.shape(), (self.k, self.d));
        let mut out = Vec::with_capacity(8 + 7 * 4 + 8 + ncols * 16 + self.k * self.d * 8 + 8);
        out.extend_from_slice(MODEL_MAGIC);
        push_u32(&mut out, MODEL_VERSION);
        push_u32(&mut out, self.party as u32);
        push_u32(&mut out, self.k as u32);
        push_u32(&mut out, self.d as u32);
        push_u32(&mut out, self.d_a as u32);
        push_u32(&mut out, FRAC_BITS);
        push_u32(&mut out, ncols as u32);
        push_f64(&mut out, self.tau);
        for &(lo, hi) in &self.stats {
            push_f64(&mut out, lo);
            push_f64(&mut out, hi);
        }
        for &w in &self.mu_share.data {
            push_u64(&mut out, w);
        }
        let sum = fnv1a64(&out);
        push_u64(&mut out, sum);
        out
    }

    /// Parse and validate the versioned binary format.
    pub fn from_bytes(bytes: &[u8]) -> Result<TrainedModel> {
        if bytes.len() < 8 + 8 {
            return Err(bad("too short"));
        }
        if &bytes[..8] != MODEL_MAGIC {
            return Err(bad("bad magic (not a ppkmeans model)"));
        }
        let body_len = bytes.len() - 8;
        let want_sum = u64::from_le_bytes(bytes[body_len..].try_into().unwrap());
        if fnv1a64(&bytes[..body_len]) != want_sum {
            return Err(bad("checksum mismatch (corrupted artifact)"));
        }
        let mut off = 8;
        let version = rd_u32(bytes, &mut off)?;
        if version != MODEL_VERSION {
            return Err(bad(format!("unsupported version {version} (expected {MODEL_VERSION})")));
        }
        let party = rd_u32(bytes, &mut off)? as usize;
        let k = rd_u32(bytes, &mut off)? as usize;
        let d = rd_u32(bytes, &mut off)? as usize;
        let d_a = rd_u32(bytes, &mut off)? as usize;
        let frac = rd_u32(bytes, &mut off)?;
        let ncols = rd_u32(bytes, &mut off)? as usize;
        if party > 1 {
            return Err(bad(format!("party {party} out of range")));
        }
        if frac != FRAC_BITS {
            return Err(bad(format!("frac_bits {frac} ≠ build's {FRAC_BITS}")));
        }
        if k == 0 || d_a == 0 || d_a >= d {
            return Err(bad(format!("inconsistent geometry k={k} d={d} d_a={d_a}")));
        }
        let want_ncols = if party == 0 { d_a } else { d - d_a };
        if ncols != want_ncols {
            return Err(bad(format!("ncols {ncols} ≠ block width {want_ncols}")));
        }
        // Bound-check the full payload length against the header geometry
        // with checked arithmetic BEFORE any allocation sized from the
        // (untrusted) header — a forged k·d must yield Err, not a
        // capacity-overflow panic or a multi-GB allocation.
        let expected = (8usize + 7 * 4 + 8 + 8) // magic + header u32s + tau + checksum
            .checked_add(ncols.checked_mul(16).ok_or_else(|| bad("ncols overflows"))?)
            .and_then(|v| {
                k.checked_mul(d)
                    .and_then(|m| m.checked_mul(8))
                    .and_then(|m| v.checked_add(m))
            })
            .ok_or_else(|| bad("header geometry overflows"))?;
        if expected != bytes.len() {
            return Err(bad(format!(
                "length {} does not match header geometry (expected {expected})",
                bytes.len()
            )));
        }
        let tau = rd_f64(bytes, &mut off)?;
        let mut stats = Vec::with_capacity(ncols);
        for _ in 0..ncols {
            let lo = rd_f64(bytes, &mut off)?;
            let hi = rd_f64(bytes, &mut off)?;
            stats.push((lo, hi));
        }
        let mut data = Vec::with_capacity(k * d);
        for _ in 0..k * d {
            data.push(rd_u64(bytes, &mut off)?);
        }
        if off != body_len {
            return Err(bad("trailing bytes after payload"));
        }
        Ok(TrainedModel { party, k, d, d_a, mu_share: Mat::from_vec(k, d, data), stats, tau })
    }

    /// Persist this party's share to disk.
    ///
    /// # Examples
    ///
    /// Save, reload, and verify the round trip (corruption would fail
    /// the checksum at [`TrainedModel::load`]):
    ///
    /// ```
    /// use ppkmeans::ring::matrix::Mat;
    /// use ppkmeans::serve::model::TrainedModel;
    ///
    /// let model = TrainedModel {
    ///     party: 0,
    ///     k: 2,
    ///     d: 3,
    ///     d_a: 1,
    ///     mu_share: Mat::from_vec(2, 3, vec![1, 2, 3, 4, 5, 6]),
    ///     stats: vec![(0.0, 1.0)],   // one (min, max) per own column
    ///     tau: 0.25,
    /// };
    /// let path = std::env::temp_dir().join("ppkmeans-doctest.ppkmodel");
    /// model.save(&path).unwrap();
    /// let back = TrainedModel::load(&path).unwrap();
    /// assert_eq!(back, model);
    /// std::fs::remove_file(&path).ok();
    /// ```
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())?;
        Ok(())
    }

    /// Load a share persisted by [`TrainedModel::save`] (validates
    /// magic, version, geometry, length, and the checksum).
    pub fn load(path: &Path) -> Result<TrainedModel> {
        let bytes = std::fs::read(path)?;
        TrainedModel::from_bytes(&bytes)
    }

    /// Conventional artifact file name for a party's share.
    pub fn file_name(party: usize) -> String {
        format!("party{party}.ppkmodel")
    }

    /// Normalize a raw feature block (row-major `rows × ncols`) with the
    /// **training** stats and encode to fixed point. Constant training
    /// columns map to 0, matching [`crate::data::normalize::min_max`];
    /// out-of-range serving values extrapolate linearly (no clamping —
    /// an unusually large value *should* look far from every centroid).
    pub fn normalize_block(&self, raw: &[f64]) -> Result<Mat> {
        let nc = self.ncols();
        if nc == 0 || raw.len() % nc != 0 {
            return Err(Error::Shape(format!(
                "raw block of {} values is not a multiple of the {}-column block",
                raw.len(),
                nc
            )));
        }
        let rows = raw.len() / nc;
        let mut out = vec![0.0; raw.len()];
        for i in 0..rows {
            for c in 0..nc {
                let (lo, hi) = self.stats[c];
                let v = raw[i * nc + c];
                out[i * nc + c] = if hi > lo { (v - lo) / (hi - lo) } else { 0.0 };
            }
        }
        Ok(Mat::encode(rows, nc, &out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prg;

    fn sample_model(party: usize) -> TrainedModel {
        let (k, d, d_a) = (3, 5, 2);
        let mut prg = Prg::new(9 + party as u128);
        let ncols = if party == 0 { d_a } else { d - d_a };
        TrainedModel {
            party,
            k,
            d,
            d_a,
            mu_share: Mat::random(k, d, &mut prg),
            stats: (0..ncols).map(|c| (c as f64 * 0.1, 1.0 + c as f64)).collect(),
            tau: 1.25,
        }
    }

    #[test]
    fn roundtrip_both_parties() {
        for party in [0, 1] {
            let m = sample_model(party);
            let back = TrainedModel::from_bytes(&m.to_bytes()).unwrap();
            assert_eq!(back, m);
        }
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let dir = std::env::temp_dir().join(format!("ppkm_model_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let m = sample_model(1);
        let path = dir.join(TrainedModel::file_name(1));
        m.save(&path).unwrap();
        let back = TrainedModel::load(&path).unwrap();
        assert_eq!(back, m);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let m = sample_model(0);
        let good = m.to_bytes();
        // Flip one payload byte → checksum mismatch.
        let mut bad = good.clone();
        bad[40] ^= 0x01;
        assert!(TrainedModel::from_bytes(&bad).is_err());
        // Truncation.
        assert!(TrainedModel::from_bytes(&good[..good.len() - 3]).is_err());
        // Wrong magic.
        let mut wrong = good.clone();
        wrong[0] = b'X';
        assert!(TrainedModel::from_bytes(&wrong).is_err());
        // Wrong version (re-checksummed so only the version check trips).
        let mut v2 = good;
        v2[8] = 2;
        let body = v2.len() - 8;
        let sum = super::fnv1a64(&v2[..body]).to_le_bytes();
        v2[body..].copy_from_slice(&sum);
        let err = TrainedModel::from_bytes(&v2).unwrap_err();
        assert!(err.to_string().contains("version"));
    }

    #[test]
    fn forged_huge_geometry_is_rejected_without_allocating() {
        // A self-consistent header with absurd k·d and a *recomputed*
        // checksum (FNV is not tamper-resistant) must come back Err —
        // never a capacity panic or a huge allocation.
        let m = sample_model(0);
        let mut forged = m.to_bytes();
        forged[16..20].copy_from_slice(&u32::MAX.to_le_bytes()); // k
        forged[20..24].copy_from_slice(&u32::MAX.to_le_bytes()); // d
        let body = forged.len() - 8;
        let sum = super::fnv1a64(&forged[..body]).to_le_bytes();
        forged[body..].copy_from_slice(&sum);
        assert!(TrainedModel::from_bytes(&forged).is_err());
    }

    #[test]
    fn normalize_block_uses_training_stats() {
        let mut m = sample_model(0); // ncols = 2
        m.stats = vec![(0.0, 2.0), (1.0, 1.0)]; // col 1 constant → 0
        let enc = m.normalize_block(&[1.0, 5.0, 3.0, 7.0]).unwrap();
        let dec = enc.decode();
        assert!((dec[0] - 0.5).abs() < 1e-5);
        assert_eq!(dec[1], 0.0);
        assert!((dec[2] - 1.5).abs() < 1e-5, "out-of-range extrapolates");
        assert_eq!(dec[3], 0.0);
        // Misaligned block length errors.
        assert!(m.normalize_block(&[1.0, 2.0, 3.0]).is_err());
    }
}
