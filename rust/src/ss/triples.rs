//! Beaver triples and the offline-material interface.
//!
//! A multiplication triple is a one-time pad for products: shares of
//! uniformly random `(U, V, Z = U·V)` let the online phase multiply with
//! a single reveal round. Triples are *data-independent* — the paper's
//! online/offline split rests on producing them ahead of time, either by
//! a trusted dealer or by OT (Gilboa); both generators live in
//! [`crate::offline`] and implement [`TripleSource`].
//!
//! The [`Ledger`] records exactly how much material a protocol consumed,
//! which is how benches price the offline phase for a given workload.

use crate::ring::matrix::Mat;
use crate::util::error::{Error, Result};

/// One party's share of a matrix Beaver triple `Z = U(m×k) · V(k×n)`.
#[derive(Debug, Clone)]
pub struct MatTriple {
    /// Share of the left mask `U (m×k)`.
    pub u: Mat,
    /// Share of the right mask `V (k×n)`.
    pub v: Mat,
    /// Share of the product `Z = U·V (m×n)`.
    pub z: Mat,
}

/// One party's share of a MAC-authenticated matrix Beaver triple
/// ([`crate::net::Security::Malicious`] tier): the base triple plus an
/// additive share of each component's MAC under the global key α —
/// `mac_u + mac_u' = α·U` (full matrices, elementwise scaling), and
/// likewise for `V` and `Z`. Trusted-dealer MACs (the dealer knows α and
/// the masks, so it can deal the limbs directly); the online phase never
/// sees α, only its own α-share (see `offline::dealer::mac_key_share`).
#[derive(Debug, Clone)]
pub struct AuthMatTriple {
    /// The unauthenticated base triple share.
    pub base: MatTriple,
    /// Share of `α·U`.
    pub mac_u: Mat,
    /// Share of `α·V`.
    pub mac_v: Mat,
    /// Share of `α·Z`.
    pub mac_z: Mat,
}

/// One party's share of `count` independent elementwise triples
/// `z[i] = u[i]·v[i]` (used by SMUL / MUX / B2A on lane vectors).
#[derive(Debug, Clone)]
pub struct VecTriple {
    /// Share of the left mask lanes.
    pub u: Vec<u64>,
    /// Share of the right mask lanes.
    pub v: Vec<u64>,
    /// Share of the lane-wise products `z[i] = u[i]·v[i]`.
    pub z: Vec<u64>,
}

/// One party's share of bit-packed boolean AND triples
/// `c = a & b` (XOR-shared), `n` lanes packed 64-per-word.
#[derive(Debug, Clone)]
pub struct BitTriple {
    /// XOR share of the `a` lanes (packed words).
    pub a: Vec<u64>,
    /// XOR share of the `b` lanes (packed words).
    pub b: Vec<u64>,
    /// XOR share of the AND lanes `c = a & b` (packed words).
    pub c: Vec<u64>,
    /// Number of valid lanes (the last word may be partial).
    pub n: usize,
}

/// One party's share of `n` **daBits** (doubly-authenticated bits):
/// uniformly random bits `r` held simultaneously as XOR shares
/// (`bool_words`, packed 64/lane) and additive shares in Z_{2^64}
/// (`arith`, one word per lane). daBits make B2A and boolean-selector
/// MUX single-flight gates: reveal `c = b ⊕ r` (a one-time-pad opening)
/// and combine `b = c + r − 2·c·r` locally — the Beaver mask for any
/// `r·x` product can ride the *same* flight because both operands'
/// shares are known before the reveal.
#[derive(Debug, Clone)]
pub struct DaBits {
    /// Number of valid lanes.
    pub n: usize,
    /// XOR shares of the bits, packed 64 lanes per word.
    pub bool_words: Vec<u64>,
    /// Additive shares of the same bits in Z_{2^64}, one word per lane.
    pub arith: Vec<u64>,
}

/// Running account of consumed offline material.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct Ledger {
    /// Ring elements of matrix-triple material (|U|+|V|+|Z| summed).
    pub mat_triple_elems: u64,
    /// Number of matrix triples requested.
    pub mat_triples: u64,
    /// Elementwise arithmetic triples consumed (lanes).
    pub vec_triple_lanes: u64,
    /// Boolean AND triples consumed (lanes).
    pub bit_triple_lanes: u64,
    /// daBits consumed (lanes).
    pub dabit_lanes: u64,
}

impl Ledger {
    /// Accumulate another ledger's counters into this one.
    pub fn merge(&mut self, o: &Ledger) {
        self.mat_triple_elems += o.mat_triple_elems;
        self.mat_triples += o.mat_triples;
        self.vec_triple_lanes += o.vec_triple_lanes;
        self.bit_triple_lanes += o.bit_triple_lanes;
        self.dabit_lanes += o.dabit_lanes;
    }
}

/// Source of one party's shares of correlated offline material.
///
/// Implementations must be *consistent across the two parties*: when both
/// parties draw the i-th triple, their shares must reconstruct to a valid
/// triple. See [`crate::offline::dealer::Dealer`] (PRG-simulated trusted
/// dealer, zero online communication) and
/// [`crate::offline::gilboa`] (OT-based two-party generation, the paper's
/// §4.1 choice).
pub trait TripleSource {
    /// Draw a matrix triple for shapes `(m×k)·(k×n)`.
    fn mat_triple(&mut self, m: usize, k: usize, n: usize) -> MatTriple;

    /// Draw `n` elementwise arithmetic triples.
    fn vec_triple(&mut self, n: usize) -> VecTriple;

    /// Draw `n` boolean AND triples (bit-packed).
    fn bit_triple(&mut self, n: usize) -> BitTriple;

    /// Draw `n` daBits (bits shared in both the XOR and additive worlds).
    fn dabits(&mut self, n: usize) -> DaBits;

    /// Material consumed so far.
    fn ledger(&self) -> Ledger;

    /// Draw a MAC-authenticated matrix triple (malicious tier). Sources
    /// that cannot produce authenticated material return a typed
    /// [`Error::Offline`] — only the trusted dealer (and wrappers
    /// forwarding to it) override this, so a malicious-mode run against
    /// an unauthenticated source fails loudly instead of silently
    /// downgrading.
    fn auth_mat_triple(&mut self, m: usize, k: usize, n: usize) -> Result<AuthMatTriple> {
        let _ = (m, k, n);
        Err(Error::Offline(
            "this triple source does not produce MAC-authenticated material".into(),
        ))
    }

    // ------------------------------------------------------------------
    // Batch draws — the offline-phase fan-out surface.
    //
    // `TripleStore::prefill_par` and `MaterialBank` replenishment call
    // these; sources that can fabricate items independently (the PRG
    // dealer) override them to shard the expansion across `threads`
    // workers. Two hard contracts bind every implementation:
    //
    // 1. **Stream equivalence** — a batch call must return exactly what
    //    the same sequence of single draws would have (so one party may
    //    prefill in batches while its peer draws one at a time and the
    //    shares still reconstruct);
    // 2. **Thread independence** — the returned material is
    //    bit-identical for any `threads` value.
    // ------------------------------------------------------------------

    /// Draw `count` matrix triples of one shape, fanning the fabrication
    /// across up to `threads` workers when the source supports it. The
    /// default runs the single-draw path sequentially.
    fn mat_triples(
        &mut self,
        m: usize,
        k: usize,
        n: usize,
        count: usize,
        threads: usize,
    ) -> Vec<MatTriple> {
        let _ = threads;
        (0..count).map(|_| self.mat_triple(m, k, n)).collect()
    }

    /// Draw one elementwise-triple chunk per entry of `lanes`, fanning
    /// across up to `threads` workers when supported.
    fn vec_triples(&mut self, lanes: &[usize], threads: usize) -> Vec<VecTriple> {
        let _ = threads;
        lanes.iter().map(|&n| self.vec_triple(n)).collect()
    }

    /// Draw one boolean-triple chunk per entry of `lanes`, fanning
    /// across up to `threads` workers when supported.
    fn bit_triples(&mut self, lanes: &[usize], threads: usize) -> Vec<BitTriple> {
        let _ = threads;
        lanes.iter().map(|&n| self.bit_triple(n)).collect()
    }

    /// Draw one daBit chunk per entry of `lanes`, fanning across up to
    /// `threads` workers when supported.
    fn dabits_many(&mut self, lanes: &[usize], threads: usize) -> Vec<DaBits> {
        let _ = threads;
        lanes.iter().map(|&n| self.dabits(n)).collect()
    }
}

/// Number of 64-bit words needed to pack `n` bit lanes.
#[inline]
pub fn bit_words(n: usize) -> usize {
    (n + 63) / 64
}

/// Mask for the last (possibly partial) word of an `n`-lane bit vector.
#[inline]
pub fn last_word_mask(n: usize) -> u64 {
    let r = n % 64;
    if r == 0 {
        u64::MAX
    } else {
        (1u64 << r) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_packing_helpers() {
        assert_eq!(bit_words(0), 0);
        assert_eq!(bit_words(1), 1);
        assert_eq!(bit_words(64), 1);
        assert_eq!(bit_words(65), 2);
        assert_eq!(last_word_mask(64), u64::MAX);
        assert_eq!(last_word_mask(3), 0b111);
    }

    #[test]
    fn ledger_merge() {
        let mut a = Ledger { mat_triples: 1, mat_triple_elems: 10, ..Default::default() };
        let b = Ledger { vec_triple_lanes: 5, bit_triple_lanes: 7, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.mat_triples, 1);
        assert_eq!(a.vec_triple_lanes, 5);
        assert_eq!(a.bit_triple_lanes, 7);
    }
}
