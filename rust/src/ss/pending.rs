//! Deferred-reveal handles for the round-batched gate engine.
//!
//! A `Pending<T>` is an interactive gate caught between its two halves:
//! the masked opening has been *staged* in the channel's round buffer
//! ([`Session::stage`]), but the peer's half has not arrived yet. After
//! any [`Session::flush`] ships the flight, [`Pending::resolve`]
//! combines the peer's reveal (and the retained local payload — no
//! clone needed at stage time) with the captured triple material into
//! the gate's output, entirely locally. Many pendings staged between
//! two flushes share one round-trip; that is the whole point.

use super::{Session, SessionOptions};
use crate::ring::matrix::Mat;

/// A staged gate awaiting its reveal. `T` is the gate output type
/// (`Mat`, `Vec<BoolShare>`, ...).
pub struct Pending<T> {
    seg: usize,
    finish: Box<dyn FnOnce(usize, Vec<u64>, Vec<u64>) -> T + Send>,
}

impl<T> Pending<T> {
    /// Stage `payload` and capture the local completion: `finish(party,
    /// local_payload, peer_payload)` runs at resolve time — the channel
    /// hands the staged payload back, so closures need not clone it.
    pub fn stage(
        s: &mut Session,
        payload: Vec<u64>,
        finish: impl FnOnce(usize, Vec<u64>, Vec<u64>) -> T + Send + 'static,
    ) -> Pending<T> {
        let seg = s.stage(payload);
        Pending { seg, finish: Box::new(finish) }
    }

    /// Combine the peer's reveal into the gate output. Panics if no
    /// flush has shipped the staging flight yet.
    pub fn resolve(self, s: &mut Session) -> T {
        let (mine, theirs) = s.take(self.seg);
        (self.finish)(s.party(), mine, theirs)
    }

    /// Post-compose a local transform onto the resolved value.
    pub fn map<U>(self, f: impl FnOnce(T) -> U + Send + 'static) -> Pending<U>
    where
        T: 'static,
    {
        let Pending { seg, finish } = self;
        Pending {
            seg,
            finish: Box::new(move |party, mine, theirs| f(finish(party, mine, theirs))),
        }
    }
}

/// Several staged reveals plus a local assembly step: the composite
/// output of a protocol fragment (a row tile's cross products, an S3
/// numerator contribution, ...) whose parts all ride whatever flight the
/// caller flushes next. Backends that finish eagerly — the HE path runs
/// its own ciphertext exchange, the naive ablation its scalar loop —
/// wrap their result with [`PendingParts::ready`] so every backend
/// presents the same staged interface to the tile scheduler.
pub struct PendingParts {
    parts: Vec<Pending<Mat>>,
    assemble: Box<dyn FnOnce(Vec<Mat>) -> Mat + Send>,
}

impl PendingParts {
    /// Wrap staged reveals plus the local assembly run at resolve time.
    pub fn new(
        parts: Vec<Pending<Mat>>,
        assemble: impl FnOnce(Vec<Mat>) -> Mat + Send + 'static,
    ) -> Self {
        PendingParts { parts, assemble: Box::new(assemble) }
    }

    /// An already-computed value (no staged reveals).
    pub fn ready(out: Mat) -> Self {
        PendingParts { parts: vec![], assemble: Box::new(move |_| out) }
    }

    /// Resolve every staged part (post-flush) and assemble.
    pub fn resolve(self, ctx: &mut Session) -> Mat {
        let mats: Vec<Mat> = self.parts.into_iter().map(|p| p.resolve(ctx)).collect();
        (self.assemble)(mats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::util::prng::Prg;

    #[test]
    fn pendings_resolve_after_one_shared_flight() {
        let ((sum, rounds), _) = run_two_party(
            |c| {
                let mut ts = Dealer::new(2, 0);
                let mut s = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let p1 = Pending::stage(&mut s, vec![5], |_, mine, theirs| {
                    assert_eq!(mine, vec![5], "local payload comes back untouched");
                    theirs[0] + 1
                });
                let p2 =
                    Pending::stage(&mut s, vec![7, 8], |_, _, theirs| theirs[0] + theirs[1]);
                s.flush();
                let a = p1.resolve(&mut s);
                let b = p2.resolve(&mut s);
                (a + b, s.chan.meter().total().rounds)
            },
            |c| {
                let mut ts = Dealer::new(2, 1);
                let mut s = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let p1 = Pending::stage(&mut s, vec![100], |_, _, t| t[0]);
                let p2 = Pending::stage(&mut s, vec![200, 300], |_, _, t| t[0]);
                s.flush();
                let _ = p1.resolve(&mut s);
                let _ = p2.resolve(&mut s);
            },
        );
        // p1: peer sent [100] → 101; p2: peer sent [200,300] → 500.
        assert_eq!(sum, 601);
        assert_eq!(rounds, 1);
    }

    #[test]
    fn map_transforms_resolved_value() {
        let ((v, _), _) = run_two_party(
            |c| {
                let mut ts = Dealer::new(3, 0);
                let mut s = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let p = Pending::stage(&mut s, vec![1], |_, _, t| t[0]).map(|x| x * 2);
                s.flush();
                (p.resolve(&mut s), ())
            },
            |c| {
                let mut ts = Dealer::new(3, 1);
                let mut s = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let p = Pending::stage(&mut s, vec![21], |_, _, t| t[0]);
                s.flush();
                let _ = p.resolve(&mut s);
            },
        );
        assert_eq!(v, 42);
    }
}
