//! MUX: oblivious selection `b ? x : y` on shares (paper §3.1).
//!
//! `MUX(⟨b⟩, ⟨x⟩, ⟨y⟩) = ⟨y⟩ + ⟨b⟩·(⟨x⟩−⟨y⟩)`: after lifting the
//! selector with B2A, one elementwise Beaver multiplication selects all
//! lanes in one round. Used by the CMPM modules of `F_min^k` to propagate
//! the smaller distance and its one-hot index up the tree.

use super::arith::smul_elem;
use super::boolean::{b2a, BoolShare};
use super::Ctx;
use crate::ring::matrix::Mat;

/// Select per-lane: out[i] = b[i] ? x[i] : y[i]. `b` has one lane per
/// element of `x`/`y`.
pub fn mux(ctx: &mut Ctx, b: &BoolShare, x: &Mat, y: &Mat) -> Mat {
    let ba = b2a(ctx, b);
    mux_arith(ctx, &ba, x, y)
}

/// MUX with an already-lifted arithmetic selector (shape 1×len).
pub fn mux_arith(ctx: &mut Ctx, b: &Mat, x: &Mat, y: &Mat) -> Mat {
    assert_eq!(x.shape(), y.shape());
    assert_eq!(b.len(), x.len(), "selector lanes");
    let diff = x.sub(y);
    let bm = Mat::from_vec(x.rows, x.cols, b.data.clone());
    let prod = smul_elem(ctx, &bm, &diff);
    y.add(&prod)
}

/// Broadcast-MUX: one selector lane per *row* of `x`/`y` (used when a
/// single comparison decides a whole row of values, e.g. a distance and
/// its k-lane one-hot index together).
pub fn mux_rows(ctx: &mut Ctx, b_rows: &Mat, x: &Mat, y: &Mat) -> Mat {
    assert_eq!(x.shape(), y.shape());
    assert_eq!(b_rows.len(), x.rows, "one selector per row");
    // Expand selector across columns, then one elementwise product.
    let mut expanded = Mat::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let b = b_rows.data[r];
        for c in 0..x.cols {
            expanded.data[r * x.cols + c] = b;
        }
    }
    let diff = x.sub(y);
    let prod = smul_elem(ctx, &expanded, &diff);
    y.add(&prod)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ss::share::{reconstruct, split};
    use crate::ss::triples::bit_words;
    use crate::util::prng::Prg;

    #[test]
    fn mux_selects_per_lane() {
        let n = 5;
        let x = Mat::from_vec(1, n, vec![10, 20, 30, 40, 50]);
        let y = Mat::from_vec(1, n, vec![1, 2, 3, 4, 5]);
        // b = 1,0,1,0,1 XOR-shared
        let mut prg = Prg::new(31);
        let bits = 0b10101u64;
        let m0 = prg.next_u64() & ((1 << n) - 1);
        let b0 = BoolShare::from_plain_words(n, vec![m0]);
        let b1 = BoolShare::from_plain_words(n, vec![bits ^ m0]);
        let (x0, x1) = split(&x, &mut prg);
        let (y0, y1) = split(&y, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(60, 0);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(1));
                let z = mux(&mut ctx, &b0, &x0, &y0);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(60, 1);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(2));
                let z = mux(&mut ctx, &b1, &x1, &y1);
                reconstruct(c, &z)
            },
        );
        assert_eq!(r.data, vec![10, 2, 30, 4, 50]);
    }

    #[test]
    fn mux_rows_broadcasts_selector() {
        let x = Mat::from_vec(2, 3, vec![1, 1, 1, 2, 2, 2]);
        let y = Mat::from_vec(2, 3, vec![9, 9, 9, 8, 8, 8]);
        // selector rows: [1, 0] arithmetic-shared
        let b = Mat::from_vec(1, 2, vec![1, 0]);
        let mut prg = Prg::new(32);
        let (b0, b1) = split(&b, &mut prg);
        let (x0, x1) = split(&x, &mut prg);
        let (y0, y1) = split(&y, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(61, 0);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(1));
                let z = mux_rows(&mut ctx, &b0, &x0, &y0);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(61, 1);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(2));
                let z = mux_rows(&mut ctx, &b1, &x1, &y1);
                reconstruct(c, &z)
            },
        );
        assert_eq!(r.data, vec![1, 1, 1, 8, 8, 8]);
    }

    #[test]
    fn selector_lanes_assert() {
        let _ = bit_words(5);
    }
}
