//! MUX: oblivious selection `b ? x : y` on shares (paper §3.1).
//!
//! The fused form works directly on the XOR-shared selector with a daBit
//! and costs **one** flight: write `b = c ⊕ r` where `r` is the daBit
//! and `c = b ⊕ r` is revealed (a one-time-pad opening), then
//!
//! `b·(x−y) = c·(x−y) + (1−2c)·r·(x−y)`
//!
//! — the Beaver masks for `r·(x−y)` ride the *same* flight as the `c`
//! reveal because both operands' shares are known before it departs.
//! The pre-batching pipeline (B2A, then arithmetic MUX) cost two
//! dependent flights; [`mux_arith`] is retained for callers that already
//! hold an arithmetic selector.
//!
//! Used by the CMPM modules of `F_min^k` to propagate the smaller
//! distance and its one-hot index row up the tree — the broadcast `group`
//! parameter selects a whole row of values with one selector lane.

use super::arith::smul_elem;
use super::boolean::BoolShare;
use super::pending::Pending;
use super::{Session, SessionOptions};
use crate::ring::matrix::Mat;

/// Stage a fused boolean-selector MUX. Selector lane `i` of `b` decides
/// data lanes `i·group .. (i+1)·group` (pass `group = 1` for per-lane
/// selection): out = b ? x : y. Resolves after the next flush; the whole
/// gate is a single staged segment.
pub fn mux_bits_begin(
    ctx: &mut Session,
    b: &BoolShare,
    x: &Mat,
    y: &Mat,
    group: usize,
) -> Pending<Mat> {
    assert_eq!(x.shape(), y.shape());
    assert!(group > 0);
    let total = x.len();
    assert_eq!(b.n * group, total, "selector lanes × group must cover the data");
    let db = ctx.ts.dabits(b.n);
    let t = ctx.ts.vec_triple(total);
    let diff = x.sub(y);
    let bw = b.words.len();
    // Payload: [c = b ⊕ r | E = r − u | F = diff − v], one segment.
    let mut payload = Vec::with_capacity(bw + 2 * total);
    for i in 0..bw {
        payload.push(b.words[i] ^ db.bool_words[i]);
    }
    for i in 0..total {
        payload.push(db.arith[i / group].wrapping_sub(t.u[i]));
    }
    for i in 0..total {
        payload.push(diff.data[i].wrapping_sub(t.v[i]));
    }
    let y_own = y.clone();
    Pending::stage(ctx, payload, move |party, mine, theirs| {
        let mut out = Mat::zeros(y_own.rows, y_own.cols);
        for i in 0..total {
            let sel = i / group;
            let c = ((mine[sel / 64] ^ theirs[sel / 64]) >> (sel % 64)) & 1;
            let e = mine[bw + i].wrapping_add(theirs[bw + i]);
            let f = mine[bw + total + i].wrapping_add(theirs[bw + total + i]);
            // ⟨r·diff⟩ = e·v + u·f + z (+ e·f at party 0)
            let mut rd =
                e.wrapping_mul(t.v[i]).wrapping_add(t.u[i].wrapping_mul(f)).wrapping_add(t.z[i]);
            if party == 0 {
                rd = rd.wrapping_add(e.wrapping_mul(f));
            }
            // ⟨b·diff⟩ = c·⟨diff⟩ + (1−2c)·⟨r·diff⟩ with public c.
            let bd = if c == 1 { diff.data[i].wrapping_sub(rd) } else { rd };
            out.data[i] = y_own.data[i].wrapping_add(bd);
        }
        out
    })
}

/// Fused boolean-selector MUX, per-lane (single-gate wrapper, one round).
pub fn mux_bits(ctx: &mut Session, b: &BoolShare, x: &Mat, y: &Mat) -> Mat {
    let p = mux_bits_begin(ctx, b, x, y, 1);
    ctx.flush();
    p.resolve(ctx)
}

/// Select per-lane: out[i] = b[i] ? x[i] : y[i]. `b` has one lane per
/// element of `x`/`y`. One round (daBit-fused).
pub fn mux(ctx: &mut Session, b: &BoolShare, x: &Mat, y: &Mat) -> Mat {
    mux_bits(ctx, b, x, y)
}

/// Batched MUX: every selection reveals in one flight.
pub fn mux_many(ctx: &mut Session, items: &[(&BoolShare, &Mat, &Mat)]) -> Vec<Mat> {
    let pending: Vec<Pending<Mat>> =
        items.iter().map(|(b, x, y)| mux_bits_begin(ctx, b, x, y, 1)).collect();
    ctx.flush();
    pending.into_iter().map(|p| p.resolve(ctx)).collect()
}

/// MUX with an already-lifted arithmetic selector (shape 1×len).
pub fn mux_arith(ctx: &mut Session, b: &Mat, x: &Mat, y: &Mat) -> Mat {
    assert_eq!(x.shape(), y.shape());
    assert_eq!(b.len(), x.len(), "selector lanes");
    let diff = x.sub(y);
    let bm = Mat::from_vec(x.rows, x.cols, b.data.clone());
    let prod = smul_elem(ctx, &bm, &diff);
    y.add(&prod)
}

/// Broadcast-MUX with an arithmetic selector: one selector lane per
/// *row* of `x`/`y` (used when a single comparison decides a whole row
/// of values, e.g. a distance and its k-lane one-hot index together).
pub fn mux_rows(ctx: &mut Session, b_rows: &Mat, x: &Mat, y: &Mat) -> Mat {
    assert_eq!(x.shape(), y.shape());
    assert_eq!(b_rows.len(), x.rows, "one selector per row");
    // Expand selector across columns, then one elementwise product.
    let mut expanded = Mat::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let b = b_rows.data[r];
        for c in 0..x.cols {
            expanded.data[r * x.cols + c] = b;
        }
    }
    let diff = x.sub(y);
    let prod = smul_elem(ctx, &expanded, &diff);
    y.add(&prod)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ss::share::{reconstruct, split};
    use crate::ss::triples::bit_words;
    use crate::ss::Session;
    use crate::util::prng::Prg;

    #[test]
    fn mux_selects_per_lane() {
        let n = 5;
        let x = Mat::from_vec(1, n, vec![10, 20, 30, 40, 50]);
        let y = Mat::from_vec(1, n, vec![1, 2, 3, 4, 5]);
        // b = 1,0,1,0,1 XOR-shared
        let mut prg = Prg::new(31);
        let bits = 0b10101u64;
        let m0 = prg.next_u64() & ((1 << n) - 1);
        let b0 = BoolShare::from_plain_words(n, vec![m0]);
        let b1 = BoolShare::from_plain_words(n, vec![bits ^ m0]);
        let (x0, x1) = split(&x, &mut prg);
        let (y0, y1) = split(&y, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(60, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let z = mux(&mut ctx, &b0, &x0, &y0);
                let rounds = ctx.chan.meter().total().rounds;
                (reconstruct(c, &z), rounds)
            },
            move |c| {
                let mut ts = Dealer::new(60, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let z = mux(&mut ctx, &b1, &x1, &y1);
                let _ = reconstruct(c, &z);
            },
        );
        let (rec, rounds) = r;
        assert_eq!(rec.data, vec![10, 2, 30, 4, 50]);
        assert_eq!(rounds, 1, "fused boolean MUX is a single flight");
    }

    #[test]
    fn mux_bits_broadcast_groups_rows() {
        // Two selector lanes, each deciding a group of 3 data lanes.
        let x = Mat::from_vec(2, 3, vec![1, 1, 1, 2, 2, 2]);
        let y = Mat::from_vec(2, 3, vec![9, 9, 9, 8, 8, 8]);
        // selector = [1, 0] XOR-shared
        let mut prg = Prg::new(32);
        let mask = prg.next_u64() & 0b11;
        let b0 = BoolShare::from_plain_words(2, vec![mask]);
        let b1 = BoolShare::from_plain_words(2, vec![0b01 ^ mask]);
        let (x0, x1) = split(&x, &mut prg);
        let (y0, y1) = split(&y, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(61, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let p = mux_bits_begin(&mut ctx, &b0, &x0, &y0, 3);
                ctx.flush();
                let z = p.resolve(&mut ctx);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(61, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let p = mux_bits_begin(&mut ctx, &b1, &x1, &y1, 3);
                ctx.flush();
                let z = p.resolve(&mut ctx);
                reconstruct(c, &z)
            },
        );
        assert_eq!(r.data, vec![1, 1, 1, 8, 8, 8]);
    }

    #[test]
    fn mux_rows_broadcasts_selector() {
        let x = Mat::from_vec(2, 3, vec![1, 1, 1, 2, 2, 2]);
        let y = Mat::from_vec(2, 3, vec![9, 9, 9, 8, 8, 8]);
        // selector rows: [1, 0] arithmetic-shared
        let b = Mat::from_vec(1, 2, vec![1, 0]);
        let mut prg = Prg::new(32);
        let (b0, b1) = split(&b, &mut prg);
        let (x0, x1) = split(&x, &mut prg);
        let (y0, y1) = split(&y, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(61, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let z = mux_rows(&mut ctx, &b0, &x0, &y0);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(61, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let z = mux_rows(&mut ctx, &b1, &x1, &y1);
                reconstruct(c, &z)
            },
        );
        assert_eq!(r.data, vec![1, 1, 1, 8, 8, 8]);
    }

    #[test]
    fn mux_many_shares_one_flight() {
        let n = 4;
        let x = Mat::from_vec(1, n, vec![10, 20, 30, 40]);
        let y = Mat::from_vec(1, n, vec![1, 2, 3, 4]);
        let mut prg = Prg::new(33);
        let m = prg.next_u64() & 0xF;
        let b0 = BoolShare::from_plain_words(n, vec![m]);
        let b1 = BoolShare::from_plain_words(n, vec![0b1111 ^ m]);
        let (x0, x1) = split(&x, &mut prg);
        let (y0, y1) = split(&y, &mut prg);
        let ((out, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(62, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let zs = mux_many(&mut ctx, &[(&b0, &x0, &y0), (&b0, &y0, &x0)]);
                let rounds = ctx.chan.meter().total().rounds;
                let r: Vec<Mat> = zs.iter().map(|z| reconstruct(c, z)).collect();
                (r, rounds)
            },
            move |c| {
                let mut ts = Dealer::new(62, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let zs = mux_many(&mut ctx, &[(&b1, &x1, &y1), (&b1, &y1, &x1)]);
                let _: Vec<Mat> = zs.iter().map(|z| reconstruct(c, z)).collect();
            },
        );
        let (r, rounds) = out;
        // selector is all-ones: first picks x, second picks y.
        assert_eq!(r[0].data, vec![10, 20, 30, 40]);
        assert_eq!(r[1].data, vec![1, 2, 3, 4]);
        assert_eq!(rounds, 1, "both MUXes share one flight");
    }

    #[test]
    fn selector_lanes_assert() {
        let _ = bit_words(5);
    }
}
