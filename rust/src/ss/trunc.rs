//! Fixed-point truncation on shares (SecureML, Mohassel-Zhang §4.1).
//!
//! After multiplying two fixed-point values the product carries scale
//! 2^(2f); each party *locally* arithmetic-shifts its share — party 1
//! negates, shifts, negates back. The reconstructed result equals the
//! truncated product up to ±1 ulp except with probability
//! ≈ |x| / 2^(l−1−f), negligible for our value ranges. Zero rounds.

use crate::ring::fixed::FRAC_BITS;
use crate::ring::matrix::Mat;

/// Locally truncate a shared fixed-point matrix by `bits` (default
/// [`FRAC_BITS`] via [`trunc_frac`]). The per-element shift (party 1:
/// `−((−⟨x⟩₁) >> f)`) runs as a packed lanewise sweep
/// ([`crate::runtime::simd::trunc_words`]) — bit-identical at every
/// lane width.
pub fn trunc_share(party: usize, x: &Mat, bits: u32) -> Mat {
    Mat {
        rows: x.rows,
        cols: x.cols,
        data: crate::runtime::simd::trunc_words(&x.data, party, bits),
    }
}

/// Truncate by the global fractional precision.
pub fn trunc_frac(party: usize, x: &Mat) -> Mat {
    trunc_share(party, x, FRAC_BITS)
}

/// Batch form for API symmetry with the interactive gates: truncation is
/// local, so this is zero-round by construction — it exists so callers
/// can treat a post-multiply batch uniformly.
pub fn trunc_many(party: usize, xs: &[&Mat], bits: u32) -> Vec<Mat> {
    xs.iter().map(|x| trunc_share(party, x, bits)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::fixed::{decode_f64, encode_f64, SCALE};
    use crate::ss::share::split;
    use crate::util::prng::Prg;

    #[test]
    fn truncation_error_is_at_most_one_ulp() {
        let mut prg = Prg::new(9);
        let vals: Vec<f64> = vec![1.5, -2.25, 1000.0, -999.5, 0.0, 0.001, -0.001];
        for &v in &vals {
            // Product-scaled encoding: v * 2^{2f}
            let scaled = (v * SCALE * SCALE).round() as i64 as u64;
            let m = Mat::from_vec(1, 1, vec![scaled]);
            for trial in 0..50 {
                let mut p = Prg::new(1000 + trial);
                let (s0, s1) = split(&m, &mut p);
                let t0 = trunc_frac(0, &s0);
                let t1 = trunc_frac(1, &s1);
                let rec = t0.add(&t1).data[0];
                let got = decode_f64(rec);
                assert!(
                    (got - v).abs() <= 2.0 / SCALE,
                    "v={v} got={got} trial={trial}"
                );
            }
            let _ = &mut prg;
        }
    }

    #[test]
    fn truncating_plain_encoding_by_zero_is_identity() {
        let m = Mat::from_vec(1, 2, vec![encode_f64(1.5), encode_f64(-1.5)]);
        let t = trunc_share(0, &m, 0);
        assert_eq!(t, m);
    }

    #[test]
    fn trunc_many_matches_per_matrix() {
        let a = Mat::from_vec(1, 2, vec![1 << 24, 7 << 24]);
        let b = Mat::from_vec(1, 1, vec![3 << 24]);
        let many = trunc_many(0, &[&a, &b], 4);
        assert_eq!(many[0], trunc_share(0, &a, 4));
        assert_eq!(many[1], trunc_share(0, &b, 4));
    }
}
