//! Arithmetic gates on shares: SADD (local) and elementwise SMUL.
//!
//! SADD / linear combinations are communication-free. SMUL uses an
//! elementwise Beaver triple and a single symmetric reveal round for all
//! lanes at once — this is the vectorization the paper leans on. The
//! `*_begin` forms stage the reveal so independent products (and any
//! other staged gates) share one flight.

use super::pending::Pending;
use super::{Session, SessionOptions};
use crate::ring::matrix::Mat;

/// Local addition of shares: `⟨x+y⟩ = ⟨x⟩ + ⟨y⟩`.
pub fn sadd(x: &Mat, y: &Mat) -> Mat {
    x.add(y)
}

/// Local affine map `⟨αx + y + β⟩` — the public constant β is added by
/// party 0 only (adding it at both parties would double it).
pub fn affine(party: usize, alpha: u64, x: &Mat, y: &Mat, beta: u64) -> Mat {
    let mut out = x.scale(alpha).add(y);
    if party == 0 {
        for v in out.data.iter_mut() {
            *v = v.wrapping_add(beta);
        }
    }
    out
}

/// Add a public constant matrix to a share (party 0 adds, party 1 no-op).
pub fn add_public(party: usize, x: &Mat, c: &Mat) -> Mat {
    if party == 0 {
        x.add(c)
    } else {
        x.clone()
    }
}

/// Stage an elementwise secure multiplication `⟨x⊙y⟩`; one triple lane
/// per element, resolved after the next flush.
pub fn smul_elem_begin(ctx: &mut Session, x: &Mat, y: &Mat) -> Pending<Mat> {
    assert_eq!(x.shape(), y.shape(), "smul_elem shape mismatch");
    let n = x.len();
    let t = ctx.ts.vec_triple(n);
    // E = x - u, F = y - v (local), revealed together.
    let mut ef = Vec::with_capacity(2 * n);
    for i in 0..n {
        ef.push(x.data[i].wrapping_sub(t.u[i]));
    }
    for i in 0..n {
        ef.push(y.data[i].wrapping_sub(t.v[i]));
    }
    let (rows, cols) = x.shape();
    Pending::stage(ctx, ef, move |party, mine, theirs| {
        let mut out = Mat::zeros(rows, cols);
        for i in 0..n {
            let e = mine[i].wrapping_add(theirs[i]);
            let f = mine[n + i].wrapping_add(theirs[n + i]);
            // xy = (e+u)(f+v) = ef + e·v + u·f + z
            let mut c =
                e.wrapping_mul(t.v[i]).wrapping_add(t.u[i].wrapping_mul(f)).wrapping_add(t.z[i]);
            if party == 0 {
                c = c.wrapping_add(e.wrapping_mul(f));
            }
            out.data[i] = c;
        }
        out
    })
}

/// Elementwise secure multiplication `⟨x⊙y⟩` of two shared matrices
/// (single-gate wrapper: one symmetric reveal round).
pub fn smul_elem(ctx: &mut Session, x: &Mat, y: &Mat) -> Mat {
    let p = smul_elem_begin(ctx, x, y);
    ctx.flush();
    p.resolve(ctx)
}

/// Batch form: all elementwise products reveal in one flight.
pub fn smul_elem_many(ctx: &mut Session, pairs: &[(&Mat, &Mat)]) -> Vec<Mat> {
    let pending: Vec<Pending<Mat>> =
        pairs.iter().map(|(x, y)| smul_elem_begin(ctx, x, y)).collect();
    ctx.flush();
    pending.into_iter().map(|p| p.resolve(ctx)).collect()
}

/// Stage an elementwise square `⟨x⊙x⟩`.
pub fn ssquare_elem_begin(ctx: &mut Session, x: &Mat) -> Pending<Mat> {
    smul_elem_begin(ctx, x, x)
}

/// Elementwise square `⟨x⊙x⟩` (same cost as one SMUL; kept separate for
/// readability at call sites such as `|μ_j|²`).
pub fn ssquare_elem(ctx: &mut Session, x: &Mat) -> Mat {
    smul_elem(ctx, x, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ss::share::{reconstruct, split};
    use crate::ss::Session;
    use crate::util::prng::Prg;

    /// Run an elementwise product under two-party simulation.
    fn run_smul(x: Vec<u64>, y: Vec<u64>) -> Vec<u64> {
        let n = x.len();
        let mut prg = Prg::new(77);
        let xm = Mat::from_vec(1, n, x);
        let ym = Mat::from_vec(1, n, y);
        let (x0, x1) = split(&xm, &mut prg);
        let (y0, y1) = split(&ym, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(123, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let z = smul_elem(&mut ctx, &x0, &y0);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(123, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let z = smul_elem(&mut ctx, &x1, &y1);
                reconstruct(c, &z)
            },
        );
        r.data
    }

    #[test]
    fn smul_matches_plaintext_with_wrap() {
        let x = vec![3, u64::MAX, 1 << 40, 0];
        let y = vec![5, 2, 1 << 30, 99];
        let want: Vec<u64> = x.iter().zip(&y).map(|(a, b)| a.wrapping_mul(*b)).collect();
        assert_eq!(run_smul(x, y), want);
    }

    #[test]
    fn smul_many_is_one_round() {
        let x = Mat::from_vec(1, 3, vec![1, 2, 3]);
        let y = Mat::from_vec(1, 3, vec![4, 5, 6]);
        let mut prg = Prg::new(78);
        let (x0, x1) = split(&x, &mut prg);
        let (y0, y1) = split(&y, &mut prg);
        let want: Vec<u64> = (0..3).map(|i| x.data[i].wrapping_mul(y.data[i])).collect();
        let ((zs, m0), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(124, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let zs = smul_elem_many(&mut ctx, &[(&x0, &y0), (&x0, &y0)]);
                zs.iter().map(|z| reconstruct(c, z)).collect::<Vec<_>>()
            },
            move |c| {
                let mut ts = Dealer::new(124, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let zs = smul_elem_many(&mut ctx, &[(&x1, &y1), (&x1, &y1)]);
                let _ = zs.iter().map(|z| reconstruct(c, z)).collect::<Vec<_>>();
            },
        );
        assert_eq!(zs[0].data, want);
        assert_eq!(zs[1].data, want);
        // One flight for both products + two reconstructs.
        assert_eq!(m0.total().rounds, 3);
    }

    #[test]
    fn affine_adds_constant_once() {
        let x0 = Mat::from_vec(1, 2, vec![1, 2]);
        let x1 = Mat::from_vec(1, 2, vec![10, 20]);
        let y0 = Mat::zeros(1, 2);
        let y1 = Mat::zeros(1, 2);
        let r0 = affine(0, 3, &x0, &y0, 100);
        let r1 = affine(1, 3, &x1, &y1, 100);
        let rec = r0.add(&r1);
        // 3*(x0+x1) + 100
        assert_eq!(rec.data, vec![3 * 11 + 100, 3 * 22 + 100]);
    }
}
