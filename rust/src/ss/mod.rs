//! Additive secret sharing over Z_{2^64} and the MPC gate set.
//!
//! Implements the paper's §3.1 primitive set — SADD (local), SMUL /
//! matrix multiplication with Beaver triples, A2B / MSB / CMP via a
//! bit-sliced Kogge-Stone adder, B2A, MUX — plus SecureML-style
//! truncation ([`trunc`]) and secure division ([`divide`]) used by the
//! centroid-update step.
//!
//! All protocols are written against [`Ctx`], which bundles the party's
//! channel, its PRG and a [`triples::TripleSource`] (trusted dealer or
//! OT-based, see [`crate::offline`]). Everything is *vectorized*: gates
//! operate on whole matrices / lane vectors, so one protocol round
//! processes all n·k lanes at once — the paper's core efficiency insight.

pub mod arith;
pub mod boolean;
pub mod compare;
pub mod divide;
pub mod matmul;
pub mod mux;
pub mod share;
pub mod triples;
pub mod trunc;

use crate::net::Chan;
use crate::util::prng::Prg;
use triples::TripleSource;

/// Per-party protocol context: channel + offline material + local PRG.
pub struct Ctx<'a> {
    pub chan: &'a mut Chan,
    pub ts: &'a mut dyn TripleSource,
    pub prg: Prg,
}

impl<'a> Ctx<'a> {
    pub fn new(chan: &'a mut Chan, ts: &'a mut dyn TripleSource, prg: Prg) -> Self {
        Ctx { chan, ts, prg }
    }

    /// This party's index (0 or 1).
    #[inline]
    pub fn party(&self) -> usize {
        self.chan.party
    }

    /// Label subsequent communication with a metering phase.
    pub fn set_phase(&mut self, label: &str) {
        self.chan.set_phase(label);
    }
}
