//! Additive secret sharing over Z_{2^64} and the MPC gate set.
//!
//! Implements the paper's §3.1 primitive set — SADD (local), SMUL /
//! matrix multiplication with Beaver triples, A2B / MSB / CMP via a
//! bit-sliced Kogge-Stone adder, B2A, MUX — plus SecureML-style
//! truncation ([`trunc`]) and secure division ([`divide`]) used by the
//! centroid-update step.
//!
//! ## The round-batched engine
//!
//! All protocols are written against [`Session`] (née `Ctx`), which
//! bundles the party's channel, its PRG and a
//! [`triples::TripleSource`]. The gate set is **batch-first**: every
//! interactive gate has a `*_begin` form that *stages* its masked reveal
//! into the channel's round buffer and returns a [`pending::Pending`]
//! handle; [`Session::flush`] ships every staged reveal in **one**
//! flight, after which the handles resolve locally. Single-gate
//! functions (`ss_matmul`, `smul_elem`, `and`, `mux`, ...) are thin
//! wrappers: begin → flush → resolve. Independent gates therefore share
//! a round-trip, and the per-flight cost of a protocol step is its
//! *dependency depth*, not its gate count.
//!
//! [`RoundPolicy::PerGate`] disables the coalescing (every staged
//! segment and every AND-pair becomes its own flight) — the
//! gate-per-flight baseline that round-count regression tests and the
//! WAN ablations compare against.

pub mod arith;
pub mod boolean;
pub mod compare;
pub mod divide;
pub mod matmul;
pub mod mux;
pub mod pending;
pub mod share;
pub mod triples;
pub mod trunc;

use crate::net::Chan;
use crate::util::prng::Prg;
use triples::TripleSource;

pub use crate::net::Security;
pub use pending::{Pending, PendingParts};

/// How the session maps gates onto network flights.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoundPolicy {
    /// Stage reveals in the round buffer; one flight per [`Session::flush`].
    #[default]
    Coalesced,
    /// Gate-per-flight ablation baseline: every staged reveal is flushed
    /// immediately and batched AND layers degrade to per-pair flights.
    PerGate,
}

/// Construction-time knobs for a [`Session`]. A struct (not positional
/// args) so adding a knob never ripples through every call site again:
/// `SessionOptions::default()` is the paper's configuration — coalesced
/// flights, semi-honest security.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SessionOptions {
    /// How gates map onto network flights.
    pub policy: RoundPolicy,
    /// Adversary model. [`Security::Malicious`] makes authenticated
    /// opens fold into the channel's deferred MAC ledger (the channel
    /// itself must be armed via [`Chan::enable_mac`] by the pipeline);
    /// [`Security::SemiHonest`] keeps the transcript byte-identical to
    /// the unauthenticated protocol.
    pub security: Security,
}

impl SessionOptions {
    /// Options with the given round policy (semi-honest security).
    pub fn with_policy(policy: RoundPolicy) -> Self {
        SessionOptions { policy, ..Default::default() }
    }

    /// Options with the given security tier (coalesced flights).
    pub fn with_security(security: Security) -> Self {
        SessionOptions { security, ..Default::default() }
    }
}

/// Per-party protocol session: channel + offline material + local PRG,
/// plus the round policy that decides how gates share flights and the
/// security tier that decides whether opens are authenticated.
pub struct Session<'a> {
    /// The party's accounted channel (round buffer + meter).
    pub chan: &'a mut Chan,
    /// Offline material source the gates draw triples/daBits from.
    pub ts: &'a mut dyn TripleSource,
    /// Local mask/share PRG (need not match the peer's).
    pub prg: Prg,
    policy: RoundPolicy,
    security: Security,
}

impl<'a> Session<'a> {
    /// Bundle a channel, a triple source and a local PRG into a session.
    /// Pass [`SessionOptions::default()`] for the paper's configuration
    /// (coalesced flights, semi-honest).
    pub fn new(
        chan: &'a mut Chan,
        ts: &'a mut dyn TripleSource,
        prg: Prg,
        opts: SessionOptions,
    ) -> Self {
        debug_assert!(
            !opts.security.malicious() || chan.mac_enabled(),
            "malicious session over an unarmed channel — call Chan::enable_mac first"
        );
        Session { chan, ts, prg, policy: opts.policy, security: opts.security }
    }

    /// Current round policy.
    #[inline]
    pub fn policy(&self) -> RoundPolicy {
        self.policy
    }

    /// The adversary model this session runs under.
    #[inline]
    pub fn security(&self) -> Security {
        self.security
    }

    /// Whether authenticated opens are required (malicious tier).
    #[inline]
    pub fn malicious(&self) -> bool {
        self.security.malicious()
    }

    /// Whether the gate-per-flight baseline is active.
    #[inline]
    pub fn per_gate(&self) -> bool {
        matches!(self.policy, RoundPolicy::PerGate)
    }

    /// This party's index (0 or 1).
    #[inline]
    pub fn party(&self) -> usize {
        self.chan.party
    }

    /// Label subsequent communication with a metering phase.
    pub fn set_phase(&mut self, label: &str) {
        self.chan.set_phase(label);
    }

    /// Stage a symmetric reveal for the next flight; under
    /// [`RoundPolicy::PerGate`] the flight departs immediately.
    pub fn stage(&mut self, payload: Vec<u64>) -> usize {
        let handle = self.chan.stage_u64s(payload);
        if self.per_gate() {
            self.chan.flush_round();
        }
        handle
    }

    /// Ship every staged reveal in one flight (no-op when empty).
    pub fn flush(&mut self) {
        self.chan.flush_round();
    }

    /// Take a staged segment's (local, peer) reveal pair (post-flush).
    pub fn take(&mut self, handle: usize) -> (Vec<u64>, Vec<u64>) {
        self.chan.take_segment(handle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;

    #[test]
    fn per_gate_policy_flushes_each_stage() {
        let ((rounds_batched, rounds_pergate), _) = run_two_party(
            |c| {
                let mut ts = Dealer::new(1, 0);
                let mut s = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let a = s.stage(vec![1]);
                let b = s.stage(vec![2]);
                s.flush();
                let _ = s.take(a);
                let _ = s.take(b);
                let batched = s.chan.meter().total().rounds;
                let mut s = Session::new(
                    c,
                    &mut ts,
                    Prg::new(1),
                    SessionOptions::with_policy(RoundPolicy::PerGate),
                );
                let a = s.stage(vec![1]);
                let b = s.stage(vec![2]);
                let _ = s.take(a);
                let _ = s.take(b);
                let total = s.chan.meter().total().rounds;
                (batched, total - batched)
            },
            |c| {
                let mut ts = Dealer::new(1, 1);
                let mut s = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let a = s.stage(vec![3]);
                let b = s.stage(vec![4]);
                s.flush();
                let _ = s.take(a);
                let _ = s.take(b);
                let mut s = Session::new(
                    c,
                    &mut ts,
                    Prg::new(2),
                    SessionOptions::with_policy(RoundPolicy::PerGate),
                );
                let a = s.stage(vec![3]);
                let b = s.stage(vec![4]);
                let _ = s.take(a);
                let _ = s.take(b);
            },
        );
        assert_eq!(rounds_batched, 1, "coalesced: one flight for two segments");
        assert_eq!(rounds_pergate, 2, "per-gate: one flight per segment");
    }
}
