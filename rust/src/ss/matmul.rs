//! Secure matrix multiplication with matrix Beaver triples.
//!
//! The vectorized analogue of SMUL (paper §4.1): to compute `⟨A·B⟩` from
//! shares, parties reveal `E = A−U` and `F = B−V` in one round and
//! locally combine `⟨AB⟩ = [EF] + E⟨V⟩ + ⟨U⟩F + ⟨Z⟩`. Online traffic is
//! `|A|+|B|` ring elements per party per product — independent of the
//! inner dimension count that a naive per-element protocol would pay.
//!
//! Batch-first: [`ss_matmul_begin`] stages the reveal in the session's
//! round buffer and returns a [`Pending`] handle, so any number of
//! independent products (plus whatever else the caller staged) share
//! **one** flight; [`ss_matmul_many`] wraps the begin/flush/resolve
//! dance for a slice of products, and [`ss_matmul`] is the single-gate
//! wrapper. The reveal payload is assembled once into a preallocated
//! `|A|+|B|` buffer (the pre-batching code cloned `E` and re-extended it,
//! reallocating mid-flight).

use super::pending::Pending;
use super::triples::MatTriple;
use super::Session;
use crate::ring::matrix::Mat;
use crate::ss::share::{trivial_share_of_mine, trivial_share_of_theirs};

/// Stage `⟨A(m×k)⟩ · ⟨B(k×n)⟩` with an explicit triple; resolves to
/// `⟨AB⟩` after the next flush.
pub fn ss_matmul_begin_with_triple(
    ctx: &mut Session,
    a: &Mat,
    b: &Mat,
    t: MatTriple,
) -> Pending<Mat> {
    assert_eq!(a.cols, b.rows, "ss_matmul inner dim");
    assert_eq!(t.u.shape(), a.shape(), "triple U shape");
    assert_eq!(t.v.shape(), b.shape(), "triple V shape");
    // Reveal E = A−U and F = B−V: one preallocated payload, no
    // intermediate clones — the round buffer hands it back at resolve.
    // Both subtractions are packed lanewise sweeps (runtime::simd).
    let (ne, nf) = (a.len(), b.len());
    let mut payload = Vec::with_capacity(ne + nf);
    crate::runtime::simd::sub_words_into(&mut payload, &a.data, &t.u.data);
    crate::runtime::simd::sub_words_into(&mut payload, &b.data, &t.v.data);
    let (a_rows, a_cols) = a.shape();
    let (b_rows, b_cols) = b.shape();
    Pending::stage(ctx, payload, move |party, mine, theirs| {
        let mut e = Mat::zeros(a_rows, a_cols);
        let mut f = Mat::zeros(b_rows, b_cols);
        crate::runtime::simd::add_words(&mut e.data, &mine[..ne], &theirs[..ne]);
        crate::runtime::simd::add_words(&mut f.data, &mine[ne..], &theirs[ne..]);
        // ⟨AB⟩ = [party0] E·F + E·⟨V⟩ + ⟨U⟩·F + ⟨Z⟩
        // Large recombination products dispatch to the PJRT ring-matmul
        // artifact when available (runtime::dispatch).
        use crate::runtime::dispatch::matmul as mm;
        let mut out = mm(&e, &t.v).add(&mm(&t.u, &f)).add(&t.z);
        if party == 0 {
            out = out.add(&mm(&e, &f));
        }
        out
    })
}

/// Stage a shared-shared product, drawing the triple from the session's
/// offline source.
pub fn ss_matmul_begin(ctx: &mut Session, a: &Mat, b: &Mat) -> Pending<Mat> {
    assert_eq!(a.cols, b.rows, "ss_matmul inner dim");
    let t: MatTriple = ctx.ts.mat_triple(a.rows, a.cols, b.cols);
    ss_matmul_begin_with_triple(ctx, a, b, t)
}

/// Batch form: all products reveal in **one** flight.
pub fn ss_matmul_many(ctx: &mut Session, products: &[(&Mat, &Mat)]) -> Vec<Mat> {
    let pending: Vec<Pending<Mat>> =
        products.iter().map(|(a, b)| ss_matmul_begin(ctx, a, b)).collect();
    ctx.flush();
    pending.into_iter().map(|p| p.resolve(ctx)).collect()
}

/// `⟨A(m×k)⟩ · ⟨B(k×n)⟩ -> ⟨AB⟩` with one reveal round (single-gate
/// wrapper over the batch form).
pub fn ss_matmul(ctx: &mut Session, a: &Mat, b: &Mat) -> Mat {
    let p = ss_matmul_begin(ctx, a, b);
    ctx.flush();
    p.resolve(ctx)
}

/// Same as [`ss_matmul`] but with an explicitly provided triple — used
/// when the caller pre-fetched material for a batch of products. Takes
/// the triple by value: it is consumed by the recombination, and a
/// by-reference API would force a three-matrix clone per product.
pub fn ss_matmul_with_triple(ctx: &mut Session, a: &Mat, b: &Mat, t: MatTriple) -> Mat {
    let p = ss_matmul_begin_with_triple(ctx, a, b, t);
    ctx.flush();
    p.resolve(ctx)
}

/// Stage a private-input product: this party holds plaintext `X (m×k)`,
/// the peer holds plaintext `Y (k×n)`; both obtain shares of `XY`.
/// Implemented by feeding trivial shares into the Beaver protocol.
/// `x_is_mine` selects which operand this party owns.
pub fn private_matmul_begin(
    ctx: &mut Session,
    mine: &Mat,
    my_rows_cols: (usize, usize),
    their_rows_cols: (usize, usize),
    x_is_mine: bool,
) -> Pending<Mat> {
    assert_eq!(mine.shape(), my_rows_cols);
    if x_is_mine {
        let a = trivial_share_of_mine(mine);
        let b = trivial_share_of_theirs(their_rows_cols.0, their_rows_cols.1);
        ss_matmul_begin(ctx, &a, &b)
    } else {
        let a = trivial_share_of_theirs(their_rows_cols.0, their_rows_cols.1);
        let b = trivial_share_of_mine(mine);
        ss_matmul_begin(ctx, &a, &b)
    }
}

/// Row-block form of [`private_matmul_begin`] for tiled schedules: this
/// party holds the full plaintext `X`, but only rows `[r0, r1)` enter
/// the product, so the staged reveal is `(r1−r0)·cols + |Y|` elements
/// and the matrix triple is tile-shaped — never n-sized. The peer (who
/// holds `Y`) mirrors the tile by passing the same row count in its
/// `their_rows_cols`, keeping the flight symmetric. With `x_is_mine ==
/// false` this is a plain pass-through (the row dimension lives on the
/// peer's side and is already tiled in `their_rows_cols`).
pub fn private_matmul_rows_begin(
    ctx: &mut Session,
    mine: &Mat,
    rows: (usize, usize),
    their_rows_cols: (usize, usize),
    x_is_mine: bool,
) -> Pending<Mat> {
    if x_is_mine {
        if rows == (0, mine.rows) {
            // Full range: no slice copy for the monolithic schedule.
            private_matmul_begin(ctx, mine, mine.shape(), their_rows_cols, true)
        } else {
            let tile = mine.rows_slice(rows.0, rows.1);
            let shape = tile.shape();
            private_matmul_begin(ctx, &tile, shape, their_rows_cols, true)
        }
    } else {
        private_matmul_begin(ctx, mine, mine.shape(), their_rows_cols, false)
    }
}

/// Private-input product (single-gate wrapper).
pub fn private_matmul(
    ctx: &mut Session,
    mine: &Mat,
    my_rows_cols: (usize, usize),
    their_rows_cols: (usize, usize),
    x_is_mine: bool,
) -> Mat {
    let p = private_matmul_begin(ctx, mine, my_rows_cols, their_rows_cols, x_is_mine);
    ctx.flush();
    p.resolve(ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ss::share::{reconstruct, split};
    use crate::ss::Ctx;
    use crate::util::prng::Prg;

    fn mats() -> (Mat, Mat) {
        let a = Mat::from_vec(2, 3, vec![1, 2, 3, 4, 5, u64::MAX]);
        let b = Mat::from_vec(3, 2, vec![7, 8, 9, 10, 11, 12]);
        (a, b)
    }

    #[test]
    fn shared_shared_matmul_reconstructs() {
        let (a, b) = mats();
        let want = a.matmul(&b);
        let mut prg = Prg::new(5);
        let (a0, a1) = split(&a, &mut prg);
        let (b0, b1) = split(&b, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(9, 0);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(1));
                let z = ss_matmul(&mut ctx, &a0, &b0);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(9, 1);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(2));
                let z = ss_matmul(&mut ctx, &a1, &b1);
                reconstruct(c, &z)
            },
        );
        assert_eq!(r, want);
    }

    #[test]
    fn private_private_matmul() {
        let (a, b) = mats();
        let want = a.matmul(&b);
        let (ac, bc) = (a.clone(), b.clone());
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(10, 0);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(1));
                let z = private_matmul(&mut ctx, &ac, (2, 3), (3, 2), true);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(10, 1);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(2));
                let z = private_matmul(&mut ctx, &bc, (3, 2), (2, 3), false);
                reconstruct(c, &z)
            },
        );
        assert_eq!(r, want);
    }

    #[test]
    fn row_block_private_matmul_matches_slice() {
        // X rows [1, 3) of a 4×3 times a 3×2: the tile-shaped reveal must
        // reconstruct to exactly the sliced plaintext product.
        let a = Mat::from_vec(4, 3, vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, u64::MAX]);
        let b = Mat::from_vec(3, 2, vec![7, 8, 9, 10, 11, 12]);
        let want = a.rows_slice(1, 3).matmul(&b);
        let (ac, bc) = (a.clone(), b.clone());
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(12, 0);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(1));
                let p = private_matmul_rows_begin(&mut ctx, &ac, (1, 3), (3, 2), true);
                ctx.flush();
                let z = p.resolve(&mut ctx);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(12, 1);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(2));
                let p = private_matmul_rows_begin(&mut ctx, &bc, (0, 3), (2, 3), false);
                ctx.flush();
                let z = p.resolve(&mut ctx);
                reconstruct(c, &z)
            },
        );
        assert_eq!(r, want);
    }

    #[test]
    fn online_traffic_is_operand_sized() {
        // |A| + |B| = 6 + 6 elements = 96 bytes per party for the reveal.
        let (a, b) = mats();
        let mut prg = Prg::new(5);
        let (a0, a1) = split(&a, &mut prg);
        let (b0, b1) = split(&b, &mut prg);
        let ((_, m0), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(9, 0);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(1));
                ss_matmul(&mut ctx, &a0, &b0);
            },
            move |c| {
                let mut ts = Dealer::new(9, 1);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(2));
                ss_matmul(&mut ctx, &a1, &b1);
            },
        );
        assert_eq!(m0.total().bytes_sent, 96);
        assert_eq!(m0.total().rounds, 1);
    }

    #[test]
    fn batched_products_share_one_flight() {
        // Two independent products (and a third staged by hand) must cost
        // exactly one round under the coalescing policy.
        let (a, b) = mats();
        let mut prg = Prg::new(6);
        let (a0, a1) = split(&a, &mut prg);
        let (b0, b1) = split(&b, &mut prg);
        let want = a.matmul(&b);
        let ((out, m0), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(11, 0);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(1));
                let zs = ss_matmul_many(&mut ctx, &[(&a0, &b0), (&a0, &b0)]);
                let r: Vec<Mat> = zs.iter().map(|z| reconstruct(c, z)).collect();
                r
            },
            move |c| {
                let mut ts = Dealer::new(11, 1);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(2));
                let zs = ss_matmul_many(&mut ctx, &[(&a1, &b1), (&a1, &b1)]);
                let _: Vec<Mat> = zs.iter().map(|z| reconstruct(c, z)).collect();
            },
        );
        assert_eq!(out[0], want);
        assert_eq!(out[1], want);
        // ss_matmul_many flight + 2 reconstruct flights.
        assert_eq!(m0.total().rounds, 3);
    }
}
