//! Secure matrix multiplication with matrix Beaver triples.
//!
//! The vectorized analogue of SMUL (paper §4.1): to compute `⟨A·B⟩` from
//! shares, parties reveal `E = A−U` and `F = B−V` in one round and
//! locally combine `⟨AB⟩ = [EF] + E⟨V⟩ + ⟨U⟩F + ⟨Z⟩`. Online traffic is
//! `|A|+|B|` ring elements per party per product — independent of the
//! inner dimension count that a naive per-element protocol would pay.
//!
//! Batch-first: [`ss_matmul_begin`] stages the reveal in the session's
//! round buffer and returns a [`Pending`] handle, so any number of
//! independent products (plus whatever else the caller staged) share
//! **one** flight; [`ss_matmul_many`] wraps the begin/flush/resolve
//! dance for a slice of products, and [`ss_matmul`] is the single-gate
//! wrapper. The reveal payload is assembled once into a preallocated
//! `|A|+|B|` buffer (the pre-batching code cloned `E` and re-extended it,
//! reallocating mid-flight).

use super::pending::Pending;
use super::share::Share;
use super::triples::{AuthMatTriple, MatTriple};
use super::{Session, SessionOptions};
use crate::ring::matrix::Mat;
use crate::ss::share::{trivial_share_of_mine, trivial_share_of_theirs};
use crate::util::error::{Error, Result};

/// Stage `⟨A(m×k)⟩ · ⟨B(k×n)⟩` with an explicit triple; resolves to
/// `⟨AB⟩` after the next flush.
pub fn ss_matmul_begin_with_triple(
    ctx: &mut Session,
    a: &Mat,
    b: &Mat,
    t: MatTriple,
) -> Pending<Mat> {
    assert_eq!(a.cols, b.rows, "ss_matmul inner dim");
    assert_eq!(t.u.shape(), a.shape(), "triple U shape");
    assert_eq!(t.v.shape(), b.shape(), "triple V shape");
    // Reveal E = A−U and F = B−V: one preallocated payload, no
    // intermediate clones — the round buffer hands it back at resolve.
    // Both subtractions are packed lanewise sweeps (runtime::simd).
    let (ne, nf) = (a.len(), b.len());
    let mut payload = Vec::with_capacity(ne + nf);
    crate::runtime::simd::sub_words_into(&mut payload, &a.data, &t.u.data);
    crate::runtime::simd::sub_words_into(&mut payload, &b.data, &t.v.data);
    let (a_rows, a_cols) = a.shape();
    let (b_rows, b_cols) = b.shape();
    Pending::stage(ctx, payload, move |party, mine, theirs| {
        let mut e = Mat::zeros(a_rows, a_cols);
        let mut f = Mat::zeros(b_rows, b_cols);
        crate::runtime::simd::add_words(&mut e.data, &mine[..ne], &theirs[..ne]);
        crate::runtime::simd::add_words(&mut f.data, &mine[ne..], &theirs[ne..]);
        // ⟨AB⟩ = [party0] E·F + E·⟨V⟩ + ⟨U⟩·F + ⟨Z⟩
        // Large recombination products dispatch to the PJRT ring-matmul
        // artifact when available (runtime::dispatch).
        use crate::runtime::dispatch::matmul as mm;
        let mut out = mm(&e, &t.v).add(&mm(&t.u, &f)).add(&t.z);
        if party == 0 {
            out = out.add(&mm(&e, &f));
        }
        out
    })
}

/// Stage a shared-shared product, drawing the triple from the session's
/// offline source.
pub fn ss_matmul_begin(ctx: &mut Session, a: &Mat, b: &Mat) -> Pending<Mat> {
    assert_eq!(a.cols, b.rows, "ss_matmul inner dim");
    let t: MatTriple = ctx.ts.mat_triple(a.rows, a.cols, b.cols);
    ss_matmul_begin_with_triple(ctx, a, b, t)
}

/// Batch form: all products reveal in **one** flight.
pub fn ss_matmul_many(ctx: &mut Session, products: &[(&Mat, &Mat)]) -> Vec<Mat> {
    let pending: Vec<Pending<Mat>> =
        products.iter().map(|(a, b)| ss_matmul_begin(ctx, a, b)).collect();
    ctx.flush();
    pending.into_iter().map(|p| p.resolve(ctx)).collect()
}

/// `⟨A(m×k)⟩ · ⟨B(k×n)⟩ -> ⟨AB⟩` with one reveal round (single-gate
/// wrapper over the batch form).
pub fn ss_matmul(ctx: &mut Session, a: &Mat, b: &Mat) -> Mat {
    let p = ss_matmul_begin(ctx, a, b);
    ctx.flush();
    p.resolve(ctx)
}

/// Same as [`ss_matmul`] but with an explicitly provided triple — used
/// when the caller pre-fetched material for a batch of products. Takes
/// the triple by value: it is consumed by the recombination, and a
/// by-reference API would force a three-matrix clone per product.
pub fn ss_matmul_with_triple(ctx: &mut Session, a: &Mat, b: &Mat, t: MatTriple) -> Mat {
    let p = ss_matmul_begin_with_triple(ctx, a, b, t);
    ctx.flush();
    p.resolve(ctx)
}

/// A staged **authenticated** product awaiting its reveal (malicious
/// tier). Unlike the plain [`Pending`], resolution needs the session
/// back explicitly: the opened `E ‖ F` words must be folded into the
/// channel's deferred MAC ledger together with their `⟨α·E⟩`/`⟨α·F⟩`
/// limbs, which a closure over `(party, mine, theirs)` alone cannot
/// reach. The captured triple material is therefore carried openly.
pub struct PendingAuthMatmul {
    seg: usize,
    t: AuthMatTriple,
    /// `⟨α·E⟩ = ⟨α·A⟩ − ⟨α·U⟩` — authenticates the opened `E`.
    mac_e: Mat,
    /// `⟨α·F⟩ = ⟨α·B⟩ − ⟨α·V⟩` — authenticates the opened `F`.
    mac_f: Mat,
}

impl PendingAuthMatmul {
    /// Combine the peer's reveal into an authenticated output share and
    /// enter the opened words into the deferred ledger. Panics if no
    /// flush has shipped the staging flight yet.
    pub fn resolve(self, ctx: &mut Session) -> Share {
        let PendingAuthMatmul { seg, t, mac_e, mac_f } = self;
        let (mine, theirs) = ctx.take(seg);
        let (er, ec) = t.base.u.shape();
        let (fr, fc) = t.base.v.shape();
        let ne = er * ec;
        let mut e = Mat::zeros(er, ec);
        let mut f = Mat::zeros(fr, fc);
        crate::runtime::simd::add_words(&mut e.data, &mine[..ne], &theirs[..ne]);
        crate::runtime::simd::add_words(&mut f.data, &mine[ne..], &theirs[ne..]);
        // Every opened word enters the deferred ledger with its ⟨α·x⟩
        // limb: an additively forged operand share shifts σ_mac by a
        // nonzero multiple of α even though the wire frames were all
        // honest, so the next phase barrier aborts on both sides.
        ctx.chan.fold_opened(&e.data, &mac_e.data);
        ctx.chan.fold_opened(&f.data, &mac_f.data);
        use crate::runtime::dispatch::matmul as mm;
        let ef = mm(&e, &f);
        // ⟨AB⟩ = [party0] E·F + E·⟨V⟩ + ⟨U⟩·F + ⟨Z⟩, as in the plain gate.
        let mut v = mm(&e, &t.base.v).add(&mm(&t.base.u, &f)).add(&t.base.z);
        if ctx.party() == 0 {
            v = v.add(&ef);
        }
        // ⟨α·AB⟩ = α_i·(E·F) + E·⟨α·V⟩ + ⟨α·U⟩·F + ⟨α·Z⟩. `E·F` is
        // public, so each party contributes its own α-share of it — the
        // shares of α sum to the key, and the rest telescopes exactly
        // like the value recombination.
        let alpha = ctx.chan.mac_alpha().unwrap_or(0);
        let mac =
            ef.scale(alpha).add(&mm(&e, &t.mac_v)).add(&mm(&t.mac_u, &f)).add(&t.mac_z);
        Share::authed(v, mac)
    }
}

/// Stage `⟨A⟩·⟨B⟩` over authenticated shares, drawing MAC'd triple
/// material from the session's offline source. The reveal flight is
/// byte-identical to the semi-honest gate (`|A|+|B|` ring elements);
/// the MAC work is all local plus ledger folding, settled at the next
/// phase barrier. Fails fast if either operand lacks its MAC limb or
/// the channel ledger is unarmed.
pub fn auth_ss_matmul_begin(
    ctx: &mut Session,
    a: &Share,
    b: &Share,
) -> Result<PendingAuthMatmul> {
    assert_eq!(a.v.cols, b.v.rows, "auth_ss_matmul inner dim");
    let (ma, mb) = match (&a.mac, &b.mac) {
        (Some(x), Some(y)) => (x, y),
        _ => {
            return Err(Error::Protocol(
                "authenticated matmul needs MAC limbs on both operands".into(),
            ))
        }
    };
    if ctx.chan.mac_alpha().is_none() {
        return Err(Error::Config(
            "authenticated matmul over an unarmed channel — call Chan::enable_mac first"
                .into(),
        ));
    }
    let t = ctx.ts.auth_mat_triple(a.v.rows, a.v.cols, b.v.cols)?;
    let mac_e = ma.sub(&t.mac_u);
    let mac_f = mb.sub(&t.mac_v);
    let (ne, nf) = (a.v.len(), b.v.len());
    let mut payload = Vec::with_capacity(ne + nf);
    crate::runtime::simd::sub_words_into(&mut payload, &a.v.data, &t.base.u.data);
    crate::runtime::simd::sub_words_into(&mut payload, &b.v.data, &t.base.v.data);
    let seg = ctx.stage(payload);
    Ok(PendingAuthMatmul { seg, t, mac_e, mac_f })
}

/// Batch form over authenticated shares: all reveals share **one**
/// flight, exactly like [`ss_matmul_many`].
pub fn auth_ss_matmul_many(
    ctx: &mut Session,
    products: &[(&Share, &Share)],
) -> Result<Vec<Share>> {
    let pending: Result<Vec<PendingAuthMatmul>> =
        products.iter().map(|(a, b)| auth_ss_matmul_begin(ctx, a, b)).collect();
    let pending = pending?;
    ctx.flush();
    Ok(pending.into_iter().map(|p| p.resolve(ctx)).collect())
}

/// Single-gate wrapper over the authenticated batch form.
pub fn auth_ss_matmul(ctx: &mut Session, a: &Share, b: &Share) -> Result<Share> {
    let mut out = auth_ss_matmul_many(ctx, &[(a, b)])?;
    Ok(out.pop().expect("one staged product resolves to one share"))
}

/// Stage a private-input product: this party holds plaintext `X (m×k)`,
/// the peer holds plaintext `Y (k×n)`; both obtain shares of `XY`.
/// Implemented by feeding trivial shares into the Beaver protocol.
/// `x_is_mine` selects which operand this party owns.
pub fn private_matmul_begin(
    ctx: &mut Session,
    mine: &Mat,
    my_rows_cols: (usize, usize),
    their_rows_cols: (usize, usize),
    x_is_mine: bool,
) -> Pending<Mat> {
    assert_eq!(mine.shape(), my_rows_cols);
    if x_is_mine {
        let a = trivial_share_of_mine(mine);
        let b = trivial_share_of_theirs(their_rows_cols.0, their_rows_cols.1);
        ss_matmul_begin(ctx, &a, &b)
    } else {
        let a = trivial_share_of_theirs(their_rows_cols.0, their_rows_cols.1);
        let b = trivial_share_of_mine(mine);
        ss_matmul_begin(ctx, &a, &b)
    }
}

/// Row-block form of [`private_matmul_begin`] for tiled schedules: this
/// party holds the full plaintext `X`, but only rows `[r0, r1)` enter
/// the product, so the staged reveal is `(r1−r0)·cols + |Y|` elements
/// and the matrix triple is tile-shaped — never n-sized. The peer (who
/// holds `Y`) mirrors the tile by passing the same row count in its
/// `their_rows_cols`, keeping the flight symmetric. With `x_is_mine ==
/// false` this is a plain pass-through (the row dimension lives on the
/// peer's side and is already tiled in `their_rows_cols`).
pub fn private_matmul_rows_begin(
    ctx: &mut Session,
    mine: &Mat,
    rows: (usize, usize),
    their_rows_cols: (usize, usize),
    x_is_mine: bool,
) -> Pending<Mat> {
    if x_is_mine {
        if rows == (0, mine.rows) {
            // Full range: no slice copy for the monolithic schedule.
            private_matmul_begin(ctx, mine, mine.shape(), their_rows_cols, true)
        } else {
            let tile = mine.rows_slice(rows.0, rows.1);
            let shape = tile.shape();
            private_matmul_begin(ctx, &tile, shape, their_rows_cols, true)
        }
    } else {
        private_matmul_begin(ctx, mine, mine.shape(), their_rows_cols, false)
    }
}

/// Private-input product (single-gate wrapper).
pub fn private_matmul(
    ctx: &mut Session,
    mine: &Mat,
    my_rows_cols: (usize, usize),
    their_rows_cols: (usize, usize),
    x_is_mine: bool,
) -> Mat {
    let p = private_matmul_begin(ctx, mine, my_rows_cols, their_rows_cols, x_is_mine);
    ctx.flush();
    p.resolve(ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::{mac_key_share, Dealer};
    use crate::ss::share::{auth_split, open_auth, reconstruct, split};
    use crate::ss::{Security, Session};
    use crate::util::prng::Prg;

    fn mats() -> (Mat, Mat) {
        let a = Mat::from_vec(2, 3, vec![1, 2, 3, 4, 5, u64::MAX]);
        let b = Mat::from_vec(3, 2, vec![7, 8, 9, 10, 11, 12]);
        (a, b)
    }

    #[test]
    fn shared_shared_matmul_reconstructs() {
        let (a, b) = mats();
        let want = a.matmul(&b);
        let mut prg = Prg::new(5);
        let (a0, a1) = split(&a, &mut prg);
        let (b0, b1) = split(&b, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(9, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let z = ss_matmul(&mut ctx, &a0, &b0);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(9, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let z = ss_matmul(&mut ctx, &a1, &b1);
                reconstruct(c, &z)
            },
        );
        assert_eq!(r, want);
    }

    #[test]
    fn private_private_matmul() {
        let (a, b) = mats();
        let want = a.matmul(&b);
        let (ac, bc) = (a.clone(), b.clone());
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(10, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let z = private_matmul(&mut ctx, &ac, (2, 3), (3, 2), true);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(10, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let z = private_matmul(&mut ctx, &bc, (3, 2), (2, 3), false);
                reconstruct(c, &z)
            },
        );
        assert_eq!(r, want);
    }

    #[test]
    fn row_block_private_matmul_matches_slice() {
        // X rows [1, 3) of a 4×3 times a 3×2: the tile-shaped reveal must
        // reconstruct to exactly the sliced plaintext product.
        let a = Mat::from_vec(4, 3, vec![1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, u64::MAX]);
        let b = Mat::from_vec(3, 2, vec![7, 8, 9, 10, 11, 12]);
        let want = a.rows_slice(1, 3).matmul(&b);
        let (ac, bc) = (a.clone(), b.clone());
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(12, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let p = private_matmul_rows_begin(&mut ctx, &ac, (1, 3), (3, 2), true);
                ctx.flush();
                let z = p.resolve(&mut ctx);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(12, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let p = private_matmul_rows_begin(&mut ctx, &bc, (0, 3), (2, 3), false);
                ctx.flush();
                let z = p.resolve(&mut ctx);
                reconstruct(c, &z)
            },
        );
        assert_eq!(r, want);
    }

    #[test]
    fn online_traffic_is_operand_sized() {
        // |A| + |B| = 6 + 6 elements = 96 bytes per party for the reveal.
        let (a, b) = mats();
        let mut prg = Prg::new(5);
        let (a0, a1) = split(&a, &mut prg);
        let (b0, b1) = split(&b, &mut prg);
        let ((_, m0), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(9, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                ss_matmul(&mut ctx, &a0, &b0);
            },
            move |c| {
                let mut ts = Dealer::new(9, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                ss_matmul(&mut ctx, &a1, &b1);
            },
        );
        assert_eq!(m0.total().bytes_sent, 96);
        assert_eq!(m0.total().rounds, 1);
    }

    #[test]
    fn batched_products_share_one_flight() {
        // Two independent products (and a third staged by hand) must cost
        // exactly one round under the coalescing policy.
        let (a, b) = mats();
        let mut prg = Prg::new(6);
        let (a0, a1) = split(&a, &mut prg);
        let (b0, b1) = split(&b, &mut prg);
        let want = a.matmul(&b);
        let ((out, m0), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(11, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let zs = ss_matmul_many(&mut ctx, &[(&a0, &b0), (&a0, &b0)]);
                let r: Vec<Mat> = zs.iter().map(|z| reconstruct(c, z)).collect();
                r
            },
            move |c| {
                let mut ts = Dealer::new(11, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let zs = ss_matmul_many(&mut ctx, &[(&a1, &b1), (&a1, &b1)]);
                let _: Vec<Mat> = zs.iter().map(|z| reconstruct(c, z)).collect();
            },
        );
        assert_eq!(out[0], want);
        assert_eq!(out[1], want);
        // ss_matmul_many flight + 2 reconstruct flights.
        assert_eq!(m0.total().rounds, 3);
    }

    /// Dealer seed, ledger seed, and this party's α-share for the
    /// authenticated-gate tests. Both parties derive their α from the
    /// same dealer stream the auth triples are MAC'd under.
    fn auth_fixture(party: usize) -> (u128, u128, u64) {
        let dealer_seed = 0x7A11_u128;
        (dealer_seed, 0x1ED6_E5_u128, mac_key_share(dealer_seed, party))
    }

    #[test]
    fn auth_matmul_reconstructs_and_passes_the_barrier() {
        let (a, b) = mats();
        let want = a.matmul(&b);
        let (seed, ledger_seed, _) = auth_fixture(0);
        let alpha = mac_key_share(seed, 0).wrapping_add(mac_key_share(seed, 1));
        let mut prg = Prg::new(0x5EED);
        let (a0, a1) = auth_split(&a, alpha, &mut prg);
        let (b0, b1) = auth_split(&b, alpha, &mut prg);
        let (((out, barrier), _), ((_, peer_barrier), _)) = run_two_party(
            move |c| {
                let (seed, ledger_seed, alpha0) = auth_fixture(0);
                c.enable_mac(alpha0, ledger_seed);
                let mut ts = Dealer::new(seed, 0);
                let mut ctx = Session::new(
                    c,
                    &mut ts,
                    Prg::new(1),
                    SessionOptions::with_security(Security::Malicious),
                );
                let z = auth_ss_matmul(&mut ctx, &a0, &b0).unwrap();
                assert!(z.is_authed(), "auth gate must emit a MAC limb");
                let opened = open_auth(c, &z);
                (opened, c.mac_barrier("matmul").is_ok())
            },
            move |c| {
                let (seed, _, alpha1) = auth_fixture(1);
                c.enable_mac(alpha1, ledger_seed);
                let mut ts = Dealer::new(seed, 1);
                let mut ctx = Session::new(
                    c,
                    &mut ts,
                    Prg::new(2),
                    SessionOptions::with_security(Security::Malicious),
                );
                let z = auth_ss_matmul(&mut ctx, &a1, &b1).unwrap();
                let opened = open_auth(c, &z);
                (opened, c.mac_barrier("matmul").is_ok())
            },
        );
        assert_eq!(out, want);
        assert!(barrier, "clean authenticated product must pass the ledger check");
        assert!(peer_barrier);
    }

    #[test]
    fn forged_auth_product_fails_the_barrier_on_both_parties() {
        // Party 1 adds 1 to its share of the *product* before opening —
        // an additive attack the wire RLC cannot see (the forged frame
        // is the genuine bytes it sent). The SPDZ limb catches it: the
        // opened word no longer matches its α·value MAC, shifting
        // σ_mac by −α, and both parties abort typed at the barrier.
        let (a, b) = mats();
        let (seed, ledger_seed, _) = auth_fixture(0);
        let alpha = mac_key_share(seed, 0).wrapping_add(mac_key_share(seed, 1));
        let mut prg = Prg::new(0x5EED);
        let (a0, a1) = auth_split(&a, alpha, &mut prg);
        let (b0, b1) = auth_split(&b, alpha, &mut prg);
        let ((r0, _), (r1, _)) = run_two_party(
            move |c| {
                let (seed, ledger_seed, alpha0) = auth_fixture(0);
                c.enable_mac(alpha0, ledger_seed);
                let mut ts = Dealer::new(seed, 0);
                let mut ctx = Session::new(
                    c,
                    &mut ts,
                    Prg::new(1),
                    SessionOptions::with_security(Security::Malicious),
                );
                let z = auth_ss_matmul(&mut ctx, &a0, &b0).unwrap();
                let _ = open_auth(c, &z);
                c.mac_barrier("matmul")
            },
            move |c| {
                let (seed, _, alpha1) = auth_fixture(1);
                c.enable_mac(alpha1, ledger_seed);
                let mut ts = Dealer::new(seed, 1);
                let mut ctx = Session::new(
                    c,
                    &mut ts,
                    Prg::new(2),
                    SessionOptions::with_security(Security::Malicious),
                );
                let z = auth_ss_matmul(&mut ctx, &a1, &b1).unwrap();
                let forged = Share {
                    v: z.v.map(|w| w.wrapping_add(1)),
                    mac: z.mac.clone(),
                };
                let _ = open_auth(c, &forged);
                c.mac_barrier("matmul")
            },
        );
        for r in [r0, r1] {
            match r {
                Err(Error::MacCheck(msg)) => {
                    assert!(msg.contains("matmul"), "abort must name the phase: {msg}")
                }
                other => panic!("expected a typed MacCheck abort, got {other:?}"),
            }
        }
    }

    #[test]
    fn auth_batch_shares_one_flight_and_matches_semi_honest_bytes() {
        // Two authenticated products reveal in one flight whose payload
        // is byte-identical to the semi-honest gate (2 × 96 bytes); the
        // only malicious-tier traffic is the 96-byte/party barrier.
        let (a, b) = mats();
        let want = a.matmul(&b);
        let (seed, ledger_seed, _) = auth_fixture(0);
        let alpha = mac_key_share(seed, 0).wrapping_add(mac_key_share(seed, 1));
        let mut prg = Prg::new(0x5EED);
        let (a0, a1) = auth_split(&a, alpha, &mut prg);
        let (b0, b1) = auth_split(&b, alpha, &mut prg);
        let ((sums, m0), _) = run_two_party(
            move |c| {
                let (seed, ledger_seed, alpha0) = auth_fixture(0);
                c.enable_mac(alpha0, ledger_seed);
                let mut ts = Dealer::new(seed, 0);
                let mut ctx = Session::new(
                    c,
                    &mut ts,
                    Prg::new(1),
                    SessionOptions::with_security(Security::Malicious),
                );
                let zs =
                    auth_ss_matmul_many(&mut ctx, &[(&a0, &b0), (&a0, &b0)]).unwrap();
                let opened: Vec<Mat> = zs.iter().map(|z| open_auth(c, z)).collect();
                c.mac_barrier("matmul").unwrap();
                opened
            },
            move |c| {
                let (seed, _, alpha1) = auth_fixture(1);
                c.enable_mac(alpha1, ledger_seed);
                let mut ts = Dealer::new(seed, 1);
                let mut ctx = Session::new(
                    c,
                    &mut ts,
                    Prg::new(2),
                    SessionOptions::with_security(Security::Malicious),
                );
                let zs =
                    auth_ss_matmul_many(&mut ctx, &[(&a1, &b1), (&a1, &b1)]).unwrap();
                let _: Vec<Mat> = zs.iter().map(|z| open_auth(c, z)).collect();
                c.mac_barrier("matmul").unwrap();
            },
        );
        assert_eq!(sums[0], want);
        assert_eq!(sums[1], want);
        let t = m0.total();
        // 1 reveal flight + 2 opens + 3 barrier flights.
        assert_eq!(t.rounds, 6);
        // 2×96 reveal + 2×32 opens + 96 barrier.
        assert_eq!(t.bytes_sent, 2 * 96 + 2 * 32 + 96);
    }

    #[test]
    fn auth_matmul_demands_armed_channel_and_mac_limbs() {
        let (a, b) = mats();
        let mut prg = Prg::new(0x5EED);
        let (a0, _) = split(&a, &mut prg);
        let (b0, _) = split(&b, &mut prg);
        let (aa, _) = auth_split(&a, 3, &mut prg);
        let (bb, _) = auth_split(&b, 3, &mut prg);
        let (((plain_err, unarmed_err), _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(3, 0);
                let mut ctx =
                    Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                // Plain (un-MAC'd) operands are rejected outright, and
                // authenticated operands over an unarmed channel are a
                // config error — both fail before staging any flight.
                let plain =
                    auth_ss_matmul_begin(&mut ctx, &Share::plain(a0), &Share::plain(b0));
                let unarmed = auth_ss_matmul_begin(&mut ctx, &aa, &bb);
                (
                    matches!(plain, Err(Error::Protocol(_))),
                    matches!(unarmed, Err(Error::Config(_))),
                )
            },
            |_c| {},
        );
        assert!(plain_err, "plain operands must be rejected by the authenticated gate");
        assert!(unarmed_err, "an unarmed channel must be rejected before staging");
    }
}
