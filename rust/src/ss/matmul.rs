//! Secure matrix multiplication with matrix Beaver triples.
//!
//! The vectorized analogue of SMUL (paper §4.1): to compute `⟨A·B⟩` from
//! shares, parties reveal `E = A−U` and `F = B−V` in one round and
//! locally combine `⟨AB⟩ = [EF] + E⟨V⟩ + ⟨U⟩F + ⟨Z⟩`. Online traffic is
//! `|A|+|B|` ring elements per party per product — independent of the
//! inner dimension count that a naive per-element protocol would pay.

use super::triples::MatTriple;
use super::Ctx;
use crate::ring::matrix::Mat;
use crate::ss::share::{trivial_share_of_mine, trivial_share_of_theirs};

/// `⟨A(m×k)⟩ · ⟨B(k×n)⟩ -> ⟨AB⟩` with one reveal round.
pub fn ss_matmul(ctx: &mut Ctx, a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "ss_matmul inner dim");
    let t: MatTriple = ctx.ts.mat_triple(a.rows, a.cols, b.cols);
    ss_matmul_with_triple(ctx, a, b, &t)
}

/// Same as [`ss_matmul`] but with an explicitly provided triple — used
/// when the caller pre-fetched material for a batch of products.
pub fn ss_matmul_with_triple(ctx: &mut Ctx, a: &Mat, b: &Mat, t: &MatTriple) -> Mat {
    assert_eq!(t.u.shape(), a.shape(), "triple U shape");
    assert_eq!(t.v.shape(), b.shape(), "triple V shape");
    let e_share = a.sub(&t.u);
    let f_share = b.sub(&t.v);
    // Reveal E and F in a single flight.
    let mut payload = e_share.data.clone();
    payload.extend_from_slice(&f_share.data);
    let theirs = ctx.chan.exchange_u64s(&payload);
    let (ne, _nf) = (e_share.len(), f_share.len());
    let mut e = e_share;
    let mut f = f_share;
    for i in 0..e.data.len() {
        e.data[i] = e.data[i].wrapping_add(theirs[i]);
    }
    for i in 0..f.data.len() {
        f.data[i] = f.data[i].wrapping_add(theirs[ne + i]);
    }
    // ⟨AB⟩ = [party0] E·F + E·⟨V⟩ + ⟨U⟩·F + ⟨Z⟩
    // Large recombination products dispatch to the PJRT ring-matmul
    // artifact when available (runtime::dispatch).
    use crate::runtime::dispatch::matmul as mm;
    let mut out = mm(&e, &t.v).add(&mm(&t.u, &f)).add(&t.z);
    if ctx.party() == 0 {
        out = out.add(&mm(&e, &f));
    }
    out
}

/// Private-input product: this party holds plaintext `X (m×k)`, the peer
/// holds plaintext `Y (k×n)`; both obtain shares of `XY`. Implemented by
/// feeding trivial shares into the Beaver protocol. `x_is_mine` selects
/// which operand this party owns.
pub fn private_matmul(
    ctx: &mut Ctx,
    mine: &Mat,
    my_rows_cols: (usize, usize),
    their_rows_cols: (usize, usize),
    x_is_mine: bool,
) -> Mat {
    if x_is_mine {
        assert_eq!(mine.shape(), my_rows_cols);
        let a = trivial_share_of_mine(mine);
        let b = trivial_share_of_theirs(their_rows_cols.0, their_rows_cols.1);
        ss_matmul(ctx, &a, &b)
    } else {
        assert_eq!(mine.shape(), my_rows_cols);
        let a = trivial_share_of_theirs(their_rows_cols.0, their_rows_cols.1);
        let b = trivial_share_of_mine(mine);
        ss_matmul(ctx, &a, &b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ss::share::{reconstruct, split};
    use crate::util::prng::Prg;

    fn mats() -> (Mat, Mat) {
        let a = Mat::from_vec(2, 3, vec![1, 2, 3, 4, 5, u64::MAX]);
        let b = Mat::from_vec(3, 2, vec![7, 8, 9, 10, 11, 12]);
        (a, b)
    }

    #[test]
    fn shared_shared_matmul_reconstructs() {
        let (a, b) = mats();
        let want = a.matmul(&b);
        let mut prg = Prg::new(5);
        let (a0, a1) = split(&a, &mut prg);
        let (b0, b1) = split(&b, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(9, 0);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(1));
                let z = ss_matmul(&mut ctx, &a0, &b0);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(9, 1);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(2));
                let z = ss_matmul(&mut ctx, &a1, &b1);
                reconstruct(c, &z)
            },
        );
        assert_eq!(r, want);
    }

    #[test]
    fn private_private_matmul() {
        let (a, b) = mats();
        let want = a.matmul(&b);
        let (ac, bc) = (a.clone(), b.clone());
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(10, 0);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(1));
                let z = private_matmul(&mut ctx, &ac, (2, 3), (3, 2), true);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(10, 1);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(2));
                let z = private_matmul(&mut ctx, &bc, (3, 2), (2, 3), false);
                reconstruct(c, &z)
            },
        );
        assert_eq!(r, want);
    }

    #[test]
    fn online_traffic_is_operand_sized() {
        // |A| + |B| = 6 + 6 elements = 96 bytes per party for the reveal.
        let (a, b) = mats();
        let mut prg = Prg::new(5);
        let (a0, a1) = split(&a, &mut prg);
        let (b0, b1) = split(&b, &mut prg);
        let ((_, m0), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(9, 0);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(1));
                ss_matmul(&mut ctx, &a0, &b0);
            },
            move |c| {
                let mut ts = Dealer::new(9, 1);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(2));
                ss_matmul(&mut ctx, &a1, &b1);
            },
        );
        assert_eq!(m0.total().bytes_sent, 96);
        assert_eq!(m0.total().rounds, 1);
    }
}
