//! Secure division on shares: `⟨num / den⟩` for positive integer
//! denominators (cluster counts in the centroid update).
//!
//! The paper converts division to "secure multiplication and addition";
//! we implement the standard Catrina-Saxena-style pipeline:
//!
//! 1. **Normalize** the divisor into [0.5, 1): A2B the count, suffix-OR
//!    its bit planes to locate the top set bit, B2A the one-hot indicator
//!    and take an inner product with public powers of two to obtain the
//!    scaling factor ⟨v⟩ with `d·v ∈ [0.5, 1)`.
//! 2. **Newton-Raphson**: `w₀ = 2.9142 − 2·d̂` (public affine), then
//!    `w ← w(2 − d̂·w)` — quadratic convergence, 4 iterations ≫ 20-bit
//!    precision.
//! 3. **Recombine**: `1/d = v·w`, then multiply the numerator.
//!
//! Everything is vectorized: one call divides all k lanes (clusters) in
//! parallel, and all bit-plane protocols batch their AND layers.

use super::arith::smul_elem;
use super::boolean::{a2b, and_many, b2a, BoolShare};
use super::trunc::trunc_share;
use super::{Session, SessionOptions};
use crate::ring::fixed::FRAC_BITS;
use crate::ring::matrix::Mat;

/// Number of Newton-Raphson iterations (each squares the error).
const NR_ITERS: usize = 4;

/// Suffix-OR of 64 bit planes: out[j] = OR(bits[j..64)). Log-depth with
/// batched AND layers (OR(a,b) = a ⊕ b ⊕ a∧b).
fn suffix_or(ctx: &mut Session, planes: &[BoolShare]) -> Vec<BoolShare> {
    let mut h: Vec<BoolShare> = planes.to_vec();
    let l = h.len();
    let mut s = 1;
    while s < l {
        // h'[j] = OR(h[j], h[j+s]) for j + s < l
        let pairs: Vec<(&BoolShare, &BoolShare)> =
            (0..l - s).map(|j| (&h[j], &h[j + s])).collect();
        let ands = and_many(ctx, &pairs);
        for j in 0..l - s {
            h[j] = h[j].xor(&h[j + s]).xor(&ands[j]);
        }
        s *= 2;
    }
    h
}

/// Secret-shared reciprocal of positive integer lanes: given ⟨d⟩ with
/// `1 ≤ d < 2^(2f−1)` **encoded unscaled**, returns ⟨1/d⟩ at scale f.
pub fn reciprocal_int(ctx: &mut Session, d: &Mat) -> Mat {
    let n = d.len();
    let party = ctx.party();
    let f = FRAC_BITS;

    // 1) bit planes of d, suffix-OR, one-hot top-bit indicator.
    let planes = a2b(ctx, d);
    let h = suffix_or(ctx, &planes);
    // e[j] = h[j] ^ h[j+1] (top plane: e[63] = h[63]).
    let mut e: Vec<BoolShare> = Vec::with_capacity(64);
    for j in 0..64 {
        if j + 1 < 64 {
            e.push(h[j].xor(&h[j + 1]));
        } else {
            e.push(h[63].clone());
        }
    }
    // Lift all planes in one B2A round. Only planes j < 2f−1 matter:
    // divisors are bounded by 2^(2f−1) (counts ≪ 2^39 at f = 20).
    let planes_used = (2 * f - 1) as usize;
    let concat = BoolShare::concat(&e[..planes_used].iter().collect::<Vec<_>>());
    let lifted = b2a(ctx, &concat);
    // v = Σ_j 2^(2f−1−j)·e[j] (scale 2f so tiny factors stay integral).
    let mut v = Mat::zeros(d.rows, d.cols);
    for j in 0..planes_used {
        let coef = 1u64 << (2 * f as i64 - 1 - j as i64);
        for i in 0..n {
            let bit = lifted.data[j * n + i];
            v.data[i] = v.data[i].wrapping_add(bit.wrapping_mul(coef));
        }
    }

    // 2) d_norm = d·v : scale 2f (d integer), truncate to scale f → [0.5,1).
    let dn2f = smul_elem(ctx, d, &v);
    let dnorm = trunc_share(party, &dn2f, f);

    // w0 = 2.9142 − 2·d_norm (public affine, scale f).
    let c29142 = ((2.9142 * (1u64 << f) as f64) as i64) as u64;
    let mut w = dnorm.map(|x| x.wrapping_mul(2).wrapping_neg());
    if party == 0 {
        for x in w.data.iter_mut() {
            *x = x.wrapping_add(c29142);
        }
    }
    // NR: w ← w(2 − d_norm·w), all at scale f with one truncation per mul.
    let two = (2u64) << f;
    for _ in 0..NR_ITERS {
        let t2f = smul_elem(ctx, &dnorm, &w);
        let t = trunc_share(party, &t2f, f);
        let mut corr = t.neg();
        if party == 0 {
            for x in corr.data.iter_mut() {
                *x = x.wrapping_add(two);
            }
        }
        let w2f = smul_elem(ctx, &w, &corr);
        w = trunc_share(party, &w2f, f);
    }

    // 3) 1/d = 2^{−1−j}·w = Σ_j e_j·(w ≫ (1+j)) — recombining with
    // *public* shifts instead of multiplying by the huge ⟨v⟩ keeps every
    // truncated value small (w ≈ 2^f), so the SecureML truncation
    // failure probability stays ≈ 2^{−42} instead of ≈ 2^{−5} for the
    // naive v·w at magnitude ~2^58 (observed to corrupt runs).
    let mut sel = Mat::zeros(1, planes_used * n);
    let mut val = Mat::zeros(1, planes_used * n);
    for j in 0..planes_used {
        let sj = trunc_share(party, &w, (1 + j) as u32);
        for i in 0..n {
            sel.data[j * n + i] = lifted.data[j * n + i];
            val.data[j * n + i] = sj.data[i];
        }
    }
    let prods = smul_elem(ctx, &sel, &val);
    let mut out = Mat::zeros(d.rows, d.cols);
    for j in 0..planes_used {
        for i in 0..n {
            out.data[i] = out.data[i].wrapping_add(prods.data[j * n + i]);
        }
    }
    out
}

/// `⟨num / den⟩` where `num` is at scale f and `den` holds positive
/// integers (unscaled). Output at scale f. Shapes must match.
pub fn divide(ctx: &mut Session, num: &Mat, den: &Mat) -> Mat {
    assert_eq!(num.shape(), den.shape());
    let recip = reciprocal_int(ctx, den);
    let prod = smul_elem(ctx, num, &recip);
    trunc_share(ctx.party(), &prod, FRAC_BITS)
}

/// Divide each *row element* of `num (k×d)` by the corresponding lane of
/// `den (1×k)` — the broadcasting division of the centroid update
/// `μ_j = Σ C_ij X_i / Σ C_ij`.
pub fn divide_rows(ctx: &mut Session, num: &Mat, den: &Mat) -> Mat {
    assert_eq!(den.len(), num.rows, "one denominator per numerator row");
    let recip = reciprocal_int(ctx, den); // 1×k at scale f
    // Broadcast reciprocal across row elements, single elementwise mul.
    let mut expanded = Mat::zeros(num.rows, num.cols);
    for r in 0..num.rows {
        for c in 0..num.cols {
            expanded.data[r * num.cols + c] = recip.data[r];
        }
    }
    let prod = smul_elem(ctx, num, &expanded);
    trunc_share(ctx.party(), &prod, FRAC_BITS)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ring::fixed::{decode_f64, encode_f64};
    use crate::ss::share::{reconstruct, split};
    use crate::util::prng::Prg;

    fn run_recip(ds: Vec<u64>) -> Vec<f64> {
        let n = ds.len();
        let mut prg = Prg::new(70);
        let (d0, d1) = split(&Mat::from_vec(1, n, ds), &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(71, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let z = reciprocal_int(&mut ctx, &d0);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(71, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let z = reciprocal_int(&mut ctx, &d1);
                reconstruct(c, &z)
            },
        );
        r.data.iter().map(|&w| decode_f64(w)).collect()
    }

    #[test]
    fn reciprocal_of_small_and_large_counts() {
        let ds = vec![1u64, 2, 3, 7, 10, 100, 1000, 123456];
        let got = run_recip(ds.clone());
        for (i, &d) in ds.iter().enumerate() {
            let want = 1.0 / d as f64;
            let tol = (want * 1e-3).max(4.0 / (1u64 << FRAC_BITS) as f64);
            assert!((got[i] - want).abs() < tol, "d={d} got={} want={want}", got[i]);
        }
    }

    #[test]
    fn divide_rows_matches_plaintext() {
        // num: 2x3 at scale f; den: counts [4, 5]
        let numf = [8.0, 2.0, -6.0, 10.0, 5.0, 2.5];
        let num = Mat::from_vec(2, 3, numf.iter().map(|&x| encode_f64(x)).collect());
        let den = Mat::from_vec(1, 2, vec![4, 5]);
        let mut prg = Prg::new(72);
        let (n0, n1) = split(&num, &mut prg);
        let (d0, d1) = split(&den, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(73, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let z = divide_rows(&mut ctx, &n0, &d0);
                reconstruct(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(73, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let z = divide_rows(&mut ctx, &n1, &d1);
                reconstruct(c, &z)
            },
        );
        let got: Vec<f64> = r.data.iter().map(|&w| decode_f64(w)).collect();
        let want = [2.0, 0.5, -1.5, 2.0, 1.0, 0.5];
        for i in 0..6 {
            assert!((got[i] - want[i]).abs() < 1e-3, "i={i} got={} want={}", got[i], want[i]);
        }
    }
}
