//! Sharing and reconstruction of ring matrices.
//!
//! `Shr_i(x)`: the owner splits `x` into uniform shares summing to `x`
//! mod 2^64 and transmits the other party's share. `Rec(x)`: parties
//! exchange shares and add. Between those two moments every value in the
//! protocol is a uniformly distributed share (see the paper's §3.1).
//!
//! ## Authenticated shares (malicious tier)
//!
//! [`Share`] is the generic share of the redesigned API: the additive
//! value share plus an *optional* SPDZ MAC limb — a share of `α·x` under
//! the global MAC key α (see `offline::dealer::mac_key_share`). Under
//! [`crate::net::Security::SemiHonest`] the limb is `None` and every
//! code path below is byte-identical to the plain functions; under
//! `Malicious`, [`open_auth`] folds each opened word and its limb into
//! the channel's deferred ledger, verified wholesale at the next
//! [`Chan::mac_barrier`], and [`reconstruct_committed`] adds a
//! commit-then-reveal exchange for final outputs so neither party can
//! choose its share after seeing the other's.

use crate::net::Chan;
use crate::ring::matrix::Mat;
use crate::util::error::{Error, Result};
use crate::util::hash::hash256;
use crate::util::prng::Prg;

/// Split a matrix into two additive shares using `prg` for share 0.
pub fn split(x: &Mat, prg: &mut Prg) -> (Mat, Mat) {
    let s0 = Mat::random(x.rows, x.cols, prg);
    let s1 = x.sub(&s0);
    (s0, s1)
}

/// Owner-side input sharing: keep one share, send the other.
pub fn share_input_owner(chan: &mut Chan, x: &Mat, prg: &mut Prg) -> Mat {
    let (mine, theirs) = split(x, prg);
    chan.send_mat(&theirs);
    mine
}

/// Receiver side of input sharing.
pub fn share_input_recv(chan: &mut Chan, rows: usize, cols: usize) -> Mat {
    chan.recv_mat(rows, cols)
}

/// The trivial sharing of a locally-held plaintext: `⟨x⟩_me = x`,
/// `⟨x⟩_other = 0`. No communication; used to feed private inputs into
/// Beaver multiplications.
pub fn trivial_share_of_mine(x: &Mat) -> Mat {
    x.clone()
}

/// The trivial share corresponding to the *other* party's private input.
pub fn trivial_share_of_theirs(rows: usize, cols: usize) -> Mat {
    Mat::zeros(rows, cols)
}

/// Reconstruct a shared matrix at both parties (one symmetric exchange).
pub fn reconstruct(chan: &mut Chan, share: &Mat) -> Mat {
    let other = chan.exchange_mat(share);
    share.add(&other)
}

/// Reconstruct toward one party only: `target` learns the value, the
/// other party learns nothing and returns `None`.
pub fn reconstruct_to(chan: &mut Chan, share: &Mat, target: usize) -> Option<Mat> {
    if chan.party == target {
        let other = chan.recv_mat(share.rows, share.cols);
        Some(share.add(&other))
    } else {
        chan.send_mat(share);
        None
    }
}

// ---- Authenticated shares (malicious tier) ----------------------------

/// A generic share: the additive value share plus an optional MAC limb
/// (share of `α·x`). `mac: None` is a semi-honest share; every operation
/// on it is byte-identical to the plain [`Mat`] path.
#[derive(Debug, Clone)]
pub struct Share {
    /// Additive share of the value.
    pub v: Mat,
    /// Additive share of `α·value` (MAC limb), present iff authenticated.
    pub mac: Option<Mat>,
}

impl Share {
    /// Wrap a plain (unauthenticated) share.
    pub fn plain(v: Mat) -> Share {
        Share { v, mac: None }
    }

    /// Wrap an authenticated share with its MAC limb.
    pub fn authed(v: Mat, mac: Mat) -> Share {
        debug_assert_eq!(v.shape(), mac.shape(), "MAC limb must match the value shape");
        Share { v, mac: Some(mac) }
    }

    /// Whether this share carries a MAC limb.
    pub fn is_authed(&self) -> bool {
        self.mac.is_some()
    }

    /// Local addition: value shares and MAC limbs add independently
    /// (both sides must agree on authentication; mixing drops to plain).
    pub fn add(&self, o: &Share) -> Share {
        Share {
            v: self.v.add(&o.v),
            mac: match (&self.mac, &o.mac) {
                (Some(a), Some(b)) => Some(a.add(b)),
                _ => None,
            },
        }
    }

    /// Local scaling by a public constant: `α·(c·x) = c·(α·x)`.
    pub fn scale(&self, c: u64) -> Share {
        Share { v: self.v.scale(c), mac: self.mac.as_ref().map(|m| m.scale(c)) }
    }
}

/// Split a value into two authenticated shares given each party's view
/// of the dealer-derived α (test / trusted-setup helper: whoever calls
/// this knows the full α, exactly like the simulated dealer).
pub fn auth_split(x: &Mat, alpha: u64, prg: &mut Prg) -> (Share, Share) {
    let (v0, v1) = split(x, prg);
    let mac = x.scale(alpha);
    let m0 = Mat::random(x.rows, x.cols, prg);
    let m1 = mac.sub(&m0);
    (Share::authed(v0, m0), Share::authed(v1, m1))
}

/// Open an authenticated share at both parties: one symmetric exchange
/// of the *value* share (MAC limbs never travel), with every opened word
/// and this party's limb folded into the channel's deferred MAC ledger —
/// verified wholesale at the next [`Chan::mac_barrier`], so opening
/// costs zero extra flights. A plain share opens exactly like
/// [`reconstruct`] (no ledger activity even on an armed channel, since
/// there is no limb to check).
pub fn open_auth(chan: &mut Chan, share: &Share) -> Mat {
    let opened = reconstruct(chan, &share.v);
    if let Some(mac) = &share.mac {
        chan.fold_opened(&opened.data, &mac.data);
    }
    opened
}

/// Commit-then-reveal reconstruction for **final outputs** (malicious
/// tier): each party first exchanges a hash commitment to its share,
/// then the share itself, and verifies the peer's reveal against the
/// commitment — a cheating party cannot choose its share after seeing
/// the honest one. Two extra flights total; the opened words also fold
/// into the MAC ledger when the share is authenticated, so the final
/// barrier still covers the revealed value itself.
pub fn reconstruct_committed(chan: &mut Chan, share: &Share, phase: &str) -> Result<Mat> {
    let mut bytes = Vec::with_capacity(share.v.data.len() * 8);
    for w in &share.v.data {
        bytes.extend_from_slice(&w.to_le_bytes());
    }
    let commit = hash256(&bytes);
    let their_commit = chan.try_exchange_bytes(&commit)?;
    let theirs = chan.exchange_mat(&share.v);
    let mut their_bytes = Vec::with_capacity(theirs.data.len() * 8);
    for w in &theirs.data {
        their_bytes.extend_from_slice(&w.to_le_bytes());
    }
    if their_commit[..] != hash256(&their_bytes)[..] {
        return Err(Error::MacCheck(format!(
            "commit-reveal at '{phase}': peer's revealed share does not match its commitment"
        )));
    }
    let opened = share.v.add(&theirs);
    if let Some(mac) = &share.mac {
        chan.fold_opened(&opened.data, &mac.data);
    }
    Ok(opened)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;

    #[test]
    fn split_reconstruct_roundtrip() {
        let mut prg = Prg::new(1);
        let x = Mat::from_vec(2, 2, vec![1, u64::MAX, 42, 7]);
        let (a, b) = split(&x, &mut prg);
        assert_ne!(a, x, "share must not equal secret");
        assert_eq!(a.add(&b), x);
    }

    #[test]
    fn two_party_input_sharing_and_reconstruction() {
        let x = Mat::from_vec(1, 3, vec![5, 6, 7]);
        let xc = x.clone();
        let ((r0, _), (r1, _)) = run_two_party(
            move |c| {
                let mut prg = Prg::new(9);
                let mine = share_input_owner(c, &xc, &mut prg);
                reconstruct(c, &mine)
            },
            |c| {
                let mine = share_input_recv(c, 1, 3);
                reconstruct(c, &mine)
            },
        );
        assert_eq!(r0, x);
        assert_eq!(r1, x);
    }

    #[test]
    fn auth_split_opens_and_passes_the_barrier() {
        use crate::offline::dealer::mac_key_share;
        let seed = 0x5EC5u128;
        let a0 = mac_key_share(seed, 0);
        let a1 = mac_key_share(seed, 1);
        let alpha = a0.wrapping_add(a1);
        let x = Mat::from_vec(2, 2, vec![1, 2, 3, u64::MAX]);
        let mut prg = Prg::new(7);
        let (s0, s1) = auth_split(&x, alpha, &mut prg);
        assert!(s0.is_authed() && s1.is_authed());
        // MAC limbs reconstruct to α·x.
        assert_eq!(
            s0.mac.clone().unwrap().add(&s1.mac.clone().unwrap()),
            x.scale(alpha)
        );
        // Local ops preserve authentication: (s+s)·3 keeps valid limbs.
        let d0 = s0.add(&s0).scale(3);
        let d1 = s1.add(&s1).scale(3);
        let want = x.scale(6);
        let xc = x.clone();
        let ((r0, _), (r1, _)) = run_two_party(
            move |c| {
                c.enable_mac(a0, seed);
                let o = open_auth(c, &s0);
                c.mac_barrier("open").unwrap();
                let o6 = open_auth(c, &d0);
                c.mac_barrier("open.scaled").unwrap();
                (o, o6)
            },
            move |c| {
                c.enable_mac(a1, seed);
                let o = open_auth(c, &s1);
                c.mac_barrier("open").unwrap();
                let o6 = open_auth(c, &d1);
                c.mac_barrier("open.scaled").unwrap();
                (o, o6)
            },
        );
        assert_eq!(r0.0, x);
        assert_eq!(r1.0, xc);
        assert_eq!(r0.1, want);
        assert_eq!(r1.1, want);
    }

    #[test]
    fn forged_opened_share_is_caught_at_the_barrier() {
        use crate::offline::dealer::mac_key_share;
        let seed = 0xBAD5u128;
        let a0 = mac_key_share(seed, 0);
        let a1 = mac_key_share(seed, 1);
        let x = Mat::from_vec(1, 2, vec![10, 20]);
        let mut prg = Prg::new(8);
        let (s0, mut s1) = auth_split(&x, a0.wrapping_add(a1), &mut prg);
        // Party 1 lies by one in its value share (an additive attack the
        // semi-honest open would silently absorb).
        s1.v.set(0, 0, s1.v.at(0, 0).wrapping_add(1));
        let ((r0, _), (r1, _)) = run_two_party(
            move |c| {
                c.enable_mac(a0, seed);
                let _ = open_auth(c, &s0);
                c.mac_barrier("open")
            },
            move |c| {
                c.enable_mac(a1, seed);
                let _ = open_auth(c, &s1);
                c.mac_barrier("open")
            },
        );
        assert!(matches!(r0.unwrap_err(), Error::MacCheck(_)));
        assert!(matches!(r1.unwrap_err(), Error::MacCheck(_)));
    }

    #[test]
    fn committed_reconstruction_round_trips() {
        let x = Mat::from_vec(1, 3, vec![5, 6, 7]);
        let mut prg = Prg::new(11);
        let (v0, v1) = split(&x, &mut prg);
        let (s0, s1) = (Share::plain(v0), Share::plain(v1));
        let xc = x.clone();
        let ((r0, _), (r1, _)) = run_two_party(
            move |c| reconstruct_committed(c, &s0, "train.done").unwrap(),
            move |c| reconstruct_committed(c, &s1, "train.done").unwrap(),
        );
        assert_eq!(r0, x);
        assert_eq!(r1, xc);
    }

    #[test]
    fn reconstruct_to_single_party() {
        let x = Mat::from_vec(1, 2, vec![100, 200]);
        let xc = x.clone();
        let ((r0, _), (r1, _)) = run_two_party(
            move |c| {
                let mut prg = Prg::new(3);
                let mine = share_input_owner(c, &xc, &mut prg);
                reconstruct_to(c, &mine, 1)
            },
            |c| {
                let mine = share_input_recv(c, 1, 2);
                reconstruct_to(c, &mine, 1)
            },
        );
        assert!(r0.is_none());
        assert_eq!(r1.unwrap(), x);
    }
}
