//! Sharing and reconstruction of ring matrices.
//!
//! `Shr_i(x)`: the owner splits `x` into uniform shares summing to `x`
//! mod 2^64 and transmits the other party's share. `Rec(x)`: parties
//! exchange shares and add. Between those two moments every value in the
//! protocol is a uniformly distributed share (see the paper's §3.1).

use crate::net::Chan;
use crate::ring::matrix::Mat;
use crate::util::prng::Prg;

/// Split a matrix into two additive shares using `prg` for share 0.
pub fn split(x: &Mat, prg: &mut Prg) -> (Mat, Mat) {
    let s0 = Mat::random(x.rows, x.cols, prg);
    let s1 = x.sub(&s0);
    (s0, s1)
}

/// Owner-side input sharing: keep one share, send the other.
pub fn share_input_owner(chan: &mut Chan, x: &Mat, prg: &mut Prg) -> Mat {
    let (mine, theirs) = split(x, prg);
    chan.send_mat(&theirs);
    mine
}

/// Receiver side of input sharing.
pub fn share_input_recv(chan: &mut Chan, rows: usize, cols: usize) -> Mat {
    chan.recv_mat(rows, cols)
}

/// The trivial sharing of a locally-held plaintext: `⟨x⟩_me = x`,
/// `⟨x⟩_other = 0`. No communication; used to feed private inputs into
/// Beaver multiplications.
pub fn trivial_share_of_mine(x: &Mat) -> Mat {
    x.clone()
}

/// The trivial share corresponding to the *other* party's private input.
pub fn trivial_share_of_theirs(rows: usize, cols: usize) -> Mat {
    Mat::zeros(rows, cols)
}

/// Reconstruct a shared matrix at both parties (one symmetric exchange).
pub fn reconstruct(chan: &mut Chan, share: &Mat) -> Mat {
    let other = chan.exchange_mat(share);
    share.add(&other)
}

/// Reconstruct toward one party only: `target` learns the value, the
/// other party learns nothing and returns `None`.
pub fn reconstruct_to(chan: &mut Chan, share: &Mat, target: usize) -> Option<Mat> {
    if chan.party == target {
        let other = chan.recv_mat(share.rows, share.cols);
        Some(share.add(&other))
    } else {
        chan.send_mat(share);
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;

    #[test]
    fn split_reconstruct_roundtrip() {
        let mut prg = Prg::new(1);
        let x = Mat::from_vec(2, 2, vec![1, u64::MAX, 42, 7]);
        let (a, b) = split(&x, &mut prg);
        assert_ne!(a, x, "share must not equal secret");
        assert_eq!(a.add(&b), x);
    }

    #[test]
    fn two_party_input_sharing_and_reconstruction() {
        let x = Mat::from_vec(1, 3, vec![5, 6, 7]);
        let xc = x.clone();
        let ((r0, _), (r1, _)) = run_two_party(
            move |c| {
                let mut prg = Prg::new(9);
                let mine = share_input_owner(c, &xc, &mut prg);
                reconstruct(c, &mine)
            },
            |c| {
                let mine = share_input_recv(c, 1, 3);
                reconstruct(c, &mine)
            },
        );
        assert_eq!(r0, x);
        assert_eq!(r1, x);
    }

    #[test]
    fn reconstruct_to_single_party() {
        let x = Mat::from_vec(1, 2, vec![100, 200]);
        let xc = x.clone();
        let ((r0, _), (r1, _)) = run_two_party(
            move |c| {
                let mut prg = Prg::new(3);
                let mine = share_input_owner(c, &xc, &mut prg);
                reconstruct_to(c, &mine, 1)
            },
            |c| {
                let mine = share_input_recv(c, 1, 2);
                reconstruct_to(c, &mine, 1)
            },
        );
        assert!(r0.is_none());
        assert_eq!(r1.unwrap(), x);
    }
}
