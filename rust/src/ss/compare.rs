//! Secure comparison: CMP = MSB ∘ subtraction (paper §3.1).
//!
//! `lt(x, y)` returns XOR shares of `[x < y]` per lane, valid whenever
//! `|x − y| < 2^63` — always true for fixed-point distances. One call
//! handles an entire matrix of lanes; this is the CMP inside the CMPM
//! comparison modules of `F_min^k` (Figure 1 of the paper). Every CMP
//! costs exactly [`crate::ss::boolean::CMP_ROUNDS`] flights.
//!
//! [`cmp_many`] concatenates the lanes of many independent comparisons
//! into **one** Kogge-Stone pass, so a whole batch of CMP gates costs
//! the same flights as a single one.

use super::boolean::{msb, BoolShare};
use super::{Session, SessionOptions};
use crate::ring::matrix::Mat;

/// XOR-shared `[x < y]` per lane.
pub fn lt(ctx: &mut Session, x: &Mat, y: &Mat) -> BoolShare {
    assert_eq!(x.shape(), y.shape());
    let diff = x.sub(y);
    msb(ctx, &diff)
}

/// XOR-shared `[x > y]` per lane.
pub fn gt(ctx: &mut Session, x: &Mat, y: &Mat) -> BoolShare {
    lt(ctx, y, x)
}

/// XOR-shared `[x < c]` against a public constant vector.
pub fn lt_public(ctx: &mut Session, x: &Mat, c: &Mat) -> BoolShare {
    // x < c  ⇔  MSB(x − c); subtract c on party 0's share only.
    let diff = if ctx.party() == 0 { x.sub(c) } else { x.clone() };
    msb(ctx, &diff)
}

/// XOR-shared `[x > c]` against a public constant vector (strict: lanes
/// equal to `c` come out 0). The serving-side fraud flag — see
/// [`crate::fraud::threshold`].
pub fn gt_public(ctx: &mut Session, x: &Mat, c: &Mat) -> BoolShare {
    // x > c  ⇔  c − x < 0  ⇔  MSB(c − x); party 0 holds c − ⟨x⟩₀,
    // party 1 holds −⟨x⟩₁.
    let diff = if ctx.party() == 0 { c.sub(x) } else { x.neg() };
    msb(ctx, &diff)
}

/// Batched CMP: one `[x < y]` share per pair, all pairs riding a single
/// comparison circuit (lane concatenation — identical flight count to
/// one CMP).
pub fn cmp_many(ctx: &mut Session, pairs: &[(&Mat, &Mat)]) -> Vec<BoolShare> {
    if pairs.is_empty() {
        return vec![];
    }
    let sizes: Vec<usize> = pairs.iter().map(|(x, _)| x.len()).collect();
    let total: usize = sizes.iter().sum();
    let mut diff = Mat::zeros(1, total);
    let mut off = 0;
    for (x, y) in pairs {
        assert_eq!(x.shape(), y.shape());
        for i in 0..x.len() {
            diff.data[off + i] = x.data[i].wrapping_sub(y.data[i]);
        }
        off += x.len();
    }
    let bits = msb(ctx, &diff);
    bits.split_lanes(&sizes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ring::fixed::encode_f64;
    use crate::ss::share::split;
    use crate::ss::Session;
    use crate::util::prng::Prg;

    fn reveal(c: &mut crate::net::Chan, s: &BoolShare) -> Vec<bool> {
        let theirs = c.exchange_u64s(&s.words);
        (0..s.n).map(|i| ((s.words[i / 64] ^ theirs[i / 64]) >> (i % 64)) & 1 == 1).collect()
    }

    fn run_lt(xs: Vec<u64>, ys: Vec<u64>) -> Vec<bool> {
        let n = xs.len();
        let mut prg = Prg::new(21);
        let (x0, x1) = split(&Mat::from_vec(1, n, xs), &mut prg);
        let (y0, y1) = split(&Mat::from_vec(1, n, ys), &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(50, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let b = lt(&mut ctx, &x0, &y0);
                reveal(c, &b)
            },
            move |c| {
                let mut ts = Dealer::new(50, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let b = lt(&mut ctx, &x1, &y1);
                reveal(c, &b)
            },
        );
        r
    }

    #[test]
    fn lt_on_fixed_point_values() {
        let xs: Vec<f64> = vec![1.5, -2.0, 0.0, 3.25, -1.0];
        let ys: Vec<f64> = vec![2.0, -3.0, 0.0, 3.25, 5.5];
        let want: Vec<bool> = xs.iter().zip(&ys).map(|(a, b)| a < b).collect();
        let got = run_lt(
            xs.iter().map(|&v| encode_f64(v)).collect(),
            ys.iter().map(|&v| encode_f64(v)).collect(),
        );
        assert_eq!(got, want);
    }

    #[test]
    fn lt_on_integers_near_boundaries() {
        let xs = vec![0u64, 1, (1u64 << 62), 100];
        let ys = vec![1u64, 0, (1u64 << 62) + 1, 100];
        let want = vec![true, false, true, false];
        assert_eq!(run_lt(xs, ys), want);
    }

    #[test]
    fn gt_public_is_strict_above() {
        // [x > c] for a shared x against a public threshold: strictly
        // greater flags, equal and below do not.
        let xs = vec![encode_f64(1.5), encode_f64(2.0), encode_f64(2.5), encode_f64(-3.0)];
        let c = Mat::from_vec(1, 4, vec![encode_f64(2.0); 4]);
        let mut prg = Prg::new(23);
        let (x0, x1) = split(&Mat::from_vec(1, 4, xs), &mut prg);
        let (c0, c1) = (c.clone(), c);
        let ((got, _), _) = run_two_party(
            move |ch| {
                let mut ts = Dealer::new(52, 0);
                let mut ctx = Session::new(ch, &mut ts, Prg::new(1), SessionOptions::default());
                let b = gt_public(&mut ctx, &x0, &c0);
                reveal(ch, &b)
            },
            move |ch| {
                let mut ts = Dealer::new(52, 1);
                let mut ctx = Session::new(ch, &mut ts, Prg::new(2), SessionOptions::default());
                let b = gt_public(&mut ctx, &x1, &c1);
                reveal(ch, &b)
            },
        );
        assert_eq!(got, vec![false, false, true, false]);
    }

    #[test]
    fn cmp_many_matches_per_pair_and_costs_one_cmp() {
        use crate::ss::boolean::CMP_ROUNDS;
        let x1 = Mat::from_vec(1, 3, vec![1, 5, 9]);
        let y1 = Mat::from_vec(1, 3, vec![2, 5, 3]);
        let x2 = Mat::from_vec(1, 2, vec![7, 0]);
        let y2 = Mat::from_vec(1, 2, vec![7, 1]);
        let mut prg = Prg::new(22);
        let (x1a, x1b) = split(&x1, &mut prg);
        let (y1a, y1b) = split(&y1, &mut prg);
        let (x2a, x2b) = split(&x2, &mut prg);
        let (y2a, y2b) = split(&y2, &mut prg);
        let ((got, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(51, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let bs = cmp_many(&mut ctx, &[(&x1a, &y1a), (&x2a, &y2a)]);
                let rounds = ctx.chan.meter().total().rounds;
                let r: Vec<Vec<bool>> = bs.iter().map(|b| reveal(c, b)).collect();
                (r, rounds)
            },
            move |c| {
                let mut ts = Dealer::new(51, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let bs = cmp_many(&mut ctx, &[(&x1b, &y1b), (&x2b, &y2b)]);
                let _: Vec<Vec<bool>> = bs.iter().map(|b| reveal(c, b)).collect();
            },
        );
        let (r, rounds) = (got.0, got.1);
        assert_eq!(r[0], vec![true, false, false]);
        assert_eq!(r[1], vec![false, true]);
        assert_eq!(rounds, CMP_ROUNDS, "batch must cost one comparison circuit");
    }
}
