//! Secure comparison: CMP = MSB ∘ subtraction (paper §3.1).
//!
//! `lt(x, y)` returns XOR shares of `[x < y]` per lane, valid whenever
//! `|x − y| < 2^63` — always true for fixed-point distances. One call
//! handles an entire matrix of lanes; this is the CMP inside the CMPM
//! comparison modules of `F_min^k` (Figure 1 of the paper).

use super::boolean::{msb, BoolShare};
use super::Ctx;
use crate::ring::matrix::Mat;

/// XOR-shared `[x < y]` per lane.
pub fn lt(ctx: &mut Ctx, x: &Mat, y: &Mat) -> BoolShare {
    assert_eq!(x.shape(), y.shape());
    let diff = x.sub(y);
    msb(ctx, &diff)
}

/// XOR-shared `[x > y]` per lane.
pub fn gt(ctx: &mut Ctx, x: &Mat, y: &Mat) -> BoolShare {
    lt(ctx, y, x)
}

/// XOR-shared `[x < c]` against a public constant vector.
pub fn lt_public(ctx: &mut Ctx, x: &Mat, c: &Mat) -> BoolShare {
    // x < c  ⇔  MSB(x − c); subtract c on party 0's share only.
    let diff = if ctx.party() == 0 { x.sub(c) } else { x.clone() };
    msb(ctx, &diff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ring::fixed::encode_f64;
    use crate::ss::share::split;
    use crate::util::prng::Prg;

    fn reveal(c: &mut crate::net::Chan, s: &BoolShare) -> Vec<bool> {
        let theirs = c.exchange_u64s(&s.words);
        (0..s.n).map(|i| ((s.words[i / 64] ^ theirs[i / 64]) >> (i % 64)) & 1 == 1).collect()
    }

    fn run_lt(xs: Vec<u64>, ys: Vec<u64>) -> Vec<bool> {
        let n = xs.len();
        let mut prg = Prg::new(21);
        let (x0, x1) = split(&Mat::from_vec(1, n, xs), &mut prg);
        let (y0, y1) = split(&Mat::from_vec(1, n, ys), &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(50, 0);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(1));
                let b = lt(&mut ctx, &x0, &y0);
                reveal(c, &b)
            },
            move |c| {
                let mut ts = Dealer::new(50, 1);
                let mut ctx = Ctx::new(c, &mut ts, Prg::new(2));
                let b = lt(&mut ctx, &x1, &y1);
                reveal(c, &b)
            },
        );
        r
    }

    #[test]
    fn lt_on_fixed_point_values() {
        let xs: Vec<f64> = vec![1.5, -2.0, 0.0, 3.25, -1.0];
        let ys: Vec<f64> = vec![2.0, -3.0, 0.0, 3.25, 5.5];
        let want: Vec<bool> = xs.iter().zip(&ys).map(|(a, b)| a < b).collect();
        let got = run_lt(
            xs.iter().map(|&v| encode_f64(v)).collect(),
            ys.iter().map(|&v| encode_f64(v)).collect(),
        );
        assert_eq!(got, want);
    }

    #[test]
    fn lt_on_integers_near_boundaries() {
        let xs = vec![0u64, 1, (1u64 << 62), 100];
        let ys = vec![1u64, 0, (1u64 << 62) + 1, 100];
        let want = vec![true, false, true, false];
        assert_eq!(run_lt(xs, ys), want);
    }
}
