//! Boolean (XOR) shares and the bit-sliced secure adder.
//!
//! B-shares (paper §3.1) are additive shares in Z_2. We keep them
//! **bit-sliced**: an `n`-lane boolean vector is packed 64 lanes per
//! `u64` word, so a secure AND processes 64 lanes per word operation and
//! a whole gate layer for all lanes costs one communication round.
//!
//! A2B runs a Kogge-Stone parallel-prefix adder over the two parties'
//! *local* arithmetic-share bit planes: `x = ⟨x⟩₀ + ⟨x⟩₁ mod 2^64`, where
//! party p inputs the bits of its own share as trivially-XOR-shared
//! planes. Depth is log2(64) = 6 AND rounds regardless of lane count —
//! the comparison backbone of the paper's `F_min^k`.
//!
//! All AND layers go through the session round buffer: under
//! [`crate::ss::RoundPolicy::Coalesced`] one `and_many` call is one
//! flight (and shares it with anything else the caller staged); under
//! `PerGate` every pair pays its own flight — the pre-batching baseline.
//! B2A rides a daBit ([`crate::ss::triples::DaBits`]): reveal
//! `c = b ⊕ r` (one-time-pad opening, one flight, no triple) and lift
//! locally with `b = c + r − 2·c·r`.

use super::pending::Pending;
use super::triples::{bit_words, last_word_mask};
use super::{Session, SessionOptions};
use crate::ring::matrix::Mat;

/// Flights per vectorized CMP (= MSB of a shared difference): the
/// initial generate layer plus one per Kogge-Stone level over 64 bits.
/// Exported so round-count regression tests can assert exact budgets.
pub const CMP_ROUNDS: u64 = 7;

/// An XOR-shared, bit-packed boolean vector of `n` lanes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BoolShare {
    /// Number of valid lanes.
    pub n: usize,
    /// The lanes, packed 64 per word (tail bits masked to zero).
    pub words: Vec<u64>,
}

impl BoolShare {
    /// The all-zero share of `n` lanes.
    pub fn zeros(n: usize) -> Self {
        BoolShare { n, words: vec![0; bit_words(n)] }
    }

    /// Wrap locally-held plaintext bits as this party's trivial share
    /// (the peer holds all-zero words).
    pub fn from_plain_words(n: usize, words: Vec<u64>) -> Self {
        assert_eq!(words.len(), bit_words(n));
        let mut s = BoolShare { n, words };
        s.mask_tail();
        s
    }

    /// Local XOR (SADD in Z_2).
    pub fn xor(&self, other: &BoolShare) -> BoolShare {
        assert_eq!(self.n, other.n);
        let words = self.words.iter().zip(&other.words).map(|(a, b)| a ^ b).collect();
        BoolShare { n: self.n, words }
    }

    /// Local NOT: party 0 flips, party 1 keeps (x ^ 1 on exactly one share).
    pub fn not(&self, party: usize) -> BoolShare {
        if party == 0 {
            let mut out = BoolShare { n: self.n, words: self.words.iter().map(|w| !w).collect() };
            out.mask_tail();
            out
        } else {
            self.clone()
        }
    }

    /// Read lane `i` of this share.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Write lane `i` of this share.
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        if v {
            self.words[i / 64] |= 1 << (i % 64);
        } else {
            self.words[i / 64] &= !(1 << (i % 64));
        }
    }

    fn mask_tail(&mut self) {
        if let Some(last) = self.words.last_mut() {
            *last &= last_word_mask(self.n);
        }
    }

    /// Concatenate lanes of several shares (for batching AND layers).
    pub fn concat(parts: &[&BoolShare]) -> BoolShare {
        let n: usize = parts.iter().map(|p| p.n).sum();
        let mut out = BoolShare::zeros(n);
        let mut off = 0;
        for p in parts {
            for i in 0..p.n {
                out.set(off + i, p.get(i));
            }
            off += p.n;
        }
        out
    }

    /// Split lanes back into `sizes.len()` shares.
    pub fn split_lanes(&self, sizes: &[usize]) -> Vec<BoolShare> {
        assert_eq!(sizes.iter().sum::<usize>(), self.n);
        let mut out = Vec::with_capacity(sizes.len());
        let mut off = 0;
        for &sz in sizes {
            let mut s = BoolShare::zeros(sz);
            for i in 0..sz {
                s.set(i, self.get(off + i));
            }
            off += sz;
            out.push(s);
        }
        out
    }
}

/// Stage a batched AND over pairs of equal-length vectors; resolves to
/// one output share per pair after the next flush.
///
/// Word-aligned batching: each vector's packed words are concatenated
/// directly (padding lanes up to the word boundary), so the hot path is
/// pure `u64` XOR/AND streams — no per-bit repacking. The tail-padding
/// lanes consume a few extra triple bits and carry garbage that is
/// masked off on output.
pub fn and_many_begin(
    ctx: &mut Session,
    pairs: &[(&BoolShare, &BoolShare)],
) -> Pending<Vec<BoolShare>> {
    let word_counts: Vec<usize> = pairs.iter().map(|(x, _)| x.words.len()).collect();
    let lane_counts: Vec<usize> = pairs.iter().map(|(x, _)| x.n).collect();
    let total_words: usize = word_counts.iter().sum();
    let t = ctx.ts.bit_triple(total_words * 64);
    // d = x ^ a, e = y ^ b revealed in one flight (word streams).
    let mut de = Vec::with_capacity(2 * total_words);
    let mut off = 0;
    for (x, y) in pairs {
        debug_assert_eq!(x.n, y.n);
        for w in &x.words {
            de.push(w ^ t.a[off]);
            off += 1;
        }
    }
    let mut off2 = 0;
    for (_, y) in pairs {
        for w in &y.words {
            de.push(w ^ t.b[off2]);
            off2 += 1;
        }
    }
    Pending::stage(ctx, de, move |party, mine, theirs| {
        let mut out = Vec::with_capacity(word_counts.len());
        let mut base = 0;
        for (i, &wc) in word_counts.iter().enumerate() {
            let mut z = BoolShare::zeros(lane_counts[i]);
            for w in 0..wc {
                let d = mine[base + w] ^ theirs[base + w];
                let e = mine[total_words + base + w] ^ theirs[total_words + base + w];
                // z = [party0] d&e ^ d&b ^ e&a ^ c
                let mut zw = (d & t.b[base + w]) ^ (e & t.a[base + w]) ^ t.c[base + w];
                if party == 0 {
                    zw ^= d & e;
                }
                z.words[w] = zw;
            }
            z.mask_tail();
            out.push(z);
            base += wc;
        }
        out
    })
}

/// Secure AND of two XOR-shared vectors (one bit triple per lane, one
/// symmetric reveal round for all lanes).
pub fn and(ctx: &mut Session, x: &BoolShare, y: &BoolShare) -> BoolShare {
    assert_eq!(x.n, y.n);
    let p = and_many_begin(ctx, &[(x, y)]);
    ctx.flush();
    p.resolve(ctx).pop().expect("one pair in, one share out")
}

/// Batched AND: pairs of equal-length vectors, one flight for all pairs
/// (`PerGate` policy: one flight per pair — the unbatched baseline).
pub fn and_many(ctx: &mut Session, pairs: &[(&BoolShare, &BoolShare)]) -> Vec<BoolShare> {
    if pairs.is_empty() {
        return vec![];
    }
    if ctx.per_gate() && pairs.len() > 1 {
        return pairs.iter().map(|(x, y)| and(ctx, x, y)).collect();
    }
    let p = and_many_begin(ctx, pairs);
    ctx.flush();
    p.resolve(ctx)
}

/// Bit-plane decomposition of this party's *local* arithmetic share:
/// plane `j` holds bit `j` of every lane, packed. These planes are the
/// party's private adder inputs (trivially XOR-shared).
pub fn local_bit_planes(share: &Mat) -> Vec<BoolShare> {
    let n = share.len();
    let words = bit_words(n);
    let mut planes = vec![vec![0u64; words]; 64];
    for (i, &v) in share.data.iter().enumerate() {
        let (w, b) = (i / 64, i % 64);
        for j in 0..64 {
            planes[j][w] |= ((v >> j) & 1) << b;
        }
    }
    planes.into_iter().map(|ws| BoolShare::from_plain_words(n, ws)).collect()
}

/// Secure 64-bit Kogge-Stone addition of the two parties' private bit
/// planes. `x_planes` is this party's local planes when `party == 0`,
/// otherwise the zero trivial share — callers use [`a2b`]/[`msb`].
///
/// Returns all 64 XOR-shared sum bit planes. `upto` limits computation to
/// sum bits `0..=upto` (pass 63 for full A2B; the MSB-only path also
/// needs 63 but saves nothing structural — kept for clarity).
fn kogge_stone(ctx: &mut Session, x: &[BoolShare], y: &[BoolShare], upto: usize) -> Vec<BoolShare> {
    assert_eq!(x.len(), 64);
    assert_eq!(y.len(), 64);
    let l = upto + 1;
    // Layer 0: p = x ^ y (local), g = x & y (one round, batched).
    let p: Vec<BoolShare> = (0..l).map(|j| x[j].xor(&y[j])).collect();
    let g_pairs: Vec<(&BoolShare, &BoolShare)> = (0..l).map(|j| (&x[j], &y[j])).collect();
    let mut g = and_many(ctx, &g_pairs);
    let mut pp = p.clone();

    let mut s = 1;
    while s < l {
        // G'[j] = G[j] ^ (P[j] & G[j-s])   for j >= s
        // P'[j] = P[j] & P[j-s]            for j >= s (skipped at last level
        //                                   since no further use)
        let last_level = s * 2 >= l;
        let mut pairs: Vec<(&BoolShare, &BoolShare)> = Vec::new();
        for j in s..l {
            pairs.push((&pp[j], &g[j - s]));
        }
        for j in s..l {
            if !last_level {
                pairs.push((&pp[j], &pp[j - s]));
            }
        }
        let results = and_many(ctx, &pairs);
        let gk = l - s;
        for j in s..l {
            g[j] = g[j].xor(&results[j - s]);
        }
        if !last_level {
            for j in s..l {
                pp[j] = results[gk + (j - s)].clone();
            }
        }
        s *= 2;
    }

    // sum[j] = p[j] ^ carry_in[j], carry_in[j] = G_prefix[j-1], carry_in[0]=0.
    let mut sum = Vec::with_capacity(l);
    for j in 0..l {
        if j == 0 {
            sum.push(p[0].clone());
        } else {
            sum.push(p[j].xor(&g[j - 1]));
        }
    }
    sum
}

/// A2B: convert an arithmetic share matrix to 64 XOR-shared bit planes
/// of the underlying value (lane i = element i of the flattened matrix).
pub fn a2b(ctx: &mut Session, share: &Mat) -> Vec<BoolShare> {
    let n = share.len();
    let mine = local_bit_planes(share);
    let zero: Vec<BoolShare> = (0..64).map(|_| BoolShare::zeros(n)).collect();
    let (x, y) = if ctx.party() == 0 { (&mine, &zero) } else { (&zero, &mine) };
    kogge_stone(ctx, x, y, 63)
}

/// MSB: XOR-shared sign-bit plane of the shared value — the comparison
/// primitive (`x < y ⇔ MSB(x−y) = 1` for |x−y| < 2^63). Costs exactly
/// [`CMP_ROUNDS`] flights under the coalescing policy.
pub fn msb(ctx: &mut Session, share: &Mat) -> BoolShare {
    let n = share.len();
    let mine = local_bit_planes(share);
    let zero: Vec<BoolShare> = (0..64).map(|_| BoolShare::zeros(n)).collect();
    let (x, y) = if ctx.party() == 0 { (&mine, &zero) } else { (&zero, &mine) };
    let sum = kogge_stone(ctx, x, y, 63);
    sum[63].clone()
}

/// Stage a B2A lift of an XOR-shared bit vector to arithmetic shares in
/// Z_{2^64} via a daBit: reveal `c = b ⊕ r` and combine locally as
/// `⟨b⟩ = c + (1−2c)·⟨r⟩`. One flight, no multiplication triple.
pub fn b2a_begin(ctx: &mut Session, bits: &BoolShare) -> Pending<Mat> {
    let n = bits.n;
    let db = ctx.ts.dabits(n);
    let w = bits.words.len();
    debug_assert_eq!(db.bool_words.len(), w);
    let mut payload = Vec::with_capacity(w);
    for i in 0..w {
        payload.push(bits.words[i] ^ db.bool_words[i]);
    }
    Pending::stage(ctx, payload, move |party, mine, theirs| {
        let mut out = Mat::zeros(1, n);
        for i in 0..n {
            let c = ((mine[i / 64] ^ theirs[i / 64]) >> (i % 64)) & 1;
            let r = db.arith[i];
            out.data[i] = if c == 1 {
                // b = 1 − r: party 0 contributes the public 1.
                let v = r.wrapping_neg();
                if party == 0 {
                    v.wrapping_add(1)
                } else {
                    v
                }
            } else {
                r
            };
        }
        out
    })
}

/// B2A: lift an XOR-shared bit vector to arithmetic shares in Z_{2^64}
/// (single-gate wrapper, one round).
pub fn b2a(ctx: &mut Session, bits: &BoolShare) -> Mat {
    let p = b2a_begin(ctx, bits);
    ctx.flush();
    p.resolve(ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ss::share::split;
    use crate::ss::Session;
    use crate::util::prng::Prg;

    fn reveal_bits(c: &mut crate::net::Chan, s: &BoolShare) -> Vec<bool> {
        let theirs = c.exchange_u64s(&s.words);
        (0..s.n).map(|i| ((s.words[i / 64] ^ theirs[i / 64]) >> (i % 64)) & 1 == 1).collect()
    }

    #[test]
    fn and_matches_plaintext() {
        let n = 130;
        let mut prg = Prg::new(3);
        let xw: Vec<u64> = (0..bit_words(n)).map(|_| prg.next_u64()).collect();
        let yw: Vec<u64> = (0..bit_words(n)).map(|_| prg.next_u64()).collect();
        let x = BoolShare::from_plain_words(n, xw.clone());
        let y = BoolShare::from_plain_words(n, yw.clone());
        // Party 0 holds x and zero-share of y; party 1 holds y.
        let x0 = x.clone();
        let y1 = y.clone();
        let ((got, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(44, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let z = and(&mut ctx, &x0, &BoolShare::zeros(n));
                reveal_bits(c, &z)
            },
            move |c| {
                let mut ts = Dealer::new(44, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let z = and(&mut ctx, &BoolShare::zeros(n), &y1);
                reveal_bits(c, &z)
            },
        );
        for i in 0..n {
            let want = x.get(i) & y.get(i);
            assert_eq!(got[i], want, "lane {i}");
        }
    }

    #[test]
    fn a2b_recovers_value_bits() {
        let vals = vec![0u64, 1, 2, 5, u64::MAX, 1 << 63, 0x0123_4567_89AB_CDEF];
        let n = vals.len();
        let x = Mat::from_vec(1, n, vals.clone());
        let mut prg = Prg::new(8);
        let (x0, x1) = split(&x, &mut prg);
        let ((planes, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(45, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let ps = a2b(&mut ctx, &x0);
                ps.iter().map(|p| reveal_bits(c, p)).collect::<Vec<_>>()
            },
            move |c| {
                let mut ts = Dealer::new(45, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let ps = a2b(&mut ctx, &x1);
                ps.iter().map(|p| reveal_bits(c, p)).collect::<Vec<_>>()
            },
        );
        for (i, v) in vals.iter().enumerate() {
            for j in 0..64 {
                assert_eq!(planes[j][i], (v >> j) & 1 == 1, "val {i} bit {j}");
            }
        }
    }

    #[test]
    fn msb_is_sign_bit() {
        let vals = vec![5u64, (-5i64) as u64, 0, (-1i64) as u64, i64::MAX as u64, 1 << 63];
        let want: Vec<bool> = vals.iter().map(|&v| (v >> 63) & 1 == 1).collect();
        let x = Mat::from_vec(1, vals.len(), vals);
        let mut prg = Prg::new(2);
        let (x0, x1) = split(&x, &mut prg);
        let ((got, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(46, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let m = msb(&mut ctx, &x0);
                reveal_bits(c, &m)
            },
            move |c| {
                let mut ts = Dealer::new(46, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let m = msb(&mut ctx, &x1);
                reveal_bits(c, &m)
            },
        );
        assert_eq!(got, want);
    }

    #[test]
    fn msb_costs_exactly_cmp_rounds() {
        let x = Mat::from_vec(1, 9, (0..9).collect());
        let mut prg = Prg::new(4);
        let (x0, x1) = split(&x, &mut prg);
        let ((_, m0), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(48, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let _ = msb(&mut ctx, &x0);
            },
            move |c| {
                let mut ts = Dealer::new(48, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let _ = msb(&mut ctx, &x1);
            },
        );
        assert_eq!(m0.total().rounds, CMP_ROUNDS);
    }

    #[test]
    fn b2a_lifts_bits() {
        // XOR-shared random bit vector.
        let n = 70;
        let mut prg = Prg::new(6);
        let w0: Vec<u64> = (0..bit_words(n)).map(|_| prg.next_u64()).collect();
        let w1: Vec<u64> = (0..bit_words(n)).map(|_| prg.next_u64()).collect();
        let b0 = BoolShare::from_plain_words(n, w0);
        let b1 = BoolShare::from_plain_words(n, w1);
        let want: Vec<u64> = (0..n).map(|i| (b0.get(i) ^ b1.get(i)) as u64).collect();
        let ((got, m0), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(47, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let a = b2a(&mut ctx, &b0);
                crate::ss::share::reconstruct(c, &a).data
            },
            move |c| {
                let mut ts = Dealer::new(47, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let a = b2a(&mut ctx, &b1);
                crate::ss::share::reconstruct(c, &a).data
            },
        );
        assert_eq!(got, want);
        // daBit B2A: one reveal flight + the reconstruct.
        assert_eq!(m0.total().rounds, 2);
    }

    #[test]
    fn per_gate_policy_splits_and_layers() {
        use crate::ss::RoundPolicy;
        let n = 16;
        let x = BoolShare::from_plain_words(n, vec![0xAAAA]);
        let y = BoolShare::from_plain_words(n, vec![0xFFFF]);
        let (xc, yc) = (x.clone(), y.clone());
        let ((rounds, got), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(49, 0);
                let mut ctx =
                    Session::new(c, &mut ts, Prg::new(1), SessionOptions::with_policy(RoundPolicy::PerGate));
                let zs = and_many(&mut ctx, &[(&xc, &BoolShare::zeros(n)), (&BoolShare::zeros(n), &xc)]);
                (ctx.chan.meter().total().rounds, zs.len())
            },
            move |c| {
                let mut ts = Dealer::new(49, 1);
                let mut ctx =
                    Session::new(c, &mut ts, Prg::new(2), SessionOptions::with_policy(RoundPolicy::PerGate));
                let _ = and_many(&mut ctx, &[(&BoolShare::zeros(n), &yc), (&yc, &BoolShare::zeros(n))]);
            },
        );
        assert_eq!(got, 2);
        assert_eq!(rounds, 2, "per-gate: one flight per AND pair");
    }

    #[test]
    fn concat_split_roundtrip() {
        let a = BoolShare::from_plain_words(3, vec![0b101]);
        let b = BoolShare::from_plain_words(2, vec![0b11]);
        let c = BoolShare::concat(&[&a, &b]);
        assert_eq!(c.n, 5);
        let parts = c.split_lanes(&[3, 2]);
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }
}
