//! K-means: the plaintext baseline and the paper's privacy-preserving
//! protocol (§4.2-4.3), on the round-batched protocol engine.
//!
//! Each Lloyd iteration decomposes into three secure steps, all
//! vectorized over the full sample set *and* flight-batched so a step
//! costs its dependency depth, not its gate count:
//!
//! * **S1 — distance** ([`esd`]): `⟨D'⟩ = ⟨U⟩ − 2·X·⟨μ⟩ᵀ` (Eq. 3). The
//!   norm square and both cross products stage into **one** reveal
//!   flight; the cross products themselves go through a pluggable
//!   [`backend::CrossProductBackend`] (Beaver triples, HE Protocol 2, or
//!   the naive Q3 ablation — `EsdMode::Auto` picks by joint density).
//! * **S2 — assignment** ([`assign`]): binary-tree reduction of `F_min^k`
//!   with CMP + fused daBit MUX modules (Fig. 1), producing a shared
//!   one-hot matrix in exactly `⌈log₂ k⌉·(CMP_ROUNDS+1)` flights.
//! * **S3 — update** ([`update`]): `⟨μ⟩ = ⟨Cᵀ X⟩ / ⟨1ᵀ C⟩` with secure
//!   division; the numerator reveals coalesce into the empty-cluster
//!   comparison's first flight, and the denominator is a free local
//!   column sum.
//!
//! [`secure`] orchestrates the iterations for vertically and
//! horizontally partitioned data over any backend, walking a **row-tile
//! schedule** (`config::tile_rows`) that bounds every matrix triple and
//! online intermediate by the tile size instead of n — lockstep tiles
//! share the monolithic flight budget, streamed tiles trade rounds for
//! O(B·d) memory. [`sparse`] is the thin HE-path entrypoint.
//! [`plaintext`] is the cleartext oracle the protocol is validated
//! against.
//!
//! Post-training, [`secure::assign_only_tile`] is the **serving** entry
//! point (S1 + S2 against a cached norm row, no S3), and
//! [`secure::SecureKmeansOutput::centroid_shares`] is the shared-centroid
//! handle the [`crate::serve`] subsystem persists per party.

pub mod assign;
pub mod backend;
pub mod config;
pub mod esd;
pub mod init;
pub mod plaintext;
pub mod secure;
pub mod sparse;
pub mod update;
