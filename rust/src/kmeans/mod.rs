//! K-means: the plaintext baseline and the paper's privacy-preserving
//! protocol (§4.2-4.3).
//!
//! Each Lloyd iteration decomposes into three secure steps, all
//! vectorized over the full sample set:
//!
//! * **S1 — distance** ([`esd`]): `⟨D'⟩ = ⟨U⟩ − 2·X·⟨μ⟩ᵀ` (Eq. 3),
//!   squared-norm term precomputed per iteration, cross products via
//!   matrix Beaver triples (dense) or HE Protocol 2 (sparse).
//! * **S2 — assignment** ([`assign`]): binary-tree reduction of `F_min^k`
//!   with CMP + MUX modules (Fig. 1), producing a shared one-hot matrix.
//! * **S3 — update** ([`update`]): `⟨μ⟩ = ⟨Cᵀ X⟩ / ⟨1ᵀ C⟩` with secure
//!   division; the denominator is a free local column sum.
//!
//! [`secure`] orchestrates the iterations for vertically and
//! horizontally partitioned data; [`sparse`] swaps the cross products to
//! the HE path. [`plaintext`] is the cleartext oracle the protocol is
//! validated against.

pub mod assign;
pub mod config;
pub mod esd;
pub mod init;
pub mod plaintext;
pub mod secure;
pub mod sparse;
pub mod update;
