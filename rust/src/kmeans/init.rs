//! Cluster-centroid initialization (paper §4.2).
//!
//! Default strategy: both parties derive the same k random sample
//! indices from the public protocol seed; each party contributes its
//! plaintext block of those rows as a trivial share. Zero communication,
//! and the indices reveal nothing beyond what the parties already agreed
//! to (the paper treats the initialization points as public).

use super::plaintext::init_indices;
use crate::ring::matrix::Mat;

/// Vertical: my share of μ₀ (k×d) from my feature block (n×d_mine).
/// Party 0 owns columns [0, d_a), party 1 the rest.
pub fn vertical(x_mine: &Mat, d_a: usize, d: usize, n: usize, k: usize, seed: u128, party: usize) -> Mat {
    let idx = init_indices(n, k, seed);
    let mut mu = Mat::zeros(k, d);
    let (lo, hi) = if party == 0 { (0, d_a) } else { (d_a, d) };
    for (j, &i) in idx.iter().enumerate() {
        for (c, l) in (lo..hi).enumerate() {
            mu.set(j, l, x_mine.at(i, c));
        }
    }
    mu
}

/// Horizontal: my share of μ₀ from my sample block. Party 0 owns rows
/// [0, n_a), party 1 the rest; a picked row is contributed entirely by
/// its owner.
pub fn horizontal(x_mine: &Mat, n_a: usize, n: usize, k: usize, seed: u128, party: usize) -> Mat {
    let idx = init_indices(n, k, seed);
    let d = x_mine.cols;
    let mut mu = Mat::zeros(k, d);
    for (j, &i) in idx.iter().enumerate() {
        let mine = if party == 0 { i < n_a } else { i >= n_a };
        if mine {
            let local_row = if party == 0 { i } else { i - n_a };
            mu.row_mut(j).copy_from_slice(x_mine.row(local_row));
        }
    }
    mu
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::fixed::encode_f64;

    #[test]
    fn vertical_shares_reassemble_rows() {
        let (n, d, d_a, k) = (5, 3, 2, 2);
        let xv: Vec<f64> = (0..n * d).map(|i| i as f64 / 10.0).collect();
        let xa = Mat::encode(n, d_a, &(0..n).flat_map(|i| xv[i * d..i * d + d_a].to_vec()).collect::<Vec<_>>());
        let xb = Mat::encode(n, d - d_a, &(0..n).map(|i| xv[i * d + 2]).collect::<Vec<_>>());
        let m0 = vertical(&xa, d_a, d, n, k, 5, 0);
        let m1 = vertical(&xb, d_a, d, n, k, 5, 1);
        let mu = m0.add(&m1);
        let idx = init_indices(n, k, 5);
        for (j, &i) in idx.iter().enumerate() {
            for l in 0..d {
                assert_eq!(mu.at(j, l), encode_f64(xv[i * d + l]), "row {j} col {l}");
            }
        }
    }

    #[test]
    fn horizontal_shares_reassemble_rows() {
        let (n, d, n_a, k) = (6, 2, 3, 3);
        let xv: Vec<f64> = (0..n * d).map(|i| i as f64 / 7.0).collect();
        let xa = Mat::encode(n_a, d, &xv[..n_a * d]);
        let xb = Mat::encode(n - n_a, d, &xv[n_a * d..]);
        let m0 = horizontal(&xa, n_a, n, k, 9, 0);
        let m1 = horizontal(&xb, n_a, n, k, 9, 1);
        let mu = m0.add(&m1);
        let idx = init_indices(n, k, 9);
        for (j, &i) in idx.iter().enumerate() {
            for l in 0..d {
                assert_eq!(mu.at(j, l), encode_f64(xv[i * d + l]), "row {j} col {l}");
            }
        }
    }
}
