//! S3 — Secure centroid update `F_SCU` (paper Eq. 6) and the stopping
//! criterion `F_CSC`.
//!
//! `⟨μ⟩ = ⟨Cᵀ·X⟩ / ⟨1ᵀ·C⟩`: the numerator reuses the same
//! local-plus-cross decomposition as the distance step (C is shared, X
//! blocks are party-local plaintext); the denominator is a *free* local
//! column sum of assignment shares. Division runs the normalized
//! Newton-Raphson reciprocal of [`crate::ss::divide`]. Empty clusters
//! are handled obliviously: a secure comparison flags `count = 0` lanes
//! and a MUX substitutes (old centroid, count 1) so the division is
//! always well-defined and reveals nothing.
//!
//! **Round batching:** the numerator's cross-product reveals are staged
//! as a [`PendingNumerator`] and ride the *first flight of the
//! empty-cluster comparison* (they are independent gates); the
//! denominator MUX and the numerator MUX share one fused daBit flight.
//! The pre-batching pipeline paid 2 (matmuls) + 2 (B2A + MUX) extra
//! dependent flights here.

use crate::ring::fixed::{FRAC_BITS, SCALE};
use crate::ring::matrix::Mat;
use crate::ss::arith::ssquare_elem;
use crate::ss::boolean::msb;
use crate::ss::compare::lt_public;
use crate::ss::divide::divide_rows;
use crate::ss::matmul::ss_matmul_begin;
use crate::ss::mux::mux_bits_begin;
use crate::ss::share::{trivial_share_of_mine, trivial_share_of_theirs};
use crate::ss::{Session, SessionOptions};

/// A staged S3 numerator: cross-product reveals sit in the round buffer
/// (riding whatever flight departs next) and the block assembly runs at
/// resolve time. Backends that finish eagerly (HE Protocol 2) wrap their
/// result with `PendingNumerator::ready`. This is the shared
/// [`crate::ss::pending::PendingParts`] handle — the row-tiled schedule
/// stages one per tile and sums the resolved k×d contributions.
pub type PendingNumerator = crate::ss::pending::PendingParts;

/// Stage the numerator `⟨Cᵀ·X⟩` for vertical partitioning: each party's
/// feature block contributes `⟨C⟩ᵀ·X_p = ⟨C⟩_pᵀ·X_p (local) +
/// ⟨C⟩_otherᵀ·X_p (cross)`. Blocks are reassembled in feature order at
/// resolve time. Scale f.
pub fn numerator_vertical_begin(
    ctx: &mut Session,
    x_mine: &Mat,
    c: &Mat,
    d_a: usize,
    d: usize,
) -> PendingNumerator {
    let n = c.rows;
    let k = c.cols;
    let party = ctx.party();
    let ct = c.transpose(); // k×n (my share)

    // Cross for block A (k×d_a): A supplies X_A as trivial right operand,
    // B supplies ⟨C⟩_Bᵀ.
    let cross_a = if party == 0 {
        let a = trivial_share_of_theirs(k, n);
        let b = trivial_share_of_mine(x_mine);
        ss_matmul_begin(ctx, &a, &b)
    } else {
        let a = trivial_share_of_mine(&ct);
        let b = trivial_share_of_theirs(n, d_a);
        ss_matmul_begin(ctx, &a, &b)
    };
    // Cross for block B (k×d_b): symmetric.
    let d_b = d - d_a;
    let cross_b = if party == 1 {
        let a = trivial_share_of_theirs(k, n);
        let b = trivial_share_of_mine(x_mine);
        ss_matmul_begin(ctx, &a, &b)
    } else {
        let a = trivial_share_of_mine(&ct);
        let b = trivial_share_of_theirs(n, d_b);
        ss_matmul_begin(ctx, &a, &b)
    };
    // Local term: ⟨C⟩_meᵀ · X_me (k×d_mine).
    let local = crate::runtime::dispatch::matmul(&ct, x_mine);
    PendingNumerator::new(vec![cross_a, cross_b], move |mut mats| {
        let cross_b = mats.pop().expect("cross B");
        let cross_a = mats.pop().expect("cross A");
        let (block_a, block_b) = if party == 0 {
            (local.add(&cross_a), cross_b)
        } else {
            (cross_a, local.add(&cross_b))
        };
        block_a.hstack(&block_b)
    })
}

/// Numerator for vertical partitioning (single-flight wrapper).
pub fn numerator_vertical(ctx: &mut Session, x_mine: &Mat, c: &Mat, d_a: usize, d: usize) -> Mat {
    let p = numerator_vertical_begin(ctx, x_mine, c, d_a, d);
    ctx.flush();
    p.resolve(ctx)
}

/// Stage the numerator for horizontal partitioning: row blocks
/// `⟨C_rows(p)⟩ᵀ·X_p` summed over parties. Thin monolithic wrapper over
/// the single `(0, n)` tile of
/// [`crate::kmeans::backend::HorizontalBackend`] — the row-block share
/// algebra lives there once, for every tile size. Clones the block to
/// adapt to the backend's `PartyData` (fine for the single-call and
/// test uses this wrapper serves; the driver feeds the backend its
/// long-lived `PartyData` directly).
pub fn numerator_horizontal_begin(
    ctx: &mut Session,
    x_mine: &Mat,
    c: &Mat,
    n_a: usize,
) -> PendingNumerator {
    use crate::kmeans::backend::{CrossProductBackend, HorizontalBackend, PartyData};
    let mut be = HorizontalBackend::new(n_a);
    let x = PartyData::dense_only(x_mine.clone());
    be.s3_numerator_tile(ctx, &x, c, (0, c.rows))
}

/// Numerator for horizontal partitioning (single-flight wrapper).
pub fn numerator_horizontal(ctx: &mut Session, x_mine: &Mat, c: &Mat, n_a: usize) -> Mat {
    let p = numerator_horizontal_begin(ctx, x_mine, c, n_a);
    ctx.flush();
    p.resolve(ctx)
}

/// Complete the update from a *staged* numerator (k×d, scale f) and the
/// assignment matrix: the numerator reveals coalesce into the first
/// flight of the empty-cluster comparison, and the oblivious
/// empty-cluster fallback runs both MUXes (denominator + numerator) in
/// one fused daBit flight before the broadcast division. Returns the new
/// centroid shares (k×d, scale f).
pub fn finish_update_pending(
    ctx: &mut Session,
    numerator: PendingNumerator,
    c: &Mat,
    mu_old: &Mat,
) -> Mat {
    // Denominator: counts = 1ᵀ·C — a free local share sum.
    finish_update_tiles(ctx, vec![numerator], &c.col_sums(), mu_old)
}

/// Complete the update from per-tile numerator contributions and
/// pre-accumulated counts: the tile schedule's S3 tail. Every staged
/// contribution's reveals ride the empty-cluster comparison's first
/// flight (exactly as the monolithic single-numerator path — tiling
/// adds zero flights here under lockstep), the resolved k×d tiles sum
/// into one running numerator, and a **single** division closes the
/// iteration regardless of the tile count. `counts` are the 1×k
/// integer count shares (`Σ_tiles 1ᵀ·C_tile = 1ᵀ·C`, a free local sum).
pub fn finish_update_tiles(
    ctx: &mut Session,
    numerators: Vec<PendingNumerator>,
    counts: &Mat,
    mu_old: &Mat,
) -> Mat {
    let k = counts.cols;
    let party = ctx.party();

    // empty_j = [count_j < 1] (counts are non-negative integers). The
    // staged numerator reveals depart with this comparison's first AND
    // layer — division prep and numerator share a flight.
    let ones = Mat::from_vec(1, k, vec![1; k]);
    let empty_bits = lt_public(ctx, counts, &ones);
    let mut num = Mat::zeros(mu_old.rows, mu_old.cols);
    for part in numerators {
        num = num.add(&part.resolve(ctx));
    }
    let d = num.cols;

    // den = empty ? 1 : count; num = empty ? μ_old row : numerator row.
    // Same boolean selector, two staged MUXes, one fused flight.
    let one_share = if party == 0 { ones } else { Mat::zeros(1, k) };
    let den_p = mux_bits_begin(ctx, &empty_bits, &one_share, counts, 1);
    let num_p = mux_bits_begin(ctx, &empty_bits, mu_old, &num, d);
    ctx.flush();
    let den = den_p.resolve(ctx);
    let num = num_p.resolve(ctx);

    divide_rows(ctx, &num, &den)
}

/// Complete the update from an already-computed numerator (compatibility
/// wrapper over [`finish_update_pending`]).
pub fn finish_update(ctx: &mut Session, numerator: &Mat, c: &Mat, mu_old: &Mat) -> Mat {
    finish_update_pending(ctx, PendingNumerator::ready(numerator.clone()), c, mu_old)
}

/// `F_CSC`: secure convergence check — reveals only the boolean
/// `‖μ_new − μ_old‖² < ε` (paper §4.2). One comparison on a single lane.
pub fn converged(ctx: &mut Session, mu_old: &Mat, mu_new: &Mat, eps: f64) -> bool {
    let diff = mu_new.sub(mu_old); // scale f
    let sq = ssquare_elem(ctx, &diff); // scale 2f
    let mut total = 0u64;
    for &v in &sq.data {
        total = total.wrapping_add(v);
    }
    let mut lane = Mat::from_vec(1, 1, vec![total]);
    // total − ε·2^{2f} < 0 ?
    if ctx.party() == 0 {
        let eps_enc = (eps * SCALE * (1u64 << FRAC_BITS) as f64) as i64 as u64;
        lane.data[0] = lane.data[0].wrapping_sub(eps_enc);
    }
    let bit = msb(ctx, &lane);
    // Reveal the single decision bit.
    let theirs = ctx.chan.exchange_u64s(&bit.words);
    (bit.words[0] ^ theirs[0]) & 1 == 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ring::fixed::decode_f64;
    use crate::ss::share::{reconstruct, split};
    use crate::ss::Session;
    use crate::util::prng::Prg;

    #[test]
    fn vertical_update_matches_plaintext_means() {
        // 5 samples, d = 3 (A: 2 cols, B: 1), k = 2.
        let x = [
            0.0, 0.2, 1.0, //
            0.1, 0.1, 0.8, //
            0.9, 0.8, 0.2, //
            1.0, 0.9, 0.1, //
            0.85, 0.95, 0.0,
        ];
        let assign = [0usize, 0, 1, 1, 1];
        let (n, d, d_a, k) = (5, 3, 2, 1 + 1);
        // Plaintext means.
        let mut want = vec![0.0; k * d];
        let mut cnt = vec![0usize; k];
        for i in 0..n {
            cnt[assign[i]] += 1;
            for l in 0..d {
                want[assign[i] * d + l] += x[i * d + l];
            }
        }
        for j in 0..k {
            for l in 0..d {
                want[j * d + l] /= cnt[j] as f64;
            }
        }

        let xa = Mat::encode(
            n,
            d_a,
            &(0..n).flat_map(|i| x[i * d..i * d + d_a].to_vec()).collect::<Vec<_>>(),
        );
        let xb = Mat::encode(n, 1, &(0..n).map(|i| x[i * d + 2]).collect::<Vec<_>>());
        let mut cmat = Mat::zeros(n, k);
        for i in 0..n {
            cmat.set(i, assign[i], 1);
        }
        let mu_old = Mat::encode(k, d, &vec![0.5; k * d]);
        let mut prg = Prg::new(111);
        let (c0, c1) = split(&cmat, &mut prg);
        let (m0, m1) = split(&mu_old, &mut prg);

        let ((got, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(112, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let num = numerator_vertical_begin(&mut ctx, &xa, &c0, d_a, d);
                let mu = finish_update_pending(&mut ctx, num, &c0, &m0);
                reconstruct(c, &mu)
            },
            move |c| {
                let mut ts = Dealer::new(112, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let num = numerator_vertical_begin(&mut ctx, &xb, &c1, d_a, d);
                let mu = finish_update_pending(&mut ctx, num, &c1, &m1);
                reconstruct(c, &mu)
            },
        );
        for i in 0..k * d {
            let g = decode_f64(got.data[i]);
            assert!((g - want[i]).abs() < 2e-3, "cell {i}: got {g} want {}", want[i]);
        }
    }

    #[test]
    fn empty_cluster_keeps_old_centroid() {
        // All samples to cluster 0; cluster 1 empty.
        let (n, d, d_a, k) = (4, 2, 1, 2);
        let xvals = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8];
        let xa = Mat::encode(n, 1, &(0..n).map(|i| xvals[i * d]).collect::<Vec<_>>());
        let xb = Mat::encode(n, 1, &(0..n).map(|i| xvals[i * d + 1]).collect::<Vec<_>>());
        let mut cmat = Mat::zeros(n, k);
        for i in 0..n {
            cmat.set(i, 0, 1);
        }
        let mu_old_vals = [0.9, 0.95, 0.25, 0.35];
        let mu_old = Mat::encode(k, d, &mu_old_vals);
        let mut prg = Prg::new(113);
        let (c0, c1) = split(&cmat, &mut prg);
        let (m0, m1) = split(&mu_old, &mut prg);
        let ((got, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(114, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let num = numerator_vertical(&mut ctx, &xa, &c0, d_a, d);
                let mu = finish_update(&mut ctx, &num, &c0, &m0);
                reconstruct(c, &mu)
            },
            move |c| {
                let mut ts = Dealer::new(114, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let num = numerator_vertical(&mut ctx, &xb, &c1, d_a, d);
                let mu = finish_update(&mut ctx, &num, &c1, &m1);
                reconstruct(c, &mu)
            },
        );
        // Cluster 0: mean of all rows; cluster 1: unchanged old centroid.
        let want0 = [(0.1 + 0.3 + 0.5 + 0.7) / 4.0, (0.2 + 0.4 + 0.6 + 0.8) / 4.0];
        for l in 0..d {
            assert!((decode_f64(got.at(0, l)) - want0[l]).abs() < 2e-3);
            assert!((decode_f64(got.at(1, l)) - mu_old_vals[d + l]).abs() < 2e-3);
        }
    }

    #[test]
    fn horizontal_numerator_matches() {
        let (n, d, n_a, k) = (6, 2, 4, 2);
        let mut prg = Prg::new(115);
        let xvals: Vec<f64> = (0..n * d).map(|_| prg.next_f64()).collect();
        let assign: Vec<usize> = (0..n).map(|i| i % k).collect();
        let mut cmat = Mat::zeros(n, k);
        for i in 0..n {
            cmat.set(i, assign[i], 1);
        }
        let mut want = vec![0.0; k * d];
        for i in 0..n {
            for l in 0..d {
                want[assign[i] * d + l] += xvals[i * d + l];
            }
        }
        let xa = Mat::encode(n_a, d, &xvals[..n_a * d]);
        let xb = Mat::encode(n - n_a, d, &xvals[n_a * d..]);
        let (c0, c1) = split(&cmat, &mut prg);
        let ((got, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(116, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let num = numerator_horizontal(&mut ctx, &xa, &c0, n_a);
                reconstruct(c, &num)
            },
            move |c| {
                let mut ts = Dealer::new(116, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let num = numerator_horizontal(&mut ctx, &xb, &c1, n_a);
                reconstruct(c, &num)
            },
        );
        for i in 0..k * d {
            assert!((decode_f64(got.data[i]) - want[i]).abs() < 1e-4, "cell {i}");
        }
    }

    #[test]
    fn staged_numerator_rides_the_comparison_flight() {
        // finish_update_pending with a staged numerator must cost exactly
        // CMP_ROUNDS + 1 flights before the division (the numerator
        // reveal shares the first comparison flight, the two MUXes fuse).
        use crate::ss::boolean::CMP_ROUNDS;
        let (n, d, d_a, k) = (4, 2, 1, 2);
        let mut prg = Prg::new(117);
        let x = Mat::random(n, d, &mut prg).map(|v| v >> 45);
        let xa = x.cols_slice(0, d_a);
        let xb = x.cols_slice(d_a, d);
        let mut cmat = Mat::zeros(n, k);
        for i in 0..n {
            cmat.set(i, i % k, 1);
        }
        let (c0, c1) = split(&cmat, &mut prg);
        let ((rounds, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(118, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let before = ctx.chan.meter().total().rounds;
                let num = numerator_vertical_begin(&mut ctx, &xa, &c0, d_a, d);
                let counts = c0.col_sums();
                let ones = Mat::from_vec(1, k, vec![1; k]);
                let bits = lt_public(&mut ctx, &counts, &ones);
                let num = num.resolve(&mut ctx);
                let den_p = mux_bits_begin(&mut ctx, &bits, &ones, &counts, 1);
                let num_p = mux_bits_begin(&mut ctx, &bits, &Mat::zeros(k, d), &num, d);
                ctx.flush();
                let _ = den_p.resolve(&mut ctx);
                let _ = num_p.resolve(&mut ctx);
                ctx.chan.meter().total().rounds - before
            },
            move |c| {
                let mut ts = Dealer::new(118, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let num = numerator_vertical_begin(&mut ctx, &xb, &c1, d_a, d);
                let counts = c1.col_sums();
                let ones = Mat::from_vec(1, k, vec![1; k]);
                let bits = lt_public(&mut ctx, &counts, &ones);
                let num = num.resolve(&mut ctx);
                let den_p = mux_bits_begin(&mut ctx, &bits, &Mat::zeros(1, k), &counts, 1);
                let num_p = mux_bits_begin(&mut ctx, &bits, &Mat::zeros(k, d), &num, d);
                ctx.flush();
                let _ = den_p.resolve(&mut ctx);
                let _ = num_p.resolve(&mut ctx);
            },
        );
        assert_eq!(rounds, CMP_ROUNDS + 1);
    }

    #[test]
    fn csc_detects_convergence() {
        let mu_a = Mat::encode(2, 2, &[0.5, 0.5, 0.2, 0.2]);
        let mu_b_close = Mat::encode(2, 2, &[0.5001, 0.5, 0.2, 0.2001]);
        let mu_b_far = Mat::encode(2, 2, &[0.9, 0.5, 0.2, 0.6]);
        for (mu_b, want) in [(mu_b_close, true), (mu_b_far, false)] {
            let mut prg = Prg::new(117);
            let (a0, a1) = split(&mu_a, &mut prg);
            let (b0, b1) = split(&mu_b, &mut prg);
            let ((got, _), _) = run_two_party(
                move |c| {
                    let mut ts = Dealer::new(118, 0);
                    let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                    converged(&mut ctx, &a0, &b0, 1e-3)
                },
                move |c| {
                    let mut ts = Dealer::new(118, 1);
                    let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                    converged(&mut ctx, &a1, &b1, 1e-3)
                },
            );
            assert_eq!(got, want);
        }
    }
}
