//! S1 — Secure distance computation `F'_ESD` (paper Eq. 2-5).
//!
//! Computes shares of `D' = U − 2·X·μᵀ` where `U` broadcasts the squared
//! centroid norms; the sample term `Σ X_i²` is constant per row and
//! omitted (it never changes comparisons). Everything stays at scale 2f —
//! comparisons are scale-invariant, so no truncation round is spent here.
//!
//! **Round batching:** the norm square and both vertical cross products
//! are independent gates, so their masked reveals are staged together
//! and the whole step is **one** flight (the seed engine paid three).
//! With `EsdMode::Naive` the cross products instead run one scalar
//! protocol per (sample, centroid) pair — the pre-vectorization baseline
//! of Q3. The HE path stages its norm reveal the same way and pushes the
//! cross products through Protocol 2 (see [`crate::kmeans::backend`]).

use crate::ring::matrix::Mat;
use crate::ss::arith::ssquare_elem_begin;
use crate::ss::matmul::{private_matmul, private_matmul_begin, private_matmul_rows_begin};
use crate::ss::pending::Pending;
use crate::ss::{Session, SessionOptions};

/// Stage the shares of the per-cluster squared-norm row
/// `[|μ_1|², …, |μ_k|²]` as a 1×k matrix (scale 2f). One staged gate
/// serves every row tile of an iteration: the k-lane row is broadcast
/// per tile with [`broadcast_norm_rows`], so tiling never re-stages it.
pub fn centroid_norms_row_begin(ctx: &mut Session, mu: &Mat) -> Pending<Mat> {
    let k = mu.rows;
    let d = mu.cols;
    ssquare_elem_begin(ctx, mu).map(move |sq| {
        // sq is k×d at scale 2f; reduce each centroid's row.
        let mut u = Mat::zeros(1, k);
        for j in 0..k {
            let mut acc = 0u64;
            for l in 0..d {
                acc = acc.wrapping_add(sq.data[j * d + l]);
            }
            u.data[j] = acc;
        }
        u
    })
}

/// Broadcast a 1×k norm row over `n` sample rows.
pub fn broadcast_norm_rows(u_row: &Mat, n: usize) -> Mat {
    let k = u_row.cols;
    let mut out = Mat::zeros(n, k);
    for i in 0..n {
        out.row_mut(i).copy_from_slice(&u_row.data);
    }
    out
}

/// Assemble a distance tile `⟨D'⟩ = ⟨U⟩ − 2·⟨X·μᵀ⟩` from the shared norm
/// row (1×k) and the tile's complete cross-product share (n_t×k, local
/// term included). Scale 2f.
pub fn dprime_from_parts(u_row: &Mat, xmu: &Mat) -> Mat {
    broadcast_norm_rows(u_row, xmu.rows).sub(&xmu.scale(2))
}

/// Stage the shares of the per-cluster squared-norm row
/// `[|μ_1|², …, |μ_k|²]`, broadcast to n rows (scale 2f). Resolves after
/// the next flush, so the reveal rides whatever flight the caller builds.
pub fn centroid_norms_begin(ctx: &mut Session, mu: &Mat, n: usize) -> Pending<Mat> {
    centroid_norms_row_begin(ctx, mu).map(move |u| broadcast_norm_rows(&u, n))
}

/// Shares of the broadcast squared-norm matrix (single-gate wrapper).
pub fn centroid_norms(ctx: &mut Session, mu: &Mat, n: usize) -> Mat {
    let p = centroid_norms_begin(ctx, mu, n);
    ctx.flush();
    p.resolve(ctx)
}

/// Split a k×d centroid share into the vertical blocks
/// (k×d_a for A's feature columns, k×d_b for B's).
pub fn split_mu_vertical(mu: &Mat, d_a: usize) -> (Mat, Mat) {
    (mu.cols_slice(0, d_a), mu.cols_slice(d_a, mu.cols))
}

/// Stage the two vertical cross products for one row tile:
/// `X_A[r0..r1]·(⟨μ⟩_B A-block)ᵀ` and `X_B[r0..r1]·(⟨μ⟩_A B-block)ᵀ`
/// (each n_t×k). Both reveals — and every other tile's — ride one
/// flight together with anything else the caller staged; the matrix
/// triples are tile-shaped (`(n_t, d_a, k)` / `(n_t, d_b, k)`), never
/// n-sized.
pub fn vertical_cross_tile_begin(
    ctx: &mut Session,
    x_mine: &Mat,
    rows: (usize, usize),
    mu: &Mat,
    d_a: usize,
) -> (Pending<Mat>, Pending<Mat>) {
    let n_t = rows.1 - rows.0;
    let k = mu.rows;
    let d_b = mu.cols - d_a;
    let party = ctx.party();
    let (mu_a_blk, mu_b_blk) = split_mu_vertical(mu, d_a);
    // Cross 1: X_A tile (A plaintext) · ⟨μ⟩_B's A-block ᵀ (B share).
    let cross1 = if party == 0 {
        private_matmul_rows_begin(ctx, x_mine, rows, (d_a, k), true)
    } else {
        let mb = mu_a_blk.transpose(); // d_a×k
        private_matmul_begin(ctx, &mb, (d_a, k), (n_t, d_a), false)
    };
    // Cross 2: X_B tile (B plaintext) · ⟨μ⟩_A's B-block ᵀ (A share).
    let cross2 = if party == 1 {
        private_matmul_rows_begin(ctx, x_mine, rows, (d_b, k), true)
    } else {
        let mb = mu_b_blk.transpose(); // d_b×k
        private_matmul_begin(ctx, &mb, (d_b, k), (n_t, d_b), false)
    };
    (cross1, cross2)
}

/// Stage the two vertical cross products over all rows (monolithic
/// wrapper around [`vertical_cross_tile_begin`]).
pub fn vertical_cross_begin(
    ctx: &mut Session,
    x_mine: &Mat,
    mu: &Mat,
    d_a: usize,
) -> (Pending<Mat>, Pending<Mat>) {
    vertical_cross_tile_begin(ctx, x_mine, (0, x_mine.rows), mu, d_a)
}

/// Vertical F'_ESD: `x_mine` is this party's plaintext feature block
/// (n×d_mine, fixed-point), `mu` this party's centroid share (k×d).
/// Returns shares of `D' (n×k)` at scale 2f. One flight total.
pub fn vertical(ctx: &mut Session, x_mine: &Mat, mu: &Mat, d_a: usize) -> Mat {
    let n = x_mine.rows;
    let party = ctx.party();
    let u_p = centroid_norms_begin(ctx, mu, n);
    let (c1_p, c2_p) = vertical_cross_begin(ctx, x_mine, mu, d_a);
    ctx.flush();
    let u = u_p.resolve(ctx);
    let cross1 = c1_p.resolve(ctx);
    let cross2 = c2_p.resolve(ctx);

    // Local term: X_mine · ⟨μ⟩_mine-block ᵀ contributes to my share.
    let (mu_a_blk, mu_b_blk) = split_mu_vertical(mu, d_a);
    let my_blk = if party == 0 { &mu_a_blk } else { &mu_b_blk };
    let local = crate::runtime::dispatch::matmul(x_mine, &my_blk.transpose()); // n×k

    let xmu = local.add(&cross1).add(&cross2);
    u.sub(&xmu.scale(2))
}

/// Horizontal F'_ESD: `x_mine` is this party's sample block (n_mine×d);
/// `n_a` is party A's (public) sample count. Returns shares of the full
/// stacked `D' (n×k)`. One flight total. Thin monolithic wrapper over
/// the single `(0, n)` tile of
/// [`crate::kmeans::backend::HorizontalBackend`] — the row-block share
/// algebra lives there once, for every tile size. Clones the block to
/// adapt to the backend's `PartyData` (fine for the single-call and
/// test uses this wrapper serves; the driver feeds the backend its
/// long-lived `PartyData` directly).
pub fn horizontal(ctx: &mut Session, x_mine: &Mat, mu: &Mat, n_a: usize, n: usize) -> Mat {
    use crate::kmeans::backend::{CrossProductBackend, HorizontalBackend, PartyData};
    let u_p = centroid_norms_row_begin(ctx, mu);
    let mut be = HorizontalBackend::new(n_a);
    let x = PartyData::dense_only(x_mine.clone());
    let xmu_p = be.s1_xmu_tile(ctx, &x, mu, (0, n));
    ctx.flush();
    let u = u_p.resolve(ctx);
    dprime_from_parts(&u, &xmu_p.resolve(ctx))
}

/// The naive cross-product sum (Q3 ablation, vertical only): one scalar
/// secure multiplication *per (sample, centroid) pair* — n·k protocol
/// flights instead of one. Returns the summed cross contribution (n×k).
pub fn vertical_naive_cross(ctx: &mut Session, x_mine: &Mat, mu: &Mat, d_a: usize) -> Mat {
    let n = x_mine.rows;
    let k = mu.rows;
    let d_b = mu.cols - d_a;
    let party = ctx.party();
    let (mu_a_blk, mu_b_blk) = split_mu_vertical(mu, d_a);
    let mut xmu = Mat::zeros(n, k);
    for i in 0..n {
        for j in 0..k {
            // Cross 1 for this single pair: row i of X_A · col j of μ_B,A-blk.
            let c1 = if party == 0 {
                let xi = Mat::from_vec(1, d_a, x_mine.row(i).to_vec());
                private_matmul(ctx, &xi, (1, d_a), (d_a, 1), true)
            } else {
                let mj: Vec<u64> = (0..d_a).map(|l| mu_a_blk.at(j, l)).collect();
                let mj = Mat::from_vec(d_a, 1, mj);
                private_matmul(ctx, &mj, (d_a, 1), (1, d_a), false)
            };
            let c2 = if party == 1 {
                let xi = Mat::from_vec(1, d_b, x_mine.row(i).to_vec());
                private_matmul(ctx, &xi, (1, d_b), (d_b, 1), true)
            } else {
                let mj: Vec<u64> = (0..d_b).map(|l| mu_b_blk.at(j, l)).collect();
                let mj = Mat::from_vec(d_b, 1, mj);
                private_matmul(ctx, &mj, (d_b, 1), (1, d_b), false)
            };
            let cell = &mut xmu.data[i * k + j];
            *cell = cell.wrapping_add(c1.data[0]).wrapping_add(c2.data[0]);
        }
    }
    xmu
}

/// Pre-vectorization baseline (Q3 ablation, vertical only): the same
/// D' but with one scalar secure multiplication per (sample, centroid)
/// pair.
pub fn vertical_naive(ctx: &mut Session, x_mine: &Mat, mu: &Mat, d_a: usize) -> Mat {
    let n = x_mine.rows;
    let party = ctx.party();
    let u = centroid_norms(ctx, mu, n);
    let (mu_a_blk, mu_b_blk) = split_mu_vertical(mu, d_a);
    let my_blk = if party == 0 { &mu_a_blk } else { &mu_b_blk };
    let local = x_mine.matmul(&my_blk.transpose());
    let xmu = local.add(&vertical_naive_cross(ctx, x_mine, mu, d_a));
    u.sub(&xmu.scale(2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ring::fixed::{decode_f64, SCALE};
    use crate::ss::share::{reconstruct, split};
    use crate::ss::Session;
    use crate::util::prng::Prg;

    /// Reference D' on plaintext reals.
    fn ref_dprime(x: &[f64], mu: &[f64], n: usize, d: usize, k: usize) -> Vec<f64> {
        let mut out = vec![0.0; n * k];
        for i in 0..n {
            for j in 0..k {
                let mut normsq = 0.0;
                let mut dot = 0.0;
                for l in 0..d {
                    normsq += mu[j * d + l] * mu[j * d + l];
                    dot += x[i * d + l] * mu[j * d + l];
                }
                out[i * k + j] = normsq - 2.0 * dot;
            }
        }
        out
    }

    fn decode_2f(w: u64) -> f64 {
        decode_f64(w) / SCALE
    }

    fn run_vertical_case(naive: bool) {
        let (n, d, k, d_a) = (6, 4, 3, 2);
        let mut prg = Prg::new(91);
        let x: Vec<f64> = (0..n * d).map(|_| prg.next_f64()).collect();
        let muv: Vec<f64> = (0..k * d).map(|_| prg.next_f64()).collect();
        let want = ref_dprime(&x, &muv, n, d, k);

        // A holds cols [0,2), B holds [2,4).
        let xa = Mat::encode(
            n,
            d_a,
            &(0..n).flat_map(|i| x[i * d..i * d + d_a].to_vec()).collect::<Vec<_>>(),
        );
        let xb = Mat::encode(
            n,
            d - d_a,
            &(0..n).flat_map(|i| x[i * d + d_a..(i + 1) * d].to_vec()).collect::<Vec<_>>(),
        );
        let mu = Mat::encode(k, d, &muv);
        let (mu0, mu1) = split(&mu, &mut prg);

        let ((got, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(92, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let dm = if naive {
                    vertical_naive(&mut ctx, &xa, &mu0, d_a)
                } else {
                    vertical(&mut ctx, &xa, &mu0, d_a)
                };
                reconstruct(c, &dm)
            },
            move |c| {
                let mut ts = Dealer::new(92, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let dm = if naive {
                    vertical_naive(&mut ctx, &xb, &mu1, d_a)
                } else {
                    vertical(&mut ctx, &xb, &mu1, d_a)
                };
                reconstruct(c, &dm)
            },
        );
        for i in 0..n * k {
            let g = decode_2f(got.data[i]);
            assert!((g - want[i]).abs() < 1e-4, "cell {i}: got {g} want {}", want[i]);
        }
    }

    #[test]
    fn vertical_matches_plaintext() {
        run_vertical_case(false);
    }

    #[test]
    fn naive_matches_plaintext() {
        run_vertical_case(true);
    }

    #[test]
    fn horizontal_matches_plaintext() {
        let (n, d, k, n_a) = (7, 3, 2, 4);
        let mut prg = Prg::new(93);
        let x: Vec<f64> = (0..n * d).map(|_| prg.next_f64()).collect();
        let muv: Vec<f64> = (0..k * d).map(|_| prg.next_f64()).collect();
        let want = ref_dprime(&x, &muv, n, d, k);
        let xa = Mat::encode(n_a, d, &x[..n_a * d]);
        let xb = Mat::encode(n - n_a, d, &x[n_a * d..]);
        let mu = Mat::encode(k, d, &muv);
        let (mu0, mu1) = split(&mu, &mut prg);

        let ((got, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(94, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let dm = horizontal(&mut ctx, &xa, &mu0, n_a, n);
                reconstruct(c, &dm)
            },
            move |c| {
                let mut ts = Dealer::new(94, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let dm = horizontal(&mut ctx, &xb, &mu1, n_a, n);
                reconstruct(c, &dm)
            },
        );
        for i in 0..n * k {
            let g = decode_2f(got.data[i]);
            assert!((g - want[i]).abs() < 1e-4, "cell {i}: got {g} want {}", want[i]);
        }
    }

    #[test]
    fn vectorized_vertical_is_one_flight() {
        let (n, d, k, d_a) = (4, 2, 2, 1);
        let mut prg = Prg::new(95);
        let x: Vec<f64> = (0..n * d).map(|_| prg.next_f64()).collect();
        let mu = Mat::encode(k, d, &vec![0.5; k * d]);
        let (mu0, mu1) = split(&mu, &mut prg);
        // A holds cols [0, d_a), B holds [d_a, d) — per-row column
        // slicing as in run_vertical_case, so the round-count assertion
        // runs on a real vertical instance (a contiguous `&x[..n*d_a]`
        // slice of the row-major buffer is not a column split).
        let xa = Mat::encode(
            n,
            d_a,
            &(0..n).flat_map(|i| x[i * d..i * d + d_a].to_vec()).collect::<Vec<_>>(),
        );
        let xb = Mat::encode(
            n,
            d - d_a,
            &(0..n).flat_map(|i| x[i * d + d_a..(i + 1) * d].to_vec()).collect::<Vec<_>>(),
        );
        let ((_, m_vec), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(96, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                vertical(&mut ctx, &xa.clone(), &mu0, d_a);
            },
            move |c| {
                let mut ts = Dealer::new(96, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                vertical(&mut ctx, &xb.clone(), &mu1, d_a);
            },
        );
        // Round-batched: norms + both cross products share one flight.
        assert_eq!(m_vec.total().rounds, 1, "S1 must coalesce into one flight");
    }
}
