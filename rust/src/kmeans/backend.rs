//! Unified cross-product backends for the secure Lloyd iteration.
//!
//! S1 (distance) and S3 (update) differ between the dense, sparse and
//! ablation configurations **only** in how the cross products are
//! evaluated; everything else (norms, `F_min^k`, the empty-cluster
//! fallback, division) is shared. The seed code branched ad hoc between
//! `kmeans::esd`, `kmeans::sparse` and `sparse::protocol2`; this module
//! replaces that with one [`CrossProductBackend`] trait whose entry
//! points are **row-tile granular**: the driver walks a tile schedule
//! (`config::tile_schedule`) and asks the backend to stage each tile's
//! S1 product `⟨X_tile·μᵀ⟩` and S3 numerator contribution
//! `⟨C_tileᵀ·X_tile⟩`. Four implementations ride that schedule:
//!
//! * [`BeaverBackend`] — vertical partition, matrix Beaver triples
//!   (Eq. 3); every tile's reveals share the caller's flight, and every
//!   triple is tile-shaped — the offline demand never contains an
//!   n-sized matrix dimension once tiling is on;
//! * [`HorizontalBackend`] — the horizontally partitioned analogue: a
//!   tile's rows split at the ownership boundary `n_a` into an A-block
//!   and a B-block, each a tile-shaped private matmul;
//! * [`HeBackend`] — HE Protocol 2 (paper §4.3): the sparse holder
//!   evaluates over ciphertexts of the small dense operand, skipping
//!   zeros, per tile with communication `O((d+n_t)·k)` ciphertexts;
//! * [`NaiveBackend`] — the pre-vectorization Q3 ablation (one scalar
//!   protocol per (sample, centroid) pair).
//!
//! [`select`] performs the `EsdMode::Auto` dispatch: the parties
//! exchange local nonzero counts once at setup (public metadata — the
//! paper treats the sparsity degree as known) and pick the HE path when
//! the joint density falls below [`AUTO_DENSITY_THRESHOLD`].

use super::config::{EsdMode, Partition, SecureKmeansConfig};
use super::esd;
use super::update::numerator_vertical_begin;
use crate::bigint::BigUint;
use crate::he::ou::{Ou, OuPk, OuSk};
use crate::he::HeScheme;
use crate::net::Chan;
use crate::ring::matrix::Mat;
use crate::sparse::csr::Csr;
use crate::sparse::protocol2;
use crate::ss::matmul::{private_matmul_begin, private_matmul_rows_begin};
use crate::ss::pending::PendingParts;
use crate::ss::Session;
use crate::util::prng::Prg;

/// Joint-density threshold below which `EsdMode::Auto` routes cross
/// products through HE Protocol 2 (density = nnz / total; `sparse_gen`
/// workloads sit well below it, dense Gaussian blobs at ≈ 1.0).
pub const AUTO_DENSITY_THRESHOLD: f64 = 0.7;

/// One party's feature block, with the CSR view the sparse path needs.
pub struct PartyData {
    /// Fixed-point dense block (n×d_mine).
    pub dense: Mat,
    /// CSR view (built when the run may take the HE path).
    pub csr: Option<Csr>,
}

impl PartyData {
    pub fn dense_only(dense: Mat) -> PartyData {
        PartyData { dense, csr: None }
    }

    pub fn with_csr(dense: Mat) -> PartyData {
        PartyData { csr: Some(Csr::from_dense(&dense)), dense }
    }

    /// Nonzero entries of the block (the Auto-dispatch signal).
    pub fn nnz(&self) -> u64 {
        match &self.csr {
            Some(c) => c.nnz() as u64,
            None => self.dense.data.iter().filter(|&&v| v != 0).count() as u64,
        }
    }

    fn csr(&self) -> &Csr {
        self.csr.as_ref().expect("CSR view not built for this run")
    }

    /// Local `X_mine · rhs`, through the sparse view when present.
    pub fn local_matmul(&self, rhs: &Mat) -> Mat {
        self.local_matmul_rows((0, self.dense.rows), rhs)
    }

    /// Local `X_mine[r0..r1] · rhs` for one row tile, through the sparse
    /// view when present. The full range borrows the existing buffers —
    /// the monolithic schedule pays no per-iteration copy. Large
    /// products (dense and CSR) fan out across
    /// [`crate::runtime::pool::global_threads`] row-block workers,
    /// bit-identically.
    pub fn local_matmul_rows(&self, rows: (usize, usize), rhs: &Mat) -> Mat {
        let full = rows == (0, self.dense.rows);
        match &self.csr {
            Some(c) if full => crate::runtime::pool::csr_matmul_auto(c, rhs),
            Some(c) => {
                crate::runtime::pool::csr_matmul_auto(&c.rows_slice(rows.0, rows.1), rhs)
            }
            None if full => crate::runtime::dispatch::matmul(&self.dense, rhs),
            None => crate::runtime::dispatch::matmul(&self.dense.rows_slice(rows.0, rows.1), rhs),
        }
    }

    /// The tile's CSR view: borrowed for the full range, sliced otherwise.
    fn csr_tile(&self, rows: (usize, usize)) -> std::borrow::Cow<'_, Csr> {
        let full = self.csr();
        if rows == (0, full.rows) {
            std::borrow::Cow::Borrowed(full)
        } else {
            std::borrow::Cow::Owned(full.rows_slice(rows.0, rows.1))
        }
    }

    /// The tile's dense view: borrowed for the full range, sliced
    /// otherwise.
    fn dense_tile(&self, rows: (usize, usize)) -> std::borrow::Cow<'_, Mat> {
        if rows == (0, self.dense.rows) {
            std::borrow::Cow::Borrowed(&self.dense)
        } else {
            std::borrow::Cow::Owned(self.dense.rows_slice(rows.0, rows.1))
        }
    }
}

/// How one Lloyd iteration evaluates its cross products, one row tile at
/// a time. `rows` is always the tile's **global** row range `[r0, r1)`
/// out of the n samples; the monolithic schedule is the single tile
/// `(0, n)`. Deferred backends (Beaver, horizontal) stage their reveals
/// and leave the flush to the caller — under `TileFlights::Lockstep`
/// every tile of a step therefore shares one flight. Eager backends (HE
/// Protocol 2's ciphertext exchange, the naive scalar loop) run their
/// own communication and return a ready handle.
pub trait CrossProductBackend: Send {
    /// Backend label (reported in [`super::secure::SecureKmeansOutput`]).
    fn name(&self) -> &'static str;

    /// Stage shares of this tile's complete product `X[r0..r1]·μᵀ`
    /// (n_t×k, **local term included**), at scale 2f like `mu`.
    fn s1_xmu_tile(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        mu: &Mat,
        rows: (usize, usize),
    ) -> PendingParts;

    /// Stage this tile's S3 numerator contribution `⟨C_tileᵀ·X_tile⟩`
    /// (k×d, local term included); the driver sums the resolved tiles.
    /// `c_tile` is this party's share of the tile's assignment rows
    /// (n_t×k).
    fn s3_numerator_tile(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        c_tile: &Mat,
        rows: (usize, usize),
    ) -> PendingParts;
}

// ---------------------------------------------------------------------
// Beaver (dense vertical, vectorized — Eq. 3)
// ---------------------------------------------------------------------

/// Matrix-Beaver cross products for the vertical partition: all reveals
/// of a step — across tiles — share one flight.
pub struct BeaverBackend {
    d_a: usize,
    d: usize,
}

impl BeaverBackend {
    pub fn new(d_a: usize, d: usize) -> BeaverBackend {
        BeaverBackend { d_a, d }
    }
}

impl CrossProductBackend for BeaverBackend {
    fn name(&self) -> &'static str {
        "beaver"
    }

    fn s1_xmu_tile(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        mu: &Mat,
        rows: (usize, usize),
    ) -> PendingParts {
        let (c1_p, c2_p) = esd::vertical_cross_tile_begin(s, &x.dense, rows, mu, self.d_a);
        let (mu_a_blk, mu_b_blk) = esd::split_mu_vertical(mu, self.d_a);
        let my_blk = if s.party() == 0 { &mu_a_blk } else { &mu_b_blk };
        let local = x.local_matmul_rows(rows, &my_blk.transpose());
        PendingParts::new(vec![c1_p, c2_p], move |mut mats| {
            let c2 = mats.pop().expect("cross 2");
            let c1 = mats.pop().expect("cross 1");
            local.add(&c1).add(&c2)
        })
    }

    fn s3_numerator_tile(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        c_tile: &Mat,
        rows: (usize, usize),
    ) -> PendingParts {
        let x_tile = x.dense_tile(rows);
        numerator_vertical_begin(s, &x_tile, c_tile, self.d_a, self.d)
    }
}

// ---------------------------------------------------------------------
// Horizontal partition (Beaver-style row blocks)
// ---------------------------------------------------------------------

/// The horizontally partitioned schedule on the same tile interface: a
/// tile's global rows `[t0, t1)` split at the ownership boundary `n_a`
/// into an A-overlap and a B-overlap, and each non-empty overlap is one
/// tile-shaped private matmul (`(t_a, d, k)` / `(t_b, d, k)` triples —
/// never n_a- or n-sized once tiling is on).
pub struct HorizontalBackend {
    n_a: usize,
}

impl HorizontalBackend {
    pub fn new(n_a: usize) -> HorizontalBackend {
        HorizontalBackend { n_a }
    }

    /// A tile's overlap with the A rows `[0, n_a)` and B rows `[n_a, n)`,
    /// as global ranges.
    fn overlaps(
        &self,
        rows: (usize, usize),
    ) -> ((usize, usize), (usize, usize)) {
        let (t0, t1) = rows;
        let a = (t0.min(self.n_a), t1.min(self.n_a));
        let b = (t0.max(self.n_a), t1.max(self.n_a));
        (a, b)
    }
}

impl CrossProductBackend for HorizontalBackend {
    fn name(&self) -> &'static str {
        "beaver"
    }

    fn s1_xmu_tile(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        mu: &Mat,
        rows: (usize, usize),
    ) -> PendingParts {
        let k = mu.rows;
        let d = mu.cols;
        let party = s.party();
        let n_a = self.n_a;
        let ((a0, a1), (b0, b1)) = self.overlaps(rows);
        let (ta, tb) = (a1 - a0, b1 - b0);
        let mt = mu.transpose(); // d×k (my centroid share)
        let mut parts = Vec::new();
        let mut local_a: Option<Mat> = None;
        let mut local_b: Option<Mat> = None;
        // A-overlap: X_A·μᵀ = X_A·⟨μ⟩_Aᵀ (A local) + ⟨X_A·⟨μ⟩_Bᵀ⟩ (cross).
        if ta > 0 {
            parts.push(if party == 0 {
                local_a = Some(x.local_matmul_rows((a0, a1), &mt));
                private_matmul_rows_begin(s, &x.dense, (a0, a1), (d, k), true)
            } else {
                private_matmul_begin(s, &mt, (d, k), (ta, d), false)
            });
        }
        // B-overlap: symmetric; B's local rows are offset by n_a.
        if tb > 0 {
            parts.push(if party == 1 {
                local_b = Some(x.local_matmul_rows((b0 - n_a, b1 - n_a), &mt));
                private_matmul_rows_begin(s, &x.dense, (b0 - n_a, b1 - n_a), (d, k), true)
            } else {
                private_matmul_begin(s, &mt, (d, k), (tb, d), false)
            });
        }
        PendingParts::new(parts, move |mut mats| {
            let cross_b = if tb > 0 { mats.pop().expect("cross B") } else { Mat::zeros(0, k) };
            let cross_a = if ta > 0 { mats.pop().expect("cross A") } else { Mat::zeros(0, k) };
            let blk_a = match local_a {
                Some(l) => l.add(&cross_a),
                None => cross_a,
            };
            let blk_b = match local_b {
                Some(l) => l.add(&cross_b),
                None => cross_b,
            };
            blk_a.vstack(&blk_b)
        })
    }

    fn s3_numerator_tile(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        c_tile: &Mat,
        rows: (usize, usize),
    ) -> PendingParts {
        let k = c_tile.cols;
        let d = x.dense.cols;
        let party = s.party();
        let n_a = self.n_a;
        let (t0, _t1) = rows;
        let ((a0, a1), (b0, b1)) = self.overlaps(rows);
        let (ta, tb) = (a1 - a0, b1 - b0);
        let mut parts = Vec::new();
        let mut local: Option<Mat> = None;
        // A-overlap: ⟨C_Aᵀ⟩·X_A = ⟨C_A⟩_0ᵀ·X_A (A local) + cross with
        // B's assignment share. Overlap rows sit at tile-local indices
        // [a0−t0, a1−t0) of c_tile.
        if ta > 0 {
            let c_a = c_tile.rows_slice(a0 - t0, a1 - t0).transpose(); // k×t_a
            parts.push(if party == 0 {
                let x_rows = x.dense_tile((a0, a1));
                local = Some(c_a.matmul(&x_rows));
                let a = crate::ss::share::trivial_share_of_theirs(k, ta);
                let b = crate::ss::share::trivial_share_of_mine(&x_rows);
                crate::ss::matmul::ss_matmul_begin(s, &a, &b)
            } else {
                let a = crate::ss::share::trivial_share_of_mine(&c_a);
                let b = crate::ss::share::trivial_share_of_theirs(ta, d);
                crate::ss::matmul::ss_matmul_begin(s, &a, &b)
            });
        }
        // B-overlap: symmetric; B's local X rows are offset by n_a.
        if tb > 0 {
            let c_b = c_tile.rows_slice(b0 - t0, b1 - t0).transpose(); // k×t_b
            parts.push(if party == 1 {
                let x_rows = x.dense_tile((b0 - n_a, b1 - n_a));
                local = Some(match local.take() {
                    Some(l) => l.add(&c_b.matmul(&x_rows)),
                    None => c_b.matmul(&x_rows),
                });
                let a = crate::ss::share::trivial_share_of_theirs(k, tb);
                let b = crate::ss::share::trivial_share_of_mine(&x_rows);
                crate::ss::matmul::ss_matmul_begin(s, &a, &b)
            } else {
                let a = crate::ss::share::trivial_share_of_mine(&c_b);
                let b = crate::ss::share::trivial_share_of_theirs(tb, d);
                crate::ss::matmul::ss_matmul_begin(s, &a, &b)
            });
        }
        PendingParts::new(parts, move |mats| {
            let mut num = match local {
                Some(l) => l,
                None => Mat::zeros(k, d),
            };
            for m in mats {
                num = num.add(&m);
            }
            num
        })
    }
}

// ---------------------------------------------------------------------
// Naive (Q3 ablation)
// ---------------------------------------------------------------------

/// One scalar secure product per (sample, centroid) pair — n·k flights.
pub struct NaiveBackend {
    d_a: usize,
    d: usize,
}

impl NaiveBackend {
    pub fn new(d_a: usize, d: usize) -> NaiveBackend {
        NaiveBackend { d_a, d }
    }
}

impl CrossProductBackend for NaiveBackend {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn s1_xmu_tile(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        mu: &Mat,
        rows: (usize, usize),
    ) -> PendingParts {
        s.flush(); // the staged norm reveal cannot ride a scalar loop
        let x_tile = x.dense_tile(rows);
        let cross = esd::vertical_naive_cross(s, &x_tile, mu, self.d_a);
        let (mu_a_blk, mu_b_blk) = esd::split_mu_vertical(mu, self.d_a);
        let my_blk = if s.party() == 0 { &mu_a_blk } else { &mu_b_blk };
        let local = x_tile.matmul(&my_blk.transpose());
        PendingParts::ready(local.add(&cross))
    }

    fn s3_numerator_tile(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        c_tile: &Mat,
        rows: (usize, usize),
    ) -> PendingParts {
        // The ablation targets S1 only (as in the paper's Q3 study).
        let x_tile = x.dense_tile(rows);
        numerator_vertical_begin(s, &x_tile, c_tile, self.d_a, self.d)
    }
}

// ---------------------------------------------------------------------
// HE Protocol 2 (sparse path, paper §4.3)
// ---------------------------------------------------------------------

/// Serialize an OU public key (n, g, h as length-prefixed big-endian).
pub fn pk_to_bytes(pk: &OuPk) -> Vec<u8> {
    let mut out = Vec::new();
    for part in [&pk.n, &pk.g, &pk.h] {
        let b = part.to_bytes_be();
        out.extend_from_slice(&(b.len() as u32).to_le_bytes());
        out.extend_from_slice(&b);
    }
    out
}

pub fn pk_from_bytes(bytes: &[u8]) -> OuPk {
    let mut parts = Vec::with_capacity(3);
    let mut off = 0;
    for _ in 0..3 {
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        off += 4;
        parts.push(BigUint::from_bytes_be(&bytes[off..off + len]));
        off += len;
    }
    let n = parts.remove(0);
    let g = parts.remove(0);
    let h = parts.remove(0);
    OuPk { n_bits: n.bits(), n, g, h }
}

/// HE cross products over each party's Okamoto-Uchiyama key pair
/// (paper §5.1); public keys are exchanged once at setup. The HE
/// exchange is eager request-response traffic (ciphertexts cannot ride
/// the round buffer), so tiles cost flights proportionally — the HE
/// path's win is bytes and sparsity-proportional work, not rounds.
pub struct HeBackend {
    my_pk: OuPk,
    my_sk: OuSk,
    their_pk: OuPk,
    prg: Prg,
    d_a: usize,
    d: usize,
    /// Worker threads for the ciphertext fan-out (encryption vectors,
    /// homomorphic row evaluation, HE2SS masking/decryption). Wire
    /// frames are byte-identical for any value.
    threads: usize,
}

impl HeBackend {
    /// Generate this party's key pair and exchange public keys.
    /// `threads` caps the per-tile ciphertext fan-out (see
    /// [`crate::sparse::protocol2::sparse_party_par`]).
    pub fn setup(
        chan: &mut Chan,
        he_bits: usize,
        seed: u128,
        d_a: usize,
        d: usize,
        threads: usize,
    ) -> HeBackend {
        let party = chan.party;
        let mut prg = Prg::new(seed ^ ((party as u128) << 96) ^ 0xE1);
        chan.set_phase("offline.hekeys");
        let (my_pk, my_sk) = Ou::keygen(he_bits, &mut prg);
        chan.send_bytes(&pk_to_bytes(&my_pk));
        let their_pk = pk_from_bytes(&chan.recv_bytes());
        HeBackend { my_pk, my_sk, their_pk, prg, d_a, d, threads: threads.max(1) }
    }

    /// One directed sparse product: this party is the sparse holder when
    /// `my_turn_sparse`, otherwise the dense holder of `dense`.
    #[allow(clippy::too_many_arguments)]
    fn sparse_cross(
        &mut self,
        chan: &mut Chan,
        x_csr: &Csr,
        dense: &Mat,
        x_rows: usize,
        y_shape: (usize, usize),
        my_turn_sparse: bool,
    ) -> Mat {
        if my_turn_sparse {
            protocol2::sparse_party_par::<Ou>(
                chan,
                &self.their_pk,
                x_csr,
                y_shape,
                &mut self.prg,
                self.threads,
            )
        } else {
            protocol2::dense_party_par::<Ou>(
                chan,
                &self.my_pk,
                &self.my_sk,
                dense,
                x_rows,
                &mut self.prg,
                self.threads,
            )
        }
    }
}

impl CrossProductBackend for HeBackend {
    fn name(&self) -> &'static str {
        "he-protocol2"
    }

    fn s1_xmu_tile(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        mu: &Mat,
        rows: (usize, usize),
    ) -> PendingParts {
        let n_t = rows.1 - rows.0;
        let k = mu.rows;
        let d = mu.cols;
        let d_a = self.d_a;
        let party = s.party();
        s.flush(); // ship any staged reveals (the norm) before the HE exchange
        let (mu_a_blk, mu_b_blk) = esd::split_mu_vertical(mu, d_a);
        let x_tile = x.csr_tile(rows);
        // Cross 1: X_A tile (sparse at A) × ⟨μ_B⟩ A-block ᵀ (dense at B).
        let ya = mu_a_blk.transpose(); // d_a×k — B's share is the payload
        let cross1 = self.sparse_cross(s.chan, &x_tile, &ya, n_t, (d_a, k), party == 0);
        // Cross 2: X_B tile (sparse at B) × ⟨μ_A⟩ B-block ᵀ (dense at A).
        let yb = mu_b_blk.transpose(); // d_b×k
        let cross2 = self.sparse_cross(s.chan, &x_tile, &yb, n_t, (d - d_a, k), party == 1);
        // Local term through the tile's CSR view.
        let my_blk = if party == 0 { &mu_a_blk } else { &mu_b_blk };
        let local = x_tile.matmul_dense(&my_blk.transpose());
        PendingParts::ready(local.add(&cross1).add(&cross2))
    }

    fn s3_numerator_tile(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        c_tile: &Mat,
        rows: (usize, usize),
    ) -> PendingParts {
        let n_t = c_tile.rows;
        let k = c_tile.cols;
        let d_a = self.d_a;
        let d = self.d;
        let party = s.party();
        let d_mine = if party == 0 { d_a } else { d - d_a };
        let x_tile = x.csr_tile(rows);
        // Local: ⟨C_tile⟩_meᵀ · X_me = (X_meᵀ·⟨C_tile⟩_me)ᵀ via sparse
        // transpose product.
        let local = x_tile.t_matmul_dense(c_tile).transpose(); // k×d_mine
        // Cross: ⟨C_tile⟩_otherᵀ · X_me = (X_meᵀ · ⟨C_tile⟩_other)ᵀ — me
        // sparse holder of X_meᵀ, other dense holder of its C share.
        let xt = x_tile.transpose(); // d_mine×n_t
        // Direction 1: block A (me = party 0 sparse).
        let cross_a = self.sparse_cross(
            s.chan,
            &xt,
            c_tile,
            if party == 0 { d_mine } else { d_a },
            (n_t, k),
            party == 0,
        );
        // Direction 2: block B (me = party 1 sparse).
        let cross_b = self.sparse_cross(
            s.chan,
            &xt,
            c_tile,
            if party == 1 { d_mine } else { d - d_a },
            (n_t, k),
            party == 1,
        );
        // Assemble numerator blocks in feature order.
        let my_cross = if party == 0 { &cross_a } else { &cross_b };
        let my_block = local.add(&my_cross.transpose()); // k×d_mine
        let other_block = if party == 0 {
            cross_b.transpose() // my share of B's block (k×d_b)
        } else {
            cross_a.transpose() // my share of A's block (k×d_a)
        };
        let num = if party == 0 {
            my_block.hstack(&other_block)
        } else {
            other_block.hstack(&my_block)
        };
        PendingParts::ready(num)
    }
}

// ---------------------------------------------------------------------
// Selection
// ---------------------------------------------------------------------

/// Resolve the configured [`EsdMode`] to a backend, performing the
/// Auto-dispatch density exchange and (for the HE path) key setup. The
/// backend's label is its own [`CrossProductBackend::name`]. `d` is the
/// joint feature count. Horizontal partitions always take
/// [`HorizontalBackend`] (the HE path is vertical-only, rejected
/// upstream; the naive ablation targets the vertical Q3 study).
pub fn select(
    chan: &mut Chan,
    cfg: &SecureKmeansConfig,
    x: &PartyData,
    d: usize,
) -> Box<dyn CrossProductBackend> {
    let d_a = match cfg.partition {
        Partition::Vertical { d_a } => d_a,
        Partition::Horizontal { n_a } => return Box::new(HorizontalBackend::new(n_a)),
    };
    let threads = cfg.parallelism.threads;
    match cfg.effective_esd() {
        EsdMode::Vectorized => Box::new(BeaverBackend::new(d_a, d)),
        EsdMode::Naive => Box::new(NaiveBackend::new(d_a, d)),
        EsdMode::He { bits } => Box::new(HeBackend::setup(chan, bits, cfg.seed, d_a, d, threads)),
        EsdMode::Auto => {
            chan.set_phase("setup.density");
            let mine = [x.nnz(), x.dense.len() as u64];
            let theirs = chan.exchange_u64s(&mine);
            let total = (mine[1] + theirs[1]).max(1);
            let density = (mine[0] + theirs[0]) as f64 / total as f64;
            if density < AUTO_DENSITY_THRESHOLD {
                Box::new(HeBackend::setup(
                    chan,
                    crate::kmeans::config::DEFAULT_HE_BITS,
                    cfg.seed,
                    d_a,
                    d,
                    threads,
                ))
            } else {
                Box::new(BeaverBackend::new(d_a, d))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pk_serialization_roundtrip() {
        let mut prg = Prg::new(5);
        let (pk, _) = Ou::keygen(384, &mut prg);
        let back = pk_from_bytes(&pk_to_bytes(&pk));
        assert_eq!(back.n, pk.n);
        assert_eq!(back.g, pk.g);
        assert_eq!(back.h, pk.h);
        assert_eq!(back.n_bits, pk.n_bits);
    }

    #[test]
    fn party_data_counts_nonzeros() {
        let m = Mat::from_vec(2, 3, vec![0, 5, 0, 1, 0, 0]);
        assert_eq!(PartyData::dense_only(m.clone()).nnz(), 2);
        assert_eq!(PartyData::with_csr(m).nnz(), 2);
    }

    #[test]
    fn horizontal_overlaps_split_at_boundary() {
        let be = HorizontalBackend::new(20);
        // Tile fully inside A.
        assert_eq!(be.overlaps((0, 17)), ((0, 17), (20, 20)));
        // Tile spanning the boundary.
        assert_eq!(be.overlaps((17, 34)), ((17, 20), (20, 34)));
        // Tile fully inside B.
        assert_eq!(be.overlaps((34, 51)), ((20, 20), (34, 51)));
    }

    #[test]
    fn local_matmul_rows_matches_slice() {
        let x = Mat::from_vec(4, 2, vec![1, 2, 0, 3, 4, 0, 5, 6]);
        let rhs = Mat::from_vec(2, 3, vec![1, 0, 2, 0, 1, 3]);
        let want = x.rows_slice(1, 3).matmul(&rhs);
        assert_eq!(PartyData::dense_only(x.clone()).local_matmul_rows((1, 3), &rhs), want);
        assert_eq!(PartyData::with_csr(x).local_matmul_rows((1, 3), &rhs), want);
    }
}
