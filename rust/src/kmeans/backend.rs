//! Unified cross-product backends for the secure Lloyd iteration.
//!
//! S1 (distance) and S3 (update) differ between the dense, sparse and
//! ablation configurations **only** in how the two vertical cross
//! products are evaluated; everything else (norms, `F_min^k`, the
//! empty-cluster fallback, division) is shared. The seed code branched
//! ad hoc between `kmeans::esd`, `kmeans::sparse` and
//! `sparse::protocol2`; this module replaces that with one
//! [`CrossProductBackend`] trait and three implementations:
//!
//! * [`BeaverBackend`] — matrix Beaver triples (Eq. 3), both reveals in
//!   one staged flight;
//! * [`HeBackend`] — HE Protocol 2 (paper §4.3): the sparse holder
//!   evaluates over ciphertexts of the small dense operand, skipping
//!   zeros, with communication `O((d+n)·k)` ciphertexts;
//! * [`NaiveBackend`] — the pre-vectorization Q3 ablation (one scalar
//!   protocol per (sample, centroid) pair).
//!
//! [`select`] performs the `EsdMode::Auto` dispatch: the parties
//! exchange local nonzero counts once at setup (public metadata — the
//! paper treats the sparsity degree as known) and pick the HE path when
//! the joint density falls below [`AUTO_DENSITY_THRESHOLD`].

use super::config::{EsdMode, SecureKmeansConfig};
use super::esd;
use super::update::{numerator_vertical_begin, PendingNumerator};
use crate::bigint::BigUint;
use crate::he::ou::{Ou, OuPk, OuSk};
use crate::he::HeScheme;
use crate::net::Chan;
use crate::ring::matrix::Mat;
use crate::sparse::csr::Csr;
use crate::sparse::protocol2;
use crate::ss::Session;
use crate::util::prng::Prg;

/// Joint-density threshold below which `EsdMode::Auto` routes cross
/// products through HE Protocol 2 (density = nnz / total; `sparse_gen`
/// workloads sit well below it, dense Gaussian blobs at ≈ 1.0).
pub const AUTO_DENSITY_THRESHOLD: f64 = 0.7;

/// One party's feature block, with the CSR view the sparse path needs.
pub struct PartyData {
    /// Fixed-point dense block (n×d_mine).
    pub dense: Mat,
    /// CSR view (built when the run may take the HE path).
    pub csr: Option<Csr>,
}

impl PartyData {
    pub fn dense_only(dense: Mat) -> PartyData {
        PartyData { dense, csr: None }
    }

    pub fn with_csr(dense: Mat) -> PartyData {
        PartyData { csr: Some(Csr::from_dense(&dense)), dense }
    }

    /// Nonzero entries of the block (the Auto-dispatch signal).
    pub fn nnz(&self) -> u64 {
        match &self.csr {
            Some(c) => c.nnz() as u64,
            None => self.dense.data.iter().filter(|&&v| v != 0).count() as u64,
        }
    }

    fn csr(&self) -> &Csr {
        self.csr.as_ref().expect("CSR view not built for this run")
    }

    /// Local `X_mine · rhs`, through the sparse view when present.
    pub fn local_matmul(&self, rhs: &Mat) -> Mat {
        match &self.csr {
            Some(c) => c.matmul_dense(rhs),
            None => crate::runtime::dispatch::matmul(&self.dense, rhs),
        }
    }
}

/// How one Lloyd iteration evaluates its vertical cross products.
pub trait CrossProductBackend: Send {
    /// Backend label (reported in [`super::secure::SecureKmeansOutput`]).
    fn name(&self) -> &'static str;

    /// S1: shares of `X_A·(⟨μ⟩_B A-block)ᵀ + X_B·(⟨μ⟩_A B-block)ᵀ`
    /// summed (n×k). Backends flush their own reveals; anything the
    /// caller staged beforehand (the norm square) rides along.
    fn s1_cross(&mut self, s: &mut Session, x: &PartyData, mu: &Mat, d_a: usize) -> Mat;

    /// S3: the full numerator `⟨Cᵀ·X⟩` (k×d) as a staged
    /// [`PendingNumerator`] so its reveals can coalesce with the
    /// division-prep comparison.
    fn s3_numerator(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        c_share: &Mat,
        d_a: usize,
        d: usize,
    ) -> PendingNumerator;
}

// ---------------------------------------------------------------------
// Beaver (dense, vectorized — Eq. 3)
// ---------------------------------------------------------------------

/// Matrix-Beaver cross products: both reveals share one flight.
pub struct BeaverBackend;

impl CrossProductBackend for BeaverBackend {
    fn name(&self) -> &'static str {
        "beaver"
    }

    fn s1_cross(&mut self, s: &mut Session, x: &PartyData, mu: &Mat, d_a: usize) -> Mat {
        let (c1_p, c2_p) = esd::vertical_cross_begin(s, &x.dense, mu, d_a);
        s.flush();
        c1_p.resolve(s).add(&c2_p.resolve(s))
    }

    fn s3_numerator(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        c_share: &Mat,
        d_a: usize,
        d: usize,
    ) -> PendingNumerator {
        numerator_vertical_begin(s, &x.dense, c_share, d_a, d)
    }
}

// ---------------------------------------------------------------------
// Naive (Q3 ablation)
// ---------------------------------------------------------------------

/// One scalar secure product per (sample, centroid) pair — n·k flights.
pub struct NaiveBackend;

impl CrossProductBackend for NaiveBackend {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn s1_cross(&mut self, s: &mut Session, x: &PartyData, mu: &Mat, d_a: usize) -> Mat {
        s.flush(); // the staged norm reveal cannot ride a scalar loop
        esd::vertical_naive_cross(s, &x.dense, mu, d_a)
    }

    fn s3_numerator(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        c_share: &Mat,
        d_a: usize,
        d: usize,
    ) -> PendingNumerator {
        // The ablation targets S1 only (as in the paper's Q3 study).
        numerator_vertical_begin(s, &x.dense, c_share, d_a, d)
    }
}

// ---------------------------------------------------------------------
// HE Protocol 2 (sparse path, paper §4.3)
// ---------------------------------------------------------------------

/// Serialize an OU public key (n, g, h as length-prefixed big-endian).
pub fn pk_to_bytes(pk: &OuPk) -> Vec<u8> {
    let mut out = Vec::new();
    for part in [&pk.n, &pk.g, &pk.h] {
        let b = part.to_bytes_be();
        out.extend_from_slice(&(b.len() as u32).to_le_bytes());
        out.extend_from_slice(&b);
    }
    out
}

pub fn pk_from_bytes(bytes: &[u8]) -> OuPk {
    let mut parts = Vec::with_capacity(3);
    let mut off = 0;
    for _ in 0..3 {
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        off += 4;
        parts.push(BigUint::from_bytes_be(&bytes[off..off + len]));
        off += len;
    }
    let n = parts.remove(0);
    let g = parts.remove(0);
    let h = parts.remove(0);
    OuPk { n_bits: n.bits(), n, g, h }
}

/// HE cross products over each party's Okamoto-Uchiyama key pair
/// (paper §5.1); public keys are exchanged once at setup.
pub struct HeBackend {
    my_pk: OuPk,
    my_sk: OuSk,
    their_pk: OuPk,
    prg: Prg,
}

impl HeBackend {
    /// Generate this party's key pair and exchange public keys.
    pub fn setup(chan: &mut Chan, he_bits: usize, seed: u128) -> HeBackend {
        let party = chan.party;
        let mut prg = Prg::new(seed ^ ((party as u128) << 96) ^ 0xE1);
        chan.set_phase("offline.hekeys");
        let (my_pk, my_sk) = Ou::keygen(he_bits, &mut prg);
        chan.send_bytes(&pk_to_bytes(&my_pk));
        let their_pk = pk_from_bytes(&chan.recv_bytes());
        HeBackend { my_pk, my_sk, their_pk, prg }
    }

    /// One directed sparse product: this party is the sparse holder when
    /// `my_turn_sparse`, otherwise the dense holder of `dense`.
    #[allow(clippy::too_many_arguments)]
    fn sparse_cross(
        &mut self,
        chan: &mut Chan,
        x_csr: &Csr,
        dense: &Mat,
        x_rows: usize,
        y_shape: (usize, usize),
        my_turn_sparse: bool,
    ) -> Mat {
        if my_turn_sparse {
            protocol2::sparse_party::<Ou>(chan, &self.their_pk, x_csr, y_shape, &mut self.prg)
        } else {
            protocol2::dense_party::<Ou>(chan, &self.my_pk, &self.my_sk, dense, x_rows, &mut self.prg)
        }
    }
}

impl CrossProductBackend for HeBackend {
    fn name(&self) -> &'static str {
        "he-protocol2"
    }

    fn s1_cross(&mut self, s: &mut Session, x: &PartyData, mu: &Mat, d_a: usize) -> Mat {
        let n = x.dense.rows;
        let k = mu.rows;
        let d = mu.cols;
        let party = s.party();
        s.flush(); // ship the staged norm reveal before the HE exchange
        let (mu_a_blk, mu_b_blk) = esd::split_mu_vertical(mu, d_a);
        // Cross 1: X_A (sparse at A) × ⟨μ_B⟩ A-block ᵀ (dense at B).
        let ya = mu_a_blk.transpose(); // d_a×k — B's share is the payload
        let cross1 =
            self.sparse_cross(s.chan, x.csr(), &ya, n, (d_a, k), party == 0);
        // Cross 2: X_B (sparse at B) × ⟨μ_A⟩ B-block ᵀ (dense at A).
        let yb = mu_b_blk.transpose(); // d_b×k
        let cross2 =
            self.sparse_cross(s.chan, x.csr(), &yb, n, (d - d_a, k), party == 1);
        cross1.add(&cross2)
    }

    fn s3_numerator(
        &mut self,
        s: &mut Session,
        x: &PartyData,
        c_share: &Mat,
        d_a: usize,
        d: usize,
    ) -> PendingNumerator {
        let n = c_share.rows;
        let k = c_share.cols;
        let party = s.party();
        let d_mine = if party == 0 { d_a } else { d - d_a };
        // Local: ⟨C⟩_meᵀ · X_me = (X_meᵀ·⟨C⟩_me)ᵀ via sparse transpose product.
        let local = x.csr().t_matmul_dense(c_share).transpose(); // k×d_mine
        // Cross: ⟨C⟩_otherᵀ · X_me = (X_meᵀ · ⟨C⟩_other)ᵀ — me sparse
        // holder of X_meᵀ, other dense holder of its C share.
        let xt = x.csr().transpose(); // d_mine×n
        // Direction 1: block A (me = party 0 sparse).
        let cross_a = self.sparse_cross(
            s.chan,
            &xt,
            c_share,
            if party == 0 { d_mine } else { d_a },
            (n, k),
            party == 0,
        );
        // Direction 2: block B (me = party 1 sparse).
        let cross_b = self.sparse_cross(
            s.chan,
            &xt,
            c_share,
            if party == 1 { d_mine } else { d - d_a },
            (n, k),
            party == 1,
        );
        // Assemble numerator blocks in feature order.
        let my_cross = if party == 0 { &cross_a } else { &cross_b };
        let my_block = local.add(&my_cross.transpose()); // k×d_mine
        let other_block = if party == 0 {
            cross_b.transpose() // my share of B's block (k×d_b)
        } else {
            cross_a.transpose() // my share of A's block (k×d_a)
        };
        let num = if party == 0 {
            my_block.hstack(&other_block)
        } else {
            other_block.hstack(&my_block)
        };
        PendingNumerator::ready(num)
    }
}

// ---------------------------------------------------------------------
// Selection
// ---------------------------------------------------------------------

/// Resolve the configured [`EsdMode`] to a backend, performing the
/// Auto-dispatch density exchange and (for the HE path) key setup. The
/// backend's label is its own [`CrossProductBackend::name`].
pub fn select(
    chan: &mut Chan,
    cfg: &SecureKmeansConfig,
    x: &PartyData,
) -> Box<dyn CrossProductBackend> {
    match cfg.effective_esd() {
        EsdMode::Vectorized => Box::new(BeaverBackend),
        EsdMode::Naive => Box::new(NaiveBackend),
        EsdMode::He => Box::new(HeBackend::setup(chan, cfg.he_bits, cfg.seed)),
        EsdMode::Auto => {
            chan.set_phase("setup.density");
            let mine = [x.nnz(), x.dense.len() as u64];
            let theirs = chan.exchange_u64s(&mine);
            let total = (mine[1] + theirs[1]).max(1);
            let density = (mine[0] + theirs[0]) as f64 / total as f64;
            if density < AUTO_DENSITY_THRESHOLD {
                Box::new(HeBackend::setup(chan, cfg.he_bits, cfg.seed))
            } else {
                Box::new(BeaverBackend)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pk_serialization_roundtrip() {
        let mut prg = Prg::new(5);
        let (pk, _) = Ou::keygen(384, &mut prg);
        let back = pk_from_bytes(&pk_to_bytes(&pk));
        assert_eq!(back.n, pk.n);
        assert_eq!(back.g, pk.g);
        assert_eq!(back.h, pk.h);
        assert_eq!(back.n_bits, pk.n_bits);
    }

    #[test]
    fn party_data_counts_nonzeros() {
        let m = Mat::from_vec(2, 3, vec![0, 5, 0, 1, 0, 0]);
        assert_eq!(PartyData::dense_only(m.clone()).nnz(), 2);
        assert_eq!(PartyData::with_csr(m).nnz(), 2);
    }
}
