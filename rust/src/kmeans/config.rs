//! Configuration for secure K-means runs.

/// How the joint data is split between the two parties (paper §4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partition {
    /// Feature split: A holds the first `d_a` columns, B the rest.
    Vertical { d_a: usize },
    /// Sample split: A holds the first `n_a` rows, B the rest.
    Horizontal { n_a: usize },
}

/// Distance-step implementation, for the Q3 vectorization ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EsdMode {
    /// Matrix-form Eq. (3): one Beaver round per cross product.
    #[default]
    Vectorized,
    /// Pre-vectorization baseline: one scalar protocol per (sample,
    /// centroid) pair — the n·k-interaction cost the paper eliminates.
    Naive,
}

/// Parameters of a secure K-means run.
#[derive(Debug, Clone)]
pub struct SecureKmeansConfig {
    /// Number of clusters.
    pub k: usize,
    /// Fixed number of Lloyd iterations.
    pub iters: usize,
    /// Dealer / offline seed shared by both parties (public).
    pub seed: u128,
    /// Data partition between parties.
    pub partition: Partition,
    /// Distance-step implementation.
    pub esd: EsdMode,
    /// Route sparse cross products through HE Protocol 2.
    pub sparse: bool,
    /// HE modulus bits for the sparse path (paper: 2048).
    pub he_bits: usize,
    /// Optional convergence threshold ε (checked with F_CSC each
    /// iteration when set; `None` = fixed iteration count only).
    pub epsilon: Option<f64>,
}

impl Default for SecureKmeansConfig {
    fn default() -> Self {
        SecureKmeansConfig {
            k: 2,
            iters: 10,
            seed: 0xBEEF,
            partition: Partition::Vertical { d_a: 1 },
            esd: EsdMode::Vectorized,
            sparse: false,
            he_bits: 768,
            epsilon: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_dense_vectorized() {
        let c = SecureKmeansConfig::default();
        assert_eq!(c.esd, EsdMode::Vectorized);
        assert!(!c.sparse);
        assert!(c.epsilon.is_none());
    }
}
