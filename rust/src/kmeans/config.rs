//! Configuration for secure K-means runs.

use crate::net::cost::CostModel;
use crate::net::Security;
use crate::runtime::pool::Parallelism;
use crate::runtime::simd::Lanes;
use crate::ss::RoundPolicy;

/// Default Okamoto-Uchiyama modulus bits for the HE cross-product path
/// (the paper benchmarks 2048; tests and CI use this faster setting).
pub const DEFAULT_HE_BITS: usize = 768;

/// How the joint data is split between the two parties (paper §4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partition {
    /// Feature split: A holds the first `d_a` columns, B the rest.
    Vertical { d_a: usize },
    /// Sample split: A holds the first `n_a` rows, B the rest.
    Horizontal { n_a: usize },
}

/// Which backend evaluates the S1/S3 cross products (the only step where
/// the dense, sparse and ablation paths differ — see
/// [`crate::kmeans::backend::CrossProductBackend`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EsdMode {
    /// Matrix-form Eq. (3): Beaver matrix triples, all cross products in
    /// one reveal flight.
    #[default]
    Vectorized,
    /// Pre-vectorization baseline: one scalar protocol per (sample,
    /// centroid) pair — the n·k-interaction cost the paper eliminates.
    Naive,
    /// HE Protocol 2 (paper §4.3): the sparse holder evaluates over
    /// ciphertexts of the small dense operand, with `bits` selecting the
    /// Okamoto-Uchiyama modulus size (paper: 2048; tests:
    /// [`DEFAULT_HE_BITS`]). Vertical partition only. Subsumes the
    /// retired `sparse: bool` + `he_bits: usize` config pair.
    He { bits: usize },
    /// Density-based auto-dispatch: parties exchange their local nnz
    /// counts at setup and pick [`EsdMode::He`] (at
    /// [`DEFAULT_HE_BITS`]) below
    /// [`crate::kmeans::backend::AUTO_DENSITY_THRESHOLD`], otherwise
    /// [`EsdMode::Vectorized`].
    Auto,
}

impl EsdMode {
    /// The HE backend at the default modulus size.
    pub fn he() -> EsdMode {
        EsdMode::He { bits: DEFAULT_HE_BITS }
    }
}

/// How a row-tiled run maps tiles onto network flights.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TileFlights {
    /// All tiles advance through S1/S2/S3 together: every tile's gates
    /// for a dependency level share the level's flight, so tiling costs
    /// **zero** extra rounds over the monolithic schedule (asserted by
    /// the round-count regression tests). Offline material is still
    /// tile-shaped — peak triple size is bounded by the tile, not n.
    #[default]
    Lockstep,
    /// One tile at a time through the whole iteration: rounds scale with
    /// the tile count, but every online intermediate (distance tile, MUX
    /// lanes, numerator contribution) is O(B·d) — the memory-constrained
    /// deployment mode.
    Streamed,
}

/// The row-tile schedule for `n` samples: half-open global row ranges,
/// `⌈n/B⌉` tiles of `B` rows (last tile ragged when `B ∤ n`), or one
/// monolithic tile when tiling is off.
pub fn tile_schedule(n: usize, tile_rows: Option<usize>) -> Vec<(usize, usize)> {
    match tile_rows {
        None => vec![(0, n)],
        Some(b) => {
            let b = b.max(1);
            (0..n).step_by(b).map(|r0| (r0, (r0 + b).min(n))).collect()
        }
    }
}

/// Parameters of a secure K-means run.
#[derive(Debug, Clone)]
pub struct SecureKmeansConfig {
    /// Number of clusters.
    pub k: usize,
    /// Fixed number of Lloyd iterations.
    pub iters: usize,
    /// Dealer / offline seed shared by both parties (public).
    pub seed: u128,
    /// Data partition between parties.
    pub partition: Partition,
    /// Cross-product backend selection. The HE path's modulus size
    /// rides inside the variant (`EsdMode::He { bits }`) — the old
    /// `sparse: bool` + `he_bits: usize` field pair is retired (see
    /// [`SecureKmeansConfig::set_legacy_sparse`] for the migration
    /// shim).
    pub esd: EsdMode,
    /// Adversary model for the run: [`Security::SemiHonest`] (default)
    /// is transcript-identical to every release before the tier
    /// existed; [`Security::Malicious`] arms the channel's deferred MAC
    /// ledger, adds a batched ledger barrier per Lloyd iteration plus
    /// one at `train.done`, and commit-reveals the final outputs.
    pub security: Security,
    /// Optional convergence threshold ε (checked with F_CSC each
    /// iteration when set; `None` = fixed iteration count only).
    pub epsilon: Option<f64>,
    /// How the protocol engine maps gates to flights:
    /// [`RoundPolicy::Coalesced`] (default) shares one flight among all
    /// independent gates of a dependency level; [`RoundPolicy::PerGate`]
    /// is the gate-per-flight ablation baseline.
    pub round_policy: RoundPolicy,
    /// Row-tile size `B` for the online phase: `Some(B)` streams the
    /// sample dimension through `⌈n/B⌉` tiles so every matrix-triple
    /// shape (and the S1/S3 working set) is bounded by `B` instead of
    /// `n`, making the recorded offline [`crate::offline::store::Demand`]
    /// uniform per tile and reusable across dataset sizes. `None` keeps
    /// the monolithic schedule.
    pub tile_rows: Option<usize>,
    /// Flight policy for the tile schedule (ignored without `tile_rows`).
    pub tile_flights: TileFlights,
    /// Worker threads for party-local compute (CLI: `--threads N`):
    /// offline triple fabrication, HE encryption vectors, and the
    /// plaintext-side matrix products of the online phase fan out across
    /// this many cores via [`crate::runtime::pool`]. **Never** changes an
    /// output bit or a meter reading — `threads = 1` and `threads = N`
    /// are transcript-identical (regression-tested); the [`crate::net::Chan`]
    /// flight schedule always stays sequential.
    pub parallelism: Parallelism,
    /// Packed-lane width for the crypto kernels (CLI: `--lanes
    /// {auto,1,4,8}`): Speck counter-mode batches, lockstep Hash256, the
    /// blocked IKNP bit transpose and the Beaver/truncation sweeps run
    /// [`Lanes::width`] elements per step via [`crate::runtime::simd`].
    /// Orthogonal to `parallelism` (pool workers run packed sweeps
    /// inside their chunks) and under the same hard contract: `lanes =
    /// 1` and `lanes = N` are transcript-identical — shares, reveals,
    /// Demand and every meter counter (regression-tested in
    /// `rust/tests/lanes.rs`).
    pub lanes: Lanes,
    /// Optional deterministic link shaping
    /// ([`crate::net::shape::LinkShaper`]) applied to this run's
    /// transport: every received message is delayed by the modeled
    /// one-way latency plus serialization time, so the run's wall-clock
    /// *measures* compute + link instead of modeling the link after the
    /// fact. `None` (default) leaves the transport unshaped. Outputs,
    /// reveals and meters are bit-identical either way.
    pub shape: Option<CostModel>,
}

impl SecureKmeansConfig {
    /// The backend actually requested. The legacy `sparse`/`he_bits`
    /// folding now happens at construction time ([`Self::set_legacy_sparse`]
    /// or the scenario/CLI parsers), so this is a plain accessor — kept
    /// because call sites across the tree ask the question this way.
    pub fn effective_esd(&self) -> EsdMode {
        self.esd
    }

    /// Migration shim for the retired `sparse: bool` + `he_bits: usize`
    /// field pair: folds them into [`EsdMode::He`] exactly like the old
    /// `effective_esd` did (an explicit non-default `esd` wins over the
    /// legacy flag). Removed after one release.
    #[deprecated(
        since = "0.10.0",
        note = "set `esd: EsdMode::He { bits }` directly; the `sparse`/`he_bits` fields are gone"
    )]
    pub fn set_legacy_sparse(&mut self, sparse: bool, he_bits: usize) {
        if sparse && self.esd == EsdMode::Vectorized {
            self.esd = EsdMode::He { bits: he_bits };
        }
    }
}

impl Default for SecureKmeansConfig {
    fn default() -> Self {
        SecureKmeansConfig {
            k: 2,
            iters: 10,
            seed: 0xBEEF,
            partition: Partition::Vertical { d_a: 1 },
            esd: EsdMode::Vectorized,
            security: Security::SemiHonest,
            epsilon: None,
            round_policy: RoundPolicy::Coalesced,
            tile_rows: None,
            tile_flights: TileFlights::Lockstep,
            parallelism: Parallelism::sequential(),
            lanes: Lanes::scalar(),
            shape: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_dense_vectorized() {
        let c = SecureKmeansConfig::default();
        assert_eq!(c.esd, EsdMode::Vectorized);
        assert_eq!(c.security, Security::SemiHonest);
        assert!(c.epsilon.is_none());
        assert_eq!(c.round_policy, RoundPolicy::Coalesced);
        assert_eq!(c.effective_esd(), EsdMode::Vectorized);
        assert!(c.tile_rows.is_none());
        assert_eq!(c.tile_flights, TileFlights::Lockstep);
        assert_eq!(c.parallelism, Parallelism::sequential());
        assert_eq!(c.lanes, Lanes::scalar());
    }

    #[test]
    fn tile_schedule_covers_rows_exactly_once() {
        assert_eq!(tile_schedule(10, None), vec![(0, 10)]);
        assert_eq!(tile_schedule(10, Some(4)), vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(tile_schedule(8, Some(4)), vec![(0, 4), (4, 8)]);
        assert_eq!(tile_schedule(3, Some(100)), vec![(0, 3)]);
        // Non-divisor tile sizes: ranges are contiguous and exhaustive.
        let tiles = tile_schedule(60, Some(17));
        assert_eq!(tiles.len(), 4);
        assert_eq!(tiles[0].0, 0);
        assert_eq!(tiles[tiles.len() - 1].1, 60);
        for w in tiles.windows(2) {
            assert_eq!(w[0].1, w[1].0, "tiles must abut");
        }
        assert_eq!(tiles[3], (51, 60), "ragged last tile");
    }

    #[test]
    #[allow(deprecated)]
    fn legacy_sparse_shim_maps_to_he() {
        let mut c = SecureKmeansConfig::default();
        c.set_legacy_sparse(true, 768);
        assert_eq!(c.effective_esd(), EsdMode::He { bits: 768 });
        // An explicit esd wins over the legacy flag.
        let mut c = SecureKmeansConfig { esd: EsdMode::Naive, ..Default::default() };
        c.set_legacy_sparse(true, 768);
        assert_eq!(c.effective_esd(), EsdMode::Naive);
        assert_eq!(EsdMode::he(), EsdMode::He { bits: DEFAULT_HE_BITS });
    }
}
