//! S2 — Secure cluster assignment `F_min^k` (paper Fig. 1).
//!
//! Binary-tree reduction over the k distance columns: each level runs a
//! batch of CMPM comparison modules — one vectorized CMP (Kogge-Stone
//! MSB of the difference, [`CMP_ROUNDS`] flights for *all* pairs and
//! samples at once) followed by **one** fused boolean-selector MUX
//! flight that simultaneously propagates the smaller distance *and* its
//! one-hot index row (the daBit construction of
//! [`crate::ss::mux::mux_bits_begin`] collapses the old B2A + multiply
//! pair of dependent flights). The whole assignment therefore costs
//! exactly `⌈log₂ k⌉ · (CMP_ROUNDS + 1)` flights per iteration — the
//! budget asserted by the round-count regression tests.

use crate::ring::matrix::Mat;
use crate::ss::boolean::{msb, CMP_ROUNDS};
use crate::ss::mux::mux_bits_begin;
use crate::ss::{Session, SessionOptions};

/// Flights per `F_min^k` invocation on k columns (per Lloyd iteration).
pub fn min_k_rounds(k: usize) -> u64 {
    let levels = (usize::BITS - (k - 1).leading_zeros()) as u64; // ⌈log₂ k⌉
    levels * (CMP_ROUNDS + 1)
}

/// Decode one reconstructed assignment row: `(cluster index,
/// well_formed)`. A valid row is exactly one-hot; anything else is
/// protocol corruption — the caller counts it (and typically trips a
/// `debug_assert`) while the index falls back to the first 1-entry, or
/// cluster 0 if none. Shared by the training reveal and the serving
/// scorer so the malformed-row policy cannot drift between them.
pub fn decode_one_hot_row(row: &[u64]) -> (usize, bool) {
    let ones = row.iter().filter(|&&v| v == 1).count();
    let well_formed = ones == 1 && row.iter().all(|&v| v == 0 || v == 1);
    (row.iter().position(|&v| v == 1).unwrap_or(0), well_formed)
}

/// One tree node: shared min-distance lanes (n) and shared one-hot index
/// rows (n×k).
struct Node {
    val: Vec<u64>,
    idx: Mat,
}

/// `⟨C⟩ ← F_min^k(⟨D⟩)`: returns the shared one-hot assignment matrix
/// `C (n×k)` and the shared minimum distances (n×1).
pub fn min_k(ctx: &mut Session, d: &Mat) -> (Mat, Mat) {
    let n = d.rows;
    let k = d.cols;
    assert!(k >= 1);
    let party = ctx.party();

    // Leaves: value = column j; index = public one-hot e_j (party 0 holds).
    let mut nodes: Vec<Node> = (0..k)
        .map(|j| {
            let val: Vec<u64> = (0..n).map(|i| d.at(i, j)).collect();
            let mut idx = Mat::zeros(n, k);
            if party == 0 {
                for i in 0..n {
                    idx.set(i, j, 1);
                }
            }
            Node { val, idx }
        })
        .collect();

    while nodes.len() > 1 {
        let pairs = nodes.len() / 2;
        let carry = nodes.len() % 2 == 1;

        // Batch CMP over all pairs: diff lanes = left − right.
        let mut diff = Mat::zeros(1, pairs * n);
        for p in 0..pairs {
            let (a, b) = (&nodes[2 * p], &nodes[2 * p + 1]);
            for i in 0..n {
                diff.data[p * n + i] = a.val[i].wrapping_sub(b.val[i]);
            }
        }
        // z = [left < right] per lane (MSB of the difference).
        let z_bits = msb(ctx, &diff);

        // One fused MUX flight for values and index rows: the selector
        // lane (p, i) broadcasts over its 1+k data lanes (group), so
        // out = right + z·(left − right) for all pairs in one round.
        let group = 1 + k;
        let lanes = pairs * n * group;
        let mut left = Mat::from_vec(1, lanes, vec![0; lanes]);
        let mut right = Mat::from_vec(1, lanes, vec![0; lanes]);
        for p in 0..pairs {
            let (a, b) = (&nodes[2 * p], &nodes[2 * p + 1]);
            for i in 0..n {
                let base = (p * n + i) * group;
                left.data[base] = a.val[i];
                right.data[base] = b.val[i];
                for c in 0..k {
                    left.data[base + 1 + c] = a.idx.at(i, c);
                    right.data[base + 1 + c] = b.idx.at(i, c);
                }
            }
        }
        let merged = {
            let pend = mux_bits_begin(ctx, &z_bits, &left, &right, group);
            ctx.flush();
            pend.resolve(ctx)
        };

        let mut next: Vec<Node> = Vec::with_capacity(pairs + carry as usize);
        for p in 0..pairs {
            let mut val = vec![0u64; n];
            let mut idx = Mat::zeros(n, k);
            for i in 0..n {
                let base = (p * n + i) * group;
                val[i] = merged.data[base];
                for c in 0..k {
                    idx.set(i, c, merged.data[base + 1 + c]);
                }
            }
            next.push(Node { val, idx });
        }
        if carry {
            next.push(nodes.pop().unwrap());
        }
        nodes = next;
    }

    let root = nodes.pop().unwrap();
    (root.idx, Mat::from_vec(n, 1, root.val))
}

/// Lockstep `F_min^k` across row tiles: the tiles' distance blocks are
/// concatenated along the (embarrassingly parallel) sample dimension, so
/// at every tree level **all** tiles' CMP lanes ride one comparison
/// circuit and all their value/index lanes one fused MUX — exactly the
/// lane batching of [`crate::ss::compare::cmp_many`] /
/// [`crate::ss::mux::mux_many`]. Any number of tiles therefore costs
/// exactly [`min_k_rounds`]`(k)` flights, the monolithic budget
/// (regression-tested), and the lane-chunk demand is byte-identical to a
/// monolithic call. Returns the stitched one-hot matrix (Σn_t × k, tile
/// row order) and minimum distances (Σn_t × 1).
pub fn min_k_tiles(ctx: &mut Session, tiles: &[Mat]) -> (Mat, Mat) {
    assert!(!tiles.is_empty(), "min_k_tiles needs at least one tile");
    if tiles.len() == 1 {
        // Monolithic schedule: no concatenation copy.
        return min_k(ctx, &tiles[0]);
    }
    let k = tiles[0].cols;
    let total: usize = tiles.iter().map(|t| t.rows).sum();
    // One preallocated copy (repeated vstack would re-copy the
    // accumulated prefix once per tile — O(tiles·n·k)).
    let mut d = Mat::zeros(total, k);
    let mut r = 0;
    for t in tiles {
        assert_eq!(t.cols, k, "tiles must share the cluster count");
        d.data[r * k..(r + t.rows) * k].copy_from_slice(&t.data);
        r += t.rows;
    }
    min_k(ctx, &d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;
    use crate::offline::dealer::Dealer;
    use crate::ring::fixed::encode_f64;
    use crate::ss::share::{reconstruct, split};
    use crate::ss::Session;
    use crate::util::prng::Prg;

    fn run_min_k(dvals: Vec<f64>, n: usize, k: usize) -> (Vec<u64>, Vec<f64>) {
        let enc: Vec<u64> = dvals.iter().map(|&v| encode_f64(v)).collect();
        let d = Mat::from_vec(n, k, enc);
        let mut prg = Prg::new(101);
        let (d0, d1) = split(&d, &mut prg);
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(102, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let (cm, mv) = min_k(&mut ctx, &d0);
                (reconstruct(c, &cm), reconstruct(c, &mv))
            },
            move |c| {
                let mut ts = Dealer::new(102, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let (cm, mv) = min_k(&mut ctx, &d1);
                (reconstruct(c, &cm), reconstruct(c, &mv))
            },
        );
        let (cmat, minv) = r;
        (cmat.data, minv.decode())
    }

    #[test]
    fn paper_figure1_example() {
        // k = 6 distances per the paper's Fig. 1: ⟨7 2 1 3 6 5⟩ → index 2.
        let d = vec![7.0, 2.0, 1.0, 3.0, 6.0, 5.0];
        let (c, mv) = run_min_k(d, 1, 6);
        assert_eq!(c, vec![0, 0, 1, 0, 0, 0]);
        assert!((mv[0] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn many_rows_various_k() {
        for k in [2usize, 3, 4, 5, 7, 8] {
            let n = 9;
            let mut prg = Prg::new(200 + k as u128);
            let dvals: Vec<f64> = (0..n * k).map(|_| prg.next_f64() * 10.0).collect();
            let (c, mv) = run_min_k(dvals.clone(), n, k);
            for i in 0..n {
                let row = &dvals[i * k..(i + 1) * k];
                let want = row
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap();
                for j in 0..k {
                    let expect = if j == want.0 { 1 } else { 0 };
                    assert_eq!(c[i * k + j], expect, "n={i} k={k} col={j}");
                }
                assert!((mv[i] - want.1).abs() < 1e-3, "min row {i} k {k}");
            }
        }
    }

    #[test]
    fn negative_distances_supported() {
        // D' can be negative (norm term minus 2·dot) — must still argmin.
        let d = vec![-3.0, -7.5, 2.0, -7.4];
        let (c, _) = run_min_k(d, 1, 4);
        assert_eq!(c, vec![0, 1, 0, 0]);
    }

    #[test]
    fn tiled_min_k_matches_monolithic_at_monolithic_budget() {
        // Three ragged tiles through the lockstep reduction: same one-hot
        // output as one monolithic call, and exactly min_k_rounds(k)
        // flights — tiling is free under lockstep.
        let (n, k) = (11, 3);
        let mut prg = Prg::new(401);
        let d = Mat::random(n, k, &mut prg).map(|v| v >> 40);
        let (d0, d1) = split(&d, &mut prg);
        const RANGES: [(usize, usize); 3] = [(0, 4), (4, 8), (8, 11)];
        let ((r, _), _) = run_two_party(
            move |c| {
                let mut ts = Dealer::new(402, 0);
                let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                let tiles: Vec<Mat> =
                    RANGES.iter().map(|&(r0, r1)| d0.rows_slice(r0, r1)).collect();
                let before = ctx.chan.meter().total().rounds;
                let (cm, _mv) = min_k_tiles(&mut ctx, &tiles);
                let spent = ctx.chan.meter().total().rounds - before;
                let (cm2, _) = min_k(&mut ctx, &d0);
                (reconstruct(c, &cm), reconstruct(c, &cm2), spent)
            },
            move |c| {
                let mut ts = Dealer::new(402, 1);
                let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                let tiles: Vec<Mat> =
                    RANGES.iter().map(|&(r0, r1)| d1.rows_slice(r0, r1)).collect();
                let (cm, _mv) = min_k_tiles(&mut ctx, &tiles);
                let (cm2, _) = min_k(&mut ctx, &d1);
                let _ = reconstruct(c, &cm);
                let _ = reconstruct(c, &cm2);
            },
        );
        let (tiled, mono, spent) = r;
        assert_eq!(tiled, mono, "lockstep tiling must not change the argmin");
        assert_eq!(spent, min_k_rounds(k), "tiling must cost the monolithic budget");
    }

    #[test]
    fn rounds_scale_with_log_k_not_n() {
        let run = |n: usize, k: usize| -> u64 {
            let mut prg = Prg::new(7);
            let d = Mat::random(n, k, &mut prg).map(|v| v >> 40); // small values
            let (d0, d1) = split(&d, &mut prg);
            let ((_, m), _) = run_two_party(
                move |c| {
                    let mut ts = Dealer::new(103, 0);
                    let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                    min_k(&mut ctx, &d0);
                },
                move |c| {
                    let mut ts = Dealer::new(103, 1);
                    let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                    min_k(&mut ctx, &d1);
                },
            );
            m.total().rounds
        };
        let r_small = run(4, 4);
        let r_big_n = run(64, 4);
        assert_eq!(r_small, r_big_n, "rounds must not depend on n");
        let r_big_k = run(4, 8);
        assert!(r_big_k > r_small, "more levels for larger k");
    }

    #[test]
    fn flight_budget_is_levels_times_cmp_plus_one() {
        for k in [2usize, 3, 5, 8] {
            let n = 3;
            let mut prg = Prg::new(300 + k as u128);
            let d = Mat::random(n, k, &mut prg).map(|v| v >> 40);
            let (d0, d1) = split(&d, &mut prg);
            let ((rounds, _), _) = run_two_party(
                move |c| {
                    let mut ts = Dealer::new(104, 0);
                    let mut ctx = Session::new(c, &mut ts, Prg::new(1), SessionOptions::default());
                    min_k(&mut ctx, &d0);
                    ctx.chan.meter().total().rounds
                },
                move |c| {
                    let mut ts = Dealer::new(104, 1);
                    let mut ctx = Session::new(c, &mut ts, Prg::new(2), SessionOptions::default());
                    min_k(&mut ctx, &d1);
                },
            );
            assert_eq!(rounds, min_k_rounds(k), "k={k}");
        }
    }
}
