//! The privacy-preserving K-means driver (paper Alg. 3).
//!
//! Simulates the two parties as threads over the accounted channel and
//! runs the full protocol: initialization → t × (S1 distance → S2
//! assignment → S3 update) → output reconstruction. Communication is
//! metered per phase (`online.s1` / `online.s2` / `online.s3` /
//! `reveal`), triple generation time is separated by
//! [`crate::offline::timed::TimedSource`], and the exact offline
//! [`Demand`] is recorded for OT-based pricing — together these give
//! every number the paper's tables and figures need from a single run.

use super::config::{EsdMode, Partition, SecureKmeansConfig};
use super::{assign, esd, init, update};
use crate::data::blobs::Dataset;
use crate::net::{run_two_party, Chan, Meter};
use crate::offline::dealer::Dealer;
use crate::offline::store::{Demand, TripleStore};
use crate::offline::timed::TimedSource;
use crate::ring::matrix::Mat;
use crate::ss::share::reconstruct;
use crate::ss::triples::{Ledger, TripleSource};
use crate::ss::Ctx;
use crate::util::error::{Error, Result};
use crate::util::prng::Prg;
use std::time::Instant;

/// Per-step online wall-clock (seconds, triple generation excluded).
#[derive(Debug, Default, Clone, Copy)]
pub struct StepWall {
    pub s1_distance: f64,
    pub s2_assign: f64,
    pub s3_update: f64,
}

/// Everything a bench or application needs from one protocol run.
#[derive(Debug)]
pub struct SecureKmeansOutput {
    /// Offline demand attributed to each step (s1, s2, s3).
    pub step_demands: [Demand; 3],
    /// Reconstructed centroids (k×d, real-valued).
    pub centroids: Vec<f64>,
    /// Reconstructed cluster index per sample.
    pub assignments: Vec<usize>,
    pub k: usize,
    pub d: usize,
    pub iters_run: usize,
    /// Party-0 / party-1 communication meters (phases: online.s1…).
    pub meter_a: Meter,
    pub meter_b: Meter,
    /// Offline material demand recorded by party 0.
    pub demand: Demand,
    pub ledger: Ledger,
    /// Seconds party 0 spent generating triples (the simulated dealer).
    pub offline_gen_secs: f64,
    /// Party-0 thread total wall-clock.
    pub wall_secs: f64,
    /// Online wall-clock by step.
    pub step_wall: StepWall,
}

/// One party's raw protocol outputs (shared with the sparse driver).
pub struct PartyResult {
    pub step_demands: [Demand; 3],
    pub mu: Mat,
    pub assignments: Vec<usize>,
    pub demand: Demand,
    pub ledger: Ledger,
    pub offline_secs: f64,
    pub wall: f64,
    pub steps: StepWall,
    pub iters: usize,
}

impl PartyResult {
    /// Assemble the public output struct from party 0's result.
    pub fn into_output(
        self,
        k: usize,
        d: usize,
        meter_a: Meter,
        meter_b: Meter,
        wall_b: f64,
    ) -> SecureKmeansOutput {
        SecureKmeansOutput {
            step_demands: self.step_demands,
            centroids: self.mu.decode(),
            assignments: self.assignments,
            k,
            d,
            iters_run: self.iters,
            meter_a,
            meter_b,
            demand: self.demand,
            ledger: self.ledger,
            offline_gen_secs: self.offline_secs,
            wall_secs: self.wall.max(wall_b),
            step_wall: self.steps,
        }
    }
}

/// Split a dataset according to the partition; returns (A block, B block)
/// as fixed-point matrices.
pub fn split_dataset(data: &Dataset, partition: Partition) -> (Mat, Mat) {
    match partition {
        Partition::Vertical { d_a } => {
            assert!(d_a > 0 && d_a < data.d, "vertical split needs 0 < d_a < d");
            let mut xa = Vec::with_capacity(data.n * d_a);
            let mut xb = Vec::with_capacity(data.n * (data.d - d_a));
            for i in 0..data.n {
                let row = data.row(i);
                xa.extend_from_slice(&row[..d_a]);
                xb.extend_from_slice(&row[d_a..]);
            }
            (Mat::encode(data.n, d_a, &xa), Mat::encode(data.n, data.d - d_a, &xb))
        }
        Partition::Horizontal { n_a } => {
            assert!(n_a > 0 && n_a < data.n, "horizontal split needs 0 < n_a < n");
            (
                Mat::encode(n_a, data.d, &data.x[..n_a * data.d]),
                Mat::encode(data.n - n_a, data.d, &data.x[n_a * data.d..]),
            )
        }
    }
}

/// One party's protocol main loop (dense SS path).
fn party_main(
    chan: &mut Chan,
    x_mine: Mat,
    n: usize,
    d: usize,
    cfg: &SecureKmeansConfig,
) -> PartyResult {
    let party = chan.party;
    let t_start = Instant::now();
    let timed = TimedSource::new(Dealer::new(cfg.seed, party));
    let mut store = TripleStore::new(timed);
    let mut steps = StepWall::default();

    chan.set_phase("online.init");
    let mut mu = match cfg.partition {
        Partition::Vertical { d_a } => init::vertical(&x_mine, d_a, d, n, cfg.k, cfg.seed, party),
        Partition::Horizontal { n_a } => init::horizontal(&x_mine, n_a, n, cfg.k, cfg.seed, party),
    };

    let mut c_share = Mat::zeros(n, cfg.k);
    let mut step_demands = [Demand::default(), Demand::default(), Demand::default()];
    let mut iters = 0;
    for _t in 0..cfg.iters {
        iters += 1;

        // S1 — distance.
        let t0 = Instant::now();
        let off0 = store.inner().secs;
        let dem0 = store.demand.clone();
        let dmat = {
            let mut ctx =
                Ctx::new(chan, &mut store, Prg::new(cfg.seed ^ ((party as u128) << 64) ^ 0xA5));
            ctx.set_phase("online.s1");
            match (cfg.partition, cfg.esd) {
                (Partition::Vertical { d_a }, EsdMode::Vectorized) => {
                    esd::vertical(&mut ctx, &x_mine, &mu, d_a)
                }
                (Partition::Vertical { d_a }, EsdMode::Naive) => {
                    esd::vertical_naive(&mut ctx, &x_mine, &mu, d_a)
                }
                (Partition::Horizontal { n_a }, _) => {
                    esd::horizontal(&mut ctx, &x_mine, &mu, n_a, n)
                }
            }
        };
        steps.s1_distance += t0.elapsed().as_secs_f64() - (store.inner().secs - off0);
        step_demands[0].extend(&store.demand.delta(&dem0));

        // S2 — assignment.
        let t0 = Instant::now();
        let off0 = store.inner().secs;
        let dem0 = store.demand.clone();
        {
            let mut ctx = Ctx::new(chan, &mut store, Prg::new(cfg.seed ^ 0xB6));
            ctx.set_phase("online.s2");
            let (c_new, _minvals) = assign::min_k(&mut ctx, &dmat);
            c_share = c_new;
        }
        steps.s2_assign += t0.elapsed().as_secs_f64() - (store.inner().secs - off0);
        step_demands[1].extend(&store.demand.delta(&dem0));

        // S3 — update.
        let t0 = Instant::now();
        let off0 = store.inner().secs;
        let dem0 = store.demand.clone();
        let mu_new = {
            let mut ctx = Ctx::new(chan, &mut store, Prg::new(cfg.seed ^ 0xC7));
            ctx.set_phase("online.s3");
            let num = match cfg.partition {
                Partition::Vertical { d_a } => {
                    update::numerator_vertical(&mut ctx, &x_mine, &c_share, d_a, d)
                }
                Partition::Horizontal { n_a } => {
                    update::numerator_horizontal(&mut ctx, &x_mine, &c_share, n_a)
                }
            };
            update::finish_update(&mut ctx, &num, &c_share, &mu)
        };
        steps.s3_update += t0.elapsed().as_secs_f64() - (store.inner().secs - off0);
        step_demands[2].extend(&store.demand.delta(&dem0));

        // Optional F_CSC convergence check.
        let stop = if let Some(eps) = cfg.epsilon {
            let mut ctx = Ctx::new(chan, &mut store, Prg::new(cfg.seed ^ 0xD8));
            ctx.set_phase("online.csc");
            update::converged(&mut ctx, &mu, &mu_new, eps)
        } else {
            false
        };
        mu = mu_new;
        if stop {
            break;
        }
    }

    // Output reconstruction (the single reveal of the protocol).
    chan.set_phase("reveal");
    let mu_plain = reconstruct(chan, &mu);
    let c_plain = reconstruct(chan, &c_share);
    let assignments = (0..n)
        .map(|i| (0..cfg.k).find(|&j| c_plain.at(i, j) == 1).unwrap_or(0))
        .collect();

    PartyResult {
        step_demands,
        mu: mu_plain,
        assignments,
        demand: store.demand.clone(),
        ledger: store.ledger(),
        offline_secs: store.inner().secs,
        wall: t_start.elapsed().as_secs_f64(),
        steps,
        iters,
    }
}

/// Run the full two-party protocol on a dataset (dense SS path).
pub fn run(data: &Dataset, cfg: &SecureKmeansConfig) -> Result<SecureKmeansOutput> {
    if cfg.k < 2 {
        return Err(Error::Config("k must be ≥ 2".into()));
    }
    if cfg.sparse {
        return super::sparse::run(data, cfg);
    }
    let (xa, xb) = split_dataset(data, cfg.partition);
    let (n, d) = (data.n, data.d);
    let cfg_a = cfg.clone();
    let cfg_b = cfg.clone();
    let ((ra, meter_a), (rb, meter_b)) = run_two_party(
        move |c| party_main(c, xa, n, d, &cfg_a),
        move |c| party_main(c, xb, n, d, &cfg_b),
    );
    debug_assert_eq!(ra.mu, rb.mu, "parties must reconstruct identical centroids");
    Ok(SecureKmeansOutput {
        step_demands: ra.step_demands,
        centroids: ra.mu.decode(),
        assignments: ra.assignments,
        k: cfg.k,
        d,
        iters_run: ra.iters,
        meter_a,
        meter_b,
        demand: ra.demand,
        ledger: ra.ledger,
        offline_gen_secs: ra.offline_secs,
        wall_secs: ra.wall.max(rb.wall),
        step_wall: ra.steps,
    })
}

/// Convenience: vertical partition with an even feature split.
pub fn run_vertical(data: &Dataset, cfg: &SecureKmeansConfig) -> Result<SecureKmeansOutput> {
    let mut cfg = cfg.clone();
    cfg.partition = Partition::Vertical { d_a: (data.d / 2).max(1) };
    run(data, &cfg)
}

/// Convenience: horizontal partition with an even sample split.
pub fn run_horizontal(data: &Dataset, cfg: &SecureKmeansConfig) -> Result<SecureKmeansOutput> {
    let mut cfg = cfg.clone();
    cfg.partition = Partition::Horizontal { n_a: (data.n / 2).max(1) };
    run(data, &cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::BlobSpec;
    use crate::kmeans::plaintext;

    fn well_separated(n: usize, d: usize, k: usize, seed: u128) -> Dataset {
        let mut spec = BlobSpec::new(n, d, k);
        spec.spread = 0.02;
        spec.generate(seed)
    }

    #[test]
    fn secure_matches_plaintext_vertical() {
        let ds = well_separated(60, 4, 3, 21);
        let cfg = SecureKmeansConfig {
            k: 3,
            iters: 6,
            partition: Partition::Vertical { d_a: 2 },
            ..Default::default()
        };
        let sec = run(&ds, &cfg).unwrap();
        let plain = plaintext::kmeans(&ds, 3, 6, cfg.seed);
        // Same init (same seed) → same trajectory up to fixed-point noise.
        for i in 0..sec.centroids.len() {
            assert!(
                (sec.centroids[i] - plain.centroids[i]).abs() < 1e-2,
                "centroid {i}: {} vs {}",
                sec.centroids[i],
                plain.centroids[i]
            );
        }
        assert_eq!(sec.assignments, plain.assignments);
    }

    #[test]
    fn secure_matches_plaintext_horizontal() {
        let ds = well_separated(50, 3, 2, 33);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 5,
            partition: Partition::Horizontal { n_a: 20 },
            ..Default::default()
        };
        let sec = run(&ds, &cfg).unwrap();
        let plain = plaintext::kmeans(&ds, 2, 5, cfg.seed);
        assert_eq!(sec.assignments, plain.assignments);
    }

    #[test]
    fn naive_esd_same_result_more_rounds() {
        let ds = well_separated(12, 2, 2, 5);
        let base = SecureKmeansConfig {
            k: 2,
            iters: 2,
            partition: Partition::Vertical { d_a: 1 },
            ..Default::default()
        };
        let mut naive_cfg = base.clone();
        naive_cfg.esd = EsdMode::Naive;
        let v = run(&ds, &base).unwrap();
        let nv = run(&ds, &naive_cfg).unwrap();
        assert_eq!(v.assignments, nv.assignments);
        let rv = v.meter_a.get("online.s1").rounds;
        let rn = nv.meter_a.get("online.s1").rounds;
        assert!(rn > rv * 5, "naive rounds {rn} must dwarf vectorized {rv}");
    }

    #[test]
    fn epsilon_stops_early_securely() {
        let ds = well_separated(40, 2, 2, 8);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 30,
            epsilon: Some(1e-6),
            partition: Partition::Vertical { d_a: 1 },
            ..Default::default()
        };
        let out = run(&ds, &cfg).unwrap();
        assert!(out.iters_run < 30, "stopped at {}", out.iters_run);
    }

    #[test]
    fn phase_metering_is_populated() {
        let ds = well_separated(20, 2, 2, 9);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 2,
            partition: Partition::Vertical { d_a: 1 },
            ..Default::default()
        };
        let out = run(&ds, &cfg).unwrap();
        for phase in ["online.s1", "online.s2", "online.s3"] {
            assert!(out.meter_a.get(phase).bytes_sent > 0, "phase {phase}");
        }
        assert!(out.offline_gen_secs > 0.0);
        assert!(!out.demand.mats.is_empty());
        assert!(out.ledger.bit_triple_lanes > 0);
    }
}
