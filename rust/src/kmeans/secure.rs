//! The privacy-preserving K-means driver (paper Alg. 3).
//!
//! Simulates the two parties as threads over the accounted channel and
//! runs the full protocol: initialization → t × (S1 distance → S2
//! assignment → S3 update) → output reconstruction, with every step on
//! the round-batched [`crate::ss::Session`] engine and the S1/S3 cross
//! products behind a [`CrossProductBackend`] (Beaver, HE Protocol 2,
//! the naive ablation, or the horizontal row-block path —
//! `EsdMode::Auto` dispatches on joint density).
//!
//! ## Row tiling
//!
//! The whole online phase walks a **row-tile schedule**
//! ([`crate::kmeans::config::tile_schedule`]): with `tile_rows:
//! Some(B)` every backend entry point, every matrix triple and every
//! S1/S3 intermediate is shaped by the tile (≤ B rows), never by n — the
//! offline demand becomes a handful of uniform per-tile shapes repeated
//! `tiles × iters` times, reusable across dataset sizes, which is the
//! deployable offline/online split the paper describes. Under
//! [`TileFlights::Lockstep`] all tiles advance together: S1 stages every
//! tile's gates into one flight, S2 runs the `F_min^k` levels with all
//! tiles' lanes batched per level, S3's per-tile numerators ride the
//! division-prep comparison — flight counts are **identical** to the
//! monolithic schedule (regression-tested). [`TileFlights::Streamed`]
//! processes one tile per flight group instead: rounds × tiles, but
//! O(B·d) live state.
//!
//! Communication is metered per phase (`online.s1` / `online.s2` /
//! `online.s3` / `reveal`), triple generation time is separated by
//! [`crate::offline::timed::TimedSource`], and the exact offline
//! [`Demand`] is recorded for OT-based pricing — together these give
//! every number the paper's tables and figures need from a single run.

use super::backend::{self, CrossProductBackend, PartyData};
use super::config::{tile_schedule, EsdMode, Partition, SecureKmeansConfig, TileFlights};
use super::{assign, esd, init, update};
use crate::data::blobs::Dataset;
use crate::net::{run_two_party, Chan, Meter};
use crate::offline::dealer::{mac_key_share, Dealer};
use crate::offline::store::{Demand, TripleStore};
use crate::offline::timed::TimedSource;
use crate::resume::{MeterSnapshot, Payload, ResumeCtx, TrainState};
use crate::ring::matrix::Mat;
use crate::ss::pending::PendingParts;
use crate::ss::share::{reconstruct, reconstruct_committed, Share};
use crate::ss::triples::{Ledger, TripleSource};
use crate::ss::{Session, SessionOptions};
use crate::util::error::{Error, Result};
use crate::util::prng::Prg;
use crate::util::timer::Timer;

/// Per-step online wall-clock (seconds, triple generation excluded).
#[derive(Debug, Default, Clone, Copy)]
pub struct StepWall {
    pub s1_distance: f64,
    pub s2_assign: f64,
    pub s3_update: f64,
}

/// Everything a bench or application needs from one protocol run.
#[derive(Debug)]
pub struct SecureKmeansOutput {
    /// Offline demand attributed to each step (s1, s2, s3).
    pub step_demands: [Demand; 3],
    /// Reconstructed centroids (k×d, real-valued).
    pub centroids: Vec<f64>,
    /// Reconstructed cluster index per sample.
    pub assignments: Vec<usize>,
    pub k: usize,
    pub d: usize,
    pub iters_run: usize,
    /// Which cross-product backend the run used ("beaver",
    /// "he-protocol2", "naive") — set by explicit `EsdMode` or Auto.
    pub backend_name: &'static str,
    /// Each party's additive share of the final fixed-point centroids
    /// (k×d; `[0]` + `[1]` reconstructs to the encoded `centroids`).
    /// This is the **shared-centroid handle** the serving subsystem
    /// persists: a [`crate::serve::model::TrainedModel`] carries one
    /// share per party so scoring never needs the plaintext centroids.
    pub centroid_shares: [Mat; 2],
    /// Party-0 / party-1 communication meters (phases: online.s1…).
    pub meter_a: Meter,
    pub meter_b: Meter,
    /// Offline material demand recorded by party 0.
    pub demand: Demand,
    pub ledger: Ledger,
    /// Seconds party 0 spent generating triples (the simulated dealer).
    pub offline_gen_secs: f64,
    /// Party-0 thread total wall-clock.
    pub wall_secs: f64,
    /// Online wall-clock by step.
    pub step_wall: StepWall,
    /// Number of tiles the online schedule ran per iteration (1 without
    /// tiling).
    pub tiles_run: usize,
    /// Reconstructed assignment rows that were **not** a valid one-hot
    /// vector (anything nonzero here means the protocol output is
    /// corrupt; such rows are counted instead of silently hidden — also
    /// guarded by a `debug_assert` — and assigned to the first entry
    /// holding a 1, or cluster 0 if none).
    pub malformed_assignment_rows: usize,
}

/// One party's raw protocol outputs (shared with the sparse entrypoint).
pub struct PartyResult {
    pub step_demands: [Demand; 3],
    pub mu: Mat,
    /// This party's additive centroid share (kept alongside the
    /// reconstructed `mu` so serving can resume from shares).
    pub mu_share: Mat,
    pub assignments: Vec<usize>,
    pub backend_name: &'static str,
    pub demand: Demand,
    pub ledger: Ledger,
    pub offline_secs: f64,
    pub wall: f64,
    pub steps: StepWall,
    pub iters: usize,
    pub tiles: usize,
    pub malformed_rows: usize,
}

impl PartyResult {
    /// Assemble the public output struct from party 0's result plus
    /// party 1's centroid share.
    pub fn into_output(
        self,
        k: usize,
        d: usize,
        meter_a: Meter,
        meter_b: Meter,
        wall_b: f64,
        mu_share_b: Mat,
    ) -> SecureKmeansOutput {
        SecureKmeansOutput {
            step_demands: self.step_demands,
            centroids: self.mu.decode(),
            assignments: self.assignments,
            k,
            d,
            iters_run: self.iters,
            backend_name: self.backend_name,
            centroid_shares: [self.mu_share, mu_share_b],
            meter_a,
            meter_b,
            demand: self.demand,
            ledger: self.ledger,
            offline_gen_secs: self.offline_secs,
            wall_secs: self.wall.max(wall_b),
            step_wall: self.steps,
            tiles_run: self.tiles,
            malformed_assignment_rows: self.malformed_rows,
        }
    }
}

/// Split a dataset according to the partition; returns (A block, B block)
/// as fixed-point matrices.
pub fn split_dataset(data: &Dataset, partition: Partition) -> (Mat, Mat) {
    match partition {
        Partition::Vertical { d_a } => {
            assert!(d_a > 0 && d_a < data.d, "vertical split needs 0 < d_a < d");
            let mut xa = Vec::with_capacity(data.n * d_a);
            let mut xb = Vec::with_capacity(data.n * (data.d - d_a));
            for i in 0..data.n {
                let row = data.row(i);
                xa.extend_from_slice(&row[..d_a]);
                xb.extend_from_slice(&row[d_a..]);
            }
            (Mat::encode(data.n, d_a, &xa), Mat::encode(data.n, data.d - d_a, &xb))
        }
        Partition::Horizontal { n_a } => {
            assert!(n_a > 0 && n_a < data.n, "horizontal split needs 0 < n_a < n");
            (
                Mat::encode(n_a, data.d, &data.x[..n_a * data.d]),
                Mat::encode(data.n - n_a, data.d, &data.x[n_a * data.d..]),
            )
        }
    }
}

/// Ledger-seed salt for the malicious tier: both parties derive the
/// same MacAcc stream family from the public run seed.
const MAC_LEDGER_SALT: u128 = 0x0ACC_1ED6_u128 << 64;

/// One party's protocol main loop: the row-tiled schedule over the
/// partition-appropriate cross-product backend. `rctx` writes a
/// `train.iter.{i}` checkpoint at every iteration boundary (a no-op
/// when disabled); `resume` restores one after the deterministic setup
/// has been replayed.
///
/// Under [`crate::net::Security::Malicious`] the channel ledger is
/// armed before the first flight, every Lloyd iteration ends with one
/// batched MAC barrier, the final outputs reconstruct commit-then-
/// reveal, and `train.done` closes with a last barrier. Semi-honest
/// runs skip all of it — the barrier call is a literal no-op on an
/// unarmed channel, keeping the transcript byte-identical.
fn party_main(
    chan: &mut Chan,
    mut x: PartyData,
    n: usize,
    d: usize,
    cfg: &SecureKmeansConfig,
    rctx: &mut ResumeCtx,
    resume: Option<(TrainState, MeterSnapshot)>,
) -> Result<PartyResult> {
    let party = chan.party;
    let t_start = Timer::started();
    if cfg.security.malicious() {
        chan.enable_mac(mac_key_share(cfg.seed, party), cfg.seed ^ MAC_LEDGER_SALT);
    }
    // Install this run's worker count for the deep call sites (Beaver
    // recombination, dealer matmuls, tile-local products). A pure
    // throughput knob: outputs and meters are thread-count independent.
    crate::runtime::pool::set_global_threads(cfg.parallelism.threads);
    // ... and the packed-lane width for the SIMD kernels (PRG bulk
    // fills, lockstep hashing, axpy sweeps) — same contract.
    crate::runtime::simd::set_global_lanes(cfg.lanes.width);
    // Optional measured-link mode: pace every receive to the configured
    // CostModel. Affects wall-clock only — never payloads or meters.
    if let Some(model) = cfg.shape {
        chan.set_shaper(model);
    }
    let timed = TimedSource::new(Dealer::new(cfg.seed, party));
    let mut store = TripleStore::new(timed);
    let mut steps = StepWall::default();

    let mut cross_backend: Box<dyn CrossProductBackend> = backend::select(chan, cfg, &x, d);
    let backend_name = cross_backend.name();
    // The CSR view is speculative under EsdMode::Auto; if density routed
    // us to the dense Beaver path, drop it so the per-iteration S1 local
    // product uses the blocked/PJRT kernel, not per-nonzero indirection.
    if backend_name != "he-protocol2" {
        x.csr = None;
    }

    chan.set_phase("online.init");
    let mut mu = match cfg.partition {
        Partition::Vertical { d_a } => init::vertical(&x.dense, d_a, d, n, cfg.k, cfg.seed, party),
        Partition::Horizontal { n_a } => init::horizontal(&x.dense, n_a, n, cfg.k, cfg.seed, party),
    };

    let tiles = tile_schedule(n, cfg.tile_rows);
    let streamed = cfg.tile_flights == TileFlights::Streamed && tiles.len() > 1;

    let mut c_share = Mat::zeros(n, cfg.k);
    let mut step_demands = [Demand::default(), Demand::default(), Demand::default()];
    let mut iters = 0;
    let mut done = false;
    if let Some((t, (phases, current, flight_open))) = resume {
        // The deterministic setup above (backend selection, the
        // online.init exchange) was *replayed* so the wire stayed in
        // lockstep; everything stateful is now *restored*: the shares,
        // the iteration count, the dealer PRG stream position with its
        // consumed-material ledger and recorded demand, and the original
        // run's exact per-phase meter counts (overwriting the replayed
        // setup's counts, which the snapshot already includes).
        mu = t.mu;
        c_share = t.c_share;
        iters = t.iter as usize;
        done = t.stop;
        step_demands = t.step_demands;
        store = TripleStore::new(TimedSource::new(Dealer::restore(
            cfg.seed,
            party,
            t.dealer_pos,
            t.ledger,
        )));
        store.demand = t.demand;
        chan.restore_meter(Meter::from_snapshot(phases, current, flight_open));
    }
    // A snapshot taken at the convergence stop replays zero iterations.
    let remaining = if done { cfg.iters..cfg.iters } else { iters..cfg.iters };
    for _t in remaining {
        iters += 1;

        let mu_new = if streamed {
            // ---- Streamed: one tile per flight group, O(B·d) state. ---
            // The running numerator / count shares are the only
            // cross-tile state; one division closes the iteration.
            let mut u_row: Option<Mat> = None;
            let mut num_acc = Mat::zeros(cfg.k, d);
            for (ti, &(r0, r1)) in tiles.iter().enumerate() {
                let tseed = (ti as u128 + 1) << 16;

                // S1 tile — the norm row rides tile 0's flight.
                let t0 = Timer::started();
                let off0 = store.inner().secs;
                let dem0 = store.demand.mark();
                let d_tile = {
                    let mut ctx = Session::new(
                        chan,
                        &mut store,
                        Prg::new(cfg.seed ^ ((party as u128) << 64) ^ 0xA5 ^ tseed), SessionOptions::with_policy(cfg.round_policy),);
                    ctx.set_phase("online.s1");
                    let u_p =
                        if ti == 0 { Some(esd::centroid_norms_row_begin(&mut ctx, &mu)) } else { None };
                    let xmu_p = cross_backend.s1_xmu_tile(&mut ctx, &x, &mu, (r0, r1));
                    ctx.flush();
                    if let Some(p) = u_p {
                        u_row = Some(p.resolve(&mut ctx));
                    }
                    let u = u_row.as_ref().expect("norm row resolves with tile 0");
                    esd::dprime_from_parts(u, &xmu_p.resolve(&mut ctx))
                };
                steps.s1_distance += t0.secs() - (store.inner().secs - off0);
                step_demands[0].extend(&store.demand.delta_since(&dem0));

                // S2 tile.
                let t0 = Timer::started();
                let off0 = store.inner().secs;
                let dem0 = store.demand.mark();
                let c_tile = {
                    let mut ctx =
                        Session::new(chan, &mut store, Prg::new(cfg.seed ^ 0xB6 ^ tseed), SessionOptions::with_policy(cfg.round_policy));
                    ctx.set_phase("online.s2");
                    let (c_t, _minvals) = assign::min_k(&mut ctx, &d_tile);
                    c_t
                };
                for i in r0..r1 {
                    c_share.row_mut(i).copy_from_slice(c_tile.row(i - r0));
                }
                steps.s2_assign += t0.secs() - (store.inner().secs - off0);
                step_demands[1].extend(&store.demand.delta_since(&dem0));

                // S3 tile — accumulate the numerator contribution.
                let t0 = Timer::started();
                let off0 = store.inner().secs;
                let dem0 = store.demand.mark();
                {
                    let mut ctx =
                        Session::new(chan, &mut store, Prg::new(cfg.seed ^ 0xC7 ^ tseed), SessionOptions::with_policy(cfg.round_policy));
                    ctx.set_phase("online.s3");
                    let num_p = cross_backend.s3_numerator_tile(&mut ctx, &x, &c_tile, (r0, r1));
                    ctx.flush();
                    num_acc = num_acc.add(&num_p.resolve(&mut ctx));
                }
                steps.s3_update += t0.secs() - (store.inner().secs - off0);
                step_demands[2].extend(&store.demand.delta_since(&dem0));
            }

            // S3 tail: empty-cluster fallback + the single division.
            let t0 = Timer::started();
            let off0 = store.inner().secs;
            let dem0 = store.demand.mark();
            let mu_new = {
                let mut ctx = Session::new(chan, &mut store, Prg::new(cfg.seed ^ 0xC7), SessionOptions::with_policy(cfg.round_policy));
                ctx.set_phase("online.s3");
                update::finish_update_tiles(
                    &mut ctx,
                    vec![PendingParts::ready(num_acc)],
                    &c_share.col_sums(),
                    &mu,
                )
            };
            steps.s3_update += t0.secs() - (store.inner().secs - off0);
            step_demands[2].extend(&store.demand.delta_since(&dem0));
            mu_new
        } else {
            // ---- Lockstep (and the monolithic single tile): every
            // tile's gates share the step's flights. -------------------

            // S1 — distance: norm square + every tile's cross products,
            // one flight on the Beaver path.
            let t0 = Timer::started();
            let off0 = store.inner().secs;
            let dem0 = store.demand.mark();
            let d_tiles: Vec<Mat> = {
                let mut ctx = Session::new(
                    chan,
                    &mut store,
                    Prg::new(cfg.seed ^ ((party as u128) << 64) ^ 0xA5), SessionOptions::with_policy(cfg.round_policy),);
                ctx.set_phase("online.s1");
                let u_row_p = esd::centroid_norms_row_begin(&mut ctx, &mu);
                let xmu_ps: Vec<PendingParts> = tiles
                    .iter()
                    .map(|&t| cross_backend.s1_xmu_tile(&mut ctx, &x, &mu, t))
                    .collect();
                ctx.flush();
                let u_row = u_row_p.resolve(&mut ctx);
                xmu_ps
                    .into_iter()
                    .map(|p| esd::dprime_from_parts(&u_row, &p.resolve(&mut ctx)))
                    .collect()
            };
            steps.s1_distance += t0.secs() - (store.inner().secs - off0);
            step_demands[0].extend(&store.demand.delta_since(&dem0));

            // S2 — assignment: ⌈log₂ k⌉ levels of CMP + fused MUX, all
            // tiles' lanes in lockstep per level.
            let t0 = Timer::started();
            let off0 = store.inner().secs;
            let dem0 = store.demand.mark();
            {
                let mut ctx = Session::new(chan, &mut store, Prg::new(cfg.seed ^ 0xB6), SessionOptions::with_policy(cfg.round_policy));
                ctx.set_phase("online.s2");
                let (c_new, _minvals) = assign::min_k_tiles(&mut ctx, &d_tiles);
                c_share = c_new;
            }
            steps.s2_assign += t0.secs() - (store.inner().secs - off0);
            step_demands[1].extend(&store.demand.delta_since(&dem0));

            // S3 — update: every tile's numerator reveals coalesce into
            // the division prep (empty-cluster comparison), the resolved
            // k×d contributions sum, then one fused MUX flight and one
            // division.
            let t0 = Timer::started();
            let off0 = store.inner().secs;
            let dem0 = store.demand.mark();
            let mu_new = {
                let mut ctx = Session::new(chan, &mut store, Prg::new(cfg.seed ^ 0xC7), SessionOptions::with_policy(cfg.round_policy));
                ctx.set_phase("online.s3");
                let nums: Vec<PendingParts> = tiles
                    .iter()
                    .map(|&(r0, r1)| {
                        // Full range (monolithic): borrow, don't copy.
                        let c_tile: std::borrow::Cow<'_, Mat> = if (r0, r1) == (0, n) {
                            std::borrow::Cow::Borrowed(&c_share)
                        } else {
                            std::borrow::Cow::Owned(c_share.rows_slice(r0, r1))
                        };
                        cross_backend.s3_numerator_tile(&mut ctx, &x, &c_tile, (r0, r1))
                    })
                    .collect();
                update::finish_update_tiles(&mut ctx, nums, &c_share.col_sums(), &mu)
            };
            steps.s3_update += t0.secs() - (store.inner().secs - off0);
            step_demands[2].extend(&store.demand.delta_since(&dem0));
            mu_new
        };

        // Optional F_CSC convergence check.
        let stop = if let Some(eps) = cfg.epsilon {
            let mut ctx = Session::new(chan, &mut store, Prg::new(cfg.seed ^ 0xD8), SessionOptions::with_policy(cfg.round_policy));
            ctx.set_phase("online.csc");
            update::converged(&mut ctx, &mu, &mu_new, eps)
        } else {
            false
        };
        mu = mu_new;
        // Malicious tier: settle the whole iteration's ledger in one
        // batched check — O(1) flights per Lloyd boundary regardless of
        // n, k or the tile schedule. Guarded so a semi-honest meter
        // never even grows the phase entry.
        if cfg.security.malicious() {
            chan.set_phase("mac.barrier");
            chan.mac_barrier(&format!("train.iter.{}", iters - 1))?;
        }
        // Checkpoint the iteration boundary: everything the loop carries
        // across iterations plus the dealer stream position. Saved after
        // the convergence decision so a resumed run knows whether the
        // loop had already stopped.
        rctx.save(
            &format!("train.iter.{}", iters - 1),
            chan.meter(),
            Payload::Train(TrainState {
                iter: iters as u32,
                stop,
                mu: mu.clone(),
                c_share: c_share.clone(),
                dealer_pos: store.inner().source().position(),
                ledger: store.ledger(),
                demand: store.demand.clone(),
                step_demands: step_demands.clone(),
            }),
        );
        if stop {
            break;
        }
    }

    // Output reconstruction (the single reveal of the protocol). The
    // malicious tier reveals commit-then-hash-checked so neither party
    // can pick its output share after seeing the other's, then closes
    // the run with the final `train.done` ledger barrier.
    chan.set_phase("reveal");
    let (mu_plain, c_plain) = if cfg.security.malicious() {
        let m = reconstruct_committed(chan, &Share::plain(mu.clone()), "train.reveal.mu")?;
        let c = reconstruct_committed(chan, &Share::plain(c_share.clone()), "train.reveal.assign")?;
        (m, c)
    } else {
        (reconstruct(chan, &mu), reconstruct(chan, &c_share))
    };
    if cfg.security.malicious() {
        chan.set_phase("mac.barrier");
        chan.mac_barrier("train.done")?;
    }
    // A reconstructed assignment row must be exactly one-hot; anything
    // else is protocol corruption — count it (and trip a debug assert)
    // instead of silently mapping the row to cluster 0.
    let mut malformed_rows = 0usize;
    let assignments: Vec<usize> = (0..n)
        .map(|i| {
            let row = c_plain.row(i);
            let (idx, well_formed) = assign::decode_one_hot_row(row);
            if !well_formed {
                malformed_rows += 1;
                debug_assert!(
                    well_formed,
                    "assignment row {i} is not one-hot: {:?}",
                    row
                );
            }
            idx
        })
        .collect();

    Ok(PartyResult {
        step_demands,
        mu: mu_plain,
        mu_share: mu,
        assignments,
        backend_name,
        demand: store.demand.clone(),
        ledger: store.ledger(),
        offline_secs: store.inner().secs,
        wall: t_start.secs(),
        steps,
        iters,
        tiles: tiles.len(),
        malformed_rows,
    })
}

/// Assignment-only inference for one row tile: S1 distance (the tile's
/// staged cross products plus a **cached** shared norm row — recompute
/// it only when the centroids change, see
/// [`crate::kmeans::esd::centroid_norms_row_begin`]) followed by S2
/// `F_min^k`. No S3 update step, no reveal: the returned one-hot
/// assignment matrix (n_t×k) and minimum D' distances (n_t×1, scale 2f)
/// stay secret-shared. Exactly `1 + min_k_rounds(k)` flights.
///
/// This is the serving entry point: a
/// [`crate::serve::scorer::Scorer`] calls it per micro-batch against a
/// long-lived centroid share, which is how the train-once /
/// score-forever split avoids ever re-running the update step.
/// Communication is metered under `{phase_prefix}s1` / `{phase_prefix}s2`.
pub fn assign_only_tile(
    ctx: &mut Session,
    backend: &mut dyn CrossProductBackend,
    x: &PartyData,
    mu: &Mat,
    u_row: &Mat,
    rows: (usize, usize),
    phase_prefix: &str,
) -> (Mat, Mat) {
    ctx.set_phase(&format!("{phase_prefix}s1"));
    let xmu_p = backend.s1_xmu_tile(ctx, x, mu, rows);
    ctx.flush();
    let d_tile = esd::dprime_from_parts(u_row, &xmu_p.resolve(ctx));
    ctx.set_phase(&format!("{phase_prefix}s2"));
    assign::min_k(ctx, &d_tile)
}

/// Validate a configuration before any thread or socket work starts.
fn validate(cfg: &SecureKmeansConfig) -> Result<()> {
    if cfg.k < 2 {
        return Err(Error::Config("k must be ≥ 2".into()));
    }
    if cfg.tile_rows == Some(0) {
        return Err(Error::Config("tile_rows must be ≥ 1".into()));
    }
    let horizontal = matches!(cfg.partition, Partition::Horizontal { .. });
    if horizontal && matches!(cfg.effective_esd(), EsdMode::He { .. }) {
        return Err(Error::Config("sparse path supports vertical partitioning (Alg. 3)".into()));
    }
    Ok(())
}

/// Run **one party's** side of the full protocol over any connected
/// [`Chan`] backend — the entry point for two-process TCP deployments
/// (the in-process [`run`] drives both parties over a duplex pair and
/// is implemented on top of this).
///
/// `data` is the full joint dataset (already normalized if the caller
/// wants normalization): in a deployment both processes derive it from
/// a shared scenario (synthetic generation from a negotiated seed, or a
/// pre-shared file) and this function carves out the block that
/// `cfg.partition` assigns to `chan.party`. The protocol schedule,
/// reveals and meter readings are **bit-identical** across transports —
/// the in-process duplex pair and localhost TCP produce the same
/// transcript (regression-tested).
pub fn run_party(chan: &mut Chan, data: &Dataset, cfg: &SecureKmeansConfig) -> Result<PartyResult> {
    run_party_ckpt(chan, data, cfg, &mut ResumeCtx::disabled(), None)
}

/// [`run_party`] with barrier checkpointing: `rctx` writes a
/// `train.iter.{i}` snapshot after every Lloyd iteration, and `resume`
/// restores one (as negotiated by the v2 handshake's resume leg).
///
/// Resuming is supported on the Beaver and naive backends. The HE
/// Protocol 2 backend exchanges encrypted inputs on first use, so a
/// replayed setup would not stay in wire lockstep with the original
/// run — resuming an `esd = he` (or `auto`, which may route there) run
/// is a typed [`Error::Config`]; pin `esd` in resumable scenarios.
pub fn run_party_ckpt(
    chan: &mut Chan,
    data: &Dataset,
    cfg: &SecureKmeansConfig,
    rctx: &mut ResumeCtx,
    resume: Option<(TrainState, MeterSnapshot)>,
) -> Result<PartyResult> {
    validate(cfg)?;
    let esd_mode = cfg.effective_esd();
    if cfg.security.malicious() && (rctx.enabled() || resume.is_some()) {
        return Err(Error::Config(
            "resume: a malicious-tier run cannot checkpoint or restore — the deferred MAC \
             ledger does not survive a restart; rerun from scratch or drop to semi_honest"
                .into(),
        ));
    }
    if resume.is_some() && matches!(esd_mode, EsdMode::He { .. } | EsdMode::Auto) {
        return Err(Error::Config(
            "resume: checkpointed training resumes on the beaver/naive backends only — \
             pin `esd` away from he/auto in resumable scenarios"
                .into(),
        ));
    }
    let (xa, xb) = split_dataset(data, cfg.partition);
    let x_own = if chan.party == 0 { xa } else { xb };
    // Build the CSR view when the run may take the HE path.
    let may_sparse = matches!(esd_mode, EsdMode::He { .. } | EsdMode::Auto)
        && matches!(cfg.partition, Partition::Vertical { .. });
    let p = if may_sparse { PartyData::with_csr(x_own) } else { PartyData::dense_only(x_own) };
    party_main(chan, p, data.n, data.d, cfg, rctx, resume)
}

/// Run the full two-party protocol on a dataset, any partition, any
/// cross-product backend and any tile schedule.
pub fn run(data: &Dataset, cfg: &SecureKmeansConfig) -> Result<SecureKmeansOutput> {
    validate(cfg)?;
    let (n, d) = (data.n, data.d);
    // Split once and hand each party thread only its own block — the
    // protocol path below this point (party_main) is byte-identical to
    // what run_party drives in a two-process deployment; only the
    // plaintext data-prep differs.
    let (xa, xb) = split_dataset(data, cfg.partition);
    let may_sparse = matches!(cfg.effective_esd(), EsdMode::He { .. } | EsdMode::Auto)
        && matches!(cfg.partition, Partition::Vertical { .. });
    let pa = if may_sparse { PartyData::with_csr(xa) } else { PartyData::dense_only(xa) };
    let pb = if may_sparse { PartyData::with_csr(xb) } else { PartyData::dense_only(xb) };
    let cfg_a = cfg.clone();
    let cfg_b = cfg.clone();
    let ((ra, meter_a), (rb, meter_b)) = run_two_party(
        move |c| party_main(c, pa, n, d, &cfg_a, &mut ResumeCtx::disabled(), None),
        move |c| party_main(c, pb, n, d, &cfg_b, &mut ResumeCtx::disabled(), None),
    );
    let (ra, rb) = (ra?, rb?);
    debug_assert_eq!(ra.mu, rb.mu, "parties must reconstruct identical centroids");
    if ra.malformed_rows > 0 {
        eprintln!(
            "WARNING: {} of {} reconstructed assignment rows were not one-hot \
             (protocol corruption; each mapped to its first 1-entry, or cluster 0)",
            ra.malformed_rows, n
        );
    }
    let wall_b = rb.wall;
    Ok(ra.into_output(cfg.k, d, meter_a, meter_b, wall_b, rb.mu_share))
}

/// Convenience: vertical partition with an even feature split.
pub fn run_vertical(data: &Dataset, cfg: &SecureKmeansConfig) -> Result<SecureKmeansOutput> {
    let mut cfg = cfg.clone();
    cfg.partition = Partition::Vertical { d_a: (data.d / 2).max(1) };
    run(data, &cfg)
}

/// Convenience: horizontal partition with an even sample split.
pub fn run_horizontal(data: &Dataset, cfg: &SecureKmeansConfig) -> Result<SecureKmeansOutput> {
    let mut cfg = cfg.clone();
    cfg.partition = Partition::Horizontal { n_a: (data.n / 2).max(1) };
    run(data, &cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::BlobSpec;
    use crate::kmeans::plaintext;

    fn well_separated(n: usize, d: usize, k: usize, seed: u128) -> Dataset {
        let mut spec = BlobSpec::new(n, d, k);
        spec.spread = 0.02;
        spec.generate(seed)
    }

    #[test]
    fn secure_matches_plaintext_vertical() {
        let ds = well_separated(60, 4, 3, 21);
        let cfg = SecureKmeansConfig {
            k: 3,
            iters: 6,
            partition: Partition::Vertical { d_a: 2 },
            ..Default::default()
        };
        let sec = run(&ds, &cfg).unwrap();
        let plain = plaintext::kmeans(&ds, 3, 6, cfg.seed);
        // Same init (same seed) → same trajectory up to fixed-point noise.
        for i in 0..sec.centroids.len() {
            assert!(
                (sec.centroids[i] - plain.centroids[i]).abs() < 1e-2,
                "centroid {i}: {} vs {}",
                sec.centroids[i],
                plain.centroids[i]
            );
        }
        assert_eq!(sec.assignments, plain.assignments);
        assert_eq!(sec.backend_name, "beaver");
        assert_eq!(sec.tiles_run, 1);
        assert_eq!(sec.malformed_assignment_rows, 0);
    }

    #[test]
    fn secure_matches_plaintext_horizontal() {
        let ds = well_separated(50, 3, 2, 33);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 5,
            partition: Partition::Horizontal { n_a: 20 },
            ..Default::default()
        };
        let sec = run(&ds, &cfg).unwrap();
        let plain = plaintext::kmeans(&ds, 2, 5, cfg.seed);
        assert_eq!(sec.assignments, plain.assignments);
    }

    #[test]
    fn tiled_matches_monolithic_vertical_nondivisor() {
        // B = 17 does not divide n = 60 (ragged last tile of 9 rows);
        // both tile policies must agree with the monolithic run.
        let ds = well_separated(60, 4, 3, 44);
        let base = SecureKmeansConfig {
            k: 3,
            iters: 4,
            partition: Partition::Vertical { d_a: 2 },
            ..Default::default()
        };
        let mono = run(&ds, &base).unwrap();
        for flights in [TileFlights::Lockstep, TileFlights::Streamed] {
            let cfg = SecureKmeansConfig {
                tile_rows: Some(17),
                tile_flights: flights,
                ..base.clone()
            };
            let tiled = run(&ds, &cfg).unwrap();
            assert_eq!(tiled.tiles_run, 4);
            assert_eq!(tiled.assignments, mono.assignments, "{flights:?}");
            for i in 0..mono.centroids.len() {
                assert!(
                    (tiled.centroids[i] - mono.centroids[i]).abs() < 1e-2,
                    "{flights:?} centroid {i}: {} vs {}",
                    tiled.centroids[i],
                    mono.centroids[i]
                );
            }
        }
    }

    #[test]
    fn tiled_matches_monolithic_horizontal_nondivisor() {
        // Tiles cut across the ownership boundary n_a = 20 (tile (17,34)
        // spans it), on both flight policies.
        let ds = well_separated(60, 3, 2, 45);
        let base = SecureKmeansConfig {
            k: 2,
            iters: 4,
            partition: Partition::Horizontal { n_a: 20 },
            ..Default::default()
        };
        let mono = run(&ds, &base).unwrap();
        for flights in [TileFlights::Lockstep, TileFlights::Streamed] {
            let cfg = SecureKmeansConfig {
                tile_rows: Some(17),
                tile_flights: flights,
                ..base.clone()
            };
            let tiled = run(&ds, &cfg).unwrap();
            assert_eq!(tiled.assignments, mono.assignments, "{flights:?}");
            for i in 0..mono.centroids.len() {
                assert!(
                    (tiled.centroids[i] - mono.centroids[i]).abs() < 1e-2,
                    "{flights:?} centroid {i}",
                );
            }
        }
    }

    #[test]
    fn centroid_shares_reconstruct_to_output() {
        // The shared-centroid handle must reconstruct to exactly the
        // reported plaintext centroids (serving resumes from the shares).
        let ds = well_separated(30, 3, 2, 77);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 3,
            partition: Partition::Vertical { d_a: 1 },
            ..Default::default()
        };
        let out = run(&ds, &cfg).unwrap();
        let rec = out.centroid_shares[0].add(&out.centroid_shares[1]);
        assert_eq!(rec.decode(), out.centroids);
    }

    #[test]
    fn zero_tile_rows_is_rejected() {
        let ds = well_separated(20, 2, 2, 46);
        let cfg = SecureKmeansConfig { tile_rows: Some(0), ..Default::default() };
        assert!(run(&ds, &cfg).is_err());
    }

    #[test]
    fn naive_esd_same_result_more_rounds() {
        let ds = well_separated(12, 2, 2, 5);
        let base = SecureKmeansConfig {
            k: 2,
            iters: 2,
            partition: Partition::Vertical { d_a: 1 },
            ..Default::default()
        };
        let mut naive_cfg = base.clone();
        naive_cfg.esd = EsdMode::Naive;
        let v = run(&ds, &base).unwrap();
        let nv = run(&ds, &naive_cfg).unwrap();
        assert_eq!(v.assignments, nv.assignments);
        assert_eq!(nv.backend_name, "naive");
        let rv = v.meter_a.get("online.s1").rounds;
        let rn = nv.meter_a.get("online.s1").rounds;
        assert!(rn > rv * 5, "naive rounds {rn} must dwarf vectorized {rv}");
    }

    #[test]
    fn epsilon_stops_early_securely() {
        let ds = well_separated(40, 2, 2, 8);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 30,
            epsilon: Some(1e-6),
            partition: Partition::Vertical { d_a: 1 },
            ..Default::default()
        };
        let out = run(&ds, &cfg).unwrap();
        assert!(out.iters_run < 30, "stopped at {}", out.iters_run);
    }

    #[test]
    fn phase_metering_is_populated() {
        let ds = well_separated(20, 2, 2, 9);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 2,
            partition: Partition::Vertical { d_a: 1 },
            ..Default::default()
        };
        let out = run(&ds, &cfg).unwrap();
        for phase in ["online.s1", "online.s2", "online.s3"] {
            assert!(out.meter_a.get(phase).bytes_sent > 0, "phase {phase}");
        }
        assert!(out.offline_gen_secs > 0.0);
        assert!(!out.demand.mats.is_empty());
        assert!(out.ledger.bit_triple_lanes > 0);
        assert!(out.ledger.dabit_lanes > 0, "fused MUX/B2A consume daBits");
    }

    #[test]
    fn malicious_tier_matches_semi_honest_and_costs_one_barrier_per_iter() {
        use crate::net::Security;
        let ds = well_separated(30, 3, 2, 91);
        let base = SecureKmeansConfig {
            k: 2,
            iters: 3,
            partition: Partition::Vertical { d_a: 1 },
            ..Default::default()
        };
        let sh = run(&ds, &base).unwrap();
        let mal_cfg = SecureKmeansConfig { security: Security::Malicious, ..base };
        let mal = run(&ds, &mal_cfg).unwrap();
        // Honest parties: identical outputs in both tiers.
        assert_eq!(mal.assignments, sh.assignments);
        assert_eq!(mal.centroids, sh.centroids);
        // The malicious overhead is O(1) per phase boundary: 3 flights ×
        // (iters + 1) barriers at 96 bytes each, plus one 32-byte commit
        // per final reveal — independent of n, d, k.
        let bar = mal.meter_a.get("mac.barrier");
        assert_eq!(bar.rounds, 3 * (3 + 1));
        assert_eq!(bar.bytes_sent, 96 * (3 + 1));
        let extra_reveal = mal.meter_a.get("reveal").bytes_sent
            - sh.meter_a.get("reveal").bytes_sent;
        assert_eq!(extra_reveal, 2 * 32);
        // Everything outside the barrier/commit flights is byte-identical.
        for phase in ["online.s1", "online.s2", "online.s3"] {
            assert_eq!(
                mal.meter_a.get(phase).bytes_sent,
                sh.meter_a.get(phase).bytes_sent,
                "phase {phase} must not grow under the malicious tier"
            );
            assert_eq!(mal.meter_a.get(phase).rounds, sh.meter_a.get(phase).rounds);
        }
    }

    #[test]
    fn he_on_horizontal_is_rejected() {
        let ds = well_separated(20, 2, 2, 10);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 1,
            esd: EsdMode::he(),
            partition: Partition::Horizontal { n_a: 10 },
            ..Default::default()
        };
        assert!(run(&ds, &cfg).is_err());
    }
}
