//! Plaintext Lloyd K-means: the correctness oracle for the secure
//! protocol and the Q5 single-party baseline.

use crate::data::blobs::Dataset;
use crate::util::prng::Prg;

/// Output of a plaintext K-means run.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    /// k×d row-major centroids.
    pub centroids: Vec<f64>,
    /// Cluster index per sample.
    pub assignments: Vec<usize>,
    /// Sum of squared distances to assigned centroids.
    pub inertia: f64,
    pub k: usize,
    pub d: usize,
    /// Iterations actually executed.
    pub iters_run: usize,
}

/// Squared Euclidean distance.
pub fn esd(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Pick initial centroids as `k` distinct data rows chosen by a public
/// seed — the "jointly negotiate random indexes" strategy of §4.2.
pub fn init_indices(n: usize, k: usize, seed: u128) -> Vec<usize> {
    let mut prg = Prg::new(seed ^ 0x1217);
    let mut picked = Vec::with_capacity(k);
    while picked.len() < k {
        let i = prg.next_below(n as u64) as usize;
        if !picked.contains(&i) {
            picked.push(i);
        }
    }
    picked
}

/// Run Lloyd iterations from explicit initial centroids.
pub fn kmeans_from(
    data: &Dataset,
    k: usize,
    iters: usize,
    mut centroids: Vec<f64>,
    epsilon: Option<f64>,
) -> KmeansResult {
    let (n, d) = (data.n, data.d);
    assert_eq!(centroids.len(), k * d);
    let mut assignments = vec![0usize; n];
    let mut iters_run = 0;
    for _ in 0..iters {
        iters_run += 1;
        // Assignment.
        for i in 0..n {
            let row = data.row(i);
            let mut best = 0;
            let mut bestd = f64::INFINITY;
            for j in 0..k {
                let dist = esd(row, &centroids[j * d..(j + 1) * d]);
                if dist < bestd {
                    bestd = dist;
                    best = j;
                }
            }
            assignments[i] = best;
        }
        // Update (empty clusters keep their previous centroid).
        let mut sums = vec![0.0; k * d];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let j = assignments[i];
            counts[j] += 1;
            for l in 0..d {
                sums[j * d + l] += data.x[i * d + l];
            }
        }
        let mut moved = 0.0;
        for j in 0..k {
            if counts[j] == 0 {
                continue;
            }
            for l in 0..d {
                let new = sums[j * d + l] / counts[j] as f64;
                let old = centroids[j * d + l];
                moved += (new - old) * (new - old);
                centroids[j * d + l] = new;
            }
        }
        if let Some(eps) = epsilon {
            if moved < eps {
                break;
            }
        }
    }
    // Final inertia.
    let mut inertia = 0.0;
    for i in 0..n {
        inertia += esd(data.row(i), &centroids[assignments[i] * d..(assignments[i] + 1) * d]);
    }
    KmeansResult { centroids, assignments, inertia, k, d, iters_run }
}

/// Standard run: seed-chosen data rows as initial centroids.
pub fn kmeans(data: &Dataset, k: usize, iters: usize, seed: u128) -> KmeansResult {
    let idx = init_indices(data.n, k, seed);
    let mut init = Vec::with_capacity(k * data.d);
    for &i in &idx {
        init.extend_from_slice(data.row(i));
    }
    kmeans_from(data, k, iters, init, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::BlobSpec;

    #[test]
    fn recovers_well_separated_blobs() {
        let mut spec = BlobSpec::new(300, 2, 3);
        spec.spread = 0.01;
        let ds = spec.generate(5);
        let r = kmeans(&ds, 3, 20, 42);
        // Each found cluster should be dominated by one true label.
        let mut purity = 0usize;
        for j in 0..3 {
            let members: Vec<usize> =
                (0..ds.n).filter(|&i| r.assignments[i] == j).collect();
            if members.is_empty() {
                continue;
            }
            let mut counts = [0usize; 3];
            for &i in &members {
                counts[ds.labels[i]] += 1;
            }
            purity += counts.iter().max().unwrap();
        }
        assert!(purity as f64 / ds.n as f64 > 0.95, "purity {purity}/{}", ds.n);
    }

    #[test]
    fn inertia_decreases_with_more_iterations() {
        let ds = BlobSpec::new(200, 3, 4).generate(8);
        let r1 = kmeans(&ds, 4, 1, 7);
        let r10 = kmeans(&ds, 4, 10, 7);
        assert!(r10.inertia <= r1.inertia + 1e-9);
    }

    #[test]
    fn epsilon_stops_early() {
        let ds = BlobSpec::new(100, 2, 2).generate(9);
        let idx = init_indices(ds.n, 2, 3);
        let mut init = Vec::new();
        for &i in &idx {
            init.extend_from_slice(ds.row(i));
        }
        let r = kmeans_from(&ds, 2, 50, init, Some(1e-12));
        assert!(r.iters_run < 50, "converged in {} iters", r.iters_run);
    }

    #[test]
    fn init_indices_distinct_and_seed_stable() {
        let a = init_indices(100, 5, 1);
        let b = init_indices(100, 5, 1);
        assert_eq!(a, b);
        let mut s = a.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 5);
    }
}
