//! Sparsity-optimized privacy-preserving K-means (paper §4.3, Alg. 3).
//!
//! Thin entrypoint: the sparse path is the unified driver of
//! [`super::secure`] running with the HE Protocol 2 cross-product
//! backend ([`crate::kmeans::backend::HeBackend`]) — the sparse holder
//! computes over the ciphertexts of the *small* operand (centroid share
//! block, assignment share), skipping zero entries entirely, and
//! communication drops from `O(n·d)` ring elements to `O((d+n)·k)`
//! ciphertexts — the win that grows with dimension and sparsity
//! (Figures 4a/4b). Assignment and division remain in the SS world.
//!
//! Each party owns an Okamoto-Uchiyama key pair (paper §5.1); public
//! keys are exchanged once at setup by the backend.

use super::config::{EsdMode, SecureKmeansConfig};
use super::secure::{self, SecureKmeansOutput};
use crate::data::blobs::Dataset;
use crate::util::error::Result;

/// Run the sparse-optimized protocol (vertical partitioning only, as in
/// the paper's Alg. 3).
pub fn run(data: &Dataset, cfg: &SecureKmeansConfig) -> Result<SecureKmeansOutput> {
    let mut cfg = cfg.clone();
    // Force the HE backend, keeping an explicitly configured modulus
    // size if the caller already picked the HE path.
    if !matches!(cfg.esd, EsdMode::He { .. }) {
        cfg.esd = EsdMode::he();
    }
    secure::run(data, &cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::BlobSpec;
    use crate::kmeans::config::Partition;
    use crate::kmeans::plaintext;
    use crate::util::prng::Prg;

    fn sparse_dataset(n: usize, d: usize, k: usize, sparsity: f64, seed: u128) -> Dataset {
        let mut spec = BlobSpec::new(n, d, k);
        spec.spread = 0.02;
        let mut ds = spec.generate(seed);
        // Zero out entries deterministically to reach the target sparsity.
        let mut prg = Prg::new(seed ^ 0x5EED);
        for v in ds.x.iter_mut() {
            if prg.next_f64() < sparsity {
                *v = 0.0;
            }
        }
        ds
    }

    #[test]
    fn sparse_path_matches_plaintext_kmeans() {
        let ds = sparse_dataset(24, 4, 2, 0.5, 77);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 3,
            esd: EsdMode::He { bits: 768 },
            partition: Partition::Vertical { d_a: 2 },
            ..Default::default()
        };
        let sec = run(&ds, &cfg).unwrap();
        assert_eq!(sec.backend_name, "he-protocol2");
        let plain = plaintext::kmeans(&ds, 2, 3, cfg.seed);
        assert_eq!(sec.assignments, plain.assignments);
        for i in 0..sec.centroids.len() {
            assert!(
                (sec.centroids[i] - plain.centroids[i]).abs() < 1e-2,
                "centroid {i}: {} vs {}",
                sec.centroids[i],
                plain.centroids[i]
            );
        }
    }
}
