//! Sparsity-optimized privacy-preserving K-means (paper §4.3, Alg. 3).
//!
//! Identical to the dense driver except that the two cross products of
//! S1 and S3 run through HE Protocol 2 ([`crate::sparse::protocol2`])
//! instead of matrix Beaver triples: the sparse holder computes over the
//! ciphertexts of the *small* operand (centroid share block, assignment
//! share), skipping zero entries entirely, and communication drops from
//! `O(n·d)` ring elements to `O((d+n)·k)` ciphertexts — the win that
//! grows with dimension and sparsity (Figures 4a/4b). Assignment and
//! division remain in the SS world.
//!
//! Each party owns an Okamoto-Uchiyama key pair (paper §5.1); public
//! keys are exchanged once at setup.

use super::config::{Partition, SecureKmeansConfig};
use super::secure::{PartyResult, SecureKmeansOutput, StepWall};
use super::{assign, esd, init, update};
use crate::data::blobs::Dataset;
use crate::he::ou::{Ou, OuPk};
use crate::he::HeScheme;
use crate::net::{run_two_party, Chan};
use crate::offline::dealer::Dealer;
use crate::offline::store::TripleStore;
use crate::offline::timed::TimedSource;
use crate::ring::matrix::Mat;
use crate::sparse::csr::Csr;
use crate::sparse::protocol2;
use crate::ss::share::reconstruct;
use crate::ss::triples::TripleSource;
use crate::ss::Ctx;
use crate::util::error::{Error, Result};
use crate::util::prng::Prg;
use std::time::Instant;

fn ppkmeans_default_demand() -> crate::offline::store::Demand {
    crate::offline::store::Demand::default()
}

/// Serialize an OU public key (n, g, h as length-prefixed big-endian).
fn pk_to_bytes(pk: &OuPk) -> Vec<u8> {
    let mut out = Vec::new();
    for part in [&pk.n, &pk.g, &pk.h] {
        let b = part.to_bytes_be();
        out.extend_from_slice(&(b.len() as u32).to_le_bytes());
        out.extend_from_slice(&b);
    }
    out
}

fn pk_from_bytes(bytes: &[u8]) -> OuPk {
    let mut parts = Vec::with_capacity(3);
    let mut off = 0;
    for _ in 0..3 {
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        off += 4;
        parts.push(crate::bigint::BigUint::from_bytes_be(&bytes[off..off + len]));
        off += len;
    }
    let n = parts.remove(0);
    let g = parts.remove(0);
    let h = parts.remove(0);
    OuPk { n_bits: n.bits(), n, g, h }
}

/// Sparse cross product for the distance step: this party's sparse block
/// times the *peer's* share of this party's centroid columns.
/// `my_turn_sparse` — whether I am the sparse holder in this direction.
#[allow(clippy::too_many_arguments)]
fn sparse_cross(
    chan: &mut Chan,
    my_sk: &<Ou as HeScheme>::Sk,
    my_pk: &OuPk,
    their_pk: &OuPk,
    x_csr: Option<&Csr>,
    dense: Option<&Mat>,
    x_rows: usize,
    y_shape: (usize, usize),
    prg: &mut Prg,
    my_turn_sparse: bool,
) -> Mat {
    if my_turn_sparse {
        // I hold the sparse matrix; peer encrypted its dense operand.
        protocol2::sparse_party::<Ou>(chan, their_pk, x_csr.unwrap(), y_shape, prg)
    } else {
        protocol2::dense_party::<Ou>(chan, my_pk, my_sk, dense.unwrap(), x_rows, prg)
    }
}

struct SparseParty {
    x_csr: Csr,
    x_dense: Mat,
}

/// One party's sparse-path protocol loop (vertical partitioning).
#[allow(clippy::too_many_arguments)]
fn party_main(
    chan: &mut Chan,
    me: SparseParty,
    n: usize,
    d: usize,
    d_a: usize,
    cfg: &SecureKmeansConfig,
) -> PartyResult {
    let party = chan.party;
    let t_start = Instant::now();
    let timed = TimedSource::new(Dealer::new(cfg.seed, party));
    let mut store = TripleStore::new(timed);
    let mut steps = StepWall::default();
    let mut prg = Prg::new(cfg.seed ^ ((party as u128) << 96) ^ 0xE1);

    // HE setup: generate my key pair, exchange public keys.
    chan.set_phase("offline.hekeys");
    let (my_pk, my_sk) = Ou::keygen(cfg.he_bits, &mut prg);
    chan.send_bytes(&pk_to_bytes(&my_pk));
    let their_pk = pk_from_bytes(&chan.recv_bytes());

    chan.set_phase("online.init");
    let mut mu = init::vertical(&me.x_dense, d_a, d, n, cfg.k, cfg.seed, party);

    let d_mine = if party == 0 { d_a } else { d - d_a };
    let mut c_share = Mat::zeros(n, cfg.k);
    let mut iters = 0;
    for _t in 0..cfg.iters {
        iters += 1;

        // ---- S1: distance with HE cross products.
        let t0 = Instant::now();
        let off0 = store.inner().secs;
        let dmat = {
            // Norm term via SS (k·d lanes).
            let u = {
                let mut ctx = Ctx::new(chan, &mut store, Prg::new(cfg.seed ^ 0xF2));
                ctx.set_phase("online.s1");
                esd::centroid_norms(&mut ctx, &mu, n)
            };
            // Local term: X_mine · ⟨μ⟩_mine-blockᵀ.
            let (mu_a_blk, mu_b_blk) = esd::split_mu_vertical(&mu, d_a);
            let my_blk = if party == 0 { &mu_a_blk } else { &mu_b_blk };
            let local = me.x_csr.matmul_dense(&my_blk.transpose());
            // Cross 1: X_A (sparse at A) × ⟨μ_B⟩ A-block ᵀ (dense at B).
            chan.set_phase("online.s1");
            let ya = mu_a_blk.transpose(); // d_a×k — B's share is the payload
            let cross1 = sparse_cross(
                chan,
                &my_sk,
                &my_pk,
                &their_pk,
                Some(&me.x_csr),
                Some(&ya),
                n,
                (d_a, cfg.k),
                &mut prg,
                party == 0,
            );
            // Cross 2: X_B (sparse at B) × ⟨μ_A⟩ B-block ᵀ (dense at A).
            let yb = mu_b_blk.transpose(); // d_b×k
            let cross2 = sparse_cross(
                chan,
                &my_sk,
                &my_pk,
                &their_pk,
                Some(&me.x_csr),
                Some(&yb),
                n,
                (d - d_a, cfg.k),
                &mut prg,
                party == 1,
            );
            let xmu = local.add(&cross1).add(&cross2);
            u.sub(&xmu.scale(2))
        };
        steps.s1_distance += t0.elapsed().as_secs_f64() - (store.inner().secs - off0);

        // ---- S2: assignment (unchanged SS tree).
        let t0 = Instant::now();
        let off0 = store.inner().secs;
        {
            let mut ctx = Ctx::new(chan, &mut store, Prg::new(cfg.seed ^ 0xB6));
            ctx.set_phase("online.s2");
            let (c_new, _) = assign::min_k(&mut ctx, &dmat);
            c_share = c_new;
        }
        steps.s2_assign += t0.elapsed().as_secs_f64() - (store.inner().secs - off0);

        // ---- S3: update with HE cross products.
        let t0 = Instant::now();
        let off0 = store.inner().secs;
        let mu_new = {
            chan.set_phase("online.s3");
            // Local: ⟨C⟩_meᵀ · X_me = (X_meᵀ·⟨C⟩_me)ᵀ via sparse transpose product.
            let local = me.x_csr.t_matmul_dense(&c_share).transpose(); // k×d_mine
            // Cross: ⟨C⟩_otherᵀ · X_me = (X_meᵀ · ⟨C⟩_other)ᵀ — me sparse
            // holder of X_meᵀ, other dense holder of its C share.
            let xt = me.x_csr.transpose(); // d_mine×n
            // Direction 1: block A (me = party 0 sparse).
            let cross_a = sparse_cross(
                chan,
                &my_sk,
                &my_pk,
                &their_pk,
                Some(&xt),
                Some(&c_share),
                if party == 0 { d_mine } else { d_a },
                (n, cfg.k),
                &mut prg,
                party == 0,
            );
            // Direction 2: block B (me = party 1 sparse).
            let cross_b = sparse_cross(
                chan,
                &my_sk,
                &my_pk,
                &their_pk,
                Some(&xt),
                Some(&c_share),
                if party == 1 { d_mine } else { d - d_a },
                (n, cfg.k),
                &mut prg,
                party == 1,
            );
            // Assemble numerator blocks in feature order.
            let my_cross = if party == 0 { &cross_a } else { &cross_b };
            let my_block = local.add(&my_cross.transpose()); // k×d_mine
            let other_block = if party == 0 {
                cross_b.transpose() // my share of B's block (k×d_b)
            } else {
                cross_a.transpose() // my share of A's block (k×d_a)
            };
            let num = if party == 0 {
                my_block.hstack(&other_block)
            } else {
                other_block.hstack(&my_block)
            };
            let mut ctx = Ctx::new(chan, &mut store, Prg::new(cfg.seed ^ 0xC7));
            ctx.set_phase("online.s3");
            update::finish_update(&mut ctx, &num, &c_share, &mu)
        };
        steps.s3_update += t0.elapsed().as_secs_f64() - (store.inner().secs - off0);
        mu = mu_new;
    }

    chan.set_phase("reveal");
    let mu_plain = reconstruct(chan, &mu);
    let c_plain = reconstruct(chan, &c_share);
    let assignments = (0..n)
        .map(|i| (0..cfg.k).find(|&j| c_plain.at(i, j) == 1).unwrap_or(0))
        .collect();

    PartyResult {
        step_demands: [
            ppkmeans_default_demand(),
            ppkmeans_default_demand(),
            ppkmeans_default_demand(),
        ],
        mu: mu_plain,
        assignments,
        demand: store.demand.clone(),
        ledger: store.ledger(),
        offline_secs: store.inner().secs,
        wall: t_start.elapsed().as_secs_f64(),
        steps,
        iters,
    }
}

/// Run the sparse-optimized protocol (vertical partitioning only, as in
/// the paper's Alg. 3).
pub fn run(data: &Dataset, cfg: &SecureKmeansConfig) -> Result<SecureKmeansOutput> {
    let Partition::Vertical { d_a } = cfg.partition else {
        return Err(Error::Config("sparse path supports vertical partitioning (Alg. 3)".into()));
    };
    let (xa, xb) = super::secure::split_dataset(data, cfg.partition);
    let (n, d) = (data.n, data.d);
    let pa = SparseParty { x_csr: Csr::from_dense(&xa), x_dense: xa };
    let pb = SparseParty { x_csr: Csr::from_dense(&xb), x_dense: xb };
    let cfg_a = cfg.clone();
    let cfg_b = cfg.clone();
    let ((ra, meter_a), (rb, meter_b)) = run_two_party(
        move |c| party_main(c, pa, n, d, d_a, &cfg_a),
        move |c| party_main(c, pb, n, d, d_a, &cfg_b),
    );
    debug_assert_eq!(ra.mu, rb.mu, "sparse parties disagree");
    let wall_b = rb.wall;
    Ok(ra.into_output(cfg.k, d, meter_a, meter_b, wall_b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::BlobSpec;
    use crate::kmeans::plaintext;

    fn sparse_dataset(n: usize, d: usize, k: usize, sparsity: f64, seed: u128) -> Dataset {
        let mut spec = BlobSpec::new(n, d, k);
        spec.spread = 0.02;
        let mut ds = spec.generate(seed);
        // Zero out entries deterministically to reach the target sparsity.
        let mut prg = Prg::new(seed ^ 0x5EED);
        for v in ds.x.iter_mut() {
            if prg.next_f64() < sparsity {
                *v = 0.0;
            }
        }
        ds
    }

    #[test]
    fn sparse_path_matches_plaintext_kmeans() {
        let ds = sparse_dataset(24, 4, 2, 0.5, 77);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 3,
            sparse: true,
            he_bits: 768,
            partition: Partition::Vertical { d_a: 2 },
            ..Default::default()
        };
        let sec = run(&ds, &cfg).unwrap();
        let plain = plaintext::kmeans(&ds, 2, 3, cfg.seed);
        assert_eq!(sec.assignments, plain.assignments);
        for i in 0..sec.centroids.len() {
            assert!(
                (sec.centroids[i] - plain.centroids[i]).abs() < 1e-2,
                "centroid {i}: {} vs {}",
                sec.centroids[i],
                plain.centroids[i]
            );
        }
    }

    #[test]
    fn pk_serialization_roundtrip() {
        let mut prg = Prg::new(5);
        let (pk, _) = Ou::keygen(384, &mut prg);
        let back = pk_from_bytes(&pk_to_bytes(&pk));
        assert_eq!(back.n, pk.n);
        assert_eq!(back.g, pk.g);
        assert_eq!(back.h, pk.h);
        assert_eq!(back.n_bits, pk.n_bits);
    }
}
