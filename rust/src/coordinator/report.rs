//! Protocol-run reports in the paper's accounting format.
//!
//! Measured quantities: exact bytes/rounds per phase (from the channel
//! meters), compute wall-clock per step, triple-generation time, and the
//! recorded offline [`Demand`]. Derived quantities: online time =
//! compute + modeled link time; offline time/bytes = OT-based generation
//! priced from the demand (see [`crate::offline::pricing`]).

use crate::kmeans::secure::SecureKmeansOutput;
use crate::net::cost::CostModel;
use crate::net::meter::PhaseStats;
use crate::offline::pricing::{self, OtCalibration};

/// One run's costs under a link model.
#[derive(Debug, Clone)]
pub struct Report {
    /// Online wall-clock seconds (compute + modeled link time).
    pub online_secs: f64,
    /// Offline seconds (OT-based triple generation, modeled from demand).
    pub offline_secs: f64,
    /// Online bytes (both parties).
    pub online_bytes: u64,
    /// Offline bytes (both parties, OT generation traffic).
    pub offline_bytes: u64,
    /// Per-step online breakdown (s1, s2, s3) in seconds.
    pub steps: [f64; 3],
    /// Per-step online bytes.
    pub step_bytes: [u64; 3],
}

impl Report {
    /// Build a report from a secure K-means run.
    pub fn from_run(out: &SecureKmeansOutput, link: &CostModel, cal: &OtCalibration) -> Report {
        let phase = |label: &str| -> PhaseStats {
            let mut s = out.meter_a.get(label);
            s.merge(&out.meter_b.get(label));
            s
        };
        let online_stats = {
            let mut s = out.meter_a.total_prefix("online.");
            s.merge(&out.meter_b.total_prefix("online."));
            s
        };
        // Rounds are counted per party; the flight model uses party A's
        // (symmetric exchanges overlap on a full-duplex link).
        let online_rounds = out.meter_a.total_prefix("online.").rounds;
        let link_time =
            link.time_raw(online_stats.bytes_sent / 2, online_rounds);
        let compute =
            out.step_wall.s1_distance + out.step_wall.s2_assign + out.step_wall.s3_update;
        let steps_wall = [
            out.step_wall.s1_distance,
            out.step_wall.s2_assign,
            out.step_wall.s3_update,
        ];
        let step_stats = [phase("online.s1"), phase("online.s2"), phase("online.s3")];
        let mut steps = [0.0; 3];
        let mut step_bytes = [0u64; 3];
        for i in 0..3 {
            let rounds_i = [
                out.meter_a.get("online.s1").rounds,
                out.meter_a.get("online.s2").rounds,
                out.meter_a.get("online.s3").rounds,
            ][i];
            steps[i] = steps_wall[i] + link.time_raw(step_stats[i].bytes_sent / 2, rounds_i);
            step_bytes[i] = step_stats[i].bytes_sent;
        }
        Report {
            online_secs: compute + link_time,
            offline_secs: pricing::offline_secs(&out.demand, cal),
            online_bytes: online_stats.bytes_sent,
            offline_bytes: pricing::offline_bytes(&out.demand),
            steps,
            step_bytes,
        }
    }

    pub fn total_secs(&self) -> f64 {
        self.online_secs + self.offline_secs
    }

    pub fn total_bytes(&self) -> u64 {
        self.online_bytes + self.offline_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::BlobSpec;
    use crate::kmeans::config::{Partition, SecureKmeansConfig};
    use crate::kmeans::secure;

    #[test]
    fn report_has_consistent_totals() {
        let ds = BlobSpec::new(30, 2, 2).generate(4);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 2,
            partition: Partition::Vertical { d_a: 1 },
            ..Default::default()
        };
        let out = secure::run(&ds, &cfg).unwrap();
        let cal = OtCalibration { secs_per_ot: 1e-5, secs_per_bit_lane: 1e-6, setup_secs: 0.5 };
        let r = Report::from_run(&out, &CostModel::wan(), &cal);
        assert!(r.online_secs > 0.0);
        assert!(r.offline_secs > 0.5, "includes setup");
        assert!(r.offline_bytes > r.online_bytes, "offline must dominate comm");
        assert!(r.total_secs() >= r.online_secs);
        assert!(r.steps.iter().all(|&s| s > 0.0));
    }
}
