//! Serving-run reports: per-request latency/throughput under a link
//! model, plus the bank ledger — the serving analogue of [`super::Report`].

use crate::net::cost::CostModel;
use crate::serve::driver::ServeOutput;
use crate::serve::scorer::score_rounds;

/// One serving run's costs under a link model.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Modeled end-to-end latency per batch: measured compute wall plus
    /// `rounds·RTT + bytes/bandwidth` (batch 0 is the demand probe —
    /// its wall includes inline triple generation).
    pub batch_latency_secs: Vec<f64>,
    /// Mean latency over the bank-served batches (probe excluded).
    pub mean_latency_secs: f64,
    /// Worst bank-served batch.
    pub max_latency_secs: f64,
    /// Scored transactions per second at the mean latency.
    pub throughput_rows_per_sec: f64,
    /// Online flights per batch (uniform; == `score_rounds(k)`).
    pub rounds_per_batch: u64,
    /// Mean per-batch online bytes (party 0).
    pub bytes_per_batch: u64,
    /// Matrix-triple bytes of one prefabricated bank batch.
    pub bank_batch_bytes: u64,
    /// Bank ledger (prefabricated, replenished, consumed, remaining).
    pub bank_ledger: [usize; 4],
    /// Replenishment events over the run.
    pub bank_replenish_events: usize,
    /// Checkouts that replenished synchronously on the scoring path —
    /// batches that stalled behind inline fabrication (both parties).
    pub bank_stalls: u64,
}

impl ServeReport {
    /// Summarize a serving run under a link model.
    pub fn from_serve(out: &ServeOutput, link: &CostModel) -> ServeReport {
        let lat: Vec<f64> = out
            .batch_stats
            .iter()
            .map(|b| b.wall_secs + link.time_raw(b.online.bytes_sent, b.online.rounds))
            .collect();
        // Steady-state stats exclude the probe batch when there is one.
        let steady = if lat.len() > 1 { &lat[1..] } else { &lat[..] };
        let mean = steady.iter().sum::<f64>() / steady.len() as f64;
        let max = steady.iter().cloned().fold(0.0f64, f64::max);
        let bytes: u64 = out.batch_stats.iter().map(|b| b.online.bytes_sent).sum::<u64>()
            / out.batch_stats.len() as u64;
        let rounds = out.batch_stats.first().map(|b| b.online.rounds).unwrap_or(0);
        debug_assert_eq!(rounds, score_rounds(out.k), "per-batch budget must be exact");
        ServeReport {
            batch_latency_secs: lat,
            mean_latency_secs: mean,
            max_latency_secs: max,
            throughput_rows_per_sec: out.batch_rows as f64 / mean.max(f64::MIN_POSITIVE),
            rounds_per_batch: rounds,
            bytes_per_batch: bytes,
            bank_batch_bytes: out.per_batch_mat_triple_bytes,
            bank_ledger: [
                out.bank_prefabricated,
                out.bank_replenished,
                out.bank_consumed,
                out.bank_remaining,
            ],
            bank_replenish_events: out.bank_replenish_events,
            bank_stalls: out.bank_stalls,
        }
    }
}

/// Nearest-rank percentile of an unsorted sample (`p` in `[0, 100]`).
/// Deterministic: total order via `f64::total_cmp`, no interpolation.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// One gateway sweep point's costs under a link model — the
/// session-level analogue of [`ServeReport`]: per-session modeled
/// latency percentiles instead of per-batch means, plus the sharded
/// bank's global ledger.
#[derive(Debug, Clone)]
pub struct GatewayReport {
    /// Sessions admitted (scored to completion).
    pub admitted: usize,
    /// Sessions refused at admission (queue bound).
    pub rejected: usize,
    /// Modeled end-to-end latency per admitted session: measured wall
    /// plus `rounds·RTT + bytes/bandwidth` of that session's own meter.
    pub session_latency_secs: Vec<f64>,
    /// Nearest-rank p50 of the per-session latencies.
    pub p50_latency_secs: f64,
    /// Nearest-rank p99 of the per-session latencies.
    pub p99_latency_secs: f64,
    /// Worst session.
    pub max_latency_secs: f64,
    /// Scored transactions per second over the whole run's measured
    /// wall-clock (party 0) — concurrency shows up here.
    pub throughput_rows_per_sec: f64,
    /// Sum of all per-session online bytes (== the link's
    /// `gateway.mux` bytes; the invariant is regression-tested).
    pub session_bytes: u64,
    /// Bank ledger `[prefabricated, replenished, consumed, stock]`.
    pub bank_ledger: [u64; 4],
    /// Checkouts that found their kit not ready (waited or fabricated
    /// inline on the scoring path).
    pub bank_stalls: u64,
    /// Offline-store draws that missed kit stock (0 at steady state).
    pub bank_misses: u64,
    /// Measured wall-clock of the whole gateway run (party 0).
    pub wall_secs: f64,
}

impl GatewayReport {
    /// Summarize one party's gateway run under a link model. Sessions
    /// that aborted (typed overload) are excluded from latency stats
    /// but still counted in `admitted`.
    pub fn from_gateway(
        out: &crate::serve::gateway::GatewayOutput,
        batch_rows: usize,
        link: &CostModel,
    ) -> GatewayReport {
        let reports: Vec<_> =
            out.sessions.iter().filter_map(|(_, r)| r.as_ref().ok()).collect();
        let lat: Vec<f64> = reports
            .iter()
            .map(|s| s.wall_secs + link.time_raw(s.online.bytes_sent, s.online.rounds))
            .collect();
        let rows: usize = reports.iter().map(|s| s.results.len() * batch_rows).sum();
        GatewayReport {
            admitted: out.admitted(),
            rejected: out.rejected.len(),
            p50_latency_secs: percentile(&lat, 50.0),
            p99_latency_secs: percentile(&lat, 99.0),
            max_latency_secs: lat.iter().cloned().fold(0.0f64, f64::max),
            throughput_rows_per_sec: rows as f64 / out.wall_secs.max(f64::MIN_POSITIVE),
            session_bytes: reports.iter().map(|s| s.online.bytes_sent).sum(),
            bank_ledger: [
                out.ledger.prefabricated,
                out.ledger.replenished,
                out.ledger.consumed,
                out.ledger.stock,
            ],
            bank_stalls: out.ledger.stalls,
            bank_misses: out.misses(),
            wall_secs: out.wall_secs,
            session_latency_secs: lat,
        }
    }
}

/// The `BENCH_gateway.json` payload shared by the CLI driver and the
/// `gateway` bench target: one entry per `(sessions, link)` sweep
/// point.
pub fn gateway_bench_json(
    k: usize,
    batch_rows: usize,
    batches: usize,
    sweeps: &[(String, usize, GatewayReport)],
) -> String {
    let mut json = String::from("{\n  \"bench\": \"gateway\",\n");
    json.push_str(&format!(
        "  \"config\": {{\"k\": {k}, \"batch_rows\": {batch_rows}, \"batches\": {batches}}},\n"
    ));
    json.push_str("  \"sweeps\": [\n");
    for (i, (link, sessions, r)) in sweeps.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"link\": \"{link}\", \"sessions\": {sessions}, \"admitted\": {}, \
             \"rejected\": {}, \"throughput_rows_per_sec\": {:.1}, \
             \"p50_latency_secs\": {:.6}, \"p99_latency_secs\": {:.6}, \
             \"max_latency_secs\": {:.6}, \"session_bytes\": {}, \
             \"bank\": {{\"prefabricated\": {}, \"replenished\": {}, \"consumed\": {}, \
             \"stock\": {}, \"stalls\": {}, \"misses\": {}}}}}{}\n",
            r.admitted,
            r.rejected,
            r.throughput_rows_per_sec,
            r.p50_latency_secs,
            r.p99_latency_secs,
            r.max_latency_secs,
            r.session_bytes,
            r.bank_ledger[0],
            r.bank_ledger[1],
            r.bank_ledger[2],
            r.bank_ledger[3],
            r.bank_stalls,
            r.bank_misses,
            if i + 1 < sweeps.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    json
}

/// The `BENCH_serving.json` payload shared by the CLI driver and the
/// `serving` bench target.
pub fn serving_bench_json(
    out: &ServeOutput,
    lan: &ServeReport,
    wan: &ServeReport,
    train_secs: f64,
) -> String {
    let mut json = String::from("{\n  \"bench\": \"serving\",\n");
    json.push_str(&format!(
        "  \"config\": {{\"k\": {}, \"batch_rows\": {}, \"batches\": {}}},\n",
        out.k,
        out.batch_rows,
        out.batch_stats.len()
    ));
    json.push_str(&format!("  \"train_secs\": {train_secs:.6},\n"));
    json.push_str(&format!(
        "  \"per_batch\": {{\"rounds\": {}, \"bytes\": {}, \"mat_triple_bytes\": {}}},\n",
        lan.rounds_per_batch, lan.bytes_per_batch, lan.bank_batch_bytes
    ));
    json.push_str(&format!(
        "  \"bank\": {{\"prefabricated\": {}, \"replenished\": {}, \"consumed\": {}, \
         \"remaining\": {}, \"replenish_events\": {}, \"stalls\": {}, \"misses\": {}}},\n",
        out.bank_prefabricated,
        out.bank_replenished,
        out.bank_consumed,
        out.bank_remaining,
        out.bank_replenish_events,
        out.bank_stalls,
        out.bank_misses
    ));
    json.push_str(&format!(
        "  \"lan\": {{\"mean_latency_secs\": {:.6}, \"max_latency_secs\": {:.6}, \
         \"throughput_rows_per_sec\": {:.1}}},\n",
        lan.mean_latency_secs, lan.max_latency_secs, lan.throughput_rows_per_sec
    ));
    json.push_str(&format!(
        "  \"wan\": {{\"mean_latency_secs\": {:.6}, \"max_latency_secs\": {:.6}, \
         \"throughput_rows_per_sec\": {:.1}}}\n",
        wan.mean_latency_secs, wan.max_latency_secs, wan.throughput_rows_per_sec
    ));
    json.push_str("}\n");
    json
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::BlobSpec;
    use crate::kmeans::config::{Partition, SecureKmeansConfig};
    use crate::offline::bank::BankConfig;
    use crate::serve::driver::{serve_stream, train_model, ServeConfig};

    #[test]
    fn serve_report_summarizes_a_run() {
        let mut spec = BlobSpec::new(60, 4, 2);
        spec.spread = 0.02;
        let train = spec.generate(5);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 3,
            partition: Partition::Vertical { d_a: 2 },
            ..Default::default()
        };
        let (_, models) = train_model(&train, &cfg, 0.05).unwrap();
        let stream = spec.generate(6);
        let scfg = ServeConfig {
            batch_rows: 10,
            batches: 4,
            bank: BankConfig { prefab_batches: 2, low_water: 1, refill_batches: 2 },
            seed: 0xF00D,
            ..Default::default()
        };
        let out = serve_stream(models, &stream, &scfg).unwrap();
        let lan = ServeReport::from_serve(&out, &CostModel::lan());
        let wan = ServeReport::from_serve(&out, &CostModel::wan());
        assert_eq!(lan.batch_latency_secs.len(), 4);
        assert_eq!(lan.rounds_per_batch, score_rounds(2));
        assert!(lan.mean_latency_secs > 0.0);
        assert!(wan.mean_latency_secs > lan.mean_latency_secs, "WAN RTT must dominate");
        assert!(lan.throughput_rows_per_sec > 0.0);
        assert_eq!(lan.bank_ledger[2], 3, "3 bank-served batches");
        let json = serving_bench_json(&out, &lan, &wan, 0.5);
        assert!(json.contains("\"bench\": \"serving\""));
        assert!(json.contains("\"bank\""));
        assert!(json.contains("\"stalls\""));
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let s = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&s, 50.0), 2.0);
        assert_eq!(percentile(&s, 99.0), 4.0);
        assert_eq!(percentile(&s, 100.0), 4.0);
        assert_eq!(percentile(&s, 0.0), 1.0, "p0 clamps to the minimum");
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.5], 99.0), 7.5);
    }
}
