//! Two-process deployment: scenario files, the wire handshake, phase
//! barriers, and the per-party pipeline runner.
//!
//! A *scenario* is a small `key = value` text file that pins everything
//! two independent processes must agree on to run a pipeline together:
//! which pipeline (`train` / `serve` / `score` / `fraud` / `gateway`),
//! the dataset
//! generation seeds, the clustering geometry, tiling/threads, and the
//! optional link shaping. Both processes load (what should be) the same
//! scenario; the [`handshake`] then verifies magic, wire version,
//! complementary roles, the scenario digest and the protocol seed
//! before a single protocol byte is exchanged — a mismatch is a typed
//! [`Error::Protocol`] naming the differing lines, never garbage
//! shares.
//!
//! [`run_scenario`] drives **one party's** side of the chosen pipeline
//! over any connected [`Chan`] (in-process duplex or TCP) and returns a
//! [`PartyTranscript`]: hashes of every revealed value plus the exact
//! per-phase flight/byte counts, with wall-clock deliberately excluded.
//! Transcripts are **transport-independent by construction** — the CI
//! `two-process` job diffs the JSON from two OS processes over
//! localhost TCP against the in-process reference and requires
//! byte-identical files.
//!
//! The wire format (frame layout, handshake words, barrier tags) is
//! documented in `docs/PROTOCOLS.md`.

use crate::data::blobs::{BlobSpec, Dataset};
use crate::data::{fraud_gen, normalize, sparse_gen};
use crate::fraud::{detect_outliers, jaccard, OutlierConfig};
use crate::kmeans::config::{EsdMode, Partition, SecureKmeansConfig, TileFlights};
use crate::kmeans::secure;
use crate::net::cost::CostModel;
use crate::net::fault::{FaultMode, FaultPlan};
use crate::net::meter::{Meter, PhaseStats};
use crate::net::{Chan, Security};
use crate::offline::bank::BankConfig;
use crate::resume::{Checkpoint, MeterSnapshot, Payload, ResumeCtx, ServeState, TrainState};
use crate::runtime::pool::Parallelism;
use crate::runtime::simd::Lanes;
use crate::serve::driver::{serve_party_ckpt, train_model_party_ckpt, ServeConfig};
use crate::serve::gateway::{gateway_party, GatewayConfig, SessionWorkload};
use crate::serve::model::TrainedModel;
use crate::util::error::{Error, Result};
use crate::util::hash::{hash256, Hash256};
use std::path::{Path, PathBuf};

/// Handshake magic: the ASCII bytes `PPKMWRE1`.
pub const WIRE_MAGIC: u64 = u64::from_be_bytes(*b"PPKMWRE1");
/// Version of the deployment wire protocol (handshake + barriers).
/// Version 2 added the resume leg: a tenth hello word advertising the
/// sender's highest on-disk checkpoint ordinal, plus a conditional
/// confirm-digest exchange when the negotiated common ordinal is > 0.
pub const WIRE_VERSION: u64 = 2;

/// Which pipeline a scenario runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pipeline {
    /// Secure training on generated blob (or sparse) data.
    Train,
    /// Train on fraud-shaped data, then score a transaction stream.
    Serve,
    /// Load persisted model shares and score a fresh stream.
    Score,
    /// Train on fraud-shaped data, then run outlier detection + Jaccard.
    Fraud,
    /// Train on fraud-shaped data, then score many concurrent sessions
    /// through the mux gateway ([`crate::serve::gateway`]).
    Gateway,
}

impl Pipeline {
    /// Canonical scenario-file spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            Pipeline::Train => "train",
            Pipeline::Serve => "serve",
            Pipeline::Score => "score",
            Pipeline::Fraud => "fraud",
            Pipeline::Gateway => "gateway",
        }
    }

    fn parse(s: &str) -> Result<Pipeline> {
        Ok(match s {
            "train" => Pipeline::Train,
            "serve" => Pipeline::Serve,
            "score" => Pipeline::Score,
            "fraud" => Pipeline::Fraud,
            "gateway" => Pipeline::Gateway,
            other => {
                return Err(Error::Config(format!(
                    "scenario: unknown pipeline {other:?} (train|serve|score|fraud|gateway)"
                )))
            }
        })
    }
}

/// Link shaping named by a scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// No shaping: loopback at native speed.
    Unshaped,
    /// The paper's LAN (10 Gbps, 0.02 ms RTT).
    Lan,
    /// The paper's WAN (20 Mbps, 40 ms RTT).
    Wan,
}

impl LinkKind {
    /// Canonical scenario-file spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            LinkKind::Unshaped => "none",
            LinkKind::Lan => "lan",
            LinkKind::Wan => "wan",
        }
    }

    /// The cost model to enforce, if any.
    pub fn model(&self) -> Option<CostModel> {
        match self {
            LinkKind::Unshaped => None,
            LinkKind::Lan => Some(CostModel::lan()),
            LinkKind::Wan => Some(CostModel::wan()),
        }
    }

    fn parse(s: &str) -> Result<LinkKind> {
        Ok(match s {
            "none" => LinkKind::Unshaped,
            "lan" => LinkKind::Lan,
            "wan" => LinkKind::Wan,
            other => {
                return Err(Error::Config(format!(
                    "scenario: unknown shape {other:?} (none|lan|wan)"
                )))
            }
        })
    }
}

/// Partition kind named by a scenario (the split point is derived).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartKind {
    /// Feature split.
    Vertical,
    /// Sample split.
    Horizontal,
}

/// Everything two party processes must agree on to run a pipeline.
///
/// Parsed from a `key = value` file (`#` starts a comment; unknown keys
/// are errors so typos cannot silently desynchronize the parties). The
/// [`Scenario::canonical`] rendering — every key, fixed order, parsed
/// values — is what the [`handshake`] digests, so two files that parse
/// to the same effective configuration always agree.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Which pipeline to run.
    pub pipeline: Pipeline,
    /// Samples (train pipelines) / training transactions (serve).
    pub n: usize,
    /// Features for generated blob/sparse data (fraud data is 18+24).
    pub d: usize,
    /// Clusters.
    pub k: usize,
    /// Lloyd iterations.
    pub iters: usize,
    /// Protocol seed (dealers, mask PRGs) — confirmed by the handshake.
    pub seed: u128,
    /// Dataset generation seed.
    pub data_seed: u128,
    /// Scored-stream generation seed (serve/score).
    pub stream_seed: u128,
    /// Partition kind for the `train` pipeline (fraud-shaped pipelines
    /// always split vertically at the payment/merchant boundary).
    pub partition: PartKind,
    /// Vertical split point; 0 = `d/2`.
    pub d_a: usize,
    /// Horizontal split point; 0 = `n/2`.
    pub n_a: usize,
    /// Cross-product backend selection.
    pub esd: EsdMode,
    /// Adversary model (scenario key `security`). Protocol-relevant and
    /// digested: a semi-honest party talking to a malicious-tier peer
    /// would desync on the very first MAC barrier, so the handshake
    /// must refuse the pairing up front.
    pub security: Security,
    /// Generate sparse training data; also routes the cross products
    /// through HE Protocol 2 when `esd` is left at its default
    /// (mirroring the retired `SecureKmeansConfig::sparse` fold).
    pub sparse: bool,
    /// Zero fraction for generated sparse data.
    pub sparsity: f64,
    /// Row-tile size; 0 = monolithic.
    pub tile_rows: usize,
    /// Tile flight policy.
    pub tile_flights: TileFlights,
    /// Worker threads per party (0 = one per core). Party-local:
    /// excluded from the handshake digest — outputs and meters are
    /// thread-count invariant, so the parties may differ.
    pub threads: usize,
    /// Packed-lane width per party (0 = auto/widest). Party-local like
    /// `threads` and likewise excluded from the digest: lane width is
    /// transcript-invariant by the [`crate::runtime::simd`] contract.
    pub lanes: usize,
    /// Deterministic link shaping for the whole pipeline.
    pub shape: LinkKind,
    /// Fraud/flag rate.
    pub rate: f64,
    /// Transactions per scored micro-batch.
    pub batch_rows: usize,
    /// Micro-batches to score (first is the demand probe).
    pub batches: usize,
    /// Bank batches fabricated up front.
    pub prefab: usize,
    /// Replenish below this stock.
    pub low_water: usize,
    /// Batches per replenishment.
    pub refill: usize,
    /// Refresh the served centroids from recent scored traffic every
    /// this many batches, 0 = never (scenario key `refresh.every`).
    /// Protocol-relevant: a refresh changes the model both parties
    /// score with, so it is digested.
    pub refresh_every: usize,
    /// Blend factor of a centroid refresh — `new = old + α·(recent −
    /// old)` (scenario key `refresh.alpha`). Digested like
    /// `refresh.every`.
    pub refresh_alpha: f64,
    /// Inject a fault at this flight-opening send, 0 = none (scenario
    /// key `fault.flight`). Party-local and deliberately excluded from
    /// the digest: a fault plan models a crash, and crashing hosts do
    /// not coordinate with their peer first.
    pub fault_flight: u64,
    /// What the injected fault does (scenario key `fault.mode`).
    /// Party-local like `fault.flight`.
    pub fault_mode: FaultMode,
    /// Which party arms the fault plan (scenario key `fault.party`).
    /// Party-local like `fault.flight`.
    pub fault_party: usize,
    /// Barrier-checkpoint directory, empty = checkpointing off
    /// (scenario key `ckpt_dir`). Party-local: each party keeps its own
    /// snapshots on its own disk; the handshake negotiates the common
    /// resume point at runtime instead.
    pub ckpt_dir: String,
    /// Concurrent sessions of the `gateway` pipeline (scenario key
    /// `gateway.sessions`).
    pub sessions: usize,
    /// Gateway admission queue bound, 0 = unbounded (scenario key
    /// `gateway.queue`): sessions beyond it are refused with a typed
    /// overload on **both** parties.
    pub queue: usize,
    /// Gateway scoring workers per party (scenario key
    /// `gateway.workers`). Party-local like `threads` — per-session
    /// transcripts are worker-count invariant (regression-tested), so
    /// it is excluded from the handshake digest.
    pub gateway_workers: usize,
    /// Where model shares are saved/loaded (`party{0,1}.ppkmodel`).
    /// Party-local: excluded from the handshake digest.
    pub model_dir: String,
    /// Whether the serve pipeline persists this party's share.
    /// Party-local: excluded from the handshake digest.
    pub save_model: bool,
}

impl Default for Scenario {
    fn default() -> Scenario {
        Scenario {
            pipeline: Pipeline::Train,
            n: 1000,
            d: 4,
            k: 3,
            iters: 10,
            seed: 0xBEEF,
            data_seed: 42,
            stream_seed: 4242,
            partition: PartKind::Vertical,
            d_a: 0,
            n_a: 0,
            esd: EsdMode::Vectorized,
            security: Security::SemiHonest,
            sparse: false,
            sparsity: 0.5,
            tile_rows: 0,
            tile_flights: TileFlights::Lockstep,
            threads: 1,
            lanes: 1,
            shape: LinkKind::Unshaped,
            rate: 0.05,
            batch_rows: 64,
            batches: 12,
            prefab: 8,
            low_water: 2,
            refill: 4,
            refresh_every: 0,
            refresh_alpha: 0.25,
            fault_flight: 0,
            fault_mode: FaultMode::Kill,
            fault_party: 0,
            ckpt_dir: String::new(),
            sessions: 4,
            queue: 0,
            gateway_workers: 2,
            model_dir: "model".into(),
            save_model: false,
        }
    }
}

fn want_usize(key: &str, val: &str) -> Result<usize> {
    val.parse()
        .map_err(|_| Error::Config(format!("scenario: {key} wants an integer, got {val:?}")))
}

fn want_u128(key: &str, val: &str) -> Result<u128> {
    val.parse()
        .map_err(|_| Error::Config(format!("scenario: {key} wants an integer, got {val:?}")))
}

fn want_f64(key: &str, val: &str) -> Result<f64> {
    val.parse()
        .map_err(|_| Error::Config(format!("scenario: {key} wants a number, got {val:?}")))
}

fn want_bool(key: &str, val: &str) -> Result<bool> {
    match val {
        "true" => Ok(true),
        "false" => Ok(false),
        _ => Err(Error::Config(format!("scenario: {key} wants true|false, got {val:?}"))),
    }
}

impl Scenario {
    /// Parse scenario text (`key = value` lines, `#` comments). Unknown
    /// keys and malformed values are errors — a typo must fail loudly,
    /// not run a subtly different protocol on one side.
    pub fn parse(text: &str) -> Result<Scenario> {
        let mut sc = Scenario::default();
        for (idx, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| {
                let lineno = idx + 1;
                Error::Config(format!("scenario line {lineno}: expected `key = value`, got {raw:?}"))
            })?;
            let (key, val) = (key.trim(), val.trim());
            match key {
                "pipeline" => sc.pipeline = Pipeline::parse(val)?,
                "n" => sc.n = want_usize(key, val)?,
                "d" => sc.d = want_usize(key, val)?,
                "k" => sc.k = want_usize(key, val)?,
                "iters" => sc.iters = want_usize(key, val)?,
                "seed" => sc.seed = want_u128(key, val)?,
                "data_seed" => sc.data_seed = want_u128(key, val)?,
                "stream_seed" => sc.stream_seed = want_u128(key, val)?,
                "partition" => {
                    sc.partition = match val {
                        "vertical" => PartKind::Vertical,
                        "horizontal" => PartKind::Horizontal,
                        other => {
                            return Err(Error::Config(format!(
                                "scenario: unknown partition {other:?} (vertical|horizontal)"
                            )))
                        }
                    }
                }
                "d_a" => sc.d_a = want_usize(key, val)?,
                "n_a" => sc.n_a = want_usize(key, val)?,
                "esd" => {
                    sc.esd = match val {
                        "vectorized" => EsdMode::Vectorized,
                        "naive" => EsdMode::Naive,
                        "he" => EsdMode::he(),
                        "auto" => EsdMode::Auto,
                        other => {
                            return Err(Error::Config(format!(
                                "scenario: unknown esd {other:?} (vectorized|naive|he|auto)"
                            )))
                        }
                    }
                }
                "security" => sc.security = Security::parse(val)?,
                "sparse" => sc.sparse = want_bool(key, val)?,
                "sparsity" => sc.sparsity = want_f64(key, val)?,
                "tile_rows" => sc.tile_rows = want_usize(key, val)?,
                "tile_flights" => {
                    sc.tile_flights = match val {
                        "lockstep" => TileFlights::Lockstep,
                        "streamed" => TileFlights::Streamed,
                        other => {
                            return Err(Error::Config(format!(
                                "scenario: unknown tile_flights {other:?} (lockstep|streamed)"
                            )))
                        }
                    }
                }
                "threads" => sc.threads = want_usize(key, val)?,
                "lanes" => sc.lanes = want_usize(key, val)?,
                "shape" => sc.shape = LinkKind::parse(val)?,
                "rate" => sc.rate = want_f64(key, val)?,
                "batch_rows" => sc.batch_rows = want_usize(key, val)?,
                "batches" => sc.batches = want_usize(key, val)?,
                "prefab" => sc.prefab = want_usize(key, val)?,
                "low_water" => sc.low_water = want_usize(key, val)?,
                "refill" => sc.refill = want_usize(key, val)?,
                "refresh.every" => sc.refresh_every = want_usize(key, val)?,
                "refresh.alpha" => sc.refresh_alpha = want_f64(key, val)?,
                "fault.flight" => sc.fault_flight = want_usize(key, val)? as u64,
                "fault.mode" => sc.fault_mode = FaultMode::parse(val)?,
                "fault.party" => {
                    sc.fault_party = match want_usize(key, val)? {
                        p @ (0 | 1) => p,
                        other => {
                            return Err(Error::Config(format!(
                                "scenario: fault.party wants 0|1, got {other}"
                            )))
                        }
                    }
                }
                "ckpt_dir" => sc.ckpt_dir = val.to_string(),
                "gateway.sessions" => sc.sessions = want_usize(key, val)?,
                "gateway.queue" => sc.queue = want_usize(key, val)?,
                "gateway.workers" => sc.gateway_workers = want_usize(key, val)?,
                "model_dir" => sc.model_dir = val.to_string(),
                "save_model" => sc.save_model = want_bool(key, val)?,
                other => {
                    return Err(Error::Config(format!("scenario: unknown key {other:?}")))
                }
            }
        }
        Ok(sc)
    }

    /// Load a scenario file.
    pub fn from_file(path: &Path) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read scenario {}: {e}", path.display())))?;
        Scenario::parse(&text)
    }

    /// The canonical rendering the handshake digests: every
    /// **protocol-relevant** key in a fixed order with the *parsed*
    /// value, so formatting, comments and omitted-default keys never
    /// cause false mismatches. Party-local operational knobs —
    /// `threads`, `lanes`, `model_dir`, `save_model`, `ckpt_dir` and
    /// the `fault.*` injection keys — are deliberately excluded: they
    /// cannot affect outputs or meters (thread-count and lane-width
    /// invariance are regression-tested; a fault merely truncates a
    /// run, and checkpoint state is negotiated live by the handshake),
    /// so heterogeneous deployments (different core counts, different
    /// SIMD widths, different disk layouts) must handshake cleanly.
    pub fn canonical(&self) -> String {
        let esd = match self.esd {
            EsdMode::Vectorized => "vectorized",
            EsdMode::Naive => "naive",
            EsdMode::He { .. } => "he",
            EsdMode::Auto => "auto",
        };
        let flights = match self.tile_flights {
            TileFlights::Lockstep => "lockstep",
            TileFlights::Streamed => "streamed",
        };
        let partition = match self.partition {
            PartKind::Vertical => "vertical",
            PartKind::Horizontal => "horizontal",
        };
        let mut s = String::new();
        for (key, val) in [
            ("batch_rows", self.batch_rows.to_string()),
            ("batches", self.batches.to_string()),
            ("d", self.d.to_string()),
            ("d_a", self.d_a.to_string()),
            ("data_seed", self.data_seed.to_string()),
            ("esd", esd.to_string()),
            ("gateway.queue", self.queue.to_string()),
            ("gateway.sessions", self.sessions.to_string()),
            ("iters", self.iters.to_string()),
            ("k", self.k.to_string()),
            ("low_water", self.low_water.to_string()),
            ("n", self.n.to_string()),
            ("n_a", self.n_a.to_string()),
            ("pipeline", self.pipeline.as_str().to_string()),
            ("prefab", self.prefab.to_string()),
            ("rate", self.rate.to_string()),
            ("refill", self.refill.to_string()),
            ("refresh.alpha", self.refresh_alpha.to_string()),
            ("refresh.every", self.refresh_every.to_string()),
            ("security", self.security.as_str().to_string()),
            ("seed", self.seed.to_string()),
            ("shape", self.shape.as_str().to_string()),
            ("sparse", self.sparse.to_string()),
            ("sparsity", self.sparsity.to_string()),
            ("stream_seed", self.stream_seed.to_string()),
            ("tile_flights", flights.to_string()),
            ("tile_rows", self.tile_rows.to_string()),
        ] {
            s.push_str(key);
            s.push_str(" = ");
            s.push_str(&val);
            s.push('\n');
        }
        s
    }

    /// SHA-like digest of [`Scenario::canonical`] (the in-repo
    /// [`hash256`]).
    pub fn digest(&self) -> [u8; 32] {
        hash256(self.canonical().as_bytes())
    }

    /// The partition of the `train` pipeline (0 split points default to
    /// even splits).
    pub fn train_partition(&self) -> Partition {
        match self.partition {
            PartKind::Vertical => Partition::Vertical {
                d_a: if self.d_a > 0 { self.d_a } else { (self.d / 2).max(1) },
            },
            PartKind::Horizontal => Partition::Horizontal {
                n_a: if self.n_a > 0 { self.n_a } else { (self.n / 2).max(1) },
            },
        }
    }

    /// The secure-kmeans configuration this scenario pins, for a given
    /// partition.
    pub fn kmeans_config(&self, partition: Partition) -> SecureKmeansConfig {
        SecureKmeansConfig {
            k: self.k,
            iters: self.iters,
            seed: self.seed,
            partition,
            // The legacy `sparse` scenario key keeps its old protocol
            // meaning: with the default backend it routes the cross
            // products through HE Protocol 2 (an explicit esd wins).
            esd: if self.sparse && self.esd == EsdMode::Vectorized {
                EsdMode::he()
            } else {
                self.esd
            },
            security: self.security,
            tile_rows: if self.tile_rows > 0 { Some(self.tile_rows) } else { None },
            tile_flights: self.tile_flights,
            parallelism: self.parallelism(),
            lanes: self.lanes_knob(),
            shape: self.shape.model(),
            ..Default::default()
        }
    }

    /// The serving configuration this scenario pins. The serving-phase
    /// seed is derived from the protocol seed (`seed ^ 0x5E11E`),
    /// mirroring the CLI's fixed serving seed.
    pub fn serve_config(&self) -> ServeConfig {
        ServeConfig {
            batch_rows: self.batch_rows,
            batches: self.batches,
            bank: BankConfig {
                prefab_batches: self.prefab,
                low_water: self.low_water,
                refill_batches: self.refill,
            },
            seed: self.seed ^ 0x5E11E,
            parallelism: self.parallelism(),
            lanes: self.lanes_knob(),
            shape: self.shape.model(),
            refresh_every: self.refresh_every,
            refresh_alpha: self.refresh_alpha,
            security: self.security,
        }
    }

    /// The gateway configuration this scenario pins. The gateway seed
    /// derives from the protocol seed like [`Scenario::serve_config`]'s
    /// (distinct constant, so gateway and serve material never alias);
    /// shard and replenisher counts follow the party-local worker knob.
    pub fn gateway_config(&self) -> GatewayConfig {
        let workers = self.gateway_workers.max(1);
        GatewayConfig {
            sessions: self.sessions,
            queue: self.queue,
            workers,
            replenishers: 1,
            shards: workers,
            batch_rows: self.batch_rows,
            batches: self.batches,
            bank: BankConfig {
                prefab_batches: self.prefab,
                low_water: self.low_water,
                refill_batches: self.refill,
            },
            seed: self.seed ^ 0x6A7E1,
            parallelism: self.parallelism(),
            lanes: self.lanes_knob(),
            shape: self.shape.model(),
            refresh_every: self.refresh_every,
            refresh_alpha: self.refresh_alpha,
            security: self.security,
        }
    }

    fn parallelism(&self) -> Parallelism {
        if self.threads == 0 {
            Parallelism::auto()
        } else {
            Parallelism::new(self.threads)
        }
    }

    fn lanes_knob(&self) -> Lanes {
        if self.lanes == 0 {
            Lanes::auto()
        } else {
            Lanes::new(self.lanes)
        }
    }

    /// Generated training data for the `train` pipeline.
    pub fn train_dataset(&self) -> Dataset {
        if self.sparse {
            sparse_gen::generate(self.n, self.d, self.k, self.sparsity, self.data_seed)
        } else {
            BlobSpec::new(self.n, self.d, self.k).generate(self.data_seed)
        }
    }
}

// ---- Handshake & barriers ------------------------------------------------

fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

fn digest_words(words: &[u8; 32]) -> [u64; 4] {
    let mut out = [0u64; 4];
    for (i, chunk) in words.chunks_exact(8).enumerate() {
        out[i] = u64::from_le_bytes(chunk.try_into().unwrap());
    }
    out
}

/// Line-by-line diff of two canonical scenario renderings, for the
/// handshake's mismatch error.
fn canonical_diff(ours: &str, theirs: &str) -> String {
    let o: Vec<&str> = ours.lines().collect();
    let t: Vec<&str> = theirs.lines().collect();
    let mut out = String::new();
    for i in 0..o.len().max(t.len()) {
        let a = o.get(i).copied().unwrap_or("<missing>");
        let b = t.get(i).copied().unwrap_or("<missing>");
        if a != b {
            out.push_str(&format!("  ours: {a}  |  theirs: {b}\n"));
        }
    }
    out
}

/// Verify magic, wire version, complementary roles, the scenario digest
/// and the protocol seed with the peer — one symmetric exchange, plus a
/// second exchange of the canonical scenario text only on mismatch (so
/// the error can name the differing lines). Metered under `handshake`.
///
/// Equivalent to [`handshake_resume`] with a disabled [`ResumeCtx`]:
/// the hello still carries the (zero) checkpoint-ordinal word, so v2
/// endpoints with and without checkpointing interoperate.
pub fn handshake(chan: &mut Chan, sc: &Scenario) -> Result<()> {
    handshake_resume(chan, sc, &mut ResumeCtx::disabled()).map(|_| ())
}

/// The v2 handshake with the resume leg: verify magic, version, roles,
/// scenario digest and seed exactly like [`handshake`], then negotiate
/// the resume point. Word 9 of the hello advertises this party's
/// highest valid on-disk checkpoint ordinal (0 = none); the common
/// point is the **minimum** of the two advertisements. When it is
/// positive, both parties load that checkpoint into `rctx` and trade
/// its confirm digest (scenario ⊕ ordinal ⊕ site label) in one extra
/// symmetric flight — holding *different* snapshots at the same ordinal
/// is a typed [`Error::Protocol`] ("divergent checkpoints"), as is a
/// missing file this party itself advertised (a checkpoint gap).
/// Returns the negotiated common ordinal.
pub fn handshake_resume(chan: &mut Chan, sc: &Scenario, rctx: &mut ResumeCtx) -> Result<u32> {
    chan.set_phase("handshake");
    let digest = digest_words(&sc.digest());
    let max_ordinal = rctx.max_ordinal();
    let mut hello = vec![WIRE_MAGIC, WIRE_VERSION, chan.party as u64];
    hello.extend_from_slice(&digest);
    hello.push(sc.seed as u64);
    hello.push((sc.seed >> 64) as u64);
    hello.push(max_ordinal as u64);
    let theirs = chan.try_exchange_u64s(&hello)?;
    // Magic and version are diagnosed before the exact length so a
    // future version that extends the hello is reported as a version
    // mismatch, not as "not a ppkmeans party".
    if theirs.first() != Some(&WIRE_MAGIC) {
        return Err(Error::Protocol(
            "handshake: peer is not a ppkmeans party (bad magic)".into(),
        ));
    }
    if theirs.get(1) != Some(&WIRE_VERSION) {
        return Err(Error::Protocol(format!(
            "handshake: wire version mismatch (ours {WIRE_VERSION}, peer {:?})",
            theirs.get(1)
        )));
    }
    if theirs.len() != hello.len() {
        return Err(Error::Protocol(format!(
            "handshake: malformed hello of {} words (expected {})",
            theirs.len(),
            hello.len()
        )));
    }
    let want_role = 1 - chan.party as u64;
    if theirs[2] != want_role {
        return Err(Error::Protocol(format!(
            "handshake: both endpoints claim role p{} — check --role/--listen/--connect",
            chan.party
        )));
    }
    if theirs[3..7] != digest[..] {
        // Trade canonical texts so the error names what differs. Both
        // sides take this branch (they compare the same digest pair), so
        // the extra exchange stays symmetric.
        let ours = sc.canonical();
        let peer = chan.try_exchange_bytes(ours.as_bytes())?;
        let peer = String::from_utf8_lossy(&peer);
        return Err(Error::Protocol(format!(
            "handshake: scenario mismatch — the parties would run different \
             protocols. Differing keys:\n{}",
            canonical_diff(&ours, &peer)
        )));
    }
    // Defense-in-depth, normally unreachable: the seed is already part
    // of the digested canonical scenario, but hash256 is an in-repo
    // Speck-based construction rather than a vetted SHA-2, and the seed
    // is the one value whose silent divergence corrupts every share —
    // so it is also confirmed in plaintext.
    if theirs[7] != hello[7] || theirs[8] != hello[8] {
        return Err(Error::Protocol(format!(
            "handshake: protocol seed mismatch (ours {}, peer {})",
            sc.seed,
            ((theirs[8] as u128) << 64) | (theirs[7] as u128)
        )));
    }
    // The resume leg: settle on the highest checkpoint BOTH parties
    // hold, then prove the snapshots match before restoring a byte.
    let common = (max_ordinal as u64).min(theirs[9]) as u32;
    if common > 0 {
        let confirm = rctx.load(common)?.confirm_digest();
        let words = digest_words(&confirm);
        let peer = chan.try_exchange_u64s(&words)?;
        if peer.len() != words.len() || peer[..] != words[..] {
            return Err(Error::Protocol(format!(
                "handshake: divergent checkpoints at ordinal {common} — the parties hold \
                 different snapshots of this scenario; clear both checkpoint directories \
                 and rerun from scratch"
            )));
        }
    }
    Ok(common)
}

/// A named phase barrier: both parties exchange a tag derived from
/// `label` and verify they sit at the same pipeline point. One flight,
/// metered under `barrier`; a mismatch (one side skipped a phase, or
/// the peers run different pipelines) is a typed error instead of
/// protocol garbage.
pub fn barrier(chan: &mut Chan, label: &str) -> Result<()> {
    chan.set_phase("barrier");
    let tag = u64::from_le_bytes(hash256(label.as_bytes())[..8].try_into().unwrap());
    let msg = [WIRE_MAGIC, tag];
    let theirs = chan.try_exchange_u64s(&msg)?;
    if theirs != msg {
        return Err(Error::Protocol(format!(
            "barrier {label:?}: peers desynchronized (got {theirs:?})"
        )));
    }
    Ok(())
}

// ---- Transcripts ---------------------------------------------------------

/// One party's deterministic record of a scenario run: digests of every
/// revealed value plus exact per-phase flight/byte counts. Wall-clock
/// never appears, so the transcript of an in-process run and of a
/// two-process TCP run of the same scenario are **byte-identical** —
/// that equality is what the CI `two-process` job gates on.
#[derive(Debug, Clone, PartialEq)]
pub struct PartyTranscript {
    /// This endpoint's role (0 or 1).
    pub role: usize,
    /// The pipeline that ran.
    pub pipeline: Pipeline,
    /// Hex digest of the canonical scenario.
    pub scenario_sha256: String,
    /// Named reveal digests / values, in pipeline order.
    pub reveals: Vec<(String, String)>,
    /// Per-phase traffic, sorted by phase label.
    pub phases: Vec<(String, PhaseStats)>,
}

impl PartyTranscript {
    /// Render as deterministic JSON (sorted phases, insertion-ordered
    /// reveals, no floats, no wall-clock).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str("  \"transcript\": \"ppkmeans-party-v1\",\n");
        s.push_str(&format!("  \"role\": {},\n", self.role));
        s.push_str(&format!("  \"pipeline\": \"{}\",\n", self.pipeline.as_str()));
        s.push_str(&format!("  \"scenario_sha256\": \"{}\",\n", self.scenario_sha256));
        s.push_str("  \"reveals\": {\n");
        for (i, (k, v)) in self.reveals.iter().enumerate() {
            let comma = if i + 1 < self.reveals.len() { "," } else { "" };
            s.push_str(&format!("    \"{k}\": \"{v}\"{comma}\n"));
        }
        s.push_str("  },\n");
        s.push_str("  \"phases\": {\n");
        for (i, (k, p)) in self.phases.iter().enumerate() {
            let comma = if i + 1 < self.phases.len() { "," } else { "" };
            s.push_str(&format!(
                "    \"{k}\": {{\"bytes_sent\": {}, \"msgs_sent\": {}, \"rounds\": {}}}{comma}\n",
                p.bytes_sent, p.msgs_sent, p.rounds
            ));
        }
        s.push_str("  }\n}\n");
        s
    }
}

fn digest_u64s(words: impl IntoIterator<Item = u64>) -> String {
    let mut h = Hash256::new();
    for w in words {
        h.update(w.to_le_bytes());
    }
    hex(&h.finalize())
}

// ---- The per-party pipeline runner ---------------------------------------

/// Score a stream of generated transactions against a model share
/// (shared tail of the `serve` and `score` pipelines). `rctx` writes a
/// `serve.batch.{i}` checkpoint after every scored batch; `resume`
/// restores mid-stream state from such a checkpoint (the caller has
/// already restored the channel meter).
fn score_stream(
    chan: &mut Chan,
    model: TrainedModel,
    sc: &Scenario,
    reveals: &mut Vec<(String, String)>,
    rctx: &mut ResumeCtx,
    resume: Option<ServeState>,
) -> Result<()> {
    if sc.batches == 0 || sc.batch_rows == 0 {
        return Err(Error::Config("scenario: serving needs batches ≥ 1 and batch_rows ≥ 1".into()));
    }
    let rows = sc.batches * sc.batch_rows;
    let stream = fraud_gen::generate(rows, sc.rate, sc.stream_seed);
    if stream.data.d != model.d {
        return Err(Error::Config(format!(
            "scenario stream has d={} but the model was trained with d={}",
            stream.data.d, model.d
        )));
    }
    let (d_a, party) = (model.d_a, chan.party);
    let width = if party == 0 { d_a } else { model.d - d_a };
    let blocks: Vec<Vec<f64>> = (0..sc.batches)
        .map(|b| {
            let mut x = Vec::with_capacity(sc.batch_rows * width);
            for i in b * sc.batch_rows..(b + 1) * sc.batch_rows {
                let row = stream.data.row(i);
                x.extend_from_slice(if party == 0 { &row[..d_a] } else { &row[d_a..] });
            }
            x
        })
        .collect();
    let out = serve_party_ckpt(chan, model, blocks, &sc.serve_config(), rctx, resume)?;
    let mut h = Hash256::new();
    for r in &out.results {
        for &a in &r.assignments {
            h.update((a as u64).to_le_bytes());
        }
        for &f in &r.fraud_flags {
            h.update([f as u8]);
        }
        h.update((r.malformed_rows as u64).to_le_bytes());
    }
    reveals.push(("scores".into(), hex(&h.finalize())));
    let flagged: usize = out.results.iter().map(|r| r.flagged()).sum();
    reveals.push(("flagged_total".into(), flagged.to_string()));
    reveals.push((
        "bank_ledger".into(),
        format!(
            "{}+{}-{}={}",
            out.bank_prefabricated, out.bank_replenished, out.bank_consumed, out.bank_remaining
        ),
    ));
    reveals.push(("bank_misses".into(), out.bank_misses.to_string()));
    Ok(())
}

/// Score `gateway.sessions` concurrent transaction streams through the
/// mux gateway (tail of the `gateway` pipeline). Reveals are strictly
/// per-session plus scheduling-independent gateway totals: stall and
/// replenishment counts are *throughput* facts that legitimately vary
/// with worker interleaving, so they stay out of the transcript.
fn gateway_score_stream(
    chan: &mut Chan,
    model: TrainedModel,
    sc: &Scenario,
    reveals: &mut Vec<(String, String)>,
) -> Result<()> {
    let gcfg = sc.gateway_config();
    if gcfg.sessions == 0 || sc.batches == 0 || sc.batch_rows == 0 {
        return Err(Error::Config(
            "scenario: gateway needs gateway.sessions ≥ 1, batches ≥ 1 and batch_rows ≥ 1".into(),
        ));
    }
    let rows = gcfg.sessions * sc.batches * sc.batch_rows;
    let stream = fraud_gen::generate(rows, sc.rate, sc.stream_seed);
    if stream.data.d != model.d {
        return Err(Error::Config(format!(
            "scenario stream has d={} but the model was trained with d={}",
            stream.data.d, model.d
        )));
    }
    let (d_a, party) = (model.d_a, chan.party);
    let width = if party == 0 { d_a } else { model.d - d_a };
    let workloads: Vec<SessionWorkload> = (0..gcfg.sessions)
        .map(|s| {
            let blocks = (0..sc.batches)
                .map(|b| {
                    let base = (s * sc.batches + b) * sc.batch_rows;
                    let mut x = Vec::with_capacity(sc.batch_rows * width);
                    for i in base..base + sc.batch_rows {
                        let row = stream.data.row(i);
                        x.extend_from_slice(if party == 0 { &row[..d_a] } else { &row[d_a..] });
                    }
                    x
                })
                .collect();
            SessionWorkload { tag: s as u64 + 1, blocks }
        })
        .collect();
    let out = gateway_party(chan, model, workloads, &gcfg)?;
    for (tag, session) in &out.sessions {
        match session {
            Ok(s) => {
                let mut h = Hash256::new();
                for r in &s.results {
                    for &a in &r.assignments {
                        h.update((a as u64).to_le_bytes());
                    }
                    for &f in &r.fraud_flags {
                        h.update([f as u8]);
                    }
                    h.update((r.malformed_rows as u64).to_le_bytes());
                }
                reveals.push((format!("session{tag}.scores"), hex(&h.finalize())));
                reveals.push((
                    format!("session{tag}.online"),
                    format!("{}:{}:{}", s.online.bytes_sent, s.online.msgs_sent, s.online.rounds),
                ));
            }
            // Session-level failures are part of the transcript too —
            // a deterministic Overload (bank dry, refill = 0) must hit
            // both parties at the same batch with the same message.
            Err(e) => reveals.push((format!("session{tag}.error"), e.to_string())),
        }
    }
    reveals.push(("gateway.admitted".into(), out.admitted().to_string()));
    reveals.push(("gateway.rejected".into(), out.rejected.len().to_string()));
    reveals.push(("gateway.consumed".into(), out.ledger.consumed.to_string()));
    reveals.push(("gateway.misses".into(), out.misses().to_string()));
    Ok(())
}

/// How a negotiated checkpoint routes into a pipeline: not at all,
/// back into the training loop, or past training into the scoring tail.
enum PipelineResume {
    /// No checkpoint — run the pipeline from the top.
    Fresh,
    /// Mid-training snapshot: replay deterministic setup, restore the
    /// training loop ([`crate::kmeans::secure::run_party_ckpt`]).
    Training((TrainState, MeterSnapshot)),
    /// Post-training snapshot: training is skipped entirely; the model
    /// comes from the checkpoint, `state` (when present) restores a
    /// mid-stream scoring position.
    Scoring {
        model: TrainedModel,
        state: Option<ServeState>,
        meter: MeterSnapshot,
    },
}

fn split_resume(ckpt: Option<Checkpoint>) -> Result<PipelineResume> {
    let Some(c) = ckpt else { return Ok(PipelineResume::Fresh) };
    let meter = c.meter;
    Ok(match c.payload {
        Payload::Train(t) => PipelineResume::Training((t, meter)),
        Payload::TrainDone(t) => PipelineResume::Scoring {
            model: TrainedModel::from_bytes(&t.model)?,
            state: None,
            meter,
        },
        Payload::Serve(s) => PipelineResume::Scoring {
            model: TrainedModel::from_bytes(&s.model)?,
            state: Some(s),
            meter,
        },
    })
}

/// Training-only pipelines accept training snapshots, nothing later.
fn training_only(resume: PipelineResume) -> Result<Option<(TrainState, MeterSnapshot)>> {
    match resume {
        PipelineResume::Fresh => Ok(None),
        PipelineResume::Training(t) => Ok(Some(t)),
        PipelineResume::Scoring { .. } => Err(Error::Protocol(
            "resume: this pipeline holds only training checkpoints, but the negotiated \
             snapshot belongs to a later stage (mixed checkpoint directories?)"
                .into(),
        )),
    }
}

/// Overwrite the channel meter with a checkpointed snapshot — the
/// resumed tail then continues the original run's exact counts.
fn restore_meter(chan: &mut Chan, meter: MeterSnapshot) {
    let (phases, current, flight_open) = meter;
    chan.restore_meter(Meter::from_snapshot(phases, current, flight_open));
}

/// Run **this party's** side of the scenario pipeline over `chan`:
/// handshake (with the resume leg when `ckpt_dir` is set), the pipeline
/// phases separated by [`barrier`]s, and a final barrier — returning
/// the deterministic [`PartyTranscript`]. A scenario with `fault.*`
/// keys arms the deterministic fault plan on the chosen party first.
/// When the handshake negotiates a common checkpoint, the pipeline
/// restores it and replays only the remainder; a killed-and-resumed
/// run's transcript is byte-identical to an uninterrupted run's
/// (regression-tested in `tests/resume.rs`).
pub fn run_scenario(chan: &mut Chan, sc: &Scenario) -> Result<PartyTranscript> {
    if sc.fault_flight > 0 && chan.party == sc.fault_party {
        chan.set_fault(FaultPlan { at_flight: sc.fault_flight, mode: sc.fault_mode });
    }
    let mut rctx = if sc.ckpt_dir.is_empty() {
        ResumeCtx::disabled()
    } else {
        ResumeCtx::new(&sc.ckpt_dir, chan.party, sc.digest())
    };
    let common = handshake_resume(chan, sc, &mut rctx)?;
    let ckpt = if common > 0 { rctx.take_resume() } else { None };
    let mut reveals: Vec<(String, String)> = rctx.reveals().to_vec();
    match sc.pipeline {
        Pipeline::Train => {
            let resume = training_only(split_resume(ckpt)?)?;
            let data = sc.train_dataset();
            let normalized = normalize::min_max(&data);
            let cfg = sc.kmeans_config(sc.train_partition());
            let r = secure::run_party_ckpt(chan, &normalized, &cfg, &mut rctx, resume)?;
            reveals.push(("centroids".into(), digest_u64s(r.mu.data.iter().copied())));
            reveals.push((
                "assignments".into(),
                digest_u64s(r.assignments.iter().map(|&a| a as u64)),
            ));
            reveals.push(("iters_run".into(), r.iters.to_string()));
            reveals.push(("backend".into(), r.backend_name.to_string()));
            reveals.push(("malformed_rows".into(), r.malformed_rows.to_string()));
        }
        Pipeline::Fraud => {
            let resume = training_only(split_resume(ckpt)?)?;
            let f = fraud_gen::generate(sc.n, sc.rate, sc.data_seed);
            let cfg = sc.kmeans_config(Partition::Vertical { d_a: f.d_payment });
            let r = secure::run_party_ckpt(chan, &f.data, &cfg, &mut rctx, resume)?;
            let ocfg = OutlierConfig { rate: sc.rate, min_cluster_frac: 0.02 };
            let flagged = detect_outliers(&f.data, &r.mu.decode(), &r.assignments, sc.k, &ocfg);
            let j = jaccard(&flagged, &f.outliers);
            reveals.push(("centroids".into(), digest_u64s(r.mu.data.iter().copied())));
            reveals.push((
                "assignments".into(),
                digest_u64s(r.assignments.iter().map(|&a| a as u64)),
            ));
            reveals.push(("flagged".into(), digest_u64s(flagged.iter().map(|&i| i as u64))));
            reveals.push(("jaccard".into(), format!("{j:.6}")));
        }
        Pipeline::Serve => match split_resume(ckpt)? {
            PipelineResume::Scoring { model, state, meter } => {
                restore_meter(chan, meter);
                score_stream(chan, model, sc, &mut reveals, &mut rctx, state)?;
            }
            resume => {
                let resume = training_only(resume)?;
                let f = fraud_gen::generate(sc.n, sc.rate, sc.data_seed);
                let cfg = sc.kmeans_config(Partition::Vertical { d_a: f.d_payment });
                let (r, model) =
                    train_model_party_ckpt(chan, &f.data, &cfg, sc.rate, &mut rctx, resume)?;
                reveals.push(("centroids".into(), digest_u64s(r.mu.data.iter().copied())));
                reveals.push(("tau".into(), format!("{:.12}", model.tau)));
                rctx.set_reveals(&reveals);
                if sc.save_model {
                    let dir = PathBuf::from(&sc.model_dir);
                    std::fs::create_dir_all(&dir)?;
                    let path = dir.join(TrainedModel::file_name(chan.party));
                    model.save(&path)?;
                }
                barrier(chan, "train.done")?;
                rctx.save(
                    "train.done",
                    chan.meter(),
                    Payload::TrainDone(crate::resume::TrainDoneState { model: model.to_bytes() }),
                );
                score_stream(chan, model, sc, &mut reveals, &mut rctx, None)?;
            }
        },
        Pipeline::Gateway => match split_resume(ckpt)? {
            PipelineResume::Scoring { state: Some(_), .. } => {
                return Err(Error::Protocol(
                    "resume: the gateway pipeline writes no per-batch serve checkpoints — \
                     this snapshot belongs to a serve/score scenario"
                        .into(),
                ))
            }
            PipelineResume::Scoring { model, meter, .. } => {
                restore_meter(chan, meter);
                gateway_score_stream(chan, model, sc, &mut reveals)?;
            }
            resume => {
                let resume = training_only(resume)?;
                let f = fraud_gen::generate(sc.n, sc.rate, sc.data_seed);
                let cfg = sc.kmeans_config(Partition::Vertical { d_a: f.d_payment });
                let (r, model) =
                    train_model_party_ckpt(chan, &f.data, &cfg, sc.rate, &mut rctx, resume)?;
                reveals.push(("centroids".into(), digest_u64s(r.mu.data.iter().copied())));
                reveals.push(("tau".into(), format!("{:.12}", model.tau)));
                rctx.set_reveals(&reveals);
                barrier(chan, "train.done")?;
                rctx.save(
                    "train.done",
                    chan.meter(),
                    Payload::TrainDone(crate::resume::TrainDoneState { model: model.to_bytes() }),
                );
                gateway_score_stream(chan, model, sc, &mut reveals)?;
            }
        },
        Pipeline::Score => match split_resume(ckpt)? {
            PipelineResume::Scoring { model, state: state @ Some(_), meter } => {
                restore_meter(chan, meter);
                score_stream(chan, model, sc, &mut reveals, &mut rctx, state)?;
            }
            PipelineResume::Fresh => {
                let path = PathBuf::from(&sc.model_dir).join(TrainedModel::file_name(chan.party));
                let model = TrainedModel::load(&path).map_err(|e| {
                    Error::Config(format!(
                        "cannot load {} ({e}) — run a serve scenario with `save_model = true` \
                         first",
                        path.display()
                    ))
                })?;
                reveals.push(("tau".into(), format!("{:.12}", model.tau)));
                rctx.set_reveals(&reveals);
                score_stream(chan, model, sc, &mut reveals, &mut rctx, None)?;
            }
            _ => {
                return Err(Error::Protocol(
                    "resume: the score pipeline writes only per-batch serve checkpoints — \
                     the negotiated snapshot belongs to a different pipeline stage"
                        .into(),
                ))
            }
        },
    }
    barrier(chan, "pipeline.done")?;
    if let Some(e) = rctx.take_error() {
        return Err(e);
    }
    Ok(PartyTranscript {
        role: chan.party,
        pipeline: sc.pipeline,
        scenario_sha256: hex(&sc.digest()),
        reveals,
        phases: chan.meter().phases().map(|(k, v)| (k.to_string(), *v)).collect(),
    })
}

/// Run a scenario **in-process**: both parties over a duplex pair, each
/// through the same [`run_scenario`] code path a TCP deployment uses.
/// This is the reference the CI `two-process` job diffs real processes
/// against, and the `--role local` CLI mode.
pub fn run_scenario_local(sc: &Scenario) -> Result<(PartyTranscript, PartyTranscript)> {
    let (mut c0, mut c1) = crate::net::duplex_pair();
    let (sc0, sc1) = (sc.clone(), sc.clone());
    let (t0, t1) = crate::runtime::pool::run_pair(
        move || run_scenario(&mut c0, &sc0),
        move || run_scenario(&mut c1, &sc1),
    );
    Ok((t0?, t1?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_train() -> Scenario {
        Scenario {
            pipeline: Pipeline::Train,
            n: 48,
            d: 4,
            k: 2,
            iters: 2,
            seed: 7,
            data_seed: 5,
            ..Default::default()
        }
    }

    #[test]
    fn scenario_roundtrips_through_parse() {
        // (Local knobs are at their defaults here; canonical() omits
        // them by design, so the parse is a faithful roundtrip.)
        let sc = tiny_train();
        let parsed = Scenario::parse(&sc.canonical()).unwrap();
        assert_eq!(parsed, sc);
        assert_eq!(parsed.digest(), sc.digest());
    }

    #[test]
    fn every_protocol_key_changes_the_digest_and_local_keys_do_not() {
        // Ties the three hand-maintained key lists (struct fields,
        // parse() arms, canonical() order) together: a key that parses
        // but fails to move the digest would let two parties handshake
        // into different protocols. Every parse() key must appear here.
        let base = Scenario::default();
        let protocol_keys = [
            ("pipeline", "fraud"),
            ("n", "7"),
            ("d", "9"),
            ("k", "5"),
            ("iters", "3"),
            ("seed", "99"),
            ("data_seed", "98"),
            ("stream_seed", "97"),
            ("partition", "horizontal"),
            ("d_a", "2"),
            ("n_a", "3"),
            ("esd", "naive"),
            ("security", "malicious"),
            ("sparse", "true"),
            ("sparsity", "0.25"),
            ("tile_rows", "8"),
            ("tile_flights", "streamed"),
            ("shape", "wan"),
            ("rate", "0.1"),
            ("batch_rows", "5"),
            ("batches", "6"),
            ("prefab", "7"),
            ("low_water", "3"),
            ("refill", "9"),
            ("refresh.every", "4"),
            ("refresh.alpha", "0.5"),
            ("gateway.sessions", "3"),
            ("gateway.queue", "2"),
        ];
        for (key, val) in protocol_keys {
            let sc = Scenario::parse(&format!("{key} = {val}")).unwrap();
            assert_ne!(sc.digest(), base.digest(), "protocol key {key} must move the digest");
        }
        // Party-local knobs must NOT move the digest: heterogeneous
        // deployments (core counts, disk layouts) handshake cleanly.
        let local_keys = [
            ("threads", "16"),
            ("lanes", "8"),
            ("gateway.workers", "4"),
            ("model_dir", "elsewhere"),
            ("save_model", "true"),
            ("fault.flight", "3"),
            ("fault.mode", "abort"),
            ("fault.party", "1"),
            ("ckpt_dir", "ckpts"),
        ];
        for (key, val) in local_keys {
            let sc = Scenario::parse(&format!("{key} = {val}")).unwrap();
            assert_eq!(sc.digest(), base.digest(), "local key {key} must not move the digest");
        }
    }

    #[test]
    fn scenario_rejects_unknown_keys_and_bad_values() {
        assert!(Scenario::parse("pipelin = train").is_err());
        assert!(Scenario::parse("n = many").is_err());
        assert!(Scenario::parse("pipeline = dance").is_err());
        assert!(Scenario::parse("just a line").is_err());
        // Comments and blank lines are fine.
        let sc = Scenario::parse("# comment\n\nn = 10 # trailing\n").unwrap();
        assert_eq!(sc.n, 10);
    }

    #[test]
    fn comments_and_defaults_do_not_change_the_digest() {
        let a = Scenario::parse("n = 9\nk = 2\n").unwrap();
        let b = Scenario::parse("# header\nk = 2\n\nn = 9   # trailing comment\n").unwrap();
        assert_eq!(a.digest(), b.digest());
        let c = Scenario::parse("n = 10\nk = 2\n").unwrap();
        assert_ne!(a.digest(), c.digest());
    }

    #[test]
    fn local_run_produces_matching_transcripts() {
        let sc = tiny_train();
        let (t0, t1) = run_scenario_local(&sc).unwrap();
        assert_eq!(t0.role, 0);
        assert_eq!(t1.role, 1);
        // Reveals are public joint outputs: identical on both sides.
        assert_eq!(t0.reveals, t1.reveals);
        assert_eq!(t0.scenario_sha256, t1.scenario_sha256);
        // And a re-run is bit-identical (the CI diff relies on this).
        let (t0b, _) = run_scenario_local(&sc).unwrap();
        assert_eq!(t0.to_json(), t0b.to_json());
    }

    #[test]
    fn handshake_rejects_mismatched_scenarios() {
        let (mut c0, mut c1) = crate::net::duplex_pair();
        let a = tiny_train();
        let mut b = tiny_train();
        b.iters = 3; // one key differs
        let h = std::thread::spawn(move || handshake(&mut c1, &b));
        let r0 = handshake(&mut c0, &a);
        let r1 = h.join().unwrap();
        let e0 = r0.unwrap_err().to_string();
        assert!(e0.contains("scenario mismatch"), "{e0}");
        assert!(e0.contains("iters"), "must name the differing key: {e0}");
        assert!(r1.is_err());
    }

    #[test]
    fn barrier_detects_desync() {
        let (mut c0, mut c1) = crate::net::duplex_pair();
        let h = std::thread::spawn(move || barrier(&mut c1, "phase.b"));
        let r0 = barrier(&mut c0, "phase.a");
        assert!(r0.is_err());
        assert!(h.join().unwrap().is_err());
    }

    #[test]
    fn matched_handshake_and_barrier_succeed() {
        let (mut c0, mut c1) = crate::net::duplex_pair();
        let a = tiny_train();
        let b = a.clone();
        let h = std::thread::spawn(move || {
            handshake(&mut c1, &b)?;
            barrier(&mut c1, "x")
        });
        handshake(&mut c0, &a).unwrap();
        barrier(&mut c0, "x").unwrap();
        h.join().unwrap().unwrap();
    }
}
