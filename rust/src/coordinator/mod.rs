//! L3 coordinator: session orchestration and cost reporting.
//!
//! Wraps a full protocol run — artifact loading, data preparation,
//! protocol execution, and translation of the exact (bytes, rounds,
//! wall-clock) measurements into the paper's reporting format (online /
//! offline time and communication under a LAN or WAN link model).
//! [`serve`] is the serving analogue: per-request latency/throughput and
//! the material-bank ledger for a [`crate::serve`] run. [`remote`] is
//! the two-process deployment layer: scenario files, the wire
//! handshake/barriers, and the per-party pipeline runner with
//! transport-independent transcripts.

pub mod remote;
pub mod report;
pub mod serve;
pub mod session;

pub use remote::{PartyTranscript, Scenario};
pub use report::Report;
pub use serve::{GatewayReport, ServeReport};
pub use session::Session;
