//! L3 coordinator: session orchestration and cost reporting.
//!
//! Wraps a full protocol run — artifact loading, data preparation,
//! protocol execution, and translation of the exact (bytes, rounds,
//! wall-clock) measurements into the paper's reporting format (online /
//! offline time and communication under a LAN or WAN link model).

pub mod report;
pub mod session;

pub use report::Report;
pub use session::Session;
