//! High-level session: the entrypoint the launcher and examples use.

use crate::data::blobs::Dataset;
use crate::data::normalize;
use crate::kmeans::config::SecureKmeansConfig;
use crate::kmeans::secure::{self, SecureKmeansOutput};
use crate::net::cost::CostModel;
use crate::offline::pricing::OtCalibration;
use crate::util::error::Result;
use std::path::Path;

/// A configured secure-clustering session.
pub struct Session {
    pub cfg: SecureKmeansConfig,
    pub link: CostModel,
    /// Whether to load PJRT artifacts for the compute hot path.
    pub use_artifacts: bool,
}

impl Session {
    pub fn new(cfg: SecureKmeansConfig) -> Session {
        Session { cfg, link: CostModel::lan(), use_artifacts: true }
    }

    pub fn with_link(mut self, link: CostModel) -> Session {
        self.link = link;
        self
    }

    /// Run the protocol on (normalized) data; loads artifacts if present.
    pub fn run(&self, data: &Dataset) -> Result<SecureKmeansOutput> {
        if self.use_artifacts {
            // Best-effort: protocol falls back to native kernels.
            let _ = crate::runtime::dispatch::init(Path::new("artifacts"));
        }
        let normalized = normalize::min_max(data);
        secure::run(&normalized, &self.cfg)
    }

    /// Run and summarize under this session's link model.
    pub fn run_with_report(
        &self,
        data: &Dataset,
        cal: &OtCalibration,
    ) -> Result<(SecureKmeansOutput, super::Report)> {
        let out = self.run(data)?;
        let report = super::Report::from_run(&out, &self.link, cal);
        Ok((out, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::BlobSpec;
    use crate::kmeans::config::Partition;

    #[test]
    fn session_runs_end_to_end() {
        let ds = BlobSpec::new(24, 2, 2).generate(6);
        let cfg = SecureKmeansConfig {
            k: 2,
            iters: 2,
            partition: Partition::Vertical { d_a: 1 },
            ..Default::default()
        };
        let mut s = Session::new(cfg);
        s.use_artifacts = false; // unit tests must not require artifacts
        let out = s.run(&ds).unwrap();
        assert_eq!(out.assignments.len(), 24);
    }
}
