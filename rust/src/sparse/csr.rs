//! Compressed sparse row matrices over Z_{2^64}.

use crate::ring::matrix::Mat;
use crate::ring::fixed::encode_f64;

/// CSR matrix: ring-element values at (row, col) positions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Row pointer array, length rows+1.
    pub indptr: Vec<usize>,
    /// Column indices of stored entries.
    pub indices: Vec<usize>,
    /// Stored (nonzero) values.
    pub values: Vec<u64>,
}

impl Csr {
    /// Build from a dense matrix, dropping zeros.
    ///
    /// # Examples
    ///
    /// ```
    /// use ppkmeans::ring::matrix::Mat;
    /// use ppkmeans::sparse::csr::Csr;
    ///
    /// let dense = Mat::from_vec(2, 3, vec![0, 5, 0, 7, 0, 0]);
    /// let sparse = Csr::from_dense(&dense);
    /// assert_eq!(sparse.nnz(), 2);
    /// assert_eq!(sparse.indptr, vec![0, 1, 2]);     // one nonzero per row
    /// assert_eq!(sparse.to_dense(), dense);         // lossless round-trip
    /// ```
    pub fn from_dense(m: &Mat) -> Csr {
        let mut indptr = Vec::with_capacity(m.rows + 1);
        let mut indices = vec![];
        let mut values = vec![];
        indptr.push(0);
        for r in 0..m.rows {
            for c in 0..m.cols {
                let v = m.at(r, c);
                if v != 0 {
                    indices.push(c);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Csr { rows: m.rows, cols: m.cols, indptr, indices, values }
    }

    /// Build from real-valued row-major data with fixed-point encoding.
    pub fn encode_dense(rows: usize, cols: usize, xs: &[f64]) -> Csr {
        assert_eq!(xs.len(), rows * cols);
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = vec![];
        let mut values = vec![];
        indptr.push(0);
        for r in 0..rows {
            for c in 0..cols {
                let x = xs[r * cols + c];
                if x != 0.0 {
                    let v = encode_f64(x);
                    if v != 0 {
                        indices.push(c);
                        values.push(v);
                    }
                }
            }
            indptr.push(indices.len());
        }
        Csr { rows, cols, indptr, indices, values }
    }

    /// Materialize as dense.
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for idx in self.indptr[r]..self.indptr[r + 1] {
                m.set(r, self.indices[idx], self.values[idx]);
            }
        }
        m
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of entries that are zero.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Slice rows `[r0, r1)` into a new CSR (the row-tile view of the
    /// sparse protocol path): indptr is rebased, the nonzero payload is
    /// the contiguous `[indptr[r0], indptr[r1])` range.
    pub fn rows_slice(&self, r0: usize, r1: usize) -> Csr {
        assert!(r0 <= r1 && r1 <= self.rows, "row slice bounds");
        let (s, e) = (self.indptr[r0], self.indptr[r1]);
        Csr {
            rows: r1 - r0,
            cols: self.cols,
            indptr: self.indptr[r0..=r1].iter().map(|&p| p - s).collect(),
            indices: self.indices[s..e].to_vec(),
            values: self.values[s..e].to_vec(),
        }
    }

    /// Iterate the nonzeros of one row as (col, value).
    pub fn row_iter(&self, r: usize) -> impl Iterator<Item = (usize, u64)> + '_ {
        (self.indptr[r]..self.indptr[r + 1]).map(move |i| (self.indices[i], self.values[i]))
    }

    /// Transpose into a new CSR (CSC view materialized).
    pub fn transpose(&self) -> Csr {
        let mut counts = vec![0usize; self.cols];
        for &c in &self.indices {
            counts[c] += 1;
        }
        let mut indptr = Vec::with_capacity(self.cols + 1);
        indptr.push(0);
        for c in 0..self.cols {
            indptr.push(indptr[c] + counts[c]);
        }
        let mut cursor = indptr[..self.cols].to_vec();
        let mut indices = vec![0usize; self.nnz()];
        let mut values = vec![0u64; self.nnz()];
        for r in 0..self.rows {
            for (c, v) in self.row_iter(r) {
                indices[cursor[c]] = r;
                values[cursor[c]] = v;
                cursor[c] += 1;
            }
        }
        Csr { rows: self.cols, cols: self.rows, indptr, indices, values }
    }

    /// Plaintext sparse · dense product (wrapping), `self (n×d) · m (d×k)`.
    /// The per-nonzero row update is a packed axpy sweep
    /// ([`crate::runtime::simd::axpy`]).
    pub fn matmul_dense(&self, m: &Mat) -> Mat {
        assert_eq!(self.cols, m.rows, "spmm shape");
        let mut out = Mat::zeros(self.rows, m.cols);
        for r in 0..self.rows {
            let orow = out.row_mut(r);
            for (j, v) in self.row_iter(r) {
                crate::runtime::simd::axpy(orow, v, m.row(j));
            }
        }
        out
    }

    /// Transposed product `self^T (d×n) · m (n×k)` without materializing
    /// the transpose.
    pub fn t_matmul_dense(&self, m: &Mat) -> Mat {
        assert_eq!(self.rows, m.rows, "spmm^T shape");
        let mut out = Mat::zeros(self.cols, m.cols);
        for r in 0..self.rows {
            let brow = m.row(r);
            for (j, v) in self.row_iter(r) {
                crate::runtime::simd::axpy(out.row_mut(j), v, brow);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prg;

    fn sample() -> Mat {
        Mat::from_vec(3, 4, vec![0, 2, 0, 0, 1, 0, 0, 3, 0, 0, 0, 0])
    }

    #[test]
    fn dense_roundtrip() {
        let m = sample();
        let s = Csr::from_dense(&m);
        assert_eq!(s.nnz(), 3);
        assert_eq!(s.to_dense(), m);
        assert!((s.sparsity() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn spmm_matches_dense_matmul() {
        let mut prg = Prg::new(4);
        let mut dense = Mat::random(6, 5, &mut prg);
        // zero ~60% of entries
        for v in dense.data.iter_mut() {
            if prg.next_f64() < 0.6 {
                *v = 0;
            }
        }
        let s = Csr::from_dense(&dense);
        let b = Mat::random(5, 3, &mut prg);
        assert_eq!(s.matmul_dense(&b), dense.matmul(&b));
    }

    #[test]
    fn transposed_spmm() {
        let mut prg = Prg::new(5);
        let mut dense = Mat::random(4, 6, &mut prg);
        for v in dense.data.iter_mut() {
            if prg.next_f64() < 0.5 {
                *v = 0;
            }
        }
        let s = Csr::from_dense(&dense);
        let b = Mat::random(4, 2, &mut prg);
        assert_eq!(s.t_matmul_dense(&b), dense.transpose().matmul(&b));
    }

    #[test]
    fn transpose_matches_dense() {
        let m = sample();
        let s = Csr::from_dense(&m);
        assert_eq!(s.transpose().to_dense(), m.transpose());
        assert_eq!(s.transpose().transpose(), s);
    }

    #[test]
    fn encode_dense_drops_zeros() {
        let s = Csr::encode_dense(2, 2, &[0.0, 1.5, 0.0, -2.0]);
        assert_eq!(s.nnz(), 2);
    }

    #[test]
    fn rows_slice_matches_dense_slice() {
        let mut prg = Prg::new(6);
        let mut dense = Mat::random(7, 5, &mut prg);
        for v in dense.data.iter_mut() {
            if prg.next_f64() < 0.6 {
                *v = 0;
            }
        }
        let s = Csr::from_dense(&dense);
        for (r0, r1) in [(0, 7), (0, 3), (2, 5), (6, 7), (4, 4)] {
            let tile = s.rows_slice(r0, r1);
            assert_eq!(tile.to_dense(), dense.rows_slice(r0, r1), "rows [{r0}, {r1})");
        }
    }
}
