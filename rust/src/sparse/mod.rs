//! Sparsity-aware machinery (paper §4.3).
//!
//! Secret sharing destroys sparsity — shares of 0 are uniform — so the
//! paper routes sparse matrix products through HE instead: the sparse
//! holder computes on ciphertexts of the *small dense* operand, skipping
//! zeros entirely, and HE2SS converts the result back into the SS world.

pub mod csr;
pub mod protocol2;

pub use csr::Csr;
