//! Protocol 2: secure sparse matrix multiplication (paper §4.3).
//!
//! Inputs: a *sparse* plaintext matrix `X (n×d)` held by party A and a
//! dense matrix `Y (d×k)` held by party B (in K-means, B's share of the
//! centroids or of the assignment matrix). Output: additive shares of
//! `Z = X·Y mod 2^64`.
//!
//! 1. B encrypts `Y` entrywise under its own key and sends `[[Y]]` —
//!    `d·k` ciphertexts, *independent of n*.
//! 2. A evaluates each output cell `[[Z_ik]] = Σ_j x_ij·[[Y_jk]]` over
//!    the **nonzero** `x_ij` only — the sparsity win: work ∝ nnz(X)·k.
//! 3. A masks (and thereby rerandomizes) each cell and returns it; HE2SS
//!    turns the batch into additive shares ([`crate::he::he2ss`]).
//!
//! Communication: `(d·k + n·k)` ciphertexts total, versus `(n·d + d·k)`
//! ring elements for the Beaver path — much cheaper precisely in the
//! paper's high-dimensional-sparse regime (d ≫ k).

use crate::he::he2ss::{he2ss_receiver_par, he2ss_sender_par};
use crate::he::{ct_from_bytes, ct_to_bytes, HeScheme};
use crate::bigint::BigUint;
use crate::net::Chan;
use crate::ring::matrix::Mat;
use crate::sparse::csr::Csr;
use crate::util::prng::Prg;

/// Upper bound (bits) on an output integer: products of two 64-bit ring
/// elements summed over ≤ d terms.
fn value_bits(d: usize) -> usize {
    128 + (usize::BITS - d.leading_zeros()) as usize + 1
}

/// B-side (dense holder): returns B's share of `X·Y`.
///
/// `x_rows` is the (public) row count of A's sparse matrix.
/// Single-threaded wrapper over [`dense_party_par`].
pub fn dense_party<S: HeScheme>(
    chan: &mut Chan,
    pk: &S::Pk,
    sk: &S::Sk,
    y: &Mat,
    x_rows: usize,
    prg: &mut Prg,
) -> Mat {
    dense_party_par::<S>(chan, pk, sk, y, x_rows, prg, 1)
}

/// [`dense_party`] with the encryption vector (`d·k` ciphertexts) and
/// the HE2SS decryptions fanned out across up to `threads` workers.
/// The wire frames are byte-identical for any thread count (per-element
/// randomness forks sequentially — see
/// [`crate::he::encrypt_u64s_many`]).
pub fn dense_party_par<S: HeScheme>(
    chan: &mut Chan,
    pk: &S::Pk,
    sk: &S::Sk,
    y: &Mat,
    x_rows: usize,
    prg: &mut Prg,
    threads: usize,
) -> Mat {
    // 1) encrypt and ship Y (lane-parallel modexps).
    let cts = crate::he::encrypt_u64s_many::<S>(pk, &y.data, prg, threads);
    let mut payload = Vec::with_capacity(y.len() * S::ct_bytes(pk));
    for ct in &cts {
        payload.extend_from_slice(&ct_to_bytes::<S>(pk, ct));
    }
    chan.send_bytes(&payload);
    // 3) receive masked products, decrypt into shares.
    let shares = he2ss_receiver_par::<S>(chan, pk, sk, x_rows * y.cols, threads);
    Mat::from_vec(x_rows, y.cols, shares)
}

/// A-side (sparse holder): returns A's share of `X·Y`.
///
/// `y_shape` is the (public) shape of B's dense matrix.
/// Single-threaded wrapper over [`sparse_party_par`].
pub fn sparse_party<S: HeScheme>(
    chan: &mut Chan,
    pk: &S::Pk,
    x: &Csr,
    y_shape: (usize, usize),
    prg: &mut Prg,
) -> Mat {
    sparse_party_par::<S>(chan, pk, x, y_shape, prg, 1)
}

/// [`sparse_party`] with the homomorphic evaluation (work ∝ nnz(X)·k)
/// sharded across row blocks on up to `threads` workers, and the
/// mask-and-return conversion fanned out likewise. Output cells are
/// assembled in row order; the wire traffic is byte-identical for any
/// thread count.
pub fn sparse_party_par<S: HeScheme>(
    chan: &mut Chan,
    pk: &S::Pk,
    x: &Csr,
    y_shape: (usize, usize),
    prg: &mut Prg,
    threads: usize,
) -> Mat {
    let (d, k) = y_shape;
    assert_eq!(x.cols, d, "X cols must match Y rows");
    // 1) receive [[Y]].
    let w = S::ct_bytes(pk);
    let payload = chan.recv_bytes();
    assert_eq!(payload.len(), d * k * w, "ciphertext frame");
    let y_cts: Vec<BigUint> = payload.chunks_exact(w).map(ct_from_bytes).collect();

    // 2) sparse evaluation: for each row, combine only nonzero columns
    //    (row-block parallel; each output cell depends on one row only).
    let zero_ct = S::encrypt(pk, &BigUint::zero(), prg);
    let ranges = crate::runtime::pool::chunk_ranges(x.rows, threads.max(1));
    let blocks: Vec<Vec<BigUint>> =
        crate::runtime::pool::parallel_map(threads, &ranges, |_, &(r0, r1)| {
            let mut cts = Vec::with_capacity((r1 - r0) * k);
            for r in r0..r1 {
                for c in 0..k {
                    let mut acc: Option<BigUint> = None;
                    for (j, v) in x.row_iter(r) {
                        let term = S::smul(pk, &y_cts[j * k + c], &BigUint::from_u64(v));
                        acc = Some(match acc {
                            None => term,
                            Some(a) => S::add(pk, &a, &term),
                        });
                    }
                    cts.push(acc.unwrap_or_else(|| zero_ct.clone()));
                }
            }
            cts
        });
    let out_cts: Vec<BigUint> = blocks.concat();

    // 3) mask + rerandomize + convert to shares.
    let shares = he2ss_sender_par::<S>(chan, pk, &out_cts, value_bits(d), prg, threads);
    Mat::from_vec(x.rows, k, shares)
}

/// Exact protocol communication cost in bytes (for cost planning):
/// `(d·k + n·k)` ciphertexts of the key's width.
pub fn comm_bytes<S: HeScheme>(pk: &S::Pk, n: usize, d: usize, k: usize) -> u64 {
    ((d * k + n * k) * S::ct_bytes(pk)) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::he::ou::Ou;
    use crate::net::run_two_party;
    use crate::ss::share::reconstruct;
    use crate::util::prng::Prg;

    fn sparse_x() -> Csr {
        // 4×6, ~70% zeros, including an all-zero row.
        let dense = Mat::from_vec(
            4,
            6,
            vec![
                0, 5, 0, 0, 0, 1, //
                0, 0, 0, 0, 0, 0, //
                7, 0, 0, u64::MAX, 0, 0, //
                0, 0, 2, 0, 3, 0,
            ],
        );
        Csr::from_dense(&dense)
    }

    #[test]
    fn protocol2_shares_reconstruct_to_product() {
        let x = sparse_x();
        let mut prg = Prg::new(31);
        let y = Mat::random(6, 2, &mut prg);
        let want = x.to_dense().matmul(&y);

        // Masks need value_bits(6)+κ ≈ 174 bits of plaintext space; OU's
        // space is ~(key/3) bits, so 768-bit keys give ~2^255 — enough.
        // (Production uses 2048-bit keys per the paper.)
        let mut kprg = Prg::new(12);
        let (pk, sk) = Ou::keygen(768, &mut kprg);
        let pk_a = pk.clone();
        let xc = x.clone();
        let yc = y.clone();
        let ((za, _), (zb, _)) = run_two_party(
            move |c| {
                let mut prg = Prg::new(41);
                let z = sparse_party::<Ou>(c, &pk_a, &xc, (6, 2), &mut prg);
                reconstruct(c, &z)
            },
            move |c| {
                let mut prg = Prg::new(42);
                let z = dense_party::<Ou>(c, &pk, &sk, &yc, 4, &mut prg);
                reconstruct(c, &z)
            },
        );
        assert_eq!(za, want);
        assert_eq!(zb, want);
    }

    #[test]
    fn communication_is_independent_of_x_size() {
        let mut kprg = Prg::new(13);
        let (pk, _sk) = Ou::keygen(384, &mut kprg);
        let c1 = comm_bytes::<Ou>(&pk, 100, 50, 2);
        let c2 = comm_bytes::<Ou>(&pk, 100, 500, 2);
        // Growing d only adds d·k ciphertexts, never n·d traffic.
        assert_eq!(c2 - c1, (450 * 2 * Ou::ct_bytes(&pk)) as u64);
    }

    #[test]
    fn value_bits_covers_worst_case() {
        assert!(value_bits(1) >= 129);
        assert!(value_bits(1 << 14) >= 143);
    }
}
