//! `ppkm-lint` — the protocol-invariant static analyzer, as a CLI.
//!
//! Walks `src/**` of the crate, applies the rule catalog
//! ([`ppkmeans::lint`]) under the `lint.rules` policy file, prints
//! findings as `rule: file:line: token`, and exits non-zero when
//! anything fires. CI runs this as a blocking job; locally:
//!
//! ```text
//! cargo run --release --bin ppkm-lint            # lint the tree
//! cargo run --release --bin ppkm-lint -- --list  # print the catalog
//! ```
//!
//! Exit codes: `0` clean, `1` findings, `2` usage or I/O failure.

use ppkmeans::lint::{load_rules, scan_tree, Scope};
use std::path::PathBuf;

/// Locate the crate root: `--root` wins; otherwise the compile-time
/// manifest dir when it still exists (the `cargo run` case); otherwise
/// walk up from the current directory looking for `Cargo.toml` next to
/// `src/` (the relocated-binary case).
fn find_root(explicit: Option<PathBuf>) -> Option<PathBuf> {
    if let Some(r) = explicit {
        return Some(r);
    }
    let baked = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    if baked.join("src").is_dir() {
        return Some(baked);
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        if cur.join("Cargo.toml").is_file() && cur.join("src").is_dir() {
            return Some(cur);
        }
        // A workspace checkout's root holds the member at rust/.
        if cur.join("rust/Cargo.toml").is_file() && cur.join("rust/src").is_dir() {
            return Some(cur.join("rust"));
        }
        if !cur.pop() {
            return None;
        }
    }
}

fn main() {
    let mut args = std::env::args().skip(1);
    let mut root: Option<PathBuf> = None;
    let mut list = false;
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(r) => root = Some(PathBuf::from(r)),
                None => {
                    eprintln!("ppkm-lint: --root needs a path");
                    std::process::exit(2);
                }
            },
            "--list" => list = true,
            "--help" | "-h" => {
                println!(
                    "ppkm-lint [--root CRATE_DIR] [--list]\n\
                     Lints src/** against the protocol-invariant rule catalog\n\
                     (policy: CRATE_DIR/lint.rules; docs: docs/STATIC_ANALYSIS.md)."
                );
                return;
            }
            other => {
                eprintln!("ppkm-lint: unknown argument `{other}` (try --help)");
                std::process::exit(2);
            }
        }
    }
    let Some(root) = find_root(root) else {
        eprintln!("ppkm-lint: cannot locate the crate root (use --root)");
        std::process::exit(2);
    };
    let rules = match load_rules(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("ppkm-lint: {e}");
            std::process::exit(2);
        }
    };
    if list {
        for r in &rules {
            let (kind, mods) = match &r.scope {
                Scope::BannedIn(m) => ("banned in", m),
                Scope::ConfinedTo(m) => ("confined to", m),
            };
            println!("{}: {} [{} {}]", r.id, r.summary, kind, mods.join(" "));
        }
        return;
    }
    match scan_tree(&root, &rules) {
        Ok(findings) if findings.is_empty() => {
            println!("ppkm-lint: clean ({} rules over {})", rules.len(), root.display());
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            eprintln!(
                "ppkm-lint: {} finding(s) — fix, or suppress with \
                 `// lint:allow(rule): justification` (see docs/STATIC_ANALYSIS.md)",
                findings.len()
            );
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("ppkm-lint: {e}");
            std::process::exit(2);
        }
    }
}
