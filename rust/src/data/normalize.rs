//! Joint min-max normalization (paper §4.2: "before performing
//! clustering, a joint normalization operation is required").
//!
//! For vertically partitioned data each feature is owned by exactly one
//! party, so min-max per column is a purely local operation; for
//! horizontal partitioning the parties would run a two-element secure
//! max/min per column — here provided in plaintext form for data
//! preparation, with column stats exposed for the secure wrapper.

use super::blobs::Dataset;

/// Per-column (min, max).
pub fn column_stats(ds: &Dataset) -> Vec<(f64, f64)> {
    let mut stats = vec![(f64::INFINITY, f64::NEG_INFINITY); ds.d];
    for i in 0..ds.n {
        for (l, &v) in ds.row(i).iter().enumerate() {
            stats[l].0 = stats[l].0.min(v);
            stats[l].1 = stats[l].1.max(v);
        }
    }
    stats
}

/// Min-max scale every column into [0, 1] (constant columns → 0).
pub fn min_max(ds: &Dataset) -> Dataset {
    let stats = column_stats(ds);
    let mut out = ds.clone();
    for i in 0..ds.n {
        for l in 0..ds.d {
            let (lo, hi) = stats[l];
            let v = &mut out.x[i * ds.d + l];
            *v = if hi > lo { (*v - lo) / (hi - lo) } else { 0.0 };
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_into_unit_interval() {
        let ds = Dataset {
            n: 3,
            d: 2,
            x: vec![-1.0, 10.0, 0.0, 20.0, 1.0, 30.0],
            labels: vec![0; 3],
        };
        let out = min_max(&ds);
        assert_eq!(out.x, vec![0.0, 0.0, 0.5, 0.5, 1.0, 1.0]);
    }

    #[test]
    fn constant_column_becomes_zero() {
        let ds = Dataset { n: 2, d: 1, x: vec![5.0, 5.0], labels: vec![0; 2] };
        assert_eq!(min_max(&ds).x, vec![0.0, 0.0]);
    }
}
