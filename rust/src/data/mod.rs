//! Dataset generation and preparation (paper §5.1).
//!
//! Three synthetic generators mirror the paper's evaluation data:
//! Gaussian blobs ([`blobs`]) for Q1-Q3, a sparsity-controlled variant
//! ([`sparse_gen`]) for Q4, and a two-party fraud dataset
//! ([`fraud_gen`]) with the production shape (10k × 42, 18 payment + 24
//! merchant features, ~1% fraud) for Q5. [`normalize`] provides the
//! joint min-max normalization the paper applies before clustering.

pub mod blobs;
pub mod fraud_gen;
pub mod normalize;
pub mod sparse_gen;
