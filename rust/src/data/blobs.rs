//! Gaussian-blob synthetic clustering data.

use crate::util::prng::Prg;

/// Specification for an n×d dataset drawn from `k` Gaussian blobs.
#[derive(Debug, Clone)]
pub struct BlobSpec {
    pub n: usize,
    pub d: usize,
    pub k: usize,
    pub spread: f64,
}

impl BlobSpec {
    pub fn new(n: usize, d: usize, k: usize) -> Self {
        BlobSpec { n, d, k, spread: 0.05 }
    }

    /// Generate row-major data in [0,1]^d with ground-truth labels.
    pub fn generate(&self, seed: u128) -> Dataset {
        let mut prg = Prg::new(seed);
        let mut centers = vec![0.0; self.k * self.d];
        for c in centers.iter_mut() {
            *c = 0.1 + 0.8 * prg.next_f64();
        }
        let mut x = vec![0.0; self.n * self.d];
        let mut labels = vec![0usize; self.n];
        for i in 0..self.n {
            let g = (prg.next_below(self.k as u64)) as usize;
            labels[i] = g;
            for j in 0..self.d {
                let v = centers[g * self.d + j] + self.spread * prg.next_gaussian();
                x[i * self.d + j] = v.clamp(0.0, 1.0);
            }
        }
        Dataset { n: self.n, d: self.d, x, labels }
    }
}

/// A dense plaintext dataset with optional ground-truth labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub n: usize,
    pub d: usize,
    /// Row-major n×d values.
    pub x: Vec<f64>,
    pub labels: Vec<usize>,
}

impl Dataset {
    pub fn row(&self, i: usize) -> &[f64] {
        &self.x[i * self.d..(i + 1) * self.d]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_shapes_and_range() {
        let ds = BlobSpec::new(100, 3, 4).generate(1);
        assert_eq!(ds.x.len(), 300);
        assert!(ds.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(ds.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = BlobSpec::new(10, 2, 2).generate(7);
        let b = BlobSpec::new(10, 2, 2).generate(7);
        assert_eq!(a.x, b.x);
    }
}
