//! Sparsity-controlled synthetic data (paper Q4).
//!
//! Starts from Gaussian blobs and zeroes entries until the target
//! sparsity degree is reached — "sparse degree 0.2, that is, 20% of the
//! elements are 0" (§5.5). Cluster structure survives because zeroing is
//! independent of the label, mimicking missing profile values / one-hot
//! padding.

use super::blobs::{BlobSpec, Dataset};
use crate::util::prng::Prg;

/// Generate an n×d dataset with `k` latent clusters where `sparsity`
/// fraction of entries are exactly zero.
pub fn generate(n: usize, d: usize, k: usize, sparsity: f64, seed: u128) -> Dataset {
    assert!((0.0..=1.0).contains(&sparsity));
    let mut spec = BlobSpec::new(n, d, k);
    spec.spread = 0.04;
    let mut ds = spec.generate(seed);
    let mut prg = Prg::new(seed ^ 0x5AA5);
    for v in ds.x.iter_mut() {
        if prg.next_f64() < sparsity {
            *v = 0.0;
        }
    }
    ds
}

/// Measured fraction of exact zeros.
pub fn measured_sparsity(ds: &Dataset) -> f64 {
    ds.x.iter().filter(|&&v| v == 0.0).count() as f64 / ds.x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_target_sparsity() {
        for target in [0.0, 0.2, 0.5, 0.9, 0.99] {
            let ds = generate(400, 10, 2, target, 3);
            let got = measured_sparsity(&ds);
            assert!((got - target).abs() < 0.05, "target {target} got {got}");
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(50, 4, 2, 0.5, 9);
        let b = generate(50, 4, 2, 0.5, 9);
        assert_eq!(a.x, b.x);
    }
}
