//! Synthetic two-party fraud-detection dataset (paper Q5 substitution).
//!
//! The production data (Ant payment company × merchant) is proprietary;
//! we generate a dataset with the same shape and the property the Q5
//! experiment actually tests: **fraud is only well-separated in the
//! *joint* feature space**. Party A (payment) holds 18 transaction/user
//! features, party B (merchant) holds 24 behaviour features; each side
//! alone carries a weak, noisy fraud signal, so single-party clustering
//! scores distinctly worse than joint clustering — reproducing the
//! 0.62-vs-0.86 Jaccard gap in *shape*.

use super::blobs::Dataset;
use crate::util::prng::Prg;

/// Payment-company feature count (party A).
pub const D_PAYMENT: usize = 18;
/// Merchant feature count (party B).
pub const D_MERCHANT: usize = 24;

/// A generated fraud dataset with ground-truth outliers.
#[derive(Debug, Clone)]
pub struct FraudDataset {
    pub data: Dataset,
    /// Ground-truth fraud indices (sorted).
    pub outliers: Vec<usize>,
    pub d_payment: usize,
}

/// Generate `n` transactions with `fraud_rate` fraction of fraud.
///
/// Normal transactions form a few dense behavioural clusters; fraud
/// sits in a sparse shell far from all normal clusters — but only a
/// *subset* of the displacement lives in each party's features, with
/// heavy per-party noise, so either side alone misses a large share.
pub fn generate(n: usize, fraud_rate: f64, seed: u128) -> FraudDataset {
    let d = D_PAYMENT + D_MERCHANT;
    let mut prg = Prg::new(seed ^ 0xF4A0D);
    let n_fraud = ((n as f64) * fraud_rate).round() as usize;
    let clusters = 3usize;
    // Normal behavioural cluster centres (both feature spaces).
    let mut centres = vec![0.0; clusters * d];
    for c in centres.iter_mut() {
        *c = 0.25 + 0.5 * prg.next_f64();
    }
    let mut x = vec![0.0; n * d];
    let mut labels = vec![0usize; n];
    let mut outliers = Vec::with_capacity(n_fraud);
    for i in 0..n {
        let is_fraud = i % (n / n_fraud.max(1)).max(1) == 0 && outliers.len() < n_fraud;
        if is_fraud {
            outliers.push(i);
            labels[i] = clusters; // fraud pseudo-label
            let kind = prg.next_f64();
            if kind < 0.07 {
                // Type 0 (~7%): behaviourally indistinguishable fraud
                // (e.g. account takeover mimicking the victim) — no
                // detector can catch these; they bound J below 1.0 for
                // every model, as in the paper's 0.86 ceiling.
                let g = prg.next_below(clusters as u64) as usize;
                for l in 0..d {
                    x[i * d + l] =
                        (centres[g * d + l] + 0.06 * prg.next_gaussian()).clamp(0.0, 1.0);
                }
            } else if kind < 0.07 + 0.62 {
                // Type 1 (~62%): anomalous *payment* behaviour — shell
                // values in A's features, perfectly normal merchant view.
                let g = prg.next_below(clusters as u64) as usize;
                for l in 0..D_PAYMENT {
                    let shell = if prg.next_f64() < 0.5 { 0.02 } else { 0.98 };
                    x[i * d + l] = shell + 0.02 * prg.next_gaussian();
                }
                for l in D_PAYMENT..d {
                    x[i * d + l] =
                        (centres[g * d + l] + 0.06 * prg.next_gaussian()).clamp(0.0, 1.0);
                }
            } else {
                // Type 2 (~31%): *cluster-mismatched* — payment features
                // of one behavioural cluster, merchant features of a
                // different one. Each party's marginal view is perfectly
                // normal; only the joint space exposes the inconsistency.
                let g1 = prg.next_below(clusters as u64) as usize;
                let g2 = (g1 + 1 + prg.next_below(clusters as u64 - 1) as usize) % clusters;
                for l in 0..D_PAYMENT {
                    x[i * d + l] =
                        (centres[g1 * d + l] + 0.06 * prg.next_gaussian()).clamp(0.0, 1.0);
                }
                for l in D_PAYMENT..d {
                    x[i * d + l] =
                        (centres[g2 * d + l] + 0.06 * prg.next_gaussian()).clamp(0.0, 1.0);
                }
            }
        } else {
            let g = prg.next_below(clusters as u64) as usize;
            labels[i] = g;
            for l in 0..d {
                x[i * d + l] = (centres[g * d + l] + 0.06 * prg.next_gaussian()).clamp(0.0, 1.0);
            }
        }
    }
    for v in x.iter_mut() {
        *v = v.clamp(0.0, 1.0);
    }
    FraudDataset {
        data: Dataset { n, d, x, labels },
        outliers,
        d_payment: D_PAYMENT,
    }
}

impl FraudDataset {
    /// The payment company's single-party view (first 18 columns).
    pub fn payment_only(&self) -> Dataset {
        let d = self.d_payment;
        let mut x = Vec::with_capacity(self.data.n * d);
        for i in 0..self.data.n {
            x.extend_from_slice(&self.data.row(i)[..d]);
        }
        Dataset { n: self.data.n, d, x, labels: self.data.labels.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_rate() {
        let f = generate(1000, 0.05, 1);
        assert_eq!(f.data.d, 42);
        assert_eq!(f.data.n, 1000);
        let rate = f.outliers.len() as f64 / 1000.0;
        assert!((rate - 0.05).abs() < 0.01, "rate {rate}");
        assert!(f.data.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn payment_view_is_prefix_columns() {
        let f = generate(100, 0.05, 2);
        let p = f.payment_only();
        assert_eq!(p.d, D_PAYMENT);
        assert_eq!(p.row(3), &f.data.row(3)[..D_PAYMENT]);
    }
}
