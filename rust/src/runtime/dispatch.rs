//! Global matmul dispatch: PJRT artifacts when loaded and profitable,
//! native blocked matmul otherwise.
//!
//! With the `pjrt` feature, the PJRT client is not `Send` (it holds `Rc`
//! internals), so a single **service thread** owns the `ArtifactStore`;
//! party threads submit requests over a channel. This also serializes
//! device access, which the CPU plugin requires anyway. Small shapes stay
//! native — per-call dispatch overhead dominates below
//! [`DISPATCH_THRESHOLD`].
//!
//! Without the feature (the default offline build), every entry point
//! compiles to the native fallback: `init` reports the runtime is
//! unavailable, `matmul` runs the blocked kernel, and the fused paths
//! return `None` so callers fall back.

use crate::ring::matrix::Mat;
use crate::util::error::Result;
use std::path::Path;

/// Minimum multiply-accumulate count before PJRT dispatch pays off.
pub const DISPATCH_THRESHOLD: usize = 1 << 22;

#[cfg(feature = "pjrt")]
mod service {
    use super::DISPATCH_THRESHOLD;
    use crate::runtime::artifact::ArtifactStore;
    use crate::runtime::tiled;
    use crate::ring::matrix::Mat;
    use crate::util::error::{Error, Result};
    use std::path::{Path, PathBuf};
    use std::sync::mpsc::{channel, Sender};
    use std::sync::{Mutex, OnceLock};

    enum Request {
        Matmul(Mat, Mat, Sender<Result<Mat>>),
        Esd(Mat, Mat, Sender<Result<Mat>>),
        KmeansStep(Vec<f32>, Vec<f32>, usize, usize, usize, Sender<Result<(Vec<f32>, Vec<f32>)>>),
    }

    static SERVICE: OnceLock<Mutex<Sender<Request>>> = OnceLock::new();

    /// Load artifacts from `dir` and start the service thread (idempotent).
    pub fn init(dir: &Path) -> Result<()> {
        if SERVICE.get().is_some() {
            return Ok(());
        }
        // Probe the manifest on the caller thread for a crisp error.
        if !dir.join("manifest.tsv").exists() {
            return Err(Error::Runtime(format!(
                "no artifacts at {} — run `make artifacts`",
                dir.display()
            )));
        }
        let dir: PathBuf = dir.to_path_buf();
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        // The PJRT C API client is pinned to one thread for its lifetime,
        // so this is a single long-lived service thread, not protocol
        // fan-out — pool::run_pair/parallel_map only cover scoped spawns.
        // lint:allow(no-rogue-threads): one long-lived PJRT service thread, not protocol fan-out
        std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                let store = match ArtifactStore::load(&dir) {
                    Ok(s) => {
                        let _ = ready_tx.send(Ok(()));
                        s
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Matmul(a, b, reply) => {
                            let _ = reply.send(tiled::ring_matmul(&store, &a, &b));
                        }
                        Request::Esd(x, mu, reply) => {
                            let _ = reply.send(tiled::esd(&store, &x, &mu));
                        }
                        Request::KmeansStep(x, mu, n, d, k, reply) => {
                            let name = format!("kmeans_step_{n}x{d}x{k}");
                            let r = match store.get(&name) {
                                Some(e) => crate::runtime::executor::execute_f32(e, &[&x, &mu])
                                    .map(|out| {
                                        let mut it = out.into_iter();
                                        (
                                            it.next().unwrap_or_default(),
                                            it.next().unwrap_or_default(),
                                        )
                                    }),
                                None => Err(Error::Runtime(format!("no artifact {name}"))),
                            };
                            let _ = reply.send(r);
                        }
                    }
                }
            })
            .expect("spawn pjrt service");
        ready_rx.recv().map_err(|_| Error::Runtime("pjrt service died".into()))??;
        let _ = SERVICE.set(Mutex::new(tx));
        Ok(())
    }

    /// Whether the PJRT service is running.
    pub fn available() -> bool {
        SERVICE.get().is_some()
    }

    fn submit<T>(make: impl FnOnce(Sender<Result<T>>) -> Request) -> Option<T> {
        let svc = SERVICE.get()?;
        let (tx, rx) = channel();
        svc.lock().ok()?.send(make(tx)).ok()?;
        rx.recv().ok()?.ok()
    }

    pub fn matmul(a: &Mat, b: &Mat) -> Mat {
        let work = a.rows * a.cols * b.cols;
        if work >= DISPATCH_THRESHOLD && available() {
            if let Some(out) = submit(|tx| Request::Matmul(a.clone(), b.clone(), tx)) {
                return out;
            }
        }
        crate::runtime::pool::matmul_auto(a, b)
    }

    pub fn esd(x: &Mat, mu: &Mat) -> Option<Mat> {
        if !available() {
            return None;
        }
        submit(|tx| Request::Esd(x.clone(), mu.clone(), tx))
    }

    pub fn kmeans_step(
        x: &[f32],
        mu: &[f32],
        n: usize,
        d: usize,
        k: usize,
    ) -> Option<(Vec<f32>, Vec<f32>)> {
        if !available() {
            return None;
        }
        submit(|tx| Request::KmeansStep(x.to_vec(), mu.to_vec(), n, d, k, tx))
    }
}

/// Load artifacts and start the service thread (idempotent). Without the
/// `pjrt` feature this always reports the runtime as unavailable.
pub fn init(dir: &Path) -> Result<()> {
    #[cfg(feature = "pjrt")]
    {
        service::init(dir)
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = dir;
        Err(crate::util::error::Error::Runtime(
            "built without the `pjrt` feature — native kernels only".into(),
        ))
    }
}

/// Whether the PJRT service is running.
pub fn available() -> bool {
    #[cfg(feature = "pjrt")]
    {
        service::available()
    }
    #[cfg(not(feature = "pjrt"))]
    {
        false
    }
}

/// Ring matmul with automatic backend choice. The native path fans out
/// across [`crate::runtime::pool::global_threads`] row-block workers
/// for large products (bit-identical to the sequential kernel).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    #[cfg(feature = "pjrt")]
    {
        service::matmul(a, b)
    }
    #[cfg(not(feature = "pjrt"))]
    {
        crate::runtime::pool::matmul_auto(a, b)
    }
}

/// Fused D' tile via the Pallas ESD artifact (`None` → caller falls back).
pub fn esd(x: &Mat, mu: &Mat) -> Option<Mat> {
    #[cfg(feature = "pjrt")]
    {
        service::esd(x, mu)
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = (x, mu);
        None
    }
}

/// One plaintext Lloyd step through the `kmeans_step` artifact.
pub fn kmeans_step(
    x: &[f32],
    mu: &[f32],
    n: usize,
    d: usize,
    k: usize,
) -> Option<(Vec<f32>, Vec<f32>)> {
    #[cfg(feature = "pjrt")]
    {
        service::kmeans_step(x, mu, n, d, k)
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let _ = (x, mu, n, d, k);
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_fallback_matches_blocked_matmul() {
        let a = Mat::from_vec(2, 2, vec![1, 2, 3, 4]);
        let b = Mat::from_vec(2, 2, vec![5, 6, 7, 8]);
        assert_eq!(matmul(&a, &b), a.matmul(&b));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn without_feature_runtime_is_unavailable() {
        assert!(!available());
        assert!(init(Path::new("artifacts")).is_err());
        let x = Mat::zeros(2, 2);
        assert!(esd(&x, &x).is_none());
        assert!(kmeans_step(&[0.0], &[0.0], 1, 1, 1).is_none());
    }
}
