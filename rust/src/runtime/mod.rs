//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts and execute
//! them from the Rust hot path.
//!
//! `make artifacts` lowers the L2 graphs (which call the L1 Pallas
//! kernels) to HLO text; [`artifact::ArtifactStore`] parses
//! `artifacts/manifest.tsv`, compiles every entry once on the PJRT CPU
//! client, and [`executor`]/[`tiled`] dispatch party-local linear
//! algebra (ring matmuls, the fused ESD tile, plaintext Lloyd steps)
//! onto the compiled executables — Python never runs at protocol time.
//!
//! The whole PJRT path is gated behind the off-by-default `pjrt` cargo
//! feature (it needs the external `xla` crate and a Python/JAX toolchain
//! to build the artifacts). Without the feature, [`dispatch`] routes
//! every call to the native blocked kernels — protocol results are
//! identical; only large-shape throughput differs.

#[cfg(feature = "pjrt")]
pub mod artifact;
pub mod dispatch;
#[cfg(feature = "pjrt")]
pub mod executor;
pub mod tile_select;
#[cfg(feature = "pjrt")]
pub mod tiled;

#[cfg(feature = "pjrt")]
pub use artifact::{ArtifactStore, Entry};
