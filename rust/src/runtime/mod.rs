//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts and execute
//! them from the Rust hot path.
//!
//! `make artifacts` lowers the L2 graphs (which call the L1 Pallas
//! kernels) to HLO text; [`artifact::ArtifactStore`] parses
//! `artifacts/manifest.tsv`, compiles every entry once on the PJRT CPU
//! client, and [`executor`]/[`tiled`] dispatch party-local linear
//! algebra (ring matmuls, the fused ESD tile, plaintext Lloyd steps)
//! onto the compiled executables — Python never runs at protocol time.
//!
//! The whole PJRT path is gated behind the off-by-default `pjrt` cargo
//! feature (it needs the external `xla` crate and a Python/JAX toolchain
//! to build the artifacts). Without the feature, [`dispatch`] routes
//! every call to the native blocked kernels — protocol results are
//! identical; only large-shape throughput differs.
//!
//! Independent of PJRT, [`pool`] is the multi-core execution layer: a
//! dependency-free `std::thread::scope` fan-out that shards offline
//! triple fabrication and the online plaintext-side matrix work across
//! a configurable worker count ([`pool::Parallelism`]) with a hard
//! bit-determinism contract — `threads = 1` and `threads = N` produce
//! identical shares, reveals and meter readings.
//!
//! [`simd`] is the orthogonal packed-lane layer: explicit `[u64; N]`
//! lane blocks ([`simd::U64x4`]/[`simd::U64x8`]) that stable rustc
//! autovectorizes, behind the crypto hot paths (Speck CTR batches,
//! lockstep Hash256, the 64×64 IKNP bit transpose, Beaver/truncation
//! sweeps). Its knob ([`simd::Lanes`]) carries the same bit-determinism
//! contract as the pool, and the two compose: pool workers run packed
//! sweeps inside their chunks, so the speedups multiply.

#[cfg(feature = "pjrt")]
pub mod artifact;
pub mod dispatch;
#[cfg(feature = "pjrt")]
pub mod executor;
pub mod pool;
pub mod simd;
pub mod tile_select;
#[cfg(feature = "pjrt")]
pub mod tiled;
#[cfg(feature = "pjrt")]
pub mod xla_stub;

#[cfg(feature = "pjrt")]
pub use artifact::{ArtifactStore, Entry};
pub use pool::Parallelism;
pub use simd::Lanes;
