//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts and execute
//! them from the Rust hot path.
//!
//! `make artifacts` lowers the L2 graphs (which call the L1 Pallas
//! kernels) to HLO text; [`artifact::ArtifactStore`] parses
//! `artifacts/manifest.tsv`, compiles every entry once on the PJRT CPU
//! client, and [`executor`]/[`tiled`] dispatch party-local linear
//! algebra (ring matmuls, the fused ESD tile, plaintext Lloyd steps)
//! onto the compiled executables — Python never runs at protocol time.

pub mod artifact;
pub mod dispatch;
pub mod executor;
pub mod tiled;

pub use artifact::{ArtifactStore, Entry};
