//! Packed-lane SIMD kernels for the crypto hot paths.
//!
//! The paper's efficiency story leans on vectorization in both phases
//! (§4: "we take advantage of the vectorization techniques in both
//! online and offline phases"). This module is the portable packed-lane
//! layer that delivers it without any `unsafe`, nightly intrinsics or
//! external crates: [`U64s`] wraps a fixed `[u64; N]` block and every
//! operation is a straight-line per-lane loop over independent lanes —
//! exactly the shape stable rustc autovectorizes to SSE/AVX (or NEON)
//! at `opt-level ≥ 2`. The widths are [`U64x4`] and [`U64x8`]; the
//! per-run knob is [`Lanes`] (CLI `--lanes {auto,1,4,8}`), mirroring
//! [`crate::runtime::pool::Parallelism`] exactly:
//!
//! * **offline** — Speck-128 counter-mode batches
//!   ([`crate::util::cipher::Speck128::encrypt_blocks`]) feed the bulk
//!   PRG draws behind share expansion and triple fabrication, and the
//!   multi-key [`crate::util::cipher::SpeckMulti`] drives the lockstep
//!   [`crate::util::hash::hash256_many`] used by the per-OT mask loop
//!   of the IKNP extension;
//! * **online** — the Beaver payload/recombination sweeps of
//!   [`crate::ss::matmul`], the local truncation of [`crate::ss::trunc`]
//!   and the dense/CSR row kernels ([`axpy`]) all run as packed sweeps.
//!
//! **Determinism is the same hard contract as the thread pool.** The
//! lane width is purely a throughput knob: every packed kernel computes
//! the same elementwise wrapping/XOR arithmetic as its scalar reference,
//! so shares, reveals, the recorded offline `Demand` and every
//! [`crate::net::Meter`] flight/byte counter are bit-identical for
//! `lanes = 1` and `lanes = N` (regression-tested in
//! `rust/tests/simd.rs` and `rust/tests/lanes.rs`). Packed kernels
//! compose with the [`crate::runtime::pool`] fan-out — workers run
//! packed sweeps inside their index-ordered chunks — so the two
//! speedups multiply.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Packed-lane width knob for a protocol run (the `--lanes {auto,1,4,8}`
/// CLI flag and the `lanes` field of
/// [`crate::kmeans::config::SecureKmeansConfig`] /
/// [`crate::serve::driver::ServeConfig`]), mirroring
/// [`crate::runtime::pool::Parallelism`].
///
/// Purely a throughput knob: all protocol outputs and meters are
/// bit-identical for any value (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lanes {
    /// Packed lane width for party-local kernels: 1 (scalar reference
    /// path), 4 ([`U64x4`]) or 8 ([`U64x8`]).
    pub width: usize,
}

impl Lanes {
    /// Request `width` lanes, rounded down to the nearest supported
    /// block width (8, 4 or 1).
    pub fn new(width: usize) -> Lanes {
        let width = if width >= 8 {
            8
        } else if width >= 4 {
            4
        } else {
            1
        };
        Lanes { width }
    }

    /// Scalar reference path (the default — no behavioural or perf
    /// surprise for small runs and tests, matching
    /// [`crate::runtime::pool::Parallelism::sequential`]).
    pub fn scalar() -> Lanes {
        Lanes { width: 1 }
    }

    /// The widest supported block ([`U64x8`] — two AVX2 registers or
    /// one AVX-512 register per block after autovectorization).
    pub fn auto() -> Lanes {
        Lanes { width: 8 }
    }
}

impl Default for Lanes {
    fn default() -> Self {
        Lanes::scalar()
    }
}

/// Process-wide default lane width, consulted by the deep call sites
/// that have no configuration path of their own (the PRG's bulk fill
/// inside a dealer, the axpy inside a Beaver recombination closure).
/// Set once per run by the protocol drivers from their config; safe to
/// race because the value can only change *throughput*, never an output
/// bit — the same contract as
/// [`crate::runtime::pool::set_global_threads`].
static GLOBAL_LANES: AtomicUsize = AtomicUsize::new(1);

/// Set the process-wide default lane width (rounded down to 8, 4 or 1).
pub fn set_global_lanes(width: usize) {
    GLOBAL_LANES.store(Lanes::new(width).width, Ordering::Relaxed);
}

/// The process-wide default lane width (1, 4 or 8).
pub fn global_lanes() -> usize {
    GLOBAL_LANES.load(Ordering::Relaxed).max(1)
}

/// A block of `N` independent `u64` lanes.
///
/// Every method is a straight-line per-lane loop with no cross-lane
/// dependency, so stable rustc autovectorizes it; semantics are exactly
/// the scalar `wrapping_*` / bit operations applied lane by lane, which
/// is what makes packed kernels bit-identical to their scalar
/// references by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct U64s<const N: usize>(pub [u64; N]);

/// Four-lane block (one AVX2 register).
pub type U64x4 = U64s<4>;
/// Eight-lane block (two AVX2 registers / one AVX-512 register).
pub type U64x8 = U64s<8>;

impl<const N: usize> U64s<N> {
    /// Broadcast one value into every lane.
    #[inline(always)]
    pub fn splat(v: u64) -> Self {
        U64s([v; N])
    }

    /// Load a block from the first `N` elements of a slice.
    #[inline(always)]
    pub fn from_slice(s: &[u64]) -> Self {
        let mut a = [0u64; N];
        a.copy_from_slice(&s[..N]);
        U64s(a)
    }

    /// Store the block into the first `N` elements of a slice.
    #[inline(always)]
    pub fn write(self, out: &mut [u64]) {
        out[..N].copy_from_slice(&self.0);
    }

    /// Lanewise wrapping add.
    #[inline(always)]
    pub fn add(self, o: Self) -> Self {
        let mut a = self.0;
        for i in 0..N {
            a[i] = a[i].wrapping_add(o.0[i]);
        }
        U64s(a)
    }

    /// Lanewise wrapping subtract.
    #[inline(always)]
    pub fn sub(self, o: Self) -> Self {
        let mut a = self.0;
        for i in 0..N {
            a[i] = a[i].wrapping_sub(o.0[i]);
        }
        U64s(a)
    }

    /// Lanewise wrapping multiply.
    #[inline(always)]
    pub fn mul(self, o: Self) -> Self {
        let mut a = self.0;
        for i in 0..N {
            a[i] = a[i].wrapping_mul(o.0[i]);
        }
        U64s(a)
    }

    /// Lanewise XOR.
    #[inline(always)]
    pub fn xor(self, o: Self) -> Self {
        let mut a = self.0;
        for i in 0..N {
            a[i] ^= o.0[i];
        }
        U64s(a)
    }

    /// Lanewise wrapping negation.
    #[inline(always)]
    pub fn neg(self) -> Self {
        let mut a = self.0;
        for i in 0..N {
            a[i] = a[i].wrapping_neg();
        }
        U64s(a)
    }

    /// Lanewise rotate left.
    #[inline(always)]
    pub fn rotl(self, r: u32) -> Self {
        let mut a = self.0;
        for i in 0..N {
            a[i] = a[i].rotate_left(r);
        }
        U64s(a)
    }

    /// Lanewise rotate right.
    #[inline(always)]
    pub fn rotr(self, r: u32) -> Self {
        let mut a = self.0;
        for i in 0..N {
            a[i] = a[i].rotate_right(r);
        }
        U64s(a)
    }

    /// Lanewise logical shift left.
    #[inline(always)]
    pub fn shl(self, s: u32) -> Self {
        let mut a = self.0;
        for i in 0..N {
            a[i] <<= s;
        }
        U64s(a)
    }

    /// Lanewise logical shift right.
    #[inline(always)]
    pub fn shr(self, s: u32) -> Self {
        let mut a = self.0;
        for i in 0..N {
            a[i] >>= s;
        }
        U64s(a)
    }

    /// Lanewise *arithmetic* shift right (two's-complement sign
    /// preserved — the fixed-point truncation primitive).
    #[inline(always)]
    pub fn sar(self, s: u32) -> Self {
        let mut a = self.0;
        for i in 0..N {
            a[i] = ((a[i] as i64) >> s) as u64;
        }
        U64s(a)
    }
}

/// In-place 64×64 bit-matrix transpose (Hacker's Delight §7-3, scaled
/// to 64 bits), LSB-first convention: after the call,
/// `bit i of out[j] == bit j of in[i]`. This is the cache-blocked core
/// of the IKNP column→row-key transposition — log₂ 64 = 6 butterfly
/// passes of 32 word ops each, instead of 64×64 single-bit probes.
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j: usize = 32;
    let mut m: u64 = 0x0000_0000_FFFF_FFFF;
    while j != 0 {
        let mut k: usize = 0;
        while k < 64 {
            // Swap the (bits j..2j of rows k..k+j) block with the
            // (bits 0..j of rows k+j..k+2j) block, j lanes at a time.
            let t = ((a[k] >> j) ^ a[k + j]) & m;
            a[k] ^= t << j;
            a[k + j] ^= t;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        m ^= m << j;
    }
}

/// `orow[i] += a · brow[i]` (wrapping) — the inner kernel of every
/// dense/CSR row product, dispatched on [`global_lanes`]. Bit-identical
/// for any width: the packed path computes the same lanewise wrapping
/// arithmetic in [`U64s`] blocks with a scalar tail.
#[inline]
pub fn axpy(orow: &mut [u64], a: u64, brow: &[u64]) {
    debug_assert_eq!(orow.len(), brow.len());
    match global_lanes() {
        8 => axpy_blocks::<8>(orow, a, brow),
        4 => axpy_blocks::<4>(orow, a, brow),
        _ => {
            for (o, b) in orow.iter_mut().zip(brow) {
                *o = o.wrapping_add(a.wrapping_mul(*b));
            }
        }
    }
}

#[inline]
fn axpy_blocks<const N: usize>(orow: &mut [u64], a: u64, brow: &[u64]) {
    let av = U64s::<N>::splat(a);
    let mut i = 0;
    while i + N <= orow.len() {
        let o = U64s::<N>::from_slice(&orow[i..]);
        let b = U64s::<N>::from_slice(&brow[i..]);
        o.add(b.mul(av)).write(&mut orow[i..]);
        i += N;
    }
    while i < orow.len() {
        orow[i] = orow[i].wrapping_add(a.wrapping_mul(brow[i]));
        i += 1;
    }
}

/// `dst[i] = a[i] + b[i]` (wrapping) — the Beaver `E`/`F`
/// reconstruction sweep, dispatched on [`global_lanes`].
#[inline]
pub fn add_words(dst: &mut [u64], a: &[u64], b: &[u64]) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    match global_lanes() {
        8 => add_words_blocks::<8>(dst, a, b),
        4 => add_words_blocks::<4>(dst, a, b),
        _ => {
            for i in 0..dst.len() {
                dst[i] = a[i].wrapping_add(b[i]);
            }
        }
    }
}

#[inline]
fn add_words_blocks<const N: usize>(dst: &mut [u64], a: &[u64], b: &[u64]) {
    let mut i = 0;
    while i + N <= dst.len() {
        U64s::<N>::from_slice(&a[i..]).add(U64s::<N>::from_slice(&b[i..])).write(&mut dst[i..]);
        i += N;
    }
    while i < dst.len() {
        dst[i] = a[i].wrapping_add(b[i]);
        i += 1;
    }
}

/// Append `a[i] - b[i]` (wrapping) for every `i` to `out` — the Beaver
/// reveal-payload sweep (`E = A−U`, `F = B−V`), dispatched on
/// [`global_lanes`].
#[inline]
pub fn sub_words_into(out: &mut Vec<u64>, a: &[u64], b: &[u64]) {
    debug_assert_eq!(a.len(), b.len());
    let start = out.len();
    out.resize(start + a.len(), 0);
    let dst = &mut out[start..];
    match global_lanes() {
        8 => sub_words_blocks::<8>(dst, a, b),
        4 => sub_words_blocks::<4>(dst, a, b),
        _ => {
            for i in 0..dst.len() {
                dst[i] = a[i].wrapping_sub(b[i]);
            }
        }
    }
}

#[inline]
fn sub_words_blocks<const N: usize>(dst: &mut [u64], a: &[u64], b: &[u64]) {
    let mut i = 0;
    while i + N <= dst.len() {
        U64s::<N>::from_slice(&a[i..]).sub(U64s::<N>::from_slice(&b[i..])).write(&mut dst[i..]);
        i += N;
    }
    while i < dst.len() {
        dst[i] = a[i].wrapping_sub(b[i]);
        i += 1;
    }
}

/// The SecureML local-truncation sweep, dispatched on [`global_lanes`]:
/// party 0 arithmetic-shifts each share word by `bits`; party 1 negates,
/// shifts, negates back (see [`crate::ss::trunc`]).
pub fn trunc_words(xs: &[u64], party: usize, bits: u32) -> Vec<u64> {
    let mut out = vec![0u64; xs.len()];
    match global_lanes() {
        8 => trunc_words_blocks::<8>(&mut out, xs, party, bits),
        4 => trunc_words_blocks::<4>(&mut out, xs, party, bits),
        _ => {
            for (o, &v) in out.iter_mut().zip(xs) {
                *o = trunc_word(v, party, bits);
            }
        }
    }
    out
}

/// Scalar reference lane of [`trunc_words`].
#[inline(always)]
pub fn trunc_word(v: u64, party: usize, bits: u32) -> u64 {
    if party == 0 {
        ((v as i64) >> bits) as u64
    } else {
        // ⟨x⟩₁' = −((−⟨x⟩₁) >> f)
        (((v.wrapping_neg()) as i64 >> bits) as u64).wrapping_neg()
    }
}

#[inline]
fn trunc_words_blocks<const N: usize>(out: &mut [u64], xs: &[u64], party: usize, bits: u32) {
    let mut i = 0;
    while i + N <= xs.len() {
        let v = U64s::<N>::from_slice(&xs[i..]);
        let t = if party == 0 { v.sar(bits) } else { v.neg().sar(bits).neg() };
        t.write(&mut out[i..]);
        i += N;
    }
    while i < xs.len() {
        out[i] = trunc_word(xs[i], party, bits);
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prg;

    /// Run `f` at the given global lane width, restoring the scalar
    /// default afterwards. (Racing tests can only flip throughput, never
    /// an output bit — the module contract — so no lock is needed.)
    fn with_lanes<T>(width: usize, f: impl FnOnce() -> T) -> T {
        set_global_lanes(width);
        let out = f();
        set_global_lanes(1);
        out
    }

    #[test]
    fn lanes_round_to_supported_widths() {
        assert_eq!(Lanes::new(0).width, 1);
        assert_eq!(Lanes::new(1).width, 1);
        assert_eq!(Lanes::new(3).width, 1);
        assert_eq!(Lanes::new(4).width, 4);
        assert_eq!(Lanes::new(7).width, 4);
        assert_eq!(Lanes::new(8).width, 8);
        assert_eq!(Lanes::new(64).width, 8);
        assert_eq!(Lanes::default(), Lanes::scalar());
        assert_eq!(Lanes::auto().width, 8);
    }

    #[test]
    fn global_lanes_clamps() {
        set_global_lanes(0);
        assert_eq!(global_lanes(), 1);
        set_global_lanes(5);
        assert_eq!(global_lanes(), 4);
        set_global_lanes(1);
    }

    #[test]
    fn lane_ops_match_scalar() {
        let mut p = Prg::new(0x51D);
        for _ in 0..50 {
            let a8: [u64; 8] = std::array::from_fn(|_| p.next_u64());
            let b8: [u64; 8] = std::array::from_fn(|_| p.next_u64());
            let (va, vb) = (U64s(a8), U64s(b8));
            for i in 0..8 {
                assert_eq!(va.add(vb).0[i], a8[i].wrapping_add(b8[i]));
                assert_eq!(va.sub(vb).0[i], a8[i].wrapping_sub(b8[i]));
                assert_eq!(va.mul(vb).0[i], a8[i].wrapping_mul(b8[i]));
                assert_eq!(va.xor(vb).0[i], a8[i] ^ b8[i]);
                assert_eq!(va.neg().0[i], a8[i].wrapping_neg());
                assert_eq!(va.rotl(13).0[i], a8[i].rotate_left(13));
                assert_eq!(va.rotr(8).0[i], a8[i].rotate_right(8));
                assert_eq!(va.shl(5).0[i], a8[i] << 5);
                assert_eq!(va.shr(20).0[i], a8[i] >> 20);
                assert_eq!(va.sar(20).0[i], ((a8[i] as i64) >> 20) as u64);
            }
        }
        assert_eq!(U64x4::splat(7).0, [7u64; 4]);
        let v = U64x8::from_slice(&[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert_eq!(v.0, [1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn transpose64_matches_bit_probe_reference() {
        let mut p = Prg::new(0x7A0);
        for _ in 0..10 {
            let orig: [u64; 64] = std::array::from_fn(|_| p.next_u64());
            let mut t = orig;
            transpose64(&mut t);
            for i in 0..64 {
                for j in 0..64 {
                    assert_eq!(
                        (t[j] >> i) & 1,
                        (orig[i] >> j) & 1,
                        "bit ({i},{j})"
                    );
                }
            }
            // Involution.
            transpose64(&mut t);
            assert_eq!(t, orig);
        }
    }

    #[test]
    fn axpy_is_width_independent_at_odd_tails() {
        let mut p = Prg::new(0xA11);
        for len in [0usize, 1, 3, 4, 7, 8, 9, 31, 64, 65] {
            let base = p.u64s(len);
            let b = p.u64s(len);
            let a = p.next_u64();
            let mut want = base.clone();
            for i in 0..len {
                want[i] = want[i].wrapping_add(a.wrapping_mul(b[i]));
            }
            for width in [1usize, 4, 8] {
                let mut got = base.clone();
                with_lanes(width, || axpy(&mut got, a, &b));
                assert_eq!(got, want, "len={len} width={width}");
            }
        }
    }

    #[test]
    fn add_sub_trunc_sweeps_are_width_independent() {
        let mut p = Prg::new(0xADD);
        for len in [0usize, 1, 5, 8, 13, 40] {
            let a = p.u64s(len);
            let b = p.u64s(len);
            let mut want_add = vec![0u64; len];
            let mut want_sub = Vec::new();
            for i in 0..len {
                want_add[i] = a[i].wrapping_add(b[i]);
                want_sub.push(a[i].wrapping_sub(b[i]));
            }
            for width in [1usize, 4, 8] {
                with_lanes(width, || {
                    let mut got = vec![0u64; len];
                    add_words(&mut got, &a, &b);
                    assert_eq!(got, want_add, "add len={len} width={width}");
                    let mut got_sub = Vec::new();
                    sub_words_into(&mut got_sub, &a, &b);
                    assert_eq!(got_sub, want_sub, "sub len={len} width={width}");
                    for party in [0usize, 1] {
                        let want: Vec<u64> =
                            a.iter().map(|&v| trunc_word(v, party, 20)).collect();
                        assert_eq!(
                            trunc_words(&a, party, 20),
                            want,
                            "trunc party={party} len={len} width={width}"
                        );
                    }
                });
            }
        }
    }

    #[test]
    fn sub_words_into_appends_after_existing_payload() {
        let mut out = vec![99u64];
        with_lanes(8, || {
            sub_words_into(&mut out, &[10, 20, 30], &[1, 2, 3]);
        });
        assert_eq!(out, vec![99, 9, 18, 27]);
    }
}
