//! Typed execution of compiled artifacts.

use super::artifact::Entry;
use crate::runtime::xla_stub as xla; // swap for the real `xla` crate to execute
use crate::util::error::{Error, Result};

fn shape_i64(shape: &[usize]) -> Vec<i64> {
    shape.iter().map(|&x| x as i64).collect()
}

/// Execute an i64 entry: `inputs[i]` is the row-major buffer for the
/// baked input shape `entry.in_shapes[i]`. Returns the flattened outputs
/// (one buffer per tuple element).
pub fn execute_i64(entry: &Entry, inputs: &[&[i64]]) -> Result<Vec<Vec<i64>>> {
    if entry.dtype != "i64" {
        return Err(Error::Runtime(format!("{} is {} not i64", entry.name, entry.dtype)));
    }
    let mut lits = Vec::with_capacity(inputs.len());
    for (&buf, shape) in inputs.iter().zip(&entry.in_shapes) {
        let expected: usize = shape.iter().product();
        if buf.len() != expected {
            return Err(Error::Runtime(format!(
                "{}: input len {} != shape {:?}",
                entry.name,
                buf.len(),
                shape
            )));
        }
        lits.push(xla::Literal::vec1(buf).reshape(&shape_i64(shape))?);
    }
    let result = entry.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
    let parts = result.to_tuple()?;
    let mut out = Vec::with_capacity(parts.len());
    for p in parts {
        out.push(p.to_vec::<i64>()?);
    }
    Ok(out)
}

/// Execute an f32 entry.
pub fn execute_f32(entry: &Entry, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
    if entry.dtype != "f32" {
        return Err(Error::Runtime(format!("{} is {} not f32", entry.name, entry.dtype)));
    }
    let mut lits = Vec::with_capacity(inputs.len());
    for (&buf, shape) in inputs.iter().zip(&entry.in_shapes) {
        lits.push(xla::Literal::vec1(buf).reshape(&shape_i64(shape))?);
    }
    let result = entry.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
    let parts = result.to_tuple()?;
    let mut out = Vec::with_capacity(parts.len());
    for p in parts {
        out.push(p.to_vec::<f32>()?);
    }
    Ok(out)
}
