//! Tiled dispatch: arbitrary-shape ring matmuls onto the fixed-shape
//! AOT artifacts.
//!
//! HLO bakes shapes, so `make artifacts` exports canonical square tiles
//! (128³, 256³). This module pads the operands with zeros (exact in
//! Z_2^64), walks the block grid calling the compiled executable per
//! (i, s, j) tile, and accumulates partial products — the same schedule
//! the Pallas kernel's `BlockSpec` expresses on-device, driven from Rust.

use super::artifact::{ArtifactStore, Entry};
use super::executor::execute_i64;
use crate::ring::matrix::Mat;
use crate::util::error::{Error, Result};

/// Pick the largest exported tile that fits the problem (fit rule in
/// [`crate::runtime::tile_select::pick_tile_size`], which is where the
/// unit tests live — this wrapper only maps artifact entries to their
/// baked tile sizes).
fn pick_tile<'a>(store: &'a ArtifactStore, m: usize, t: usize, n: usize) -> Option<&'a Entry> {
    let entries = store.by_kind("ring_matmul");
    let sizes: Vec<usize> = entries.iter().map(|e| e.in_shapes[0][0]).collect();
    let b = crate::runtime::tile_select::pick_tile_size_par(
        &sizes,
        m,
        t,
        n,
        crate::runtime::pool::global_threads(),
    )?;
    entries.into_iter().find(|e| e.in_shapes[0][0] == b)
}

/// Copy a padded block of `src` (rows0..rows0+b, cols0..cols0+b) into a
/// b×b i64 buffer.
fn block_of(src: &Mat, r0: usize, c0: usize, b: usize) -> Vec<i64> {
    let mut out = vec![0i64; b * b];
    let rmax = (r0 + b).min(src.rows);
    let cmax = (c0 + b).min(src.cols);
    for r in r0..rmax {
        let srow = src.row(r);
        let orow = &mut out[(r - r0) * b..];
        for c in c0..cmax {
            orow[c - c0] = srow[c] as i64;
        }
    }
    out
}

/// `a (m×t) · b (t×n) mod 2^64` through the PJRT ring-matmul artifact.
pub fn ring_matmul(store: &ArtifactStore, a: &Mat, bm: &Mat) -> Result<Mat> {
    if a.cols != bm.rows {
        return Err(Error::Shape(format!(
            "tiled matmul {}x{} · {}x{}",
            a.rows, a.cols, bm.rows, bm.cols
        )));
    }
    let (m, t, n) = (a.rows, a.cols, bm.cols);
    let entry =
        pick_tile(store, m, t, n).ok_or_else(|| Error::Runtime("no ring_matmul artifact".into()))?;
    let blk = entry.in_shapes[0][0];
    let (mb, tb, nb) = (m.div_ceil(blk), t.div_ceil(blk), n.div_ceil(blk));
    let mut out = Mat::zeros(m, n);
    for i in 0..mb {
        for j in 0..nb {
            // Accumulate over the inner dimension.
            let mut acc = vec![0u64; blk * blk];
            for s in 0..tb {
                let ab = block_of(a, i * blk, s * blk, blk);
                let bb = block_of(bm, s * blk, j * blk, blk);
                let prod = execute_i64(entry, &[&ab, &bb])?;
                for (dst, &src) in acc.iter_mut().zip(&prod[0]) {
                    *dst = dst.wrapping_add(src as u64);
                }
            }
            // Write back the unpadded region.
            let rmax = ((i + 1) * blk).min(m);
            let cmax = ((j + 1) * blk).min(n);
            for r in i * blk..rmax {
                for c in j * blk..cmax {
                    out.set(r, c, acc[(r - i * blk) * blk + (c - j * blk)]);
                }
            }
        }
    }
    Ok(out)
}

/// Fused distance tile `D' = U − 2·X·μᵀ` via the Pallas ESD artifact:
/// pads d→128 columns and k→16 clusters with zeros (exact), walks
/// 256-row blocks. Returns n×k at scale 2f.
pub fn esd(store: &ArtifactStore, x: &Mat, mu: &Mat) -> Result<Mat> {
    let entry = store
        .by_kind("esd")
        .first()
        .copied()
        .ok_or_else(|| Error::Runtime("no esd artifact".into()))?;
    let bn = entry.in_shapes[0][0]; // 256
    let dp = entry.in_shapes[0][1]; // 128
    let kp = entry.in_shapes[1][0]; // 16
    let (n, d) = (x.rows, x.cols);
    let k = mu.rows;
    if d > dp || k > kp {
        return Err(Error::Runtime(format!(
            "esd artifact supports d ≤ {dp}, k ≤ {kp} (got {d}, {k})"
        )));
    }
    // Pad μ once.
    let mut mu_pad = vec![0i64; kp * dp];
    for j in 0..k {
        for l in 0..d {
            mu_pad[j * dp + l] = mu.at(j, l) as i64;
        }
    }
    let mut out = Mat::zeros(n, k);
    let blocks = n.div_ceil(bn);
    for bi in 0..blocks {
        let mut xb = vec![0i64; bn * dp];
        let rmax = ((bi + 1) * bn).min(n);
        for r in bi * bn..rmax {
            for l in 0..d {
                xb[(r - bi * bn) * dp + l] = x.at(r, l) as i64;
            }
        }
        let res = execute_i64(entry, &[&xb, &mu_pad])?;
        for r in bi * bn..rmax {
            for j in 0..k {
                out.set(r, j, res[0][(r - bi * bn) * kp + j] as u64);
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    // Exercised by rust/tests/runtime_pjrt.rs (needs built artifacts).
}
