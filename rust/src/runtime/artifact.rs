//! Artifact manifest parsing and one-time PJRT compilation.

use crate::runtime::xla_stub as xla; // swap for the real `xla` crate to execute
use crate::util::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A compiled artifact with its (baked) shapes.
pub struct Entry {
    pub name: String,
    /// Kind tag from the manifest: `ring_matmul`, `esd`, `kmeans_step`.
    pub kind: String,
    /// Element type: `i64` or `f32`.
    pub dtype: String,
    pub in_shapes: Vec<Vec<usize>>,
    pub out_shape: Vec<usize>,
    pub exe: xla::PjRtLoadedExecutable,
}

/// All compiled artifacts plus the PJRT client that owns them.
pub struct ArtifactStore {
    pub client: xla::PjRtClient,
    // BTreeMap so every registry walk (names, by_kind, dispatch probes)
    // sees the same name order in every process (ppkm-lint rule
    // no-unordered-iteration).
    entries: BTreeMap<String, Entry>,
}

fn parse_shape(s: &str) -> Vec<usize> {
    s.split(',').map(|x| x.parse().expect("shape int")).collect()
}

impl ArtifactStore {
    /// Load and compile every entry of `dir/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<ArtifactStore> {
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                manifest.display()
            ))
        })?;
        let client = xla::PjRtClient::cpu()?;
        let mut entries = BTreeMap::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 6 {
                return Err(Error::Runtime(format!("malformed manifest line: {line}")));
            }
            let (name, file, kind, dtype, shapes, out_shape) =
                (cols[0], cols[1], cols[2], cols[3], cols[4], cols[5]);
            let proto = xla::HloModuleProto::from_text_file(
                dir.join(file).to_str().expect("utf8 path"),
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            entries.insert(
                name.to_string(),
                Entry {
                    name: name.to_string(),
                    kind: kind.to_string(),
                    dtype: dtype.to_string(),
                    in_shapes: shapes.split(';').map(parse_shape).collect(),
                    out_shape: parse_shape(out_shape),
                    exe,
                },
            );
        }
        Ok(ArtifactStore { client, entries })
    }

    pub fn get(&self, name: &str) -> Option<&Entry> {
        self.entries.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        // BTreeMap keys are already in ascending (sorted) order.
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    /// Entries of a given kind, in name order (the map's key order).
    pub fn by_kind(&self, kind: &str) -> Vec<&Entry> {
        self.entries.values().filter(|e| e.kind == kind).collect()
    }
}
