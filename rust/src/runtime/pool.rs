//! Multi-core fan-out for party-local work: a dependency-free thread
//! pool built on [`std::thread::scope`].
//!
//! The paper's performance story puts almost all cryptographic cost into
//! the precomputable offline phase and makes the online phase a handful
//! of vectorized local passes — exactly the work profile that scales
//! with cores. This module is the one place that fan-out happens:
//!
//! * **offline** — [`crate::offline::store::TripleStore::prefill_par`]
//!   shards triple/daBit fabrication across workers (including
//!   [`crate::offline::bank::MaterialBank`] replenishment), the IKNP
//!   extension parallelizes its per-OT hashing/transposition, and the
//!   Paillier/OU encryption vectors of the HE sparse path encrypt
//!   lane-parallel;
//! * **online** — the plaintext-side matrix products (the local terms of
//!   `CrossProductBackend` tiles, dense and CSR, and the Beaver
//!   recombination inside `ss_matmul_many`) run row-block parallel via
//!   [`matmul_auto`] / [`csr_matmul_auto`].
//!
//! **Determinism is a hard contract.** Every helper here assigns work to
//! workers by *index*, writes results back in index order, and never
//! lets the thread count influence a single output bit: protocols that
//! need per-item randomness fork one child PRG per item *sequentially*
//! (thread-count independent) before fanning out the expensive
//! expansion. Output shares, reveals, and the [`crate::net::Meter`]
//! flight/byte counts are bit-identical for `threads = 1` and
//! `threads = N` — regression-tested in `rust/tests/parallel.rs`. The
//! [`crate::net::Chan`] flight schedule itself always stays on the
//! party's protocol thread; only pure local compute fans out.

use crate::ring::matrix::Mat;
use crate::sparse::csr::Csr;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker-thread knob for a protocol run (the `--threads N` CLI flag
/// and the `parallelism` field of
/// [`crate::kmeans::config::SecureKmeansConfig`] /
/// [`crate::serve::driver::ServeConfig`]).
///
/// Purely a throughput knob: all protocol outputs and meters are
/// bit-identical for any value (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism {
    /// Maximum worker threads for party-local compute (≥ 1).
    pub threads: usize,
}

impl Parallelism {
    /// Cap at `threads` workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> Parallelism {
        Parallelism { threads: threads.max(1) }
    }

    /// Single-threaded (the default — no behavioural or perf surprise
    /// for small runs and tests).
    pub fn sequential() -> Parallelism {
        Parallelism { threads: 1 }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> Parallelism {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Parallelism { threads: n }
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism::sequential()
    }
}

/// Process-wide default worker count, consulted by the deep call sites
/// that have no configuration path of their own (the Beaver
/// recombination inside a [`crate::ss::Pending`] closure, a dealer's
/// inline `U·V`). Set once per run by the protocol drivers from their
/// config; safe to race because the value can only change *throughput*,
/// never an output bit.
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Set the process-wide default worker count (clamped to ≥ 1).
pub fn set_global_threads(threads: usize) {
    GLOBAL_THREADS.store(threads.max(1), Ordering::Relaxed);
}

/// The process-wide default worker count.
pub fn global_threads() -> usize {
    GLOBAL_THREADS.load(Ordering::Relaxed).max(1)
}

/// Minimum multiply-accumulate count before a row-parallel matmul pays
/// for its spawn overhead (scoped threads are cheap but not free).
pub const PAR_MACS_THRESHOLD: usize = 1 << 16;

/// Split `len` items into at most `parts` contiguous half-open ranges
/// covering `[0, len)` exactly once (empty input → no ranges).
pub fn chunk_ranges(len: usize, parts: usize) -> Vec<(usize, usize)> {
    if len == 0 {
        return vec![];
    }
    let parts = parts.max(1).min(len);
    let chunk = len.div_ceil(parts);
    (0..len).step_by(chunk).map(|lo| (lo, (lo + chunk).min(len))).collect()
}

fn effective(threads: usize, items: usize) -> usize {
    threads.max(1).min(items.max(1))
}

/// Map `f` over `items` on up to `threads` workers; results come back in
/// input order regardless of scheduling. `f` receives the item's global
/// index. Falls back to a plain sequential map for one worker or one
/// item.
pub fn parallel_map<T, U, F>(threads: usize, items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let threads = effective(threads, items.len());
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let ranges = chunk_ranges(items.len(), threads);
    let mut parts: Vec<Vec<U>> = Vec::with_capacity(ranges.len());
    std::thread::scope(|s| {
        let fr = &f;
        let handles: Vec<_> = ranges
            .iter()
            .map(|&(lo, hi)| {
                s.spawn(move || {
                    items[lo..hi]
                        .iter()
                        .enumerate()
                        .map(|(i, t)| fr(lo + i, t))
                        .collect::<Vec<U>>()
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("runtime::pool worker panicked"));
        }
    });
    parts.into_iter().flatten().collect()
}

/// [`parallel_map`] over mutable items (each worker owns a disjoint
/// contiguous chunk): the per-column PRG streams of the IKNP extension
/// advance exactly as they would sequentially.
pub fn parallel_map_mut<T, U, F>(threads: usize, items: &mut [T], f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(usize, &mut T) -> U + Sync,
{
    let n = items.len();
    let threads = effective(threads, n);
    if threads <= 1 || n <= 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = n.div_ceil(threads);
    let mut parts: Vec<Vec<U>> = Vec::new();
    std::thread::scope(|s| {
        let fr = &f;
        let handles: Vec<_> = items
            .chunks_mut(chunk)
            .enumerate()
            .map(|(ci, slice)| {
                s.spawn(move || {
                    slice
                        .iter_mut()
                        .enumerate()
                        .map(|(i, t)| fr(ci * chunk + i, t))
                        .collect::<Vec<U>>()
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("runtime::pool worker panicked"));
        }
    });
    parts.into_iter().flatten().collect()
}

/// Generate `count` values from an index function on up to `threads`
/// workers, in index order.
pub fn parallel_gen<U, F>(threads: usize, count: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    let ranges = chunk_ranges(count, effective(threads, count));
    if ranges.len() <= 1 {
        return (0..count).map(&f).collect();
    }
    let parts = parallel_map(ranges.len(), &ranges, |_, &(lo, hi)| {
        (lo..hi).map(&f).collect::<Vec<U>>()
    });
    parts.into_iter().flatten().collect()
}

/// Row-block parallel wrapping matmul on exactly `threads` workers
/// (sequential for `threads ≤ 1`). Bit-identical to [`Mat::matmul`]:
/// each worker runs the same i-k-j kernel on a disjoint row range of
/// the output.
pub fn matmul_with(threads: usize, a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols, b.rows,
        "matmul shape mismatch {}x{} · {}x{}",
        a.rows, a.cols, b.rows, b.cols
    );
    let threads = effective(threads, a.rows);
    if threads <= 1 {
        return a.matmul(b);
    }
    let (kk, n) = (a.cols, b.cols);
    let ranges = chunk_ranges(a.rows, threads);
    let parts: Vec<Vec<u64>> = parallel_map(threads, &ranges, |_, &(r0, r1)| {
        let mut out = vec![0u64; (r1 - r0) * n];
        for i in r0..r1 {
            let arow = &a.data[i * kk..(i + 1) * kk];
            let orow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
            for k in 0..kk {
                let av = arow[k];
                if av == 0 {
                    continue;
                }
                let brow = &b.data[k * n..(k + 1) * n];
                crate::runtime::simd::axpy(orow, av, brow);
            }
        }
        out
    });
    Mat { rows: a.rows, cols: n, data: parts.concat() }
}

/// Ring matmul that fans out across [`global_threads`] workers when the
/// product is large enough to amortize the spawn cost — the default
/// plaintext-side kernel behind [`crate::runtime::dispatch::matmul`].
pub fn matmul_auto(a: &Mat, b: &Mat) -> Mat {
    let threads = global_threads();
    let work = a.rows.saturating_mul(a.cols).saturating_mul(b.cols);
    if threads <= 1 || work < PAR_MACS_THRESHOLD || a.rows < 2 {
        return a.matmul(b);
    }
    matmul_with(threads, a, b)
}

/// Run a two-party protocol pair to completion on dedicated threads,
/// returning both results: the one sanctioned way to stand up an
/// in-process two-party run (`run_two_party`, the coordinator's local
/// scenario runner, the M-Kmeans driver, offline calibration).
///
/// The parties get deep stacks (the GC garbler and the bigint tower
/// recurse) and stable names (`party0`/`party1`, which profilers and
/// TSan reports show). Scoped spawning means the closures may borrow
/// from the caller. A panic on either party thread propagates to the
/// caller as a panic — protocol bugs stay loud.
pub fn run_pair<R0, R1, F0, F1>(f0: F0, f1: F1) -> (R0, R1)
where
    R0: Send,
    R1: Send,
    F0: FnOnce() -> R0 + Send,
    F1: FnOnce() -> R1 + Send,
{
    std::thread::scope(|s| {
        let h0 = std::thread::Builder::new()
            .name("party0".into())
            .stack_size(64 << 20)
            .spawn_scoped(s, f0)
            .expect("runtime::pool: spawn party0");
        let h1 = std::thread::Builder::new()
            .name("party1".into())
            .stack_size(64 << 20)
            .spawn_scoped(s, f1)
            .expect("runtime::pool: spawn party1");
        (
            h0.join().expect("party0 panicked"),
            h1.join().expect("party1 panicked"),
        )
    })
}

/// Run `n` long-lived worker bodies with **one dedicated thread each**,
/// returning their results in index order. Unlike [`parallel_map`]
/// (which chunks items over a bounded pool and assumes bodies are pure
/// local compute), every body here is guaranteed to be *live
/// concurrently* — required when bodies block on each other through
/// shared state, as the gateway's scoring workers and bank replenishers
/// do ([`crate::serve::gateway`]): chunking two interdependent blocking
/// bodies onto one thread would deadlock.
///
/// Threads are named `{name}{i}` with 16 MiB stacks; a panic in any
/// body propagates to the caller after all bodies are joined.
pub fn run_workers<R, F>(name: &str, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    std::thread::scope(|s| {
        let fr = &f;
        let handles: Vec<_> = (0..n)
            .map(|i| {
                std::thread::Builder::new()
                    .name(format!("{name}{i}"))
                    .stack_size(16 << 20)
                    .spawn_scoped(s, move || fr(i))
                    .expect("runtime::pool: spawn worker")
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("runtime::pool worker panicked"))
            .collect()
    })
}

/// Sparse·dense product fanned out across row blocks when large enough;
/// bit-identical to [`Csr::matmul_dense`].
pub fn csr_matmul_auto(x: &Csr, rhs: &Mat) -> Mat {
    assert_eq!(x.cols, rhs.rows, "spmm shape");
    let threads = global_threads();
    let work = x.nnz().saturating_mul(rhs.cols);
    if threads <= 1 || work < PAR_MACS_THRESHOLD || x.rows < 2 {
        return x.matmul_dense(rhs);
    }
    let n = rhs.cols;
    let ranges = chunk_ranges(x.rows, effective(threads, x.rows));
    let parts: Vec<Vec<u64>> = parallel_map(threads, &ranges, |_, &(r0, r1)| {
        let mut out = vec![0u64; (r1 - r0) * n];
        for r in r0..r1 {
            let orow = &mut out[(r - r0) * n..(r - r0 + 1) * n];
            for (j, v) in x.row_iter(r) {
                crate::runtime::simd::axpy(orow, v, rhs.row(j));
            }
        }
        out
    });
    Mat { rows: x.rows, cols: n, data: parts.concat() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prg;

    #[test]
    fn chunk_ranges_cover_exactly() {
        assert_eq!(chunk_ranges(0, 4), vec![]);
        assert_eq!(chunk_ranges(10, 1), vec![(0, 10)]);
        assert_eq!(chunk_ranges(10, 3), vec![(0, 4), (4, 8), (8, 10)]);
        // More parts than items: one range per item.
        assert_eq!(chunk_ranges(2, 8), vec![(0, 1), (1, 2)]);
        for (len, parts) in [(100, 7), (64, 64), (5, 2), (1, 1)] {
            let rs = chunk_ranges(len, parts);
            assert!(rs.len() <= parts);
            assert_eq!(rs[0].0, 0);
            assert_eq!(rs[rs.len() - 1].1, len);
            for w in rs.windows(2) {
                assert_eq!(w[0].1, w[1].0, "ranges must abut");
            }
        }
    }

    #[test]
    fn parallel_map_preserves_order_for_any_thread_count() {
        let items: Vec<usize> = (0..97).collect();
        let want: Vec<usize> = items.iter().map(|x| x * 3 + 1).collect();
        for threads in [1, 2, 4, 8, 97, 200] {
            let got = parallel_map(threads, &items, |i, &x| {
                assert_eq!(i, x, "global index must match the item");
                x * 3 + 1
            });
            assert_eq!(got, want, "threads = {threads}");
        }
    }

    #[test]
    fn parallel_map_mut_sees_each_item_once() {
        for threads in [1, 3, 8] {
            let mut items = vec![0u64; 50];
            let idx = parallel_map_mut(threads, &mut items, |i, slot| {
                *slot += 1;
                i
            });
            assert!(items.iter().all(|&v| v == 1), "threads = {threads}");
            assert_eq!(idx, (0..50).collect::<Vec<_>>());
        }
    }

    #[test]
    fn parallel_gen_matches_sequential() {
        let want: Vec<u64> = (0..33).map(|i| (i as u64).wrapping_mul(0x9E37)).collect();
        for threads in [1, 2, 5] {
            assert_eq!(
                parallel_gen(threads, 33, |i| (i as u64).wrapping_mul(0x9E37)),
                want
            );
        }
    }

    #[test]
    fn parallel_matmul_is_bit_identical() {
        let mut prg = Prg::new(9);
        let a = Mat::random(37, 19, &mut prg);
        let b = Mat::random(19, 23, &mut prg);
        let want = a.matmul(&b);
        for threads in [1, 2, 4, 8] {
            assert_eq!(matmul_with(threads, &a, &b), want, "threads = {threads}");
        }
    }

    #[test]
    fn csr_parallel_matmul_is_bit_identical() {
        let mut prg = Prg::new(10);
        let mut dense = Mat::random(40, 12, &mut prg);
        for v in dense.data.iter_mut() {
            if prg.next_f64() < 0.7 {
                *v = 0;
            }
        }
        let x = Csr::from_dense(&dense);
        let rhs = Mat::random(12, 6, &mut prg);
        let want = x.matmul_dense(&rhs);
        // Below the work gate this stays sequential — the auto wrapper
        // must be a no-op equality either way; the parallel kernel's
        // bit-identity is covered by parallel_matmul_is_bit_identical.
        let saved = global_threads();
        set_global_threads(4);
        let got = csr_matmul_auto(&x, &rhs);
        set_global_threads(saved);
        assert_eq!(got, want);
    }

    #[test]
    fn global_threads_clamps_to_one() {
        let saved = global_threads();
        set_global_threads(0);
        assert_eq!(global_threads(), 1);
        set_global_threads(saved);
    }

    #[test]
    fn run_pair_returns_both_sides_and_borrows() {
        let shared = vec![1u64, 2, 3];
        let (a, b) = run_pair(|| shared.iter().sum::<u64>(), || shared.len());
        assert_eq!(a, 6);
        assert_eq!(b, 3);
    }

    #[test]
    fn run_workers_gives_every_body_a_live_thread() {
        use std::sync::{Condvar, Mutex};
        // Bodies block until *all* are running at once: with chunked
        // scheduling this would deadlock, with one-thread-per-body it
        // completes. 8 bodies rendezvous through a shared counter.
        let state = Mutex::new(0usize);
        let cv = Condvar::new();
        let n = 8;
        let out = run_workers("rdv", n, |i| {
            let mut g = state.lock().unwrap();
            *g += 1;
            cv.notify_all();
            while *g < n {
                g = cv.wait(g).unwrap();
            }
            i * 2
        });
        assert_eq!(out, (0..n).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallelism_constructors() {
        assert_eq!(Parallelism::default(), Parallelism::sequential());
        assert_eq!(Parallelism::new(0).threads, 1);
        assert!(Parallelism::auto().threads >= 1);
    }
}
