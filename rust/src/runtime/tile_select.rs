//! Tile-size selection for the fixed-shape AOT matmul artifacts.
//!
//! Pure and PJRT-free so the fit rule is unit-testable in the default
//! build; `runtime::tiled::pick_tile` (behind the `pjrt` feature) maps
//! artifact entries through [`pick_tile_size`].

/// Pick the largest available square tile that **fits the problem**: a
/// tile must not exceed any of the three problem dimensions, i.e.
/// `b ≤ min(m, t, n)`.
///
/// The seed rule accepted tiles up to `dim.next_power_of_two()`, so a
/// 256³ artifact could be chosen for a 129-row problem even though an
/// exact 128-grid covers it with a fraction of the padded work (for a
/// 129×128×128 problem the 256³ tile computes 16.8M padded MACs where
/// two 128³ calls need 4.2M). Tiles larger than the whole problem are
/// only ever pure padding, so they are excluded outright; problems
/// smaller than every available tile fall back to the smallest tile
/// (padding is then unavoidable). Returns `None` only when no tiles are
/// available.
pub fn pick_tile_size(available: &[usize], m: usize, t: usize, n: usize) -> Option<usize> {
    let limit = m.min(t).min(n);
    available
        .iter()
        .copied()
        .filter(|&b| b <= limit)
        .max()
        .or_else(|| available.iter().copied().min())
}

#[cfg(test)]
mod tests {
    use super::*;

    const AVAIL: &[usize] = &[128, 256];

    #[test]
    fn exact_fit_prefers_the_largest_tile() {
        assert_eq!(pick_tile_size(AVAIL, 256, 256, 256), Some(256));
        assert_eq!(pick_tile_size(AVAIL, 512, 512, 512), Some(256));
        assert_eq!(pick_tile_size(AVAIL, 128, 128, 128), Some(128));
    }

    #[test]
    fn regression_129_rows_must_not_take_the_256_tile() {
        // The old next_power_of_two rule rounded 129 up to 256 and chose
        // the 256³ artifact over the exact-fit 128 grid.
        assert_eq!(pick_tile_size(AVAIL, 129, 128, 128), Some(128));
        assert_eq!(pick_tile_size(AVAIL, 129, 129, 129), Some(128));
        assert_eq!(pick_tile_size(AVAIL, 255, 255, 255), Some(128));
    }

    #[test]
    fn any_small_dimension_caps_the_tile() {
        // One thin dimension forces the smaller tile even when the
        // others are huge.
        assert_eq!(pick_tile_size(AVAIL, 512, 128, 512), Some(128));
        assert_eq!(pick_tile_size(AVAIL, 1024, 1024, 200), Some(128));
    }

    #[test]
    fn tiny_problems_fall_back_to_the_smallest_tile() {
        assert_eq!(pick_tile_size(AVAIL, 64, 64, 64), Some(128));
        assert_eq!(pick_tile_size(AVAIL, 1, 1, 1), Some(128));
    }

    #[test]
    fn no_artifacts_means_no_tile() {
        assert_eq!(pick_tile_size(&[], 128, 128, 128), None);
    }

    #[test]
    fn unsorted_availability_is_handled() {
        assert_eq!(pick_tile_size(&[256, 128, 64], 200, 200, 200), Some(128));
        assert_eq!(pick_tile_size(&[256, 128, 64], 32, 500, 500), Some(64));
    }
}
