//! Tile-size selection for the fixed-shape AOT matmul artifacts.
//!
//! Pure and PJRT-free so the fit rule is unit-testable in the default
//! build; `runtime::tiled::pick_tile` (behind the `pjrt` feature) maps
//! artifact entries through [`pick_tile_size`].

/// Pick the largest available square tile that **fits the problem**: a
/// tile must not exceed any of the three problem dimensions, i.e.
/// `b ≤ min(m, t, n)`.
///
/// The seed rule accepted tiles up to `dim.next_power_of_two()`, so a
/// 256³ artifact could be chosen for a 129-row problem even though an
/// exact 128-grid covers it with a fraction of the padded work (for a
/// 129×128×128 problem the 256³ tile computes 16.8M padded MACs where
/// two 128³ calls need 4.2M). Tiles larger than the whole problem are
/// only ever pure padding, so they are excluded outright; problems
/// smaller than every available tile fall back to the smallest tile
/// (padding is then unavoidable). Returns `None` only when no tiles are
/// available.
pub fn pick_tile_size(available: &[usize], m: usize, t: usize, n: usize) -> Option<usize> {
    pick_tile_size_par(available, m, t, n, 1)
}

/// Parallelism-aware fit rule: like [`pick_tile_size`], but among the
/// fitting tiles prefer the **largest** whose output tile grid
/// `⌈m/b⌉·⌈n/b⌉` has at least `threads` tiles — a grid smaller than the
/// worker count strands threads (e.g. one 256³ call on a 256³ problem
/// leaves 3 of 4 workers idle where the 128-grid's 4 tiles keep them
/// all busy). When even the smallest fitting tile cannot produce
/// `threads` tiles, take the smallest fitting tile (it maximizes the
/// grid); with `threads = 1` this degenerates to exactly the plain fit
/// rule. Problems smaller than every tile still fall back to the
/// smallest available tile.
pub fn pick_tile_size_par(
    available: &[usize],
    m: usize,
    t: usize,
    n: usize,
    threads: usize,
) -> Option<usize> {
    let limit = m.min(t).min(n);
    let threads = threads.max(1);
    let fitting: Vec<usize> = available.iter().copied().filter(|&b| b <= limit).collect();
    let grid = |b: usize| m.div_ceil(b) * n.div_ceil(b);
    fitting
        .iter()
        .copied()
        .filter(|&b| grid(b) >= threads)
        .max()
        .or_else(|| fitting.iter().copied().min())
        .or_else(|| available.iter().copied().min())
}

#[cfg(test)]
mod tests {
    use super::*;

    const AVAIL: &[usize] = &[128, 256];

    #[test]
    fn exact_fit_prefers_the_largest_tile() {
        assert_eq!(pick_tile_size(AVAIL, 256, 256, 256), Some(256));
        assert_eq!(pick_tile_size(AVAIL, 512, 512, 512), Some(256));
        assert_eq!(pick_tile_size(AVAIL, 128, 128, 128), Some(128));
    }

    #[test]
    fn regression_129_rows_must_not_take_the_256_tile() {
        // The old next_power_of_two rule rounded 129 up to 256 and chose
        // the 256³ artifact over the exact-fit 128 grid.
        assert_eq!(pick_tile_size(AVAIL, 129, 128, 128), Some(128));
        assert_eq!(pick_tile_size(AVAIL, 129, 129, 129), Some(128));
        assert_eq!(pick_tile_size(AVAIL, 255, 255, 255), Some(128));
    }

    #[test]
    fn any_small_dimension_caps_the_tile() {
        // One thin dimension forces the smaller tile even when the
        // others are huge.
        assert_eq!(pick_tile_size(AVAIL, 512, 128, 512), Some(128));
        assert_eq!(pick_tile_size(AVAIL, 1024, 1024, 200), Some(128));
    }

    #[test]
    fn tiny_problems_fall_back_to_the_smallest_tile() {
        assert_eq!(pick_tile_size(AVAIL, 64, 64, 64), Some(128));
        assert_eq!(pick_tile_size(AVAIL, 1, 1, 1), Some(128));
    }

    #[test]
    fn no_artifacts_means_no_tile() {
        assert_eq!(pick_tile_size(&[], 128, 128, 128), None);
    }

    #[test]
    fn unsorted_availability_is_handled() {
        assert_eq!(pick_tile_size(&[256, 128, 64], 200, 200, 200), Some(128));
        assert_eq!(pick_tile_size(&[256, 128, 64], 32, 500, 500), Some(64));
    }

    #[test]
    fn one_thread_matches_the_plain_fit_rule() {
        for (m, t, n) in [(256, 256, 256), (129, 128, 128), (64, 64, 64), (512, 512, 512)] {
            assert_eq!(
                pick_tile_size_par(AVAIL, m, t, n, 1),
                pick_tile_size(AVAIL, m, t, n),
                "{m}x{t}x{n}"
            );
        }
    }

    #[test]
    fn tile_grid_must_cover_the_worker_count() {
        // 512³, 4 workers: the 256 tile gives a 2×2 = 4-tile grid — still
        // the largest fitting choice.
        assert_eq!(pick_tile_size_par(AVAIL, 512, 512, 512, 4), Some(256));
        // 512³, 8 workers: 256 strands half the pool (4 tiles < 8);
        // 128 gives 16 tiles.
        assert_eq!(pick_tile_size_par(AVAIL, 512, 512, 512, 8), Some(128));
        // 256³, 4 workers: one 256³ tile would leave 3 workers idle;
        // the 128 grid has 4 tiles.
        assert_eq!(pick_tile_size_par(AVAIL, 256, 256, 256, 4), Some(128));
    }

    #[test]
    fn starved_grids_fall_back_to_the_smallest_fitting_tile() {
        // 128³ with 64 workers: even the 128 tile is a 1-tile grid, but
        // it is the only fitting size — take it (maximal grid).
        assert_eq!(pick_tile_size_par(AVAIL, 128, 128, 128, 64), Some(128));
        // 256³ with 64 workers: 128 gives 4 tiles < 64 — still the best
        // fitting option.
        assert_eq!(pick_tile_size_par(AVAIL, 256, 256, 256, 64), Some(128));
        // Tiny problems keep the smallest-available fallback.
        assert_eq!(pick_tile_size_par(AVAIL, 16, 16, 16, 8), Some(128));
        assert_eq!(pick_tile_size_par(&[], 128, 128, 128, 8), None);
    }

    #[test]
    fn thin_dimensions_still_cap_under_parallelism() {
        // The inner dimension never contributes tiles but still caps b.
        assert_eq!(pick_tile_size_par(AVAIL, 1024, 128, 1024, 4), Some(128));
    }
}
