//! Type-level stub of the `xla` crate API surface the PJRT path uses.
//!
//! The crate is deliberately dependency-free (offline builds), yet the
//! PJRT plumbing in [`super::artifact`], [`super::executor`] and
//! [`super::tiled`] should not rot unchecked: CI's
//! `cargo check --features pjrt` job compiles all of it against this
//! stub, which mirrors the external crate's signatures but whose entry
//! point ([`PjRtClient::cpu`]) always returns a typed
//! [`Error`] — so a `pjrt` build without a real backend fails **at
//! runtime with a clear message**, never at a protocol boundary.
//!
//! Wiring a real XLA backend: add the `xla` crate to `[dependencies]`
//! and replace the `use crate::runtime::xla_stub as xla;` alias in the
//! modules above (and in `util::error`) with the external crate. The
//! stub exists so that step is a two-line diff instead of a bitrotted
//! merge.

use std::fmt;

/// Stand-in for `xla::Error`.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT stub: this binary was built against runtime::xla_stub — add the real \
         `xla` crate and swap the stub alias to execute compiled artifacts"
            .into(),
    ))
}

/// Stand-in for `xla::Literal` (host-side tensor).
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host buffer.
    pub fn vec1<T>(_buf: &[T]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    /// Split a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::PjRtBuffer` (device-side buffer).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Transfer the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    /// The CPU client — the stub's single failure point: everything
    /// else is unreachable without a client.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
