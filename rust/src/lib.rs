//! # ppkmeans — Scalable & Sparsity-Aware Privacy-Preserving K-means
//!
//! A full-system reproduction of *"Scalable and Sparsity-Aware
//! Privacy-Preserving K-means Clustering with Application to Fraud
//! Detection"* (Liu et al., 2022): a two-party, semi-honest MPC framework
//! for K-means with
//!
//! * an **online/offline split** — all cryptographic material (Beaver
//!   triples, OT extensions) is produced in a data-independent offline
//!   phase ([`offline`]), leaving a near-plaintext-speed online phase;
//! * **vectorized secret-shared Lloyd iterations** — distance
//!   computation, tree-reduction cluster assignment and centroid update
//!   all operate on whole matrices ([`kmeans`]);
//! * a **sparsity-aware HE+SS hybrid** — sparse matrix products are
//!   evaluated under additively homomorphic encryption and converted back
//!   to secret shares ([`sparse`], [`he`]);
//! * the **M-Kmeans baseline** (Mohassel-Rosulek-Trieu) rebuilt on the
//!   same substrate for apples-to-apples comparison ([`mkmeans`], [`gc`]).
//!
//! The numeric hot path (blocked ring matmuls, the ESD distance kernel)
//! is AOT-compiled from JAX/Pallas to HLO text at build time and executed
//! through the PJRT C API by [`runtime`]; Python never runs at protocol
//! time.
//!
//! ## Quick start
//!
//! ```no_run
//! use ppkmeans::prelude::*;
//!
//! let data = ppkmeans::data::blobs::BlobSpec::new(1_000, 4, 3).generate(7);
//! let cfg = SecureKmeansConfig { k: 3, iters: 10, ..Default::default() };
//! let out = ppkmeans::kmeans::secure::run_vertical(&data, &cfg).unwrap();
//! println!("centroids: {:?}", out.centroids);
//! ```
#![allow(clippy::needless_range_loop)] // index-style loops mirror the math

pub mod util;
pub mod ring;
pub mod net;
pub mod ss;
pub mod bigint;
pub mod he;
pub mod offline;
pub mod sparse;
pub mod gc;
pub mod mkmeans;
pub mod kmeans;
pub mod runtime;
pub mod coordinator;
pub mod data;
pub mod fraud;
pub mod bench;
pub mod cli;

/// Common re-exports for examples and benches.
pub mod prelude {
    pub use crate::kmeans::config::SecureKmeansConfig;
    pub use crate::net::cost::CostModel;
    pub use crate::net::meter::Meter;
    pub use crate::ring::fixed::{decode_f64, encode_f64, FRAC_BITS};
    pub use crate::ring::matrix::Mat;
    pub use crate::util::error::{Error, Result};
    pub use crate::util::prng::Prg;
}
