//! # ppkmeans — Scalable & Sparsity-Aware Privacy-Preserving K-means
//!
//! A full-system reproduction of *"Scalable and Sparsity-Aware
//! Privacy-Preserving K-means Clustering with Application to Fraud
//! Detection"* (Liu et al., 2022): a two-party, semi-honest MPC framework
//! for K-means with
//!
//! * an **online/offline split** — all cryptographic material (Beaver
//!   triples, daBits, OT extensions) is produced in a data-independent
//!   offline phase ([`offline`]), leaving a near-plaintext-speed online
//!   phase;
//! * **vectorized secret-shared Lloyd iterations** — distance
//!   computation, tree-reduction cluster assignment and centroid update
//!   all operate on whole matrices ([`kmeans`]);
//! * a **sparsity-aware HE+SS hybrid** — sparse matrix products are
//!   evaluated under additively homomorphic encryption and converted back
//!   to secret shares ([`sparse`], [`he`]);
//! * the **M-Kmeans baseline** (Mohassel-Rosulek-Trieu) rebuilt on the
//!   same substrate for apples-to-apples comparison ([`mkmeans`], [`gc`]).
//!
//! ## The round-batched protocol engine
//!
//! Four layers cooperate so the online phase runs as close to one
//! network flight per protocol round as the math allows:
//!
//! 1. **net** ([`net`]): [`net::Chan`] carries a *round buffer* — gates
//!    stage masked reveals, `flush_round()` ships them all in one
//!    exchange, and the per-phase [`net::Meter`] counts bytes **and
//!    flights** exactly.
//! 2. **ss** ([`ss`]): [`ss::Session`] exposes batch-first gate APIs
//!    (`ss_matmul_many`, `cmp_many`, `mux_many`, `and_many`, ...) built
//!    on deferred-reveal [`ss::Pending`] handles; single-gate functions
//!    are thin wrappers. daBits fuse B2A and boolean-selector MUX into
//!    single flights. [`ss::RoundPolicy::PerGate`] is the
//!    gate-per-flight ablation baseline.
//! 3. **kmeans** ([`kmeans`]): S1 reveals norms + both cross products in
//!    one flight; each `F_min^k` level costs `CMP_ROUNDS + 1` flights;
//!    S3's numerator reveals coalesce into the division-prep comparison.
//! 4. **backends** ([`kmeans::backend`]): the S1/S3 cross products sit
//!    behind a `CrossProductBackend` trait — dense Beaver triples, HE
//!    Protocol 2 for sparse data, or the naive Q3 ablation — with
//!    `EsdMode::Auto` dispatching on the jointly-measured density.
//!
//! The numeric hot path (blocked ring matmuls, the ESD distance kernel)
//! can be AOT-compiled from JAX/Pallas to HLO and executed through the
//! PJRT C API by [`runtime`] (cargo feature `pjrt`, off by default);
//! without it the native blocked kernels run — results are identical.
//!
//! ## The scoring service
//!
//! Training is a one-off; the deployed product is **scoring**: [`serve`]
//! persists each party's secret-shared centroids as a versioned
//! [`serve::model::TrainedModel`] artifact, and a long-lived
//! [`serve::scorer::Scorer`] runs assignment-only inference (S1 + S2 +
//! a secure distance-threshold fraud flag, **no S3**) over streaming
//! micro-batches at exactly [`serve::scorer::score_rounds`]`(k)` flights
//! per batch, drawing prefabricated material from a replenished
//! [`offline::bank::MaterialBank`].
//!
//! ## Quick start
//!
//! ```no_run
//! use ppkmeans::prelude::*;
//!
//! let data = ppkmeans::data::blobs::BlobSpec::new(1_000, 4, 3).generate(7);
//! let cfg = SecureKmeansConfig { k: 3, iters: 10, ..Default::default() };
//! let out = ppkmeans::kmeans::secure::run_vertical(&data, &cfg).unwrap();
//! println!("centroids: {:?}", out.centroids);
//! let online = out.meter_a.total_prefix("online.");
//! println!("online: {} bytes in {} flights", online.bytes_sent, online.rounds);
//! ```
#![allow(clippy::needless_range_loop)] // index-style loops mirror the math
// Every public item must carry rustdoc; CI runs `cargo doc` with
// `RUSTDOCFLAGS="-D warnings"` so a missing or broken doc fails the
// build. Modules still carrying `#[allow(missing_docs)]` below are the
// documented-incrementally backlog — ss/, offline/, serve/, runtime::,
// util/ and ring/ are fully covered and must stay that way.
#![warn(missing_docs)]

pub mod util;
pub mod lint;
pub mod ring;
#[allow(missing_docs)]
pub mod net;
pub mod ss;
#[allow(missing_docs)]
pub mod bigint;
#[allow(missing_docs)]
pub mod he;
pub mod offline;
#[allow(missing_docs)]
pub mod sparse;
#[allow(missing_docs)]
pub mod gc;
#[allow(missing_docs)]
pub mod mkmeans;
#[allow(missing_docs)]
pub mod kmeans;
pub mod runtime;
#[allow(missing_docs)]
pub mod coordinator;
pub mod resume;
pub mod serve;
#[allow(missing_docs)]
pub mod data;
#[allow(missing_docs)]
pub mod fraud;
#[allow(missing_docs)]
pub mod bench;
#[allow(missing_docs)]
pub mod cli;

/// Common re-exports for examples and benches.
pub mod prelude {
    pub use crate::kmeans::config::{EsdMode, SecureKmeansConfig, TileFlights};
    pub use crate::net::cost::CostModel;
    pub use crate::net::meter::Meter;
    pub use crate::ring::fixed::{decode_f64, encode_f64, FRAC_BITS};
    pub use crate::ring::matrix::Mat;
    pub use crate::ss::RoundPolicy;
    pub use crate::util::error::{Error, Result};
    pub use crate::util::prng::Prg;
}
