//! Boolean circuit representation.
//!
//! Wires are dense indices. Layout: wire 0 is the constant-1 wire
//! (semantically a garbler input fixed to 1 — NOT gates become free
//! XORs against it), then the garbler's input bits, then the
//! evaluator's, then internal wires in topological order.

/// A gate over wire indices (out is always a fresh wire).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gate {
    /// out = a ⊕ b (free under free-XOR).
    Xor { a: u32, b: u32, out: u32 },
    /// out = a ∧ b (two ciphertexts).
    And { a: u32, b: u32, out: u32 },
}

/// A complete circuit.
#[derive(Debug, Clone)]
pub struct Circuit {
    /// Total wires including const-1, inputs and internals.
    pub n_wires: usize,
    /// Garbler input bit count (excluding the const-1 wire).
    pub n_garbler: usize,
    /// Evaluator input bit count.
    pub n_eval: usize,
    pub gates: Vec<Gate>,
    /// Output wire indices.
    pub outputs: Vec<u32>,
}

impl Circuit {
    /// Index of the constant-1 wire.
    pub const ONE: u32 = 0;

    /// First garbler input wire.
    pub fn garbler_input(&self, i: usize) -> u32 {
        assert!(i < self.n_garbler);
        1 + i as u32
    }

    /// First evaluator input wire.
    pub fn eval_input(&self, i: usize) -> u32 {
        assert!(i < self.n_eval);
        (1 + self.n_garbler + i) as u32
    }

    /// Number of AND gates (the cost metric).
    pub fn and_count(&self) -> usize {
        self.gates.iter().filter(|g| matches!(g, Gate::And { .. })).count()
    }

    /// Plaintext evaluation (testing oracle).
    pub fn eval_plain(&self, garbler_bits: &[bool], eval_bits: &[bool]) -> Vec<bool> {
        assert_eq!(garbler_bits.len(), self.n_garbler);
        assert_eq!(eval_bits.len(), self.n_eval);
        let mut w = vec![false; self.n_wires];
        w[0] = true;
        for (i, &b) in garbler_bits.iter().enumerate() {
            w[1 + i] = b;
        }
        for (i, &b) in eval_bits.iter().enumerate() {
            w[1 + self.n_garbler + i] = b;
        }
        for g in &self.gates {
            match *g {
                Gate::Xor { a, b, out } => w[out as usize] = w[a as usize] ^ w[b as usize],
                Gate::And { a, b, out } => w[out as usize] = w[a as usize] & w[b as usize],
            }
        }
        self.outputs.iter().map(|&o| w[o as usize]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_eval_xor_and() {
        // out = (g0 ^ e0) & g1
        let c = Circuit {
            n_wires: 5,
            n_garbler: 2,
            n_eval: 1,
            gates: vec![
                Gate::Xor { a: 1, b: 3, out: 4 },
                Gate::And { a: 4, b: 2, out: 4 + 1 - 1 },
            ],
            outputs: vec![4],
        };
        // fix: output of And must be a fresh wire; rebuild properly
        let c = Circuit {
            n_wires: 6,
            gates: vec![
                Gate::Xor { a: 1, b: 3, out: 4 },
                Gate::And { a: 4, b: 2, out: 5 },
            ],
            outputs: vec![5],
            ..c
        };
        for g0 in [false, true] {
            for g1 in [false, true] {
                for e0 in [false, true] {
                    let out = c.eval_plain(&[g0, g1], &[e0]);
                    assert_eq!(out[0], (g0 ^ e0) & g1);
                }
            }
        }
        assert_eq!(c.and_count(), 1);
    }
}
