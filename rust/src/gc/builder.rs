//! Circuit builder: word-level operations (ripple adders, comparators,
//! multiplexers, argmin tournaments) compiled to XOR/AND gates.
//!
//! Words are LSB-first vectors of wire ids. The M-Kmeans assignment
//! circuit is `argmin_onehot`: reconstruct each distance from the two
//! parties' additive shares (mod 2^w), then a tournament of
//! compare-and-swap modules tracking a one-hot index.

use super::circuit::{Circuit, Gate};

/// Incremental circuit builder.
pub struct Builder {
    n_garbler: usize,
    n_eval: usize,
    next: u32,
    gates: Vec<Gate>,
}

impl Builder {
    pub fn new(n_garbler: usize, n_eval: usize) -> Builder {
        Builder {
            n_garbler,
            n_eval,
            next: (1 + n_garbler + n_eval) as u32,
            gates: Vec::new(),
        }
    }

    /// The constant-1 wire.
    pub fn one(&self) -> u32 {
        Circuit::ONE
    }

    pub fn garbler_input(&self, i: usize) -> u32 {
        assert!(i < self.n_garbler);
        1 + i as u32
    }

    pub fn eval_input(&self, i: usize) -> u32 {
        assert!(i < self.n_eval);
        (1 + self.n_garbler + i) as u32
    }

    /// Garbler input word (w consecutive bits starting at bit `off`).
    pub fn garbler_word(&self, off: usize, w: usize) -> Vec<u32> {
        (0..w).map(|i| self.garbler_input(off + i)).collect()
    }

    pub fn eval_word(&self, off: usize, w: usize) -> Vec<u32> {
        (0..w).map(|i| self.eval_input(off + i)).collect()
    }

    fn fresh(&mut self) -> u32 {
        let w = self.next;
        self.next += 1;
        w
    }

    pub fn xor(&mut self, a: u32, b: u32) -> u32 {
        let out = self.fresh();
        self.gates.push(Gate::Xor { a, b, out });
        out
    }

    pub fn and(&mut self, a: u32, b: u32) -> u32 {
        let out = self.fresh();
        self.gates.push(Gate::And { a, b, out });
        out
    }

    pub fn not(&mut self, a: u32) -> u32 {
        self.xor(a, Circuit::ONE)
    }

    /// Ripple-carry addition mod 2^w (w-1 AND gates via the
    /// carry recurrence c' = c ⊕ ((a⊕c)∧(b⊕c))).
    pub fn add(&mut self, a: &[u32], b: &[u32]) -> Vec<u32> {
        assert_eq!(a.len(), b.len());
        let w = a.len();
        let mut out = Vec::with_capacity(w);
        let mut carry: Option<u32> = None;
        for i in 0..w {
            let axb = self.xor(a[i], b[i]);
            match carry {
                None => {
                    out.push(axb);
                    if i + 1 < w {
                        carry = Some(self.and(a[i], b[i]));
                    }
                }
                Some(c) => {
                    out.push(self.xor(axb, c));
                    if i + 1 < w {
                        let t1 = self.xor(a[i], c);
                        let t2 = self.xor(b[i], c);
                        let t3 = self.and(t1, t2);
                        carry = Some(self.xor(c, t3));
                    }
                }
            }
        }
        out
    }

    /// `[a < b]` for w-bit two's-complement words: the borrow-out sign of
    /// a − b computed as a + ¬b + 1 — we track the final carry and
    /// combine with the operand signs for a signed comparison.
    pub fn lt_signed(&mut self, a: &[u32], b: &[u32]) -> u32 {
        assert_eq!(a.len(), b.len());
        let w = a.len();
        // Full subtraction with carry chain: c_0 = 1, b̄ = ¬b.
        let mut carry = Circuit::ONE; // +1 of two's complement
        let mut diff_msb = 0u32;
        for i in 0..w {
            let nb = self.not(b[i]);
            let axb = self.xor(a[i], nb);
            let s = self.xor(axb, carry);
            if i == w - 1 {
                diff_msb = s;
                // overflow = carry_into_msb ^ carry_out — compute carry out too.
                let t1 = self.xor(a[i], carry);
                let t2 = self.xor(nb, carry);
                let t3 = self.and(t1, t2);
                let carry_out = self.xor(carry, t3);
                // signed less-than: sign(diff) ^ overflow, where
                // overflow = c_in(msb) ^ c_out(msb); c_in(msb) = carry.
                let ovf = self.xor(carry, carry_out);
                return self.xor(diff_msb, ovf);
            }
            let t1 = self.xor(a[i], carry);
            let t2 = self.xor(nb, carry);
            let t3 = self.and(t1, t2);
            carry = self.xor(carry, t3);
            let _ = s;
        }
        diff_msb // unreachable for w ≥ 1
    }

    /// Word MUX: out_i = sel ? x_i : y_i (one AND per bit).
    pub fn mux_word(&mut self, sel: u32, x: &[u32], y: &[u32]) -> Vec<u32> {
        assert_eq!(x.len(), y.len());
        let mut out = Vec::with_capacity(x.len());
        for i in 0..x.len() {
            let d = self.xor(x[i], y[i]);
            let m = self.and(sel, d);
            out.push(self.xor(y[i], m));
        }
        out
    }

    /// Tournament argmin over `vals` (equal-width words), tracking a
    /// one-hot index of `vals.len()` bits. Returns (min_word, onehot).
    pub fn argmin_onehot(&mut self, vals: &[Vec<u32>]) -> (Vec<u32>, Vec<u32>) {
        let k = vals.len();
        assert!(k >= 1);
        // Initial one-hot rows: e_j as constants (bit j = 1).
        let zero = {
            let one = self.one();
            self.xor(one, one) // constant 0 wire
        };
        let mut nodes: Vec<(Vec<u32>, Vec<u32>)> = (0..k)
            .map(|j| {
                let mut idx = vec![zero; k];
                idx[j] = self.one();
                (vals[j].clone(), idx)
            })
            .collect();
        while nodes.len() > 1 {
            let mut next = Vec::with_capacity(nodes.len().div_ceil(2));
            let mut it = nodes.into_iter();
            while let (Some(a), opt_b) = (it.next(), None::<()>) {
                let _ = opt_b;
                match it.next() {
                    None => next.push(a),
                    Some(b) => {
                        let sel = self.lt_signed(&a.0, &b.0); // a < b → pick a
                        let v = self.mux_word(sel, &a.0, &b.0);
                        let i = self.mux_word(sel, &a.1, &b.1);
                        next.push((v, i));
                    }
                }
            }
            nodes = next;
        }
        let root = nodes.pop().unwrap();
        (root.0, root.1)
    }

    /// Finish, declaring output wires.
    pub fn build(self, outputs: Vec<u32>) -> Circuit {
        Circuit {
            n_wires: self.next as usize,
            n_garbler: self.n_garbler,
            n_eval: self.n_eval,
            gates: self.gates,
            outputs,
        }
    }
}

/// The M-Kmeans assignment circuit for one sample: inputs are the two
/// parties' w-bit shares of k distances; output is the one-hot argmin
/// of the reconstructed (mod 2^w) distances.
pub fn assign_circuit(k: usize, w: usize) -> Circuit {
    let mut b = Builder::new(k * w, k * w);
    let mut dists = Vec::with_capacity(k);
    for j in 0..k {
        let ga = b.garbler_word(j * w, w);
        let ea = b.eval_word(j * w, w);
        dists.push(b.add(&ga, &ea)); // reconstruct share sum mod 2^w
    }
    let (_min, onehot) = b.argmin_onehot(&dists);
    b.build(onehot)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(x: u64, w: usize) -> Vec<bool> {
        (0..w).map(|i| (x >> i) & 1 == 1).collect()
    }

    #[test]
    fn adder_matches_wrapping_add() {
        let w = 16;
        let mut b = Builder::new(w, w);
        let x = b.garbler_word(0, w);
        let y = b.eval_word(0, w);
        let s = b.add(&x, &y);
        let c = b.build(s);
        for (a, bb) in [(3u64, 5u64), (65535, 1), (40000, 30000), (0, 0)] {
            let out = c.eval_plain(&bits(a, w), &bits(bb, w));
            let got: u64 = out.iter().enumerate().map(|(i, &v)| (v as u64) << i).sum();
            assert_eq!(got, (a + bb) & 0xFFFF, "{a}+{bb}");
        }
    }

    #[test]
    fn signed_lt_matches() {
        let w = 8;
        let mut b = Builder::new(w, w);
        let x = b.garbler_word(0, w);
        let y = b.eval_word(0, w);
        let lt = b.lt_signed(&x, &y);
        let c = b.build(vec![lt]);
        for a in [-128i64, -5, -1, 0, 1, 7, 127] {
            for bb in [-128i64, -2, 0, 3, 127] {
                let out = c.eval_plain(&bits(a as u64, w), &bits(bb as u64, w));
                assert_eq!(out[0], a < bb, "{a} < {bb}");
            }
        }
    }

    #[test]
    fn assign_circuit_finds_min_of_shared_distances() {
        let w = 16;
        let k = 4;
        let c = assign_circuit(k, w);
        // Distances (two's complement in 16 bits) shared additively.
        let dvals: [i64; 4] = [300, -7, 42, -6];
        let shares0: [u64; 4] = [11, 222, 3333, 44444];
        let g: Vec<bool> = (0..k).flat_map(|j| bits(shares0[j], w)).collect();
        let e: Vec<bool> = (0..k)
            .flat_map(|j| bits((dvals[j] as u64).wrapping_sub(shares0[j]), w))
            .collect();
        let out = c.eval_plain(&g, &e);
        assert_eq!(out, vec![false, true, false, false]); // -7 wins
        // Cost sanity: linear-ish in k·w.
        assert!(c.and_count() < 6 * k * w);
    }
}
