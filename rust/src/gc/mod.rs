//! Garbled circuits: free-XOR + half-gates (Zahur-Rosulek-Evans 2015).
//!
//! The substrate for the M-Kmeans baseline (Mohassel-Rosulek-Trieu
//! 2020), whose cluster-assignment step is a customized garbled circuit
//! computing the argmin of k distances and outputting a *boolean-shared*
//! one-hot vector. XOR gates are free; each AND gate costs two 128-bit
//! ciphertexts of transmission and one fixed-key-AES hash per evaluation
//! wire.

pub mod builder;
pub mod circuit;
pub mod garble;

pub use builder::Builder;
pub use circuit::{Circuit, Gate};
pub use garble::{evaluate, garble, Garbling};
