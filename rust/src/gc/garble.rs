//! Garbling and evaluation: free-XOR, point-and-permute, half-gates.
//!
//! Labels are 128-bit; the global offset `R` has LSB 1 so a label's LSB
//! is its permute bit. AND gates follow Zahur-Rosulek-Evans half-gates:
//! two ciphertexts per gate, two fixed-key permutation hashes to
//! evaluate (Speck-128 standing in for fixed-key AES, see
//! [`crate::util::cipher`]).

use super::circuit::{Circuit, Gate};
use crate::util::cipher::Speck128;
use crate::util::prng::Prg;
use std::sync::OnceLock;

/// Fixed-key permutation for the hash (standard free-XOR instantiation).
static FIXED_CIPHER: OnceLock<Speck128> = OnceLock::new();

fn fixed_cipher() -> &'static Speck128 {
    FIXED_CIPHER.get_or_init(|| Speck128::new(*b"ppkmeans-gc-key!"))
}

/// Correlation-robust hash H(x, i) = π(2x ⊕ i) ⊕ (2x ⊕ i).
#[inline]
fn h(x: u128, index: u64) -> u128 {
    let t = (x << 1) ^ (index as u128);
    fixed_cipher().encrypt_u128(t) ^ t
}

#[inline]
fn lsb(x: u128) -> bool {
    x & 1 == 1
}

/// The garbler's material for one circuit.
pub struct Garbling {
    /// (TG, TE) per AND gate, in gate order.
    pub tables: Vec<(u128, u128)>,
    /// Zero-labels per wire (garbler secret; label1 = label0 ^ r).
    pub wire0: Vec<u128>,
    /// Global offset.
    pub r: u128,
    /// Output decode bits: lsb of each output wire's zero-label.
    pub decode: Vec<bool>,
}

impl Garbling {
    /// Label pair for a wire.
    pub fn labels(&self, wire: u32) -> (u128, u128) {
        let w0 = self.wire0[wire as usize];
        (w0, w0 ^ self.r)
    }

    /// The garbler's own input labels for concrete bits.
    pub fn garbler_labels(&self, circ: &Circuit, bits: &[bool]) -> Vec<u128> {
        assert_eq!(bits.len(), circ.n_garbler);
        let mut out = Vec::with_capacity(bits.len() + 1);
        // Constant-1 wire label (always the one-label).
        let (w0, w1) = self.labels(Circuit::ONE);
        let _ = w0;
        out.push(w1);
        for (i, &b) in bits.iter().enumerate() {
            let (l0, l1) = self.labels(circ.garbler_input(i));
            out.push(if b { l1 } else { l0 });
        }
        out
    }
}

/// Garble a circuit.
pub fn garble(circ: &Circuit, prg: &mut Prg) -> Garbling {
    let mut r = prg.next_u128();
    r |= 1; // permute bit of the offset
    let mut wire0 = vec![0u128; circ.n_wires];
    // Inputs (and const-1) get fresh zero-labels.
    let n_in = 1 + circ.n_garbler + circ.n_eval;
    for w in wire0.iter_mut().take(n_in) {
        *w = prg.next_u128();
    }
    let mut tables = Vec::with_capacity(circ.and_count());
    let mut gate_index = 0u64;
    for g in &circ.gates {
        match *g {
            Gate::Xor { a, b, out } => {
                wire0[out as usize] = wire0[a as usize] ^ wire0[b as usize];
            }
            Gate::And { a, b, out } => {
                let a0 = wire0[a as usize];
                let b0 = wire0[b as usize];
                let (j0, j1) = (gate_index * 2, gate_index * 2 + 1);
                let pa = lsb(a0);
                let pb = lsb(b0);
                // Garbler half gate.
                let tg = h(a0, j0) ^ h(a0 ^ r, j0) ^ if pb { r } else { 0 };
                let wg = h(a0, j0) ^ if pa { tg } else { 0 };
                // Evaluator half gate.
                let te = h(b0, j1) ^ h(b0 ^ r, j1) ^ a0;
                let we = h(b0, j1) ^ if pb { te ^ a0 } else { 0 };
                wire0[out as usize] = wg ^ we;
                tables.push((tg, te));
                gate_index += 1;
            }
        }
    }
    let decode = circ.outputs.iter().map(|&o| lsb(wire0[o as usize])).collect();
    Garbling { tables, wire0, r, decode }
}

/// Evaluate with one label per input wire (const-1 first, then garbler
/// inputs, then evaluator inputs). Returns the output labels.
pub fn evaluate(circ: &Circuit, tables: &[(u128, u128)], input_labels: &[u128]) -> Vec<u128> {
    let n_in = 1 + circ.n_garbler + circ.n_eval;
    assert_eq!(input_labels.len(), n_in);
    let mut wires = vec![0u128; circ.n_wires];
    wires[..n_in].copy_from_slice(input_labels);
    let mut gate_index = 0u64;
    let mut t = 0usize;
    for g in &circ.gates {
        match *g {
            Gate::Xor { a, b, out } => {
                wires[out as usize] = wires[a as usize] ^ wires[b as usize];
            }
            Gate::And { a, b, out } => {
                let la = wires[a as usize];
                let lb = wires[b as usize];
                let (tg, te) = tables[t];
                let (j0, j1) = (gate_index * 2, gate_index * 2 + 1);
                let wg = h(la, j0) ^ if lsb(la) { tg } else { 0 };
                let we = h(lb, j1) ^ if lsb(lb) { te ^ la } else { 0 };
                wires[out as usize] = wg ^ we;
                gate_index += 1;
                t += 1;
            }
        }
    }
    circ.outputs.iter().map(|&o| wires[o as usize]).collect()
}

/// Decode output labels with the garbler's decode bits.
pub fn decode(labels: &[u128], decode_bits: &[bool]) -> Vec<bool> {
    labels.iter().zip(decode_bits).map(|(l, &d)| lsb(*l) ^ d).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gc::builder::{assign_circuit, Builder};

    fn bits(x: u64, w: usize) -> Vec<bool> {
        (0..w).map(|i| (x >> i) & 1 == 1).collect()
    }

    /// Garble + evaluate with known inputs; compare against eval_plain.
    fn run_gc(circ: &Circuit, g_bits: &[bool], e_bits: &[bool], seed: u128) -> Vec<bool> {
        let mut prg = Prg::new(seed);
        let gb = garble(circ, &mut prg);
        let mut labels = gb.garbler_labels(circ, g_bits);
        for (i, &b) in e_bits.iter().enumerate() {
            let (l0, l1) = gb.labels(circ.eval_input(i));
            labels.push(if b { l1 } else { l0 });
        }
        let out = evaluate(circ, &gb.tables, &labels);
        decode(&out, &gb.decode)
    }

    #[test]
    fn and_xor_gate_truth_tables() {
        let mut b = Builder::new(1, 1);
        let x = b.garbler_input(0);
        let y = b.eval_input(0);
        let a = b.and(x, y);
        let o = b.xor(a, x);
        let circ = b.build(vec![a, o]);
        for gx in [false, true] {
            for ey in [false, true] {
                let got = run_gc(&circ, &[gx], &[ey], 7);
                assert_eq!(got, circ.eval_plain(&[gx], &[ey]), "g={gx} e={ey}");
            }
        }
    }

    #[test]
    fn garbled_adder_matches_plain() {
        let w = 16;
        let mut b = Builder::new(w, w);
        let x = b.garbler_word(0, w);
        let y = b.eval_word(0, w);
        let s = b.add(&x, &y);
        let circ = b.build(s);
        for (seed, (a, bb)) in [(1u128, (12345u64, 54321u64)), (2, (65535, 2)), (3, (0, 0))]
        {
            let got = run_gc(&circ, &bits(a, w), &bits(bb, w), seed);
            let got_val: u64 = got.iter().enumerate().map(|(i, &v)| (v as u64) << i).sum();
            assert_eq!(got_val, (a + bb) & 0xFFFF);
        }
    }

    #[test]
    fn garbled_assign_circuit_matches_plain() {
        let (k, w) = (5, 24);
        let circ = assign_circuit(k, w);
        let dvals: [i64; 5] = [100, 3, -44, 9, -43];
        let shares0: [u64; 5] = [7, 1 << 20, 999, 123456, 42];
        let g: Vec<bool> = (0..k).flat_map(|j| bits(shares0[j], w)).collect();
        let e: Vec<bool> = (0..k)
            .flat_map(|j| bits((dvals[j] as u64).wrapping_sub(shares0[j]), w))
            .collect();
        let got = run_gc(&circ, &g, &e, 11);
        assert_eq!(got, circ.eval_plain(&g, &e));
        assert_eq!(got, vec![false, false, true, false, false]); // -44 wins
    }

    #[test]
    fn wrong_label_does_not_decode_to_valid_row() {
        // Flipping one input label must corrupt the output (no partial
        // information — sanity, not a security proof).
        let mut b = Builder::new(1, 1);
        let x = b.garbler_input(0);
        let y = b.eval_input(0);
        let a = b.and(x, y);
        let circ = b.build(vec![a]);
        let mut prg = Prg::new(5);
        let gb = garble(&circ, &mut prg);
        let mut labels = gb.garbler_labels(&circ, &[true]);
        let (l0, _l1) = gb.labels(circ.eval_input(0));
        labels.push(l0 ^ 0xDEADBEEF); // corrupted label
        let out = evaluate(&circ, &gb.tables, &labels);
        let (o0, o1) = gb.labels(circ.outputs[0]);
        assert!(out[0] != o0 && out[0] != o1, "corrupt label must not map to a valid output");
    }
}
