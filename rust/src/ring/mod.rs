//! The ring `Z_{2^64}` and fixed-point arithmetic over it.
//!
//! All secret-shared values live in `Z_{2^64}` represented as `u64` with
//! wrapping arithmetic (the paper uses l = 64, §5.1). Real numbers are
//! embedded with a two's-complement fixed-point encoding with
//! [`fixed::FRAC_BITS`] fractional bits (the paper uses 20 of 64 bits).

pub mod fixed;
pub mod matrix;

/// Ring word: an element of Z_{2^64}.
pub type Rw = u64;

/// Wrapping dot product of two equal-length slices in Z_{2^64}.
#[inline]
pub fn dot(a: &[Rw], b: &[Rw]) -> Rw {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0u64;
    for i in 0..a.len() {
        acc = acc.wrapping_add(a[i].wrapping_mul(b[i]));
    }
    acc
}

/// Elementwise wrapping add: `a += b`.
#[inline]
pub fn add_assign(a: &mut [Rw], b: &[Rw]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        a[i] = a[i].wrapping_add(b[i]);
    }
}

/// Elementwise wrapping sub: `a -= b`.
#[inline]
pub fn sub_assign(a: &mut [Rw], b: &[Rw]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        a[i] = a[i].wrapping_sub(b[i]);
    }
}

/// Elementwise wrapping product into a new vector.
pub fn mul_elem(a: &[Rw], b: &[Rw]) -> Vec<Rw> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x.wrapping_mul(*y)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_wraps() {
        let a = [u64::MAX, 2];
        let b = [2, 3];
        // MAX*2 = 2^65-2 = -2 mod 2^64; -2 + 6 = 4
        assert_eq!(dot(&a, &b), 4);
    }

    #[test]
    fn add_sub_roundtrip() {
        let mut a = vec![1u64, u64::MAX, 7];
        let b = vec![5u64, 1, u64::MAX];
        let orig = a.clone();
        add_assign(&mut a, &b);
        sub_assign(&mut a, &b);
        assert_eq!(a, orig);
    }
}
