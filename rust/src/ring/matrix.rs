//! Dense row-major matrices over Z_{2^64}.
//!
//! This is the workhorse container for secret shares and plaintext
//! fixed-point data. Matmul is blocked for cache locality; the runtime
//! module can alternatively dispatch large products to the AOT-compiled
//! XLA ring-matmul artifact (see [`crate::runtime::tiled`]).

use super::Rw;
use crate::ring::fixed;
use crate::util::prng::Prg;

/// Row-major dense matrix over Z_{2^64}.
#[derive(Clone, PartialEq, Eq)]
pub struct Mat {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major element buffer (`rows * cols` ring words).
    pub data: Vec<Rw>,
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0; rows * cols] }
    }

    /// Matrix from an explicit row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<Rw>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Mat { rows, cols, data }
    }

    /// Build elementwise from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> Rw) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Uniformly random matrix from a PRG (used for shares and masks).
    pub fn random(rows: usize, cols: usize, prg: &mut Prg) -> Self {
        let mut data = vec![0u64; rows * cols];
        prg.fill_u64s(&mut data);
        Mat { rows, cols, data }
    }

    /// Encode a real-valued row-major buffer with fixed-point scaling.
    pub fn encode(rows: usize, cols: usize, xs: &[f64]) -> Self {
        assert_eq!(xs.len(), rows * cols);
        Mat { rows, cols, data: fixed::encode_slice(xs) }
    }

    /// Decode back to reals.
    pub fn decode(&self) -> Vec<f64> {
        fixed::decode_slice(&self.data)
    }

    /// Element at (row, col).
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> Rw {
        self.data[r * self.cols + c]
    }

    /// Overwrite the element at (row, col).
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: Rw) {
        self.data[r * self.cols + c] = v;
    }

    /// Borrow one row.
    #[inline]
    pub fn row(&self, r: usize) -> &[Rw] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [Rw] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `(rows, cols)` pair.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Total element count (`rows * cols`).
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the matrix holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Transpose into a new matrix.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Wrapping elementwise sum.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let data =
            self.data.iter().zip(&other.data).map(|(a, b)| a.wrapping_add(*b)).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Wrapping elementwise difference.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let data =
            self.data.iter().zip(&other.data).map(|(a, b)| a.wrapping_sub(*b)).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Wrapping elementwise (Hadamard) product.
    pub fn hadamard(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let data =
            self.data.iter().zip(&other.data).map(|(a, b)| a.wrapping_mul(*b)).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Multiply every element by a ring scalar.
    pub fn scale(&self, s: Rw) -> Mat {
        let data = self.data.iter().map(|a| a.wrapping_mul(s)).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Wrapping negation.
    pub fn neg(&self) -> Mat {
        let data = self.data.iter().map(|a| a.wrapping_neg()).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Apply a function to every element.
    pub fn map(&self, f: impl Fn(Rw) -> Rw) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Blocked wrapping matmul `self (m×k) · other (k×n) -> (m×n)`.
    ///
    /// i-k-j loop order with the `other` row kept hot; the inner axpy
    /// runs as a packed lanewise sweep ([`crate::runtime::simd::axpy`]).
    /// This is the native fallback, the PJRT path handles large shapes
    /// (see runtime::tiled).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(
            self.cols, other.rows,
            "matmul shape mismatch {}x{} · {}x{}",
            self.rows, self.cols, other.rows, other.cols
        );
        let (m, kk, n) = (self.rows, self.cols, other.cols);
        let mut out = vec![0u64; m * n];
        for i in 0..m {
            let arow = &self.data[i * kk..(i + 1) * kk];
            let orow = &mut out[i * n..(i + 1) * n];
            for k in 0..kk {
                let a = arow[k];
                if a == 0 {
                    continue; // free sparsity skip in the plaintext-side product
                }
                let brow = &other.data[k * n..(k + 1) * n];
                crate::runtime::simd::axpy(orow, a, brow);
            }
        }
        Mat { rows: m, cols: n, data: out }
    }

    /// Column sums as a 1×cols matrix (used for `1_{1×n}·C`).
    pub fn col_sums(&self) -> Mat {
        let mut out = vec![0u64; self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            for c in 0..self.cols {
                out[c] = out[c].wrapping_add(row[c]);
            }
        }
        Mat { rows: 1, cols: self.cols, data: out }
    }

    /// Stack rows of `self` above rows of `other` (same cols).
    pub fn vstack(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Concatenate columns of `self` with columns of `other` (same rows).
    pub fn hstack(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(other.row(r));
        }
        out
    }

    /// Slice a block of columns `[c0, c1)`.
    pub fn cols_slice(&self, c0: usize, c1: usize) -> Mat {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Mat::zeros(self.rows, c1 - c0);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Slice a block of rows `[r0, r1)`.
    pub fn rows_slice(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> (Mat, Mat) {
        let a = Mat::from_vec(2, 3, vec![1, 2, 3, 4, 5, 6]);
        let b = Mat::from_vec(3, 2, vec![7, 8, 9, 10, 11, 12]);
        (a, b)
    }

    #[test]
    fn matmul_matches_hand_computed() {
        let (a, b) = small();
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58, 64, 139, 154]);
    }

    #[test]
    fn matmul_wraps_mod_2_64() {
        let a = Mat::from_vec(1, 1, vec![u64::MAX]);
        let b = Mat::from_vec(1, 1, vec![3]);
        assert_eq!(a.matmul(&b).data, vec![u64::MAX - 2]); // -3 mod 2^64
    }

    #[test]
    fn transpose_involution() {
        let (a, _) = small();
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn add_sub_neg() {
        let (a, _) = small();
        let z = a.add(&a.neg());
        assert!(z.data.iter().all(|&x| x == 0));
        assert_eq!(a.sub(&a).data, vec![0; 6]);
    }

    #[test]
    fn stack_and_slice_roundtrip() {
        let (a, _) = small();
        let v = a.vstack(&a);
        assert_eq!(v.rows_slice(2, 4), a);
        let h = a.hstack(&a);
        assert_eq!(h.cols_slice(3, 6), a);
    }

    #[test]
    fn col_sums_matches_manual() {
        let (a, _) = small();
        assert_eq!(a.col_sums().data, vec![5, 7, 9]);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let xs = vec![0.5, -1.25, 3.0, 0.0];
        let m = Mat::encode(2, 2, &xs);
        let back = m.decode();
        for (x, y) in xs.iter().zip(&back) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn random_shares_reconstruct() {
        let mut prg = Prg::new(5);
        let x = Mat::from_vec(2, 2, vec![10, 20, 30, 40]);
        let s0 = Mat::random(2, 2, &mut prg);
        let s1 = x.sub(&s0);
        assert_eq!(s0.add(&s1), x);
    }
}
