//! Fixed-point embedding of reals into Z_{2^64}.
//!
//! The paper (§5.1) uses l = 64-bit ring elements with 20 fractional
//! bits. A real `x` is encoded as `round(x * 2^20)` interpreted as a
//! two's-complement 64-bit integer; products of two encoded values carry
//! scale `2^40` and must be truncated by [`FRAC_BITS`] (see
//! [`crate::ss::trunc`] for the secret-shared version).

use super::Rw;

/// Number of fractional bits (paper: 20 of 64).
pub const FRAC_BITS: u32 = 20;

/// The scale factor 2^FRAC_BITS as f64.
pub const SCALE: f64 = (1u64 << FRAC_BITS) as f64;

/// Encode a real into the ring (two's complement fixed point).
#[inline]
pub fn encode_f64(x: f64) -> Rw {
    (x * SCALE).round() as i64 as u64
}

/// Decode a ring element back to a real.
#[inline]
pub fn decode_f64(w: Rw) -> f64 {
    (w as i64) as f64 / SCALE
}

/// Encode a slice of reals.
pub fn encode_slice(xs: &[f64]) -> Vec<Rw> {
    xs.iter().map(|&x| encode_f64(x)).collect()
}

/// Decode a slice of ring elements.
pub fn decode_slice(ws: &[Rw]) -> Vec<f64> {
    ws.iter().map(|&w| decode_f64(w)).collect()
}

/// Encode an integer (no fractional scaling) into the ring.
#[inline]
pub fn encode_int(x: i64) -> Rw {
    x as u64
}

/// Plaintext truncation by FRAC_BITS: arithmetic shift right preserving
/// the sign of the embedded value. Matches what the secure truncation
/// protocol computes (up to its ±1 ulp probabilistic error).
#[inline]
pub fn truncate(w: Rw) -> Rw {
    ((w as i64) >> FRAC_BITS) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_positive_negative() {
        for &x in &[0.0, 1.0, -1.0, 3.141592, -123.456, 1e4, -1e4] {
            let w = encode_f64(x);
            assert!((decode_f64(w) - x).abs() < 1.0 / SCALE, "x={x}");
        }
    }

    #[test]
    fn additive_homomorphism() {
        let a = encode_f64(1.25);
        let b = encode_f64(-3.5);
        assert!((decode_f64(a.wrapping_add(b)) - (1.25 - 3.5)).abs() < 2.0 / SCALE);
    }

    #[test]
    fn product_needs_one_truncation() {
        let a = encode_f64(2.5);
        let b = encode_f64(-1.5);
        let prod = truncate(a.wrapping_mul(b));
        assert!((decode_f64(prod) - (2.5 * -1.5)).abs() < 4.0 / SCALE);
    }

    #[test]
    fn truncate_matches_float_division_for_negatives() {
        let w = encode_f64(-7.75);
        let t = truncate(w.wrapping_mul(encode_f64(1.0)));
        assert!((decode_f64(t) - -7.75).abs() < 4.0 / SCALE);
    }

    #[test]
    fn encode_int_is_unscaled() {
        assert_eq!(encode_int(-1), u64::MAX);
        assert_eq!(encode_int(5), 5);
    }
}
