//! Base oblivious transfer: Chou-Orlandi "simplest OT" over a classic
//! MODP Schnorr group (RFC 3526 1536-bit group).
//!
//! λ = 128 base OTs seed the IKNP extension ([`super::iknp`]); each
//! transfers a 16-byte PRG seed. Sender: `A = g^a`; receiver with choice
//! `c`: `B = g^b·A^c`; keys `k0 = H(B^a)`, `k1 = H((B/A)^a)` for the
//! sender and `k_c = H(A^b)` for the receiver.

use crate::bigint::modular::{mod_inv, Montgomery};
use crate::bigint::BigUint;
use crate::net::Chan;
use crate::util::hash::Hash256;
use crate::util::prng::Prg;

/// RFC 3526 group 5 (1536-bit MODP).
const MODP_1536_HEX: &str = concat!(
    "FFFFFFFFFFFFFFFFC90FDAA22168C234C4C6628B80DC1CD129024E088A67CC74",
    "020BBEA63B139B22514A08798E3404DDEF9519B3CD3A431B302B0A6DF25F1437",
    "4FE1356D6D51C245E485B576625E7EC6F44C42E9A637ED6B0BFF5CB6F406B7ED",
    "EE386BFB5A899FA5AE9F24117C4B1FE649286651ECE45B3DC2007CB8A163BF05",
    "98DA48361C55D39A69163FA8FD24CF5F83655D23DCA3AD961C62F356208552BB",
    "9ED529077096966D670C354E4ABC9804F1746C08CA237327FFFFFFFFFFFFFFFF"
);

/// Parse a hex string into a BigUint.
pub fn from_hex(s: &str) -> BigUint {
    let mut acc = BigUint::zero();
    for ch in s.bytes() {
        let nib = (ch as char).to_digit(16).expect("hex digit") as u64;
        acc = acc.shl(4).add(&BigUint::from_u64(nib));
    }
    acc
}

/// The Diffie-Hellman group used by base OTs.
pub struct OtGroup {
    /// The group modulus (a safe prime).
    pub p: BigUint,
    /// The generator.
    pub g: BigUint,
    mont: Montgomery,
    /// Exponent width in bits (256-bit exponents give 128-bit security
    /// against discrete log in a 1536-bit group's large subgroup).
    exp_bits: usize,
}

impl OtGroup {
    /// The standard RFC 3526 1536-bit group, generator 2.
    pub fn rfc3526() -> OtGroup {
        let p = from_hex(MODP_1536_HEX);
        let mont = Montgomery::new(&p);
        OtGroup { g: BigUint::from_u64(2), mont, p, exp_bits: 256 }
    }

    fn rand_exp(&self, prg: &mut Prg) -> BigUint {
        BigUint::from_limbs((0..self.exp_bits / 64).map(|_| prg.next_u64()).collect())
    }

    fn pow_g(&self, e: &BigUint) -> BigUint {
        self.mont.pow(&self.g, e)
    }

    fn pow(&self, b: &BigUint, e: &BigUint) -> BigUint {
        self.mont.pow(b, e)
    }

    fn mul(&self, a: &BigUint, b: &BigUint) -> BigUint {
        self.mont.mul(a, b)
    }

    fn inv(&self, a: &BigUint) -> BigUint {
        mod_inv(a, &self.p).expect("group element invertible")
    }

    fn elem_bytes(&self) -> usize {
        (self.p.bits() + 7) / 8
    }

    fn ser(&self, x: &BigUint) -> Vec<u8> {
        let mut out = vec![0u8; self.elem_bytes()];
        let raw = x.to_bytes_be();
        let off = out.len() - raw.len();
        out[off..].copy_from_slice(&raw);
        out
    }
}

/// Hash a group element to a 16-byte OT seed (in-repo [`Hash256`] — the
/// parties only need to agree on the function).
fn hash_seed(domain: u64, x: &BigUint) -> [u8; 16] {
    let mut h = Hash256::new();
    h.update(domain.to_le_bytes());
    h.update(x.to_bytes_be());
    let d = h.finalize();
    d[..16].try_into().unwrap()
}

/// Sender side: run `count` base OTs, returning per-OT key pairs
/// `(k0, k1)` (16-byte seeds).
pub fn base_ot_send(
    chan: &mut Chan,
    group: &OtGroup,
    count: usize,
    prg: &mut Prg,
) -> Vec<([u8; 16], [u8; 16])> {
    let a = group.rand_exp(prg);
    let big_a = group.pow_g(&a);
    chan.send_bytes(&group.ser(&big_a));
    let a_inv_pow = group.pow(&group.inv(&big_a), &a); // A^{-a}
    // Receive all B_i in one frame.
    let payload = chan.recv_bytes();
    let w = group.elem_bytes();
    assert_eq!(payload.len(), count * w);
    let mut keys = Vec::with_capacity(count);
    for (i, chunk) in payload.chunks_exact(w).enumerate() {
        let b = BigUint::from_bytes_be(chunk);
        let ba = group.pow(&b, &a);
        let k0 = hash_seed(i as u64, &ba);
        let k1 = hash_seed(i as u64, &group.mul(&ba, &a_inv_pow));
        keys.push((k0, k1));
    }
    keys
}

/// Receiver side: run base OTs with the given choice bits, returning
/// `k_{c_i}` per OT.
pub fn base_ot_recv(
    chan: &mut Chan,
    group: &OtGroup,
    choices: &[bool],
    prg: &mut Prg,
) -> Vec<[u8; 16]> {
    let w = group.elem_bytes();
    let a_bytes = chan.recv_bytes();
    assert_eq!(a_bytes.len(), w);
    let big_a = BigUint::from_bytes_be(&a_bytes);
    let mut payload = Vec::with_capacity(choices.len() * w);
    let mut bs = Vec::with_capacity(choices.len());
    for &c in choices {
        let b = group.rand_exp(prg);
        let gb = group.pow_g(&b);
        let big_b = if c { group.mul(&big_a, &gb) } else { gb };
        payload.extend_from_slice(&group.ser(&big_b));
        bs.push(b);
    }
    chan.send_bytes(&payload);
    bs.iter()
        .enumerate()
        .map(|(i, b)| hash_seed(i as u64, &group.pow(&big_a, b)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::run_two_party;

    #[test]
    fn hex_parse() {
        assert_eq!(from_hex("ff"), BigUint::from_u64(255));
        assert_eq!(from_hex("100"), BigUint::from_u64(256));
        let p = OtGroup::rfc3526().p;
        assert_eq!(p.bits(), 1536);
    }

    #[test]
    fn base_ot_correctness() {
        let choices = vec![true, false, true, true, false];
        let ch = choices.clone();
        let ((keys, _), (recv, _)) = run_two_party(
            move |c| {
                let g = OtGroup::rfc3526();
                let mut prg = Prg::new(101);
                base_ot_send(c, &g, 5, &mut prg)
            },
            move |c| {
                let g = OtGroup::rfc3526();
                let mut prg = Prg::new(102);
                base_ot_recv(c, &g, &ch, &mut prg)
            },
        );
        for i in 0..choices.len() {
            let want = if choices[i] { keys[i].1 } else { keys[i].0 };
            assert_eq!(recv[i], want, "ot {i}");
            let other = if choices[i] { keys[i].0 } else { keys[i].1 };
            assert_ne!(recv[i], other, "ot {i} must not learn the other key");
        }
    }
}
