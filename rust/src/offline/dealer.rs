//! PRG-simulated trusted dealer.
//!
//! Both parties hold the same dealer seed and deterministically expand
//! identical correlated randomness; each keeps only its own share. This
//! models a trusted third party distributing triples out-of-band (the
//! paper: "this step ... can be prepared in advance as an offline phase,
//! using either cryptography-based methods or a trusted third party").
//! Protocol communication: zero. The [`crate::ss::triples::Ledger`]
//! still records consumption so benches can price the material as if it
//! had been produced by the OT generator.

use crate::ring::matrix::Mat;
use crate::ss::triples::{
    bit_words, last_word_mask, BitTriple, DaBits, Ledger, MatTriple, TripleSource, VecTriple,
};
use crate::util::prng::Prg;

/// One party's endpoint of the simulated dealer.
pub struct Dealer {
    prg: Prg,
    party: usize,
    ledger: Ledger,
}

impl Dealer {
    /// `seed` must match across the two parties; `party` ∈ {0, 1}.
    pub fn new(seed: u128, party: usize) -> Self {
        assert!(party < 2);
        Dealer { prg: Prg::new(seed ^ 0xD0_1E_55), party, ledger: Ledger::default() }
    }
}

impl TripleSource for Dealer {
    fn mat_triple(&mut self, m: usize, k: usize, n: usize) -> MatTriple {
        self.ledger.mat_triples += 1;
        self.ledger.mat_triple_elems += (m * k + k * n + m * n) as u64;
        // Both parties expand the *same* stream: full U, V, then share-0s.
        let u = Mat::random(m, k, &mut self.prg);
        let v = Mat::random(k, n, &mut self.prg);
        let u0 = Mat::random(m, k, &mut self.prg);
        let v0 = Mat::random(k, n, &mut self.prg);
        let z0 = Mat::random(m, n, &mut self.prg);
        if self.party == 0 {
            MatTriple { u: u0, v: v0, z: z0 }
        } else {
            let z = u.matmul(&v);
            MatTriple { u: u.sub(&u0), v: v.sub(&v0), z: z.sub(&z0) }
        }
    }

    fn vec_triple(&mut self, n: usize) -> VecTriple {
        self.ledger.vec_triple_lanes += n as u64;
        let u = self.prg.u64s(n);
        let v = self.prg.u64s(n);
        let u0 = self.prg.u64s(n);
        let v0 = self.prg.u64s(n);
        let z0 = self.prg.u64s(n);
        if self.party == 0 {
            VecTriple { u: u0, v: v0, z: z0 }
        } else {
            let u1: Vec<u64> = u.iter().zip(&u0).map(|(a, b)| a.wrapping_sub(*b)).collect();
            let v1: Vec<u64> = v.iter().zip(&v0).map(|(a, b)| a.wrapping_sub(*b)).collect();
            let z1: Vec<u64> = (0..n)
                .map(|i| u[i].wrapping_mul(v[i]).wrapping_sub(z0[i]))
                .collect();
            VecTriple { u: u1, v: v1, z: z1 }
        }
    }

    fn bit_triple(&mut self, n: usize) -> BitTriple {
        self.ledger.bit_triple_lanes += n as u64;
        let w = bit_words(n);
        let a = self.prg.u64s(w);
        let b = self.prg.u64s(w);
        let a0 = self.prg.u64s(w);
        let b0 = self.prg.u64s(w);
        let c0 = self.prg.u64s(w);
        if self.party == 0 {
            BitTriple { a: a0, b: b0, c: c0, n }
        } else {
            let a1: Vec<u64> = a.iter().zip(&a0).map(|(x, y)| x ^ y).collect();
            let b1: Vec<u64> = b.iter().zip(&b0).map(|(x, y)| x ^ y).collect();
            let c1: Vec<u64> = (0..w).map(|i| (a[i] & b[i]) ^ c0[i]).collect();
            BitTriple { a: a1, b: b1, c: c1, n }
        }
    }

    fn dabits(&mut self, n: usize) -> DaBits {
        self.ledger.dabit_lanes += n as u64;
        let w = bit_words(n);
        // Full bit vector r, then party-0's boolean and arithmetic pads.
        let r = self.prg.u64s(w);
        let b0 = self.prg.u64s(w);
        let a0 = self.prg.u64s(n);
        if self.party == 0 {
            let mut bool_words = b0;
            if let Some(last) = bool_words.last_mut() {
                *last &= last_word_mask(n);
            }
            DaBits { n, bool_words, arith: a0 }
        } else {
            let mut bool_words: Vec<u64> = r.iter().zip(&b0).map(|(x, y)| x ^ y).collect();
            if let Some(last) = bool_words.last_mut() {
                *last &= last_word_mask(n);
            }
            let arith: Vec<u64> = (0..n)
                .map(|i| ((r[i / 64] >> (i % 64)) & 1).wrapping_sub(a0[i]))
                .collect();
            DaBits { n, bool_words, arith }
        }
    }

    fn ledger(&self) -> Ledger {
        self.ledger
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_triples_reconstruct_to_products() {
        let mut d0 = Dealer::new(99, 0);
        let mut d1 = Dealer::new(99, 1);
        for (m, k, n) in [(1, 1, 1), (2, 3, 4), (5, 2, 5)] {
            let t0 = d0.mat_triple(m, k, n);
            let t1 = d1.mat_triple(m, k, n);
            let u = t0.u.add(&t1.u);
            let v = t0.v.add(&t1.v);
            let z = t0.z.add(&t1.z);
            assert_eq!(u.matmul(&v), z, "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn vec_triples_reconstruct() {
        let mut d0 = Dealer::new(5, 0);
        let mut d1 = Dealer::new(5, 1);
        let t0 = d0.vec_triple(100);
        let t1 = d1.vec_triple(100);
        for i in 0..100 {
            let u = t0.u[i].wrapping_add(t1.u[i]);
            let v = t0.v[i].wrapping_add(t1.v[i]);
            let z = t0.z[i].wrapping_add(t1.z[i]);
            assert_eq!(u.wrapping_mul(v), z, "lane {i}");
        }
    }

    #[test]
    fn bit_triples_reconstruct() {
        let mut d0 = Dealer::new(6, 0);
        let mut d1 = Dealer::new(6, 1);
        let t0 = d0.bit_triple(200);
        let t1 = d1.bit_triple(200);
        for i in 0..t0.a.len() {
            let a = t0.a[i] ^ t1.a[i];
            let b = t0.b[i] ^ t1.b[i];
            let c = t0.c[i] ^ t1.c[i];
            assert_eq!(a & b, c, "word {i}");
        }
    }

    #[test]
    fn dabits_agree_across_worlds() {
        let mut d0 = Dealer::new(12, 0);
        let mut d1 = Dealer::new(12, 1);
        let n = 70;
        let a = d0.dabits(n);
        let b = d1.dabits(n);
        for i in 0..n {
            let bool_bit = ((a.bool_words[i / 64] ^ b.bool_words[i / 64]) >> (i % 64)) & 1;
            let arith_bit = a.arith[i].wrapping_add(b.arith[i]);
            assert_eq!(bool_bit, arith_bit, "lane {i}: XOR and additive worlds disagree");
            assert!(arith_bit <= 1, "lane {i}: not a bit");
        }
        // Tail lanes beyond n are masked off in the boolean packing.
        let tail = a.bool_words[1] ^ b.bool_words[1];
        assert_eq!(tail >> (n - 64), 0, "tail bits must be masked");
    }

    #[test]
    fn shares_look_independent_of_secret() {
        // Party 0's share stream must not depend on which party asks —
        // i.e. dealer outputs for party 0 are pure PRG output.
        let mut a = Dealer::new(7, 0);
        let mut b = Dealer::new(7, 0);
        let ta = a.mat_triple(2, 2, 2);
        let tb = b.mat_triple(2, 2, 2);
        assert_eq!(ta.u, tb.u);
        assert_eq!(ta.z, tb.z);
    }

    #[test]
    fn ledger_counts_material() {
        let mut d = Dealer::new(8, 0);
        d.mat_triple(2, 3, 4);
        d.vec_triple(10);
        d.bit_triple(65);
        let l = d.ledger();
        assert_eq!(l.mat_triples, 1);
        assert_eq!(l.mat_triple_elems, (6 + 12 + 8) as u64);
        assert_eq!(l.vec_triple_lanes, 10);
        assert_eq!(l.bit_triple_lanes, 65);
    }
}
